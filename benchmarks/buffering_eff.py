"""Buffering-mechanism effectiveness (paper Fig. 16): with streaming on, the
live working set shrinks (paper: −37 % heap) for a small step-time overhead
(paper: +8 %).

Here: the same train step compiled with and without microbatch streaming;
memory = XLA's temp-buffer estimate from memory_analysis(), time = measured
CPU wall clock."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.executor import plan_and_compile
from repro.core.ir import SystemCatalog
from repro.models import build_model
from repro.models.lm import CATALOG
from repro.train.optim import cosine_schedule, make_optimizer
from repro.train.train_step import init_state, make_train_step

from .common import emit, time_fn

SYS = SystemCatalog()


def main():
    cfg = get_smoke_config("qwen3-0.6b").replace(
        dtype="float32", n_layers=4, d_model=128, heads=8, kv_heads=4,
        head_dim=16, d_ff=512)
    model = build_model(cfg)
    b, s = 32, 128
    plan = model.build_plan(b, s, mode="train")
    fwd = plan_and_compile(plan, CATALOG, SYS, buffering=True,
                           global_batch=b)
    opt = make_optimizer("adamw", cosine_schedule(1e-3, 2, 100))
    params, _ = model.init_params(jax.random.key(0))
    state = init_state(params, opt)
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

    rows, res = [], {}
    for mode, nmb in (("blocking", 1),
                      ("buffered", fwd.buffering.num_microbatches)):
        step = make_train_step(fwd, opt, num_microbatches=nmb,
                               grad_dtype="float32")
        jstep = jax.jit(step)
        comp = jstep.lower(jax.eval_shape(lambda: state),
                           {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                            for k, v in batch.items()}).compile()
        temp = comp.memory_analysis().temp_size_in_bytes
        sec = time_fn(jstep, state, batch, warmup=1, iters=3)
        res[mode] = (temp, sec)
        rows.append((f"buffering/{mode}", sec * 1e6,
                     f"microbatches={nmb} temp_bytes={temp}"))
    dm = 1 - res["buffered"][0] / res["blocking"][0]
    dt = res["buffered"][1] / res["blocking"][1] - 1
    rows.append(("buffering/effect", 0.0,
                 f"temp_mem_reduction={dm * 100:.1f}% "
                 f"time_overhead={dt * 100:+.1f}% "
                 f"(paper: 37% heap reduction, +8% time)"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
