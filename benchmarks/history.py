"""Benchmark history + the CI perf-regression gate.

Every ``merge_report`` write appends one git-SHA-stamped JSONL record to
``BENCH_history.jsonl`` — section name, provenance (commit, device count,
mesh shape, platform, smoke flag), and the section's *pinned metrics*
(the wall-times the guard bars already watch).  The ``check`` subcommand
compares the newest record per section against the previous run's history
artifact and fails on material slowdown:

    python -m benchmarks.history check \
        --prev prev/BENCH_history.jsonl --new BENCH_history.jsonl \
        --threshold 0.20

Records are only compared when their provenance matches (same smoke flag,
same device count): an 8-device sweep regressing against a 1-device sweep
would be noise, not signal.  A missing previous artifact (first run,
expired artifact) passes with a notice — the gate bootstraps itself.
"""
import argparse
import json
import os
import sys
import time


def extract_metrics(section: str, report: dict) -> dict:
    """The pinned wall-time metrics per section: the *optimized* path's
    time, keyed so sweeps compare pointwise (per selectivity / per size),
    not as an average that hides a regressed point."""
    out = {}
    if section == "placement":
        if "planned_ms" in report:
            out["planned_ms"] = float(report["planned_ms"])
    elif section == "selective":
        for row in report.get("sweep", ()):
            out[f"pushed_ms@{row['selectivity']:g}"] = \
                float(row["pushed_ms"])
    elif section == "bounded":
        for row in report.get("sweep", ()):
            out[f"compacted_ms@{row['selectivity']:g}"] = \
                float(row["compacted_ms"])
    elif section == "sharded":
        for row in report.get("sweep", ()):
            out[f"sharded_ms@{row['tweets']}"] = float(row["sharded_ms"])
    return out


def append_record(path: str, section: str, report: dict) -> dict:
    """Append one history record for a section run; returns the record."""
    prov = report.get("provenance", {})
    rec = {
        "record": "bench",
        "section": section,
        "ts": prov.get("recorded_at", time.time()),
        "git_sha": prov.get("git_sha", "unknown"),
        "devices": prov.get("devices"),
        "mesh_shape": prov.get("mesh_shape"),
        "platform": prov.get("platform"),
        "smoke": report.get("smoke"),
        "ok": report.get("ok"),
        "metrics": extract_metrics(section, report),
    }
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "a") as fh:
        fh.write(json.dumps(rec) + "\n")
    return rec


def load_history(path: str) -> list:
    """All bench records in file order (corrupt lines skipped)."""
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except Exception:
                continue
            if rec.get("record") == "bench":
                out.append(rec)
    return out


def latest_per_section(records: list) -> dict:
    out = {}
    for rec in records:            # file order: later lines win
        out[rec["section"]] = rec
    return out


def _comparable(prev: dict, new: dict) -> bool:
    return (prev.get("smoke") == new.get("smoke")
            and prev.get("devices") == new.get("devices")
            and prev.get("platform") == new.get("platform"))


def compare(prev_records: list, new_records: list,
            threshold: float = 0.20) -> dict:
    """Newest-per-section diff: every shared pinned metric whose new time
    exceeds ``(1 + threshold) * previous`` is a regression.  Sections or
    metrics present on only one side, and provenance-mismatched pairs,
    are skipped (reported, not failed)."""
    prev_by = latest_per_section(prev_records)
    new_by = latest_per_section(new_records)
    regressions, compared, skipped = [], [], []
    for section, new in sorted(new_by.items()):
        prev = prev_by.get(section)
        if prev is None:
            skipped.append((section, "no previous record"))
            continue
        if not _comparable(prev, new):
            skipped.append((section, "provenance mismatch "
                            f"(prev {prev.get('smoke')}/{prev.get('devices')}"
                            f"dev vs new {new.get('smoke')}/"
                            f"{new.get('devices')}dev)"))
            continue
        for name, new_ms in sorted(new.get("metrics", {}).items()):
            prev_ms = prev.get("metrics", {}).get(name)
            if prev_ms is None or prev_ms <= 0:
                continue
            ratio = new_ms / prev_ms
            row = {"section": section, "metric": name,
                   "prev_ms": prev_ms, "new_ms": new_ms, "ratio": ratio,
                   "prev_sha": prev.get("git_sha"),
                   "new_sha": new.get("git_sha")}
            compared.append(row)
            if ratio > 1.0 + threshold:
                regressions.append(row)
    return {"regressions": regressions, "compared": compared,
            "skipped": skipped, "threshold": threshold}


def check(prev_path: str, new_path: str, threshold: float = 0.20) -> int:
    """The CI gate: exit 1 on any regression past the threshold.  Missing
    or empty previous history passes (bootstrap), as does zero comparable
    metrics — the gate only fails on *evidence* of a slowdown."""
    prev = load_history(prev_path)
    new = load_history(new_path)
    if not new:
        print(f"[history] FAIL: no new records in {new_path}")
        return 1
    if not prev:
        print(f"[history] no previous history at {prev_path}: "
              f"bootstrap run, gate passes")
        return 0
    result = compare(prev, new, threshold)
    for section, why in result["skipped"]:
        print(f"[history] skip {section}: {why}")
    for row in result["compared"]:
        mark = "REGRESSION" if row in result["regressions"] else "ok"
        print(f"[history] {row['section']}/{row['metric']}: "
              f"{row['prev_ms']:.1f} ms ({row['prev_sha']}) -> "
              f"{row['new_ms']:.1f} ms ({row['new_sha']}) = "
              f"{row['ratio']:.2f}x  {mark}")
    if result["regressions"]:
        print(f"[history] FAIL: {len(result['regressions'])} metric(s) "
              f"slower than {1 + threshold:.2f}x the previous run")
        return 1
    if not result["compared"]:
        print("[history] no comparable metrics (all skipped): gate passes")
    else:
        print(f"[history] {len(result['compared'])} metric(s) within "
              f"{1 + threshold:.2f}x: gate passes")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    ap_app = sub.add_parser("append", help="append a record from a report "
                            "JSON section (what merge_report does inline)")
    ap_app.add_argument("--history", default="BENCH_history.jsonl")
    ap_app.add_argument("--report", required=True)
    ap_app.add_argument("--section", required=True)
    ap_chk = sub.add_parser("check", help="compare against the previous "
                            "run's history; exit 1 on regression")
    ap_chk.add_argument("--prev", required=True)
    ap_chk.add_argument("--new", required=True)
    ap_chk.add_argument("--threshold", type=float, default=0.20)
    args = ap.parse_args(argv)
    if args.cmd == "append":
        with open(args.report) as fh:
            doc = json.load(fh)
        section_report = doc.get(args.section, doc)
        rec = append_record(args.history, args.section, section_report)
        print(f"[history] appended {args.section} @ {rec['git_sha']} "
              f"to {args.history}")
        return 0
    return check(args.prev, args.new, args.threshold)


if __name__ == "__main__":
    sys.exit(main())
