"""Map-fusion effectiveness (paper §4.2.3, Fig. 5 + Fig. 15a/b).

Two measurable effects of fusion:
  1. q/k/v-projection fusion: one gemm instead of three — wall time + HLO
     dot count drop;
  2. the fused pattern exposes the larger `fused_attention` match, whose
     candidates avoid the engine-conversion penalty the paper describes
     (JGraphT→Tinkerpop ≙ unfused-projection → attention relayout).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import plan_and_compile
from repro.core.ir import SystemCatalog
from repro.models import build_model
from repro.configs import get_smoke_config
from repro.models.lm import CATALOG

from .common import emit, time_fn

SYS = SystemCatalog()


def main():
    cfg = get_smoke_config("deepseek-7b").replace(dtype="float32")
    model = build_model(cfg)
    b, s = 2, 128
    plan = model.build_plan(b, s, mode="train")
    params, _ = model.init_params(jax.random.key(0))
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

    rows = []
    results = {}
    for mode, pipeline in (("unfused", ("decompose", "cse")),
                           ("fused", None)):
        fwd = plan_and_compile(plan, CATALOG, SYS, rewrite_pipeline=pipeline)
        f = jax.jit(lambda p, bb: fwd(p, bb))
        sec = time_fn(f, params, batch, warmup=1, iters=3)
        lowered = jax.jit(lambda p, bb: fwd(p, bb)).lower(
            jax.eval_shape(lambda: params),
            {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in batch.items()})
        n_dots = lowered.as_text().count("dot_general")
        results[mode] = (sec, n_dots)
        rows.append((f"fusion/{mode}", sec * 1e6, f"hlo_dots={n_dots}"))
    speed = results["unfused"][0] / results["fused"][0]
    rows.append(("fusion/effect", 0.0,
                 f"speedup={speed:.2f}x "
                 f"dots {results['unfused'][1]}->{results['fused'][1]}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
