"""Multi-query serving: cross-query sharing vs sequential run_analysis.

16 concurrent analytical clients over one shared tri-store, with the
overlap profile real dashboards have:

  * 4 **identical** heavy tri-queries (scan -> filter -> agg -> pagerank
    + text relevance) — exact twins, single-flighted to ONE execution;
  * 4 heavy queries that differ **only in the text query vector** — their
    relational/graph prefix (the expensive part) comes out of the subplan
    cache, only the text suffix re-executes (cross-query CSE);
  * 8 **same-shape** light text-relevance queries differing in a declared
    ``batch_param`` leaf — coalesced into ONE vmapped planned forward.

Baseline: the same 16 queries through sequential ``run_analysis`` on a
runtime without a subplan cache (exactly what every query paid before this
change).  Both sides fully warm (XLA primitive caches populated); the
subplan cache is cleared after warmup so the concurrent pass must earn its
sharing during the measured run.

Acceptance (ISSUE 10), asserted here:
  * >= 3x aggregate throughput (>= 2x under ``--smoke``);
  * per-query results bitwise-identical to isolated runs;
  * subplan-cache bytes within the ledger budget, zero leaks after drain.

    PYTHONPATH=src python -m benchmarks.multi_query [--smoke]
    PYTHONPATH=src python -m benchmarks.multi_query --smoke \
        --flight-dir /tmp/flight-mq
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.adil import Analysis
from repro.core.ir import SystemCatalog, TensorT, standard_catalog
from repro.core.ledger import FlightRecorder, MemoryLedger
from repro.core.plan_cache import PlanCache
from repro.models import build_model
from repro.serving import AnalysisRequest, AsyncServingRuntime
from repro.stores import ColumnStore, GraphStore, TextStore, store_engines

from .common import emit

CAT = standard_catalog()
SYS = SystemCatalog()


def build_stores(rng, *, rows, nodes, vocab):
    table = ColumnStore({
        "hashtag": rng.randint(0, nodes, rows).astype(np.int32),
        "doc": np.arange(rows, dtype=np.int32),
        "engagement": (rng.gamma(2.0, 12.0, rows)).astype(np.float32),
    })
    e = rng.randint(0, nodes, (2, rows // 2))
    graph = GraphStore.from_edges(e[0], e[1], nodes, symmetric=True)
    corpus = TextStore.from_docs(
        [rng.randint(0, vocab, rng.randint(3, 10)) for _ in range(rows)],
        vocab)
    return table, graph, corpus


def heavy_analysis(table, graph, corpus, *, iters):
    """The paper's tri-query: relational seed -> pagerank authority +
    text relevance, fused.  The graph side dominates and is independent
    of the text query vector ``q`` — the CSE target."""
    nodes = graph.n_nodes
    with Analysis("pulse", CAT) as a:
        tw = a.bind("tweets", table)
        gr = a.bind("g", graph)
        cx = a.bind("cx", corpus)
        q = a.input("q", TensorT((corpus.vocab,), "float32", ("vocab",)))
        t = a.op("rel_scan", tw)
        hot = a.op("rel_filter", t, col="engagement", cmp="ge", value=30.0)
        seeds = a.op("rel_group_agg", hot, key="hashtag", num_groups=nodes,
                     aggs=(("seed", "count", None),))
        sv = a.op("col_tensor", seeds, col="seed", dim="nodes")
        fr = a.op("graph_expand", gr, sv, hops=2)
        pr = a.op("graph_pagerank", gr, fr, iters=iters, damping=0.85)
        hits = a.op("text_topk", cx, q, k=64)
        j = a.op("rel_join", hits, tw, left_on="doc", right_on="doc")
        trel = a.op("rel_group_agg", j, key="hashtag", num_groups=nodes,
                    aggs=(("textrel", "sum", "score"),))
        tv = a.op("col_tensor", trel, col="textrel", dim="nodes")
        a.store(a.op("residual_add", pr, tv))
    return a, a.compile(SYS, engines=store_engines(), cache=False)


def light_analysis(table, corpus, nodes):
    """Per-hashtag text relevance only — cheap, fully determined by the
    query vector: the vmapped-batching target."""
    with Analysis("textrel", CAT) as a:
        tw = a.bind("tweets", table)
        cx = a.bind("cx", corpus)
        q = a.input("q", TensorT((corpus.vocab,), "float32", ("vocab",)))
        hits = a.op("text_topk", cx, q, k=64)
        j = a.op("rel_join", hits, tw, left_on="doc", right_on="doc")
        trel = a.op("rel_group_agg", j, key="hashtag", num_groups=nodes,
                    aggs=(("textrel", "sum", "score"),))
        a.store(a.op("col_tensor", trel, col="textrel", dim="nodes"))
    return a, a.compile(SYS, engines=store_engines(), cache=False)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized stores")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-mb", type=int, default=64,
                    help="subplan-cache byte budget")
    ap.add_argument("--flight-dir", default=None,
                    help="directory for flight-recorder incident dumps")
    args = ap.parse_args(argv)

    rows = 3_000 if args.smoke else 20_000
    nodes = 64 if args.smoke else 128
    vocab = 64 if args.smoke else 256
    iters = 12 if args.smoke else 24
    target = 2.0 if args.smoke else 3.0

    rng = np.random.RandomState(args.seed)
    table, graph, corpus = build_stores(rng, rows=rows, nodes=nodes,
                                        vocab=vocab)
    ah, fh = heavy_analysis(table, graph, corpus, iters=iters)
    al, fl = light_analysis(table, corpus, nodes)
    ins = {"tweets": table.payload(), "g": graph.payload(),
           "cx": corpus.payload()}
    ins_l = {"tweets": ins["tweets"], "cx": ins["cx"]}

    def qv():
        return jnp.asarray(corpus.query_vector(rng.randint(0, vocab, 6)))

    qa, qb1, qb2 = qv(), qv(), qv()
    qcs = [qv() for _ in range(8)]
    # 16 clients: 4 exact twins + 2x2 prefix-sharing + 8 batchable
    workload = (
        [(fh, {**ins, "q": qa}, None, ah.store_versions())] * 4
        + [(fh, {**ins, "q": qb1}, None, ah.store_versions())] * 2
        + [(fh, {**ins, "q": qb2}, None, ah.store_versions())] * 2
        + [(fl, {**ins_l, "q": q}, "q", al.store_versions()) for q in qcs])
    n = len(workload)

    # isolated references (and XLA primitive-cache warmup for both paths)
    refs = [np.asarray(fn({}, inp)) for fn, inp, _, _ in workload]

    # -- sequential baseline: no subplan cache, one query at a time --------
    cfg = get_smoke_config("qwen3-0.6b").replace(dtype="float32")
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(args.seed))
    rt_seq = AsyncServingRuntime(model, params, max_batch=2, max_seq=32,
                                 plan_cache=PlanCache())
    assert rt_seq.subplans is None
    t0 = time.perf_counter()
    seq_out = [np.asarray(rt_seq.run_analysis(fn, {}, inp))
               for fn, inp, _, _ in workload]
    t_seq = time.perf_counter() - t0

    # -- multi-query path: admission loop + subplan cache ------------------
    ledger = MemoryLedger()
    recorder = FlightRecorder(dump_dir=args.flight_dir)
    budget = args.budget_mb << 20
    rt = AsyncServingRuntime(model, params, max_batch=2, max_seq=32,
                             plan_cache=PlanCache(ledger=ledger),
                             ledger=ledger, recorder=recorder,
                             subplan_budget=budget)
    reqs = [AnalysisRequest(rid=i, planned=fn, inputs=inp, params={},
                            tenant=f"client{i % 4}", batch_param=bp,
                            store_versions=sv)
            for i, (fn, inp, bp, sv) in enumerate(workload)]
    # warmup pass on a throwaway runtime: the isolated-reference loop above
    # warmed the *unbatched* shapes, this warms the vmapped ones (XLA's
    # eager kernel cache is per shape — both paths must exclude compiles,
    # exactly like serving_throughput warms both of its paths)
    rt_warm = AsyncServingRuntime(model, params, max_batch=2, max_seq=32,
                                  plan_cache=PlanCache(),
                                  subplan_budget=budget)
    rt_warm.serve_analyses(reqs, timeout_s=600)
    rt.subplans.clear()                   # the measured pass earns its hits
    t0 = time.perf_counter()
    res = rt.serve_analyses(reqs, timeout_s=600)
    t_conc = time.perf_counter() - t0

    qps_seq, qps_conc = n / t_seq, n / t_conc
    speedup = t_seq / t_conc
    s = rt.metrics.analytics_summary()
    sub = rt.subplans.stats()
    emit([
        ("mq_sequential", t_seq / n * 1e3, f"{qps_seq:.1f} q/s"),
        ("mq_concurrent", t_conc / n * 1e3, f"{qps_conc:.1f} q/s"),
        ("mq_speedup", 0.0, f"{speedup:.2f}x"),
    ])
    print(rt.metrics.analytics_report())
    print(f"[bench] {n} queries: sequential {t_seq:.2f}s "
          f"({qps_seq:.1f} q/s), multi-query {t_conc:.2f}s "
          f"({qps_conc:.1f} q/s) -> {speedup:.2f}x")
    print(f"[bench] shared_hits={s['shared_hits']} deduped={s['deduped']} "
          f"batched={s['batched']}; subplan cache: {sub['entries']} "
          f"entries, {sub['bytes'] / 1e6:.2f} MB / "
          f"{sub['byte_budget'] / 1e6:.0f} MB budget")

    # -- acceptance asserts ------------------------------------------------
    for i, (r, ref, s_out) in enumerate(zip(res, refs, seq_out)):
        assert r.status == "ok", f"query {i} failed: {r.error}"
        got = np.asarray(r.value)
        assert np.array_equal(ref, got), \
            f"query {i}: concurrent result diverged from isolated run"
        assert np.array_equal(ref, s_out), \
            f"query {i}: sequential baseline diverged from isolated run"
    assert s["deduped"] >= 5, f"expected >=5 deduped twins, got {s}"
    assert s["batched"] >= 8, f"expected 8 vmapped-batched queries, got {s}"
    assert s["shared_hits"] >= 1, f"expected subplan-cache reuse, got {s}"
    assert sub["bytes"] <= budget, \
        f"subplan cache over budget: {sub['bytes']} > {budget}"
    led_sub = ledger.snapshot()["by_kind"].get("subplan", 0)
    assert led_sub == sub["bytes"], \
        f"ledger/cache byte mismatch: {led_sub} != {sub['bytes']}"
    rt.subplans.clear()
    assert ledger.snapshot()["by_kind"].get("subplan", 0) == 0
    leaks = ledger.leaks()
    assert not leaks, f"ledger leaks after drain: {leaks}"
    assert speedup >= target, (
        f"multi-query speedup {speedup:.2f}x < {target}x target")
    print(f"[bench] OK: >={target}x aggregate throughput, bitwise-identical "
          "per-query results, subplan cache within budget, zero leaks")
    return speedup


if __name__ == "__main__":
    main()
