"""§5.4 / Appendix C — the paper's negative result, quantified.

The paper proves T1 (pure data parallelism) ≤ T2 (pipeline + data
parallelism) when (a) aggregation cost is negligible and (b) ST operators
stream fast.  We reproduce the analysis with *measured* per-operator costs:

    T1 = (t1 + t2)·m / n + agg·n
    T2 = max(t1·m/n1, t2·m/(n−n1)) + agg·n1      (optimal n1 = t1·n/(t1+t2))

using CPU-measured costs for a producer (attention) / consumer (mlp) chain,
and we check the two premises on our operator set: the aggregation analogue
(loss/grad accumulation) is ≤1 % of block cost, and the chain's ST ops
(norms) emit batches far faster than the PR analytical ops consume them.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.layers import attention as A
from repro.layers import mlp as F
from repro.layers.common import KeyGen, rmsnorm

from .common import emit, time_fn


def main():
    kg = KeyGen(jax.random.key(0))
    b, s, e = 2, 256, 64
    h, d = 4, 16
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(b, s, e), jnp.float32)
    ap, _ = A.init_attention(kg, {"embed": e, "heads": h, "kv_heads": h,
                                  "head_dim": d})
    mp, _ = F.init_mlp(kg, {"embed": e, "ffn": 4 * e})

    def attn(x):
        q = A.project_q(ap, x, h, d)
        k, v = A.project_kv(ap, x, h, d)
        return A.out_project(ap, A.sdpa_full(q, k, v))

    t1 = time_fn(jax.jit(attn), x, warmup=1, iters=3)
    t2 = time_fn(jax.jit(lambda x: F.mlp_fused(mp, x)), x, warmup=1,
                 iters=3)
    t_norm = time_fn(jax.jit(lambda x: rmsnorm(
        x, jnp.zeros((e,)))), x, warmup=1, iters=3)      # the "ST" streamer
    t_agg = time_fn(jax.jit(lambda x: jnp.sum(x)), x, warmup=1, iters=3)

    m, n = 8, 16                       # batches, cores (the paper's setting)
    T1 = (t1 + t2) * m / n + t_agg * n
    n1 = max(1, round(t1 * n / (t1 + t2)))
    T2 = max(t1 * m / n1, t2 * m / (n - n1)) + t_agg * n1

    rows = [
        ("pipeline_vs_dp/op_attention", t1 * 1e6, "producer t1"),
        ("pipeline_vs_dp/op_mlp", t2 * 1e6, "consumer t2"),
        ("pipeline_vs_dp/op_norm_ST", t_norm * 1e6,
         f"streams {t1 / t_norm:.0f}x faster than PR ops (premise 2 holds)"),
        ("pipeline_vs_dp/op_agg", t_agg * 1e6,
         f"agg/block = {t_agg / (t1 + t2) * 100:.2f}% (premise 1 holds)"),
        ("pipeline_vs_dp/T1_dataparallel", T1 * 1e6, ""),
        ("pipeline_vs_dp/T2_pipeline_plus_dp", T2 * 1e6,
         f"optimal n1={n1}"),
        ("pipeline_vs_dp/verdict", 0.0,
         f"T1<=T2: {bool(T1 <= T2 * 1.001)} "
         f"(paper Appendix C inequality, measured costs)"),
    ]
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
