"""Cost-model effectiveness (paper Fig. 14/15 — "bars with stars").

For each multi-candidate pattern, time every candidate physical sub-plan on
CPU across input sizes, and check whether the learned/analytic cost model
selects the actually-fastest one.  Reports per-point winner vs. selection
and overall selection accuracy + regret."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import CostModel
from repro.core.ir import SystemCatalog, TensorT
from repro.layers import attention as A
from repro.layers import moe as X
from repro.layers.common import KeyGen

from .common import emit, time_fn

SYS = SystemCatalog()                      # 1-device catalog for CPU timing


def bench_attention_candidates():
    rows, hits, regrets = [], 0, []
    model = CostModel()
    kg = KeyGen(jax.random.key(0))
    h, kv, d = 4, 2, 16
    window = 32
    cands = {
        "attn_xla": lambda q, k, v: A.sdpa_full(q, k, v, causal=True,
                                                window=0),
        "attn_banded": lambda q, k, v: A.sdpa_banded(q, k, v, window=window),
        "attn_flash": lambda q, k, v: A.sdpa_flash(q, k, v, causal=True,
                                                   window=window,
                                                   interpret=True),
    }
    for seq in (128, 512, 1024):
        rng = np.random.RandomState(seq)
        q = jnp.asarray(rng.randn(1, seq, h, d), jnp.float32)
        k = jnp.asarray(rng.randn(1, seq, kv, d), jnp.float32)
        v = jnp.asarray(rng.randn(1, seq, kv, d), jnp.float32)
        times = {}
        for name, fn in cands.items():
            if name == "attn_flash" and seq > 256:
                continue   # interpret-mode flash too slow to time fairly
            times[name] = time_fn(jax.jit(fn), q, k, v, warmup=1, iters=3)
        t = TensorT((1, seq, h * d), "float32", ("batch", "seq", "embed"))
        attrs = {"heads": h, "kv_heads": kv, "head_dim": d, "window": window,
                 "causal": True}
        est = {"attn_xla": model.op_seconds("sdpa_xla", [t], attrs, SYS),
               "attn_banded": model.op_seconds("sdpa_banded_xla", [t], attrs,
                                               SYS)}
        est = {k2: v2 for k2, v2 in est.items() if k2 in times}
        pick = min(est, key=est.get)
        best = min(times, key=times.get)
        hits += int(pick == best)
        regrets.append(times[pick] / times[best])
        for name, sec in times.items():
            star = "*chosen*" if name == pick else ""
            rows.append((f"cost_model/attn/seq{seq}/{name}", sec * 1e6,
                         f"best={best}{star}"))
    rows.append(("cost_model/attn/selection", 0.0,
                 f"accuracy={hits}/3 regret={np.mean(regrets):.3f}x"))
    return rows


def bench_moe_candidates():
    rows, hits, regrets = [], 0, []
    model = CostModel()
    kg = KeyGen(jax.random.key(1))
    e, f, nx, k = 32, 64, 8, 2
    p, _ = X.init_moe(kg, {"embed": e, "ffn": f, "experts": nx})
    cands = {
        "moe_dense": lambda x: X.moe_dense(p, x, top_k=k, experts=nx),
        "moe_drop": lambda x: X.moe_dropping(p, x, top_k=k, experts=nx),
    }
    for toks in (256, 1024):
        rng = np.random.RandomState(toks)
        x = jnp.asarray(rng.randn(1, toks, e), jnp.float32)
        times = {n: time_fn(jax.jit(fn), x, warmup=1, iters=3)
                 for n, fn in cands.items()}
        t = TensorT((1, toks, e), "float32", ("batch", "seq", "embed"))
        attrs = {"ffn": f, "experts": nx, "top_k": k}
        est = {
            "moe_dense": model.op_seconds("moe_dense_onehot", [t], attrs,
                                          SYS),
            "moe_drop": model.op_seconds("moe_dropping", [t], attrs, SYS),
        }
        pick = min(est, key=est.get)
        best = min(times, key=times.get)
        hits += int(pick == best)
        regrets.append(times[pick] / times[best])
        for name, sec in times.items():
            star = "*chosen*" if name == pick else ""
            rows.append((f"cost_model/moe/toks{toks}/{name}", sec * 1e6,
                         f"best={best}{star}"))
    rows.append(("cost_model/moe/selection", 0.0,
                 f"accuracy={hits}/2 regret={np.mean(regrets):.3f}x"))
    return rows


def main():
    rows = bench_attention_candidates() + bench_moe_candidates()
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
