import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: re-lower the three chosen cells under each
candidate change and report the roofline-term deltas.

Cells (see DESIGN.md):
  1. llama4-maverick-400b × train_4k   — most collective-bound
  2. gemma3-27b × prefill_32k          — technique-representative
  3. qwen3-0.6b × decode_32k           — worst memory-bound fraction
plus the chunked-engine fix for zamba2/rwkv6 train (worst absolute cells).

    PYTHONPATH=src python -m benchmarks.perf_iters [--cell N]
"""
import argparse
import json

from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh
from repro.core.ir import HardwareSpec

HW = HardwareSpec()

CELLS = {
    "llama4_train": ("llama4-maverick-400b-a17b", "train_4k", [
        ("rowgrouped_a2a", {}),
        ("final_mb8", {"num_microbatches": 8}),
        # refuted variants kept for the record (see EXPERIMENTS.md §Perf):
        # expert_nofsdp (unpinned -> 5x replicated compute; pinned -> temp
        # blow-up; the big AR is the Megatron TP activation all-reduce, not
        # expert-weight FSDP), kv/no_fsdp combinations likewise.
    ]),
    "gemma3_prefill": ("gemma3-27b", "prefill_32k", [
        ("baseline", {}),
        ("sharded_store", {}),           # code change vs round-1 baseline
        ("no_fsdp_inference", {"inference_rules": True}),
        ("no_fsdp_bf16",
         {"inference_rules": True,
          "cfg_overrides": {"param_dtype": "bfloat16"}}),
    ]),
    "qwen3_decode": ("qwen3-0.6b", "decode_32k", [
        ("baseline", {}),
        ("kv_seq_sharded", {"kv_shard_seq": True}),      # refuted
        ("kv_dim_sharded", {"kv_shard_dim": True}),      # refuted
        ("kv_repeat_tp16", {"kv_repeat_tp": 16}),
        ("int8_kv", {"quantize_kv": True}),
        ("int8_kv_seq_sharded",
         {"quantize_kv": True, "kv_shard_seq": True}),   # final config
    ]),
    "zamba2_train_engine": ("zamba2-7b", "train_4k", [
        ("chunked_engine", {}),     # code change: ssd_chunked is now the
                                    # XLA candidate (old baseline in log)
    ]),
    "rwkv6_train_engine": ("rwkv6-3b", "train_4k", [
        ("chunked_engine", {}),
    ]),
    "gemma3_long_ring": ("gemma3-27b", "long_500k", [
        ("baseline_full_cache", {}),
        ("ring_local_cache", {"ring_local": True}),
    ]),
}


def terms(rec):
    return {
        "t_compute": rec["flops"] / HW.peak_flops,
        "t_memory": rec["hbm_bytes"] / HW.hbm_bw,
        "t_collective": rec["wire_bytes"] / HW.ici_bw,
        "temp_gb": (rec["memory"].get("temp_bytes") or 0) / 1e9,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None)
    ap.add_argument("--out", default="experiments/perf_iters")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    mesh = make_production_mesh()
    for cell, (arch, shape, variants) in CELLS.items():
        if args.cell and args.cell != cell:
            continue
        print(f"=== {cell}: {arch} × {shape} ===", flush=True)
        for name, opts in variants:
            path = os.path.join(args.out, f"{cell}__{name}.json")
            try:
                rec = lower_cell(arch, shape, mesh, opts=opts)
                t = terms(rec)
                rec["terms"] = t
                rec["variant"] = name
                with open(path, "w") as fh:
                    json.dump(rec, fh, indent=1)
                dom = max(t, key=lambda k: t[k] if k != "temp_gb" else -1)
                print(f"  {name:28s} tc={t['t_compute']:.3g}s "
                      f"tm={t['t_memory']:.3g}s tx={t['t_collective']:.3g}s "
                      f"temp={t['temp_gb']:.1f}GB  dom={dom}", flush=True)
            except Exception as e:
                print(f"  {name:28s} FAIL: {e}", flush=True)


if __name__ == "__main__":
    main()
