"""Render EXPERIMENTS.md §Dry-run / §Roofline markdown tables from the
dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.report > experiments/tables.md
"""
import glob
import json
import os

from .roofline import HW, load_rows, model_flops


def dryrun_table(mesh_tag):
    rows = []
    for path in sorted(glob.glob(f"experiments/dryrun/*__{mesh_tag}.json")):
        r = json.load(open(path))
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | |")
            continue
        mem = r["memory"]
        temp = (mem.get("temp_bytes") or 0) / 1e9
        arg = (mem.get("argument_bytes") or 0) / 1e9
        coll = r["collectives"]
        sched = " ".join(f"{k.split('-')[-1][:4]}:{v['count']}"
                         for k, v in coll.items() if v["count"])
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['flops']:.2e} | "
            f"{r['hbm_bytes']:.2e} | {r['wire_bytes']:.2e} | "
            f"{arg:.1f}+{temp:.1f} | {sched} |")
    head = (f"\n### {mesh_tag} ({'512' if mesh_tag == 'multipod' else '256'}"
            " chips)\n\n"
            "| arch | shape | FLOPs/dev | HBM B/dev | wire B/dev | "
            "mem arg+temp GB | collective schedule (counts) |\n"
            "|---|---|---|---|---|---|---|")
    return "\n".join([head] + rows)


def roofline_table():
    rows = load_rows()
    out = ["| arch | shape | t_comp s | t_mem s | t_coll s | dominant | "
           "MODEL_FLOPS | useful ratio | roofline frac | next lever |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    levers = {
        "compute": "remat policy / fused kernels",
        "memory": "Pallas recurrent kernels / cache layout / microbatching",
        "collective": "TP-AR (bf16 on TPU halves) / sharding rules",
    }
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3g} | "
            f"{r['t_memory']:.3g} | {r['t_collective']:.3g} | "
            f"{r['dominant']} | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} | "
            f"{levers[r['dominant']]} |")
    return "\n".join(out)


def main():
    print("## §Dry-run tables\n")
    print(dryrun_table("singlepod"))
    print()
    print(dryrun_table("multipod"))
    print("\n## §Roofline table (single-pod)\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
