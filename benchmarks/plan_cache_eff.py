"""Plan-cache efficiency: cold planning vs content-hash cache hit.

The staged plan pipeline gives every compile a stable ``plan_id``; the LRU
plan cache keyed by it turns repeated/bucketed workloads into lookups.
This microbenchmark times plan *construction* (the full pass pipeline vs a
cache hit) for a small transformer workload and for the bare attention
analysis, and verifies the cached path is result-identical to cold.

Acceptance target (ISSUE 1): >= 10x lower plan-construction latency on hit.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.executor import plan_and_compile
from repro.core.ir import SystemCatalog
from repro.core.plan_cache import PlanCache
from repro.models import build_model
from repro.models.lm import CATALOG

from .common import emit

SYS = SystemCatalog()


def _median_ms(fn, iters=9):
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e3)
    ts.sort()
    return ts[len(ts) // 2]


def _bench(name, make_plan):
    """Times the planner on a repeated identical workload: ``cold`` runs the
    full pass pipeline every call, ``hit`` is the second-and-later compile
    (content hash + LRU lookup), ``hit_rebuilt`` additionally rebuilds the
    logical plan each request (the serving-bucket pattern)."""
    cache = PlanCache()
    plan = make_plan()

    cold_ms = _median_ms(
        lambda: plan_and_compile(plan, CATALOG, SYS, cache=False))
    plan_and_compile(plan, CATALOG, SYS, cache=cache)  # warm the cache
    hit_ms = _median_ms(
        lambda: plan_and_compile(plan, CATALOG, SYS, cache=cache))
    rebuilt_ms = _median_ms(
        lambda: plan_and_compile(make_plan(), CATALOG, SYS, cache=cache))
    speedup = cold_ms / max(hit_ms, 1e-6)
    assert cache.stats()["hits"] >= 2, "expected cache hits"
    return [
        (f"plan_cache/{name}/cold", cold_ms * 1e3, "full pass pipeline"),
        (f"plan_cache/{name}/hit", hit_ms * 1e3,
         f"speedup={speedup:.1f}x target>=10x"),
        (f"plan_cache/{name}/hit_rebuilt", rebuilt_ms * 1e3,
         f"speedup={cold_ms / max(rebuilt_ms, 1e-6):.1f}x (plan rebuilt "
         f"per request)"),
    ]


def _verify_identical():
    """Cold-planned and cache-hit PlannedFunctions must agree bitwise."""
    cfg = get_smoke_config("qwen3-0.6b").replace(dtype="float32")
    model = build_model(cfg)
    cache = PlanCache()
    b, s = 2, 16

    cold = plan_and_compile(model.build_plan(b, s, mode="prefill"),
                            CATALOG, SYS, cache=False)
    plan_and_compile(model.build_plan(b, s, mode="prefill"),
                     CATALOG, SYS, cache=cache)
    hit = plan_and_compile(model.build_plan(b, s, mode="prefill"),
                           CATALOG, SYS, cache=cache)
    assert cache.stats()["hits"] == 1

    params, _ = model.init_params(jax.random.key(0))
    toks = jnp.asarray(np.random.RandomState(0).randint(0, cfg.vocab, (b, s)),
                       jnp.int32)
    a = np.asarray(cold(params, {"tokens": toks}))
    c = np.asarray(hit(params, {"tokens": toks}))
    assert np.array_equal(a, c), "cached plan changed results"
    return [("plan_cache/bitwise_identical", 0.0, "cold==hit exact")]


def main():
    cfg = get_smoke_config("qwen3-0.6b").replace(dtype="float32")
    model = build_model(cfg)

    rows = []
    rows += _bench("qwen3_prefill",
                   lambda: model.build_plan(2, 32, mode="prefill"))
    rows += _bench("qwen3_train",
                   lambda: model.build_plan(4, 64, mode="train"))
    rows += _verify_identical()
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
