"""Calibration (paper §6.2, Fig. 10/11 + Table 4): run operators over a
synthetic size grid, measure wall time, fit the per-operator degree-2
polynomial cost model (Eq. 2), and report fit quality.  Saves fitted
coefficients to experiments/cost_coeffs.json for the planner to load."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import CostModel, raw_features
from repro.core.ir import SystemCatalog, TensorT
from repro.layers import attention as A
from repro.layers import mlp as F
from repro.layers.common import KeyGen

from .common import emit, time_fn

SYS = SystemCatalog()


def _grid():
    """Table-4 analogue: the synthetic parameter grid."""
    for seq in (64, 128, 256, 512):
        for width in (64, 128):
            yield seq, width


def main(out_path="experiments/cost_coeffs.json"):
    rows, samples = [], []
    kg = KeyGen(jax.random.key(0))
    h_factor = 4

    for seq, width in _grid():
        h = h_factor
        d = width // h
        rng = np.random.RandomState(seq + width)
        x = jnp.asarray(rng.randn(1, seq, width), jnp.float32)
        q = jnp.asarray(rng.randn(1, seq, h, d), jnp.float32)
        k = jnp.asarray(rng.randn(1, seq, h, d), jnp.float32)
        v = jnp.asarray(rng.randn(1, seq, h, d), jnp.float32)
        t = TensorT((1, seq, width), "float32", ("batch", "seq", "embed"))

        # sdpa_xla (Type-I query analogue: cost vs seq — the 'keyword size')
        sec = time_fn(jax.jit(lambda q, k, v: A.sdpa_full(q, k, v)),
                      q, k, v, warmup=1, iters=3)
        attrs = {"heads": h, "kv_heads": h, "head_dim": d, "causal": True}
        samples.append(("sdpa_xla", raw_features("sdpa_xla", [t], attrs,
                                                 SYS), sec))
        rows.append((f"calibration/sdpa_xla/s{seq}w{width}", sec * 1e6, ""))

        # banded attention (Type-II analogue)
        sec = time_fn(jax.jit(lambda q, k, v: A.sdpa_banded(q, k, v,
                                                            window=32)),
                      q, k, v, warmup=1, iters=3)
        attrs_b = dict(attrs, window=32)
        samples.append(("sdpa_banded_xla",
                        raw_features("sdpa_banded_xla", [t], attrs_b, SYS),
                        sec))
        rows.append((f"calibration/sdpa_banded/s{seq}w{width}", sec * 1e6,
                     ""))

        # fused mlp (cross-model join analogue: cost vs both table sizes)
        p, _ = F.init_mlp(kg, {"embed": width, "ffn": 4 * width})
        sec = time_fn(jax.jit(lambda x: F.mlp_fused(p, x)), x,
                      warmup=1, iters=3)
        attrs_m = {"ffn": 4 * width, "gated": True}
        samples.append(("mlp_fused_xla",
                        raw_features("mlp_fused_xla", [t], attrs_m, SYS),
                        sec))
        rows.append((f"calibration/mlp/s{seq}w{width}", sec * 1e6, ""))

    model = CostModel().fit(samples)
    pred = model.predict_samples(samples)
    truth = np.array([s[2] for s in samples])
    mape = float(np.mean(np.abs(pred - truth) / truth))
    # per-op R^2
    for op in ("sdpa_xla", "sdpa_banded_xla", "mlp_fused_xla"):
        idx = [i for i, s in enumerate(samples) if s[0] == op]
        y, yh = truth[idx], pred[idx]
        ss = 1 - np.sum((y - yh) ** 2) / max(np.sum((y - y.mean()) ** 2),
                                             1e-30)
        rows.append((f"calibration/fit/{op}", 0.0, f"r2={ss:.4f}"))
    rows.append(("calibration/fit/overall", 0.0, f"mape={mape:.3f}"))

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    model.save(out_path)
    rows.append(("calibration/saved", 0.0, out_path))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
