"""Sharded tri-store: partitioned stores over the device mesh vs the same
workload replicated on every device.

The tri-model analysis family from ``tri_store_eff`` (scan/filter a tweet
table -> seed + expand a hashtag graph -> PageRank -> score the corpus ->
broadcast-join the hits -> all-to-all co-partitioned influencer join ->
per-hashtag rollups) runs three ways on a host mesh forced to 8 devices:

  * **single** — unsharded stores, no mesh: the honest one-device timing;
  * **replicated** — unsharded stores bound to the 8-device mesh with every
    input replicated: each device executes the *full* workload (what a
    mesh buys you without ``shard_stores``);
  * **sharded** — every store ``with_shards(8)``: the planner stamps
    ``dist`` attrs, kinds the xfers (local / replicate / repartition), and
    the runtime executes shard-local kernels with one all-gather per
    PageRank iteration, a distributed top-k merge, and one all-to-all for
    the co-partitioned join.

The headline guard is **sharded vs replicated on the same mesh** (devices
execute 1/n of the store work instead of all of it), which holds even when
the 8 "devices" are threads time-slicing one physical core — exactly the CI
situation, where wall-clock parallel speedup over ``single`` is impossible
by construction.  Both timings and the host's CPU count are recorded so the
report is honest about what was measured.  Results must stay allclose to
the single-device run (the sharded graph / text kernels are bitwise; the
psum'd float rollups re-associate).

    PYTHONPATH=src python -m benchmarks.tri_store_sharded [--smoke]
"""
import argparse
import os
import sys

# must precede ``import jax``: force a multi-device host platform so the
# mesh actually spans devices under CI / local smoke runs.  Respect an
# existing setting (the CI job exports its own XLA_FLAGS).
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from benchmarks.tri_store_eff import DEFAULT_JSON_OUT, merge_report, t_min
from repro.core.adil import Analysis
from repro.core.ir import SystemCatalog, TensorT, standard_catalog
from repro.launch.mesh import (make_cpu_mesh, replicated_sharding,
                               shard_store_inputs, syscat_for_mesh)
from repro.stores import ColumnStore, GraphStore, TextStore, store_engines


def build_workload(rng, shards, *, tweets, docs, hashtags, edges, vocab,
                   terms_hi, iters, influencers):
    user = rng.randint(0, 65536, tweets).astype(np.int32)
    tag = (rng.zipf(1.3, tweets) % hashtags).astype(np.int32)
    cols = {
        "user": user,
        "hashtag": tag,
        "doc": np.arange(tweets, dtype=np.int32),
        "engagement": (rng.gamma(2.0, 12.0, tweets)).astype(np.float32),
        "retweets": rng.randint(0, 500, tweets).astype(np.int32),
    }
    for i in range(8):
        cols[f"metric{i}"] = rng.rand(tweets).astype(np.float32)
    table = ColumnStore(cols)
    e = rng.randint(0, hashtags, (2, edges))
    graph = GraphStore.from_edges(e[0], e[1], hashtags, symmetric=True)
    lens = rng.randint(3, terms_hi, docs)
    flat = (rng.zipf(1.4, int(lens.sum())) % vocab).astype(np.int64)
    corpus = TextStore.from_docs(np.split(flat, np.cumsum(lens)[:-1]), vocab)
    # influencer side table: non-unique user keys, large enough that the
    # planner must co-partition (build_expected > BROADCAST_BUILD_MAX)
    infl = ColumnStore({
        "user": rng.randint(0, 65536, influencers).astype(np.int32),
        "influence": rng.rand(influencers).astype(np.float32)})
    if shards > 1:
        table = table.with_shards(shards)
        graph = graph.with_shards(shards)
        corpus = corpus.with_shards(shards)
        infl = infl.with_shards(shards)

    cat = standard_catalog()
    with Analysis(f"tri_sharded_s{shards}", cat) as a:
        tw = a.bind("tweets", table)
        gr = a.bind("g", graph)
        cx = a.bind("cx", corpus)
        fl = a.bind("infl", infl)
        q = a.input("q", TensorT((vocab,), "float32", ("vocab",)))
        t = a.op("rel_scan", tw)
        hot = a.op("rel_filter", t, col="engagement", cmp="ge", value=25.0)
        viral = a.op("rel_filter", hot, col="retweets", cmp="ge", value=10)
        seeds = a.op("rel_group_agg", viral, key="hashtag",
                     num_groups=hashtags, aggs=(("seed", "count", None),))
        sv = a.op("col_tensor", seeds, col="seed", dim="nodes")
        fr = a.op("graph_expand", gr, sv, hops=2)
        pr = a.op("graph_pagerank", gr, fr, iters=iters, damping=0.85)
        hits = a.op("text_topk", cx, q, k=64)
        j = a.op("rel_join", t, hits, left_on="doc", right_on="doc")
        trel = a.op("rel_group_agg", j, key="hashtag", num_groups=hashtags,
                    aggs=(("textrel", "sum", "score"),))
        tv = a.op("col_tensor", trel, col="textrel", dim="nodes")
        mentions = a.op("bounded_join", viral, fl, left_on="user",
                        right_on="user", capacity=tweets)
        irel = a.op("rel_group_agg", mentions, key="hashtag",
                    num_groups=hashtags,
                    aggs=(("infl", "sum", "influence"),))
        iv = a.op("col_tensor", irel, col="infl", dim="nodes")
        comb = a.op("residual_add", a.op("residual_add", pr, tv), iv)
        a.store(comb)

    inputs = {"tweets": table.payload(), "g": graph.payload(),
              "cx": corpus.payload(), "infl": infl.payload(),
              "q": jnp.asarray(corpus.query_vector(rng.randint(0, vocab, 6)))}
    return a, inputs


def _replicate_inputs(mesh, values):
    rep = replicated_sharding(mesh)

    def place(x):
        return jax.device_put(x, rep) if hasattr(x, "shape") else x

    return {k: jax.tree.map(place, v) for k, v in values.items()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized workload (seconds, not minutes)")
    ap.add_argument("--min-speedup", type=float, default=2.0,
                    help="sharded-vs-replicated floor on the largest "
                         "workload")
    ap.add_argument("--json-out", default=DEFAULT_JSON_OUT)
    args = ap.parse_args(argv)

    n_dev = jax.local_device_count()
    if n_dev < 2:
        print(f"[tri_store_sharded] SKIP: {n_dev} device(s); force a host "
              f"mesh with XLA_FLAGS=--xla_force_host_platform_device_count=8")
        return 0
    mesh = make_cpu_mesh(n_dev, 1)
    engines = store_engines()

    sizes = ([dict(tweets=48_000, docs=8_000, hashtags=1024, edges=8_000,
                   vocab=256, terms_hi=6, iters=2, influencers=16_384),
              dict(tweets=120_000, docs=16_000, hashtags=2048, edges=16_000,
                   vocab=256, terms_hi=6, iters=3, influencers=32_768)]
             if args.smoke else
             [dict(tweets=240_000, docs=32_000, hashtags=4096, edges=40_000,
                   vocab=512, terms_hi=8, iters=3, influencers=65_536)])

    rows, ok = [], True
    for size in sizes:
        a1, in1 = build_workload(np.random.RandomState(0), 1, **size)
        f1 = a1.compile(SystemCatalog(), engines=engines, cache=False)
        single = jax.jit(lambda i, f=f1: f({}, i))
        out1 = np.asarray(single(in1))

        # replicated baseline: same (unsharded) plan bound to the mesh,
        # every input replicated -> every device runs the full workload
        fr_ = a1.compile(syscat_for_mesh(mesh), engines=engines,
                         cache=False, mesh=mesh)
        in_r = _replicate_inputs(mesh, in1)
        repl = jax.jit(lambda i, f=fr_: f({}, i))
        out_r = np.asarray(repl(in_r))

        a8, in8 = build_workload(np.random.RandomState(0), n_dev, **size)
        f8 = a8.compile(syscat_for_mesh(mesh), engines=engines,
                        cache=False, mesh=mesh)
        in_s = shard_store_inputs(mesh, in8)
        shrd = jax.jit(lambda i, f=f8: f({}, i))
        out_s = np.asarray(shrd(in_s))

        kinds = sorted(r["chosen"] for r in f8.report
                       if r["pattern"] == "xfer_op")
        dist = sorted({(n.impl, n.attrs["dist"]) for n in f8.concrete.topo()
                       if n.attrs.get("dist")})
        print(f"[tri_store_sharded] tweets={size['tweets']}: xfer kinds "
              f"{kinds}")
        print(f"[tri_store_sharded] dist nodes: {dist}")
        close = (np.allclose(out1, out_s, rtol=1e-4, atol=1e-5)
                 and np.allclose(out1, out_r, rtol=1e-4, atol=1e-5))
        miss = bool(f1.plan_id != f8.plan_id)

        t1 = t_min(single, in1, warmup=2, iters=5)
        tr = t_min(repl, in_r, warmup=2, iters=5)
        ts = t_min(shrd, in_s, warmup=2, iters=5)
        speedup = tr / ts
        rows.append({
            "tweets": size["tweets"],
            "single_ms": t1 * 1e3, "replicated_ms": tr * 1e3,
            "sharded_ms": ts * 1e3, "speedup_vs_replicated": speedup,
            "speedup_vs_single": t1 / ts,
            "allclose": bool(close), "plan_cache_miss": miss,
            "xfer_kinds": kinds,
            "dist_nodes": [f"{i}:{d}" for i, d in dist],
        })
        print(f"[tri_store_sharded] single {t1 * 1e3:8.1f} ms | "
              f"replicated(x{n_dev}) {tr * 1e3:8.1f} ms | "
              f"sharded {ts * 1e3:8.1f} ms -> {speedup:5.2f}x vs "
              f"replicated  allclose={close}  cache_miss={miss}")
        ok &= close and miss
        if not close:
            print("[tri_store_sharded] FAIL: results diverge")
        if not miss:
            print("[tri_store_sharded] FAIL: sharded plan hit the "
                  "unsharded cache entry")

    # the guard applies to the largest workload, where the per-device work
    # reduction dominates the collective overhead
    head = rows[-1]["speedup_vs_replicated"]
    if head < args.min_speedup:
        ok = False
        print(f"[tri_store_sharded] FAIL: speedup {head:.2f}x < "
              f"{args.min_speedup:.1f}x")

    report = {
        "mode": "sharded", "smoke": bool(args.smoke),
        "devices": n_dev, "cpu_count": os.cpu_count(),
        "min_speedup": args.min_speedup, "sweep": rows, "ok": bool(ok),
    }
    merge_report(args.json_out, report, section="sharded",
                 mesh_shape=tuple(mesh.devices.shape))
    print(f"[tri_store_sharded] wrote {args.json_out} (sharded section)")
    emit([(f"tri_sharded_{r['tweets']}", r["sharded_ms"] * 1e3,
           f"vs_replicated={r['speedup_vs_replicated']:.2f}x")
          for r in rows])
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
