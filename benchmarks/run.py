"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints ``name,us_per_call,derived`` CSV."""
import argparse
import sys
import traceback

SUITES = (
    ("end_to_end", "Fig. 12/13 — SingleThread/DataParallel/AWESOME"),
    ("cost_model_eff", "Fig. 14/15 — candidate plans vs cost-model choice"),
    ("fusion_eff", "Fig. 5/15 — map fusion"),
    ("buffering_eff", "Fig. 16 — buffering memory/time"),
    ("calibration_curves", "Fig. 10/11 + Table 4 — calibration + fit"),
    ("pipeline_vs_dp", "§5.4/App. C — pipeline+DP vs DP (negative result)"),
    ("plan_cache_eff", "ISSUE 1 — cold plan vs content-hash cache hit"),
    ("roofline", "§Roofline — dry-run derived terms"),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = []
    for mod_name, desc in SUITES:
        if args.only and args.only != mod_name:
            continue
        print(f"# {mod_name}: {desc}", flush=True)
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
            mod.main()
        except Exception as e:
            failures.append(mod_name)
            traceback.print_exc()
            print(f"{mod_name}/ERROR,0.0,{e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
