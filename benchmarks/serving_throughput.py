"""Serving throughput: continuous batching vs the sequential seed path.

Same mixed-length prompt trace through both paths, both fully warm
(plans cached, jits traced):

  * **sequential** — the seed's one-request-at-a-time loop: planned
    (bucketed, cached) prefill for the prompt logits, prompt *replay*
    through cached decode to rebuild the KV state, then batch-1 decode;
  * **continuous** — the async runtime: planned ``prefill_kv`` forward
    seeds the paged KV pool directly (no replay) and all in-flight requests
    decode together, joining/leaving the fixed-width batch at token
    boundaries.

Acceptance targets (ISSUE 2), asserted here:
  * continuous batching >= 2x tokens/sec over the sequential path;
  * zero plan-cache misses after warmup — the runtime never re-plans a
    bucket whose plan is cached (hit-rate 100 % during serving);
  * both paths emit identical token streams (greedy decode is
    deterministic; batching must not change results).

    PYTHONPATH=src python -m benchmarks.serving_throughput [--smoke]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.plan_cache import PlanCache
from repro.models import build_model
from repro.serving import AsyncServingRuntime, ServeRequest, serve_sequential

from .common import emit


def make_trace(rng, vocab, n_requests, prompt_lens, gen):
    return [ServeRequest(i, tuple(rng.randint(0, vocab,
                                              prompt_lens[i % len(prompt_lens)]
                                              ).tolist()), gen)
            for i in range(n_requests)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized trace (also the deadlock smoke test)")
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--gen", type=int, default=None)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    n_requests = args.requests or (8 if args.smoke else 16)
    gen = args.gen or (12 if args.smoke else 24)
    prompt_lens = [5, 12, 8, 20, 16, 3, 27, 9]

    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(args.seed))
    rng = np.random.RandomState(args.seed)
    reqs = make_trace(rng, cfg.vocab, n_requests, prompt_lens, gen)
    total_tokens = sum(r.gen for r in reqs)

    # -- sequential seed path (warm: jit memo reused across invocations) ----
    pc_seq = PlanCache()
    memo: dict = {}
    serve_sequential(model, params, reqs, max_seq=args.max_seq,
                     plan_cache=pc_seq, jit_memo=memo)           # warmup
    t0 = time.perf_counter()
    seq_results = serve_sequential(model, params, reqs, max_seq=args.max_seq,
                                   plan_cache=pc_seq, jit_memo=memo)
    t_seq = time.perf_counter() - t0

    # -- continuous batching runtime ----------------------------------------
    pc_cb = PlanCache()
    rt = AsyncServingRuntime(model, params, max_batch=args.max_batch,
                             max_seq=args.max_seq, plan_cache=pc_cb)
    rt.warmup(prompt_lens)
    misses_after_warmup = pc_cb.stats()["misses"]
    t0 = time.perf_counter()
    cb_results = rt.serve(reqs, timeout_s=180)
    t_cb = time.perf_counter() - t0

    tps_seq = total_tokens / t_seq
    tps_cb = total_tokens / t_cb
    speedup = tps_cb / tps_seq
    stats = pc_cb.stats()
    serve_hits = stats["hits"]
    serve_misses = stats["misses"] - misses_after_warmup

    emit([
        ("serving_sequential", t_seq / total_tokens * 1e6,
         f"{tps_seq:.1f} tok/s"),
        ("serving_continuous", t_cb / total_tokens * 1e6,
         f"{tps_cb:.1f} tok/s"),
        ("serving_speedup", 0.0, f"{speedup:.2f}x"),
    ])
    print(rt.metrics.report())
    print(f"[bench] {n_requests} requests x {gen} tokens, "
          f"max_batch={args.max_batch}: sequential {tps_seq:.1f} tok/s, "
          f"continuous {tps_cb:.1f} tok/s -> {speedup:.2f}x")
    print(f"[bench] plan cache after warmup: {serve_hits} hits / "
          f"{serve_misses} misses during serving")

    # -- acceptance asserts --------------------------------------------------
    mismatches = [r.rid for r, s in zip(cb_results, seq_results)
                  if r.tokens != s.tokens or r.status != "ok"]
    assert not mismatches, f"token streams diverged for requests {mismatches}"
    assert serve_misses == 0 and serve_hits >= n_requests, (
        f"runtime re-planned a warm bucket: {serve_misses} misses, "
        f"{serve_hits} hits after warmup")
    assert speedup >= 2.0, (
        f"continuous batching speedup {speedup:.2f}x < 2x target")
    print("[bench] OK: >=2x throughput, 100% plan-cache hit rate after "
          "warmup, identical token streams")
    return speedup


if __name__ == "__main__":
    main()
