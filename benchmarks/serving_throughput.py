"""Serving throughput: continuous batching vs the sequential seed path.

Same mixed-length prompt trace through both paths, both fully warm
(plans cached, jits traced):

  * **sequential** — the seed's one-request-at-a-time loop: planned
    (bucketed, cached) prefill for the prompt logits, prompt *replay*
    through cached decode to rebuild the KV state, then batch-1 decode;
  * **continuous** — the async runtime: planned ``prefill_kv`` forward
    seeds the paged KV pool directly (no replay) and all in-flight requests
    decode together, joining/leaving the fixed-width batch at token
    boundaries.

Acceptance targets (ISSUE 2), asserted here:
  * continuous batching >= 2x tokens/sec over the sequential path;
  * zero plan-cache misses after warmup — the runtime never re-plans a
    bucket whose plan is cached (hit-rate 100 % during serving);
  * both paths emit identical token streams (greedy decode is
    deterministic; batching must not change results).

Chaos mode (``--faults seed=0,rate=0.05``) replays the same trace through a
second runtime with a pinned deterministic fault schedule and asserts the
ISSUE 9 survival properties instead of the speedup: no hang, every request
terminates with a result or a *structured* error, requests that dodge the
faults are bitwise-identical to the fault-free run, and the KV pool +
resource ledger end with zero leaks.  ``--flight-dir`` dumps flight-recorder
incident files there (the CI chaos-smoke job uploads them on failure).

    PYTHONPATH=src python -m benchmarks.serving_throughput [--smoke]
    PYTHONPATH=src python -m benchmarks.serving_throughput --smoke \
        --faults seed=0,rate=0.05 --flight-dir /tmp/flight
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.faults import FaultInjector
from repro.core.ledger import FlightRecorder, MemoryLedger
from repro.core.plan_cache import PlanCache
from repro.models import build_model
from repro.serving import AsyncServingRuntime, ServeRequest, serve_sequential

from .common import emit


def make_trace(rng, vocab, n_requests, prompt_lens, gen):
    return [ServeRequest(i, tuple(rng.randint(0, vocab,
                                              prompt_lens[i % len(prompt_lens)]
                                              ).tolist()), gen)
            for i in range(n_requests)]


def run_chaos(model, params, reqs, prompt_lens, args):
    """Replay the trace under a pinned fault schedule and assert the
    survival properties (no hang, structured errors, bitwise-identical
    non-faulted outputs, zero leaks)."""
    # fault-free reference pass: the bitwise baseline
    led0 = MemoryLedger()
    rt0 = AsyncServingRuntime(model, params, max_batch=args.max_batch,
                              max_seq=args.max_seq,
                              plan_cache=PlanCache(ledger=led0), ledger=led0)
    rt0.warmup(prompt_lens)
    clean = {r.rid: r for r in rt0.serve(reqs, timeout_s=180)}

    faults = FaultInjector.from_spec(args.faults)
    recorder = FlightRecorder(dump_dir=args.flight_dir)
    ledger = MemoryLedger()
    rt = AsyncServingRuntime(model, params, max_batch=args.max_batch,
                             max_seq=args.max_seq,
                             plan_cache=PlanCache(ledger=ledger),
                             ledger=ledger, recorder=recorder, faults=faults)
    rt.warmup(prompt_lens)
    t0 = time.perf_counter()
    results = rt.serve(reqs, timeout_s=180)        # no-hang bound
    t_chaos = time.perf_counter() - t0

    n_ok = sum(1 for r in results if r.status == "ok")
    n_err = len(results) - n_ok
    emit([("serving_chaos", t_chaos * 1e3,
           f"{n_ok}/{len(results)} ok, {faults.n_errors()} faults "
           f"injected ({args.faults})")])
    print(f"[chaos] {len(results)} requests under '{args.faults}': "
          f"{n_ok} ok, {n_err} resolved with structured errors, "
          f"{faults.n_errors()} faults injected in {t_chaos:.1f}s")

    # -- survival asserts ---------------------------------------------------
    assert len(results) == len(reqs), (
        f"hang/loss: {len(reqs) - len(results)} requests never resolved")
    for r in results:
        if r.status == "ok":
            assert r.tokens == clean[r.rid].tokens, (
                f"request {r.rid}: non-faulted output diverged from the "
                f"fault-free run")
        else:
            assert r.error is not None and "reason" in r.error, (
                f"request {r.rid} resolved {r.status} without a "
                f"structured error")
    occ = rt.pool.occupancy()
    assert occ["slots_used"] == 0 and occ["pages_used"] == 0, (
        f"KV pool not drained after chaos run: {occ}")
    leaks = rt.ledger.leaks()
    assert not leaks, f"ledger leaks after chaos run: {leaks}"
    print("[chaos] OK: every request terminated (result or structured "
          "error), non-faulted outputs bitwise-identical, zero KV/ledger "
          "leaks")
    return n_ok, n_err


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized trace (also the deadlock smoke test)")
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--gen", type=int, default=None)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="chaos mode: pinned fault schedule, e.g. "
                         "'seed=0,rate=0.05' (skips the speedup benchmark)")
    ap.add_argument("--flight-dir", default=None,
                    help="directory for flight-recorder incident dumps")
    args = ap.parse_args(argv)

    n_requests = args.requests or (8 if args.smoke else 16)
    gen = args.gen or (12 if args.smoke else 24)
    prompt_lens = [5, 12, 8, 20, 16, 3, 27, 9]

    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(args.seed))
    rng = np.random.RandomState(args.seed)
    reqs = make_trace(rng, cfg.vocab, n_requests, prompt_lens, gen)
    total_tokens = sum(r.gen for r in reqs)

    if args.faults:
        return run_chaos(model, params, reqs, prompt_lens, args)

    # -- sequential seed path (warm: jit memo reused across invocations) ----
    pc_seq = PlanCache()
    memo: dict = {}
    serve_sequential(model, params, reqs, max_seq=args.max_seq,
                     plan_cache=pc_seq, jit_memo=memo)           # warmup
    t0 = time.perf_counter()
    seq_results = serve_sequential(model, params, reqs, max_seq=args.max_seq,
                                   plan_cache=pc_seq, jit_memo=memo)
    t_seq = time.perf_counter() - t0

    # -- continuous batching runtime ----------------------------------------
    pc_cb = PlanCache()
    rt = AsyncServingRuntime(model, params, max_batch=args.max_batch,
                             max_seq=args.max_seq, plan_cache=pc_cb)
    rt.warmup(prompt_lens)
    misses_after_warmup = pc_cb.stats()["misses"]
    t0 = time.perf_counter()
    cb_results = rt.serve(reqs, timeout_s=180)
    t_cb = time.perf_counter() - t0

    tps_seq = total_tokens / t_seq
    tps_cb = total_tokens / t_cb
    speedup = tps_cb / tps_seq
    stats = pc_cb.stats()
    serve_hits = stats["hits"]
    serve_misses = stats["misses"] - misses_after_warmup

    emit([
        ("serving_sequential", t_seq / total_tokens * 1e6,
         f"{tps_seq:.1f} tok/s"),
        ("serving_continuous", t_cb / total_tokens * 1e6,
         f"{tps_cb:.1f} tok/s"),
        ("serving_speedup", 0.0, f"{speedup:.2f}x"),
    ])
    print(rt.metrics.report())
    print(f"[bench] {n_requests} requests x {gen} tokens, "
          f"max_batch={args.max_batch}: sequential {tps_seq:.1f} tok/s, "
          f"continuous {tps_cb:.1f} tok/s -> {speedup:.2f}x")
    print(f"[bench] plan cache after warmup: {serve_hits} hits / "
          f"{serve_misses} misses during serving")

    # -- acceptance asserts --------------------------------------------------
    mismatches = [r.rid for r, s in zip(cb_results, seq_results)
                  if r.tokens != s.tokens or r.status != "ok"]
    assert not mismatches, f"token streams diverged for requests {mismatches}"
    assert serve_misses == 0 and serve_hits >= n_requests, (
        f"runtime re-planned a warm bucket: {serve_misses} misses, "
        f"{serve_hits} hits after warmup")
    assert speedup >= 2.0, (
        f"continuous batching speedup {speedup:.2f}x < 2x target")
    print("[bench] OK: >=2x throughput, 100% plan-cache hit rate after "
          "warmup, identical token streams")
    return speedup


if __name__ == "__main__":
    main()
