"""§Roofline: per-(arch × shape × mesh) roofline terms from the dry-run
artifacts (experiments/dryrun/*.json).

  compute    = HLO_FLOPs_per_device / peak_FLOP/s          (197e12 bf16)
  memory     = HLO_bytes_per_device / HBM_bw               (819e9 B/s)
  collective = wire_bytes_per_device / ICI_bw              (50e9 B/s)

plus MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (fwd) and the useful-compute
ratio MODEL_FLOPS / (HLO_FLOPs × devices)."""
import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.core.ir import HardwareSpec

HW = HardwareSpec()


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count() if cfg.family == "moe" \
        else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def _useful_decode_bytes(arch: str, shape) -> float:
    """Params (bf16) + KV/recurrent state bytes — the unavoidable per-token
    HBM traffic of a decode step."""
    import jax
    from repro.models import build_model
    from repro.models.decode import init_cache

    cfg = get_config(arch)
    model = build_model(cfg)
    cache = init_cache(model, shape.global_batch, shape.seq_len,
                       abstract=True)
    cache_bytes = sum(
        float(np_prod(l.shape)) * jax.numpy.dtype(l.dtype).itemsize
        for l in jax.tree.leaves(cache))
    return cfg.param_count() * 2.0 + cache_bytes


def np_prod(shape):
    out = 1
    for s in shape:
        out *= int(s)
    return out


def load_rows(dryrun_dir="experiments/dryrun", mesh_tag="singlepod"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir,
                                              f"*__{mesh_tag}.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": "fail"})
            continue
        dev = rec["devices"]
        t_c = rec["flops"] / HW.peak_flops
        t_m = rec["hbm_bytes"] / HW.hbm_bw
        t_x = rec["wire_bytes"] / HW.ici_bw
        dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
        mf = model_flops(rec["arch"], rec["shape"])
        ratio = mf / max(rec["flops"] * dev, 1.0)
        bound = max(t_c, t_m, t_x)
        shape = SHAPES[rec["shape"]]
        if shape.kind == "decode":
            # decode is memory-bound by physics: the roofline fraction is
            # MBU-style — useful bytes (params + KV cache, each read once
            # per token) over the HBM bytes the compiled step actually moves
            useful = _useful_decode_bytes(rec["arch"], shape) / dev
            frac = min(1.0, useful / max(rec["hbm_bytes"], 1.0))
        else:
            # train/prefill: MFU-style — useful model flops vs what the
            # dominant term allows at peak
            frac = (mf / dev / HW.peak_flops) / bound if bound else 0.0
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
            "devices": dev, "t_compute": t_c, "t_memory": t_m,
            "t_collective": t_x, "dominant": dom,
            "model_flops": mf, "useful_ratio": ratio,
            "roofline_frac": frac,
            "temp_gb": (rec["memory"].get("temp_bytes") or 0) / 1e9,
            "selected": rec.get("selected", []),
        })
    return rows


def main():
    rows = load_rows()
    out = []
    for r in rows:
        if r["status"] != "ok":
            out.append((f"roofline/{r['arch']}/{r['shape']}", 0.0, "FAIL"))
            continue
        out.append((
            f"roofline/{r['arch']}/{r['shape']}",
            max(r["t_compute"], r["t_memory"], r["t_collective"]) * 1e6,
            f"dom={r['dominant']} frac={r['roofline_frac']:.3f} "
            f"useful={r['useful_ratio']:.2f} "
            f"tc={r['t_compute']:.2e} tm={r['t_memory']:.2e} "
            f"tx={r['t_collective']:.2e} temp={r['temp_gb']:.1f}GB"))
    for name, us, d in out:
        print(f"{name},{us:.1f},{d}")
    return rows


if __name__ == "__main__":
    main()
