"""Tri-store placement efficiency: planned cross-engine placement vs naive
per-op materialization.

Both paths run the *same* tri-model analysis (scan/filter/aggregate a tweet
table -> expand + PageRank a hashtag co-mention graph -> TF-IDF top-k over
the tweet corpus -> join + rank) through the same ``PlanPipeline``; the only
difference is the final rewrite rule:

  * **planned** — ``place_xfers``: xfer nodes only at true engine
    boundaries, and the cost model picks ``xfer_pin`` (value stays
    device-resident) per boundary: AWESOME's in-memory placement;
  * **naive**   — ``place_xfers_naive``: every store-engine operator's
    output is materialized through the host (``xfer_spill``), the way a
    naive federated mediator hands each engine result back per call.

Spill is an exact copy, so the two paths must produce **bitwise-identical**
results; the planned path must be **>= 2x** faster.  Run with ``--smoke``
for the CI-sized workload.

    PYTHONPATH=src python -m benchmarks.tri_store_eff [--smoke]
"""
import argparse
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.adil import Analysis
from repro.core.ir import SystemCatalog, TensorT, standard_catalog
from repro.core.rewrite import DEFAULT_PIPELINE
from repro.stores import ColumnStore, GraphStore, TextStore, store_engines

# the naive pipeline swaps only the placement rule
NAIVE_PIPELINE = tuple(p for p in DEFAULT_PIPELINE if p != "place_xfers") \
    + ("place_xfers_naive",)


def build_workload(rng, *, tweets, docs, hashtags, edges, vocab, terms_hi,
                   iters):
    user = rng.randint(0, max(tweets // 20, 2), tweets).astype(np.int32)
    tag = (rng.zipf(1.3, tweets) % hashtags).astype(np.int32)
    cols = {
        "user": user,
        "hashtag": tag,
        "doc": np.arange(tweets, dtype=np.int32),
        "engagement": (rng.gamma(2.0, 12.0, tweets)).astype(np.float32),
        "retweets": rng.randint(0, 500, tweets).astype(np.int32),
        "ts": rng.randint(0, 1 << 20, tweets).astype(np.int32),
    }
    # ride-along metric columns (likes, replies, quotes, ...): the analysis
    # never reads them, so planned placement never moves them — but naive
    # per-op materialization round-trips the *whole* relation every call.
    # This is AWESOME's in-memory placement argument in its purest form.
    for i in range(28):
        cols[f"metric{i}"] = rng.rand(tweets).astype(np.float32)
    table = ColumnStore(cols)
    e = rng.randint(0, hashtags, (2, edges))
    graph = GraphStore.from_edges(e[0], e[1], hashtags, symmetric=True)
    # the first ``docs`` tweets have indexed text (a corpus is typically a
    # filtered slice of the relation, not 1:1 with it)
    lens = rng.randint(3, terms_hi, docs)
    flat = (rng.zipf(1.4, int(lens.sum())) % vocab).astype(np.int64)
    corpus = TextStore.from_docs(np.split(flat, np.cumsum(lens)[:-1]), vocab)

    cat = standard_catalog()
    with Analysis("tri_store_eff", cat) as a:
        tw = a.bind("tweets", table)
        gr = a.bind("g", graph)
        cx = a.bind("cx", corpus)
        q = a.input("q", TensorT((vocab,), "float32", ("vocab",)))
        t = a.op("rel_scan", tw)
        hot = a.op("rel_filter", t, col="engagement", cmp="ge", value=25.0)
        viral = a.op("rel_filter", hot, col="retweets", cmp="ge", value=10)
        seeds = a.op("rel_group_agg", viral, key="hashtag",
                     num_groups=hashtags, aggs=(("seed", "count", None),))
        sv = a.op("col_tensor", seeds, col="seed", dim="nodes")
        fr = a.op("graph_expand", gr, sv, hops=2)
        pr = a.op("graph_pagerank", gr, fr, iters=iters, damping=0.85)
        hits = a.op("text_topk", cx, q, k=64)
        # probe the tweet relation against the top-k hits (unique build
        # keys); unmatched rows mask out, so the per-hashtag score sum
        # equals summing over the hits alone — but the wide joined relation
        # is exactly the intermediate naive placement round-trips
        j = a.op("rel_join", t, hits, left_on="doc", right_on="doc")
        trel = a.op("rel_group_agg", j, key="hashtag", num_groups=hashtags,
                    aggs=(("textrel", "sum", "score"),))
        tv = a.op("col_tensor", trel, col="textrel", dim="nodes")
        comb = a.op("residual_add", pr, tv)
        a.store(comb)

    inputs = {"tweets": table.payload(), "g": graph.payload(),
              "cx": corpus.payload(),
              "q": jnp.asarray(corpus.query_vector(rng.randint(0, vocab, 6)))}
    return a, inputs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized workload (seconds, not minutes)")
    ap.add_argument("--min-speedup", type=float, default=2.0)
    args = ap.parse_args(argv)

    rng = np.random.RandomState(0)
    size = (dict(tweets=120_000, docs=6_000, hashtags=1024, edges=4_000,
                 vocab=256, terms_hi=6, iters=2) if args.smoke else
            dict(tweets=250_000, docs=30_000, hashtags=2048, edges=20_000,
                 vocab=512, terms_hi=6, iters=3))
    analysis, inputs = build_workload(rng, **size)

    # identical engine set for both paths (no pallas: the point under test
    # is placement, and identical impls guarantee bitwise-equal results)
    engines = store_engines()
    syscat = SystemCatalog()
    planned = analysis.compile(syscat, engines=engines, cache=False)
    naive = analysis.compile(syscat, engines=engines, cache=False,
                             rewrite_pipeline=NAIVE_PIPELINE)

    n_pin = sum(1 for r in planned.report
                if r["pattern"] == "xfer_op" and r["chosen"] == "xfer_pin")
    n_spill = sum(1 for n in naive.concrete.topo()
                  if n.impl == "xfer_spill")
    print(f"[tri_store_eff] planned: {n_pin} boundaries pinned; "
          f"naive: {n_spill} per-op host materializations")

    fp = jax.jit(lambda i: planned({}, i))
    fn = jax.jit(lambda i: naive({}, i))
    out_p = np.asarray(fp(inputs))
    out_n = np.asarray(fn(inputs))
    identical = np.array_equal(out_p, out_n)
    print(f"[tri_store_eff] bitwise-identical results: {identical}")

    # min-of-N: background noise in shared CI runners is strictly additive,
    # so the minimum is the clean estimate of each path's true cost
    def t_min(f, warmup=2, iters=10):
        for _ in range(warmup):
            jax.block_until_ready(f(inputs))
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(f(inputs))
            best = min(best, time.perf_counter() - t0)
        return best

    t_planned = t_min(fp)
    t_naive = t_min(fn)
    speedup = t_naive / t_planned
    emit([
        ("tri_planned", t_planned * 1e6, f"speedup={speedup:.2f}x"),
        ("tri_naive_per_op", t_naive * 1e6, ""),
    ])
    print(f"[tri_store_eff] planned {t_planned * 1e3:.1f} ms vs naive "
          f"{t_naive * 1e3:.1f} ms -> {speedup:.2f}x")

    ok = identical and speedup >= args.min_speedup
    if not identical:
        print("[tri_store_eff] FAIL: results differ")
    if speedup < args.min_speedup:
        print(f"[tri_store_eff] FAIL: speedup {speedup:.2f}x < "
              f"{args.min_speedup:.1f}x")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
