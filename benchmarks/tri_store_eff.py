"""Tri-store efficiency: cross-engine placement, predicate pushdown, and
bounded-relation compaction.

Three benchmark modes over the same tri-model analysis family (scan/filter/
aggregate a tweet table -> expand a hashtag graph -> score the tweet corpus
-> join + rank), all through the same ``PlanPipeline``:

**Placement mode** (default, PR 3): planned ``place_xfers`` (xfer nodes
only at true engine boundaries, cost model pins them device-resident) vs
``place_xfers_naive`` (every store-op output materialized through the
host, the federated-mediator strawman).  Spill is an exact copy, so the
two paths must produce **bitwise-identical** results; planned must be
**>= 2x** faster.

**Selective mode** (``--selective``): planned-*pushdown* (the default
pipeline's ``push_predicates`` + ``fuse_store_ops``: candidate-doc masks
cross into the text engine, frontier sparsity into the graph engine, rel
chains fuse) vs PR 3's planned-but-unpushed pipeline on a time-windowed
workload ("rank this window's tweets") at 1-100% window selectivity.
Pushdown executes the same math behind masked block-skipping candidates,
so results stay **bitwise identical** while skipping the posting/edge
blocks the window masks out; at <= 10% selectivity the pushed plan must be
**>= 2x** faster.  The sweep is written to ``BENCH_tri_store.json``.

**Bounded mode** (``--bounded``): compact-then-dense (the default
pipeline's ``choose_compaction``: a prefix ``compact`` node below the
confidently-selective window filter, downstream join/group-by running at
the narrowed capacity) vs masked-dense (same pushdown, no compaction —
every operator drags the full-capacity relation behind its mask) on a
rel-heavy windowed aggregation.  Compaction preserves valid rows in order
(dropped rows contributed exactly +/-0.0 — which requires *finite* column
data: a masked NaN/inf row poisons a masked-dense sum but not a compacted
one), so results stay **bitwise identical**; at <= 10% selectivity
compact-then-dense must be **>= 1.5x** faster.  The sweep is merged into
``BENCH_tri_store.json`` under ``"bounded"``.

    PYTHONPATH=src python -m benchmarks.tri_store_eff \
        [--smoke] [--selective | --bounded]
"""
import argparse
import json
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import PhaseRecorder, emit, min_time
from repro.core.adil import Analysis

# report path anchored at the repo root regardless of the invoking CWD (CI
# uploads the artifact from the checkout root; a relative default silently
# wrote to wherever the runner happened to be)
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_JSON_OUT = os.path.join(REPO_ROOT, "BENCH_tri_store.json")
from repro.core.ir import SystemCatalog, TensorT, standard_catalog
from repro.core.rewrite import UNCOMPACTED_PIPELINE, UNPUSHED_PIPELINE
from repro.stores import ColumnStore, GraphStore, TextStore, store_engines

# the naive baseline keeps PR 3's *unfused* per-op shape (fusion would
# collapse store ops and quietly halve its host round-trips) and swaps
# only the placement rule
NAIVE_PIPELINE = tuple(p for p in UNPUSHED_PIPELINE if p != "place_xfers") \
    + ("place_xfers_naive",)


def build_workload(rng, *, tweets, docs, hashtags, edges, vocab, terms_hi,
                   iters):
    user = rng.randint(0, max(tweets // 20, 2), tweets).astype(np.int32)
    tag = (rng.zipf(1.3, tweets) % hashtags).astype(np.int32)
    cols = {
        "user": user,
        "hashtag": tag,
        "doc": np.arange(tweets, dtype=np.int32),
        "engagement": (rng.gamma(2.0, 12.0, tweets)).astype(np.float32),
        "retweets": rng.randint(0, 500, tweets).astype(np.int32),
        "ts": rng.randint(0, 1 << 20, tweets).astype(np.int32),
    }
    # ride-along metric columns (likes, replies, quotes, ...): the analysis
    # never reads them, so planned placement never moves them — but naive
    # per-op materialization round-trips the *whole* relation every call.
    # This is AWESOME's in-memory placement argument in its purest form.
    for i in range(28):
        cols[f"metric{i}"] = rng.rand(tweets).astype(np.float32)
    table = ColumnStore(cols)
    e = rng.randint(0, hashtags, (2, edges))
    graph = GraphStore.from_edges(e[0], e[1], hashtags, symmetric=True)
    # the first ``docs`` tweets have indexed text (a corpus is typically a
    # filtered slice of the relation, not 1:1 with it)
    lens = rng.randint(3, terms_hi, docs)
    flat = (rng.zipf(1.4, int(lens.sum())) % vocab).astype(np.int64)
    corpus = TextStore.from_docs(np.split(flat, np.cumsum(lens)[:-1]), vocab)

    cat = standard_catalog()
    with Analysis("tri_store_eff", cat) as a:
        tw = a.bind("tweets", table)
        gr = a.bind("g", graph)
        cx = a.bind("cx", corpus)
        q = a.input("q", TensorT((vocab,), "float32", ("vocab",)))
        t = a.op("rel_scan", tw)
        hot = a.op("rel_filter", t, col="engagement", cmp="ge", value=25.0)
        viral = a.op("rel_filter", hot, col="retweets", cmp="ge", value=10)
        seeds = a.op("rel_group_agg", viral, key="hashtag",
                     num_groups=hashtags, aggs=(("seed", "count", None),))
        sv = a.op("col_tensor", seeds, col="seed", dim="nodes")
        fr = a.op("graph_expand", gr, sv, hops=2)
        pr = a.op("graph_pagerank", gr, fr, iters=iters, damping=0.85)
        hits = a.op("text_topk", cx, q, k=64)
        # probe the tweet relation against the top-k hits (unique build
        # keys); unmatched rows mask out, so the per-hashtag score sum
        # equals summing over the hits alone — but the wide joined relation
        # is exactly the intermediate naive placement round-trips
        j = a.op("rel_join", t, hits, left_on="doc", right_on="doc")
        trel = a.op("rel_group_agg", j, key="hashtag", num_groups=hashtags,
                    aggs=(("textrel", "sum", "score"),))
        tv = a.op("col_tensor", trel, col="textrel", dim="nodes")
        comb = a.op("residual_add", pr, tv)
        a.store(comb)

    inputs = {"tweets": table.payload(), "g": graph.payload(),
              "cx": corpus.payload(),
              "q": jnp.asarray(corpus.query_vector(rng.randint(0, vocab, 6)))}
    return a, inputs


def build_selective_workload(rng, selectivity, *, tweets, hashtags, edges,
                             vocab, terms_lo, terms_hi):
    """Time-windowed ranking: "among the window's tweets, the top-k most
    query-relevant, aggregated per hashtag, plus the window's seed
    expansion over the co-mention graph".

    Tweets arrive append-ordered (``ts`` ascending), so a recency window
    is a clustered doc range — exactly the regime where masked block-
    skipping pays.  Hashtag popularity is zipfian (popular tags = low
    ids), so the seed frontier clusters too.  The window's selection is
    expressed *relationally* (filter -> sel_mask -> masked top-k); the
    default pipeline's ``push_predicates`` carries it into the text and
    graph engines, the unpushed PR 3 pipeline executes it densely.
    """
    docs = tweets                       # 1:1 tweet <-> indexed document
    tag = (rng.zipf(1.3, tweets) % hashtags).astype(np.int32)
    cols = {
        "hashtag": tag,
        "doc": np.arange(tweets, dtype=np.int32),
        "ts": np.arange(tweets, dtype=np.int32),       # append-ordered log
        "engagement": (rng.gamma(2.0, 12.0, tweets)).astype(np.float32),
    }
    for i in range(8):
        cols[f"metric{i}"] = rng.rand(tweets).astype(np.float32)
    table = ColumnStore(cols)
    # co-mention edges between zipf-popular tags: frontier support clusters
    src = (rng.zipf(1.3, edges) % hashtags).astype(np.int64)
    dst = rng.randint(0, hashtags, edges)
    graph = GraphStore.from_edges(src, dst, hashtags, symmetric=True)
    lens = rng.randint(terms_lo, terms_hi, docs)
    flat = (rng.zipf(1.4, int(lens.sum())) % vocab).astype(np.int64)
    corpus = TextStore.from_docs(np.split(flat, np.cumsum(lens)[:-1]), vocab)

    cut = int(tweets * (1.0 - selectivity))
    cat = standard_catalog()
    with Analysis(f"tri_selective_{selectivity}", cat) as a:
        tw = a.bind("tweets", table)
        gr = a.bind("g", graph)
        cx = a.bind("cx", corpus)
        q = a.input("q", TensorT((vocab,), "float32", ("vocab",)))
        t = a.op("rel_scan", tw)
        recent = a.op("rel_filter", t, col="ts", cmp="ge", value=cut,
                      selectivity=selectivity)
        m = a.op("sel_mask", recent, col="doc", size=docs)
        sc = a.op("text_scores", cx, q)
        hits = a.op("masked_topk", sc, m, k=64)
        j = a.op("rel_join", recent, hits, left_on="doc", right_on="doc")
        trel = a.op("rel_group_agg", j, key="hashtag", num_groups=hashtags,
                    aggs=(("textrel", "sum", "score"),))
        seeds = a.op("rel_group_agg", recent, key="hashtag",
                     num_groups=hashtags, aggs=(("seed", "count", None),))
        sv = a.op("col_tensor", seeds, col="seed", dim="nodes")
        fr = a.op("graph_expand", gr, sv, hops=2)
        tv = a.op("col_tensor", trel, col="textrel", dim="nodes")
        comb = a.op("residual_add", fr, tv)
        a.store(comb)

    inputs = {"tweets": table.payload(), "g": graph.payload(),
              "cx": corpus.payload(),
              "q": jnp.asarray(corpus.query_vector(rng.randint(0, vocab, 6)))}
    return a, inputs


def build_bounded_workload(rng, selectivity, *, tweets, hashtags, metrics):
    """Windowed relational rollup: "this window's tweets, joined against
    the hashtag dimension table, rolled up per hashtag over ``metrics``
    engagement columns".  The window filter carries an exact
    ``selectivity=`` hint (windows are ranges over the append-ordered
    ``ts`` column, so the fraction is known), which is precisely the
    confidence ``choose_compaction`` requires before bounding a capacity:
    the compacted plan probes and aggregates ~selectivity x tweets rows
    while the masked plan drags all of them behind the validity vector.
    """
    cols = {
        "hashtag": (rng.zipf(1.3, tweets) % hashtags).astype(np.int32),
        "doc": np.arange(tweets, dtype=np.int32),
        "ts": np.arange(tweets, dtype=np.int32),       # append-ordered log
    }
    for i in range(metrics):
        cols[f"metric{i}"] = rng.rand(tweets).astype(np.float32)
    table = ColumnStore(cols)
    dims = ColumnStore({"hashtag": np.arange(hashtags, dtype=np.int32),
                        "weight": rng.rand(hashtags).astype(np.float32)})

    cut = int(tweets * (1.0 - selectivity))
    cat = standard_catalog()
    with Analysis(f"tri_bounded_{selectivity}", cat) as a:
        tw = a.bind("tweets", table)
        dm = a.bind("dims", dims)
        t = a.op("rel_scan", tw)
        recent = a.op("rel_filter", t, col="ts", cmp="ge", value=cut,
                      selectivity=selectivity)
        j = a.op("rel_join", recent, dm, left_on="hashtag",
                 right_on="hashtag")
        aggs = tuple((f"s{i}", "sum", f"metric{i}") for i in range(metrics))
        roll = a.op("rel_group_agg", j, key="hashtag", num_groups=hashtags,
                    aggs=aggs + (("w", "sum", "weight"),))
        out = a.op("col_tensor", roll, col="s0", dim="nodes")
        for i in range(1, metrics):
            out = a.op("residual_add", out,
                       a.op("col_tensor", roll, col=f"s{i}", dim="nodes"))
        a.store(out)

    inputs = {"tweets": table.payload(), "dims": dims.payload()}
    return a, inputs


# merge_report / SECTIONS moved to benchmarks.common (provenance stamping
# + history append live there now); re-exported here because
# tri_store_sharded and older tooling import them from this module
from benchmarks.common import SECTIONS, merge_report  # noqa: E402,F401


def t_min(f, inputs, warmup=2, iters=10, phases=None):
    """min-of-N timing (see ``benchmarks.common.min_time``); kept here as
    the name other benchmarks import (``tri_store_sharded``)."""
    return min_time(f, inputs, warmup=warmup, iters=iters, phases=phases)


def run_traced(args, planned, inputs, phases):
    """EXPLAIN ANALYZE smoke (``--trace-out``): run the plan eagerly
    traced vs untraced (min-of-N on both sides), enforce the <= 5%
    overhead guard, write the Chrome-trace + JSON-lines exports, and print
    the merged ``predicted~ / observed=`` report."""
    from repro.core.tracing import validate_chrome_trace

    recorder = None
    if getattr(args, "flight_dir", None):
        from repro.core.ledger import FlightRecorder
        recorder = FlightRecorder(capacity=32, dump_dir=args.flight_dir)

    f_plain = lambda i: planned({}, i)            # noqa: E731
    f_traced = lambda i: planned.analyze({}, i, recorder=recorder)  # noqa: E731
    with phases.phase("trace"):
        # interleaved min-of-N: clock drift / runner noise hits both paths
        # equally instead of biasing whichever loop ran second
        jax.block_until_ready(f_plain(inputs))
        jax.block_until_ready(f_traced(inputs))
        t_plain = t_traced = float("inf")
        for _ in range(8):
            t0 = time.perf_counter()
            jax.block_until_ready(f_plain(inputs))
            t_plain = min(t_plain, time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(f_traced(inputs))
            t_traced = min(t_traced, time.perf_counter() - t0)
    overhead = t_traced / t_plain - 1.0
    ok = overhead <= 0.05
    print(f"[tri_store_eff] eager untraced {t_plain * 1e3:.1f} ms vs "
          f"traced {t_traced * 1e3:.1f} ms -> overhead {overhead:+.1%} "
          f"({'ok' if ok else 'FAIL: > 5%'})")

    trace = planned.last_run_trace
    trace.to_chrome(args.trace_out)
    jsonl = os.path.splitext(args.trace_out)[0] + ".jsonl"
    trace.to_jsonl(jsonl)
    with open(args.trace_out) as fh:
        errs = validate_chrome_trace(json.load(fh))
    if errs:
        print(f"[tri_store_eff] FAIL: chrome trace schema: {errs[:5]}")
        ok = False
    print(f"[tri_store_eff] wrote {args.trace_out} "
          f"({len(trace.spans)} spans; load at ui.perfetto.dev) and {jsonl}")

    report = planned.explain(analyze=True)
    head = report.index("  EXPLAIN ANALYZE")
    print(report[head:])

    out = {
        "untraced_ms": t_plain * 1e3, "traced_ms": t_traced * 1e3,
        "overhead": overhead, "overhead_ok": bool(ok),
        "spans": len(trace.spans), "wall_ms": trace.wall_ms,
        "sync_ms": trace.sync_ms, "chrome": args.trace_out, "jsonl": jsonl,
        "collective_totals": trace.collective_totals(),
    }
    if recorder is not None:
        # end-of-run dump: the flight ring (every analyze's RunTrace
        # summary + any overflow trips) lands as a JSONL artifact
        dump = recorder.trip("run_complete", {"benchmark": "tri_store_eff"})
        out["flight"] = {"events": len(recorder), "trips":
                         [r for r, _ in recorder.trips], "dump": dump}
        print(f"[tri_store_eff] flight recorder: {len(recorder)} events, "
              f"dumped to {dump}")
    return ok, out


def run_placement(args):
    from repro.core.ledger import default_ledger
    phases = PhaseRecorder()
    rng = np.random.RandomState(0)
    size = (dict(tweets=120_000, docs=6_000, hashtags=1024, edges=4_000,
                 vocab=256, terms_hi=6, iters=2) if args.smoke else
            dict(tweets=250_000, docs=30_000, hashtags=2048, edges=20_000,
                 vocab=512, terms_hi=6, iters=3))
    # clean accounting baseline: the only registrations after this reset
    # are this workload's three store payloads (+ plan-cache inserts)
    default_ledger().reset()
    analysis, inputs = build_workload(rng, **size)

    # identical engine set for both paths (no pallas: the point under test
    # is placement, and identical impls guarantee bitwise-equal results)
    engines = store_engines()
    syscat = SystemCatalog()
    with phases.phase("plan"):
        planned = analysis.compile(syscat, engines=engines, cache=False)
        naive = analysis.compile(syscat, engines=engines, cache=False,
                                 rewrite_pipeline=NAIVE_PIPELINE)

    n_pin = sum(1 for r in planned.report
                if r["pattern"] == "xfer_op" and r["chosen"] == "xfer_pin")
    n_spill = sum(1 for n in naive.concrete.topo()
                  if n.impl == "xfer_spill")
    print(f"[tri_store_eff] planned: {n_pin} boundaries pinned; "
          f"naive: {n_spill} per-op host materializations")

    fp = jax.jit(lambda i: planned({}, i))
    fn = jax.jit(lambda i: naive({}, i))
    out_p = np.asarray(fp(inputs))
    out_n = np.asarray(fn(inputs))
    identical = np.array_equal(out_p, out_n)
    print(f"[tri_store_eff] bitwise-identical results: {identical}")

    t_planned = t_min(fp, inputs, phases=phases)
    t_naive = t_min(fn, inputs, phases=phases)
    speedup = t_naive / t_planned
    emit([
        ("tri_planned", t_planned * 1e6, f"speedup={speedup:.2f}x"),
        ("tri_naive_per_op", t_naive * 1e6, ""),
    ])
    print(f"[tri_store_eff] planned {t_planned * 1e3:.1f} ms vs naive "
          f"{t_naive * 1e3:.1f} ms -> {speedup:.2f}x")

    ok = identical and speedup >= args.min_speedup
    if not identical:
        print("[tri_store_eff] FAIL: results differ")
    if speedup < args.min_speedup:
        print(f"[tri_store_eff] FAIL: speedup {speedup:.2f}x < "
              f"{args.min_speedup:.1f}x")

    report = {
        "mode": "placement", "smoke": bool(args.smoke),
        "min_speedup": args.min_speedup, "workload": size,
        "planned_ms": t_planned * 1e3, "naive_ms": t_naive * 1e3,
        "speedup": speedup, "identical": bool(identical),
        "pinned": n_pin, "spilled": n_spill,
    }
    # ledger gate: the cost model's capacity-derived byte prediction must
    # land within 2x of the measured payload bytes for *every* store
    ledger = default_ledger()
    ledger_rows = []
    ledger_ok = True
    for entry, pred, act, ratio in ledger.predicted_vs_actual():
        within = ratio is not None and 0.5 <= ratio <= 2.0
        ledger_ok &= within
        ledger_rows.append({
            "owner": "/".join(map(str, entry.owner)), "kind": entry.kind,
            "predicted_bytes": pred, "actual_bytes": act,
            "ratio": ratio, "within_2x": bool(within)})
        print(f"[tri_store_eff] ledger {entry.kind}: predicted "
              f"{pred / 1e6:.2f} MB, actual {act / 1e6:.2f} MB "
              f"({ratio:.2f}x) {'ok' if within else 'FAIL: outside 2x'}")
    if not ledger_rows:
        ledger_ok = False
        print("[tri_store_eff] FAIL: no ledger predictions registered")
    print(ledger.report())
    ok = ok and ledger_ok
    report["ledger"] = {
        "ok": bool(ledger_ok), "rows": ledger_rows,
        "total_bytes": ledger.total_bytes(),
        "peak_bytes": ledger.peak_bytes,
        "leaks": [reason for reason, _e in ledger.leaks()],
    }

    if args.trace_out:
        trace_ok, trace_report = run_traced(args, planned, inputs, phases)
        ok = ok and trace_ok
        report["trace"] = trace_report
    report["phases_ms"] = phases.as_dict()
    report["ok"] = bool(ok)
    merge_report(args.json_out, report, section="placement")
    print(f"[tri_store_eff] wrote {args.json_out} (placement section)")
    return 0 if ok else 1


def run_selective(args):
    phases = PhaseRecorder()
    size = (dict(tweets=120_000, hashtags=16_384, edges=60_000,
                 vocab=512, terms_lo=10, terms_hi=18) if args.smoke else
            dict(tweets=250_000, hashtags=32_768, edges=150_000,
                 vocab=1024, terms_lo=12, terms_hi=20))
    sweep = [0.01, 0.05, 0.10, 1.0]
    engines = store_engines()
    syscat = SystemCatalog()
    rows, ok = [], True
    for sel in sweep:
        rng = np.random.RandomState(0)
        analysis, inputs = build_selective_workload(rng, sel, **size)
        with phases.phase("plan"):
            pushed = analysis.compile(syscat, engines=engines, cache=False)
            unpushed = analysis.compile(syscat, engines=engines, cache=False,
                                        rewrite_pipeline=UNPUSHED_PIPELINE)
        impls = {n.impl for n in pushed.concrete.topo()}
        fp = jax.jit(lambda i, p=pushed: p({}, i))
        fu = jax.jit(lambda i, u=unpushed: u({}, i))
        identical = bool(np.array_equal(np.asarray(fp(inputs)),
                                        np.asarray(fu(inputs))))
        tp = t_min(fp, inputs, phases=phases)
        tu = t_min(fu, inputs, phases=phases)
        speedup = tu / tp
        rows.append({
            "selectivity": sel,
            "pushed_ms": tp * 1e3, "unpushed_ms": tu * 1e3,
            "speedup": speedup, "identical": identical,
            "masked_impls": sorted(i for i in impls
                                   if "skip" in i or "masked" in i),
        })
        print(f"[tri_store_eff] sel={sel:>5.0%}  pushed {tp * 1e3:7.1f} ms  "
              f"unpushed {tu * 1e3:7.1f} ms  -> {speedup:5.2f}x  "
              f"identical={identical}  {rows[-1]['masked_impls']}")
        ok &= identical
        if sel <= 0.10:
            ok &= speedup >= args.min_speedup
            if speedup < args.min_speedup:
                print(f"[tri_store_eff] FAIL: sel={sel:.0%} speedup "
                      f"{speedup:.2f}x < {args.min_speedup:.1f}x")
        if not identical:
            print(f"[tri_store_eff] FAIL: sel={sel:.0%} results differ")

    report = {
        "benchmark": "tri_store_eff", "mode": "selective",
        "smoke": bool(args.smoke), "min_speedup": args.min_speedup,
        "workload": size, "sweep": rows, "ok": bool(ok),
        "phases_ms": phases.as_dict(),
    }
    merge_report(args.json_out, report)
    print(f"[tri_store_eff] wrote {args.json_out}")
    emit([(f"tri_pushed_sel{int(r['selectivity'] * 100)}",
           r["pushed_ms"] * 1e3, f"speedup={r['speedup']:.2f}x")
          for r in rows])
    return 0 if ok else 1


def run_bounded(args):
    phases = PhaseRecorder()
    size = (dict(tweets=150_000, hashtags=4096, metrics=6) if args.smoke
            else dict(tweets=400_000, hashtags=8192, metrics=8))
    sweep = [0.01, 0.05, 0.10, 1.0]
    engines = store_engines()
    syscat = SystemCatalog()
    rows, ok = [], True
    for sel in sweep:
        rng = np.random.RandomState(0)
        analysis, inputs = build_bounded_workload(rng, sel, **size)
        with phases.phase("plan"):
            compacted = analysis.compile(syscat, engines=engines,
                                         cache=False)
            masked = analysis.compile(syscat, engines=engines, cache=False,
                                      rewrite_pipeline=UNCOMPACTED_PIPELINE)
        # compact appears standalone or as a step inside a fused rel chain
        has_compact = any(
            "compact" in n.impl
            or any(op == "compact" for op, *_ in n.attrs.get("chain", ()))
            for n in compacted.concrete.topo())
        fc = jax.jit(lambda i, c=compacted: c({}, i))
        fm = jax.jit(lambda i, m=masked: m({}, i))
        identical = bool(np.array_equal(np.asarray(fc(inputs)),
                                        np.asarray(fm(inputs))))
        tc = t_min(fc, inputs, phases=phases)
        tm = t_min(fm, inputs, phases=phases)
        speedup = tm / tc
        rows.append({
            "selectivity": sel,
            "compacted_ms": tc * 1e3, "masked_ms": tm * 1e3,
            "speedup": speedup, "identical": identical,
            "compact_inserted": has_compact,
        })
        print(f"[tri_store_eff] sel={sel:>5.0%}  compact {tc * 1e3:7.1f} ms"
              f"  masked {tm * 1e3:7.1f} ms  -> {speedup:5.2f}x  "
              f"identical={identical}  compact_inserted={has_compact}")
        ok &= identical
        if sel <= 0.10:
            ok &= has_compact and speedup >= args.min_speedup
            if speedup < args.min_speedup:
                print(f"[tri_store_eff] FAIL: sel={sel:.0%} speedup "
                      f"{speedup:.2f}x < {args.min_speedup:.1f}x")
            if not has_compact:
                print(f"[tri_store_eff] FAIL: sel={sel:.0%} planner did "
                      f"not insert compaction")
        else:
            ok &= not has_compact     # full window: no compaction, parity
        if not identical:
            print(f"[tri_store_eff] FAIL: sel={sel:.0%} results differ")

    report = {
        "mode": "bounded", "smoke": bool(args.smoke),
        "min_speedup": args.min_speedup, "workload": size,
        "sweep": rows, "ok": bool(ok), "phases_ms": phases.as_dict(),
    }
    merge_report(args.json_out, report, section="bounded")
    print(f"[tri_store_eff] wrote {args.json_out} (bounded section)")
    emit([(f"tri_bounded_sel{int(r['selectivity'] * 100)}",
           r["compacted_ms"] * 1e3, f"speedup={r['speedup']:.2f}x")
          for r in rows])
    return 0 if ok else 1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized workload (seconds, not minutes)")
    ap.add_argument("--selective", action="store_true",
                    help="predicate-pushdown sweep (pushed vs PR 3 "
                         "unpushed) instead of placement vs naive")
    ap.add_argument("--bounded", action="store_true",
                    help="bounded-relation sweep: compact-then-dense vs "
                         "masked-dense")
    ap.add_argument("--min-speedup", type=float, default=2.0)
    ap.add_argument("--json-out", default=DEFAULT_JSON_OUT)
    ap.add_argument("--trace-out", default=None,
                    help="EXPLAIN ANALYZE the placement plan: write a "
                         "Chrome-trace JSON (Perfetto-loadable) here plus "
                         "a .jsonl span log, and enforce the <= 5% traced "
                         "overhead guard (placement mode only)")
    ap.add_argument("--flight-dir", default=None,
                    help="flight-recorder dump directory: traced runs "
                         "record RunTrace summaries into a bounded ring "
                         "and dump JSONL here on overflow / completion")
    args = ap.parse_args(argv)
    if args.bounded:
        return run_bounded(args)
    if args.selective:
        return run_selective(args)
    return run_placement(args)


if __name__ == "__main__":
    sys.exit(main())
