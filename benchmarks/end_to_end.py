"""End-to-end efficiency (paper Fig. 12/13).

The paper compares SingleThread / DataParallel / AWESOME wall-clock on two
workloads.  The analogue here, on one CPU core:

  * naive       ≙ SingleThread — no rewrites (unfused q/k/v + full SDPA),
                  first-candidate selection, no partitioning pass;
  * dataparallel≙ + §5.2 partitioned parallelism — structural on 1 device
                  (its pod-scale effect is the dry-run/roofline table);
  * awesome     ≙ + fusion rewrites + learned-cost selection (+ buffering).

Two workloads mirror PoliSci (mixed pipeline, moderate seq) and NewsAnalysis
(long-sequence analytics where the cost model's banded-attention choice is
the big win), each swept over input sizes like the paper's newsS / newsR.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.cost_model import CostModel
from repro.core.executor import plan_and_compile
from repro.core.ir import SystemCatalog
from repro.models import build_model
from repro.models.lm import CATALOG

from .common import emit, time_fn

SYS = SystemCatalog()

# the paper's workflow: calibrate on this machine, select with the learned
# model (falls back to the analytic roofline model when not yet calibrated)
_COEFFS = "experiments/cost_coeffs.json"


def _cost_model():
    if os.path.exists(_COEFFS):
        return CostModel.load(_COEFFS)
    return None


MODES = {
    "naive": dict(rewrite_pipeline=("decompose",), data_parallel=False,
                  engines=("xla",)),
    "dataparallel": dict(rewrite_pipeline=("decompose",),
                         data_parallel=True, engines=("xla",)),
    "awesome": dict(data_parallel=True, engines=("xla",)),
}


def _run(arch, seq, batch=2, window=None):
    cfg = get_smoke_config(arch).replace(dtype="float32")
    if window:
        cfg = cfg.replace(window=window, local_ratio=5)
    model = build_model(cfg)
    plan = model.build_plan(batch, seq, mode="train")
    params, _ = model.init_params(jax.random.key(0))
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, (batch, seq)), jnp.int32)
    batch_d = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

    rows = []
    base_us = None
    cm = _cost_model()
    for mode, kw in MODES.items():
        fwd = plan_and_compile(plan, CATALOG, SYS,
                               cost_model=cm if mode == "awesome" else None,
                               **kw)
        f = jax.jit(lambda p, b: jax.grad(
            lambda pp: fwd(pp, b))(p)["final_norm"]["scale"][0])
        sec = time_fn(f, params, batch_d, warmup=1, iters=3)
        us = sec * 1e6
        if mode == "naive":
            base_us = us
        rows.append((f"end_to_end/{arch}/seq{seq}/{mode}", us,
                     f"speedup_vs_naive={base_us / us:.2f}x"))
    return rows


def main():
    rows = []
    # PoliSci analogue: moderate seq, dense pipeline
    for seq in (64, 128):
        rows += _run("qwen3-0.6b", seq)
    # NewsAnalysis analogue: long-seq where banded attention wins
    for seq in (256, 512):
        rows += _run("gemma3-27b", seq, window=32)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
