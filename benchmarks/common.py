"""Shared benchmark utilities."""
import time
from contextlib import contextmanager

import jax


class PhaseRecorder:
    """Per-phase wall-time provenance for a benchmark run: how long each
    named phase (warmup, measure, plan, trace, ...) actually took, emitted
    into the benchmark's JSON report so the trajectory file carries its own
    timing provenance alongside the results."""

    def __init__(self):
        self.phases: dict = {}     # name -> accumulated seconds

    @contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.phases[name] = (self.phases.get(name, 0.0)
                                 + time.perf_counter() - t0)

    def as_dict(self) -> dict:
        """Phase timings in milliseconds, JSON-ready."""
        return {name: sec * 1e3 for name, sec in self.phases.items()}


def time_fn(fn, *args, warmup=2, iters=5, phases=None):
    """Median wall time of a jitted callable, in seconds."""
    rec = phases if phases is not None else PhaseRecorder()
    with rec.phase("warmup"):
        for _ in range(warmup):
            out = fn(*args)
            jax.block_until_ready(out)
    ts = []
    with rec.phase("measure"):
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def min_time(fn, *args, warmup=2, iters=10, phases=None):
    """min-of-N wall time of a callable, in seconds: background noise in
    shared CI runners is strictly additive, so the minimum is the clean
    estimate of the path's cost.  Warmup and measure loops record into
    ``phases`` (a :class:`PhaseRecorder`) when given."""
    rec = phases if phases is not None else PhaseRecorder()
    with rec.phase("warmup"):
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
    best = float("inf")
    with rec.phase("measure"):
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
    return best


def emit(rows):
    """Print ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
