"""Shared benchmark utilities."""
import json
import os
import subprocess
import time
from contextlib import contextmanager

import jax

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class PhaseRecorder:
    """Per-phase wall-time provenance for a benchmark run: how long each
    named phase (warmup, measure, plan, trace, ...) actually took, emitted
    into the benchmark's JSON report so the trajectory file carries its own
    timing provenance alongside the results."""

    def __init__(self):
        self.phases: dict = {}     # name -> accumulated seconds

    @contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.phases[name] = (self.phases.get(name, 0.0)
                                 + time.perf_counter() - t0)

    def as_dict(self) -> dict:
        """Phase timings in milliseconds, JSON-ready."""
        return {name: sec * 1e3 for name, sec in self.phases.items()}


def time_fn(fn, *args, warmup=2, iters=5, phases=None):
    """Median wall time of a jitted callable, in seconds."""
    rec = phases if phases is not None else PhaseRecorder()
    with rec.phase("warmup"):
        for _ in range(warmup):
            out = fn(*args)
            jax.block_until_ready(out)
    ts = []
    with rec.phase("measure"):
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def min_time(fn, *args, warmup=2, iters=10, phases=None):
    """min-of-N wall time of a callable, in seconds: background noise in
    shared CI runners is strictly additive, so the minimum is the clean
    estimate of the path's cost.  Warmup and measure loops record into
    ``phases`` (a :class:`PhaseRecorder`) when given."""
    rec = phases if phases is not None else PhaseRecorder()
    with rec.phase("warmup"):
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
    best = float("inf")
    with rec.phase("measure"):
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
    return best


def emit(rows):
    """Print ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


# --------------------------------------------------------------------------
# report provenance + the shared BENCH_tri_store.json merge
# --------------------------------------------------------------------------


def git_sha(short: int = 12) -> str:
    """The commit this run measured: CI's ``GITHUB_SHA`` when set, else
    ``git rev-parse HEAD``, else ``"unknown"`` (a bare tarball checkout
    still benchmarks, it just can't be compared across commits)."""
    sha = os.environ.get("GITHUB_SHA", "")
    if not sha:
        try:
            sha = subprocess.run(
                ["git", "rev-parse", "HEAD"], cwd=REPO_ROOT,
                capture_output=True, text=True, timeout=10,
            ).stdout.strip()
        except Exception:
            sha = ""
    return sha[:short] if sha else "unknown"


def provenance(mesh_shape=None) -> dict:
    """What produced this report: commit, device fleet, platform.  Stamped
    into every section ``merge_report`` writes — the history gate refuses
    to compare records whose provenance differs (an 8-device sweep is not
    a regression of a 1-device sweep)."""
    out = {
        "git_sha": git_sha(),
        "devices": jax.device_count(),
        "platform": jax.default_backend(),
        "cpu_count": os.cpu_count(),
        "recorded_at": time.time(),
    }
    if mesh_shape is not None:
        out["mesh_shape"] = list(mesh_shape)
    return out


# sections the per-mode runs own inside the one shared artifact: a
# top-level (selective) write must carry them along, never clobber them
SECTIONS = ("bounded", "sharded", "placement")


def merge_report(json_out, report, section=None, mesh_shape=None,
                 history_out=None):
    """Write ``report`` to ``json_out``, preserving the other modes'
    sections: a mode's sweep lands under its ``section`` inside whatever
    is already there; the selective sweep becomes the top level but
    carries all prior sections along.  Every write stamps provenance
    (git SHA, device count, mesh shape) into the section and appends a
    one-line record to the benchmark history JSONL
    (``BENCH_history.jsonl`` next to ``json_out`` unless ``history_out``
    overrides; the CI regression gate diffs consecutive histories)."""
    report = dict(report)
    report["provenance"] = provenance(mesh_shape)
    base = {}
    if os.path.exists(json_out):
        try:
            with open(json_out) as fh:
                base = json.load(fh)
        except Exception:
            base = {}
    if section is not None:
        base[section] = report
        out = base
    else:
        carried = {k: base[k] for k in SECTIONS if k in base}
        out = dict(report, **carried)
    with open(json_out, "w") as fh:
        json.dump(out, fh, indent=2)
    try:
        from benchmarks.history import append_record
        if history_out is None:
            history_out = os.path.join(
                os.path.dirname(os.path.abspath(json_out)),
                "BENCH_history.jsonl")
        append_record(history_out, section or "selective", report)
    except Exception as exc:      # history is telemetry, never a failure
        print(f"[common] history append skipped: {exc!r}")
