"""Shared benchmark utilities."""
import time

import jax


def time_fn(fn, *args, warmup=2, iters=5):
    """Median wall time of a jitted callable, in seconds."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(rows):
    """Print ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
