"""Benchmark history + the CI perf-regression gate: provenance stamping in
merge_report, history-record append/load, newest-per-section comparison,
provenance-mismatch skipping, the bootstrap path, and the >20%-slowdown
failure the gate exists for."""
import json
import os

from benchmarks.common import SECTIONS, merge_report, provenance
from benchmarks.history import (append_record, check, compare,
                                extract_metrics, latest_per_section,
                                load_history)


def _rec(section, metrics, *, smoke=True, devices=1, platform="cpu",
         sha="aaa"):
    return {"record": "bench", "section": section, "git_sha": sha,
            "devices": devices, "platform": platform, "smoke": smoke,
            "ok": True, "metrics": metrics}


# --------------------------------------------------------------------------
# pinned-metric extraction per section
# --------------------------------------------------------------------------


def test_extract_metrics_per_section():
    assert extract_metrics("placement", {"planned_ms": 12.5}) == \
        {"planned_ms": 12.5}
    sel = {"sweep": [{"selectivity": 0.01, "pushed_ms": 3.0},
                     {"selectivity": 0.1, "pushed_ms": 5.0}]}
    assert extract_metrics("selective", sel) == \
        {"pushed_ms@0.01": 3.0, "pushed_ms@0.1": 5.0}
    bnd = {"sweep": [{"selectivity": 0.05, "compacted_ms": 7.0}]}
    assert extract_metrics("bounded", bnd) == {"compacted_ms@0.05": 7.0}
    shd = {"sweep": [{"tweets": 48000, "sharded_ms": 99.0}]}
    assert extract_metrics("sharded", shd) == {"sharded_ms@48000": 99.0}
    assert extract_metrics("unknown", {"x": 1}) == {}


# --------------------------------------------------------------------------
# append / load round-trip
# --------------------------------------------------------------------------


def test_append_and_load_roundtrip(tmp_path):
    path = str(tmp_path / "BENCH_history.jsonl")
    report = {"planned_ms": 10.0, "smoke": True, "ok": True,
              "provenance": {"git_sha": "abc123", "devices": 1,
                             "platform": "cpu", "recorded_at": 1.0}}
    rec = append_record(path, "placement", report)
    assert rec["git_sha"] == "abc123"
    assert rec["metrics"] == {"planned_ms": 10.0}
    append_record(path, "placement", dict(report, planned_ms=11.0))
    records = load_history(path)
    assert len(records) == 2
    # later lines win in the newest-per-section view
    latest = latest_per_section(records)
    assert latest["placement"]["metrics"]["planned_ms"] == 11.0


def test_load_history_skips_corrupt_lines(tmp_path):
    path = str(tmp_path / "h.jsonl")
    with open(path, "w") as fh:
        fh.write("not json\n")
        fh.write(json.dumps(_rec("placement", {"planned_ms": 1.0})) + "\n")
        fh.write(json.dumps({"record": "other"}) + "\n")
    assert len(load_history(path)) == 1
    assert load_history(str(tmp_path / "missing.jsonl")) == []


# --------------------------------------------------------------------------
# the gate: regression threshold, provenance matching, bootstrap
# --------------------------------------------------------------------------


def _write(path, records):
    with open(path, "w") as fh:
        for rec in records:
            fh.write(json.dumps(rec) + "\n")


def test_gate_trips_on_25_percent_slowdown(tmp_path, capsys):
    prev = str(tmp_path / "prev.jsonl")
    new = str(tmp_path / "new.jsonl")
    _write(prev, [_rec("placement", {"planned_ms": 100.0})])
    _write(new, [_rec("placement", {"planned_ms": 125.0}, sha="bbb")])
    assert check(prev, new, threshold=0.20) == 1
    assert "REGRESSION" in capsys.readouterr().out


def test_gate_passes_within_threshold(tmp_path):
    prev = str(tmp_path / "prev.jsonl")
    new = str(tmp_path / "new.jsonl")
    _write(prev, [_rec("placement", {"planned_ms": 100.0}),
                  _rec("bounded", {"compacted_ms@0.05": 50.0})])
    _write(new, [_rec("placement", {"planned_ms": 115.0}, sha="bbb"),
                 _rec("bounded", {"compacted_ms@0.05": 40.0}, sha="bbb")])
    assert check(prev, new, threshold=0.20) == 0


def test_gate_skips_provenance_mismatch(tmp_path, capsys):
    prev = str(tmp_path / "prev.jsonl")
    new = str(tmp_path / "new.jsonl")
    # 3x slower, but the previous record measured a full (non-smoke)
    # 8-device run: not comparable, skipped, gate passes
    _write(prev, [_rec("sharded", {"sharded_ms@48000": 10.0},
                       smoke=False, devices=8)])
    _write(new, [_rec("sharded", {"sharded_ms@48000": 30.0})])
    assert check(prev, new, threshold=0.20) == 0
    out = capsys.readouterr().out
    assert "skip sharded" in out and "no comparable metrics" in out


def test_gate_bootstraps_without_previous_history(tmp_path):
    new = str(tmp_path / "new.jsonl")
    _write(new, [_rec("placement", {"planned_ms": 100.0})])
    assert check(str(tmp_path / "missing.jsonl"), new) == 0


def test_gate_fails_on_empty_new_history(tmp_path):
    prev = str(tmp_path / "prev.jsonl")
    _write(prev, [_rec("placement", {"planned_ms": 100.0})])
    assert check(prev, str(tmp_path / "empty.jsonl")) == 1


def test_compare_is_newest_per_section_and_pointwise(tmp_path):
    prev = [_rec("bounded", {"compacted_ms@0.01": 10.0,
                             "compacted_ms@0.1": 20.0})]
    # two new records for the section: only the later one is compared
    new = [_rec("bounded", {"compacted_ms@0.01": 50.0,
                            "compacted_ms@0.1": 50.0}, sha="bbb"),
           _rec("bounded", {"compacted_ms@0.01": 10.5,
                            "compacted_ms@0.1": 30.0}, sha="ccc")]
    result = compare(prev, new, threshold=0.20)
    assert len(result["compared"]) == 2
    # one point regressed (1.5x), the other is fine (1.05x): pointwise
    assert [r["metric"] for r in result["regressions"]] == \
        ["compacted_ms@0.1"]
    assert result["regressions"][0]["new_sha"] == "ccc"


# --------------------------------------------------------------------------
# merge_report: provenance stamping + history side effect
# --------------------------------------------------------------------------


def test_provenance_carries_commit_and_fleet():
    prov = provenance(mesh_shape=(8, 1))
    assert set(prov) >= {"git_sha", "devices", "platform", "cpu_count",
                         "recorded_at"}
    assert prov["mesh_shape"] == [8, 1]
    assert prov["devices"] >= 1
    # inside the repo the SHA resolves (12-hex short form)
    assert prov["git_sha"] == "unknown" or len(prov["git_sha"]) == 12


def test_merge_report_stamps_provenance_and_appends_history(tmp_path):
    json_out = str(tmp_path / "BENCH.json")
    merge_report(json_out, {"planned_ms": 42.0, "smoke": True, "ok": True},
                 section="placement")
    doc = json.load(open(json_out))
    prov = doc["placement"]["provenance"]
    assert prov["devices"] >= 1 and "git_sha" in prov
    hist = str(tmp_path / "BENCH_history.jsonl")
    assert os.path.exists(hist)
    (rec,) = load_history(hist)
    assert rec["section"] == "placement"
    assert rec["git_sha"] == prov["git_sha"]
    assert rec["metrics"] == {"planned_ms": 42.0}


def test_merge_report_preserves_section_merge_semantics(tmp_path):
    json_out = str(tmp_path / "BENCH.json")
    merge_report(json_out, {"planned_ms": 1.0}, section="placement")
    merge_report(json_out, {"sweep": [], "ok": True}, section="bounded")
    # a top-level (selective) write carries the prior sections along
    merge_report(json_out, {"sweep": [], "mode": "selective"})
    doc = json.load(open(json_out))
    assert doc["mode"] == "selective"
    assert doc["placement"]["planned_ms"] == 1.0
    assert "bounded" in doc and set(SECTIONS) >= {"placement", "bounded"}
    # each write appended one history record
    assert len(load_history(str(tmp_path / "BENCH_history.jsonl"))) == 3


def test_merge_report_honors_history_out_override(tmp_path):
    json_out = str(tmp_path / "BENCH.json")
    hist = str(tmp_path / "elsewhere" / "h.jsonl")
    merge_report(json_out, {"planned_ms": 2.0}, section="placement",
                 history_out=hist)
    assert not os.path.exists(str(tmp_path / "BENCH_history.jsonl"))
    assert len(load_history(hist)) == 1
