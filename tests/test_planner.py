"""Physical planning, §5.2 partition/merge insertion, §5.3 buffering chains,
§6 cost model."""
import numpy as np
import pytest

from repro.core.buffering import partition_chains, plan_buffering
from repro.core.cost_model import (CostModel, op_cost, raw_features,
                                   select_candidates)
from repro.core.ir import (Plan, SystemCatalog, TensorT, infer_types,
                           standard_catalog)
from repro.core.parallel import add_data_parallelism, partition_stats
from repro.core.physical import (DEFAULT_PATTERNS, PHYS_OPS, PhysPlan,
                                 generate_candidates, materialize_choice)
from repro.core.rewrite import rewrite

CAT = standard_catalog()
SYS = SystemCatalog()


def attn_plan(window=0):
    p = Plan("ap")
    p.add_input("h", TensorT((2, 32, 32), "float32",
                             ("batch", "seq", "embed")))
    a = p.add("attention", ["h"], {"heads": 4, "kv_heads": 2, "head_dim": 8,
                                   "embed": 32, "window": window,
                                   "pp": ("attn",)})
    p.set_outputs(a)
    return rewrite(p, CAT)


# --------------------------------------------------------------------------
# Alg. 2: candidate generation
# --------------------------------------------------------------------------

def test_single_candidate_direct_replacement():
    """With pallas off and no window, fused attention has one candidate →
    substituted in place (Alg. 2 lines 6–7), no virtual node."""
    pp = generate_candidates(attn_plan(), engines=("xla",))
    assert not pp.pm
    assert any(n.impl == "sdpa_xla" for n in pp.topo())


def test_multi_candidate_virtual_node():
    pp = generate_candidates(attn_plan(window=8), engines=("xla", "pallas"))
    assert len(pp.pm) == 1
    (vid, cands), = pp.pm.items()
    names = {c.name for c in cands}
    assert names == {"attn_xla", "attn_flash", "attn_banded"}


def test_largest_pattern_matches_first():
    """After fusion the 3-op chain matches, not the single-op sdpa."""
    pp = generate_candidates(attn_plan(window=8), engines=("xla", "pallas"))
    (vid, cands), = pp.pm.items()
    assert pp.nodes[vid].attrs["pattern"] == "fused_attention"


def test_materialize_choice_roundtrip():
    pp = generate_candidates(attn_plan(window=8), engines=("xla", "pallas"))
    choices, report = select_candidates(pp, SYS, engines=("xla", "pallas"))
    concrete = materialize_choice(pp, choices)
    assert not any(n.virtual for n in concrete.topo())
    assert len(report) == 1


# --------------------------------------------------------------------------
# §5.2 partition / merge insertion
# --------------------------------------------------------------------------

def test_partition_inserted_for_pr_op():
    pp = generate_candidates(attn_plan(), engines=("xla",))
    out = add_data_parallelism(pp)
    stats = partition_stats(out)
    assert stats["partition"] >= 1
    assert stats["merge"] == 0          # no ST consumer in this plan


def test_merge_inserted_before_st_op():
    p = PhysPlan("t")
    p.inputs["x"] = TensorT((4, 8), "float32", ("batch", "seq"))
    a = p.add("rmsnorm_xla", ["x"], {})          # PR -> partitions x
    b = p.add("const", [a], {})                  # ST consumer -> merge
    p.outputs = (b,)
    out = add_data_parallelism(p)
    impls = [n.impl for n in out.topo()]
    assert "partition" in impls and "merge" in impls


def test_elementwise_join_never_merges():
    """The cap_all extension: residual_add with two partitioned inputs must
    not all-gather either side (the Iter-0b bug)."""
    p = PhysPlan("t")
    p.inputs["x"] = TensorT((4, 8, 16), "float32",
                            ("batch", "seq", "embed"))
    a = p.add("rmsnorm_xla", ["x"], {})
    b = p.add("mlp_fused_xla", [a], {"ffn": 32, "embed": 16})
    c = p.add("residual_add_xla", [a, b], {})
    p.outputs = (c,)
    out = add_data_parallelism(p)
    assert partition_stats(out)["merge"] == 0


# --------------------------------------------------------------------------
# §5.3 buffering chains (Appendix B rules)
# --------------------------------------------------------------------------

def test_chain_cut_on_blocking_op():
    p = PhysPlan("t")
    p.inputs["x"] = TensorT((4, 8), "float32", ("batch", "seq"))
    a = p.add("rmsnorm_xla", ["x"], {})          # SS
    b = p.add("scan_layers_xla", [a], {})        # B: cuts both sides
    c = p.add("rmsnorm_xla", [b], {})            # SS
    p.outputs = (c,)
    chains = partition_chains(p)
    assert len(chains) == 3


def test_chain_cut_on_fanout():
    p = PhysPlan("t")
    p.inputs["x"] = TensorT((4, 8), "float32", ("batch", "seq"))
    a = p.add("rmsnorm_xla", ["x"], {})
    b = p.add("rmsnorm_xla", [a], {})
    c = p.add("residual_add_xla", [a, b], {})    # a has 2 consumers
    p.outputs = (c,)
    chains = partition_chains(p)
    # rule 3 cuts both outgoing edges of a; rule 2 cuts (b, c)'s non-capOn
    assert all(len(ch) == 1 for ch in chains)


def test_streaming_chain_stays_whole():
    p = PhysPlan("t")
    p.inputs["x"] = TensorT((4, 8), "float32", ("batch", "seq"))
    a = p.add("rmsnorm_xla", ["x"], {})
    b = p.add("rmsnorm_xla", [a], {})
    c = p.add("rmsnorm_xla", [b], {})
    p.outputs = (c,)
    chains = partition_chains(p)
    assert sorted(len(ch) for ch in chains) == [3]


def test_plan_buffering_picks_divisor():
    p = PhysPlan("t")
    p.inputs["x"] = TensorT((24, 8), "float32", ("batch", "seq"))
    a = p.add("rmsnorm_xla", ["x"], {})
    p.outputs = (a,)
    dec = plan_buffering(p, enabled=True, global_batch=24)
    assert dec.enabled and 24 % dec.num_microbatches == 0
    dec2 = plan_buffering(p, enabled=False, global_batch=24)
    assert not dec2.enabled and dec2.num_microbatches == 1


# --------------------------------------------------------------------------
# §6 cost model
# --------------------------------------------------------------------------

def _feat(impl, toks=4096, width=512, **attrs):
    t = TensorT((1, toks, width), "bfloat16", ("batch", "seq", "embed"))
    return raw_features(impl, [t], attrs, SYS)


def test_analytic_costs_order_attention_candidates():
    """Banded must beat full SDPA at long seq with a small window; flash must
    beat full SDPA on memory."""
    m = CostModel()
    attrs = {"heads": 8, "kv_heads": 8, "head_dim": 64, "window": 256}
    t = TensorT((1, 8192, 512), "bfloat16", ("batch", "seq", "embed"))
    full = m.op_seconds("sdpa_xla", [t], attrs, SYS)
    band = m.op_seconds("sdpa_banded_xla", [t], attrs, SYS)
    flash = m.op_seconds("attn_flash_pallas", [t], attrs, SYS)
    assert band < full
    assert flash < full


def test_fit_recovers_polynomial():
    """Eq. 2 fit: synthetic quadratic-in-features cost is recovered."""
    rng = np.random.RandomState(0)
    samples = []
    for _ in range(200):
        f = {k: float(v) for k, v in zip(
            ("f_compute", "f_memory", "f_network", "tokens_m", "width_k"),
            rng.uniform(0, 2, 5))}
        y = (1.0 + 3 * f["f_compute"] + 0.5 * f["f_memory"] ** 2
             + 0.25 * f["tokens_m"] * f["width_k"])
        samples.append(("op_x", f, y))
    m = CostModel().fit(samples)
    pred = m.predict_samples(samples)
    truth = np.array([s[2] for s in samples])
    assert np.max(np.abs(pred - truth)) < 1e-4


def test_fitted_model_changes_selection():
    """§6.3: the learned weights drive argmin selection at virtual nodes."""
    plan = attn_plan(window=8)
    pp = generate_candidates(plan, engines=("xla", "pallas"))
    # craft a model that makes banded absurdly expensive
    bad = CostModel()
    feats = ("f_compute", "f_memory", "f_network", "tokens_m", "width_k")
    n_phi = 1 + 5 + 5 + 10
    w = np.zeros(n_phi)
    w[0] = 1e9
    bad.weights["sdpa_banded_xla"] = w
    choices, report = select_candidates(pp, SYS, bad, engines=("xla", "pallas"))
    assert all(c.name != "attn_banded" for c in choices.values())
