"""Per-kernel correctness: Pallas (interpret=True) vs the pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import mha_reference
from repro.kernels.moe_gmm.ops import grouped_matmul
from repro.kernels.moe_gmm.ref import gmm_reference
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_reference
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_reference


def _randn(rng, shape, dtype):
    return jnp.asarray(rng.randn(*shape), dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,kv,d", [
    (1, 16, 2, 2, 8),       # MHA tiny
    (2, 48, 4, 2, 16),      # GQA, non-multiple-of-block seq
    (1, 128, 8, 1, 32),     # MQA, block-aligned
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(rng, b, s, h, kv, d, causal, window,
                                     dtype):
    q = _randn(rng, (b, s, h, d), dtype)
    k = _randn(rng, (b, s, kv, d), dtype)
    v = _randn(rng, (b, s, kv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = mha_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=8 * TOL[dtype], rtol=8 * TOL[dtype])


def test_flash_attention_decode_shape(rng):
    """q_len=1 against a longer kv (the serve_step hot path)."""
    q = _randn(rng, (2, 1, 4, 16), jnp.float32)
    k = _randn(rng, (2, 40, 2, 16), jnp.float32)
    v = _randn(rng, (2, 40, 2, 16), jnp.float32)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# --------------------------------------------------------------------------
# wkv6
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,h,d", [(1, 8, 2, 8), (2, 24, 3, 8),
                                     (1, 33, 2, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_wkv6_matches_ref(rng, b, t, h, d, dtype):
    r = _randn(rng, (b, t, h, d), dtype)
    k = _randn(rng, (b, t, h, d), dtype)
    v = _randn(rng, (b, t, h, d), dtype)
    w = jnp.asarray(rng.uniform(0.4, 0.99, (b, t, h, d)), dtype)
    u = _randn(rng, (h, d), dtype)
    out = wkv6(r, k, v, w, u, chunk=8, interpret=True)
    ref, _ = wkv6_reference(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_wkv6_state_continuity(rng):
    """Running two halves with carried state == running the whole."""
    b, t, h, d = 1, 16, 2, 8
    r = _randn(rng, (b, t, h, d), jnp.float32)
    k = _randn(rng, (b, t, h, d), jnp.float32)
    v = _randn(rng, (b, t, h, d), jnp.float32)
    w = jnp.asarray(rng.uniform(0.4, 0.99, (b, t, h, d)), jnp.float32)
    u = _randn(rng, (h, d), jnp.float32)
    full, _ = wkv6_reference(r, k, v, w, u)
    y1, s1 = wkv6_reference(r[:, :8], k[:, :8], v[:, :8], w[:, :8], u)
    y2, _ = wkv6_reference(r[:, 8:], k[:, 8:], v[:, 8:], w[:, 8:], u,
                           initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), atol=1e-5)


# --------------------------------------------------------------------------
# ssd
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,h,p,n", [(1, 8, 2, 8, 4), (2, 24, 3, 8, 4),
                                       (1, 40, 2, 16, 8)])
def test_ssd_matches_ref(rng, b, t, h, p, n):
    x = _randn(rng, (b, t, h, p), jnp.float32)
    a = jnp.asarray(rng.uniform(0.5, 0.99, (b, t, h)), jnp.float32)
    bb = _randn(rng, (b, t, h, n), jnp.float32)
    cc = _randn(rng, (b, t, h, n), jnp.float32)
    out = ssd(x, a, bb, cc, chunk=8, interpret=True)
    ref, _ = ssd_reference(x, a, bb, cc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_ssd_state_continuity(rng):
    b, t, h, p, n = 1, 16, 2, 8, 4
    x = _randn(rng, (b, t, h, p), jnp.float32)
    a = jnp.asarray(rng.uniform(0.5, 0.99, (b, t, h)), jnp.float32)
    bb = _randn(rng, (b, t, h, n), jnp.float32)
    cc = _randn(rng, (b, t, h, n), jnp.float32)
    full, sf = ssd_reference(x, a, bb, cc)
    y1, s1 = ssd_reference(x[:, :8], a[:, :8], bb[:, :8], cc[:, :8])
    y2, s2 = ssd_reference(x[:, 8:], a[:, 8:], bb[:, 8:], cc[:, 8:],
                           initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sf), atol=1e-5)


# --------------------------------------------------------------------------
# grouped matmul
# --------------------------------------------------------------------------

@pytest.mark.parametrize("e,c,d,f", [(2, 8, 8, 8), (4, 20, 12, 28),
                                     (3, 128, 64, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_matches_ref(rng, e, c, d, f, dtype):
    x = _randn(rng, (e, c, d), dtype)
    w = _randn(rng, (e, d, f), dtype)
    out = grouped_matmul(x, w, block=8, interpret=True)
    ref = gmm_reference(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=8 * TOL[dtype], rtol=8 * TOL[dtype])


# --------------------------------------------------------------------------
# gradients through the kernels (custom_vjp == oracle VJP)
# --------------------------------------------------------------------------

def test_flash_attention_grad_matches_ref(rng):
    b, s, h, kv, d = 1, 16, 2, 1, 8
    q = _randn(rng, (b, s, h, d), jnp.float32)
    k = _randn(rng, (b, s, kv, d), jnp.float32)
    v = _randn(rng, (b, s, kv, d), jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=True,
                                       interpret=True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(mha_reference(q, k, v, causal=True) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=5e-5, rtol=5e-5)


def test_wkv6_grad_finite(rng):
    b, t, h, d = 1, 8, 2, 8
    r = _randn(rng, (b, t, h, d), jnp.float32)
    k = _randn(rng, (b, t, h, d), jnp.float32)
    v = _randn(rng, (b, t, h, d), jnp.float32)
    w = jnp.asarray(rng.uniform(0.4, 0.99, (b, t, h, d)), jnp.float32)
    u = _randn(rng, (h, d), jnp.float32)
    g = jax.grad(lambda *a: jnp.sum(wkv6(*a, chunk=8, interpret=True) ** 2),
                 argnums=(0, 1, 2, 3, 4))(r, k, v, w, u)
    for x in g:
        assert bool(jnp.all(jnp.isfinite(x)))
        assert float(jnp.sum(jnp.abs(x))) > 0


def test_gmm_grad_matches_einsum(rng):
    x = _randn(rng, (2, 8, 8), jnp.float32)
    w = _randn(rng, (2, 8, 8), jnp.float32)
    gk = jax.grad(lambda x, w: jnp.sum(
        grouped_matmul(x, w, block=8, interpret=True) ** 2),
        argnums=(0, 1))(x, w)
    gr = jax.grad(lambda x, w: jnp.sum(gmm_reference(x, w) ** 2),
                  argnums=(0, 1))(x, w)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5)


# --------------------------------------------------------------------------
# chunked jnp engines vs the sequential oracles
# --------------------------------------------------------------------------

def test_wkv6_chunked_matches_ref(rng):
    from repro.kernels.wkv6.ref import wkv6_chunked
    b, t, h, d = 2, 50, 3, 8
    r = _randn(rng, (b, t, h, d), jnp.float32)
    k = _randn(rng, (b, t, h, d), jnp.float32)
    v = _randn(rng, (b, t, h, d), jnp.float32)
    w = jnp.asarray(rng.uniform(0.4, 0.999, (b, t, h, d)), jnp.float32)
    u = _randn(rng, (h, d), jnp.float32)
    y1, s1 = wkv6_reference(r, k, v, w, u)
    y2, s2 = wkv6_chunked(r, k, v, w, u, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


def test_ssd_chunked_matches_ref(rng):
    from repro.kernels.ssd.ref import ssd_chunked
    b, t, h, p, n = 2, 50, 3, 8, 4
    x = _randn(rng, (b, t, h, p), jnp.float32)
    a = jnp.asarray(rng.uniform(0.5, 0.99, (b, t, h)), jnp.float32)
    bb = _randn(rng, (b, t, h, n), jnp.float32)
    cc = _randn(rng, (b, t, h, n), jnp.float32)
    y1, s1 = ssd_reference(x, a, bb, cc)
    y2, s2 = ssd_chunked(x, a, bb, cc, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)
