"""Serving subsystem: bucketed admission, continuous-batching scheduler,
paged KV pool, plan-output KV seeding, batched decode, and the async
runtime end-to-end against the sequential seed path."""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.executor import plan_and_compile
from repro.core.ir import SystemCatalog, TupleT, ValidationError
from repro.core.plan_cache import PlanCache
from repro.models import build_model
from repro.models.decode import (attn_block_indices, decode_step,
                                 decode_step_batched, init_cache,
                                 seed_cache_from_prefill)
from repro.models.lm import CATALOG
from repro.serving import (AdmissionController, AsyncServingRuntime,
                           ContinuousBatchScheduler, PagedKVPool,
                           ServeRequest, bucket_len, serve_sequential)

SYS = SystemCatalog()


def smoke_model(arch="qwen3-0.6b"):
    cfg = get_smoke_config(arch).replace(dtype="float32")
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(1))
    return cfg, model, params


# --------------------------------------------------------------------------
# bucket_len edge cases (ISSUE satellite)
# --------------------------------------------------------------------------

def test_bucket_len_rounds_up_to_power_of_two():
    assert bucket_len(9) == 16
    assert bucket_len(17) == 32
    assert bucket_len(100) == 128


def test_bucket_len_short_prompts_share_the_floor_bucket():
    assert bucket_len(0) == 8
    assert bucket_len(1) == 8
    assert bucket_len(7, lo=8) == 8
    assert bucket_len(3, lo=4) == 4


def test_bucket_len_exact_power_of_two_is_its_own_bucket():
    for n in (8, 16, 32, 64, 1024):
        assert bucket_len(n) == n          # no promotion to the next bucket


def test_bucket_len_max_context():
    assert bucket_len(100, hi=128) == 128
    with pytest.raises(ValueError):
        bucket_len(129, hi=128)            # longer than the model's context
    # a non-power-of-two ceiling caps the top bucket at the ceiling itself
    assert bucket_len(100, hi=100) == 100
    assert bucket_len(65, hi=100) == 100


def test_bucket_len_invalid_inputs():
    with pytest.raises(ValueError):
        bucket_len(-1)
    with pytest.raises(ValueError):
        bucket_len(4, lo=0)


# --------------------------------------------------------------------------
# admission controller
# --------------------------------------------------------------------------

def test_admission_matrix():
    ac = AdmissionController(max_queue=2, cold_plan_occupancy=0.5)
    # warm buckets admit while there is queue room
    assert ac.decide(warm=True, queue_depth=0, active=4, max_batch=4) == \
        "admit"
    # full queue sheds regardless of warmth
    assert ac.decide(warm=True, queue_depth=2, active=0, max_batch=4) == \
        "reject"
    # cold bucket on a quiet system may plan
    assert ac.decide(warm=False, queue_depth=0, active=1, max_batch=4) == \
        "admit"
    # cold bucket under load waits
    assert ac.decide(warm=False, queue_depth=1, active=4, max_batch=4) == \
        "queue"
    assert ac.can_plan_cold(active=2, max_batch=4)
    assert not ac.can_plan_cold(active=3, max_batch=4)


# --------------------------------------------------------------------------
# scheduler: FIFO + longest-waiting-first, token-boundary join/leave
# --------------------------------------------------------------------------

def test_scheduler_longest_waiting_first_across_buckets():
    sch = ContinuousBatchScheduler(max_batch=2)

    class R:                              # minimal request stub
        def __init__(self, rid):
            self.rid = rid
            self.gen = 4

    sch.enqueue(R("a"), bucket=16, now=0.0)
    sch.enqueue(R("b"), bucket=32, now=1.0)
    sch.enqueue(R("c"), bucket=16, now=2.0)
    assert sch.queue_depth() == 3
    # oldest head overall wins, regardless of bucket
    w = sch.peek_next()
    assert w.request.rid == "a"
    # bucket filter: only warm buckets qualify
    w32 = sch.peek_next(warm_buckets={32})
    assert w32.request.rid == "b"
    # FIFO within a bucket: popping "a" exposes "c" behind "b"
    sch.pop(w)
    assert sch.peek_next().request.rid == "b"

    st = sch.join(R("a"), pos=5, tok=7, first_out=7, now=3.0)
    assert sch.n_active() == 1 and st.slot == 0
    st2 = sch.join(R("b"), pos=9, tok=1, first_out=1, now=3.0)
    assert st2.slot == 1 and sch.free_slot() is None
    sch.leave(0)
    assert sch.free_slot() == 0           # slot reusable at token boundary


# --------------------------------------------------------------------------
# paged KV pool
# --------------------------------------------------------------------------

def test_kv_pool_pages_and_slots():
    _, model, _ = smoke_model()
    pool = PagedKVPool(model, n_slots=2, max_seq=32, page_size=8)
    assert pool.pages_per_slot == 4 and pool.page_budget == 8
    pt = pool.alloc("r1", 9)              # 9 tokens -> 2 pages
    assert len(pt.pages) == 2 and pt.covers(16) and not pt.covers(17)
    assert pool.pages_in_use == 2
    # lazy growth as decode crosses a page boundary
    assert pool.extend("r1", 17)
    assert len(pool.table("r1").pages) == 3
    assert not pool.extend("r1", 33)      # beyond max_seq
    # second slot
    assert pool.alloc("r2", 30) is not None
    assert pool.alloc("r3", 1) is None    # out of slots
    occ = pool.occupancy()
    assert occ["slots_used"] == 2 and occ["pages_used"] == 7
    slot = pool.free("r1")
    assert slot in (0, 1) and pool.pages_in_use == 4
    assert pool.alloc("r3", 1) is not None   # slot recycled, no realloc


def test_kv_pool_page_budget_gates_admission():
    _, model, _ = smoke_model()
    pool = PagedKVPool(model, n_slots=4, max_seq=32, page_size=8,
                       page_budget=5)
    assert pool.alloc("a", 32) is not None        # 4 pages
    # a free slot exists, but only 1 page remains -> memory admission holds
    assert not pool.can_admit(9)
    assert pool.alloc("b", 9) is None
    assert pool.alloc("c", 8) is not None         # exactly 1 page fits


# --------------------------------------------------------------------------
# prefill_kv: per-layer K/V as plan outputs
# --------------------------------------------------------------------------

def test_prefill_kv_plan_types_and_structure():
    _, model, _ = smoke_model()
    plan = model.build_plan(1, 16, mode="prefill_kv")
    assert len(plan.outputs) == 1 + len(model.groups)
    from repro.core.ir import infer_types
    infer_types(plan, CATALOG)
    scan = next(n for n in plan.topo() if n.op == "scan_layers")
    out_t = plan.type_of(scan.id)
    assert isinstance(out_t, TupleT) and len(out_t.elems) == 2
    kv_t = out_t.elems[1]
    n_attn = len(attn_block_indices(model.groups[0]))
    assert isinstance(kv_t, TupleT) and len(kv_t.elems) == n_attn
    k_t = kv_t.elems[0].elems[0]
    assert k_t.dims == ("layers", "batch", "seq", "kv_heads", "head_dim")
    # a different plan identity than the plain prefill (separate cache entry)
    from repro.core.ir import plan_id
    assert plan_id(plan, CATALOG, SYS) != \
        plan_id(model.build_plan(1, 16, mode="prefill"), CATALOG, SYS)


def test_prefill_kv_rejected_for_recurrent_families():
    _, model, _ = smoke_model("rwkv6-3b")
    assert not model.supports_prefill_kv()
    with pytest.raises(ValueError):
        model.build_plan(1, 16, mode="prefill_kv")


def test_collect_kv_without_emitters_fails_validation():
    _, model, _ = smoke_model()
    plan = model.build_plan(1, 16, mode="prefill")
    scan = next(n for n in plan.topo() if n.op == "scan_layers")
    scan.attrs["collect_kv"] = True       # no emit_kv attention inside
    from repro.core.ir import infer_types
    with pytest.raises(ValidationError):
        infer_types(plan, CATALOG)


@pytest.mark.parametrize("arch", [
    "qwen3-0.6b",
    pytest.param("gemma3-27b", marks=pytest.mark.slow),
])
def test_plan_seeded_cache_matches_decode_replay(arch, rng):
    """The tentpole equivalence: seeding the KV cache from the planned
    prefill's K/V outputs must match replaying the prompt through
    decode_step — both in cache contents and in subsequent decode logits."""
    cfg, model, params = smoke_model(arch)
    b, s, max_seq = 1, 8, 16
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)

    fwd = plan_and_compile(model.build_plan(b, s, mode="prefill_kv"),
                           CATALOG, SYS, cache=False)
    outs = fwd(params, {"tokens": tokens})
    logits_plan, kv_groups = outs[0], outs[1:]

    cache_ref = init_cache(model, b, max_seq)
    for t in range(s):
        lg, cache_ref = decode_step(model, params, cache_ref,
                                    tokens[:, t:t + 1], jnp.int32(t))
    cache_kv = seed_cache_from_prefill(model, init_cache(model, b, max_seq),
                                       kv_groups, s)
    for g in model.groups:
        for key in cache_ref[g.name]:
            np.testing.assert_allclose(
                np.asarray(cache_ref[g.name][key])[:, :, :s],
                np.asarray(cache_kv[g.name][key])[:, :, :s],
                atol=2e-4, rtol=2e-4, err_msg=f"{g.name}/{key}")
    # prefill logits at the last prompt position == replay's last logits
    np.testing.assert_allclose(
        np.asarray(logits_plan[:, s - 1, :cfg.vocab]),
        np.asarray(lg[:, 0, :cfg.vocab]), atol=2e-2, rtol=2e-2)
    # and decode continues identically from either cache
    tok = jnp.argmax(logits_plan[:, s - 1, :cfg.vocab],
                     axis=-1).astype(jnp.int32)[:, None]
    l_ref, _ = decode_step(model, params, cache_ref, tok, jnp.int32(s))
    l_kv, _ = decode_step(model, params, cache_kv, tok, jnp.int32(s))
    np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_kv),
                               atol=2e-2, rtol=2e-2)


def test_decode_step_batched_matches_per_request_decode(rng):
    """Slots at *different* positions (the continuous batch) must decode
    exactly as each request would alone."""
    cfg, model, params = smoke_model()
    B, max_seq = 3, 12
    cache = init_cache(model, B, max_seq)
    idx = jnp.asarray([0, 3, 7], jnp.int32)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (B, 1)), jnp.int32)
    lb, cb = decode_step_batched(model, params, cache, toks, idx)
    for i in range(B):
        c1 = jax.tree.map(lambda x: x[:, i:i + 1], cache)
        l1, c1n = decode_step(model, params, c1, toks[i:i + 1], idx[i])
        np.testing.assert_allclose(np.asarray(l1[0]), np.asarray(lb[i]),
                                   atol=1e-4, rtol=1e-4)
        for g in model.groups:
            for key in c1n[g.name]:
                np.testing.assert_allclose(
                    np.asarray(c1n[g.name][key][:, 0]),
                    np.asarray(cb[g.name][key][:, i]),
                    atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# the async runtime end-to-end
# --------------------------------------------------------------------------

def test_runtime_matches_sequential_and_never_replans(rng):
    cfg, model, params = smoke_model()
    lens = [5, 12, 8, 16, 3]
    reqs = [ServeRequest(i, tuple(rng.randint(0, cfg.vocab, n).tolist()), 8)
            for i, n in enumerate(lens)]
    pc = PlanCache()
    rt = AsyncServingRuntime(model, params, max_batch=2, max_seq=64,
                             plan_cache=pc)
    assert rt.kv_mode
    rt.warmup(lens)
    misses0 = pc.stats()["misses"]
    res = rt.serve(reqs, timeout_s=120)
    assert [r.status for r in res] == ["ok"] * len(reqs)
    assert pc.stats()["misses"] == misses0          # no warm-bucket re-plan
    assert pc.stats()["hits"] >= len(reqs)
    seq = serve_sequential(model, params, reqs, max_seq=64,
                           plan_cache=PlanCache())
    for a, b in zip(res, seq):
        assert a.tokens == b.tokens and len(a.tokens) == 8
    # metrics populated
    s = rt.metrics.summary()
    assert s["completed"] == len(reqs) and s["generated_tokens"] == 40
    assert s["plan_hit_rate"] > 0
    # pool drained after the trace
    occ = rt.pool.occupancy()
    assert occ["slots_used"] == 0 and occ["pages_used"] == 0


def test_batched_prefill_identical_token_streams(rng):
    """Same-bucket waiting requests prefill as ONE vmapped planned forward
    (multi-query satellite): token streams must be identical to the
    sequential per-request prefill path."""
    cfg, model, params = smoke_model()
    lens = [7, 6, 5, 8]                       # one bucket (8) for all four
    mk = lambda: [                                            # noqa: E731
        ServeRequest(i, tuple(rng2.randint(0, cfg.vocab, n).tolist()), 6)
        for i, n in enumerate(lens)]
    rng2 = np.random.RandomState(3)
    reqs_b = mk()
    rng2 = np.random.RandomState(3)
    reqs_s = mk()

    rt_b = AsyncServingRuntime(model, params, max_batch=4, max_seq=32,
                               plan_cache=PlanCache(), prefill_batch=4)
    rt_b.warmup(lens)
    res_b = rt_b.serve(reqs_b, timeout_s=120)
    assert rt_b.registry.count("lm.batched_prefills", 0) >= 2

    rt_s = AsyncServingRuntime(model, params, max_batch=4, max_seq=32,
                               plan_cache=PlanCache(), prefill_batch=1)
    rt_s.warmup(lens)
    res_s = rt_s.serve(reqs_s, timeout_s=120)
    assert rt_s.registry.count("lm.batched_prefills", 0) == 0
    for a, b in zip(res_b, res_s):
        assert a.status == "ok" and a.tokens == b.tokens
    # pool fully drained after the batched-prefill trace
    occ = rt_b.pool.occupancy()
    assert occ["slots_used"] == 0 and occ["pages_used"] == 0


def test_runtime_replay_fallback_for_recurrent_family(rng):
    cfg, model, params = smoke_model("rwkv6-3b")
    reqs = [ServeRequest(i, tuple(rng.randint(0, cfg.vocab, n).tolist()), 5)
            for i, n in enumerate([4, 9])]
    rt = AsyncServingRuntime(model, params, max_batch=2, max_seq=32,
                             plan_cache=PlanCache())
    assert not rt.kv_mode
    rt.warmup([4, 9])
    res = rt.serve(reqs, timeout_s=120)
    seq = serve_sequential(model, params, reqs, max_seq=32,
                           plan_cache=PlanCache())
    for a, b in zip(res, seq):
        assert a.status == "ok" and a.tokens == b.tokens


def test_runtime_staggered_arrivals_async(rng):
    """Late arrivals join mid-flight at token boundaries; results are
    identical to the all-at-once trace (greedy decode is order-free)."""
    cfg, model, params = smoke_model()
    lens = [5, 12, 8]
    mk = lambda arr: [                                        # noqa: E731
        ServeRequest(i, tuple(rng2.randint(0, cfg.vocab, n).tolist()), 6,
                     arrival=arr * i)
        for i, n in enumerate(lens)]
    rng2 = np.random.RandomState(7)
    reqs0 = mk(0.0)
    rng2 = np.random.RandomState(7)
    reqs_lag = mk(0.01)
    rt = AsyncServingRuntime(model, params, max_batch=2, max_seq=64,
                             plan_cache=PlanCache())
    rt.warmup(lens)
    res0 = rt.serve(reqs0, timeout_s=120)

    rt2 = AsyncServingRuntime(model, params, max_batch=2, max_seq=64,
                              plan_cache=PlanCache())
    rt2.warmup(lens)
    res_lag = asyncio.run(rt2.run(reqs_lag, timeout_s=120))
    for a, b in zip(res0, res_lag):
        assert a.tokens == b.tokens


def test_runtime_page_pressure_queues_instead_of_truncating(rng):
    """Admission reserves prompt+1 pages (the first decode tick writes
    position prompt_len before extend() runs): under a tight page budget a
    request that cannot fit waits for a leaver instead of being admitted
    and immediately truncated."""
    cfg, model, params = smoke_model()
    # 2 slots x 4 pages of 8 tokens, but a global budget of 5 pages:
    # r0 (prompt 24 -> reserves 25 tokens = 4 pages) leaves 1 page, so
    # r1 (prompt 8 -> reserves 9 tokens = 2 pages) must wait for r0
    reqs = [
        ServeRequest(0, tuple(rng.randint(0, cfg.vocab, 24).tolist()), 8),
        ServeRequest(1, tuple(rng.randint(0, cfg.vocab, 8).tolist()), 8),
    ]
    rt = AsyncServingRuntime(model, params, max_batch=2, max_seq=32,
                             page_size=8, page_budget=5,
                             plan_cache=PlanCache())
    rt.warmup([24, 8])
    res = rt.serve(reqs, timeout_s=120)
    assert [r.status for r in res] == ["ok", "ok"]    # nobody truncated
    assert len(res[0].tokens) == 8 and len(res[1].tokens) == 8
    # r1 really waited: it joined only after r0 finished
    m0, m1 = res[0].metrics, res[1].metrics
    assert m1.joined_at >= m0.finished_at


def test_runtime_rejects_oversized_and_sheds_overload(rng):
    cfg, model, params = smoke_model()
    rt = AsyncServingRuntime(
        model, params, max_batch=1, max_seq=32, plan_cache=PlanCache(),
        admission=AdmissionController(max_queue=2))
    rt.warmup([8])
    too_long = ServeRequest("big", tuple(rng.randint(0, cfg.vocab, 40)), 8)
    rt.submit(too_long)
    assert rt._results["big"].status == "rejected"
    # queue overload: capacity 2, submit 4 -> at least one rejection
    for i in range(4):
        rt.submit(ServeRequest(
            i, tuple(rng.randint(0, cfg.vocab, 8).tolist()), 4))
    assert rt.metrics.rejected >= 2      # "big" + queue-full sheds


def test_run_analysis_shares_metrics_registry(rng):
    """Analytical (tri-store) requests report into the same registry as
    the LM serving series: one report covers both workload families."""
    from repro.core.adil import Analysis
    from repro.stores import ColumnStore, store_engines
    from repro.core.ir import standard_catalog

    _, model, params = smoke_model()
    rt = AsyncServingRuntime(model, params, max_batch=1, max_seq=32,
                             plan_cache=PlanCache())
    table = ColumnStore({"k": np.arange(64, dtype=np.int32),
                         "v": rng.rand(64).astype(np.float32)})
    with Analysis("serve_analytics", standard_catalog()) as a:
        t = a.op("rel_scan", a.bind("t", table))
        g = a.op("rel_group_agg", t, key="k", num_groups=64,
                 aggs=(("s", "sum", "v"),))
        a.store(a.op("col_tensor", g, col="s", dim="nodes"))
    planned = a.compile(SystemCatalog(), engines=store_engines(),
                        cache=False)
    inputs = {"t": table.payload()}

    plain = rt.run_analysis(planned, {}, inputs)
    traced = rt.run_analysis(planned, {}, inputs, analyze=True)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(traced))

    reg = rt.registry
    assert reg.counters["analytics.requests"] == 2
    assert reg.counters["analytics.traced"] == 1
    assert reg.summary("analytics.run_ms").count == 2
    assert reg.summary("analytics.trace_wall_ms").count == 1
    # LM series live in the same registry next to the analytics series
    assert "lm.ttft_s" in reg.summaries
    rep = reg.report()
    assert "analytics.run_ms" in rep and "lm.ttft_s" in rep
