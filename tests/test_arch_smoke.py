"""Per-architecture smoke tests: reduced same-family config, one planned
train step on CPU, asserting output shapes and finite loss; plus one decode
step through the serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core.executor import plan_and_compile
from repro.core.ir import SystemCatalog
from repro.data.pipeline import DataConfig, synth_batch
from repro.models import build_model
from repro.models.decode import decode_step, init_cache
from repro.models.lm import CATALOG
from repro.train.optim import cosine_schedule, make_optimizer
from repro.train.train_step import init_state, make_train_step

SYS = SystemCatalog()
B, S = 2, 16


def _inputs(cfg, model, rng):
    dc = DataConfig(vocab=cfg.vocab, seq_len=S, global_batch=B,
                    frontend_tokens=cfg.frontend_tokens,
                    d_model=cfg.d_model, encdec=cfg.family == "encdec",
                    dtype=str(model.dtype))
    batch = synth_batch(dc, step=0)
    return {k: jnp.asarray(v) for k, v in batch.items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, rng):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    plan = model.build_plan(B, S, mode="train")
    fwd = plan_and_compile(plan, CATALOG, SYS)
    params, specs = model.init_params(jax.random.key(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda s: isinstance(s, tuple) and all(
            isinstance(x, str) for x in s))
    opt = make_optimizer(cfg.optimizer, cosine_schedule(1e-3, 2, 100))
    step = make_train_step(fwd, opt, grad_dtype="float32")
    state = init_state(params, opt)
    batch = _inputs(cfg, model, rng)
    state, metrics = jax.jit(step)(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: loss={loss}"
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    diff = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))),
            state.params, params))
    assert diff > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_logits_shape(arch, rng):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    plan = model.build_plan(B, S, mode="prefill")
    fwd = plan_and_compile(plan, CATALOG, SYS)
    params, _ = model.init_params(jax.random.key(0))
    batch = _inputs(cfg, model, rng)
    batch.pop("labels")
    logits = fwd(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab])))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch, rng):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(0))
    cache = init_cache(model, B, max_seq=8)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, (B, 1)), jnp.int32)
    logits, cache2 = decode_step(model, params, cache, tokens, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab])))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
