"""Cross-engine predicate pushdown + fused store superkernels: rewrite
passes, cost-model gating, masked kernels vs references, and the EXPLAIN
surface."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.adil import Analysis
from repro.core.ir import (SystemCatalog, TensorT, ValidationError,
                           standard_catalog)
from repro.core.rewrite import (DEFAULT_PIPELINE, UNPUSHED_PIPELINE,
                                estimate_selectivity, fuse_store_ops,
                                push_predicates)
from repro.stores import ColumnStore, GraphStore, TextStore, store_engines
from repro.stores import ref as R
from repro.stores.masked_kernels import (masked_segment_agg_pallas,
                                         masked_tfidf_pallas)
from repro.stores.graph_store import expand_frontier, expand_frontier_blockskip
from repro.stores.text_store import (tfidf_topk, tfidf_topk_blockskip,
                                     tfidf_topk_masked)

CAT = standard_catalog()
SYS = SystemCatalog()


def _stores(rng, rows=400, nodes=64, vocab=32):
    table = ColumnStore({
        "hashtag": rng.randint(0, nodes, rows).astype(np.int32),
        "doc": np.arange(rows, dtype=np.int32),
        "ts": np.arange(rows, dtype=np.int32),
        "engagement": (rng.rand(rows) * 50).astype(np.float32),
    })
    e = rng.randint(0, nodes, (2, 300))
    graph = GraphStore.from_edges(e[0], e[1], nodes, symmetric=True)
    corpus = TextStore.from_docs(
        [rng.randint(0, vocab, rng.randint(2, 8)) for _ in range(rows)],
        vocab)
    return table, graph, corpus


def _selective_analysis(table, graph, corpus, *, selectivity, k=16,
                        cut=None):
    """The unpushed selective idiom: filter -> sel_mask -> full text scores
    -> masked top-k -> join -> aggregate (+ seeded graph expansion)."""
    rows = table.rows
    nodes = graph.n_nodes
    cut = int(rows * (1 - selectivity)) if cut is None else cut
    with Analysis("sel", CAT) as a:
        tw = a.bind("tweets", table)
        gr = a.bind("g", graph)
        cx = a.bind("cx", corpus)
        q = a.input("q", TensorT((corpus.vocab,), "float32", ("vocab",)))
        t = a.op("rel_scan", tw)
        recent = a.op("rel_filter", t, col="ts", cmp="ge", value=cut,
                      selectivity=selectivity)
        m = a.op("sel_mask", recent, col="doc", size=corpus.n_docs)
        sc = a.op("text_scores", cx, q)
        hits = a.op("masked_topk", sc, m, k=k)
        j = a.op("rel_join", recent, hits, left_on="doc", right_on="doc")
        trel = a.op("rel_group_agg", j, key="hashtag", num_groups=nodes,
                    aggs=(("textrel", "sum", "score"),))
        seeds = a.op("rel_group_agg", recent, key="hashtag",
                     num_groups=nodes, aggs=(("seed", "count", None),))
        sv = a.op("col_tensor", seeds, col="seed", dim="nodes")
        fr = a.op("graph_expand", gr, sv, hops=2)
        tv = a.op("col_tensor", trel, col="textrel", dim="nodes")
        a.store(a.op("residual_add", fr, tv))
    return a


def _inputs(table, graph, corpus, terms=(1, 2, 3)):
    return {"tweets": table.payload(), "g": graph.payload(),
            "cx": corpus.payload(),
            "q": jnp.asarray(corpus.query_vector(terms))}


# --------------------------------------------------------------------------
# the push_predicates rewrite
# --------------------------------------------------------------------------

def test_push_predicates_mask_into_text(rng):
    a = _selective_analysis(*_stores(rng), selectivity=0.05)
    out = push_predicates(a.plan, CAT)
    ops = [n.op for n in out.topo()]
    assert "text_scores" not in ops and "masked_topk" not in ops
    tk = next(n for n in out.topo() if n.op == "text_topk")
    assert len(tk.inputs) == 3 and tk.attrs["pushed"]
    assert tk.attrs["selectivity"] == pytest.approx(0.05)
    # the mask input is the sel_mask node: the rel-born predicate now
    # crosses the engine boundary into the text engine
    assert out.nodes[tk.inputs[2]].op == "sel_mask"
    info = out.__dict__.get("_pass_info") or {}
    assert any(r["rule"] == "mask_into_text" for r in info.get("pushed", ()))


def test_push_predicates_annotates_graph_frontier(rng):
    a = _selective_analysis(*_stores(rng), selectivity=0.01)
    out = push_predicates(a.plan, CAT)
    ex = next(n for n in out.topo() if n.op == "graph_expand")
    # row selectivity rescaled onto the hashtag domain, still < 1
    assert 0.0 < ex.attrs["frontier_selectivity"] < 1.0


def test_push_predicates_sinks_filter_below_join(rng):
    table, graph, corpus = _stores(rng)
    with Analysis("sink", CAT) as a:
        tw = a.bind("tweets", table)
        cx = a.bind("cx", corpus)
        q = a.input("q", TensorT((corpus.vocab,), "float32", ("vocab",)))
        t = a.op("rel_scan", tw)
        hits = a.op("text_topk", cx, q, k=8)
        j = a.op("rel_join", t, hits, left_on="doc", right_on="doc")
        f = a.op("rel_filter", j, col="ts", cmp="ge", value=100)
        a.store(a.op("col_tensor", f, col="engagement"))
    out = push_predicates(a.plan, CAT)
    jn = next(n for n in out.topo() if n.op == "rel_join")
    assert out.nodes[jn.inputs[0]].op == "rel_filter"   # probe side narrowed
    # the filter no longer runs above the join
    cons = out.consumers()
    assert all(out.nodes[c].op != "rel_filter" for c in cons[jn.id])


def test_push_predicates_keeps_build_side_filters(rng):
    """A predicate over a column gathered from the build side cannot sink
    below the join — the rewrite must leave it in place."""
    table, graph, corpus = _stores(rng)
    with Analysis("nosink", CAT) as a:
        tw = a.bind("tweets", table)
        cx = a.bind("cx", corpus)
        q = a.input("q", TensorT((corpus.vocab,), "float32", ("vocab",)))
        t = a.op("rel_scan", tw)
        hits = a.op("text_topk", cx, q, k=8)
        j = a.op("rel_join", t, hits, left_on="doc", right_on="doc")
        f = a.op("rel_filter", j, col="score", cmp="ge", value=0.5)
        a.store(a.op("col_tensor", f, col="score"))
    out = push_predicates(a.plan, CAT)
    jn = next(n for n in out.topo() if n.op == "rel_join")
    assert out.nodes[jn.inputs[0]].op == "rel_scan"     # probe untouched
    assert any(n.op == "rel_filter" for n in out.topo())


def test_push_predicates_noop_on_tensor_plans():
    from repro.core.ir import Plan
    p = Plan("t")
    p.add_input("h", TensorT((2, 8, 16), "float32",
                             ("batch", "seq", "embed")))
    a = p.add("mlp", ["h"], {"ffn": 32, "embed": 16})
    p.set_outputs(a)
    assert push_predicates(p, CAT) is p
    assert fuse_store_ops(p, CAT) is p


def test_selectivity_estimation(rng):
    a = _selective_analysis(*_stores(rng), selectivity=0.02)
    plan = a.plan
    from repro.core.ir import infer_types
    infer_types(plan, CAT)
    flt = next(n for n in plan.topo() if n.op == "rel_filter")
    assert estimate_selectivity(plan, flt.id, CAT) == pytest.approx(0.02)
    # without an explicit hint, comparators fall back to heuristics
    with Analysis("h", CAT) as b:
        tw = b.bind("t", _stores(rng)[0])
        f = b.op("rel_filter", b.op("rel_scan", tw), col="ts", cmp="eq",
                 value=3)
        b.store(b.op("col_tensor", f, col="engagement"))
    infer_types(b.plan, CAT)
    f2 = next(n for n in b.plan.topo() if n.op == "rel_filter")
    assert estimate_selectivity(b.plan, f2.id, CAT) == pytest.approx(0.1)


# --------------------------------------------------------------------------
# fuse_store_ops
# --------------------------------------------------------------------------

def test_fuse_store_ops_collapses_rel_chains(rng):
    a = _selective_analysis(*_stores(rng), selectivity=0.05)
    out = fuse_store_ops(push_predicates(a.plan, CAT), CAT)
    fused = [n for n in out.topo() if n.op == "rel_fused"]
    assert fused, "expected at least one fused rel chain"
    chains = [[s[0] for s in n.attrs["chain"]] for n in fused]
    assert ["rel_scan", "rel_filter"] in chains
    assert ["rel_join", "rel_group_agg"] in chains
    # fused nodes carry the chain's output type
    for n in fused:
        assert out.types[n.id] == n.attrs["chain"][-1][3]


def test_fused_plan_runs_identical_to_unfused(rng):
    table, graph, corpus = _stores(rng)
    a = _selective_analysis(table, graph, corpus, selectivity=0.05)
    pipeline_nofuse = tuple(p for p in DEFAULT_PIPELINE
                            if p != "fuse_store_ops")
    fused = a.compile(SYS, engines=store_engines(), cache=False)
    unfused = a.compile(SYS, engines=store_engines(), cache=False,
                        rewrite_pipeline=pipeline_nofuse)
    assert any(n.impl == "rel_fused_col" for n in fused.concrete.topo())
    ins = _inputs(table, graph, corpus)
    np.testing.assert_array_equal(np.asarray(fused({}, ins)),
                                  np.asarray(unfused({}, ins)))


# --------------------------------------------------------------------------
# cost-model gating (pushdown only where it wins)
# --------------------------------------------------------------------------

def test_full_selectivity_keeps_dense_plan(rng):
    """At 100% selectivity the planner must keep the unpushed (dense)
    execution: the skip candidates are not even offered."""
    table, graph, corpus = _stores(rng)
    a = _selective_analysis(table, graph, corpus, selectivity=1.0, cut=0)
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    impls = {n.impl for n in fn.concrete.topo()}
    assert "text_topk_inv" in impls
    assert "text_topk_skip_inv" not in impls
    assert "graph_expand_skip" not in impls


def test_low_selectivity_chooses_skip_candidates(rng):
    table, graph, corpus = _stores(rng)
    a = _selective_analysis(table, graph, corpus, selectivity=0.05)
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    impls = {n.impl for n in fn.concrete.topo()}
    assert "text_topk_skip_inv" in impls
    chosen = {r["pattern"]: r["chosen"] for r in fn.report}
    assert chosen["text_topk_op"] == "topk_blockskip"


def test_explain_reports_pushed_masks(rng):
    a = _selective_analysis(*_stores(rng), selectivity=0.05)
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    text = fn.explain()
    assert "push_predicates" in text and "fuse_store_ops" in text
    assert "mask_into_text" in text and "selectivity=0.05" in text
    assert "fused rel_scan->rel_filter" in text


# --------------------------------------------------------------------------
# masked kernels vs references
# --------------------------------------------------------------------------

def test_masked_tfidf_pallas_matches_reference(rng):
    docs, vocab = 37, 16
    tx = TextStore.from_docs(
        [rng.randint(0, vocab, rng.randint(1, 9)) for _ in range(docs)],
        vocab)
    q = tx.query_vector([1, 3, 5, 5])
    mask = rng.rand(docs) > 0.5
    w = (q * tx.idf).astype(np.float32)
    got = masked_tfidf_pallas(
        jnp.asarray(tx.doc_ids), jnp.asarray(w[tx.term_ids]),
        jnp.asarray(tx.tf), jnp.asarray(tx.doc_len[tx.doc_ids]),
        jnp.asarray(mask[tx.doc_ids].astype(np.float32)),
        n_docs=docs, interpret=True)
    want = R.masked_tfidf_scores_ref(tx.doc_ids, tx.term_ids, tx.tf,
                                     tx.doc_len, tx.idf, q, mask)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


def test_masked_segment_agg_pallas_matches_reference(rng):
    n, groups = 150, 11
    vals = rng.randn(n).astype(np.float32)
    keys = rng.randint(0, groups, n).astype(np.int32)
    maskw = (rng.rand(n) > 0.4).astype(np.float32)
    s, c = masked_segment_agg_pallas(jnp.asarray(vals), jnp.asarray(keys),
                                     jnp.asarray(maskw), num_groups=groups,
                                     interpret=True)
    ws, wc = R.masked_segment_agg_ref(vals, keys, maskw, groups)
    np.testing.assert_allclose(np.asarray(s), ws, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c), wc, rtol=1e-5, atol=1e-6)


def test_blockskip_scoring_bitwise_matches_dense(rng):
    docs, vocab = 300, 32
    tx = TextStore.from_docs(
        [rng.randint(0, vocab, rng.randint(1, 7)) for _ in range(docs)],
        vocab)
    cp = tx.payload()
    q = jnp.asarray(tx.query_vector([2, 4, 4, 7]))
    for mask in (np.zeros(docs, bool),            # 0%
                 np.ones(docs, bool),             # 100%
                 np.arange(docs) >= docs - 30,    # clustered window
                 rng.rand(docs) > 0.9):           # scattered
        m = jnp.asarray(mask)
        for blk in (64, 128, 1 << 20):
            got = tfidf_topk_blockskip(cp, q, m, 16, block=blk)
            want = tfidf_topk_masked(cp, q, m, 16)
            for g, w in zip(got, want):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_expand_blockskip_bitwise_matches_dense(rng):
    n, e = 200, 900
    g = GraphStore.from_edges(rng.randint(0, n, e), rng.randint(0, n, e),
                              n, symmetric=True)
    gp = g.payload()
    for density in (0.0, 0.02, 1.0):
        fr = np.where(rng.rand(n) < density, rng.rand(n), 0.0) \
            .astype(np.float32)
        for hops in (1, 3):
            got = expand_frontier_blockskip(gp, jnp.asarray(fr), hops=hops,
                                            block=128)
            want = expand_frontier(gp, jnp.asarray(fr), hops=hops)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# regressions: k clamping, masked-out top-k slots
# --------------------------------------------------------------------------

def test_tfidf_topk_clamps_k_beyond_doc_count(rng):
    tx = TextStore.from_docs([[0, 1], [1, 2], [2, 3]], vocab=4)
    ids, scores, valid = tfidf_topk(tx.payload(), jnp.asarray(
        tx.query_vector([1])), 50)                 # k >> n_docs: no crash
    assert ids.shape == (3,) and bool(np.asarray(valid).all())


def test_text_topk_k_clamp_through_planner(rng):
    table, graph, corpus = _stores(rng, rows=40)
    with Analysis("clamp", CAT) as a:
        cx = a.bind("cx", corpus)
        q = a.input("q", TensorT((corpus.vocab,), "float32", ("vocab",)))
        hits = a.op("text_topk", cx, q, k=10_000)
        a.store(hits)
    assert a.plan.types[a.plan.outputs[0]].rows == corpus.n_docs
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    out = fn({}, {"cx": corpus.payload(),
                  "q": jnp.asarray(corpus.query_vector([1]))})
    assert out["doc"].shape == (corpus.n_docs,)
    with pytest.raises(ValidationError):           # k < 1 still rejected
        with Analysis("bad", CAT) as b:
            cx = b.bind("cx", corpus)
            q = b.input("q", TensorT((corpus.vocab,), "float32", ("vocab",)))
            b.store(b.op("text_topk", cx, q, k=0))


def test_pushed_plan_bitwise_identical_at_edge_selectivities(rng):
    """Deterministic twin of the hypothesis property: 0% (empty build
    side — no unmasked doc survives into the join), 100%, and k beyond
    the doc count must all be bitwise-identical pushed vs unpushed."""
    table, graph, corpus = _stores(rng, rows=80, nodes=12, vocab=16)
    ins = _inputs(table, graph, corpus)
    for sel, k in ((0.0, 8), (1.0, 8), (0.05, 10_000), (0.2, 4)):
        a = _selective_analysis(table, graph, corpus, selectivity=sel, k=k)
        pushed = a.compile(SYS, engines=store_engines(), cache=False)
        unpushed = a.compile(SYS, engines=store_engines(), cache=False,
                             rewrite_pipeline=UNPUSHED_PIPELINE)
        np.testing.assert_array_equal(np.asarray(pushed({}, ins)),
                                      np.asarray(unpushed({}, ins)))


def test_masked_topk_overflow_slots_are_invalid_not_inf(rng):
    """k beyond the unmasked count: the overflow slots come back invalid
    with score 0.0 — never -inf, which would NaN-poison a downstream
    mask-weighted aggregate."""
    docs = 20
    tx = TextStore.from_docs([[0, 1]] * docs, vocab=4)
    mask = np.zeros(docs, bool)
    mask[:3] = True
    ids, scores, valid = tfidf_topk_masked(
        tx.payload(), jnp.asarray(tx.query_vector([0, 1])),
        jnp.asarray(mask), 8)
    v = np.asarray(valid)
    assert v.sum() == 3 and not v[3:].any()
    assert np.isfinite(np.asarray(scores)).all()
    assert (np.asarray(scores)[~v] == 0.0).all()
