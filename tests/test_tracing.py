"""EXPLAIN ANALYZE observability: the span tracer (nesting, thread
safety, off-by-default zero-overhead path), the merged predicted-vs-
observed report, the single-transfer count sink, the trace exporters
(Chrome-trace / JSON-lines), cost-model refitting from traces, the shared
serving metrics registry, and the traced-execution overhead guard."""
import io
import json
import threading

import numpy as np
import jax
import pytest

from repro.core.adil import Analysis
from repro.core.feedback import SelectivityFeedback, fit_weights
from repro.core.ir import SystemCatalog, standard_catalog
from repro.core.tracing import (RunTrace, Tracer, resolve_counts,
                                tree_bytes, validate_chrome_trace,
                                xfer_wire_bytes)
from repro.serving.metrics import (MetricsRegistry, ServingMetrics, Summary)
from repro.stores import ColumnStore, store_engines

CAT = standard_catalog()


# --------------------------------------------------------------------------
# workload: a small windowed rollup (filter -> join -> group -> tensor)
# --------------------------------------------------------------------------


def build_rollup(tweets=20_000, hashtags=256, selectivity=0.1, metrics=2):
    rng = np.random.RandomState(0)
    cols = {"hashtag": (rng.zipf(1.3, tweets) % hashtags).astype(np.int32),
            "doc": np.arange(tweets, dtype=np.int32),
            "ts": np.arange(tweets, dtype=np.int32)}
    for i in range(metrics):
        cols[f"m{i}"] = rng.rand(tweets).astype(np.float32)
    table = ColumnStore(cols)
    dims = ColumnStore({"hashtag": np.arange(hashtags, dtype=np.int32),
                        "weight": rng.rand(hashtags).astype(np.float32)})
    cut = int(tweets * (1.0 - selectivity))
    with Analysis(f"trace_rollup_{tweets}_{selectivity}", CAT) as a:
        tw = a.bind("tweets", table)
        dm = a.bind("dims", dims)
        t = a.op("rel_scan", tw)
        recent = a.op("rel_filter", t, col="ts", cmp="ge", value=cut,
                      selectivity=selectivity)
        j = a.op("rel_join", recent, dm, left_on="hashtag",
                 right_on="hashtag")
        aggs = tuple((f"s{i}", "sum", f"m{i}") for i in range(metrics))
        roll = a.op("rel_group_agg", j, key="hashtag", num_groups=hashtags,
                    aggs=aggs)
        out = a.op("col_tensor", roll, col="s0", dim="nodes")
        a.store(out)
    inputs = {"tweets": table.payload(), "dims": dims.payload()}
    return a, inputs


def compile_rollup(**kw):
    a, inputs = build_rollup(**kw)
    planned = a.compile(SystemCatalog(), engines=store_engines(),
                        cache=False)
    return planned, inputs


# --------------------------------------------------------------------------
# the tracer itself
# --------------------------------------------------------------------------


def test_span_nesting_parent_ids():
    tr = Tracer()
    with tr.span("outer") as o:
        with tr.span("mid") as m:
            with tr.span("inner") as i:
                pass
    by = {s.name: s for s in tr.spans}
    assert by["inner"].parent_id == by["mid"].span_id
    assert by["mid"].parent_id == by["outer"].span_id
    assert by["outer"].parent_id is None
    # completion order: innermost closes first
    assert [s.name for s in tr.spans] == ["inner", "mid", "outer"]
    assert all(s.dur >= 0 for s in tr.spans)


def test_annotate_targets_innermost_open_span():
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("inner"):
            tr.annotate(dist="row", coll_bytes=42.0)
    by = {s.name: s for s in tr.spans}
    assert by["inner"].attrs["dist"] == "row"
    assert "dist" not in by["outer"].attrs


def test_tracer_thread_safety():
    tr = Tracer()
    n_threads, per_thread = 8, 50

    def work(tid):
        for i in range(per_thread):
            with tr.span(f"t{tid}_outer{i}"):
                with tr.span(f"t{tid}_inner{i}"):
                    pass

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr.spans) == n_threads * per_thread * 2
    # span ids unique; nesting resolved per-thread (inner's parent is its
    # own thread's outer, never another thread's span)
    ids = [s.span_id for s in tr.spans]
    assert len(set(ids)) == len(ids)
    by_id = {s.span_id: s for s in tr.spans}
    for s in tr.spans:
        if "inner" in s.name:
            parent = by_id[s.parent_id]
            assert parent.tid == s.tid
            assert parent.name.replace("outer", "inner") == s.name


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("x") as sp:
        tr.annotate(a=1)
        tr.defer("count", 3)
        assert sp is None
    tr.resolve()
    assert tr.spans == [] and tr._deferred == []


def test_defer_resolves_in_one_transfer():
    import jax.numpy as jnp
    tr = Tracer()
    with tr.span("op1"):
        tr.defer("count", jnp.int32(7))
    with tr.span("op2"):
        tr.defer("count", jnp.int32(9))
        tr.defer("overflow", jnp.bool_(False))
    tr.resolve()
    by = {s.name: s for s in tr.spans}
    assert by["op1"].attrs["count"] == 7
    assert by["op2"].attrs["count"] == 9
    assert by["op2"].attrs["overflow"] is False


def test_xfer_wire_bytes_formulas():
    assert xfer_wire_bytes("pin", 1000, 4) == 0.0
    assert xfer_wire_bytes("local", 1000, 4) == 0.0
    assert xfer_wire_bytes("replicate", 1000, 4) == pytest.approx(750.0)
    assert xfer_wire_bytes("repartition", 1600, 4) == pytest.approx(300.0)
    assert xfer_wire_bytes("spill", 1000, 4) == pytest.approx(2000.0)
    assert xfer_wire_bytes("replicate", 1000, 1) == 0.0


def test_tree_bytes_counts_leaves():
    import jax.numpy as jnp
    v = {"a": jnp.zeros((10,), jnp.float32), "b": jnp.zeros((4,), jnp.int32)}
    assert tree_bytes(v) == 40 + 16


# --------------------------------------------------------------------------
# EXPLAIN ANALYZE end to end
# --------------------------------------------------------------------------


def test_analyze_matches_untraced_outputs():
    planned, inputs = compile_rollup()
    plain = np.asarray(planned({}, inputs))
    traced = np.asarray(planned.analyze({}, inputs))
    np.testing.assert_array_equal(plain, traced)


def test_explain_analyze_golden_shape():
    planned, inputs = compile_rollup()
    planned.analyze({}, inputs)
    rep = planned.explain(analyze=True)
    # plan-time section still present
    assert "StagedPhysicalPlan" in rep and "choice [" in rep
    # runtime section: wall/sync header + one predicted~/observed= row per
    # executed physical node
    assert "EXPLAIN ANALYZE wall=" in rep
    trace = planned.last_run_trace
    assert trace.op_spans(), "no op spans recorded"
    for sp in trace.op_spans():
        assert f"analyze {sp.name}" in rep
    assert rep.count("predicted~") >= len(trace.op_spans())
    assert rep.count("observed=") >= len(trace.op_spans())
    # BoundedRel ops report observed cardinality; the filter's count sink
    # row renders too
    assert "count=" in rep
    assert "observed ('rel_filter'" in rep


def test_explain_analyze_requires_a_run():
    planned, _ = compile_rollup()
    with pytest.raises(ValueError):
        planned.explain(analyze=True)
    # plain explain still fine (and unchanged signature for old callers)
    assert "StagedPhysicalPlan" in planned.explain()


def test_analyze_xfer_attribution():
    planned, inputs = compile_rollup()
    planned.analyze({}, inputs)
    spans = {s.name: s for s in planned.last_run_trace.op_spans()}
    xfers = [s for s in spans.values() if "xfer_kind" in s.attrs]
    assert xfers, "no xfer nodes traced"
    for s in xfers:
        assert s.attrs["payload_bytes"] > 0
        assert s.attrs["xfer_kind"] in ("pin", "local", "replicate",
                                        "repartition", "spill")
        # device-resident kinds move nothing on the wire off-mesh
        if s.attrs["xfer_kind"] in ("pin", "local"):
            assert s.attrs["wire_bytes"] == 0.0


def test_analyze_drains_feedback_like_observe():
    planned, inputs = compile_rollup()
    fb_obs, fb_ana = SelectivityFeedback(), SelectivityFeedback()
    planned.observe({}, inputs, fb_obs)
    planned.analyze({}, inputs, feedback=fb_ana)
    assert len(fb_obs) == len(fb_ana) > 0
    assert fb_obs.fingerprint() == fb_ana.fingerprint()


def test_resolve_counts_single_transfer_semantics():
    import jax.numpy as jnp
    sink = [(("site", "a"), jnp.float32(12.0), jnp.int32(100)),
            (("compact_overflow", ("site", "a")), jnp.bool_(True), 1)]
    out = resolve_counts(sink)
    assert out[0] == (("site", "a"), 12.0, 100)
    assert out[1][0][0] == "compact_overflow" and out[1][1] == 1.0
    assert resolve_counts([]) == []


# --------------------------------------------------------------------------
# exporters
# --------------------------------------------------------------------------


def test_chrome_trace_export_validates(tmp_path):
    planned, inputs = compile_rollup()
    planned.analyze({}, inputs)
    path = tmp_path / "trace.json"
    planned.last_run_trace.to_chrome(path)
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) == []
    evs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    names = {e["name"] for e in evs}
    assert "run" in names and "device_sync" in names
    # microsecond complete events with args carried through
    assert all(isinstance(e["ts"], float) and e["dur"] >= 0 for e in evs)
    op = next(e for e in evs if e["cat"] == "op")
    assert "impl" in op["args"]


def test_jsonl_export_round_trips(tmp_path):
    planned, inputs = compile_rollup()
    planned.analyze({}, inputs)
    path = tmp_path / "trace.jsonl"
    planned.last_run_trace.to_jsonl(path)
    recs = [json.loads(ln) for ln in path.read_text().splitlines()]
    kinds = [r["record"] for r in recs]
    assert kinds[0] == "run"
    assert kinds.count("span") == len(planned.last_run_trace.spans)
    assert "count" in kinds
    run = recs[0]
    assert run["wall_ms"] > 0 and run["spans"] == kinds.count("span")


def test_validate_chrome_trace_catches_violations():
    assert validate_chrome_trace({}) == ["missing traceEvents"]
    assert validate_chrome_trace({"traceEvents": []})
    bad = {"traceEvents": [{"ph": "X", "pid": 1, "tid": 0, "name": "x",
                            "ts": "oops", "dur": 1.0}]}
    assert any("ts" in e for e in validate_chrome_trace(bad))


# --------------------------------------------------------------------------
# fit_weights: traces as the calibration dataset
# --------------------------------------------------------------------------


def test_fit_weights_from_traces():
    planned, inputs = compile_rollup()
    traces = []
    for _ in range(3):
        planned.analyze({}, inputs)
        traces.append(planned.last_run_trace)
    model = fit_weights(traces, min_samples=3)
    assert model.weights, "no impl got enough samples to fit"
    assert model.fingerprint() != "analytic"
    # the refit model predicts finite times for the ops it saw
    for impl, feats, _sec in traces[0].samples:
        if impl in model.weights:
            x = {k: feats[k] for k in model.feature_names}
            import numpy as _np
            from repro.core.cost_model import poly2
            xv = _np.array([x[k] for k in model.feature_names])
            pred = float(poly2(xv[None, :])[0] @ model.weights[impl])
            assert _np.isfinite(pred)


def test_fit_weights_min_samples_gate():
    t = RunTrace(samples=[("some_impl",
                           {"f_compute": 0.0, "f_memory": 0.0,
                            "f_network": 0.0, "tokens_m": 0.0,
                            "width_k": 0.0}, 1e-3)])
    model = fit_weights([t], min_samples=3)
    assert model.weights == {}          # one sample: gated out


# --------------------------------------------------------------------------
# serving metrics: summaries, registry, shared LM + analytics reporting
# --------------------------------------------------------------------------


def test_summary_percentiles_nearest_rank():
    s = Summary("x")
    for v in range(1, 101):             # 1..100
        s.observe(v)
    assert s.count == 100 and s.min == 1 and s.max == 100
    assert s.percentile(50) == 50
    assert s.percentile(95) == 95
    assert s.percentile(99) == 99
    snap = s.snapshot()
    assert snap["p50"] == 50 and snap["p95"] == 95 and snap["p99"] == 99


def test_summary_bounded_ring_without_keep_samples():
    s = Summary("x", keep_samples=False, cap=8)
    for v in range(100):
        s.observe(v)
    assert len(s.samples) == 8          # bounded memory
    assert s.count == 100 and s.max == 99   # running stats stay exact


def test_serving_metrics_summary_keys_and_percentiles():
    from repro.serving.metrics import RequestMetrics
    m = ServingMetrics()
    for i in range(20):
        rm = RequestMetrics(i, gen=4, submitted_at=0.0, joined_at=0.01,
                            first_token_at=0.02 + i * 0.001,
                            finished_at=0.08 + i * 0.001)
        m.finish(rm)
        m.observe_tick(queue_depth=i % 3, pool_fill=0.5)
    m.observe_plan(hit=True)
    m.observe_plan(hit=False)
    s = m.summary()
    # the legacy keys tests/benchmarks consume
    for k in ("completed", "rejected", "ticks", "mean_ttft_s",
              "mean_tpot_s", "mean_queue_wait_s", "mean_queue_depth",
              "max_queue_depth", "mean_pool_fill", "plan_hits",
              "plan_misses", "plan_hit_rate", "generated_tokens"):
        assert k in s
    assert s["completed"] == 20 and s["generated_tokens"] == 80
    # the new percentile keys
    assert s["p50_ttft_s"] <= s["p95_ttft_s"] <= s["p99_ttft_s"]
    assert "p50" in m.report() and "p95" in m.report()
    # legacy raw-list views stay live
    assert len(m.queue_depth_samples) == 20


def test_registry_shared_between_lm_and_analytics():
    reg = MetricsRegistry()
    m = ServingMetrics(registry=reg)
    m.observe_tick(1, 0.5)
    reg.summary("analytics.run_ms").observe(12.5)
    reg.count("analytics.requests")
    assert "lm.queue_depth" in reg.summaries
    assert "analytics.run_ms" in reg.summaries
    rep = reg.report()
    assert "lm.queue_depth" in rep and "analytics.run_ms" in rep
    assert reg.counters["analytics.requests"] == 1


# --------------------------------------------------------------------------
# overhead guard: tracing must stay within 5% of the untraced eager run
# --------------------------------------------------------------------------


def test_traced_overhead_within_5_percent():
    planned, inputs = compile_rollup(tweets=200_000, hashtags=1024,
                                     metrics=4)

    import time

    # warm both paths (first eager run pays op compilation)
    jax.block_until_ready(planned({}, inputs))
    planned.analyze({}, inputs)
    # interleave the two timing loops: clock drift / background noise then
    # hits both paths equally instead of biasing whichever ran second
    t_plain = t_traced = float("inf")
    for _ in range(10):
        t0 = time.perf_counter()
        jax.block_until_ready(planned({}, inputs))
        t_plain = min(t_plain, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(planned.analyze({}, inputs))
        t_traced = min(t_traced, time.perf_counter() - t0)
    overhead = t_traced / t_plain - 1.0
    assert overhead <= 0.05, (
        f"traced eager run {t_traced * 1e3:.2f} ms vs untraced "
        f"{t_plain * 1e3:.2f} ms: overhead {overhead:+.1%} > 5%")
