"""Staged plan pipeline: content-hashed plan identity, the LRU plan cache,
per-pass EXPLAIN trace, and the pluggable engine registry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adil_parser
from repro.core.adil import Analysis
from repro.core.engines import (dispatch, engine_names, get_engine,
                                resolve_engines)
from repro.core.executor import plan_and_compile
from repro.core.ir import (HardwareSpec, Plan, SystemCatalog, TensorT,
                           ValidationError, plan_fingerprint, plan_id,
                           standard_catalog)
from repro.core.pipeline import (PASS_REGISTRY, PlanOptions, PlanPipeline,
                                 compile_staged, staged_plan_id)
from repro.core.physical import generate_candidates
from repro.core.plan_cache import (PlanCache, load_plan_cache,
                                   save_plan_cache)
from repro.core.rewrite import rewrite

CAT = standard_catalog()
SYS = SystemCatalog()

ADIL_SRC = """
USE demoDB;
create analysis tiny as {
  toks := input([2, 16], int32, dims=[batch, seq]);
  h    := embed(toks, vocab=64, embed=32, pp=[embed], dtype=float32);
  h2   := attention(h, heads=4, kv_heads=2, head_dim=8, embed=32, pp=[attn]);
  out  := mlp(h2, ffn=64, embed=32, pp=[mlp]);
  store(out);
}
"""


def builder_equivalent():
    with Analysis("tiny", CAT) as a:
        toks = a.input("toks", TensorT((2, 16), "int32", ("batch", "seq")))
        h = a.op("embed", toks, vocab=64, embed=32, pp=("embed",),
                 dtype="float32")
        h2 = a.op("attention", h, heads=4, kv_heads=2, head_dim=8, embed=32,
                  pp=("attn",))
        out = a.op("mlp", h2, ffn=64, embed=32, pp=("mlp",))
        a.store(out)
    return a


def attn_plan(window=8, seq=32):
    p = Plan("ap")
    p.add_input("h", TensorT((2, seq, 32), "float32",
                             ("batch", "seq", "embed")))
    a = p.add("attention", ["h"], {"heads": 4, "kv_heads": 2, "head_dim": 8,
                                   "embed": 32, "window": window,
                                   "pp": ("attn",)})
    p.set_outputs(a)
    return p


# --------------------------------------------------------------------------
# plan identity (canonical serialization + content hash)
# --------------------------------------------------------------------------

def test_adil_script_and_builder_share_plan_id():
    """The textual front end and the embedded DSL describe the same workload
    -> identical content hash (node ids are canonicalized away)."""
    parsed = adil_parser.parse(ADIL_SRC, CAT)
    built = builder_equivalent()
    assert plan_id(parsed.plan, CAT, SYS) == plan_id(built.plan, CAT, SYS)
    assert built.plan_id(SYS) == plan_id(built.plan, CAT, SYS)


def test_plan_id_sensitive_to_structure_attrs_and_syscat():
    base = plan_id(attn_plan(window=8), CAT, SYS)
    assert base != plan_id(attn_plan(window=16), CAT, SYS)   # attr change
    assert base != plan_id(attn_plan(seq=64), CAT, SYS)      # shape change
    sys2 = SystemCatalog(mesh_shape=(4, 2))
    assert base != plan_id(attn_plan(window=8), CAT, sys2)   # syscat change
    sys3 = SystemCatalog(hardware=HardwareSpec(peak_flops=1e12))
    assert base != plan_id(attn_plan(window=8), CAT, sys3)   # hardware change
    assert base == plan_id(attn_plan(window=8), CAT, SYS)    # deterministic


def test_fingerprint_ignores_node_ids():
    p1 = attn_plan()
    p2 = Plan("other_name")
    p2.add_input("h", TensorT((2, 32, 32), "float32",
                              ("batch", "seq", "embed")))
    a = p2.add("attention", ["h"], {"heads": 4, "kv_heads": 2, "head_dim": 8,
                                    "embed": 32, "window": 8,
                                    "pp": ("attn",)}, id="totally_different")
    p2.set_outputs(a)
    assert plan_fingerprint(p1) == plan_fingerprint(p2)


def test_callable_attrs_hash_captured_state():
    """Two predicates with identical bytecode but different captured values
    must not collide to one cache entry (closure cells and default args are
    part of the content hash)."""
    def mk(k):
        return lambda v: v > k

    def filter_plan(pred):
        p = Plan("fp")
        p.add_input("xs", TensorT((4, 8), "float32", ("batch", "seq")))
        # wrap in a ListT via map-less direct filter: use attrs only
        nid = p.add("store", ["xs"], {"predicate": pred})
        p.set_outputs(nid)
        return p

    a = plan_fingerprint(filter_plan(mk(1)))
    b = plan_fingerprint(filter_plan(mk(2)))
    assert a != b
    # default-arg capture too
    c = plan_fingerprint(filter_plan(lambda v, k=1: v > k))
    d = plan_fingerprint(filter_plan(lambda v, k=2: v > k))
    assert c != d
    # and identical captures still agree
    assert plan_fingerprint(filter_plan(mk(3))) == \
        plan_fingerprint(filter_plan(mk(3)))


def test_callable_canonicalization_is_process_stable():
    """Callables with nested code objects (genexprs/comprehensions) must
    canonicalize without memory addresses — otherwise plan ids differ
    across processes and the persisted plan cache never hits."""
    from repro.core.ir import _canon
    from repro.core.physical import _has_window

    def with_genexpr(nodes):
        return any(n for n in nodes if n)

    for fn in (_has_window, with_genexpr, lambda xs: [x + 1 for x in xs]):
        assert "0x" not in repr(_canon(fn)), fn


def test_callable_canonicalization_stable_across_hash_seeds():
    """Frozenset literals inside hashed callables (``x in {...}``) iterate
    in PYTHONHASHSEED order; their canonical form must not — otherwise
    plan ids differ per process and persisted warm starts never hit."""
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = ("from repro.core.ir import _canon\n"
            "def pred(n):\n"
            "    return n in {'sdpa', 'attention', 'moe', 'wkv6', 'ssd'}\n"
            "print(repr(_canon(pred)))\n")
    outs = set()
    for seed in ("0", "1", "2"):
        env = {**os.environ, "PYTHONHASHSEED": seed,
               "PYTHONPATH": os.path.join(root, "src")}
        outs.add(subprocess.check_output(
            [sys.executable, "-c", code], env=env).decode())
    assert len(outs) == 1, "canonical form varies with PYTHONHASHSEED"


def test_options_and_cost_model_part_of_staged_id():
    p = attn_plan()
    a = staged_plan_id(p, CAT, SYS, PlanOptions())
    b = staged_plan_id(p, CAT, SYS, PlanOptions(engines=("xla", "pallas")))
    c = staged_plan_id(p, CAT, SYS, PlanOptions(buffering=True,
                                                global_batch=8))
    assert len({a, b, c}) == 3


# --------------------------------------------------------------------------
# plan cache
# --------------------------------------------------------------------------

def test_second_compile_is_cache_hit_and_syscat_change_misses():
    cache = PlanCache()
    s1 = compile_staged(attn_plan(), CAT, SYS, cache=cache)
    assert cache.stats() == {**cache.stats(), "hits": 0, "misses": 1}
    s2 = compile_staged(attn_plan(), CAT, SYS, cache=cache)
    assert s2 is s1                       # the staged plan object is reused
    assert cache.stats()["hits"] == 1
    sys2 = SystemCatalog(mesh_shape=(2, 4))
    s3 = compile_staged(attn_plan(), CAT, sys2, cache=cache)
    assert s3 is not s1
    assert cache.stats()["misses"] == 2


def test_cached_and_cold_planned_functions_agree_bitwise():
    cache = PlanCache()
    cold = plan_and_compile(attn_plan(), CAT, SYS, cache=False)
    plan_and_compile(attn_plan(), CAT, SYS, cache=cache)
    hit = plan_and_compile(attn_plan(), CAT, SYS, cache=cache)
    assert hit.staged is not None and cache.stats()["hits"] == 1
    rng = np.random.RandomState(0)
    params = {"attn": {
        "wq": jnp.asarray(rng.randn(32, 32), jnp.float32),
        "wk": jnp.asarray(rng.randn(32, 16), jnp.float32),
        "wv": jnp.asarray(rng.randn(32, 16), jnp.float32),
        "wo": jnp.asarray(rng.randn(32, 32), jnp.float32),
    }}
    x = jnp.asarray(rng.randn(2, 32, 32), jnp.float32)
    a = cold(params, {"h": x})
    b = hit(params, {"h": x})
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_patterns_and_pass_list_part_of_cache_key():
    """Custom pattern sets and custom pass lists must not collide with the
    default pipeline's cache entries."""
    from repro.core.physical import DEFAULT_PATTERNS
    cache = PlanCache()
    s1 = compile_staged(attn_plan(), CAT, SYS, cache=cache)
    no_dp = PlanPipeline(passes=("rewrite", "generate_candidates",
                                 "select_candidates", "materialize_choice",
                                 "plan_buffering"))
    s2 = compile_staged(attn_plan(), CAT, SYS, cache=cache, pipeline=no_dp)
    assert s2 is not s1 and s2.plan_id != s1.plan_id
    s3 = compile_staged(attn_plan(), CAT, SYS, cache=cache,
                        patterns=DEFAULT_PATTERNS[:1])
    assert s3 is not s1 and s3.plan_id != s1.plan_id
    assert cache.stats()["hits"] == 0 and cache.stats()["misses"] == 3


def test_plan_cache_persists_and_warm_starts(tmp_path):
    """Disk persistence keyed by plan_id (ROADMAP open item): a restarted
    process warm-starts from the persisted directory and its first compile
    of the same workload is a pure cache hit."""
    d = str(tmp_path / "plans")
    cache = PlanCache()
    s1 = compile_staged(attn_plan(), CAT, SYS, cache=cache)
    s2 = compile_staged(attn_plan(seq=64), CAT, SYS, cache=cache)
    assert save_plan_cache(cache, d) == 2
    assert save_plan_cache(cache, d) == 0      # idempotent: ids on disk

    warm = load_plan_cache(d)                  # "restarted process"
    assert len(warm) == 2
    assert warm.stats()["hits"] == 0 and warm.stats()["misses"] == 0
    s1b = compile_staged(attn_plan(), CAT, SYS, cache=warm)
    assert warm.stats()["hits"] == 1 and s1b.plan_id == s1.plan_id
    assert s1b.options == s1.options
    assert [r.name for r in s1b.trace] == [r.name for r in s1.trace]
    # the warm-started plan executes identically to the original
    rng = np.random.RandomState(0)
    params = {"attn": {
        "wq": jnp.asarray(rng.randn(32, 32), jnp.float32),
        "wk": jnp.asarray(rng.randn(32, 16), jnp.float32),
        "wv": jnp.asarray(rng.randn(32, 16), jnp.float32),
        "wo": jnp.asarray(rng.randn(32, 32), jnp.float32),
    }}
    x = jnp.asarray(rng.randn(2, 32, 32), jnp.float32)
    from repro.core.executor import PlannedFunction
    a = PlannedFunction.from_staged(s1, SYS)(params, {"h": x})
    b = PlannedFunction.from_staged(s1b, SYS)(params, {"h": x})
    assert np.array_equal(np.asarray(a), np.asarray(b))
    # a corrupt file is skipped, not fatal
    (tmp_path / "plans" / (s2.plan_id + ".staged.pkl")).write_bytes(b"junk")
    assert len(load_plan_cache(d)) == 1
    # a missing directory is an empty warm start
    assert len(load_plan_cache(str(tmp_path / "nope"))) == 0


def test_cost_model_fit_invalidates_cached_plans():
    """CostModel.fit changes the weight fingerprint, which is part of
    staged_plan_id — so calibration invalidates cached plans (ROADMAP
    plumbing, previously untested)."""
    from repro.core.cost_model import CostModel, FEATURE_NAMES
    p = attn_plan()
    cm = CostModel()
    assert cm.fingerprint() == "analytic"
    id_analytic = staged_plan_id(p, CAT, SYS, PlanOptions(), cost_model=cm)
    assert id_analytic == staged_plan_id(p, CAT, SYS, PlanOptions(),
                                         cost_model=None)

    feats = {k: 1.0 for k in FEATURE_NAMES}
    cm.fit([("sdpa_xla", feats, 1e-3), ("sdpa_xla", feats, 2e-3)])
    fp1 = cm.fingerprint()
    assert fp1 != "analytic"
    id_fit = staged_plan_id(p, CAT, SYS, PlanOptions(), cost_model=cm)
    assert id_fit != id_analytic

    # the cache sees calibration as a different planning problem
    cache = PlanCache()
    compile_staged(p, CAT, SYS, cache=cache, cost_model=None)
    compile_staged(p, CAT, SYS, cache=cache, cost_model=cm)
    assert cache.stats() == {**cache.stats(), "hits": 0, "misses": 2}
    # refit with different measurements -> different fingerprint again
    cm2 = CostModel()
    cm2.fit([("sdpa_xla", feats, 5e-3)])
    assert cm2.fingerprint() != fp1
    assert staged_plan_id(p, CAT, SYS, PlanOptions(), cost_model=cm2) not in \
        (id_analytic, id_fit)
    # identical fits agree (content hash, not identity)
    cm3 = CostModel()
    cm3.fit([("sdpa_xla", feats, 5e-3)])
    assert cm3.fingerprint() == cm2.fingerprint()


def test_engine_availability_surfaces_in_explain():
    """Engine.is_available is reported per engine in the EXPLAIN trace
    (ROADMAP open item): a hardware-gated engine shows up/DOWN."""
    from repro.core.engines import get_engine
    staged = PlanPipeline().run(attn_plan(window=8), CAT, SYS,
                                options=PlanOptions(
                                    engines=("xla", "pallas")))
    gen = next(r for r in staged.trace if r.name == "generate_candidates")
    assert gen.info["engine_availability"] == {"xla": True, "pallas": True}
    assert "xla[up]" in staged.explain() and "pallas[up]" in staged.explain()

    pallas = get_engine("pallas")
    old = pallas.is_available
    pallas.is_available = lambda: False
    try:
        staged2 = PlanPipeline().run(attn_plan(window=8), CAT, SYS,
                                     options=PlanOptions(
                                         engines=("xla", "pallas")))
        assert staged2.trace[1].info["engine_availability"]["pallas"] is False
        assert "pallas[DOWN]" in staged2.explain()
    finally:
        pallas.is_available = old


def test_calibration_aware_eviction_prefers_stale_entries():
    """Eviction order (ROADMAP open item): entries planned under a
    superseded cost-model fit and untouched since the fit changed are
    evicted first; entries re-proven live by a lookup under the current
    fit stay protected (two callers sharing a cache must not thrash each
    other); with no stale entries eviction is plain LRU."""
    cache = PlanCache(maxsize=3)
    cache.insert("A", "staged-a", fingerprint="fit-old")
    cache.insert("B", "staged-b", fingerprint="fit-old")
    cache.insert("C", "staged-c", fingerprint="fit-new")  # refit: epoch bump
    assert cache.current_fingerprint == "fit-new"
    cache.lookup("A")            # A touched after the refit -> proven live
    cache.insert("D", "staged-d", fingerprint="fit-new")
    assert "B" not in cache      # stale victim: old fit, untouched since
    assert "A" in cache
    assert cache.stale_evictions == 1
    cache.insert("E", "staged-e", fingerprint="fit-new")
    assert "A" in cache          # live-under-new-fit entries never stale...
    assert "C" not in cache      # ...so plain LRU evicts C
    assert cache.stale_evictions == 1 and cache.evictions == 2
    assert set(cache._entries) == {"A", "D", "E"}
    # the uncalibrated fallback never displaces a fitted fingerprint, so
    # interleaved no-cost-model compiles cannot mark fitted entries stale
    cache.note_fingerprint("analytic")
    assert cache.current_fingerprint == "fit-new"


def test_persisted_entries_keep_fit_fingerprints(tmp_path):
    """Warm-started entries stay classified for stale-first eviction: the
    fingerprint rides along on disk (without claiming currency on load)."""
    d = str(tmp_path / "plans")
    cache = PlanCache()
    cache.insert("A", "staged-a", fingerprint="fit-1")
    cache.insert("B", "staged-b")               # no fingerprint recorded
    assert save_plan_cache(cache, d) == 2
    warm = load_plan_cache(d)
    assert warm._fps.get("A") == "fit-1" and "B" not in warm._fps
    assert warm.current_fingerprint is None     # loading != calibrating


def test_compile_refit_marks_cached_entries_stale():
    """compile_staged threads the fit fingerprint into the cache: after a
    refit, the next overflow evicts the pre-refit entry first."""
    from repro.core.cost_model import CostModel, FEATURE_NAMES
    cache = PlanCache(maxsize=2)
    compile_staged(attn_plan(seq=16), CAT, SYS, cache=cache)      # analytic
    compile_staged(attn_plan(seq=32), CAT, SYS, cache=cache)      # analytic
    stale_id = next(iter(cache._entries))
    cm = CostModel().fit([("sdpa_xla", {k: 1.0 for k in FEATURE_NAMES},
                           1e-3)])
    compile_staged(attn_plan(seq=64), CAT, SYS, cache=cache, cost_model=cm)
    assert cache.stale_evictions == 1
    assert stale_id not in cache


def test_parallel_candidate_generation_identical_plans():
    """Scan-group-parallel generation (ROADMAP open item): plan_threads
    changes planning wall time only — the chosen plan, the choices, and the
    plan_id are identical to the serial path (and plan_threads is not part
    of the cache key)."""
    from repro.core.ir import standard_catalog

    def two_scan_plan():
        p = Plan("ms")
        t = TensorT((2, 8, 32), "float32", ("batch", "seq", "embed"))
        p.add_input("h", t)
        bodies = []
        for i, n_layers in enumerate((2, 3)):   # different trip counts: no
            b = Plan(f"body{i}")                # scan fusion, two groups
            b.add_input("x", t)
            a = b.add("attention", ["x"],
                      {"heads": 4, "kv_heads": 2, "head_dim": 8, "embed": 32,
                       "window": 4, "pp": ("attn",)})
            m = b.add("mlp", [a], {"ffn": 64, "embed": 32, "pp": ("mlp",)})
            b.set_outputs(m)
            bodies.append((n_layers, b))
        prev = "h"
        for i, (n_layers, b) in enumerate(bodies):
            prev = p.add("scan_layers", [prev],
                         {"n_layers": n_layers, "pp": (f"blk{i}",)}, b)
        p.set_outputs(prev)
        return p

    def concrete_shape(pp):
        out = []
        for n in pp.topo():
            out.append((n.id, n.impl, n.inputs))
            if n.subplan is not None:
                out.extend(concrete_shape(n.subplan))
        return out

    serial = compile_staged(two_scan_plan(), CAT, SYS, cache=False,
                            options=PlanOptions(engines=("xla", "pallas")))
    threaded = compile_staged(
        two_scan_plan(), CAT, SYS, cache=False,
        options=PlanOptions(engines=("xla", "pallas"), plan_threads=4))
    assert threaded.plan_id == serial.plan_id
    assert concrete_shape(threaded.concrete) == concrete_shape(serial.concrete)
    assert [(r["pattern"], r["chosen"]) for r in threaded.report] == \
        [(r["pattern"], r["chosen"]) for r in serial.report]


def test_lru_eviction_and_clear():
    cache = PlanCache(maxsize=2)
    for seq in (16, 32, 64):
        compile_staged(attn_plan(seq=seq), CAT, SYS, cache=cache)
    assert len(cache) == 2 and cache.evictions == 1
    # seq=16 was evicted -> recompiling it misses
    compile_staged(attn_plan(seq=16), CAT, SYS, cache=cache)
    assert cache.stats()["misses"] == 4
    cache.clear()
    assert len(cache) == 0 and cache.stats()["hits"] == 0


# --------------------------------------------------------------------------
# pass manager
# --------------------------------------------------------------------------

def test_pipeline_runs_all_passes_with_timing_and_deltas():
    staged = PlanPipeline().run(attn_plan(), CAT, SYS,
                                options=PlanOptions())
    names = [r.name for r in staged.trace]
    assert names == list(PlanPipeline.DEFAULT_PASSES)
    assert all(r.wall_ms >= 0 for r in staged.trace)
    assert all(r.nodes_before > 0 and r.nodes_after > 0
               for r in staged.trace)
    report = staged.explain()
    for name in names:
        assert name in report
    assert staged.plan_id == staged_plan_id(attn_plan(), CAT, SYS,
                                            PlanOptions())


def test_pipeline_rejects_unknown_pass_and_incomplete_pipelines():
    with pytest.raises(ValidationError):
        PlanPipeline(passes=("rewrite", "nope"))
    with pytest.raises(ValidationError):
        PlanPipeline(passes=("rewrite",)).run(attn_plan(), CAT, SYS)


def test_passes_are_individually_registered():
    for name in PlanPipeline.DEFAULT_PASSES:
        assert name in PASS_REGISTRY


# --------------------------------------------------------------------------
# engine registry
# --------------------------------------------------------------------------

def test_engine_registry_resolution():
    assert resolve_engines(None) == ("xla",)
    assert resolve_engines(None, allow_pallas=True) == ("xla", "pallas")
    assert resolve_engines("xla") == ("xla",)
    assert resolve_engines(("xla", "pallas")) == ("xla", "pallas")
    with pytest.raises(ValidationError):
        resolve_engines(("cuda",))
    assert set(engine_names()) >= {"xla", "pallas"}


def test_engines_own_their_impl_tables():
    assert "rmsnorm_xla" in get_engine("xla")
    assert "attn_flash_pallas" in get_engine("pallas")
    assert "attn_flash_pallas" not in get_engine("xla")
    assert dispatch("rmsnorm_xla", "xla") is not None
    assert dispatch("attn_flash_pallas") is not None
    assert dispatch("no_such_impl") is None


def test_engine_selection_gates_candidates():
    xla_only = generate_candidates(rewrite(attn_plan(window=0), CAT),
                                   engines=("xla",))
    assert not xla_only.pm           # single candidate -> direct substitution
    both = generate_candidates(rewrite(attn_plan(window=8), CAT),
                               engines=("xla", "pallas"))
    (vid, cands), = both.pm.items()
    assert {c.requires_backend for c in cands} == {"xla", "pallas"}


def test_legacy_allow_pallas_still_maps_through():
    fwd = plan_and_compile(attn_plan(), CAT, SYS, allow_pallas=True,
                           cache=False)
    # the boolean must resolve to both engines in the staged options, and
    # the cost model must have scored the pallas flash candidate
    assert fwd.staged.options.engines == ("xla", "pallas")
    assert fwd.report
    assert any("attn_flash" in r["costs"] for r in fwd.report)


# --------------------------------------------------------------------------
# sharded stores in plan identity
# --------------------------------------------------------------------------

def _tri_store_plan(shards):
    from repro.stores import ColumnStore
    table = ColumnStore({"k": np.arange(64, dtype=np.int32),
                         "v": np.ones(64, np.float32)})
    if shards > 1:
        table = table.with_shards(shards)
    with Analysis(f"pid_s{shards}", CAT) as a:
        t = a.op("rel_scan", a.bind("tweets", table))
        f = a.op("rel_filter", t, col="v", cmp="ge", value=0.5)
        g = a.op("rel_group_agg", f, key="k", num_groups=64,
                 aggs=(("n", "count", None),))
        a.store(a.op("col_tensor", g, col="n", dim="nodes"))
    return a


def test_sharding_round_trips_through_plan_id():
    """Input partitioning and mesh shape are both part of plan identity.
    Round trip: rebuilding the same program reproduces the id exactly, so
    the only misses below come from the sharding declarations themselves."""
    assert _tri_store_plan(1).plan_id(SYS) == _tri_store_plan(1).plan_id(SYS)
    assert _tri_store_plan(8).plan_id(SYS) == _tri_store_plan(8).plan_id(SYS)
    # per-input partitioning ("row" on the bound table type) changes the id
    assert _tri_store_plan(1).plan_id(SYS) != _tri_store_plan(8).plan_id(SYS)
    # mesh shape changes the id through the syscat fingerprint
    sys8 = SystemCatalog(mesh_shape=(8, 1))
    assert _tri_store_plan(8).plan_id(SYS) != _tri_store_plan(8).plan_id(sys8)


def test_sharded_stores_miss_unsharded_cache_entry():
    """A plan compiled for 1 device must not be served to the 8-way sharded
    program (and vice versa): the staged cache sees four distinct keys for
    {unsharded, sharded} x {(1,1) mesh, (8,1) mesh}."""
    from repro.stores import store_engines
    cache = PlanCache()
    opts = PlanOptions(engines=resolve_engines(store_engines()))
    sys8 = SystemCatalog(mesh_shape=(8, 1))
    keys = {staged_plan_id(a.plan, CAT, sc, opts)
            for a in (_tri_store_plan(1), _tri_store_plan(8))
            for sc in (SYS, sys8)}
    assert len(keys) == 4
    s1 = compile_staged(_tri_store_plan(1).plan, CAT, SYS, cache=cache,
                        options=opts)
    s8 = compile_staged(_tri_store_plan(8).plan, CAT, sys8, cache=cache,
                        options=opts)
    assert s8 is not s1 and s8.plan_id != s1.plan_id
    assert cache.stats()["misses"] == 2 and cache.stats()["hits"] == 0
    # and the sharded compile is a hit only for its exact key
    s8b = compile_staged(_tri_store_plan(8).plan, CAT, sys8, cache=cache,
                        options=opts)
    assert s8b is s8 and cache.stats()["hits"] == 1
