"""Bounded relations: the BoundedRel runtime representation, non-unique
hash joins, compaction placement, incremental appends + plan-cache
invalidation, selectivity feedback, and the first-iteration PageRank
pushdown."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.adil import Analysis
from repro.core.feedback import SelectivityFeedback, filter_site
from repro.core.ir import (SystemCatalog, TableT, TensorT, ValidationError,
                           standard_catalog)
from repro.core.plan_cache import PlanCache
from repro.core.rewrite import (DEFAULT_PIPELINE, UNCOMPACTED_PIPELINE,
                                UNPUSHED_PIPELINE)
from repro.stores import (BoundedRel, ColumnStore, GraphStore, TextStore,
                          as_bounded, compact_rel, store_engines)
from repro.stores import ref as R
from repro.stores.column_store import hash_join_nonunique
from repro.stores.graph_store import pagerank
from repro.stores.masked_kernels import (compact_prefix_pallas,
                                         join_probe_pallas)
from repro.stores.runtime import _step_compact, _step_compact_pallas

CAT = standard_catalog()
SYS = SystemCatalog()
NOFUSE_PIPELINE = tuple(p for p in DEFAULT_PIPELINE if p != "fuse_store_ops")


def _has_compact(fn) -> bool:
    """Whether the planned function compacts anywhere — as a standalone
    physical node or as a step inside a fused rel chain."""
    for n in fn.concrete.topo():
        if "compact" in n.impl:
            return True
        for op, *_ in n.attrs.get("chain", ()):
            if op == "compact":
                return True
    return False


# --------------------------------------------------------------------------
# the BoundedRel representation
# --------------------------------------------------------------------------

def test_payload_is_bounded_rel_with_count():
    cs = ColumnStore({"id": np.arange(5, dtype=np.int32),
                      "v": np.ones(5, np.float32)}, capacity=8)
    rel = cs.payload()
    assert isinstance(rel, BoundedRel)
    assert rel.capacity == 8 and int(rel.count) == 5
    assert not bool(rel.overflow)
    # dict-like compat: columns + "_mask" view over validity
    assert set(rel) == {"id", "v", "_mask"}
    np.testing.assert_array_equal(np.asarray(rel["_mask"]),
                                  np.arange(8) < 5)
    # capacity headroom surfaces as the type's expected count
    assert cs.type == TableT((("id", "int32"), ("v", "float32")), 8, 5)
    with pytest.raises(ValidationError):
        ColumnStore({"x": np.arange(4)}, capacity=2)   # capacity < rows


def test_bounded_rel_is_a_pytree():
    rel = ColumnStore({"a": np.arange(6, dtype=np.int32)}).payload()
    doubled = jax.jit(lambda r: jax.tree.map(lambda x: x * 2, r))(rel)
    assert isinstance(doubled, BoundedRel)
    np.testing.assert_array_equal(np.asarray(doubled.cols["a"]),
                                  np.arange(6) * 2)


def test_narrowed_recomputes_count():
    rel = ColumnStore({"a": np.arange(10, dtype=np.int32)}).payload()
    narrowed = rel.narrowed(rel.cols["a"] < 3)
    assert int(narrowed.count) == 3 and narrowed.capacity == 10


# --------------------------------------------------------------------------
# non-unique hash join (capacity-bounded, overflow-flagged)
# --------------------------------------------------------------------------

def test_hash_join_nonunique_matches_reference(rng):
    for trial in range(5):
        nl, nr = rng.randint(1, 60), rng.randint(1, 40)
        lk = rng.randint(-5, 10, nl)
        lm = rng.rand(nl) > 0.3
        rk = rng.randint(-5, 10, nr)
        rm = rng.rand(nr) > 0.2
        for cap in (4, 37, 500):
            gl, gr_, gv, gc, go = [np.asarray(x) for x in hash_join_nonunique(
                jnp.asarray(lk), jnp.asarray(lm), jnp.asarray(rk),
                jnp.asarray(rm), cap)]
            wl, wr, wv, wc, wo = R.bounded_join_ref(lk, lm, rk, rm, cap)
            np.testing.assert_array_equal(gv, wv)
            np.testing.assert_array_equal(gl[wv], wl[wv])
            np.testing.assert_array_equal(gr_[wv], wr[wv])
            assert int(gc) == wc and bool(go) == wo


def test_hash_join_nonunique_empty_sides():
    z = hash_join_nonunique(jnp.asarray([1, 2]), jnp.asarray([True, True]),
                            jnp.zeros((0,), jnp.int32),
                            jnp.zeros((0,), jnp.bool_), 4)
    assert int(z[3]) == 0 and not bool(z[4])
    assert not bool(np.asarray(z[2]).any())


def test_bounded_join_through_planner_matches_numpy(rng):
    nodes, rows = 16, 120
    dims = ColumnStore({"tag": np.arange(nodes, dtype=np.int32),
                        "w": rng.rand(nodes).astype(np.float32)})
    facts = ColumnStore({"tag": rng.randint(0, nodes, rows).astype(np.int32),
                         "v": rng.rand(rows).astype(np.float32)})
    with Analysis("bj", CAT) as a:
        dm = a.bind("dims", dims)
        fc = a.bind("facts", facts)
        # dims probe x facts build: non-unique build keys, one output row
        # per (dim, matching fact) pair
        bj = a.op("bounded_join", dm, fc, left_on="tag", right_on="tag",
                  capacity=rows)
        agg = a.op("rel_group_agg", bj, key="tag", num_groups=nodes,
                   aggs=(("s", "sum", "v"),))
        a.store(a.op("col_tensor", agg, col="s", dim="nodes"))
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    out = np.asarray(fn({}, {"dims": dims.payload(),
                             "facts": facts.payload()}))
    want = np.zeros(nodes, np.float32)
    for t, v in zip(facts.column("tag"), facts.column("v")):
        want[t] += v
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)


def test_bounded_join_overflow_flag_surfaces(rng):
    nodes, rows = 8, 64
    dims = ColumnStore({"tag": np.arange(nodes, dtype=np.int32)})
    facts = ColumnStore({"tag": rng.randint(0, nodes, rows).astype(np.int32),
                         "v": rng.rand(rows).astype(np.float32)})
    with Analysis("ovf", CAT) as a:
        dm = a.bind("dims", dims)
        fc = a.bind("facts", facts)
        bj = a.op("bounded_join", dm, fc, left_on="tag", right_on="tag",
                  capacity=8)        # 64 matches cannot fit
        a.store(bj)
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    out = fn({}, {"dims": dims.payload(), "facts": facts.payload()})
    assert isinstance(out, BoundedRel)
    assert bool(out.overflow) and int(out.count) == 8
    with pytest.raises(ValidationError):       # capacity must be >= 1
        with Analysis("bad", CAT) as b:
            dm = b.bind("dims", dims)
            fc = b.bind("facts", facts)
            b.store(b.op("bounded_join", dm, fc, left_on="tag",
                         right_on="tag", capacity=0))


# --------------------------------------------------------------------------
# compaction: kernels + planner placement + bitwise identity
# --------------------------------------------------------------------------

def test_compact_rel_matches_reference(rng):
    cs = ColumnStore({"a": np.arange(50, dtype=np.int32),
                      "b": rng.randn(50).astype(np.float32)})
    rel = cs.payload().narrowed(jnp.asarray(np.arange(50) % 7 == 0))
    for cap in (4, 16, 50):
        got = compact_rel(rel, cap)
        cols, valid, count, ovf = R.compact_ref(
            {k: np.asarray(rel.cols[k]) for k in rel.cols},
            np.asarray(rel.valid), cap)
        np.testing.assert_array_equal(np.asarray(got.valid), valid)
        assert int(got.count) == count and bool(got.overflow) == ovf
        for k in cols:
            np.testing.assert_array_equal(np.asarray(got.cols[k])[valid],
                                          cols[k][valid])


def test_compact_pallas_matches_gather(rng):
    cs = ColumnStore({"a": rng.randint(0, 1000, 90).astype(np.int32),
                      "b": rng.randn(90).astype(np.float32)})
    rel = cs.payload().narrowed(jnp.asarray(rng.rand(90) > 0.7))
    for cap in (8, 40):
        xla = _step_compact(rel, {"capacity": cap})
        pls = _step_compact_pallas(rel, {"capacity": cap}, interpret=True)
        assert int(xla.count) == int(pls.count)
        v = np.asarray(xla.valid)
        np.testing.assert_array_equal(v, np.asarray(pls.valid))
        for k in ("a", "b"):
            np.testing.assert_array_equal(np.asarray(xla.cols[k])[v],
                                          np.asarray(pls.cols[k])[v])


def _selective_analysis(table, graph, corpus, *, selectivity, k=16):
    rows, nodes = table.rows, graph.n_nodes
    cut = int(rows * (1 - selectivity))
    with Analysis("sel", CAT) as a:
        tw = a.bind("tweets", table)
        gr = a.bind("g", graph)
        cx = a.bind("cx", corpus)
        q = a.input("q", TensorT((corpus.vocab,), "float32", ("vocab",)))
        t = a.op("rel_scan", tw)
        recent = a.op("rel_filter", t, col="ts", cmp="ge", value=cut,
                      selectivity=selectivity)
        m = a.op("sel_mask", recent, col="doc", size=corpus.n_docs)
        sc = a.op("text_scores", cx, q)
        hits = a.op("masked_topk", sc, m, k=k)
        j = a.op("rel_join", recent, hits, left_on="doc", right_on="doc")
        trel = a.op("rel_group_agg", j, key="hashtag", num_groups=nodes,
                    aggs=(("textrel", "sum", "score"),))
        seeds = a.op("rel_group_agg", recent, key="hashtag",
                     num_groups=nodes, aggs=(("seed", "count", None),))
        sv = a.op("col_tensor", seeds, col="seed", dim="nodes")
        pr = a.op("graph_pagerank", gr, sv, iters=3)
        tv = a.op("col_tensor", trel, col="textrel", dim="nodes")
        a.store(a.op("residual_add", pr, tv))
    return a


def _stores(rng, rows=400, nodes=64, vocab=32):
    table = ColumnStore({
        "hashtag": rng.randint(0, nodes, rows).astype(np.int32),
        "doc": np.arange(rows, dtype=np.int32),
        "ts": np.arange(rows, dtype=np.int32),
        "engagement": (rng.rand(rows) * 50).astype(np.float32),
    })
    e = rng.randint(0, nodes, (2, 300))
    graph = GraphStore.from_edges(e[0], e[1], nodes, symmetric=True)
    corpus = TextStore.from_docs(
        [rng.randint(0, vocab, rng.randint(2, 8)) for _ in range(rows)],
        vocab)
    return table, graph, corpus


def _inputs(table, graph, corpus, terms=(1, 2, 3)):
    return {"tweets": table.payload(), "g": graph.payload(),
            "cx": corpus.payload(),
            "q": jnp.asarray(corpus.query_vector(terms))}


def test_choose_compaction_inserts_and_stays_bitwise(rng):
    table, graph, corpus = _stores(rng)
    a = _selective_analysis(table, graph, corpus, selectivity=0.05)
    compacted = a.compile(SYS, engines=store_engines(), cache=False)
    masked = a.compile(SYS, engines=store_engines(), cache=False,
                       rewrite_pipeline=UNCOMPACTED_PIPELINE)
    assert _has_compact(compacted)
    assert not _has_compact(masked)
    ins = _inputs(table, graph, corpus)
    out_c = np.asarray(jax.jit(lambda i: compacted({}, i))(ins))
    out_m = np.asarray(jax.jit(lambda i: masked({}, i))(ins))
    np.testing.assert_array_equal(out_c, out_m)
    # EXPLAIN surfaces the cardinality reasoning
    text = compacted.explain()
    assert "count~" in text and "capacity=" in text


def test_compaction_skips_capacity_sensitive_consumers(rng):
    """A join whose output feeds a capacity-long tensor (col_tensor) must
    not have its probe side compacted: the output tensor's shape would
    change.  The planner detects the transitive capacity-sensitivity and
    leaves the plan alone."""
    table, graph, corpus = _stores(rng)
    with Analysis("shape", CAT) as a:
        tw = a.bind("tweets", table)
        cx = a.bind("cx", corpus)
        q = a.input("q", TensorT((corpus.vocab,), "float32", ("vocab",)))
        t = a.op("rel_scan", tw)
        recent = a.op("rel_filter", t, col="ts", cmp="ge",
                      value=int(table.rows * 0.95), selectivity=0.05)
        hits = a.op("text_topk", cx, q, k=8)
        j = a.op("rel_join", recent, hits, left_on="doc", right_on="doc")
        # capacity-long tensor out of the join: compaction would change
        # this output's shape from (rows,) to (capacity,)
        a.store(a.op("col_tensor", j, col="score"))
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    assert not _has_compact(fn)
    uncompacted = a.compile(SYS, engines=store_engines(), cache=False,
                            rewrite_pipeline=UNCOMPACTED_PIPELINE)
    ins = {"tweets": table.payload(), "cx": corpus.payload(),
           "q": jnp.asarray(corpus.query_vector([1, 2]))}
    np.testing.assert_array_equal(np.asarray(fn({}, ins)),
                                  np.asarray(uncompacted({}, ins)))


def test_observed_overflow_backs_compaction_off(rng):
    """A compaction bound sized from a wildly wrong hint drops rows at run
    time; observing the run flags the site and re-planning stops
    compacting it (and the corrected selectivity estimate agrees)."""
    table, graph, corpus = _stores(rng)
    rows = table.rows

    def build():
        with Analysis("ovf", CAT) as a:
            tw = a.bind("tweets", table)
            t = a.op("rel_scan", tw)
            # actual selectivity 50%, hinted 1% -> capacity far too small
            f = a.op("rel_filter", t, col="ts", cmp="ge",
                     value=int(rows * 0.5), selectivity=0.01)
            seeds = a.op("rel_group_agg", f, key="hashtag",
                         num_groups=graph.n_nodes,
                         aggs=(("seed", "count", None),))
            a.store(a.op("col_tensor", seeds, col="seed", dim="nodes"))
        return a

    ins = {"tweets": table.payload()}
    fb = SelectivityFeedback()
    cache = PlanCache()
    fn1 = build().compile(SYS, engines=store_engines(), cache=cache,
                          feedback=fb)
    assert _has_compact(fn1)
    fn1.observe({}, ins, feedback=fb)
    site = filter_site({"col": "ts", "cmp": "ge", "value": int(rows * 0.5)},
                       table.type.col_names(), table.rows)
    assert fb.is_overflowed(site)
    fn2 = build().compile(SYS, engines=store_engines(), cache=cache,
                          feedback=fb)
    assert fn2.plan_id != fn1.plan_id
    assert not _has_compact(fn2)
    # the un-compacted re-plan is correct (the overflowed one was lossy)
    want = np.zeros(graph.n_nodes, np.float32)
    sel_rows = table.column("ts") >= int(rows * 0.5)
    for h in table.column("hashtag")[sel_rows]:
        want[h] += 1.0
    np.testing.assert_allclose(np.asarray(fn2({}, ins)), want)


def test_compile_refreshes_bound_store_types(rng):
    """Re-compiling the *same* Analysis object after an append must plan
    against the store's current statistics, not the bind-time snapshot."""
    st = ColumnStore({"x": np.arange(60, dtype=np.int32)}, capacity=128)
    with Analysis("stale", CAT) as a:
        tw = a.bind("t", st)
        a.store(a.op("rel_scan", tw))
    fn1 = a.compile(SYS, engines=store_engines(), cache=False)
    st.append({"x": np.arange(20, dtype=np.int32)})
    fn2 = a.compile(SYS, engines=store_engines(), cache=False)
    assert a.plan.inputs["t"].expected_count == 80
    out = fn2({}, {"t": st.payload()})
    assert int(out.count) == 80
    assert fn2.plan_id != fn1.plan_id


def test_choose_compaction_requires_confidence(rng):
    """A bare-heuristic filter (no hint, no observation) must not be
    compacted: an underestimated capacity would silently drop rows."""
    table, graph, corpus = _stores(rng)
    rows = table.rows
    with Analysis("noconf", CAT) as a:
        tw = a.bind("tweets", table)
        t = a.op("rel_scan", tw)
        f = a.op("rel_filter", t, col="ts", cmp="eq", value=3)  # no hint
        seeds = a.op("rel_group_agg", f, key="hashtag",
                     num_groups=graph.n_nodes,
                     aggs=(("seed", "count", None),))
        a.store(a.op("col_tensor", seeds, col="seed", dim="nodes"))
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    assert not _has_compact(fn)


def test_compaction_edge_selectivities_bitwise(rng):
    table, graph, corpus = _stores(rng, rows=80, nodes=12, vocab=16)
    ins = _inputs(table, graph, corpus)
    for sel in (0.0, 0.05, 0.125):
        a = _selective_analysis(table, graph, corpus, selectivity=sel)
        compacted = a.compile(SYS, engines=store_engines(), cache=False)
        unpushed = a.compile(SYS, engines=store_engines(), cache=False,
                             rewrite_pipeline=UNPUSHED_PIPELINE)
        np.testing.assert_array_equal(np.asarray(compacted({}, ins)),
                                      np.asarray(unpushed({}, ins)))


# --------------------------------------------------------------------------
# incremental appends: version bumps provably invalidate cached plans
# --------------------------------------------------------------------------

def test_column_store_append_within_capacity():
    st = ColumnStore({"x": np.arange(60, dtype=np.int32)}, capacity=128)
    st.append({"x": np.arange(20, dtype=np.int32)})
    assert st.rows == 80 and st.capacity == 128 and st.version == 1
    rel = st.payload()
    assert rel.capacity == 128 and int(rel.count) == 80
    st.append({"x": np.arange(100, dtype=np.int32)})   # beyond capacity
    assert st.rows == 180 and st.capacity == 180 and st.version == 2
    with pytest.raises(ValidationError):               # schema mismatch
        st.append({"y": np.arange(3)})


def test_append_bumps_version_and_invalidates_cache(rng):
    cache = PlanCache()
    st = ColumnStore({"x": rng.randint(0, 4, 60).astype(np.int32)},
                     capacity=128)

    def build():
        with Analysis("inc", CAT) as a:
            tw = a.bind("t", st)
            f = a.op("rel_filter", a.op("rel_scan", tw), col="x", cmp="ge",
                     value=1)
            a.store(a.op("rel_group_agg", f, key="x", num_groups=4,
                         aggs=(("n", "count", None),)))
        return a

    fn1 = build().compile(SYS, engines=store_engines(), cache=cache)
    fn1b = build().compile(SYS, engines=store_engines(), cache=cache)
    assert fn1b.plan_id == fn1.plan_id and cache.hits == 1
    st.append({"x": rng.randint(0, 4, 30).astype(np.int32)})
    assert st.version == 1
    fn2 = build().compile(SYS, engines=store_engines(), cache=cache)
    assert fn2.plan_id != fn1.plan_id          # provably not the stale plan
    assert cache.hits == 1                     # the re-plan was a miss
    # and the recompiled plan sees the appended rows
    out = fn2({}, {"t": st.payload()})
    assert float(np.asarray(out["n"]).sum()) == float(
        (st.column("x") >= 1).sum())


def test_store_versions_alone_change_plan_id(rng):
    """The version vector is identity material in its own right — two
    compiles of the *same* plan under different store versions never share
    a cache entry."""
    st = ColumnStore({"x": np.arange(8, dtype=np.int32)})
    with Analysis("v", CAT) as a:
        tw = a.bind("t", st)
        a.store(a.op("rel_scan", tw))
    fn0 = a.compile(SYS, engines=store_engines(), cache=False,
                    store_versions=(("t", 0),))
    fn1 = a.compile(SYS, engines=store_engines(), cache=False,
                    store_versions=(("t", 1),))
    assert fn0.plan_id != fn1.plan_id


def test_text_store_append_reindexes(rng):
    vocab = 16
    docs1 = [rng.randint(0, vocab, rng.randint(2, 6)) for _ in range(10)]
    docs2 = [rng.randint(0, vocab, rng.randint(2, 6)) for _ in range(7)]
    inc = TextStore.from_docs(docs1, vocab)
    inc.append(docs2)
    full = TextStore.from_docs(docs1 + docs2, vocab)
    assert inc.version == 1 and inc.n_docs == full.n_docs
    np.testing.assert_array_equal(inc.doc_ids, full.doc_ids)
    np.testing.assert_array_equal(inc.term_ids, full.term_ids)
    np.testing.assert_array_equal(inc.tf, full.tf)
    np.testing.assert_array_equal(inc.doc_len, full.doc_len)
    np.testing.assert_allclose(inc.idf, full.idf, rtol=1e-6)


# --------------------------------------------------------------------------
# selectivity feedback: a mis-hinted filter self-corrects after observation
# --------------------------------------------------------------------------

def test_selectivity_feedback_self_corrects(rng):
    table, graph, corpus = _stores(rng)
    rows = table.rows

    def build():
        # actual selectivity ~5%, mis-hinted as 90%
        with Analysis("fb", CAT) as a:
            tw = a.bind("tweets", table)
            cx = a.bind("cx", corpus)
            q = a.input("q", TensorT((corpus.vocab,), "float32", ("vocab",)))
            t = a.op("rel_scan", tw)
            recent = a.op("rel_filter", t, col="ts", cmp="ge",
                          value=int(rows * 0.95), selectivity=0.9)
            m = a.op("sel_mask", recent, col="doc", size=corpus.n_docs)
            sc = a.op("text_scores", cx, q)
            hits = a.op("masked_topk", sc, m, k=16)
            j = a.op("rel_join", recent, hits, left_on="doc",
                     right_on="doc")
            trel = a.op("rel_group_agg", j, key="hashtag",
                        num_groups=graph.n_nodes,
                        aggs=(("textrel", "sum", "score"),))
            a.store(a.op("col_tensor", trel, col="textrel", dim="nodes"))
        return a

    ins = {"tweets": table.payload(), "cx": corpus.payload(),
           "q": jnp.asarray(corpus.query_vector([1, 2, 3]))}
    fb = SelectivityFeedback()
    cache = PlanCache()
    fn1 = build().compile(SYS, engines=store_engines(), cache=cache,
                          feedback=fb)
    impls1 = {n.impl for n in fn1.concrete.topo()}
    # mis-hint (0.9) keeps the dense text plan
    assert "text_topk_inv" in impls1
    assert "text_topk_skip_inv" not in impls1
    out1 = fn1.observe({}, ins, feedback=fb)
    assert len(fb) >= 1
    site = filter_site({"col": "ts", "cmp": "ge",
                        "value": int(rows * 0.95)},
                       table.type.col_names(), table.rows)
    assert fb.lookup(site) == pytest.approx(0.05, abs=0.01)
    fn2 = build().compile(SYS, engines=store_engines(), cache=cache,
                          feedback=fb)
    # new observations are a provable cache miss, and the corrected
    # estimate now clears the skip-candidate gate
    assert fn2.plan_id != fn1.plan_id
    impls2 = {n.impl for n in fn2.concrete.topo()}
    assert "text_topk_skip_inv" in impls2
    np.testing.assert_array_equal(np.asarray(out1),
                                  np.asarray(fn2({}, ins)))


def test_feedback_records_marginal_selectivity(rng):
    """Chained filters: each site must record its *own* survivor fraction
    (what estimate_selectivity multiplies along the lineage), not the
    cumulative count/capacity — a cumulative record would double-discount
    upstream narrowing on re-plan."""
    st = ColumnStore({"x": np.arange(100, dtype=np.int32)})
    with Analysis("marg", CAT) as a:
        tw = a.bind("t", st)
        f1 = a.op("rel_filter", a.op("rel_scan", tw), col="x", cmp="ge",
                  value=50)                      # 50% survive
        f2 = a.op("rel_filter", f1, col="x", cmp="lt", value=75)
        a.store(a.op("rel_group_agg", f2, key="x", num_groups=4,
                     aggs=(("n", "count", None),)))
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    fb = SelectivityFeedback()
    fn.observe({}, {"t": st.payload()}, feedback=fb)
    cols = st.type.col_names()
    s1 = fb.lookup(filter_site({"col": "x", "cmp": "ge", "value": 50},
                               cols, st.rows))
    s2 = fb.lookup(filter_site({"col": "x", "cmp": "lt", "value": 75},
                               cols, st.rows))
    assert s1 == pytest.approx(0.5)
    # of the 50 survivors of f1, 25 pass f2: marginal 0.5, cumulative 0.25
    assert s2 == pytest.approx(0.5)


def test_compact_fuses_into_rel_chains(rng):
    """Inserting a compaction must not split the fused superkernel chain:
    scan->filter->compact->join->group_agg stays one rel_fused call."""
    table, graph, corpus = _stores(rng)
    a = _selective_analysis(table, graph, corpus, selectivity=0.05)
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    chains = [[s[0] for s in n.attrs["chain"]]
              for n in fn.logical.topo() if n.op == "rel_fused"]
    assert any("compact" in c for c in chains), chains


# --------------------------------------------------------------------------
# PageRank first-iteration pushdown
# --------------------------------------------------------------------------

def test_pagerank_skip_first_bitwise(rng):
    n = 64
    g = GraphStore.from_edges(rng.randint(0, n, 300),
                              rng.randint(0, n, 300), n, symmetric=True)
    gp = g.payload()
    for density in (0.0, 0.05, 1.0):
        p = np.where(rng.rand(n) < density, rng.rand(n), 0.0) \
            .astype(np.float32)
        dense = pagerank(gp, iters=5, personalization=jnp.asarray(p))
        skip = pagerank(gp, iters=5, personalization=jnp.asarray(p),
                        skip_first=True, block=64)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(skip))


def test_pagerank_skip_candidate_chosen_when_sparse(rng):
    table, graph, corpus = _stores(rng)
    a = _selective_analysis(table, graph, corpus, selectivity=0.02)
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    chosen = {r["pattern"]: r["chosen"] for r in fn.report}
    assert chosen["graph_pagerank_op"] == "pagerank_skip"
    unpushed = a.compile(SYS, engines=store_engines(), cache=False,
                         rewrite_pipeline=UNPUSHED_PIPELINE)
    ins = _inputs(table, graph, corpus)
    np.testing.assert_array_equal(np.asarray(fn({}, ins)),
                                  np.asarray(unpushed({}, ins)))


def test_pagerank_dense_personalization_keeps_csr(rng):
    table, graph, corpus = _stores(rng)
    a = _selective_analysis(table, graph, corpus, selectivity=1.0)
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    chosen = {r["pattern"]: r["chosen"] for r in fn.report}
    assert chosen.get("graph_pagerank_op", "pagerank_csr") == "pagerank_csr"


# --------------------------------------------------------------------------
# masked hash-join probe kernel
# --------------------------------------------------------------------------

def test_join_probe_pallas_matches_reference(rng):
    for trial in range(3):
        nr = rng.randint(1, 40)
        rk = rng.permutation(100)[:nr].astype(np.int32)    # unique keys
        rv = rng.rand(nr) > 0.3
        lk = rng.randint(0, 100, rng.randint(1, 90)).astype(np.int32)
        gi, gm = join_probe_pallas(jnp.asarray(lk), jnp.asarray(rk),
                                   jnp.asarray(rv), interpret=True)
        wi, wm = R.join_probe_ref(lk, rk, rv)
        np.testing.assert_array_equal(np.asarray(gm), wm)
        np.testing.assert_array_equal(np.asarray(gi), wi)


def test_join_probe_candidate_gated_by_build_expected(rng):
    table, graph, corpus = _stores(rng)
    a = _selective_analysis(table, graph, corpus, selectivity=0.05)
    # keep the join un-fused so the rel_join pattern is visible
    fn = a.compile(SYS, engines=store_engines(pallas=True), cache=False,
                   rewrite_pipeline=NOFUSE_PIPELINE)
    joins = [r for r in fn.report if r["pattern"] == "rel_join_op"]
    assert joins, "rel_join should be pattern-matched"
    # build side is the k=16 top-k relation: expected count clears the gate
    assert "join_probe_kernel" in joins[0]["costs"]
    rel_only = a.compile(SYS, engines=store_engines(), cache=False,
                         rewrite_pipeline=NOFUSE_PIPELINE)
    ins = _inputs(table, graph, corpus)
    # enabling pallas swaps several candidates (masked scoring, pagerank),
    # which are allclose-not-bitwise by design; the probe kernel itself is
    # bitwise vs its reference (test above)
    np.testing.assert_allclose(np.asarray(fn({}, ins)),
                               np.asarray(rel_only({}, ins)),
                               rtol=1e-5, atol=1e-7)


# --------------------------------------------------------------------------
# unified validity conventions (text top-k, group-agg max)
# --------------------------------------------------------------------------

def test_text_topk_emits_bounded_rel(rng):
    corpus = TextStore.from_docs([[0, 1]] * 20, vocab=4)
    with Analysis("tk", CAT) as a:
        cx = a.bind("cx", corpus)
        q = a.input("q", TensorT((4,), "float32", ("vocab",)))
        a.store(a.op("text_topk", cx, q, k=8))
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    out = fn({}, {"cx": corpus.payload(),
                  "q": jnp.asarray(corpus.query_vector([0, 1]))})
    assert isinstance(out, BoundedRel)
    assert int(out.count) == 8 and not bool(out.overflow)
    assert set(out) == {"doc", "score", "_mask"}   # dict-compat surface


def test_group_agg_max_empty_groups_are_invalid_rows(rng):
    table = ColumnStore({"g": np.asarray([0, 0, 2], np.int32),
                         "v": np.asarray([0.0, -1.0, 5.0], np.float32)})
    with Analysis("gm", CAT) as a:
        tw = a.bind("t", table)
        a.store(a.op("rel_group_agg", tw, key="g", num_groups=3,
                     aggs=(("m", "max", "v"),)))
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    out = fn({}, {"t": table.payload()})
    # group 1 has no rows: its output row is invalid, not "max == 0.0"
    np.testing.assert_array_equal(np.asarray(out.valid),
                                  [True, False, True])
    np.testing.assert_array_equal(np.asarray(out["m"]), [0.0, 0.0, 5.0])
    assert int(out.count) == 2
