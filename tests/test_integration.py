"""Integration: planner-compiled forward vs the serving decode path must
agree; training must learn; buffering/microbatching must not change grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.executor import plan_and_compile
from repro.core.ir import SystemCatalog
from repro.data.pipeline import DataConfig, synth_batch
from repro.models import build_model
from repro.models.decode import decode_step, init_cache
from repro.models.lm import CATALOG
from repro.train.optim import cosine_schedule, make_optimizer
from repro.train.train_step import init_state, make_train_step

SYS = SystemCatalog()


@pytest.mark.parametrize("arch", [
    "qwen3-0.6b",
    pytest.param("gemma3-27b", marks=pytest.mark.slow),
    "rwkv6-3b",
    pytest.param("zamba2-7b", marks=pytest.mark.slow),
])
def test_plan_forward_matches_decode_path(arch, rng):
    """The same params through (a) the planner-compiled prefill and (b) the
    token-by-token cached decode must produce the same logits — this pins
    the two execution paths (training/serving) to each other."""
    cfg = get_smoke_config(arch).replace(dtype="float32")
    model = build_model(cfg)
    b, s = 1, 8
    params, _ = model.init_params(jax.random.key(1))
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)

    plan = model.build_plan(b, s, mode="prefill")
    fwd = plan_and_compile(plan, CATALOG, SYS)
    logits_plan = fwd(params, {"tokens": tokens})

    cache = init_cache(model, b, max_seq=s)
    outs = []
    for t in range(s):
        lg, cache = decode_step(model, params, cache, tokens[:, t:t + 1],
                                jnp.int32(t))
        outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_plan[..., :cfg.vocab], np.float32),
        np.asarray(logits_dec[..., :cfg.vocab], np.float32),
        atol=2e-2, rtol=2e-2)


def test_training_reduces_loss(rng):
    cfg = get_smoke_config("qwen3-0.6b").replace(dtype="float32")
    model = build_model(cfg)
    b, s = 4, 16
    plan = model.build_plan(b, s, mode="train")
    fwd = plan_and_compile(plan, CATALOG, SYS)
    opt = make_optimizer("adamw", cosine_schedule(3e-3, 5, 200))
    step = jax.jit(make_train_step(fwd, opt, grad_dtype="float32"))
    params, _ = model.init_params(jax.random.key(0))
    state = init_state(params, opt)
    dc = DataConfig(vocab=cfg.vocab, seq_len=s, global_batch=b)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in
                 synth_batch(dc, step=i % 2).items()}   # 2 repeating batches
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_microbatched_grads_match_full_batch(rng):
    """§5.3 streaming must be semantics-preserving: accumulated microbatch
    grads == full-batch grads (loss is a mean over valid tokens; equal-sized
    microbatches with identical valid counts keep the mean exact)."""
    cfg = get_smoke_config("deepseek-7b").replace(dtype="float32")
    model = build_model(cfg)
    b, s = 4, 8
    plan = model.build_plan(b, s, mode="train")
    fwd = plan_and_compile(plan, CATALOG, SYS)
    opt = make_optimizer("adamw", cosine_schedule(1e-3, 5, 100))
    params, _ = model.init_params(jax.random.key(0))
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1)
    batch = {"tokens": tokens, "labels": labels}

    s1 = init_state(params, opt)
    step_full = jax.jit(make_train_step(fwd, opt, num_microbatches=1,
                                        grad_dtype="float32"))
    step_mb = jax.jit(make_train_step(fwd, opt, num_microbatches=2,
                                      grad_dtype="float32"))
    _, m1 = step_full(s1, batch)
    s2 = init_state(params, opt)
    _, m2 = step_mb(s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    assert abs(float(m1["grad_norm"]) - float(m2["grad_norm"])) < 1e-3


def test_optimizers_step_all_families():
    for name in ("adamw", "adafactor"):
        opt = make_optimizer(name, cosine_schedule(1e-2, 1, 10))
        params = {"w": jnp.ones((4, 8)), "b": jnp.ones((8,))}
        grads = {"w": jnp.full((4, 8), 0.1), "b": jnp.full((8,), 0.1)}
        st = opt.init(params)
        new_p, st2 = opt.update(grads, st, params)
        assert float(jnp.sum(jnp.abs(new_p["w"] - params["w"]))) > 0
        assert int(st2["count"]) == 1


def test_shared_weights_are_actually_shared():
    """zamba2's shared attention block: grads flow into the single shared
    param set from every application."""
    cfg = get_smoke_config("zamba2-7b").replace(dtype="float32")
    model = build_model(cfg)
    b, s = 2, 8
    plan = model.build_plan(b, s, mode="train")
    fwd = plan_and_compile(plan, CATALOG, SYS)
    params, _ = model.init_params(jax.random.key(0))
    tokens = jnp.zeros((b, s), jnp.int32)
    labels = jnp.ones((b, s), jnp.int32)
    g = jax.grad(lambda p: fwd(p, {"tokens": tokens, "labels": labels}))(
        params)
    gn = float(jnp.sum(jnp.abs(g["shared"]["attn"]["wq"])))
    assert gn > 0, "no gradient reached the shared attention weights"


def test_int8_kv_cache_decode_close_to_bf16(rng):
    """int8 KV caches: same decode logits within quantization tolerance."""
    from repro.models.decode import init_cache
    cfg = get_smoke_config("qwen3-0.6b").replace(dtype="float32")
    model = build_model(cfg)
    b, s = 1, 8
    params, _ = model.init_params(jax.random.key(1))
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)

    def run(quant):
        cache = init_cache(model, b, max_seq=s, quantize_kv=quant)
        outs = []
        for t in range(s):
            lg, cache = decode_step(model, params, cache,
                                    tokens[:, t:t + 1], jnp.int32(t))
            outs.append(lg)
        return jnp.concatenate(outs, axis=1)

    ref = run(False)
    q = run(True)
    # logits agree to quantization error (int8 abs-max per head/position)
    err = float(jnp.max(jnp.abs(ref - q)))
    rel = err / float(jnp.max(jnp.abs(ref)))
    assert rel < 0.08, (err, rel)
