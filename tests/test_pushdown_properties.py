"""Property-based tests (hypothesis): pushed/fused plans are **bitwise
identical** to the unpushed PR 3 plans across random masks, selectivities
(including 0% and 100%), k beyond the unmasked count, and empty build
sides — pushdown may only change *where* the selection executes, never
what comes out."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dependency: property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.adil import Analysis
from repro.core.ir import SystemCatalog, TensorT, standard_catalog
from repro.core.rewrite import UNPUSHED_PIPELINE
from repro.stores import ColumnStore, GraphStore, TextStore, store_engines
from repro.stores import ref as R
from repro.stores.masked_kernels import masked_segment_agg_pallas
from repro.stores.text_store import tfidf_topk_blockskip, tfidf_topk_masked

CAT = standard_catalog()
SYS = SystemCatalog()
SETTINGS = dict(max_examples=8, deadline=None)


@st.composite
def workload_case(draw):
    rows = draw(st.integers(20, 120))
    nodes = draw(st.integers(4, 24))
    vocab = draw(st.integers(4, 24))
    # selectivity: force the edge cases in, then anything in between.
    # 0.0 also exercises the empty build side: no unmasked docs, so every
    # top-k row is invalid and the join probes an all-masked build relation
    sel = draw(st.one_of(st.sampled_from([0.0, 1.0, 0.01]),
                         st.floats(0.0, 1.0)))
    k = draw(st.one_of(st.integers(1, 8),
                       st.just(10_000)))           # k > docs: clamp path
    seed = draw(st.integers(0, 2 ** 31 - 1))
    return rows, nodes, vocab, sel, k, seed


def _build(rows, nodes, vocab, sel, k, rng):
    table = ColumnStore({
        "hashtag": rng.randint(0, nodes, rows).astype(np.int32),
        "doc": np.arange(rows, dtype=np.int32),
        "ts": np.arange(rows, dtype=np.int32),
    })
    e = rng.randint(0, nodes, (2, max(2 * nodes, 8)))
    graph = GraphStore.from_edges(e[0], e[1], nodes, symmetric=True)
    corpus = TextStore.from_docs(
        [rng.randint(0, vocab, rng.randint(1, 7)) for _ in range(rows)],
        vocab)
    cut = int(round(rows * (1 - sel)))
    with Analysis("prop", CAT) as a:
        tw = a.bind("tweets", table)
        gr = a.bind("g", graph)
        cx = a.bind("cx", corpus)
        q = a.input("q", TensorT((vocab,), "float32", ("vocab",)))
        t = a.op("rel_scan", tw)
        recent = a.op("rel_filter", t, col="ts", cmp="ge", value=cut,
                      selectivity=sel)
        m = a.op("sel_mask", recent, col="doc", size=rows)
        sc = a.op("text_scores", cx, q)
        hits = a.op("masked_topk", sc, m, k=k)
        j = a.op("rel_join", recent, hits, left_on="doc", right_on="doc")
        trel = a.op("rel_group_agg", j, key="hashtag", num_groups=nodes,
                    aggs=(("textrel", "sum", "score"),))
        seeds = a.op("rel_group_agg", recent, key="hashtag",
                     num_groups=nodes, aggs=(("seed", "count", None),))
        sv = a.op("col_tensor", seeds, col="seed", dim="nodes")
        fr = a.op("graph_expand", gr, sv, hops=2)
        tv = a.op("col_tensor", trel, col="textrel", dim="nodes")
        a.store(a.op("residual_add", fr, tv))
    inputs = {"tweets": table.payload(), "g": graph.payload(),
              "cx": corpus.payload(),
              "q": jnp.asarray(corpus.query_vector(
                  rng.randint(0, vocab, 3)))}
    return a, inputs


@given(workload_case())
@settings(**SETTINGS)
def test_pushed_plan_bitwise_identical_to_unpushed(case):
    rows, nodes, vocab, sel, k, seed = case
    rng = np.random.RandomState(seed)
    a, inputs = _build(rows, nodes, vocab, sel, k, rng)
    pushed = a.compile(SYS, engines=store_engines(), cache=False)
    unpushed = a.compile(SYS, engines=store_engines(), cache=False,
                         rewrite_pipeline=UNPUSHED_PIPELINE)
    np.testing.assert_array_equal(np.asarray(pushed({}, inputs)),
                                  np.asarray(unpushed({}, inputs)))


@st.composite
def mask_case(draw):
    docs = draw(st.integers(1, 80))
    vocab = draw(st.integers(2, 16))
    kind = draw(st.sampled_from(["none", "all", "window", "scatter"]))
    block = draw(st.sampled_from([16, 64, 4096]))
    k = draw(st.integers(1, 12))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    return docs, vocab, kind, block, k, seed


@given(mask_case())
@settings(**SETTINGS)
def test_blockskip_scoring_bitwise_equals_dense(case):
    docs, vocab, kind, block, k, seed = case
    rng = np.random.RandomState(seed)
    tx = TextStore.from_docs(
        [rng.randint(0, vocab, rng.randint(1, 8)) for _ in range(docs)],
        vocab)
    mask = {"none": np.zeros(docs, bool),
            "all": np.ones(docs, bool),
            "window": np.arange(docs) >= docs // 2,
            "scatter": rng.rand(docs) > 0.7}[kind]
    q = jnp.asarray(tx.query_vector(rng.randint(0, vocab, 3)))
    got = tfidf_topk_blockskip(tx.payload(), q, jnp.asarray(mask), k,
                               block=block)
    want = tfidf_topk_masked(tx.payload(), q, jnp.asarray(mask), k)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@st.composite
def segagg_case(draw):
    groups = draw(st.integers(1, 12))
    n = draw(st.integers(1, 100))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    return groups, n, seed


@given(segagg_case())
@settings(**SETTINGS)
def test_masked_segment_agg_kernel_agrees_with_reference(case):
    groups, n, seed = case
    rng = np.random.RandomState(seed)
    vals = rng.randn(n).astype(np.float32)
    keys = rng.randint(0, groups, n).astype(np.int32)
    maskw = (rng.rand(n) > 0.5).astype(np.float32)
    s, c = masked_segment_agg_pallas(jnp.asarray(vals), jnp.asarray(keys),
                                     jnp.asarray(maskw), num_groups=groups,
                                     interpret=True)
    ws, wc = R.masked_segment_agg_ref(vals, keys, maskw, groups)
    np.testing.assert_allclose(np.asarray(s), ws, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c), wc, rtol=1e-5, atol=1e-6)
