"""Multi-query optimization: sub-DAG fingerprints, the subplan cache,
cross-query CSE, single-flight dedup, vmapped query batching, tenant
fairness, and the concurrent plan-cache counters."""
import asyncio
import subprocess
import sys
import threading
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adil import Analysis
from repro.core.feedback import SelectivityFeedback
from repro.core.ir import SystemCatalog, TensorT, standard_catalog, \
    subdag_fingerprints
from repro.core.ledger import FlightRecorder, MemoryLedger
from repro.core.mqo import (SubplanCache, content_key, input_keys_for,
                            mqo_run, split_at_frontier, subdag_keys)
from repro.core.plan_cache import PlanCache
from repro.serving import TenantScheduler
from repro.stores import ColumnStore, store_engines

SYS = SystemCatalog()
SRC = Path(__file__).resolve().parents[1] / "src"


def _table(rng, rows=64):
    return ColumnStore({"k": (np.arange(rows) % 16).astype(np.int32),
                        "v": rng.rand(rows).astype(np.float32)})


def _compile_agg(table, name="q", *, feedback=None, extra=0.0):
    """rel_scan -> group_agg -> col_tensor (+``extra`` marks a variant)."""
    with Analysis(name, standard_catalog()) as a:
        t = a.op("rel_scan", a.bind("t", table))
        g = a.op("rel_group_agg", t, key="k", num_groups=16,
                 aggs=(("s", "sum", "v"),))
        vec = a.op("col_tensor", g, col="s", dim="nodes")
        if extra:
            vec = a.op("residual_add", vec, vec)
        a.store(vec)
    kw = {"engines": store_engines(), "cache": False}
    if feedback is not None:
        kw["feedback"] = feedback
    return a, a.compile(SYS, **kw)


# --------------------------------------------------------------------------
# sub-DAG fingerprint stability (ISSUE satellite)
# --------------------------------------------------------------------------

def test_subdag_fingerprints_are_stable_across_processes(rng):
    """Same program, fresh interpreter: every node fingerprint matches —
    the keys are content, not ids or iteration order."""
    table = _table(rng)
    _, fn = _compile_agg(table)
    fps = fn.staged.subdag_fingerprints()
    prog = (
        "import numpy as np\n"
        "from tests.test_mqo import _table, _compile_agg\n"
        "rng = np.random.RandomState(7)\n"
        "_, fn = _compile_agg(_table(rng))\n"
        "fps = fn.staged.subdag_fingerprints()\n"
        "print('\\n'.join(f'{k}={v}' for k, v in sorted(fps.items())))\n")
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        cwd=SRC.parent, env={"PYTHONPATH": f"{SRC}:{SRC.parent}",
                             "JAX_PLATFORMS": "cpu", "PATH": "/usr/bin:/bin"},
        check=True)
    remote = dict(line.split("=", 1)
                  for line in out.stdout.strip().splitlines())
    assert remote == {str(k): v for k, v in fps.items()}


def test_subdag_keys_miss_on_store_append(rng):
    """An append bumps the store version; every key under that input
    changes, so stale intermediates can never be hit."""
    table = _table(rng)
    a, fn = _compile_agg(table)
    k0 = subdag_keys(fn, {"t": table.payload()},
                     versions=a.store_versions())
    table.append({"k": np.array([3], np.int32),
                  "v": np.array([1.0], np.float32)})
    k1 = subdag_keys(fn, {"t": table.payload()},
                     versions=(("t", table.version),))
    assert set(k0) == set(k1)
    assert all(k0[n] != k1[n] for n in k0)   # version reaches every node


def test_subdag_keys_miss_on_feedback_change(rng):
    """A changed feedback fingerprint changes the staged plan's mqo_salt,
    which reaches every sub-DAG key — calibration shifts invalidate."""
    table = _table(rng)
    fb = SelectivityFeedback()
    _, f0 = _compile_agg(table, feedback=fb)
    assert "none" in f0.staged.mqo_salt
    fb.record(("sel_filter", "v", 64), 10, 64)
    _, f1 = _compile_agg(table, feedback=fb)
    assert f0.staged.mqo_salt != f1.staged.mqo_salt
    ins = {"t": table.payload()}
    k0 = subdag_keys(f0, ins, versions=(("t", 0),))
    k1 = subdag_keys(f1, ins, versions=(("t", 0),))
    assert all(k0[n] != k1[n] for n in k0 if n in k1)


def test_subdag_keys_hit_across_different_programs(rng):
    """Two textually different ADIL programs sharing the scan->agg subtree
    produce the same keys under it (node ids never enter the hash), so
    the second query reuses the first one's intermediates."""
    table = _table(rng)
    a1, f1 = _compile_agg(table, "prog_one")
    a2, f2 = _compile_agg(table, "prog_two", extra=1.0)  # extra residual_add
    ins = {"t": table.payload()}
    k1 = subdag_keys(f1, ins, versions=a1.store_versions())
    k2 = subdag_keys(f2, ins, versions=a2.store_versions())
    shared = set(k1.values()) & set(k2.values())
    assert len(shared) >= 3              # scan + agg + col_tensor at least
    cache = SubplanCache(8 << 20, ledger=MemoryLedger())
    out1, _ = mqo_run(f1, {}, ins, cache=cache,
                      versions=a1.store_versions())
    out2, info2 = mqo_run(f2, {}, ins, cache=cache,
                          versions=a2.store_versions())
    assert info2["shared_hits"] >= 1
    ref = f2({}, ins)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out2))


def test_mqo_run_bitwise_identical_and_residual_shrinks(rng):
    table = _table(rng)
    a, fn = _compile_agg(table)
    ins = {"t": table.payload()}
    ref = fn({}, ins)
    cache = SubplanCache(8 << 20, ledger=MemoryLedger())
    out1, i1 = mqo_run(fn, {}, ins, cache=cache,
                       versions=a.store_versions())
    out2, i2 = mqo_run(fn, {}, ins, cache=cache,
                       versions=a.store_versions())
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out1))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out2))
    assert i1["shared_hits"] == 0 and i2["shared_hits"] >= 1
    assert i2["executed"] < i1["executed"]


def test_content_key_framing_never_collides():
    """Leaves are type-tagged and delimited, containers emit open/close
    markers — adjacent scalars can't run together into a twin digest."""
    assert content_key([1, 2]) != content_key([12])
    assert content_key({}) != content_key([])
    assert content_key([1.5, 2]) != content_key([1.52])
    assert content_key((1, 2)) != content_key([1, 2])
    assert content_key([1, [2]]) != content_key([[1], 2])
    assert content_key({"a": 1}) != content_key({"a": "1"})
    assert content_key("1") != content_key(1)
    assert content_key(1) != content_key(True)
    assert content_key(None) != content_key([None])
    assert content_key([np.arange(3)]) != \
        content_key([np.arange(3).astype(np.int8)])


def test_subdag_keys_fold_in_params_identity(rng):
    """Physical ops read params through pp-attr bindings, so two queries
    with equal plans/inputs but different params must never share keys
    (or subplan-cache entries).  Empty params keep the param-free keys."""
    table = _table(rng)
    a, fn = _compile_agg(table)
    ins = {"t": table.payload()}
    sv = a.store_versions()
    k0 = subdag_keys(fn, ins, versions=sv, params={"w": 1.0})
    k1 = subdag_keys(fn, ins, versions=sv, params={"w": 2.0})
    kn = subdag_keys(fn, ins, versions=sv)
    assert all(k0[n] != k1[n] for n in k0)
    assert all(kn[n] != k0[n] for n in kn)
    assert subdag_keys(fn, ins, versions=sv, params={}) == kn
    # the CSE pass keys on params too: no cross-params hit
    cache = SubplanCache(8 << 20, ledger=MemoryLedger())
    _, i1 = mqo_run(fn, {"w": 1.0}, ins, cache=cache, versions=sv)
    _, i2 = mqo_run(fn, {"w": 2.0}, ins, cache=cache, versions=sv)
    assert i1["shared_hits"] == 0 and i2["shared_hits"] == 0


def test_split_at_frontier_survives_deep_plans():
    """The frontier walk is an explicit stack: a chain deeper than
    Python's recursion limit splits without RecursionError."""
    from types import SimpleNamespace
    depth = sys.getrecursionlimit() + 500
    nodes = {0: SimpleNamespace(id=0, inputs=("in",))}
    for i in range(1, depth):
        nodes[i] = SimpleNamespace(id=i, inputs=(i - 1,))
    pplan = SimpleNamespace(
        nodes=nodes, outputs=(depth - 1,),
        topo=lambda: [nodes[i] for i in range(depth)])
    cache = SubplanCache(1 << 20, ledger=MemoryLedger())
    hits, residual = split_at_frontier(pplan, {}, cache)
    assert not hits and residual == list(range(depth))


def test_input_keys_version_beats_content_and_uniq_never_collides():
    keys = input_keys_for({"a": np.zeros(4), "b": np.zeros(4)},
                          versions=(("a", 3),))
    assert keys["a"] == "ver:a:3"
    assert keys["b"].startswith("sha:")
    # unhashable/too-large inputs get unique keys: no false sharing
    big = np.zeros(1 << 23, np.int8)     # over the 4 MB hash cap
    k1 = input_keys_for({"x": big})["x"]
    k2 = input_keys_for({"x": big})["x"]
    assert k1.startswith("uniq:") and k1 != k2
    assert content_key({"q": np.arange(3)}) == \
        content_key({"q": np.arange(3)})


# --------------------------------------------------------------------------
# SubplanCache: budget, ledger, invalidation, thrash trip
# --------------------------------------------------------------------------

def test_subplan_cache_byte_budget_evicts_lru():
    led = MemoryLedger()
    cache = SubplanCache(4 * 100, ledger=led)   # room for ~4 arrays
    vals = {f"k{i}": np.zeros(25, np.float32) for i in range(6)}
    for k, v in vals.items():
        assert cache.insert(k, v)
    assert cache.bytes_in_cache <= cache.byte_budget
    assert cache.evictions >= 2
    assert cache.lookup("k0") is None            # LRU victim
    assert cache.lookup("k5") is not None
    snap = led.snapshot()
    assert snap["by_kind"]["subplan"] == cache.bytes_in_cache
    cache.clear()
    assert led.snapshot()["by_kind"].get("subplan", 0) == 0


def test_subplan_cache_oversize_value_is_skipped():
    cache = SubplanCache(64, ledger=MemoryLedger())
    assert not cache.insert("big", np.zeros(1000, np.float32))
    assert cache.oversize_skips == 1
    assert len(cache) == 0


def test_subplan_cache_note_store_evicts_stale_versions():
    cache = SubplanCache(1 << 20, ledger=MemoryLedger())
    cache.insert("old", np.ones(8), stores=(("t", 0),))
    cache.insert("other", np.ones(8) * 2, stores=(("u", 5),))
    assert cache.note_store("t", 1) == 1
    assert cache.lookup("old") is None
    assert cache.lookup("other") is not None
    assert cache.version_evictions == 1


def test_subplan_cache_thrash_trips_flight_recorder(tmp_path):
    rec = FlightRecorder(dump_dir=tmp_path)
    cache = SubplanCache(4 * 100, ledger=MemoryLedger(), recorder=rec,
                         thrash_window=8, thrash_rate=0.5)
    cache.note_frontier({"plan_id": "p", "shared_hits": 0, "executed": 9})
    for i in range(40):                          # way past the budget
        cache.insert(f"k{i}", np.zeros(25, np.float32))
    assert cache.thrash_trips >= 1
    dumps = list(tmp_path.glob("flight_*_subplan_thrash.jsonl"))
    assert dumps, "thrash trip must dump the flight ring"
    text = dumps[0].read_text()
    assert "eviction_rate" in text and "frontiers" in text


# --------------------------------------------------------------------------
# PlanCache counters under concurrency (ISSUE satellite)
# --------------------------------------------------------------------------

def test_plan_cache_stats_atomic_under_contention(rng):
    table = _table(rng)
    pc = PlanCache(ledger=MemoryLedger())
    _compile_agg(table)  # warm up compile machinery outside the threads
    n_threads, per_thread = 8, 40
    errs = []

    def hammer(tid):
        try:
            for i in range(per_thread):
                pid = f"plan_{tid}_{i % 5}"
                pc.note_fingerprint(pid)
                if pc.lookup(pid) is None:
                    pc.insert(pid, ("payload", tid, i))
                pc.stats()
        except Exception as exc:          # pragma: no cover
            errs.append(exc)

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    s = pc.stats()
    total = n_threads * per_thread
    # every lookup resolved to exactly one of hit/miss — no lost updates
    assert s["hits"] + s["misses"] == total
    assert s["misses"] == n_threads * 5          # 5 distinct ids per thread
    assert s["size"] == n_threads * 5


# --------------------------------------------------------------------------
# TenantScheduler: weighted round-robin fairness
# --------------------------------------------------------------------------

def test_tenant_scheduler_wrr_is_weight_proportional():
    sched = TenantScheduler({"gold": 3, "free": 1})
    for i in range(40):
        sched.enqueue(("gold", i), "gold")
        sched.enqueue(("free", i), "free")
    first = [sched.pop_next()[0] for _ in range(20)]
    assert first.count("gold") == 15 and first.count("free") == 5
    # smooth WRR interleaves rather than bursting
    assert "free" in set(first[:4])


def test_tenant_scheduler_idle_tenant_does_not_accrue_credit():
    sched = TenantScheduler({"a": 1, "b": 1})
    for i in range(4):
        sched.enqueue(i, "a")
    assert [sched.pop_next() for _ in range(4)] == [0, 1, 2, 3]
    for i in range(4):                     # b arrives late: no stored burst
        sched.enqueue(("b", i), "b")
        sched.enqueue(("a", i + 4), "a")
    picks = [sched.pop_next() for _ in range(4)]
    assert sum(1 for p in picks if p[0] == "b") == 2   # 1:1, no burst
    assert sched.drain() and sched.depth() == 0


# --------------------------------------------------------------------------
# run_analyses: dedup single-flight + vmapped batching (runtime path)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def runtime():
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serving import AsyncServingRuntime
    cfg = get_smoke_config("qwen3-0.6b").replace(dtype="float32")
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(1))
    return AsyncServingRuntime(model, params, max_batch=2, max_seq=32,
                               plan_cache=PlanCache(),
                               subplan_budget=16 << 20,
                               tenant_weights={"gold": 3, "free": 1})


def test_run_analyses_single_flights_identical_queries(rng, runtime):
    from repro.serving import AnalysisRequest
    table = _table(rng)
    a, fn = _compile_agg(table, "dedup_q")
    ins = {"t": table.payload()}
    ref = fn({}, ins)
    reqs = [AnalysisRequest(rid=i, planned=fn, inputs=ins, params={},
                            tenant="gold" if i % 2 else "free",
                            store_versions=a.store_versions())
            for i in range(6)]
    res = runtime.serve_analyses(reqs)
    assert [r.rid for r in res] == list(range(6))
    assert all(r.ok for r in res)
    for r in res:
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(r.value))
    assert sum(1 for r in res if r.deduped) == 5   # one leader computed
    assert runtime.registry.count("analytics.deduped", 0) >= 5


def test_run_analyses_batches_same_shape_queries(rng, runtime):
    """Queries identical modulo the declared ``batch_param`` leaf coalesce
    into ONE vmapped forward with bitwise-identical per-query results."""
    from repro.serving import AnalysisRequest
    table = _table(rng)
    with Analysis("param_q", standard_catalog()) as a:
        t = a.op("rel_scan", a.bind("t", table))
        g = a.op("rel_group_agg", t, key="k", num_groups=16,
                 aggs=(("s", "sum", "v"),))
        vec = a.op("col_tensor", g, col="s", dim="nodes")
        seed = a.input("seed", TensorT((16,), "float32", ("nodes",)))
        a.store(a.op("residual_add", vec, seed))
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    ins0 = {"t": table.payload()}
    seeds = [jnp.asarray(rng.rand(16).astype(np.float32))
             for _ in range(4)]
    iso = [fn({}, {**ins0, "seed": s}) for s in seeds]
    reqs = [AnalysisRequest(rid=f"b{i}", planned=fn,
                            inputs={**ins0, "seed": s}, params={},
                            batch_param="seed",
                            store_versions=a.store_versions())
            for i, s in enumerate(seeds)]
    res = runtime.serve_analyses(reqs)
    assert all(r.ok and r.batched for r in res)
    for r, ref in zip(res, iso):
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(r.value))
    assert runtime.registry.count("analytics.batched", 0) >= 4


def test_run_analyses_concurrent_tasks_share_inflight_futures(rng, runtime):
    """Two concurrently running run_analyses calls over the same query
    single-flight through the in-flight future map."""
    from repro.serving import AnalysisRequest
    table = _table(rng)
    a, fn = _compile_agg(table, "xtask_q")
    ins = {"t": table.payload()}
    sv = a.store_versions()

    async def both():
        r1 = runtime.run_analyses(
            [AnalysisRequest(rid="t1", planned=fn, inputs=ins, params={},
                             store_versions=sv)])
        r2 = runtime.run_analyses(
            [AnalysisRequest(rid="t2", planned=fn, inputs=ins, params={},
                             store_versions=sv)])
        return await asyncio.gather(r1, r2)

    (a_res,), (b_res,) = asyncio.run(both())
    assert a_res.ok and b_res.ok
    np.testing.assert_array_equal(np.asarray(a_res.value),
                                  np.asarray(b_res.value))


def test_run_analyses_distinct_params_are_not_deduped(rng, runtime):
    """Same plan + inputs, different params: root keys differ, so neither
    single-flight dedup nor the in-flight future map may fuse them."""
    from repro.serving import AnalysisRequest
    table = _table(rng)
    a, fn = _compile_agg(table, "params_q")
    ins = {"t": table.payload()}
    reqs = [AnalysisRequest(rid=f"p{i}", planned=fn, inputs=ins,
                            params={"w": float(i)},
                            store_versions=a.store_versions())
            for i in range(2)]
    res = runtime.serve_analyses(reqs)
    assert all(r.ok for r in res)
    assert not any(r.deduped for r in res)


def test_run_analyses_timeout_purges_stragglers(rng, runtime):
    """A timed-out call pulls its own queued requests back out of the
    shared tenant queues (structured timeout errors, nothing lingering)
    and a later call on the same runtime serves only its own work."""
    from repro.serving import AnalysisRequest
    table = _table(rng)
    a, fn = _compile_agg(table, "timeout_q")
    ins = {"t": table.payload()}
    sv = a.store_versions()
    reqs = [AnalysisRequest(rid=f"to{i}", planned=fn, inputs=ins,
                            params={}, store_versions=sv)
            for i in range(3)]
    res = runtime.serve_analyses(reqs, timeout_s=0.0)
    assert [r.rid for r in res] == ["to0", "to1", "to2"]
    assert all(not r.ok and r.error["reason"] == "timeout" for r in res)
    assert runtime.analysis_sched.depth() == 0
    res2 = runtime.serve_analyses(
        [AnalysisRequest(rid="after", planned=fn, inputs=ins, params={},
                         store_versions=sv)])
    assert len(res2) == 1 and res2[0].ok


def test_run_analyses_does_not_adopt_orphan_stragglers(rng, runtime):
    """A leftover queue entry from another (dead) caller must not count
    toward a new call's completion: the loop is scoped to its own rids,
    so the fresh request still resolves."""
    from repro.serving import AnalysisRequest
    table = _table(rng)
    a, fn = _compile_agg(table, "orphan_q")
    ins = {"t": table.payload()}
    sv = a.store_versions()
    orphan = AnalysisRequest(rid="orphan", planned=fn, inputs=ins,
                             params={}, tenant="free", store_versions=sv)
    runtime.analysis_sched.enqueue(orphan, orphan.tenant)
    old_tick = runtime.analysis_tick
    runtime.analysis_tick = 1          # one query per tick: the orphan
    try:                               # settles first, alone in its tick
        res = runtime.serve_analyses(
            [AnalysisRequest(rid="fresh", planned=fn, inputs=ins,
                             params={}, store_versions=sv)])
    finally:
        runtime.analysis_tick = old_tick
    assert [r.rid for r in res] == ["fresh"] and res[0].ok
    assert runtime.analysis_sched.depth() == 0


def test_run_analysis_routes_through_subplan_cache(rng, runtime):
    """The single-query entry point reuses cached sub-DAGs too (and stays
    bitwise-identical to plain execution)."""
    table = _table(rng)
    a, fn = _compile_agg(table, "single_q")
    ins = {"t": table.payload()}
    ref = fn({}, ins)
    hits0 = runtime.subplans.hits
    r1 = runtime.run_analysis(fn, {}, ins,
                              store_versions=a.store_versions())
    r2 = runtime.run_analysis(fn, {}, ins,
                              store_versions=a.store_versions())
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(r1))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(r2))
    assert runtime.subplans.hits > hits0


def test_analytics_summary_reports_the_mqo_counters(runtime):
    s = runtime.metrics.analytics_summary()
    assert s["requests"] >= 1
    assert "shared_hits" in s and "batched" in s and "deduped" in s
    assert "p95_ttfr_ms" in s
    assert "shared subplan hits" in runtime.metrics.analytics_report()
