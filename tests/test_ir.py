"""IR, validation, inference, and rewrite-rule tests (paper §2–§4)."""
import pytest

from repro.core.ir import (ListT, Plan, ScalarT, TensorT, TupleT,
                           ValidationError, infer_types, standard_catalog)
from repro.core.rewrite import (decompose, eliminate_redundancy, fuse_qkv,
                                fuse_scans, rewrite)

CAT = standard_catalog()


def _attn_attrs(**kw):
    a = {"heads": 4, "kv_heads": 2, "head_dim": 8, "embed": 32,
         "pp": ("attn",)}
    a.update(kw)
    return a


def small_plan():
    p = Plan("t")
    p.add_input("tokens", TensorT((2, 8), "int32", ("batch", "seq")))
    e = p.add("embed", ["tokens"], {"vocab": 64, "embed": 32,
                                    "pp": ("embed",)})
    a = p.add("attention", [e], _attn_attrs())
    m = p.add("mlp", [a], {"ffn": 64, "embed": 32, "pp": ("mlp",)})
    p.set_outputs(m)
    return p


# --------------------------------------------------------------------------
# typing / validation
# --------------------------------------------------------------------------

def test_infer_types_end_to_end():
    p = infer_types(small_plan(), CAT)
    out = p.type_of(p.outputs[0])
    assert isinstance(out, TensorT)
    assert out.shape == (2, 8, 32)
    assert out.dims == ("batch", "seq", "embed")


def test_embed_rejects_float_ids():
    p = Plan("t")
    p.add_input("x", TensorT((2, 8), "float32", ("batch", "seq")))
    p.add("embed", ["x"], {"vocab": 64, "embed": 32})
    with pytest.raises(ValidationError):
        infer_types(p, CAT)


def test_unknown_op_rejected():
    p = Plan("t")
    p.add_input("x", TensorT((2, 8), "int32", ("batch", "seq")))
    p.add("not_an_op", ["x"])
    with pytest.raises(ValidationError):
        infer_types(p, CAT)


def test_unknown_input_rejected():
    p = Plan("t")
    with pytest.raises(ValidationError):
        p.add("rmsnorm", ["missing"])


def test_residual_shape_mismatch_rejected():
    p = Plan("t")
    p.add_input("a", TensorT((2, 8, 32), "float32",
                             ("batch", "seq", "embed")))
    p.add_input("b", TensorT((2, 8, 16), "float32",
                             ("batch", "seq", "embed")))
    p.add("residual_add", ["a", "b"])
    with pytest.raises(ValidationError):
        infer_types(p, CAT)


def test_xent_validates_label_shape():
    p = Plan("t")
    p.add_input("logits", TensorT((2, 8, 64), "float32",
                                  ("batch", "seq", "vocab")))
    p.add_input("labels", TensorT((2, 9), "int32", ("batch", "seq")))
    p.add("softmax_xent", ["logits", "labels"])
    with pytest.raises(ValidationError):
        infer_types(p, CAT)


def test_higher_order_map_types():
    p = Plan("t")
    p.add_input("xs", ListT(TensorT((4, 4), "float32"), 3))
    sub = Plan("s")
    sub.add_input("x", TensorT((4, 4), "float32"))
    n = sub.add("rmsnorm", ["x"], {"pp": ("n",)})
    sub.set_outputs(n)
    m = p.add("map", ["xs"], {}, subplan=sub)
    p.set_outputs(m)
    infer_types(p, CAT)
    out = p.type_of(m)
    assert isinstance(out, ListT) and out.size == 3


# --------------------------------------------------------------------------
# rewrites (§4.2)
# --------------------------------------------------------------------------

def test_decompose_attention_and_mlp():
    p = infer_types(small_plan(), CAT)
    d = decompose(p, CAT)
    ops = [n.op for n in d.topo()]
    assert "attention" not in ops and "mlp" not in ops
    for needed in ("q_proj", "k_proj", "v_proj", "sdpa", "out_proj",
                   "ffn_up", "ffn_gate", "ffn_glu", "ffn_down"):
        assert needed in ops, needed
    # pp attrs survive decomposition
    qn = next(n for n in d.topo() if n.op == "q_proj")
    assert qn.attrs["pp"] == ("attn",)


def test_cse_merges_identical_subtrees():
    p = Plan("t")
    p.add_input("x", TensorT((2, 8, 32), "float32",
                             ("batch", "seq", "embed")))
    a = p.add("rmsnorm", ["x"], {"pp": ("n",)})
    b = p.add("rmsnorm", ["x"], {"pp": ("n",)})       # identical
    c = p.add("residual_add", [a, b])
    p.set_outputs(c)
    infer_types(p, CAT)
    out = eliminate_redundancy(p, CAT)
    assert len([n for n in out.topo() if n.op == "rmsnorm"]) == 1


def test_cse_respects_differing_attrs():
    p = Plan("t")
    p.add_input("x", TensorT((2, 8, 32), "float32",
                             ("batch", "seq", "embed")))
    a = p.add("rmsnorm", ["x"], {"pp": ("n1",)})
    b = p.add("rmsnorm", ["x"], {"pp": ("n2",)})      # different params
    c = p.add("residual_add", [a, b])
    p.set_outputs(c)
    infer_types(p, CAT)
    out = eliminate_redundancy(p, CAT)
    assert len([n for n in out.topo() if n.op == "rmsnorm"]) == 2


def test_qkv_fusion_fires_after_decompose():
    p = infer_types(small_plan(), CAT)
    d = decompose(p, CAT)
    f = fuse_qkv(d, CAT)
    ops = [n.op for n in f.topo()]
    assert "qkv_proj" in ops
    assert "q_proj" not in ops and "pack_qkv" not in ops


def test_scan_fusion_merges_same_group():
    p = Plan("t")
    p.add_input("h", TensorT((2, 8, 32), "float32",
                             ("batch", "seq", "embed")))
    sub = Plan("s")
    sub.add_input("x", TensorT((2, 8, 32), "float32",
                               ("batch", "seq", "embed")))
    n = sub.add("rmsnorm", ["x"], {"pp": ("n",)})
    sub.set_outputs(n)
    s1 = p.add("scan_layers", ["h"], {"n_layers": 4, "param_group": "g",
                                      "pp": ("g",)}, subplan=sub)
    s2 = p.add("scan_layers", [s1], {"n_layers": 4, "param_group": "g",
                                     "pp": ("g",)}, subplan=sub.copy())
    p.set_outputs(s2)
    infer_types(p, CAT)
    out = fuse_scans(p, CAT)
    scans = [n for n in out.topo() if n.op == "scan_layers"]
    assert len(scans) == 1
    assert len(scans[0].subplan) == 2     # concatenated subplans


def test_scan_fusion_skips_different_groups():
    p = Plan("t")
    p.add_input("h", TensorT((2, 8, 32), "float32",
                             ("batch", "seq", "embed")))
    sub = Plan("s")
    sub.add_input("x", TensorT((2, 8, 32), "float32",
                               ("batch", "seq", "embed")))
    n = sub.add("rmsnorm", ["x"], {"pp": ("n",)})
    sub.set_outputs(n)
    s1 = p.add("scan_layers", ["h"], {"n_layers": 4, "param_group": "a",
                                      "pp": ("a",)}, subplan=sub)
    s2 = p.add("scan_layers", [s1], {"n_layers": 4, "param_group": "b",
                                     "pp": ("b",)}, subplan=sub.copy())
    p.set_outputs(s2)
    infer_types(p, CAT)
    out = fuse_scans(p, CAT)
    assert len([n for n in out.topo() if n.op == "scan_layers"]) == 2


def test_rewrite_pipeline_revalidates():
    p = small_plan()
    out = rewrite(p, CAT)
    assert out.outputs[0] in out.types
