"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dependency: property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.buffering import partition_chains
from repro.core.cost_model import CostModel, poly2
from repro.core.ir import (Plan, TensorT, infer_types, standard_catalog)
from repro.core.parallel import add_data_parallelism
from repro.core.physical import PHYS_OPS, PhysPlan, generate_candidates
from repro.core.rewrite import rewrite
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import mha_reference

CAT = standard_catalog()
SETTINGS = dict(max_examples=25, deadline=None)


# --------------------------------------------------------------------------
# IR invariants
# --------------------------------------------------------------------------

@st.composite
def dense_plan(draw):
    b = draw(st.sampled_from([1, 2, 4]))
    s = draw(st.sampled_from([4, 8, 16]))
    e = draw(st.sampled_from([16, 32]))
    n_blocks = draw(st.integers(1, 3))
    p = Plan("prop")
    p.add_input("h", TensorT((b, s, e), "float32",
                             ("batch", "seq", "embed")))
    x = "h"
    for i in range(n_blocks):
        a = p.add("attention", [x], {"heads": 4, "kv_heads": 2,
                                     "head_dim": e // 4, "embed": e,
                                     "pp": (f"a{i}",)})
        x = p.add("residual_add", [x, a])
        m = p.add("mlp", [x], {"ffn": 2 * e, "embed": e, "pp": (f"m{i}",)})
        x = p.add("residual_add", [x, m])
    p.set_outputs(x)
    return p


@given(dense_plan())
@settings(**SETTINGS)
def test_rewrite_preserves_output_type(p):
    t_before = infer_types(p.copy(), CAT).type_of(p.outputs[0])
    out = rewrite(p, CAT)
    t_after = out.type_of(out.outputs[0])
    assert t_before.shape == t_after.shape
    assert t_before.dims == t_after.dims


@given(dense_plan())
@settings(**SETTINGS)
def test_inference_is_idempotent(p):
    p1 = infer_types(p, CAT)
    snap = dict(p1.types)
    p2 = infer_types(p1, CAT)
    assert snap == p2.types


@given(dense_plan(), st.booleans())
@settings(**SETTINGS)
def test_candidate_generation_total_and_acyclic(p, with_pallas):
    engines = ("xla", "pallas") if with_pallas else ("xla",)
    out = generate_candidates(rewrite(p, CAT), engines=engines)
    seen = set(out.inputs)
    for n in out.topo():                      # topological: inputs precede
        assert all(i in seen for i in n.inputs), n.id
        seen.add(n.id)
    for vid in out.pm:
        assert out.nodes[vid].virtual


@given(dense_plan())
@settings(**SETTINGS)
def test_dp_insertion_only_adds_partition_merge(p):
    pp = generate_candidates(rewrite(p, CAT))
    out = add_data_parallelism(pp)
    before = {n.id for n in pp.topo()}
    added = [n for n in out.topo() if n.id not in before]
    assert all(n.impl in ("partition", "merge") for n in added)


@given(dense_plan())
@settings(**SETTINGS)
def test_chains_partition_every_node_exactly_once(p):
    pp = add_data_parallelism(generate_candidates(rewrite(p, CAT)))
    chains = partition_chains(pp)
    flat = [n for ch in chains for n in ch]
    assert sorted(flat) == sorted(n.id for n in pp.topo())


# --------------------------------------------------------------------------
# cost model invariants
# --------------------------------------------------------------------------

@given(st.lists(st.tuples(st.floats(0, 10), st.floats(0, 10),
                          st.floats(0, 10)), min_size=20, max_size=60))
@settings(**SETTINGS)
def test_fit_is_interpolating_on_consistent_data(rows):
    """If measurements follow an exact deg-2 polynomial, Eq.2 fit matches."""
    samples = []
    for a, b, c in rows:
        f = {"f_compute": a, "f_memory": b, "f_network": c,
             "tokens_m": 0.0, "width_k": 0.0}
        y = 2.0 + a + 0.1 * b * b + 0.3 * a * c
        samples.append(("op", f, y))
    m = CostModel().fit(samples, ridge=1e-10)
    pred = m.predict_samples(samples)
    np.testing.assert_allclose(pred, [s[2] for s in samples],
                               atol=1e-5, rtol=1e-4)


@given(st.integers(1, 5))
@settings(**SETTINGS)
def test_poly2_feature_count(n):
    x = np.ones((1, n))
    assert poly2(x).shape[-1] == 1 + n + n + n * (n - 1) // 2


# --------------------------------------------------------------------------
# kernel invariants
# --------------------------------------------------------------------------

@given(st.integers(1, 2), st.sampled_from([8, 24, 32]),
       st.sampled_from([(2, 1), (4, 2), (4, 4)]),
       st.sampled_from([8, 16]), st.booleans())
@settings(max_examples=10, deadline=None)
def test_flash_matches_ref_property(b, s, hkv, d, causal):
    h, kv = hkv
    rng = np.random.RandomState(b * s + h + d)
    q = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, kv, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, kv, d), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = mha_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@given(st.sampled_from([4, 8, 12]), st.booleans())
@settings(max_examples=10, deadline=None)
def test_attention_permutation_equivariance_over_batch(s, causal):
    """Permuting the batch permutes the output — no cross-batch leakage."""
    rng = np.random.RandomState(s)
    b, h, d = 4, 2, 8
    q = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    perm = np.array([2, 0, 3, 1])
    out = mha_reference(q, k, v, causal=causal)
    out_p = mha_reference(q[perm], k[perm], v[perm], causal=causal)
    np.testing.assert_allclose(np.asarray(out[perm]), np.asarray(out_p),
                               atol=1e-6)
