"""Data pipeline: determinism (the fault-tolerance contract), masking,
prefetch thread behavior."""
import numpy as np

from repro.data.pipeline import DataConfig, PrefetchPipeline, synth_batch


def test_batch_is_pure_function_of_step():
    dc = DataConfig(vocab=100, seq_len=8, global_batch=4, seed=7)
    a = synth_batch(dc, 5)
    b = synth_batch(dc, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synth_batch(dc, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_and_masked():
    dc = DataConfig(vocab=100, seq_len=8, global_batch=2)
    b = synth_batch(dc, 0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -100).all()


def test_frontend_prefix_masks_labels():
    dc = DataConfig(vocab=100, seq_len=12, global_batch=2,
                    frontend_tokens=4, d_model=16)
    b = synth_batch(dc, 0)
    assert b["frontend_embeds"].shape == (2, 4, 16)
    assert (b["labels"][:, :4] == -100).all()
    assert b["tokens"].shape == (2, 8)


def test_prefetch_matches_sync_and_resumes_mid_stream():
    dc = DataConfig(vocab=100, seq_len=8, global_batch=2, seed=3)
    pipe = PrefetchPipeline(dc, start_step=10, prefetch=2)
    try:
        for want in (10, 11, 12):
            step, batch = next(pipe)
            assert step == want
            ref = synth_batch(dc, want)
            np.testing.assert_array_equal(batch["tokens"], ref["tokens"])
    finally:
        pipe.close()
