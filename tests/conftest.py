import os

# Tests run on the single real CPU device (the 512-device dry-run owns its
# own process; see src/repro/launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
