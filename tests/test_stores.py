"""Tri-store subsystem: store containers, relational/graph/text kernels,
cross-engine xfer placement, and end-to-end tri-model planning."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.adil import Analysis
from repro.core.adil_parser import parse_adil
from repro.core.engines import engine_names
from repro.core.ir import (CorpusT, GraphT, SystemCatalog, TableT, TensorT,
                           ValidationError, plan_id, standard_catalog)
from repro.core.rewrite import (DEFAULT_PIPELINE, place_xfers,
                                place_xfers_naive, rewrite)
from repro.stores import (ColumnStore, GraphStore, TextStore, store_engines)
from repro.stores import ref as R
from repro.stores.column_store import filter_mask, group_agg, hash_join
from repro.stores.graph_kernels import scatter_add_pallas
from repro.stores.graph_store import expand_frontier, pagerank, triangle_count
from repro.stores.text_store import tfidf_scores, tfidf_topk

CAT = standard_catalog()
SYS = SystemCatalog()


# --------------------------------------------------------------------------
# store containers
# --------------------------------------------------------------------------

def test_column_store_type_and_payload():
    cs = ColumnStore({"id": np.arange(5, dtype=np.int32),
                      "v": np.ones(5, np.float32)})
    assert cs.type == TableT((("id", "int32"), ("v", "float32")), 5)
    p = cs.payload()
    assert set(p) == {"id", "v", "_mask"}
    assert bool(p["_mask"].all())
    with pytest.raises(ValidationError):
        ColumnStore({"a": np.zeros(3), "b": np.zeros(4)})


def test_column_store_canonicalizes_64bit_columns():
    """64-bit host columns narrow to the 32-bit device representation
    explicitly: the declared type matches what actually executes, and keys
    that would wrap are refused instead of silently corrupted."""
    cs = ColumnStore({"id": np.arange(4),            # int64 on Linux
                      "v": np.ones(4, np.float64)})
    assert cs.type == TableT((("id", "int32"), ("v", "float32")), 4)
    assert str(cs.payload()["id"].dtype) == "int32"
    with pytest.raises(ValidationError):             # snowflake-scale ids
        ColumnStore({"id": np.array([2 ** 40, 1])})


def test_graph_store_csr_and_type():
    #  0 -> 1, 0 -> 2, 1 -> 2  (made symmetric)
    g = GraphStore.from_edges([0, 0, 1], [1, 2, 2], 3, symmetric=True)
    assert g.type == GraphT(3, 6)
    assert list(g.indptr) == [0, 2, 4, 6]
    assert sorted(zip(g.src.tolist(), g.indices.tolist())) == [
        (0, 1), (0, 2), (1, 0), (1, 2), (2, 0), (2, 1)]
    with pytest.raises(ValidationError):
        GraphStore.from_edges([0], [5], 3)


def test_text_store_index_and_type():
    tx = TextStore.from_docs([[0, 0, 1], [1, 2]], vocab=4)
    assert tx.type == CorpusT(2, 4, 4)     # (d0,t0) (d0,t1) (d1,t1) (d1,t2)
    assert tx.n_postings == 4
    # term 1 appears in both docs -> lowest idf among used terms
    assert tx.idf[1] < tx.idf[0] and tx.idf[1] < tx.idf[2]


# --------------------------------------------------------------------------
# kernels vs references (deterministic spot checks; property tests live in
# test_stores_properties.py)
# --------------------------------------------------------------------------

def test_hash_join_matches_reference(rng):
    lkeys = rng.randint(0, 50, 64)
    rkeys = rng.permutation(50)[:32]
    idx, matched = hash_join(jnp.asarray(lkeys), jnp.asarray(rkeys))
    ridx, rmatched = R.hash_join_ref(lkeys, rkeys)
    np.testing.assert_array_equal(np.asarray(matched), rmatched)
    np.testing.assert_array_equal(np.asarray(idx)[rmatched], ridx[rmatched])


def test_group_agg_matches_reference(rng):
    keys = rng.randint(0, 8, 100).astype(np.int32)
    vals = rng.randn(100).astype(np.float32)
    mask = rng.rand(100) > 0.3
    for fn in ("sum", "count", "mean", "max"):
        got = group_agg(jnp.asarray(vals), jnp.asarray(keys), 8,
                        jnp.asarray(mask), fn)
        want = R.group_agg_ref(vals, keys, 8, mask, fn)
        if fn == "max":
            (got, gvalid), (want, wvalid) = got, want
            np.testing.assert_array_equal(np.asarray(gvalid), wvalid)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5,
                                   atol=1e-6)


def test_group_agg_max_distinguishes_empty_from_zero():
    """An all-masked group is *invalid*, not a max of 0.0 — and a group
    whose true max is 0.0 is valid (the regression this guards)."""
    vals = jnp.asarray([0.0, -1.0, 5.0], jnp.float32)
    keys = jnp.asarray([0, 0, 1], jnp.int32)
    mask = jnp.asarray([True, True, False])
    got, valid = group_agg(vals, keys, 2, mask, "max")
    np.testing.assert_array_equal(np.asarray(valid), [True, False])
    np.testing.assert_array_equal(np.asarray(got), [0.0, 0.0])


def test_graph_ops_match_reference(rng):
    n, e = 32, 200
    src, dst = rng.randint(0, n, e), rng.randint(0, n, e)
    g = GraphStore.from_edges(src, dst, n, symmetric=True)
    gp = g.payload()
    x = rng.rand(n).astype(np.float32)
    got = expand_frontier(gp, jnp.asarray(x), hops=2)
    want = R.expand_ref(g.src, g.indices, g.weights, n, x, hops=2)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4)

    got_pr = pagerank(gp, iters=6, personalization=jnp.asarray(x))
    want_pr = R.pagerank_ref(g.src, g.indices, g.weights, n, iters=6,
                             personalization=x)
    np.testing.assert_allclose(np.asarray(got_pr), want_pr, rtol=1e-4)

    got_t = float(triangle_count(gp))
    assert got_t == pytest.approx(R.triangle_count_ref(g.src, g.indices, n))


def test_scatter_add_pallas_matches_segment_sum(rng):
    n, e = 100, 500
    dst = rng.randint(0, n, e).astype(np.int32)
    vals = rng.randn(e).astype(np.float32)
    got = scatter_add_pallas(jnp.asarray(vals), jnp.asarray(dst),
                             num_nodes=n, interpret=True)
    want = jax.ops.segment_sum(jnp.asarray(vals), jnp.asarray(dst),
                               num_segments=n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_empty_edge_and_build_sides():
    """Degenerate stores must degrade, not crash: a zero-edge graph scatters
    to zeros on both backends, and an empty join build side leaves every
    probe row unmatched."""
    z = scatter_add_pallas(jnp.zeros((0,)), jnp.zeros((0,), jnp.int32),
                           num_nodes=7, interpret=True)
    np.testing.assert_array_equal(np.asarray(z), np.zeros(7))
    g = GraphStore.from_edges(np.zeros(0, int), np.zeros(0, int), 5)
    got = expand_frontier(g.payload(), jnp.ones(5), hops=1, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(5))
    idx, matched = hash_join(jnp.asarray([1, 2, 3]),
                             jnp.asarray([], dtype=jnp.int32))
    assert not bool(np.asarray(matched).any())
    assert idx.shape == (3,)


def test_tfidf_matches_reference(rng):
    docs = [rng.randint(0, 16, rng.randint(2, 8)) for _ in range(20)]
    tx = TextStore.from_docs(docs, 16)
    q = tx.query_vector([1, 3, 5])
    got = tfidf_scores(tx.payload(), jnp.asarray(q))
    want = R.tfidf_scores_ref(tx.doc_ids, tx.term_ids, tx.tf, tx.doc_len,
                              tx.idf, q)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)
    ids, scores, valid = tfidf_topk(tx.payload(), jnp.asarray(q), 5)
    np.testing.assert_allclose(np.asarray(scores),
                               np.sort(want)[::-1][:5], rtol=1e-5)
    assert bool(np.asarray(valid).all())


# --------------------------------------------------------------------------
# xfer placement
# --------------------------------------------------------------------------

def _attn_plan():
    from repro.core.ir import Plan
    p = Plan("ap")
    p.add_input("h", TensorT((2, 16, 32), "float32",
                             ("batch", "seq", "embed")))
    a = p.add("attention", ["h"], {"heads": 4, "kv_heads": 2, "head_dim": 8,
                                   "embed": 32, "pp": ("attn",)})
    p.set_outputs(a)
    return p


def test_place_xfers_noop_on_tensor_plans():
    out = rewrite(_attn_plan(), CAT)
    assert not any(n.op == "xfer" for n in out.topo())


def _tri_analysis(table, graph, corpus):
    with Analysis("tri", CAT) as a:
        tw = a.bind("tweets", table)
        gr = a.bind("g", graph)
        cx = a.bind("cx", corpus)
        q = a.input("q", TensorT((corpus.vocab,), "float32", ("vocab",)))
        t = a.op("rel_scan", tw)
        hot = a.op("rel_filter", t, col="engagement", cmp="ge", value=20.0)
        seeds = a.op("rel_group_agg", hot, key="hashtag", num_groups=graph.n_nodes,
                     aggs=(("seed", "count", None),))
        sv = a.op("col_tensor", seeds, col="seed", dim="nodes")
        fr = a.op("graph_expand", gr, sv, hops=2)
        pr = a.op("graph_pagerank", gr, fr, iters=4)
        hits = a.op("text_topk", cx, q, k=8)
        j = a.op("rel_join", t, hits, left_on="doc", right_on="doc")
        trel = a.op("rel_group_agg", j, key="hashtag",
                    num_groups=graph.n_nodes,
                    aggs=(("textrel", "sum", "score"),))
        tv = a.op("col_tensor", trel, col="textrel", dim="nodes")
        comb = a.op("residual_add", pr, tv)
        a.store(comb)
    return a


def _small_social(rng):
    rows, nodes, vocab, docs = 300, 24, 32, 300
    table = ColumnStore({
        "user": rng.randint(0, 30, rows).astype(np.int32),
        "hashtag": rng.randint(0, nodes, rows).astype(np.int32),
        "doc": np.arange(rows, dtype=np.int32),
        "engagement": (rng.rand(rows) * 50).astype(np.float32),
    })
    e = rng.randint(0, nodes, (2, 200))
    graph = GraphStore.from_edges(e[0], e[1], nodes, symmetric=True)
    corpus = TextStore.from_docs(
        [rng.randint(0, vocab, rng.randint(2, 8)) for _ in range(docs)],
        vocab)
    return table, graph, corpus


def test_place_xfers_marks_engine_boundaries(rng):
    a = _tri_analysis(*_small_social(rng))
    placed = place_xfers(a.plan, CAT)
    xfers = [n for n in placed.topo() if n.op == "xfer"]
    assert len(xfers) >= 4
    crossings = {(n.attrs["src_engine"], n.attrs["dst_engine"])
                 for n in xfers}
    # rel -> graph (frontier seed), text -> rel (topk relation), and the
    # store-engine -> xla boundaries of the final ranking
    assert ("rel", "graph") in crossings
    assert ("text", "rel") in crossings
    assert not any(n.attrs.get("spill_only") for n in xfers)
    naive = place_xfers_naive(a.plan, CAT)
    spills = [n for n in naive.topo() if n.op == "xfer"]
    assert all(n.attrs["spill_only"] for n in spills)
    n_store_ops = sum(1 for n in a.plan.topo()
                      if CAT.get(n.op).engine != "xla")
    assert len(spills) == n_store_ops


# --------------------------------------------------------------------------
# end-to-end tri-model planning + execution
# --------------------------------------------------------------------------

def test_store_engines_registered():
    assert set(engine_names()) >= {"xla", "pallas", "rel", "graph", "text"}
    assert store_engines() == ("xla", "rel", "graph", "text")
    assert store_engines(pallas=True)[-1] == "pallas"


def test_tri_model_end_to_end_matches_numpy(rng):
    table, graph, corpus = _small_social(rng)
    a = _tri_analysis(table, graph, corpus)
    fn = a.compile(SYS, engines=store_engines(), cache=False)

    # planner pins every cross-engine boundary in device memory
    xfer_choices = [r for r in fn.report if r["pattern"] == "xfer_op"]
    assert xfer_choices and all(r["chosen"] == "xfer_pin"
                                for r in xfer_choices)

    q = corpus.query_vector([1, 2, 3])
    inputs = {"tweets": table.payload(), "g": graph.payload(),
              "cx": corpus.payload(), "q": jnp.asarray(q)}
    got = np.asarray(fn({}, inputs))

    # pure-NumPy reference pipeline
    eng = table.column("engagement")
    tags = table.column("hashtag")
    mask = eng >= 20.0
    seeds = R.group_agg_ref(None, tags, graph.n_nodes, mask, "count")
    fr = R.expand_ref(graph.src, graph.indices, graph.weights,
                      graph.n_nodes, seeds, hops=2)
    pr = R.pagerank_ref(graph.src, graph.indices, graph.weights,
                        graph.n_nodes, iters=4, personalization=fr)
    scores = R.tfidf_scores_ref(corpus.doc_ids, corpus.term_ids, corpus.tf,
                                corpus.doc_len, corpus.idf, q)
    top = np.argsort(-scores, kind="stable")[:8]
    trel = np.zeros(graph.n_nodes)
    for d in top:
        trel[tags[d]] += scores[d]          # doc id == row id here
    np.testing.assert_allclose(got, pr + trel, rtol=1e-4, atol=1e-6)


def test_naive_and_planned_placement_agree_bitwise(rng):
    table, graph, corpus = _small_social(rng)
    a = _tri_analysis(table, graph, corpus)
    naive_pipeline = tuple(p for p in DEFAULT_PIPELINE
                           if p != "place_xfers") + ("place_xfers_naive",)
    planned = a.compile(SYS, engines=store_engines(), cache=False)
    naive = a.compile(SYS, engines=store_engines(), cache=False,
                      rewrite_pipeline=naive_pipeline)
    assert any(n.impl == "xfer_spill" for n in naive.concrete.topo())
    inputs = {"tweets": table.payload(), "g": graph.payload(),
              "cx": corpus.payload(),
              "q": jnp.asarray(corpus.query_vector([4, 5]))}
    out_p = np.asarray(jax.jit(lambda i: planned({}, i))(inputs))
    out_n = np.asarray(jax.jit(lambda i: naive({}, i))(inputs))
    np.testing.assert_array_equal(out_p, out_n)


def test_pallas_graph_candidates_selected_and_close(rng):
    table, graph, corpus = _small_social(rng)
    a = _tri_analysis(table, graph, corpus)
    fn = a.compile(SYS, engines=store_engines(pallas=True), cache=False)
    chosen = {r["pattern"]: r["chosen"] for r in fn.report}
    assert chosen["graph_expand_op"] == "expand_pallas"
    assert chosen["graph_pagerank_op"] == "pagerank_pallas"
    fb = a.compile(SYS, engines=store_engines(), cache=False)
    inputs = {"tweets": table.payload(), "g": graph.payload(),
              "cx": corpus.payload(),
              "q": jnp.asarray(corpus.query_vector([4, 5]))}
    np.testing.assert_allclose(np.asarray(fn({}, inputs)),
                               np.asarray(fb({}, inputs)), rtol=1e-4,
                               atol=1e-6)


# --------------------------------------------------------------------------
# ADIL front ends: native table/graph/corpus declarations
# --------------------------------------------------------------------------

TRI_SRC = """
USE socialDB;
create analysis tiny_tri as {
  tweets := table(rows=100, cols=[[hashtag, int32], [engagement, float32]]);
  g      := graph(nodes=16, edges=64);
  cx     := corpus(docs=100, vocab=32, postings=400);
  q      := input([32], float32, dims=[vocab]);
  t      := rel_scan(tweets);
  hot    := rel_filter(t, col=engagement, cmp=ge, value=10.0);
  seeds  := rel_group_agg(hot, key=hashtag, num_groups=16,
                          aggs=[[seed, count, hashtag]]);
  sv     := col_tensor(seeds, col=seed, dim=nodes);
  pr     := graph_pagerank(g, sv, iters=3);
  hits   := text_topk(cx, q, k=5);
  store(pr);
  store(hits);
}
"""


def test_parser_store_declarations_match_builder():
    parsed = parse_adil(TRI_SRC, CAT)
    assert parsed.plan.inputs["tweets"] == TableT(
        (("hashtag", "int32"), ("engagement", "float32")), 100)
    assert parsed.plan.inputs["g"] == GraphT(16, 64)
    assert parsed.plan.inputs["cx"] == CorpusT(100, 32, 400)

    with Analysis("tiny_tri", CAT) as b:
        tw = b.table("tweets", 100, (("hashtag", "int32"),
                                     ("engagement", "float32")))
        gr = b.graph("g", 16, 64)
        cx = b.corpus("cx", 100, 32, 400)
        q = b.input("q", TensorT((32,), "float32", ("vocab",)))
        t = b.op("rel_scan", tw)
        hot = b.op("rel_filter", t, col="engagement", cmp="ge", value=10.0)
        seeds = b.op("rel_group_agg", hot, key="hashtag", num_groups=16,
                     aggs=(("seed", "count", "hashtag"),))
        sv = b.op("col_tensor", seeds, col="seed", dim="nodes")
        pr = b.op("graph_pagerank", gr, sv, iters=3)
        hits = b.op("text_topk", cx, q, k=5)
        b.store(pr)
        b.store(hits)
    assert plan_id(parsed.plan, CAT, SYS) == plan_id(b.plan, CAT, SYS)


def test_tri_store_type_validation():
    with pytest.raises(ValidationError):        # filter on missing column
        with Analysis("bad", CAT) as a:
            tw = a.table("t", 10, (("x", "int32"),))
            a.store(a.op("rel_filter", tw, col="nope", cmp="ge", value=1))
    with pytest.raises(ValidationError):        # frontier shape mismatch
        with Analysis("bad2", CAT) as a:
            g = a.graph("g", 8, 16)
            f = a.input("f", TensorT((4,), "float32", ("nodes",)))
            a.store(a.op("graph_expand", g, f))
    with pytest.raises(ValidationError):        # query vocab mismatch
        with Analysis("bad3", CAT) as a:
            cx = a.corpus("c", 10, 32, 50)
            q = a.input("q", TensorT((16,), "float32", ("vocab",)))
            a.store(a.op("text_topk", cx, q, k=3))
    with pytest.raises(ValidationError):        # float group key
        with Analysis("bad4", CAT) as a:
            tw = a.table("t", 10, (("x", "float32"),))
            a.store(a.op("rel_group_agg", tw, key="x", num_groups=4,
                         aggs=(("n", "count", None),)))
    with pytest.raises(ValidationError):        # weights/edges mismatch
        GraphStore.from_edges([0, 1], [1, 0], 2, weights=[1.0, 2.0, 3.0])
