"""Property-based tests (hypothesis) for the tri-store kernels: every
JAX/Pallas store kernel must agree with its pure-NumPy reference on
arbitrary inputs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dependency: property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.stores import GraphStore, TextStore
from repro.stores import ref as R
from repro.stores.bounded import BoundedRel, compact_rel
from repro.stores.column_store import (group_agg, hash_join,
                                       hash_join_nonunique)
from repro.stores.graph_kernels import scatter_add_pallas
from repro.stores.graph_store import pagerank
from repro.stores.text_store import tfidf_scores

SETTINGS = dict(max_examples=20, deadline=None)


@st.composite
def join_case(draw):
    n_right = draw(st.integers(1, 40))
    universe = draw(st.integers(n_right, 80))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.RandomState(seed)
    rkeys = rng.permutation(universe)[:n_right].astype(np.int32)  # unique
    lkeys = rng.randint(0, universe, draw(st.integers(1, 60))).astype(np.int32)
    return lkeys, rkeys


@given(join_case())
@settings(**SETTINGS)
def test_hash_join_agrees_with_reference(case):
    lkeys, rkeys = case
    idx, matched = hash_join(jnp.asarray(lkeys), jnp.asarray(rkeys))
    ridx, rmatched = R.hash_join_ref(lkeys, rkeys)
    np.testing.assert_array_equal(np.asarray(matched), rmatched)
    np.testing.assert_array_equal(np.asarray(idx)[rmatched], ridx[rmatched])


@st.composite
def group_case(draw):
    groups = draw(st.integers(1, 12))
    n = draw(st.integers(1, 80))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.RandomState(seed)
    return (rng.randn(n).astype(np.float32),
            rng.randint(0, groups, n).astype(np.int32),
            groups,
            rng.rand(n) > 0.4,
            draw(st.sampled_from(["sum", "count", "mean", "max"])))


@given(group_case())
@settings(**SETTINGS)
def test_group_agg_agrees_with_reference(case):
    vals, keys, groups, mask, fn = case
    got = group_agg(jnp.asarray(vals), jnp.asarray(keys), groups,
                    jnp.asarray(mask), fn)
    want = R.group_agg_ref(vals, keys, groups, mask, fn)
    if fn == "max":
        (got, gvalid), (want, wvalid) = got, want
        np.testing.assert_array_equal(np.asarray(gvalid), wvalid)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)


@st.composite
def bounded_join_case(draw):
    nl = draw(st.integers(1, 60))
    nr = draw(st.integers(1, 40))
    universe = draw(st.integers(1, 20))        # small domain -> duplicates
    capacity = draw(st.integers(1, 120))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.RandomState(seed)
    return (rng.randint(0, universe, nl).astype(np.int32),
            rng.rand(nl) > 0.3,
            rng.randint(0, universe, nr).astype(np.int32),
            rng.rand(nr) > 0.3,
            capacity)


@given(bounded_join_case())
@settings(**SETTINGS)
def test_bounded_join_agrees_with_reference(case):
    """Non-unique-build join: every capacity (undersized included) must
    reproduce the reference's slot assignment, count, and overflow flag."""
    lk, lm, rk, rm, cap = case
    gl, gr, gv, gc, go = [np.asarray(x) for x in hash_join_nonunique(
        jnp.asarray(lk), jnp.asarray(lm), jnp.asarray(rk), jnp.asarray(rm),
        cap)]
    wl, wr, wv, wc, wo = R.bounded_join_ref(lk, lm, rk, rm, cap)
    np.testing.assert_array_equal(gv, wv)
    np.testing.assert_array_equal(gl[wv], wl[wv])
    np.testing.assert_array_equal(gr[wv], wr[wv])
    assert int(gc) == wc and bool(go) == wo


@st.composite
def compact_case(draw):
    n = draw(st.integers(1, 120))
    capacity = draw(st.integers(1, 150))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    density = draw(st.floats(0.0, 1.0))
    rng = np.random.RandomState(seed)
    return (rng.randn(n).astype(np.float32),
            rng.randint(0, 100, n).astype(np.int32),
            rng.rand(n) < density,
            capacity)


@given(compact_case())
@settings(**SETTINGS)
def test_compact_agrees_with_reference(case):
    """Stable prefix compaction preserves valid rows in order at any
    capacity, flagging (never silently hiding) overflow."""
    vals, ids, valid, cap = case
    rel = BoundedRel({"v": jnp.asarray(vals), "id": jnp.asarray(ids)},
                     jnp.asarray(valid))
    got = compact_rel(rel, cap)
    cols, wvalid, wcount, wovf = R.compact_ref(
        {"v": vals, "id": ids}, valid, min(cap, len(vals)))
    np.testing.assert_array_equal(np.asarray(got.valid), wvalid)
    assert int(got.count) == wcount and bool(got.overflow) == wovf
    np.testing.assert_array_equal(np.asarray(got.cols["v"])[wvalid],
                                  cols["v"][wvalid])
    np.testing.assert_array_equal(np.asarray(got.cols["id"])[wvalid],
                                  cols["id"][wvalid])


@st.composite
def graph_case(draw):
    n = draw(st.integers(2, 40))
    e = draw(st.integers(1, 150))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.RandomState(seed)
    return (rng.randint(0, n, e), rng.randint(0, n, e), n,
            rng.rand(n).astype(np.float32), draw(st.integers(1, 6)))


@given(graph_case())
@settings(**SETTINGS)
def test_pagerank_agrees_with_reference(case):
    src, dst, n, p, iters = case
    g = GraphStore.from_edges(src, dst, n, symmetric=True)
    got = pagerank(g.payload(), iters=iters, personalization=jnp.asarray(p))
    want = R.pagerank_ref(g.src, g.indices, g.weights, n, iters=iters,
                          personalization=p)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-5)


@st.composite
def scatter_case(draw):
    n = draw(st.integers(1, 300))
    e = draw(st.integers(1, 600))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.RandomState(seed)
    return (rng.randn(e).astype(np.float32),
            rng.randint(0, n, e).astype(np.int32), n)


@given(scatter_case())
@settings(max_examples=10, deadline=None)
def test_pallas_scatter_add_agrees_with_segment_sum(case):
    vals, dst, n = case
    got = scatter_add_pallas(jnp.asarray(vals), jnp.asarray(dst),
                             num_nodes=n, interpret=True)
    want = jax.ops.segment_sum(jnp.asarray(vals), jnp.asarray(dst),
                               num_segments=n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@st.composite
def corpus_case(draw):
    vocab = draw(st.integers(2, 24))
    n_docs = draw(st.integers(1, 25))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.RandomState(seed)
    docs = [rng.randint(0, vocab, rng.randint(1, 10)) for _ in range(n_docs)]
    q_terms = rng.randint(0, vocab, draw(st.integers(1, 5)))
    return docs, vocab, q_terms


@given(corpus_case())
@settings(**SETTINGS)
def test_tfidf_agrees_with_reference(case):
    docs, vocab, q_terms = case
    tx = TextStore.from_docs(docs, vocab)
    q = tx.query_vector(q_terms)
    got = tfidf_scores(tx.payload(), jnp.asarray(q))
    want = R.tfidf_scores_ref(tx.doc_ids, tx.term_ids, tx.tf, tx.doc_len,
                              tx.idf, q)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# shard invariance: distributed kernels vs their dense versions
# --------------------------------------------------------------------------
# Guarantee per kernel (each asserted at exactly that strength below):
#   bitwise   — broadcast join, PageRank, k-hop expand, top-k TF-IDF: the
#               stable dst-block / doc-block selection preserves per-
#               destination contribution order, and the top-k merge
#               reproduces lax.top_k's (score desc, doc asc) tie-breaking;
#   allclose  — group aggregate: the cross-shard psum re-associates float
#               sums (max stays bitwise via pmax, but sum/mean do not);
#   set-equal — partitioned join: the all_to_all lands output slots in
#               shard-major order, so the match *set* and the exact global
#               count agree while slot order differs.
# The mesh spans every local device: 1 in the default tier-1 run (the
# kernels still execute through shard_map), 8 under CI's forced host
# platform — the same tests then exercise real cross-shard collectives.

from repro.launch.mesh import make_cpu_mesh
from repro.stores.graph_store import expand_frontier
from repro.stores.sharded import (sharded_broadcast_join, sharded_count,
                                  sharded_expand, sharded_group_agg,
                                  sharded_pagerank, sharded_partitioned_join,
                                  sharded_tfidf_topk)
from repro.stores.text_store import tfidf_topk

N_DEV = jax.local_device_count()
MESH = make_cpu_mesh(N_DEV, 1)
SHARD_SETTINGS = dict(max_examples=10, deadline=None)


@st.composite
def sharded_bjoin_case(draw):
    per = draw(st.integers(1, 8))
    n_right = draw(st.integers(1, 40))
    universe = draw(st.integers(n_right, 80))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.RandomState(seed)
    rkeys = rng.permutation(universe)[:n_right].astype(np.int32)  # unique
    lkeys = rng.randint(0, universe, per * N_DEV).astype(np.int32)
    return lkeys, rkeys


@given(sharded_bjoin_case())
@settings(**SHARD_SETTINGS)
def test_sharded_broadcast_join_bitwise(case):
    """Probe row-partitioned, build replicated: the probe-aligned output
    is bitwise identical to the dense hash join."""
    lkeys, rkeys = case
    gi, gm = sharded_broadcast_join(jnp.asarray(lkeys), jnp.asarray(rkeys),
                                    MESH)
    wi, wm = hash_join(jnp.asarray(lkeys), jnp.asarray(rkeys))
    np.testing.assert_array_equal(np.asarray(gm), np.asarray(wm))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


@st.composite
def sharded_pjoin_case(draw):
    nl = draw(st.integers(1, 4)) * N_DEV
    nr = draw(st.integers(1, 3)) * N_DEV
    universe = draw(st.integers(1, 16))        # small domain -> duplicates
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.RandomState(seed)
    # capacity gives every shard headroom for the worst case (all matches
    # hashing to one owner), so neither side can overflow and the global
    # match set is uniquely determined
    return (rng.randint(0, universe, nl).astype(np.int32),
            rng.rand(nl) > 0.3,
            rng.randint(0, universe, nr).astype(np.int32),
            rng.rand(nr) > 0.3,
            nl * nr * N_DEV)


@given(sharded_pjoin_case())
@settings(**SHARD_SETTINGS)
def test_sharded_partitioned_join_set_equal(case):
    """Co-partitioned join: slot order is shard-major (not the dense
    order), but the set of matched (left row, right row) pairs and the
    exact global count agree with the dense non-unique join."""
    lk, lm, rk, rm, cap = case
    gl, gr, gv, gc, go = sharded_partitioned_join(
        jnp.asarray(lk), jnp.asarray(lm), jnp.asarray(rk), jnp.asarray(rm),
        cap, MESH, bucket_cap=max(len(lk), len(rk)))
    wl, wr, wv, wc, wo = hash_join_nonunique(
        jnp.asarray(lk), jnp.asarray(lm), jnp.asarray(rk), jnp.asarray(rm),
        cap)
    assert int(gc) == int(wc) and not bool(go) and not bool(wo)
    got = np.stack([np.asarray(gl)[np.asarray(gv)],
                    np.asarray(gr)[np.asarray(gv)]], 1)
    want = np.stack([np.asarray(wl)[np.asarray(wv)],
                     np.asarray(wr)[np.asarray(wv)]], 1)
    got = got[np.lexsort(got.T[::-1])]
    want = want[np.lexsort(want.T[::-1])]
    np.testing.assert_array_equal(got, want)


@st.composite
def sharded_group_case(draw):
    groups = draw(st.integers(1, 12))
    n = draw(st.integers(1, 10)) * N_DEV
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.RandomState(seed)
    return (rng.randn(n).astype(np.float32),
            rng.randint(0, groups, n).astype(np.int32),
            groups,
            rng.rand(n) > 0.4,
            draw(st.sampled_from(["sum", "count", "mean", "max"])))


@given(sharded_group_case())
@settings(**SHARD_SETTINGS)
def test_sharded_group_agg_allclose_and_count_exact(case):
    """psum-merged segment aggregate: float sums re-associate across
    shards (allclose); the psum'd valid-row count — the selectivity
    feedback path — is integer-exact."""
    vals, keys, groups, mask, fn = case
    got = sharded_group_agg(jnp.asarray(vals), jnp.asarray(keys), groups,
                            jnp.asarray(mask), fn, MESH)
    want = group_agg(jnp.asarray(vals), jnp.asarray(keys), groups,
                     jnp.asarray(mask), fn)
    if fn == "max":
        (got, gvalid), (want, wvalid) = got, want
        np.testing.assert_array_equal(np.asarray(gvalid), np.asarray(wvalid))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    assert int(sharded_count(jnp.asarray(mask), MESH)) == int(mask.sum())


@st.composite
def sharded_graph_case(draw):
    n = draw(st.integers(2, 40))
    e = draw(st.integers(1, 150))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.RandomState(seed)
    g = GraphStore.from_edges(rng.randint(0, n, e), rng.randint(0, n, e),
                              n, symmetric=True).with_shards(N_DEV)
    return (g, rng.rand(g.n_nodes).astype(np.float32),
            draw(st.integers(1, 4)))


@pytest.mark.skipif(
    N_DEV < 2,
    reason="block/doc partitioning needs >= 2 devices: with_shards(1) "
           "carries no block payload")
@given(sharded_graph_case())
@settings(**SHARD_SETTINGS)
def test_sharded_pagerank_bitwise(case):
    """Dst-block SpMV with a per-iteration frontier all-gather: the stable
    dst-block edge selection preserves per-destination contribution order,
    so the sharded iteration is bitwise equal to the dense one."""
    g, p, iters = case
    pay = g.payload()
    got = sharded_pagerank(pay, iters, 0.85, jnp.asarray(p), MESH)
    want = pagerank(pay, iters=iters, damping=0.85,
                    personalization=jnp.asarray(p))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.skipif(
    N_DEV < 2,
    reason="block/doc partitioning needs >= 2 devices: with_shards(1) "
           "carries no block payload")
@given(sharded_graph_case())
@settings(**SHARD_SETTINGS)
def test_sharded_expand_bitwise(case):
    g, p, hops = case
    pay = g.payload()
    frontier = jnp.asarray((p > 0.7).astype(np.float32))
    got = sharded_expand(pay, frontier, hops, MESH)
    want = expand_frontier(pay, frontier, hops)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@st.composite
def sharded_corpus_case(draw):
    vocab = draw(st.integers(2, 24))
    n_docs = draw(st.integers(1, 25))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.RandomState(seed)
    docs = [rng.randint(0, vocab, rng.randint(1, 10)) for _ in range(n_docs)]
    tx = TextStore.from_docs(docs, vocab).with_shards(N_DEV)
    return tx, rng.randint(0, vocab, draw(st.integers(1, 5))), \
        draw(st.integers(1, 40))


@pytest.mark.skipif(
    N_DEV < 2,
    reason="block/doc partitioning needs >= 2 devices: with_shards(1) "
           "carries no block payload")
@given(sharded_corpus_case())
@settings(**SHARD_SETTINGS)
def test_sharded_topk_bitwise(case):
    """Shard-local top-k + fixed-capacity merge ordered by (score desc,
    doc asc): exactly lax.top_k's lowest-index tie-breaking, so ids,
    scores, and valid flags are all bitwise equal to the dense top-k —
    zero-score ties included."""
    tx, q_terms, k = case
    pay = tx.payload()
    q = jnp.asarray(tx.query_vector(q_terms))
    gi, gs, gv = sharded_tfidf_topk(pay, q, k, MESH)
    wi, ws, wv = tfidf_topk(pay, q, k)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
