"""Distribution semantics, run in subprocesses with 8 host-platform devices
(device count is locked at first jax init, so these cannot share the main
test process):

  * sharded (data×model) train step == single-device step (same loss/grads);
  * checkpoint saved on one mesh restores onto a different mesh (elastic);
  * bf16 grad reduction (compression) halves collective wire bytes.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# heavyweight: every test spawns a fresh 8-device subprocess that compiles a
# sharded train step — minutes each on CPU.  Deselected from the tier-1
# default run (see pytest.ini); run with `pytest -m slow`.
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, n_dev: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout[-2000:]
    return json.loads(line[-1][len("RESULT "):])


COMMON = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.core.executor import (ShardingRules, params_sharding,
                                     plan_and_compile)
    from repro.models import build_model
    from repro.models.lm import CATALOG
    from repro.launch.mesh import input_shardings, state_shardings, \
        syscat_for_mesh
    from repro.data.pipeline import DataConfig, synth_batch
    from repro.train.optim import cosine_schedule, make_optimizer
    from repro.train.train_step import init_state, make_train_step

    def setup(mesh=None, grad_dtype="float32"):
        cfg = get_smoke_config("qwen3-0.6b").replace(dtype="float32")
        model = build_model(cfg)
        b, s = 8, 16
        plan = model.build_plan(b, s, mode="train")
        syscat = syscat_for_mesh(mesh) if mesh is not None else None
        from repro.core.ir import SystemCatalog
        fwd = plan_and_compile(plan, CATALOG, syscat or SystemCatalog(),
                               mesh=mesh)
        opt = make_optimizer("adamw", cosine_schedule(1e-3, 2, 100))
        step = make_train_step(fwd, opt, grad_dtype=grad_dtype)
        params, _ = model.init_params(jax.random.key(0))
        state = init_state(params, opt)
        dc = DataConfig(vocab=cfg.vocab, seq_len=s, global_batch=b)
        batch = {k: jnp.asarray(v) for k, v in synth_batch(dc, 0).items()}
        return model, opt, step, state, batch
""")


def test_sharded_step_matches_single_device():
    code = COMMON + textwrap.dedent("""
        # single device
        _, _, step, state, batch = setup()
        s1, m1 = jax.jit(step)(state, batch)

        # 4x2 data x model mesh
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        model, opt, step2, state2, batch2 = setup(mesh)
        st_shard = state_shardings(mesh, model, opt)
        in_shard = input_shardings(mesh, {k: jax.ShapeDtypeStruct(
            v.shape, v.dtype) for k, v in batch2.items()})
        state2 = jax.device_put(state2, st_shard)
        batch2 = {k: jax.device_put(v, in_shard[k])
                  for k, v in batch2.items()}
        s2, m2 = jax.jit(step2, in_shardings=(st_shard, in_shard),
                         out_shardings=(st_shard, None))(state2, batch2)
        print("RESULT " + json.dumps({
            "loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
            "gn1": float(m1["grad_norm"]), "gn2": float(m2["grad_norm"])}))
    """)
    r = run_sub(code)
    assert abs(r["loss1"] - r["loss2"]) < 1e-4, r
    assert abs(r["gn1"] - r["gn2"]) < 1e-3, r


def test_elastic_reshard_restore(tmp_path):
    code = COMMON + textwrap.dedent("""
        from repro.train.checkpoint import restore_checkpoint, \
            save_checkpoint
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        model, opt, step, state, batch = setup(mesh_a)
        st_shard_a = state_shardings(mesh_a, model, opt)
        state = jax.device_put(state, st_shard_a)
        s1, _ = jax.jit(step)(state, batch)
        path = save_checkpoint(CKPT_DIR, 1, s1)

        # restore onto a DIFFERENT mesh layout (grow model, shrink data)
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        model_b, opt_b, step_b, state_b, batch_b = setup(mesh_b)
        st_shard_b = state_shardings(mesh_b, model_b, opt_b)
        restored = restore_checkpoint(path, jax.eval_shape(lambda: s1),
                                      shardings=st_shard_b)
        s2, m2 = jax.jit(step_b)(restored, batch_b)
        import numpy as np
        same = all(np.allclose(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree.leaves(jax.device_get(s1.params)),
                                   jax.tree.leaves(jax.device_get(
                                       restored.params))))
        print("RESULT " + json.dumps({
            "params_equal": bool(same), "loss_after": float(m2["loss"])}))
    """)
    code = f"CKPT_DIR = {str(tmp_path)!r}\n" + code
    r = run_sub(code)
    assert r["params_equal"], r
    assert r["loss_after"] > 0


def test_bf16_master_params_cut_wire_bytes():
    """In-graph f32→bf16 casting does NOT reduce collective bytes (XLA puts
    the convert after the gather — a refuted hypothesis recorded in §Perf);
    bf16 *live* params with an fp32 master in the optimizer state do."""
    code = COMMON + textwrap.dedent("""
        from repro.launch.hlo_analysis import analyze_hlo
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        outs = {}
        for pd, master in (("float32", False), ("bfloat16", True)):
            cfg = get_smoke_config("qwen3-0.6b").replace(
                dtype="bfloat16", param_dtype=pd)
            model = build_model(cfg)
            b, s = 8, 16
            plan = model.build_plan(b, s, mode="train")
            fwd = plan_and_compile(plan, CATALOG, syscat_for_mesh(mesh),
                                   mesh=mesh)
            opt = make_optimizer("adamw", cosine_schedule(1e-3, 2, 100),
                                 master=master)
            step = make_train_step(fwd, opt, grad_dtype="float32")
            params, _ = model.init_params(jax.random.key(0))
            state = init_state(params, opt)
            dc = DataConfig(vocab=cfg.vocab, seq_len=s, global_batch=b)
            batch = {k: jnp.asarray(v)
                     for k, v in synth_batch(dc, 0).items()}
            st_shard = state_shardings(mesh, model, opt)
            in_shard = input_shardings(mesh, {k: jax.ShapeDtypeStruct(
                v.shape, v.dtype) for k, v in batch.items()})
            comp = jax.jit(step, in_shardings=(st_shard, in_shard),
                           out_shardings=(st_shard, None)).lower(
                jax.eval_shape(lambda: state),
                {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in batch.items()}).compile()
            outs[pd] = analyze_hlo(comp.as_text())["wire_bytes"]
        # live-param bytes (what FSDP gathers move on TPU) halve with bf16
        cfg32 = get_smoke_config("qwen3-0.6b").replace(param_dtype="float32")
        cfg16 = get_smoke_config("qwen3-0.6b").replace(param_dtype="bfloat16")
        import numpy as np
        def pbytes(c):
            m = build_model(c)
            return sum(np.prod(l.shape) * l.dtype.itemsize
                       for l in jax.tree.leaves(m.abstract_params()))
        outs["pbytes_f32"] = float(pbytes(cfg32))
        outs["pbytes_bf16"] = float(pbytes(cfg16))
        print("RESULT " + json.dumps(outs))
    """)
    r = run_sub(code)
    # REFUTED on CPU: XLA's CPU backend legalizes bf16 dots to f32, hoisting
    # the convert *before* the FSDP all-gather, so HLO wire bytes do not
    # shrink here (they do on TPU, where the MXU consumes bf16 natively).
    # The mechanism is still pinned down: live-param bytes — exactly what
    # the per-layer FSDP gathers move — halve.
    assert r["bfloat16"] <= r["float32"] * 1.01, r
    assert r["pbytes_bf16"] < 0.55 * r["pbytes_f32"], r
