"""ADIL-style analysis builder (paper §2) and the elastic re-mesh helper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adil import Analysis
from repro.core.ir import (SystemCatalog, TensorT, ValidationError,
                           standard_catalog)
from repro.launch.elastic import largest_mesh_shape, min_model_axis
from repro.layers import attention as A
from repro.layers import mlp as F
from repro.layers.common import KeyGen

CAT = standard_catalog()
SYS = SystemCatalog()


def test_analysis_builds_validates_and_runs(rng):
    b, s, e = 2, 16, 32
    with Analysis("demo", CAT) as a:
        toks = a.input("tokens", TensorT((b, s), "int32", ("batch", "seq")))
        h = a.op("embed", toks, vocab=64, embed=e, pp=("embed",),
                 dtype="float32")
        h = a.op("attention", h, heads=4, kv_heads=2, head_dim=8, embed=e,
                 pp=("attn",))
        h = a.op("mlp", h, ffn=64, embed=e, pp=("mlp",))
        a.store(h)
    fn = a.compile(SYS)
    kg = KeyGen(jax.random.key(0))
    params = {
        "embed": {"table": jax.random.normal(kg(), (64, e)) * 0.02},
        "attn": A.init_attention(kg, {"embed": e, "heads": 4, "kv_heads": 2,
                                      "head_dim": 8})[0],
        "mlp": F.init_mlp(kg, {"embed": e, "ffn": 64})[0],
    }
    toks = jnp.asarray(rng.randint(0, 64, (b, s)), jnp.int32)
    out = fn(params, {"tokens": toks})
    assert out.shape == (b, s, e)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_analysis_eager_validation():
    """Each assignment type-checks immediately (design decision 5)."""
    a = Analysis("bad", CAT)
    x = a.input("x", TensorT((2, 8), "float32", ("batch", "seq")))
    with pytest.raises(ValidationError):
        a.op("embed", x, vocab=64, embed=32)   # float ids rejected at once


def test_analysis_requires_store():
    with pytest.raises(ValidationError):
        with Analysis("nostore", CAT) as a:
            a.input("x", TensorT((2, 8), "int32", ("batch", "seq")))


def test_analysis_var_types_inspectable():
    a = Analysis("t", CAT)
    x = a.input("x", TensorT((2, 8), "int32", ("batch", "seq")))
    h = a.op("embed", x, vocab=64, embed=32, pp=("e",))
    assert h.type.shape == (2, 8, 32)


# --------------------------------------------------------------------------
# elastic re-mesh policy
# --------------------------------------------------------------------------

def test_largest_mesh_shape_shrinks_gracefully():
    assert largest_mesh_shape(512, prefer_model=16) == (32, 16)
    assert largest_mesh_shape(256, prefer_model=16) == (16, 16)
    # lost a host: 248 devices -> keep model=16, data=15
    assert largest_mesh_shape(248, prefer_model=16) == (15, 16)
    # tiny survivor set: model axis caps at the device count
    assert largest_mesh_shape(8, prefer_model=16, min_model=4) == (1, 8)


def test_min_model_axis_covers_params():
    # 27B fp32 params with 3x optimizer overhead on 16GB chips
    m = min_model_axis(27e9 * 4, hbm_bytes=16e9)
    assert m >= 16 and (m & (m - 1)) == 0


# --------------------------------------------------------------------------
# textual ADIL front end (paper §2 grammar)
# --------------------------------------------------------------------------

SCRIPT = """
USE demoDB;
create analysis tiny as {
  toks := input([2, 16], int32, dims=[batch, seq]);
  h    := embed(toks, vocab=64, embed=32, pp=[embed], dtype=float32);
  h2   := attention(h, heads=4, kv_heads=2, head_dim=8, embed=32, pp=[attn]);
  out  := mlp(h2, ffn=64, embed=32, pp=[mlp]);
  store(out);
}
"""


def test_parse_adil_builds_equivalent_plan(rng):
    from repro.core.adil_parser import parse_adil
    a = parse_adil(SCRIPT, CAT)
    fn = a.compile(SYS)
    kg = KeyGen(jax.random.key(0))
    params = {
        "embed": {"table": jax.random.normal(kg(), (64, 32)) * 0.02},
        "attn": A.init_attention(kg, {"embed": 32, "heads": 4, "kv_heads": 2,
                                      "head_dim": 8})[0],
        "mlp": F.init_mlp(kg, {"embed": 32, "ffn": 64})[0],
    }
    toks = jnp.asarray(rng.randint(0, 64, (2, 16)), jnp.int32)
    out = fn(params, {"toks": toks})
    assert out.shape == (2, 16, 32)

    # equivalence with the embedded DSL: same plan structure
    with Analysis("tiny", CAT) as b:
        t = b.input("toks", TensorT((2, 16), "int32", ("batch", "seq")))
        h = b.op("embed", t, vocab=64, embed=32, pp=("embed",),
                 dtype="float32")
        h = b.op("attention", h, heads=4, kv_heads=2, head_dim=8, embed=32,
                 pp=("attn",))
        h = b.op("mlp", h, ffn=64, embed=32, pp=("mlp",))
        b.store(h)
    ops_script = [n.op for n in a.plan.topo()]
    ops_dsl = [n.op for n in b.plan.topo()]
    assert ops_script == ops_dsl


def test_parse_adil_rejects_bad_scripts():
    from repro.core.adil_parser import parse_adil
    with pytest.raises(ValidationError):
        parse_adil("USE x; create analysis a as { store(y); }", CAT)
    with pytest.raises(ValidationError):
        parse_adil("USE x; create analysis a as { }", CAT)
    with pytest.raises(ValidationError):      # type error caught at parse
        parse_adil("""
USE x; create analysis a as {
  t := input([2, 4], float32, dims=[batch, seq]);
  h := embed(t, vocab=8, embed=4, pp=[e]);
  store(h);
}""", CAT)
