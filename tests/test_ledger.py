"""Resource ledger + continuous telemetry: byte accounting with
predicted-vs-actual deltas, leak detection over lifetime anchors, the
byte-budget plan-cache eviction order, the flight recorder's ring bounds
and dump triggers, gauge/counter registry semantics, KV-pool occupancy /
fragmentation gauges, and the recorder overhead guard."""
import json
import os
from types import SimpleNamespace

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core.adil import Analysis
from repro.core.cost_model import predicted_resident_bytes
from repro.core.ir import SystemCatalog, standard_catalog
from repro.core.ledger import (FlightRecorder, MemoryLedger, default_ledger,
                               register_store_payload)
from repro.core.plan_cache import PlanCache, staged_bytes
from repro.models import build_model
from repro.serving.kv_pool import PagedKVPool
from repro.serving.metrics import MetricsRegistry
from repro.stores import ColumnStore, GraphStore, TextStore, store_engines

CAT = standard_catalog()
SYS = SystemCatalog()


# --------------------------------------------------------------------------
# MemoryLedger: register / replace / release / transient accounting
# --------------------------------------------------------------------------


def test_register_release_totals():
    led = MemoryLedger()
    led.register(("a", "1"), nbytes=100, kind="x")
    led.register(("b", "1"), nbytes=50, kind="y")
    assert led.total_bytes() == 150
    assert led.bytes_for_kind("x") == 100
    assert led.bytes_for_kind("y") == 50
    assert led.release(("a", "1")) == 100
    assert led.total_bytes() == 50
    assert led.release(("a", "1")) == 0          # double release is a no-op


def test_register_value_uses_tree_bytes():
    led = MemoryLedger()
    arr = jnp.zeros(256, jnp.float32)
    e = led.register("arr", {"x": arr})
    assert e.nbytes == 1024
    assert led.total_bytes() == 1024


def test_same_owner_reregistration_replaces():
    led = MemoryLedger()
    led.register(("store", "s1"), nbytes=1000, kind="col")
    led.register(("store", "s1"), nbytes=400, kind="col")   # append→rebuild
    assert led.total_bytes() == 400                         # old bytes freed
    assert led.bytes_for_kind("col") == 400
    assert len(led.entries()) == 1
    assert led.peak_bytes == 1000                           # high-water mark


def test_transient_counts_toward_peak_not_resident():
    led = MemoryLedger()
    led.register("resident", nbytes=100)
    led.note_transient("shuffle", 900, kind="shuffle_buckets")
    assert led.total_bytes() == 100          # scratch is not resident
    assert led.peak_bytes == 1000            # but it is part of the peak
    assert led.transient_bytes == 900
    snap = led.snapshot()
    assert snap["total_bytes"] == 100 and snap["peak_bytes"] == 1000


def test_predicted_vs_actual_ratio():
    led = MemoryLedger()
    led.register("p", nbytes=150, predicted=100)
    led.register("q", nbytes=80)             # no prediction -> not listed
    rows = led.predicted_vs_actual()
    assert len(rows) == 1
    entry, pred, act, ratio = rows[0]
    assert (pred, act) == (100, 150) and ratio == pytest.approx(1.5)
    assert "predicted 0.00 MB" in led.report()


# --------------------------------------------------------------------------
# leak detection: tied_to + version anchors
# --------------------------------------------------------------------------


def test_leak_on_evicted_anchor():
    led = MemoryLedger()
    led.register(("plan_cache", "p1"), nbytes=10, kind="plan_cache")
    led.register(("plan_jit", "p1"), nbytes=0, kind="plan_jit",
                 tied_to=("plan_cache", "p1"))
    assert led.leaks() == []
    led.release(("plan_cache", "p1"))        # cache evicts, jit entry stays
    leaks = led.leaks()
    assert len(leaks) == 1
    reason, entry = leaks[0]
    assert reason == "evicted" and entry.owner == ("plan_jit", "p1")


def test_leak_on_superseded_version():
    led = MemoryLedger()
    led.register(("col", "s"), nbytes=100, kind="col", version=3)
    led.register(("pin", "c"), nbytes=100, kind="pin",
                 tied_to=("col", "s"), version=3)
    assert led.leaks() == []
    # store appends: same owner re-registers at a newer version
    led.register(("col", "s"), nbytes=120, kind="col", version=4)
    leaks = led.leaks()
    assert len(leaks) == 1
    reason, entry = leaks[0]
    assert reason == "superseded" and entry.owner == ("pin", "c")
    assert "LEAK (superseded)" in led.report()
    assert led.snapshot()["leaks"] == 1


def test_publish_sets_registry_gauges():
    led = MemoryLedger()
    led.register("a", nbytes=300, kind="col")
    reg = MetricsRegistry()
    led.publish(reg)
    assert reg.gauges["ledger.total_bytes"].value == 300
    assert reg.gauges["ledger.col_bytes"].value == 300


# --------------------------------------------------------------------------
# store payload() registration + cost-model predictions
# --------------------------------------------------------------------------


def test_store_payloads_register_with_predictions():
    rng = np.random.RandomState(0)
    table = ColumnStore({"a": np.arange(100, dtype=np.int32),
                         "v": rng.rand(100).astype(np.float32)})
    e = rng.randint(0, 64, (2, 500))
    graph = GraphStore.from_edges(e[0], e[1], 64)
    corpus = TextStore.from_docs(
        [rng.randint(0, 32, 5) for _ in range(20)], 32)
    led = default_ledger()
    for store, kind in ((table, "column_store"), (graph, "graph_store"),
                        (corpus, "text_store")):
        store.payload()
        entry = led.get((kind, f"{id(store):#x}"))
        assert entry is not None and entry.nbytes > 0
        assert entry.predicted and entry.predicted > 0
        assert entry.version == getattr(store, "version", 0)


def test_store_append_reregisters_same_owner():
    led = default_ledger()
    cs = ColumnStore({"a": np.arange(64, dtype=np.int32)})
    cs.payload()
    owner = ("column_store", f"{id(cs):#x}")
    before = led.get(owner).nbytes
    cs.append({"a": np.arange(64, dtype=np.int32)})
    cs.payload()
    after = led.get(owner).nbytes
    assert after > before
    # one entry per store: replaced, not accumulated
    assert sum(1 for e in led.entries("column_store")
               if e.owner == owner) == 1


def test_predicted_resident_bytes_shapes():
    rng = np.random.RandomState(0)
    table = ColumnStore({"a": np.arange(100, dtype=np.int32)})
    graph = GraphStore.from_edges(*rng.randint(0, 64, (2, 500)), 64)
    corpus = TextStore.from_docs(
        [rng.randint(0, 32, 5) for _ in range(20)], 32)
    for store in (table, graph, corpus):
        pred = predicted_resident_bytes(store.type)
        assert isinstance(pred, int) and pred > 0


# --------------------------------------------------------------------------
# plan cache: byte budget + stale-first-then-largest eviction
# --------------------------------------------------------------------------


def _staged(nbytes):
    return SimpleNamespace(nbytes=nbytes)


def test_staged_bytes_honors_explicit_nbytes():
    assert staged_bytes(_staged(12345)) == 12345
    assert staged_bytes("opaque") == 1024        # unwalkable -> fallback


def test_byte_budget_evicts_largest_first():
    led = MemoryLedger()
    pc = PlanCache(maxsize=10, byte_budget=500, ledger=led)
    pc.insert("a", _staged(400))
    pc.insert("b", _staged(90))
    assert pc.bytes_in_cache == 490 and led.total_bytes() == 490
    pc.insert("c", _staged(300))                 # 790 > 500
    # largest entry sheds first (not the coldest): a(400), not b(90)
    assert "a" not in pc and "b" in pc and "c" in pc
    assert pc.bytes_in_cache == 390
    assert pc.byte_evictions == 1
    assert led.get(("plan_cache", "a")) is None  # ledger entry released
    assert led.total_bytes() == 390


def test_byte_budget_evicts_stale_before_largest():
    pc = PlanCache(maxsize=10, byte_budget=600, ledger=MemoryLedger())
    pc.insert("old", _staged(50), fingerprint="fit1")
    pc.note_fingerprint("fit2")                  # calibration moved on
    pc.insert("big", _staged(400), fingerprint="fit2")
    pc.insert("new", _staged(200), fingerprint="fit2")   # 650 > 600
    # the stale entry goes first even though it is the smallest
    assert "old" not in pc and "big" in pc and "new" in pc
    assert pc.stale_evictions == 1 and pc.byte_evictions == 1


def test_byte_budget_never_evicts_the_just_inserted_entry():
    pc = PlanCache(maxsize=10, byte_budget=100, ledger=MemoryLedger())
    pc.insert("huge", _staged(1000))             # alone over budget: kept
    assert "huge" in pc and len(pc) == 1
    pc.insert("huge2", _staged(900))             # newest survives instead
    assert "huge2" in pc and "huge" not in pc
    assert len(pc) == 1


def test_plan_cache_clear_releases_ledger():
    led = MemoryLedger()
    pc = PlanCache(maxsize=4, byte_budget=None, ledger=led)
    pc.insert("a", _staged(100))
    pc.insert("b", _staged(200))
    assert led.total_bytes() == 300
    st = pc.stats()
    assert st["bytes"] == 300 and st["byte_budget"] is None
    pc.clear()
    assert led.total_bytes() == 0 and pc.bytes_in_cache == 0


def test_reinsert_same_plan_does_not_double_count():
    led = MemoryLedger()
    pc = PlanCache(maxsize=4, ledger=led)
    pc.insert("a", _staged(100))
    pc.insert("a", _staged(250))
    assert pc.bytes_in_cache == 250 and led.total_bytes() == 250


# --------------------------------------------------------------------------
# flight recorder: ring bounds + dump triggers
# --------------------------------------------------------------------------


def test_ring_bounds_and_drop_count():
    rec = FlightRecorder(capacity=4)
    for i in range(10):
        rec.record("tick", {"i": i})
    assert len(rec) == 4
    assert rec.dropped == 6
    assert [ev.payload["i"] for ev in rec.events()] == [6, 7, 8, 9]
    assert [ev.seq for ev in rec.events()] == [7, 8, 9, 10]


def test_capacity_must_be_positive():
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)


def test_trip_without_dump_dir_returns_records():
    rec = FlightRecorder(capacity=8)
    rec.record("tick", {"i": 1})
    records = rec.trip("overflow", {"site": "x"})
    assert records[0]["record"] == "flight_dump"
    assert records[0]["reason"] == "overflow"
    assert records[0]["events"] == 1
    assert rec.trips == [("overflow", None)]
    # the trip itself lands in the ring so a later dump shows it
    assert rec.events()[-1].kind == "trip"


def test_trip_with_dump_dir_writes_jsonl(tmp_path):
    rec = FlightRecorder(capacity=8, dump_dir=str(tmp_path))
    for i in range(3):
        rec.record("tick", {"i": i})
    path = rec.trip("executor_error", {"error": "boom"})
    assert os.path.basename(path) == "flight_000_executor_error.jsonl"
    lines = [json.loads(ln) for ln in open(path)]
    assert lines[0]["record"] == "flight_dump"
    assert lines[0]["detail"] == {"error": "boom"}
    assert [ln["payload"]["i"] for ln in lines[1:]] == [0, 1, 2]
    # second trip gets its own numbered file
    path2 = rec.trip("overflow")
    assert os.path.basename(path2) == "flight_001_overflow.jsonl"


def test_forced_overflow_trips_the_recorder(tmp_path):
    """A bounded join whose capacity cannot hold the matches must trip the
    recorder through PlannedFunction.analyze."""
    rng = np.random.RandomState(0)
    nodes, rows = 8, 64
    dims = ColumnStore({"tag": np.arange(nodes, dtype=np.int32)})
    facts = ColumnStore({"tag": rng.randint(0, nodes, rows).astype(np.int32),
                         "v": rng.rand(rows).astype(np.float32)})
    with Analysis("flight_ovf", CAT) as a:
        dm = a.bind("dims", dims)
        fc = a.bind("facts", facts)
        bj = a.op("bounded_join", dm, fc, left_on="tag", right_on="tag",
                  capacity=8)                    # 64 matches cannot fit
        a.store(bj)
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    rec = FlightRecorder(capacity=16, dump_dir=str(tmp_path))
    fn.analyze({}, {"dims": dims.payload(), "facts": facts.payload()},
               recorder=rec)
    reasons = [r for r, _ in rec.trips]
    assert "overflow" in reasons
    dumps = sorted(os.listdir(tmp_path))
    assert any("overflow" in d for d in dumps)
    # the ring holds the run-trace summary that preceded the trip
    kinds = [ev.kind for ev in rec.events()]
    assert "run_trace" in kinds


def test_executor_error_trips_the_recorder():
    cs = ColumnStore({"a": np.arange(16, dtype=np.int32)})
    with Analysis("flight_err", CAT) as a:
        t = a.op("rel_scan", a.bind("t", cs))
        a.store(a.op("col_tensor",
                     a.op("rel_group_agg", t, key="a", num_groups=16,
                          aggs=(("s", "sum", "a"),)),
                     col="s", dim="nodes"))
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    rec = FlightRecorder(capacity=8)
    with pytest.raises(Exception):
        fn.analyze({}, {"t": None}, recorder=rec)    # unusable input payload
    assert [r for r, _ in rec.trips] == ["executor_error"]


def test_record_trace_summarizes_run(tmp_path):
    cs = ColumnStore({"a": np.arange(32, dtype=np.int32)})
    with Analysis("flight_trace", CAT) as a:
        t = a.op("rel_scan", a.bind("t", cs))
        a.store(a.op("col_tensor",
                     a.op("rel_group_agg", t, key="a", num_groups=32,
                          aggs=(("s", "count", None),)),
                     col="s", dim="nodes"))
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    rec = FlightRecorder(capacity=8)
    fn.analyze({}, {"t": cs.payload()}, recorder=rec)
    ev = next(e for e in rec.events() if e.kind == "run_trace")
    assert ev.payload["plan_id"] == fn.plan_id
    assert ev.payload["wall_ms"] >= 0.0
    assert ev.payload["spans"] > 0


def test_recorder_overhead_within_5_percent():
    """The recorder rides on an already-traced run: its marginal cost (one
    ring append per run) must stay inside the tracing suite's 5% bar."""
    import time

    from test_tracing import compile_rollup
    planned, inputs = compile_rollup(tweets=200_000, hashtags=1024,
                                     metrics=4)
    rec = FlightRecorder(capacity=16)
    planned.analyze({}, inputs)
    planned.analyze({}, inputs, recorder=rec)
    t_plain = t_rec = float("inf")
    for _ in range(10):                      # interleaved min-of-N
        t0 = time.perf_counter()
        jax.block_until_ready(planned.analyze({}, inputs))
        t_plain = min(t_plain, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(planned.analyze({}, inputs, recorder=rec))
        t_rec = min(t_rec, time.perf_counter() - t0)
    overhead = t_rec / t_plain - 1.0
    assert overhead <= 0.05, (
        f"recorded run {t_rec * 1e3:.2f} ms vs plain traced "
        f"{t_plain * 1e3:.2f} ms: overhead {overhead:+.1%} > 5%")


# --------------------------------------------------------------------------
# gauge / counter semantics in the shared registry
# --------------------------------------------------------------------------


def test_gauge_set_inc_dec_peak_trough():
    reg = MetricsRegistry()
    g = reg.gauge("queue_depth")
    assert reg.gauge("queue_depth") is g         # stable identity
    g.set(5)
    g.inc(3)
    g.dec(6)
    assert g.value == 2.0
    assert g.peak == 8.0 and g.trough == 2.0
    snap = g.snapshot()
    assert snap == {"value": 2.0, "peak": 8.0, "trough": 2.0, "updates": 3}


def test_fresh_gauge_snapshot_is_zeroed():
    snap = MetricsRegistry().gauge("x").snapshot()
    assert snap["value"] == 0.0 and snap["peak"] == 0.0
    assert snap["trough"] == 0.0 and snap["updates"] == 0


def test_counter_is_monotone_and_shares_the_plain_dict():
    reg = MetricsRegistry()
    c = reg.counter("joins")
    c.inc()
    c.inc(4)
    assert c.value == 5
    assert reg.counters["joins"] == 5            # back-compat plain dict
    reg.count("joins")                           # legacy path still works
    assert c.value == 6
    with pytest.raises(ValueError):
        c.inc(-1)


def test_registry_snapshot_and_report_cover_gauges():
    reg = MetricsRegistry()
    reg.gauge("ledger.total_bytes").set(1234)
    reg.counter("evictions").inc(2)
    snap = reg.snapshot()
    assert snap["gauges"]["ledger.total_bytes"]["value"] == 1234.0
    assert snap["counters"]["evictions"] == 2
    rep = reg.report()
    assert "ledger.total_bytes" in rep and "evictions" in rep


# --------------------------------------------------------------------------
# KV pool: occupancy / fragmentation gauges + ledger registration
# --------------------------------------------------------------------------


def _smoke_model():
    cfg = get_smoke_config("qwen3-0.6b").replace(dtype="float32")
    model = build_model(cfg)
    return model


def test_kv_pool_fragmentation_and_gauges():
    reg = MetricsRegistry()
    pool = PagedKVPool(_smoke_model(), n_slots=4, max_seq=32, page_size=8,
                       registry=reg)
    assert pool.pages_per_slot == 4
    frag = pool.fragmentation()
    assert frag == {"free_pages": 16, "free_slots": 4,
                    "max_contig_free_run": 16}
    pool.alloc("r1", 10)                         # slot 0, 2 pages
    pool.alloc("r2", 32)                         # slot 1, 4 pages (full)
    frag = pool.fragmentation()
    assert frag["free_pages"] == 10
    assert frag["free_slots"] == 2
    # slot 0's free tail (2) is walled off by slot 1's full occupancy;
    # slots 2+3 form the longest free run
    assert frag["max_contig_free_run"] == 8
    assert reg.gauges["kv.free_pages"].value == 10
    assert reg.gauges["kv.free_slots"].value == 2
    assert reg.gauges["kv.max_contig_free_run"].value == 8
    assert reg.gauges["kv.fill"].value == pytest.approx(6 / 16)
    pool.free("r2")
    # freeing restores run contiguity and records the lifetime footprint
    assert pool.fragmentation()["max_contig_free_run"] == 14
    assert reg.summary("kv.pages_per_request").count == 1
    assert reg.summary("kv.pages_per_request").max == 4.0


def test_kv_pool_budget_caps_the_free_run():
    pool = PagedKVPool(_smoke_model(), n_slots=4, max_seq=32, page_size=8,
                       page_budget=6)
    # geometric free space is 16 pages but the budget admits only 6
    assert pool.fragmentation() == {"free_pages": 6, "free_slots": 4,
                                    "max_contig_free_run": 6}


def test_kv_pool_registers_its_one_allocation():
    led = MemoryLedger()
    pool = PagedKVPool(_smoke_model(), n_slots=2, max_seq=32, page_size=8,
                       ledger=led)
    entry = led.get(("kv_pool", f"{id(pool):#x}"))
    assert entry is not None and entry.kind == "kv_pool"
    assert entry.nbytes > 0
    assert led.bytes_for_kind("kv_pool") == entry.nbytes
