"""Fault tolerance: deterministic injection, the ExecError taxonomy,
retry/backoff, circuit-breaker blocklists that provably re-plan, serving
deadlines/cancellation with zero KV leaks, and degraded-mode replanning."""
import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.adil import Analysis
from repro.core.executor import ExecContext, plan_and_compile
from repro.core.faults import FaultInjectedError, FaultInjector
from repro.core.ir import SystemCatalog, TensorT, standard_catalog
from repro.core.ledger import FlightRecorder, MemoryLedger
from repro.core.plan_cache import PlanCache
from repro.core.resilience import (CircuitBreaker, ExecError,
                                   ResilientExecutor, RetryPolicy, classify,
                                   degrade_options, fallback_class)
from repro.core.rewrite import DEFAULT_PIPELINE
from repro.models import build_model
from repro.serving import (AsyncServingRuntime, DegradePolicy, ServeRequest,
                           ServeResult)
from repro.stores import (ColumnStore, GraphStore, TextStore, store_engines)

CAT = standard_catalog()
SYS = SystemCatalog()
# keep compaction as standalone physical nodes (named fault sites) instead
# of steps folded into fused rel chains
NOFUSE_PIPELINE = tuple(p for p in DEFAULT_PIPELINE if p != "fuse_store_ops")


# --------------------------------------------------------------------------
# fault injector: determinism, spec parsing, site filters
# --------------------------------------------------------------------------

def _drive(fi, n=40):
    for i in range(n):
        try:
            fi.check(("node", f"n{i % 8}", "impl_x"))
        except FaultInjectedError:
            pass
    return fi.schedule()


def test_fault_injector_same_seed_same_schedule():
    a = _drive(FaultInjector(seed=7, rate=0.3))
    b = _drive(FaultInjector(seed=7, rate=0.3))
    assert a and a == b
    # reset() replays the identical schedule on the same instance
    fi = FaultInjector(seed=7, rate=0.3)
    first = _drive(fi)
    fi.reset()
    assert _drive(fi) == first


def test_fault_injector_different_seed_different_schedule():
    a = _drive(FaultInjector(seed=7, rate=0.3))
    b = _drive(FaultInjector(seed=8, rate=0.3))
    assert a != b


def test_fault_injector_occurrence_keyed():
    """The n-th execution of a site is an independent decision: a site that
    faults on occurrence 0 can pass on occurrence 1 (what makes bounded
    retries converge under rate-based injection)."""
    fi = FaultInjector(seed=0, rate=0.5)
    outcomes = []
    for _ in range(16):
        try:
            fi.check(("node", "same_site", "impl"))
            outcomes.append(False)
        except FaultInjectedError:
            outcomes.append(True)
    assert True in outcomes and False in outcomes
    # and the pure decision function agrees with what happened
    assert outcomes == [fi.would_fail(("node", "same_site", "impl"), i)
                       for i in range(16)]


def test_fault_injector_spec_and_filters():
    fi = FaultInjector.from_spec("seed=3,rate=0.25,max_faults=2")
    assert fi.seed == 3 and fi.rate == 0.25 and fi.max_faults == 2
    with pytest.raises(ValueError):
        FaultInjector.from_spec("seed=1,bogus=2")
    with pytest.raises(ValueError):
        FaultInjector(rate=1.5)
    # category filter: only named categories raise
    fi = FaultInjector(seed=0, rate=1.0, categories=("prefill",))
    fi.check(("node", "n0", "impl"))           # not in categories: passes
    with pytest.raises(FaultInjectedError):
        fi.check(("prefill", "r1", 16))
    # always_fail matches site substrings regardless of rate
    fi = FaultInjector(seed=0, rate=0.0, always_fail=("compact",))
    with pytest.raises(FaultInjectedError):
        fi.check(("node", "compact_filter_3", "compact_gather_xla"))
    fi.check(("node", "rel_filter_1", "rel_filter_mask"))
    # max_faults budget: after it is spent, even always_fail sites pass
    fi = FaultInjector(seed=0, always_fail=("x",), max_faults=1)
    with pytest.raises(FaultInjectedError):
        fi.check(("node", "x1", "i"))
    fi.check(("node", "x1", "i"))


def test_fault_injector_stall_sleeps_instead_of_raising():
    slept = []
    fi = FaultInjector(seed=0, rate=1.0, stall_s=0.01, sleep=slept.append)
    fi.check(("admission", "r1"))              # stall category: no raise
    assert slept == [0.01]
    assert fi.schedule()[0][0] == "stall"


# --------------------------------------------------------------------------
# taxonomy + retry policy
# --------------------------------------------------------------------------

def test_classify_taxonomy():
    inj = classify(FaultInjectedError(("node", "n1", "sdpa_xla"), 0))
    assert inj.retryable
    fatal = classify(ValueError("bad shape"), plan_id="p1")
    assert not fatal.retryable and fatal.plan_id == "p1"
    transient = classify(RuntimeError("xla backend blew up"))
    assert transient.retryable
    # passthrough: an ExecError classifies as itself
    e = ExecError("x", retryable=False)
    assert classify(e) is e
    d = classify(ValueError("v"), engine="pallas").to_dict()
    assert d["engine"] == "pallas" and d["retryable"] is False


def test_fallback_class_mapping():
    assert fallback_class(ExecError("e", engine="pallas")) == "pallas"
    assert fallback_class(ExecError("e", impl="moe_gmm_pallas")) == "pallas"
    assert fallback_class(ExecError("e", impl="xfer_replicate")) == "sharded"
    assert fallback_class(ExecError("e", impl="compact_gather_xla")) == \
        "compacted"
    assert fallback_class(ExecError("e", impl="rel_filter_mask")) is None


def test_retry_policy_deterministic_backoff_and_deadline():
    p = RetryPolicy(max_attempts=3, base_backoff_s=0.01, jitter=0.25, seed=5)
    a = [p.backoff_s(i) for i in (1, 2, 3)]
    b = [RetryPolicy(max_attempts=3, base_backoff_s=0.01, jitter=0.25,
                     seed=5).backoff_s(i) for i in (1, 2, 3)]
    assert a == b                              # deterministic jitter
    assert a[0] != 0.01                        # jitter actually applied
    err = ExecError("e", retryable=True)
    assert p.should_retry(err, 1)
    assert not p.should_retry(err, 3)          # attempts exhausted
    assert not p.should_retry(ExecError("e", retryable=False), 1)
    # the next backoff must fit inside the deadline
    assert not p.should_retry(err, 1, elapsed_s=0.999, deadline_s=1.0)
    assert p.should_retry(err, 1, elapsed_s=0.0, deadline_s=10.0)


def test_degrade_options_structural_fallbacks():
    engines = ("xla", "rel", "graph", "text", "pallas")
    pipeline = ("decompose", "cse", "choose_compaction", "place_xfers",
                "shard_stores")
    e2, p2 = degrade_options(engines, pipeline, ("pallas",))
    assert "pallas" not in e2 and p2 == pipeline
    e3, p3 = degrade_options(engines, pipeline, ("sharded", "compacted"))
    assert e3 == engines
    assert "shard_stores" not in p3 and "choose_compaction" not in p3
    assert degrade_options(engines, pipeline, ()) == (engines, pipeline)


def test_circuit_breaker_opens_and_half_opens():
    t = [0.0]
    br = CircuitBreaker(threshold=2, cooldown_s=10.0, clock=lambda: t[0])
    err = ExecError("e", engine="pallas")
    assert br.record_failure("p1", err) is None      # 1 of 2
    assert br.record_failure("p1", err) == "pallas"  # trips open
    assert br.is_open("p1", "pallas")
    assert br.blocklist("p1") == ("pallas",)
    assert br.blocklist("p2") == ()                  # per-plan isolation
    assert br.fingerprint("p1") == ("blocklist", "pallas")
    t[0] = 11.0                                      # cooldown expired
    assert not br.is_open("p1", "pallas")            # half-open probe
    assert br.blocklist("p1") == ()
    br.record_success("p1")                          # probe succeeded
    assert ("close", "p1", "pallas") in br.events


# --------------------------------------------------------------------------
# executor fault path (analytical tri-store plans run eagerly)
# --------------------------------------------------------------------------

def _stores(rng, rows=400, nodes=64, vocab=32):
    table = ColumnStore({
        "hashtag": rng.randint(0, nodes, rows).astype(np.int32),
        "doc": np.arange(rows, dtype=np.int32),
        "ts": np.arange(rows, dtype=np.int32),
        "engagement": (rng.rand(rows) * 50).astype(np.float32),
    })
    e = rng.randint(0, nodes, (2, 300))
    graph = GraphStore.from_edges(e[0], e[1], nodes, symmetric=True)
    corpus = TextStore.from_docs(
        [rng.randint(0, vocab, rng.randint(2, 8)) for _ in range(rows)],
        vocab)
    return table, graph, corpus


def _tri_analysis(table, graph, corpus, *, selectivity=0.05, k=16,
                  iters=3):
    rows, nodes = table.rows, graph.n_nodes
    cut = int(rows * (1 - selectivity))
    with Analysis("resil", CAT) as a:
        tw = a.bind("tweets", table)
        gr = a.bind("g", graph)
        cx = a.bind("cx", corpus)
        q = a.input("q", TensorT((corpus.vocab,), "float32", ("vocab",)))
        t = a.op("rel_scan", tw)
        recent = a.op("rel_filter", t, col="ts", cmp="ge", value=cut,
                      selectivity=selectivity)
        m = a.op("sel_mask", recent, col="doc", size=corpus.n_docs)
        sc = a.op("text_scores", cx, q)
        hits = a.op("masked_topk", sc, m, k=k)
        j = a.op("rel_join", recent, hits, left_on="doc", right_on="doc")
        trel = a.op("rel_group_agg", j, key="hashtag", num_groups=nodes,
                    aggs=(("textrel", "sum", "score"),))
        seeds = a.op("rel_group_agg", recent, key="hashtag",
                     num_groups=nodes, aggs=(("seed", "count", None),))
        sv = a.op("col_tensor", seeds, col="seed", dim="nodes")
        pr = a.op("graph_pagerank", gr, sv, iters=iters)
        tv = a.op("col_tensor", trel, col="textrel", dim="nodes")
        a.store(a.op("residual_add", pr, tv))
    return a


def _inputs(table, graph, corpus, terms=(1, 2, 3)):
    return {"tweets": table.payload(), "g": graph.payload(),
            "cx": corpus.payload(),
            "q": jnp.asarray(corpus.query_vector(terms))}


def test_faulted_path_zero_rate_is_bitwise_identical(rng):
    """A wired-but-silent injector must not change results: the faulted
    executor path is the fast path plus checks, nothing else."""
    table, graph, corpus = _stores(rng)
    a = _tri_analysis(table, graph, corpus)
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    ins = _inputs(table, graph, corpus)
    base = np.asarray(fn({}, ins))
    fn.faults = FaultInjector(seed=0, rate=0.0)
    np.testing.assert_array_equal(np.asarray(fn({}, ins)), base)
    assert fn.faults.checked > 0               # the faulted path really ran


def test_executor_fault_wraps_exec_error_with_site(rng):
    table, graph, corpus = _stores(rng)
    a = _tri_analysis(table, graph, corpus)
    fn = a.compile(SYS, engines=store_engines(), cache=False)
    fn.faults = FaultInjector(seed=0, always_fail=("masked_topk",))
    with pytest.raises(ExecError) as ei:
        fn({}, _inputs(table, graph, corpus))
    err = ei.value
    assert err.retryable
    assert "masked_topk" in err.node_id or "masked_topk" in err.impl
    assert isinstance(err.cause, FaultInjectedError)


def test_retry_then_fallback_bitwise_identical_and_new_plan_id(rng):
    """The flagship loop: a persistently-failing compaction op trips the
    breaker, the re-plan drops choose_compaction (a provably different plan
    id), and the fallback's outputs are bitwise-identical to the fault-free
    run of the original plan."""
    table, graph, corpus = _stores(rng)
    a = _tri_analysis(table, graph, corpus)     # 5% selectivity: compacts
    ins = _inputs(table, graph, corpus)
    clean = a.compile(SYS, engines=store_engines(), cache=False,
                      rewrite_pipeline=NOFUSE_PIPELINE)
    assert any("compact" in n.impl for n in clean.concrete.topo())
    expected = np.asarray(clean({}, ins))

    recorder = FlightRecorder()
    rex = ResilientExecutor(
        CAT, SYS, engines=store_engines(),
        rewrite_pipeline=NOFUSE_PIPELINE,
        policy=RetryPolicy(max_attempts=3, base_backoff_s=0.0, jitter=0.0),
        breaker=CircuitBreaker(threshold=1),
        recorder=recorder,
        faults=FaultInjector(seed=0, always_fail=("compact",)),
        sleep=lambda s: None,
        plan_kwargs={"cache": False})
    out, fn = rex.run(a.plan, {}, ins)

    np.testing.assert_array_equal(np.asarray(out), expected)
    assert fn.plan_id != clean.plan_id          # provably re-planned
    assert not any("compact" in n.impl for n in fn.concrete.topo())
    base_plan_id = rex.attempts_log[0][2]       # the undegraded plan
    assert fn.plan_id != base_plan_id           # fallback got a new identity
    assert rex.breaker.blocklist(base_plan_id) == ("compacted",)
    kinds = [s for s, *_ in rex.attempts_log]
    assert kinds == ["fail", "ok"]
    assert any(r == "breaker_open" for r, _ in recorder.trips)


def test_transient_fault_plain_retry_same_plan(rng):
    """A fault budget of 1 models a transient: the retry replays the same
    plan (no breaker trip) and succeeds bitwise."""
    table, graph, corpus = _stores(rng)
    a = _tri_analysis(table, graph, corpus)
    ins = _inputs(table, graph, corpus)
    clean = a.compile(SYS, engines=store_engines(), cache=False)
    expected = np.asarray(clean({}, ins))
    rex = ResilientExecutor(
        CAT, SYS, engines=store_engines(),
        policy=RetryPolicy(max_attempts=4, base_backoff_s=0.0, jitter=0.0),
        breaker=CircuitBreaker(threshold=10),   # never opens
        faults=FaultInjector(seed=0, rate=1.0, categories=("node",),
                             max_faults=1),
        sleep=lambda s: None,
        plan_kwargs={"cache": False})
    out, fn = rex.run(a.plan, {}, ins)
    np.testing.assert_array_equal(np.asarray(out), expected)
    # same plan as attempt 1, just retried (no breaker trip, no re-plan)
    assert fn.plan_id == rex.attempts_log[0][2]
    assert [s for s, *_ in rex.attempts_log] == ["fail", "ok"]


def test_fatal_error_fails_fast_no_retry(rng):
    table, graph, corpus = _stores(rng)
    a = _tri_analysis(table, graph, corpus)
    rex = ResilientExecutor(CAT, SYS, engines=store_engines(),
                            sleep=lambda s: None,
                            plan_kwargs={"cache": False})
    with pytest.raises(ExecError) as ei:
        rex.run(a.plan, {}, {})                 # missing inputs: KeyError
    assert not ei.value.retryable
    assert len([s for s, *_ in rex.attempts_log if s == "fail"]) == 1


def test_deadline_stops_retries(rng):
    table, graph, corpus = _stores(rng)
    a = _tri_analysis(table, graph, corpus)
    recorder = FlightRecorder()
    rex = ResilientExecutor(
        CAT, SYS, engines=store_engines(),
        policy=RetryPolicy(max_attempts=50, base_backoff_s=10.0,
                           jitter=0.0),
        recorder=recorder,
        faults=FaultInjector(seed=0, always_fail=("masked_topk",)),
        sleep=lambda s: None,
        plan_kwargs={"cache": False})
    with pytest.raises(ExecError):
        # the 10s backoff cannot fit in a 1s deadline: one attempt only
        rex.run(a.plan, {}, _inputs(table, graph, corpus), deadline_s=1.0)
    assert len(rex.attempts_log) == 1
    assert any(r == "retries_exhausted" for r, _ in recorder.trips)


# --------------------------------------------------------------------------
# degraded-mode replanning for standing analytical queries
# --------------------------------------------------------------------------

def test_degrade_policy_levels():
    pol = DegradePolicy(CAT)
    assert pol.level(queue_depth=0, max_batch=4, kv_fill=0.1) == 0
    assert pol.level(queue_depth=4, max_batch=4, kv_fill=0.1) == 1
    assert pol.level(queue_depth=8, max_batch=4, kv_fill=0.1) == 2
    assert pol.level(queue_depth=0, max_batch=4, kv_fill=0.85) == 1
    assert pol.level(queue_depth=0, max_batch=4, kv_fill=0.99) == 2


def test_degrade_replan_clamps_and_changes_plan_id(rng):
    table, graph, corpus = _stores(rng)
    a = _tri_analysis(table, graph, corpus, k=64, iters=10)
    planned = a.compile(SYS, engines=store_engines(), cache=False)
    ins = _inputs(table, graph, corpus)
    full = np.asarray(planned({}, ins))

    pol = DegradePolicy(CAT)
    deg = pol.replan(planned, 2, cache=False)
    assert deg.plan_id != planned.plan_id
    clamped = {(c["attr"], c["to"]) for e in pol.events
               for c in e["clamps"]}
    assert ("k", 8) in clamped and ("iters", 3) in clamped
    out = np.asarray(deg({}, ins))
    assert out.shape == full.shape              # same query surface
    # level 0 and a plan with nothing to clamp return the original object
    assert pol.replan(planned, 0) is planned
    small = _tri_analysis(table, graph, corpus, k=4, iters=2)
    small_fn = small.compile(SYS, engines=store_engines(), cache=False)
    assert pol.replan(small_fn, 2, cache=False) is small_fn


# --------------------------------------------------------------------------
# serving: deadlines, cancellation, timeout resolution, chaos
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    cfg = get_smoke_config("qwen3-0.6b").replace(dtype="float32")
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(1))
    return cfg, model, params


def _runtime(model, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    # one isolated ledger shared by the plan cache and the runtime, so the
    # plan_jit -> plan_cache lifetime ties anchor correctly and leaks() == []
    # is a real per-test invariant
    ledger = kw.setdefault("ledger", MemoryLedger())
    kw.setdefault("plan_cache", PlanCache(ledger=ledger))
    return AsyncServingRuntime(model, params, **kw)


def _trace(rng, n=4, gen=6):
    return [ServeRequest(f"r{i}", tuple(int(t) for t in
                                        rng.randint(0, 64, 5 + i)),
                         gen, arrival=0.0) for i in range(n)]


def test_serve_timeout_resolves_every_request(served, rng):
    """Satellite: a loop timeout resolves all outstanding requests with
    structured errors instead of raising (the final gather used to
    KeyError)."""
    _, model, params = served
    rt = _runtime(model, params)
    reqs = _trace(rng, n=3)
    results = rt.serve(reqs, timeout_s=0.0)     # expires immediately
    assert len(results) == len(reqs)
    for r in results:
        assert r.status == "timeout"
        assert r.error and r.error["reason"] == "timeout"
    assert rt.pool.occupancy()["slots_used"] == 0
    assert rt.ledger.leaks() == []
    assert any(r == "serve_timeout" for r, _ in rt.recorder.trips)


def test_serve_inside_running_loop_raises_clear_error(served, rng):
    _, model, params = served
    rt = _runtime(model, params)

    async def nested():
        rt.serve(_trace(rng, n=1))

    with pytest.raises(RuntimeError, match=r"await runtime\.run"):
        asyncio.run(nested())


def test_deadline_expired_request_gets_structured_error(served, rng):
    _, model, params = served
    rt = _runtime(model, params)
    rt.warmup([8])
    # impossible deadline: expires the moment it is submitted
    req = ServeRequest("dl", (1, 2, 3), 4, arrival=0.0, deadline_s=0.0)
    ok = ServeRequest("ok", (1, 2, 3), 4, arrival=0.0)
    res = {r.rid: r for r in rt.serve([req, ok], timeout_s=120.0)}
    assert res["dl"].status == "deadline_exceeded"
    assert res["dl"].error["reason"] == "deadline_exceeded"
    assert res["ok"].status == "ok" and len(res["ok"].tokens) == 4
    assert rt.pool.occupancy()["slots_used"] == 0
    assert rt.ledger.leaks() == []


def test_token_boundary_cancellation_returns_kv_pages(served, rng):
    """Mid-decode deadline expiry: the request leaves at the next token
    boundary, keeps its partial tokens, and its KV pages return to the
    pool (ledger-verified: no leaked per-request state)."""
    _, model, params = served
    rt = _runtime(model, params)
    rt.warmup([8])
    rt._t0 = time.perf_counter()
    req = ServeRequest("c1", (1, 2, 3, 4), 32, arrival=0.0, deadline_s=60.0)
    rt.submit(req)
    assert rt._try_join()
    assert rt.pool.holds("c1")
    rt._decode_tick()
    rt._decode_tick()
    partial = len(rt.scheduler.active()[0].out)
    rt._t0 -= 120.0                             # run-clock passes deadline
    rt._expire_deadlines()
    res = rt._results["c1"]
    assert res.status == "deadline_exceeded"
    assert res.error["phase"] == "decode"
    assert len(res.tokens) == partial           # partial output preserved
    assert not rt.pool.holds("c1")
    assert rt.pool.occupancy()["slots_used"] == 0
    assert rt.pool.occupancy()["pages_used"] == 0
    assert rt.ledger.leaks() == []
    assert rt.registry.counters["serving.deadline_miss"] == 1


def test_prefill_fault_retries_then_matches_fault_free(served, rng):
    """A transient prefill fault re-enqueues the request; the retry
    succeeds and the tokens are bitwise-identical to a fault-free run."""
    _, model, params = served
    reqs = _trace(rng, n=2, gen=5)
    clean_rt = _runtime(model, params)
    clean_rt.warmup([r.prompt_len for r in reqs])
    clean = {r.rid: r.tokens for r in clean_rt.serve(reqs, timeout_s=120.0)}

    faults = FaultInjector(seed=0, rate=1.0, categories=("prefill",),
                           max_faults=1)
    rt = _runtime(model, params, faults=faults)
    rt.warmup([r.prompt_len for r in reqs])
    results = {r.rid: r for r in rt.serve(reqs, timeout_s=120.0)}
    assert faults.n_errors() == 1
    for r in reqs:
        assert results[r.rid].status == "ok"
        assert results[r.rid].tokens == clean[r.rid]
    assert any(ev.kind == "prefill_fault" for ev in rt.recorder.events())
    assert rt.pool.occupancy()["slots_used"] == 0
    assert rt.ledger.leaks() == []


def test_persistent_prefill_fault_resolves_with_error(served, rng):
    _, model, params = served
    faults = FaultInjector(seed=0, always_fail=("prefill",))
    rt = _runtime(model, params, faults=faults, prefill_retries=1)
    rt.warmup([8])
    res = rt.serve(_trace(rng, n=1), timeout_s=120.0)[0]
    assert res.status == "error"
    assert res.error["reason"] == "prefill_failed"
    assert res.error["attempts"] == 2           # initial + 1 retry
    assert rt.pool.occupancy()["slots_used"] == 0
    assert rt.ledger.leaks() == []
    assert any(r == "prefill_error" for r, _ in rt.recorder.trips)


def test_persistent_decode_fault_fails_batch_structurally(served, rng):
    _, model, params = served
    faults = FaultInjector(seed=0, always_fail=("decode",))
    rt = _runtime(model, params, faults=faults, decode_fault_cap=3)
    rt.warmup([8])
    results = rt.serve(_trace(rng, n=2, gen=4), timeout_s=120.0)
    for r in results:
        assert r.status == "error"
        assert r.error["reason"] == "decode_failed"
    assert rt.pool.occupancy()["slots_used"] == 0
    assert rt.ledger.leaks() == []


def test_chaos_schedule_every_request_terminates(served, rng):
    """The acceptance property at test scale: under a pinned seeded
    schedule every request terminates with a result or a structured
    error, non-faulted requests match the fault-free run bitwise, and the
    pool + ledger end clean."""
    _, model, params = served
    reqs = _trace(rng, n=4, gen=5)
    clean_rt = _runtime(model, params)
    clean_rt.warmup([r.prompt_len for r in reqs])
    clean = {r.rid: r.tokens for r in clean_rt.serve(reqs, timeout_s=120.0)}

    faults = FaultInjector(seed=0, rate=0.10,
                           categories=("prefill", "decode"))
    rt = _runtime(model, params, faults=faults)
    rt.warmup([r.prompt_len for r in reqs])
    results = rt.serve(reqs, timeout_s=120.0)
    assert len(results) == len(reqs)
    for r in results:
        assert r.status in ("ok", "truncated", "rejected", "error",
                            "deadline_exceeded", "timeout")
        if r.status == "ok":
            assert r.tokens == clean[r.rid]     # bitwise vs fault-free
        else:
            assert r.error is not None          # structured, never silent
    assert rt.pool.occupancy()["slots_used"] == 0
    assert rt.pool.occupancy()["pages_used"] == 0
    assert rt.ledger.leaks() == []


def test_executor_error_trip_includes_ledger_and_metrics(served, rng):
    """Satellite: run_analysis incident dumps carry memory/occupancy state
    at failure time, not just the exception repr."""
    _, model, params = served
    rt = _runtime(model, params)
    table = ColumnStore({"k": np.arange(8, dtype=np.int32),
                         "v": np.arange(8, dtype=np.float32)})
    with Analysis("boom", CAT) as a:
        t = a.op("rel_scan", a.bind("t", table))
        g = a.op("rel_group_agg", t, key="k", num_groups=8,
                 aggs=(("s", "sum", "v"),))
        a.store(a.op("col_tensor", g, col="s", dim="nodes"))
    planned = a.compile(SYS, engines=store_engines(), cache=False)
    for analyze in (False, True):
        with pytest.raises(Exception):
            rt.run_analysis(planned, {}, {}, analyze=analyze)  # no inputs
    trips = [ev.payload for ev in rt.recorder.events()
             if ev.kind == "trip"
             and ev.payload.get("reason") == "executor_error"]
    assert len(trips) == 2
    for t in trips:
        assert "ledger" in t["detail"] and "total_bytes" in \
            t["detail"]["ledger"]
        assert "metrics" in t["detail"]


def test_run_analysis_degrades_under_overload(served, rng):
    _, model, params = served
    table, graph, corpus = _stores(rng)
    a = _tri_analysis(table, graph, corpus, k=64, iters=10)
    planned = a.compile(SYS, engines=store_engines(), cache=False)
    ins = _inputs(table, graph, corpus)
    pol = DegradePolicy(CAT)
    rt = _runtime(model, params, degrade=pol)
    pol.registry = rt.registry
    pol.recorder = rt.recorder
    # normal load: the full plan runs
    rt.run_analysis(planned, {}, ins)
    assert "analytics.degraded" not in rt.registry.counters
    # forced overload level: the degraded variant runs instead
    rt.run_analysis(planned, {}, ins, degrade=2)
    assert rt.registry.counters["analytics.degraded"] == 1
    assert any(ev.kind == "degrade" for ev in rt.recorder.events())
    # opt-out leaves the plan alone even with a policy attached
    rt.run_analysis(planned, {}, ins, degrade=False)
    assert rt.registry.counters["analytics.degraded"] == 1


def test_run_analysis_deadline_miss_is_recorded(served, rng):
    _, model, params = served
    rt = _runtime(model, params)
    table = ColumnStore({"k": np.arange(8, dtype=np.int32),
                         "v": np.arange(8, dtype=np.float32)})
    with Analysis("slow", CAT) as a:
        t = a.op("rel_scan", a.bind("t", table))
        g = a.op("rel_group_agg", t, key="k", num_groups=8,
                 aggs=(("s", "sum", "v"),))
        a.store(a.op("col_tensor", g, col="s", dim="nodes"))
    planned = a.compile(SYS, engines=store_engines(), cache=False)
    rt.run_analysis(planned, {}, {"t": table.payload()}, deadline_s=0.0)
    assert rt.registry.counters["analytics.deadline_miss"] == 1
    assert any(ev.kind == "deadline_miss" for ev in rt.recorder.events())
