"""Checkpointing: atomic save/restore roundtrip, resume determinism,
retention, reshard-on-restore, and the failure-injection supervisor."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.executor import plan_and_compile
from repro.core.ir import SystemCatalog
from repro.data.pipeline import DataConfig, synth_batch
from repro.models import build_model
from repro.models.lm import CATALOG
from repro.train.checkpoint import (checkpoint_step, latest_checkpoint,
                                    restore_checkpoint, save_checkpoint)
from repro.train.fault_tolerance import (FailureInjector, Watchdog,
                                         run_resumable)
from repro.train.optim import cosine_schedule, make_optimizer
from repro.train.train_step import init_state, make_train_step

SYS = SystemCatalog()


def _setup(arch="qwen3-0.6b"):
    cfg = get_smoke_config(arch).replace(dtype="float32")
    model = build_model(cfg)
    b, s = 2, 8
    plan = model.build_plan(b, s, mode="train")
    fwd = plan_and_compile(plan, CATALOG, SYS)
    opt = make_optimizer("adamw", cosine_schedule(1e-3, 2, 100))
    step = jax.jit(make_train_step(fwd, opt, grad_dtype="float32"))
    params, _ = model.init_params(jax.random.key(0))
    state = init_state(params, opt)
    dc = DataConfig(vocab=cfg.vocab, seq_len=s, global_batch=b)
    return state, step, dc


def _run(state, step, dc, start, n):
    for i in range(start, start + n):
        batch = {k: jnp.asarray(v) for k, v in synth_batch(dc, i).items()}
        state, m = step(state, batch)
    return state, m


def test_roundtrip_identical(tmp_path):
    state, step, dc = _setup()
    state, _ = _run(state, step, dc, 0, 3)
    path = save_checkpoint(str(tmp_path), 3, state)
    restored = restore_checkpoint(path, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_is_deterministic(tmp_path):
    """6 straight steps == 3 steps + checkpoint/restore + 3 steps."""
    s1, step, dc = _setup()
    s1, m1 = _run(s1, step, dc, 0, 6)

    s2, _, _ = _setup()
    s2, _ = _run(s2, step, dc, 0, 3)
    path = save_checkpoint(str(tmp_path), 3, s2)
    s3 = restore_checkpoint(path, jax.eval_shape(lambda: s2))
    s3, m3 = _run(s3, step, dc, 3, 3)
    np.testing.assert_allclose(float(m1["loss"]), float(m3["loss"]),
                               rtol=1e-6)


def test_retention_keeps_last_n(tmp_path):
    state, step, dc = _setup()
    for k in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), k, state, keep=2)
    names = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert names == ["step_0000000004", "step_0000000005"]
    assert checkpoint_step(latest_checkpoint(str(tmp_path))) == 5


def test_restore_casts_dtype(tmp_path):
    state, step, dc = _setup()
    path = save_checkpoint(str(tmp_path), 1, state)
    # template with bf16 params -> restore casts
    tpl = jax.eval_shape(lambda: state)
    tpl_cast = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
        if l.dtype == jnp.float32 and len(l.shape) >= 2 else l, tpl)
    restored = restore_checkpoint(path, tpl_cast)
    leaves = jax.tree.leaves(restored)
    assert any(l.dtype == jnp.bfloat16 for l in leaves)


def test_supervisor_survives_injected_failures(tmp_path):
    """The node-failure drill: loop crashes at steps 4 and 9; the supervisor
    restarts from checkpoints and completes exactly 12 steps."""
    inj = FailureInjector(fail_at=(4, 9))
    state0, step, dc = _setup()
    ckpt = str(tmp_path)

    def make_loop(start):
        latest = latest_checkpoint(ckpt)
        if latest:
            state = restore_checkpoint(latest,
                                       jax.eval_shape(lambda: state0))
        else:
            state = state0
        s = state
        for i in range(start, 12):
            inj.maybe_fail(i)
            batch = {k: jnp.asarray(v)
                     for k, v in synth_batch(dc, i).items()}
            s, m = step(s, batch)
            if (i + 1) % 2 == 0:
                save_checkpoint(ckpt, i + 1, s)
        return 12, {"loss": float(m["loss"])}

    out = run_resumable(12, make_loop=make_loop, ckpt_dir=ckpt)
    assert out["final_step"] == 12
    assert out["restarts"] == 2


def test_watchdog_flags_stragglers():
    wd = Watchdog(straggler_factor=2.0)
    for i in range(10):
        assert not wd.observe(i, 1.0)
    assert wd.observe(10, 5.0)           # 5x median
    assert wd.events and wd.events[0]["step"] == 10
