"""Bucketed admission control (serving front door).

Prompt lengths are rounded up to power-of-two buckets so that repeated
traffic with varying lengths maps onto a handful of cached
StagedPhysicalPlans: every request admitted into a **warm** bucket hits an
already-cached plan and never waits on the pass pipeline.  Cold buckets are
only planned in a low-load window (idle decode batch); under load they stay
queued — or are rejected outright when the queue is full — so a burst of
novel lengths cannot stall the in-flight decode batch behind planning.
"""
from __future__ import annotations

from dataclasses import dataclass


def bucket_len(n: int, lo: int = 8, hi: int | None = None) -> int:
    """Round a prompt length up to the next power-of-two bucket.

    ``lo`` is the smallest bucket (prompts shorter than ``lo`` — including
    empty prompts — share it); an exact power of two is its own bucket
    (no unnecessary promotion); ``hi`` is the model's max context — lengths
    above it are not servable and raise, and a non-power-of-two ``hi`` caps
    the top bucket at ``hi`` itself.
    """
    if n < 0:
        raise ValueError(f"prompt length must be >= 0, got {n}")
    if lo < 1:
        raise ValueError(f"smallest bucket must be >= 1, got {lo}")
    if hi is not None and n > hi:
        raise ValueError(
            f"prompt length {n} exceeds the max context {hi}")
    b = lo
    while b < n:
        b *= 2
    if hi is not None and b > hi:
        b = hi            # top bucket clamps to the (non-pow2) max context
    return b


@dataclass
class AdmissionController:
    """Per-request admission decisions.

    ``decide`` returns one of:
      * ``"admit"``  — enqueue for the scheduler (warm bucket, or a cold
        bucket while the system is quiet enough to plan it);
      * ``"queue"``  — cold bucket under load: hold until the decode batch
        drains enough to afford a planning pause;
      * ``"reject"`` — queue full (overload shedding).
    """

    max_queue: int = 64
    # a cold bucket may be planned inline while the decode batch occupancy
    # is at or below this fraction (0.0 == only when fully idle)
    cold_plan_occupancy: float = 0.5

    def decide(self, *, warm: bool, queue_depth: int, active: int,
               max_batch: int) -> str:
        if queue_depth >= self.max_queue:
            return "reject"
        if warm:
            return "admit"
        if active <= self.cold_plan_occupancy * max_batch:
            return "admit"          # quiet enough to plan the cold bucket
        return "queue"

    def can_plan_cold(self, *, active: int, max_batch: int) -> bool:
        """Scheduler-side re-check: a queued cold-bucket request may trigger
        planning once the decode batch has drained."""
        return active <= self.cold_plan_occupancy * max_batch
