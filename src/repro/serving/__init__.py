"""Async serving runtime over the plan cache (continuous batching).

Request flow:  admission (bucket → cached plan) → scheduler (join/leave the
decode batch at token boundaries) → planned prefill seeds the paged KV pool
→ batched decode.  Fault tolerance (deadlines, retries, degraded-mode
replanning) rides the same seams.  See ARCHITECTURE.md § "Serving runtime"
and § "Fault tolerance & graceful degradation".
"""
from .admission import AdmissionController, bucket_len
from .degrade import DegradePolicy
from .kv_pool import PagedKVPool, PageTable
from .metrics import RequestMetrics, ServingMetrics
from .runtime import (AnalysisRequest, AnalysisResult, AsyncServingRuntime,
                      ServeRequest, ServeResult, serve_sequential)
from .scheduler import ContinuousBatchScheduler, SlotState, TenantScheduler

__all__ = [
    "AdmissionController", "bucket_len",
    "DegradePolicy",
    "PagedKVPool", "PageTable",
    "RequestMetrics", "ServingMetrics",
    "AnalysisRequest", "AnalysisResult",
    "AsyncServingRuntime", "ServeRequest", "ServeResult", "serve_sequential",
    "ContinuousBatchScheduler", "SlotState", "TenantScheduler",
]
