"""Serving metrics: per-request latency decomposition + runtime gauges.

Per request: queue wait, TTFT (submit → first token, i.e. admission + plan
fetch + prefill), and TPOT (mean decode seconds per generated token after
the first).  Runtime-wide: queue-depth and pool-occupancy gauges sampled at
every scheduler tick, plan-cache hit/miss deltas, and join/leave/reject
counters — the signals the ISSUE's dashboards would scrape.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class RequestMetrics:
    request_id: object
    bucket: int = 0
    prompt_len: int = 0
    gen: int = 0
    submitted_at: float = 0.0
    joined_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0
    plan_ms: float = 0.0             # plan fetch/compile (cache hit ≈ free)
    prefill_ms: float = 0.0

    @property
    def queue_wait_s(self) -> float:
        return max(self.joined_at - self.submitted_at, 0.0)

    @property
    def ttft_s(self) -> float:
        return max(self.first_token_at - self.submitted_at, 0.0)

    @property
    def tpot_s(self) -> float:
        if self.gen <= 1:
            return 0.0
        return max(self.finished_at - self.first_token_at, 0.0) / \
            (self.gen - 1)


@dataclass
class ServingMetrics:
    requests: list = field(default_factory=list)   # finished RequestMetrics
    rejected: int = 0
    joins: int = 0
    leaves: int = 0
    ticks: int = 0
    queue_depth_samples: list = field(default_factory=list)
    pool_fill_samples: list = field(default_factory=list)
    plan_hits: int = 0
    plan_misses: int = 0

    def observe_tick(self, queue_depth: int, pool_fill: float) -> None:
        self.ticks += 1
        self.queue_depth_samples.append(queue_depth)
        self.pool_fill_samples.append(pool_fill)

    def observe_plan(self, *, hit: bool) -> None:
        if hit:
            self.plan_hits += 1
        else:
            self.plan_misses += 1

    def finish(self, rm: RequestMetrics) -> None:
        self.requests.append(rm)
        self.leaves += 1

    def summary(self) -> dict:
        rs = self.requests
        n = len(rs)
        mean = lambda xs: sum(xs) / len(xs) if xs else 0.0  # noqa: E731
        total = self.plan_hits + self.plan_misses
        return {
            "completed": n,
            "rejected": self.rejected,
            "ticks": self.ticks,
            "mean_ttft_s": mean([r.ttft_s for r in rs]),
            "mean_tpot_s": mean([r.tpot_s for r in rs]),
            "mean_queue_wait_s": mean([r.queue_wait_s for r in rs]),
            "mean_queue_depth": mean(self.queue_depth_samples),
            "max_queue_depth": max(self.queue_depth_samples, default=0),
            "mean_pool_fill": mean(self.pool_fill_samples),
            "plan_hits": self.plan_hits,
            "plan_misses": self.plan_misses,
            "plan_hit_rate": (self.plan_hits / total) if total else 0.0,
            "generated_tokens": sum(r.gen for r in rs),
        }

    def report(self) -> str:
        s = self.summary()
        lines = [
            f"[serving] {s['completed']} completed, {s['rejected']} rejected "
            f"over {s['ticks']} ticks",
            f"[serving] TTFT {s['mean_ttft_s'] * 1e3:.1f} ms mean; "
            f"TPOT {s['mean_tpot_s'] * 1e3:.2f} ms/token mean; "
            f"queue wait {s['mean_queue_wait_s'] * 1e3:.1f} ms mean",
            f"[serving] queue depth mean {s['mean_queue_depth']:.2f} "
            f"max {s['max_queue_depth']}; "
            f"pool fill mean {s['mean_pool_fill']:.2f}",
            f"[serving] plan cache: {s['plan_hits']} hits / "
            f"{s['plan_misses']} misses "
            f"(hit rate {s['plan_hit_rate']:.2f})",
        ]
        return "\n".join(lines)
