"""Serving metrics: per-request latency decomposition + runtime gauges.

Per request: queue wait, TTFT (submit → first token, i.e. admission + plan
fetch + prefill), and TPOT (mean decode seconds per generated token after
the first).  Runtime-wide: queue-depth and pool-occupancy gauges sampled at
every scheduler tick, plan-cache hit/miss deltas, and join/leave/reject
counters.

Distributions are held as :class:`Summary` objects — running count / mean /
min / max plus p50/p95/p99 **percentile summaries** (nearest-rank).  Raw
sample lists stay available behind the ``keep_samples`` flag (default on,
so existing consumers keep exact lists); with it off a Summary keeps only a
bounded ring of recent samples for the percentile estimate, making
long-running servers O(1) in memory.

All summaries and counters live in a :class:`MetricsRegistry`, so the LM
serving path and analytical (tri-store) requests report into **one**
registry (``AsyncServingRuntime(registry=...)`` /
``AsyncServingRuntime.run_analysis``) and one ``report()`` covers both
workload families.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class RequestMetrics:
    request_id: object
    bucket: int = 0
    prompt_len: int = 0
    gen: int = 0
    submitted_at: float = 0.0
    joined_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0
    plan_ms: float = 0.0             # plan fetch/compile (cache hit ≈ free)
    prefill_ms: float = 0.0

    @property
    def queue_wait_s(self) -> float:
        return max(self.joined_at - self.submitted_at, 0.0)

    @property
    def ttft_s(self) -> float:
        return max(self.first_token_at - self.submitted_at, 0.0)

    @property
    def tpot_s(self) -> float:
        if self.gen <= 1:
            return 0.0
        return max(self.finished_at - self.first_token_at, 0.0) / \
            (self.gen - 1)


class Summary:
    """One observed distribution: running count/mean/min/max plus
    nearest-rank percentiles over the retained samples.  ``keep_samples``
    keeps the full raw list (exact percentiles, unbounded memory — the
    test/benchmark default); off keeps a bounded ring of the most recent
    ``cap`` samples (approximate percentiles, O(1) memory)."""

    __slots__ = ("name", "keep_samples", "cap", "count", "total",
                 "min", "max", "_samples", "_head")

    def __init__(self, name: str = "", keep_samples: bool = True,
                 cap: int = 4096):
        self.name = name
        self.keep_samples = bool(keep_samples)
        self.cap = int(cap)
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._samples: list = []
        self._head = 0

    def observe(self, value) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if self.keep_samples or len(self._samples) < self.cap:
            self._samples.append(v)
        else:                                   # bounded ring overwrite
            self._samples[self._head] = v
            self._head = (self._head + 1) % self.cap

    @property
    def samples(self) -> list:
        """The retained raw samples (full history with ``keep_samples``)."""
        return self._samples

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile over the retained samples (q in 0..100)."""
        if not self._samples:
            return 0.0
        xs = sorted(self._samples)
        rank = max(1, -(-int(q) * len(xs) // 100))   # ceil(q/100 * n)
        return xs[min(rank, len(xs)) - 1]

    def snapshot(self) -> dict:
        return {"count": self.count, "mean": self.mean,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}

    def __repr__(self):
        s = self.snapshot()
        return (f"Summary({self.name}: n={s['count']} mean={s['mean']:.4g} "
                f"p50={s['p50']:.4g} p95={s['p95']:.4g} "
                f"p99={s['p99']:.4g})")


class Gauge:
    """A point-in-time level (queue depth *now*, resident bytes *now*) —
    distinct from a Summary (a distribution of observations) and a counter
    (a monotone total).  Tracks its own peak/trough so intermittent
    snapshot readers still see the extremes between reads."""

    __slots__ = ("name", "value", "peak", "trough", "updates")

    def __init__(self, name: str = ""):
        self.name = name
        self.value = 0.0
        self.peak = float("-inf")
        self.trough = float("inf")
        self.updates = 0

    def set(self, value) -> float:
        v = float(value)
        self.value = v
        self.peak = max(self.peak, v)
        self.trough = min(self.trough, v)
        self.updates += 1
        return v

    def inc(self, delta=1.0) -> float:
        return self.set(self.value + float(delta))

    def dec(self, delta=1.0) -> float:
        return self.set(self.value - float(delta))

    def snapshot(self) -> dict:
        return {"value": self.value,
                "peak": self.peak if self.updates else 0.0,
                "trough": self.trough if self.updates else 0.0,
                "updates": self.updates}

    def __repr__(self):
        return f"Gauge({self.name}={self.value:.4g} peak={self.peak:.4g})"


class Counter:
    """Named monotone counter view over a registry's counter table (the
    table itself stays a plain ``{name: int}`` dict — existing consumers
    index ``registry.counters`` directly)."""

    __slots__ = ("name", "_counters")

    def __init__(self, name: str, counters: dict):
        self.name = name
        self._counters = counters
        self._counters.setdefault(name, 0)

    def inc(self, delta: int = 1) -> int:
        if delta < 0:
            raise ValueError(f"counter {self.name}: negative delta {delta}")
        self._counters[self.name] = self._counters.get(self.name, 0) + delta
        return self._counters[self.name]

    @property
    def value(self) -> int:
        return self._counters.get(self.name, 0)

    def __repr__(self):
        return f"Counter({self.name}={self.value})"


class MetricsRegistry:
    """Named summaries + gauges + counters shared across workload families:
    the LM serving path registers ``lm.*`` series, analytical requests
    ``analytics.*``, the resource ledger ``ledger.*`` — one registry, one
    report."""

    def __init__(self, keep_samples: bool = True):
        self.keep_samples = bool(keep_samples)
        self.summaries: dict = {}
        self.counters: dict = {}
        self.gauges: dict = {}

    def summary(self, name: str) -> Summary:
        s = self.summaries.get(name)
        if s is None:
            s = self.summaries[name] = Summary(name, self.keep_samples)
        return s

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def counter(self, name: str) -> Counter:
        return Counter(name, self.counters)

    def count(self, name: str, delta: int = 1) -> int:
        self.counters[name] = self.counters.get(name, 0) + delta
        return self.counters[name]

    def snapshot(self) -> dict:
        return {"summaries": {k: v.snapshot()
                              for k, v in sorted(self.summaries.items())},
                "gauges": {k: v.snapshot()
                           for k, v in sorted(self.gauges.items())},
                "counters": dict(sorted(self.counters.items()))}

    def report(self) -> str:
        lines = []
        for name in sorted(self.summaries):
            s = self.summaries[name].snapshot()
            lines.append(
                f"[metrics] {name}: n={s['count']} mean={s['mean']:.4g} "
                f"p50={s['p50']:.4g} p95={s['p95']:.4g} p99={s['p99']:.4g} "
                f"max={s['max']:.4g}")
        for name in sorted(self.gauges):
            g = self.gauges[name].snapshot()
            lines.append(f"[metrics] {name}: {g['value']:.4g} "
                         f"(peak {g['peak']:.4g})")
        for name in sorted(self.counters):
            lines.append(f"[metrics] {name}: {self.counters[name]}")
        return "\n".join(lines)


class ServingMetrics:
    """The LM serving path's view over a (possibly shared) registry.

    Request latency series (TTFT / TPOT / queue wait) and scheduler gauges
    (queue depth / pool fill) live as ``lm.*`` summaries in the registry;
    the legacy raw-list attributes (``queue_depth_samples`` etc.) remain as
    views over the Summary samples so existing consumers stay green."""

    def __init__(self, registry: MetricsRegistry | None = None,
                 keep_samples: bool = True, prefix: str = "lm"):
        self.registry = registry if registry is not None \
            else MetricsRegistry(keep_samples)
        self.prefix = prefix
        self.requests: list = []      # finished RequestMetrics
        self.rejected = 0
        self.joins = 0
        self.leaves = 0
        self.ticks = 0
        self.plan_hits = 0
        self.plan_misses = 0
        r = self.registry
        self._ttft = r.summary(f"{prefix}.ttft_s")
        self._tpot = r.summary(f"{prefix}.tpot_s")
        self._queue_wait = r.summary(f"{prefix}.queue_wait_s")
        self._queue_depth = r.summary(f"{prefix}.queue_depth")
        self._pool_fill = r.summary(f"{prefix}.pool_fill")

    # legacy raw-list access (tests/benchmarks iterate these directly)
    @property
    def queue_depth_samples(self) -> list:
        return self._queue_depth.samples

    @property
    def pool_fill_samples(self) -> list:
        return self._pool_fill.samples

    def observe_tick(self, queue_depth: int, pool_fill: float) -> None:
        self.ticks += 1
        self._queue_depth.observe(queue_depth)
        self._pool_fill.observe(pool_fill)

    def observe_plan(self, *, hit: bool) -> None:
        if hit:
            self.plan_hits += 1
        else:
            self.plan_misses += 1

    def finish(self, rm: RequestMetrics) -> None:
        self.requests.append(rm)
        self.leaves += 1
        self._ttft.observe(rm.ttft_s)
        self._queue_wait.observe(rm.queue_wait_s)
        if rm.gen > 1:
            self._tpot.observe(rm.tpot_s)

    def summary(self) -> dict:
        rs = self.requests
        n = len(rs)
        total = self.plan_hits + self.plan_misses
        out = {
            "completed": n,
            "rejected": self.rejected,
            "ticks": self.ticks,
            "mean_ttft_s": self._ttft.mean,
            "mean_tpot_s": self._tpot.mean,
            "mean_queue_wait_s": self._queue_wait.mean,
            "mean_queue_depth": self._queue_depth.mean,
            "max_queue_depth": int(self._queue_depth.max)
            if self._queue_depth.count else 0,
            "mean_pool_fill": self._pool_fill.mean,
            "plan_hits": self.plan_hits,
            "plan_misses": self.plan_misses,
            "plan_hit_rate": (self.plan_hits / total) if total else 0.0,
            "generated_tokens": sum(r.gen for r in rs),
        }
        for key, s in (("ttft_s", self._ttft), ("tpot_s", self._tpot),
                       ("queue_wait_s", self._queue_wait)):
            for q in (50, 95, 99):
                out[f"p{q}_{key}"] = s.percentile(q)
        return out

    def analytics_summary(self) -> dict:
        """The concurrent-analytics view over the shared registry: request
        counts, TTFR (time-to-first-result — admission to result, the
        analytical analogue of TTFT), and the multi-query sharing counters
        (``analytics.shared_hits`` — sub-DAG cache hits + deduped
        twins; ``analytics.batched`` — queries executed inside a vmapped
        same-shape batch)."""
        r = self.registry
        ttfr = r.summary("analytics.ttfr_ms")
        out = {
            "requests": r.count("analytics.requests", 0),
            "shared_hits": r.count("analytics.shared_hits", 0),
            "batched": r.count("analytics.batched", 0),
            "deduped": r.count("analytics.deduped", 0),
            "mean_ttfr_ms": ttfr.mean,
        }
        for q in (50, 95, 99):
            out[f"p{q}_ttfr_ms"] = ttfr.percentile(q)
        return out

    def analytics_report(self) -> str:
        s = self.analytics_summary()
        return (f"[analytics] {s['requests']} queries: "
                f"{s['shared_hits']} shared subplan hits, "
                f"{s['deduped']} deduped, {s['batched']} vmapped-batched; "
                f"TTFR {s['mean_ttfr_ms']:.1f} ms mean "
                f"(p50 {s['p50_ttfr_ms']:.1f} / p95 {s['p95_ttfr_ms']:.1f})")

    def report(self) -> str:
        s = self.summary()
        lines = [
            f"[serving] {s['completed']} completed, {s['rejected']} rejected "
            f"over {s['ticks']} ticks",
            f"[serving] TTFT {s['mean_ttft_s'] * 1e3:.1f} ms mean "
            f"(p50 {s['p50_ttft_s'] * 1e3:.1f} / "
            f"p95 {s['p95_ttft_s'] * 1e3:.1f} / "
            f"p99 {s['p99_ttft_s'] * 1e3:.1f})",
            f"[serving] TPOT {s['mean_tpot_s'] * 1e3:.2f} ms/token mean "
            f"(p50 {s['p50_tpot_s'] * 1e3:.2f} / "
            f"p95 {s['p95_tpot_s'] * 1e3:.2f} / "
            f"p99 {s['p99_tpot_s'] * 1e3:.2f})",
            f"[serving] queue wait {s['mean_queue_wait_s'] * 1e3:.1f} ms "
            f"mean (p95 {s['p95_queue_wait_s'] * 1e3:.1f}); "
            f"depth mean {s['mean_queue_depth']:.2f} "
            f"max {s['max_queue_depth']}; "
            f"pool fill mean {s['mean_pool_fill']:.2f}",
            f"[serving] plan cache: {s['plan_hits']} hits / "
            f"{s['plan_misses']} misses "
            f"(hit rate {s['plan_hit_rate']:.2f})",
        ]
        return "\n".join(lines)
