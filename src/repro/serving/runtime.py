"""Async serving runtime: continuous batching over the plan cache.

One :class:`AsyncServingRuntime` owns

  * a **bucketed planned prefill** per power-of-two prompt bucket, fetched
    through the content-hashed plan cache (warm buckets never re-plan) and
    jitted once per plan_id;
  * a fixed-width **batched decode step** (``decode_step_batched`` jitted at
    ``max_batch``) whose slots requests join/leave at token boundaries;
  * a :class:`~repro.serving.kv_pool.PagedKVPool` seeded **directly from the
    planned prefill's per-layer K/V outputs** (``mode="prefill_kv"``) —
    no decode replay of the prompt — with a replay fallback for families
    whose decode state is not pure attention K/V (mamba/rwkv hybrids);
  * an asyncio event loop that interleaves admission, planned prefill of
    incoming requests, and decode of in-flight ones at token boundaries
    (continuous batching; JAX's async dispatch pipelines the prefill and
    decode computations it enqueues).

The runtime never re-plans a warm bucket: each request's prefill goes
through ``plan_and_compile`` against the shared plan cache, so steady-state
traffic is 100 % cache hits (asserted by ``benchmarks/serving_throughput``).
"""
from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.executor import plan_and_compile
from ..core.faults import FaultInjectedError
from ..core.ir import SystemCatalog
from ..core.ledger import FlightRecorder, MemoryLedger, default_ledger
from ..core.mqo import SubplanCache, mqo_run, subdag_keys
from ..core.resilience import classify
from ..core.plan_cache import (PlanCache, default_plan_cache,
                               load_plan_cache, save_plan_cache)
from ..models.decode import decode_step, decode_step_batched, init_cache
from ..models.lm import CATALOG, LM
from .admission import AdmissionController, bucket_len
from .kv_pool import PagedKVPool
from .metrics import MetricsRegistry, RequestMetrics, ServingMetrics
from .scheduler import ContinuousBatchScheduler, TenantScheduler


@dataclass(frozen=True)
class ServeRequest:
    rid: object
    prompt: tuple                    # token ids
    gen: int
    arrival: float = 0.0             # seconds after run() start
    deadline_s: Optional[float] = None   # budget from arrival; None = none

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


@dataclass
class ServeResult:
    rid: object
    tokens: list = field(default_factory=list)
    # ok | rejected | truncated | deadline_exceeded | error | timeout
    status: str = "ok"
    metrics: Optional[RequestMetrics] = None
    error: Optional[dict] = None     # structured failure detail (non-ok)

    @property
    def ok(self) -> bool:
        return self.status in ("ok", "truncated")


@dataclass
class AnalysisRequest:
    """One analytical query submitted to the multi-query admission loop.

    ``batch_param`` names an input whose value may differ across otherwise
    identical queries (a PageRank seed set, a top-k query vector): requests
    sharing a plan fingerprint modulo that slot are coalesced per admission
    tick into one vmapped planned forward.  ``store_versions`` are the
    (name, version) pairs of the bound stores — they key the sub-DAG cache
    entries so appends provably invalidate."""

    rid: object
    planned: object                  # PlannedFunction
    inputs: dict
    params: object = None
    tenant: object = "default"
    batch_param: Optional[str] = None
    store_versions: tuple = ()
    tied_to: object = None           # ledger owner of the producing store
    aux: Optional[dict] = None


@dataclass
class AnalysisResult:
    rid: object
    value: object = None
    status: str = "ok"               # ok | error
    error: Optional[dict] = None
    shared_hits: int = 0             # cached sub-DAGs reused by this query
    executed: int = 0                # residual nodes actually run
    deduped: bool = False            # rode an identical in-flight query
    batched: bool = False            # ran inside a vmapped batch
    ttfr_ms: float = 0.0             # submit -> first result

    @property
    def ok(self) -> bool:
        return self.status == "ok"


class AsyncServingRuntime:
    def __init__(self, model: LM, params, *, max_batch: int = 4,
                 max_seq: int = 128, page_size: int = 16,
                 page_budget: int | None = None,
                 bucket_lo: int = 8, engines=("xla",),
                 syscat: Optional[SystemCatalog] = None,
                 plan_cache: Optional[PlanCache] = None,
                 plan_cache_dir: Optional[str] = None,
                 admission: Optional[AdmissionController] = None,
                 use_prefill_kv: Optional[bool] = None,
                 registry: Optional[MetricsRegistry] = None,
                 ledger: Optional[MemoryLedger] = None,
                 recorder: Optional[FlightRecorder] = None,
                 snapshot_every: int = 64,
                 faults=None,
                 degrade=None,
                 prefill_retries: int = 2,
                 decode_fault_cap: int = 8,
                 subplan_cache: Optional[SubplanCache] = None,
                 subplan_budget: Optional[int] = None,
                 tenant_weights: Optional[dict] = None,
                 analysis_tick: int = 16,
                 prefill_batch: int = 4):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.bucket_lo = bucket_lo
        self.engines = tuple(engines)
        self.syscat = syscat or SystemCatalog()
        self.pc = plan_cache if plan_cache is not None else \
            default_plan_cache()
        self.plan_cache_dir = plan_cache_dir
        if plan_cache_dir:
            load_plan_cache(plan_cache_dir, self.pc)   # warm start
        self.kv_mode = model.supports_prefill_kv() if use_prefill_kv is None \
            else bool(use_prefill_kv)
        # one registry for both workload families: LM request series land
        # as "lm.*" summaries, analytical runs (run_analysis) as
        # "analytics.*" — a shared registry makes one report() cover both
        self.registry = registry if registry is not None else \
            MetricsRegistry()
        # resource accounting + incident capture: the ledger tracks every
        # resident pytree (KV pool, plan-cache entries, store payloads);
        # the flight recorder keeps a bounded ring of recent run traces +
        # telemetry snapshots, dumped on rejection / overflow / error
        self.ledger = ledger if ledger is not None else \
            getattr(self.pc, "ledger", None) or default_ledger()
        self.recorder = recorder if recorder is not None else FlightRecorder()
        self.snapshot_every = max(int(snapshot_every), 1)
        self.pool = PagedKVPool(model, max_batch, max_seq,
                                page_size=page_size, page_budget=page_budget,
                                registry=self.registry, ledger=self.ledger)
        self.scheduler = ContinuousBatchScheduler(max_batch)
        self.admission = admission or AdmissionController()
        self.metrics = ServingMetrics(registry=self.registry)
        self._prefill_fns: dict = {}     # bucket -> (PlannedFunction, jitted)
        self._jitted_by_plan: dict = {}  # plan_id -> jitted callable
        # the pool cache is donated (argnums 1): on backends with donation
        # the per-tick cache update aliases the preallocated pool instead of
        # copying it; every call site rebinds pool.cache to the result
        self._dstep = jax.jit(lambda p, c, t, i: decode_step_batched(
            self.model, p, c, t, i), donate_argnums=1)
        self._dstep1 = jax.jit(lambda p, c, t, i: decode_step(
            self.model, p, c, t, i), donate_argnums=1)
        self._results: dict = {}
        self._t0 = time.perf_counter()
        # fault tolerance: an optional FaultInjector exercises the
        # admission/prefill/decode seams; prefill faults retry by
        # re-enqueueing (bounded), decode-tick faults retry the whole tick
        # (state is untouched — the fault fires before the donated decode
        # call); a DegradePolicy (serving.degrade) cheapens analytical
        # plans under overload
        self.faults = faults
        self.degrade = degrade
        self.prefill_retries = int(prefill_retries)
        self.decode_fault_cap = int(decode_fault_cap)
        self._prefill_attempts: dict = {}   # rid -> failed attempts
        self._tick_no = 0
        self._decode_faults = 0             # consecutive faulted ticks
        # multi-query analytics: a byte-budgeted cache of materialized
        # sub-DAG intermediates (cross-query CSE), a weighted round-robin
        # tenant scheduler feeding the admission loop, and single-flight
        # futures so concurrent identical sub-DAGs compute once
        if subplan_cache is not None:
            self.subplans: Optional[SubplanCache] = subplan_cache
        elif subplan_budget is not None:
            self.subplans = SubplanCache(
                subplan_budget, ledger=self.ledger, recorder=self.recorder,
                registry=self.registry)
        else:
            self.subplans = None
        self.analysis_sched = TenantScheduler(tenant_weights)
        self.analysis_tick = max(int(analysis_tick), 1)
        self._analysis_inflight: dict = {}  # root key -> asyncio.Future
        # id(request) -> (results dict, t0) of the run_analyses call that
        # owns it: concurrent calls share one tenant scheduler, so a tick
        # may drain another call's request — settlement routes through the
        # owning call's sink, never the draining call's
        self._analysis_sinks: dict = {}
        # batched prefill: up to ``prefill_batch`` same-bucket waiting
        # requests prefill as ONE vmapped planned forward (1 disables);
        # deterministic fault replay needs per-request prefill sites, so
        # injection forces the sequential path
        self.prefill_batch = 1 if faults is not None \
            else max(int(prefill_batch), 1)
        self._prefill_base: dict = {}    # plan_id -> unjitted prefill call
        self._vjitted_by_plan: dict = {}  # plan_id -> jit(vmap(prefill))

    # -- planning ----------------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def bucket_of(self, prompt_len: int) -> int:
        return bucket_len(prompt_len, lo=self.bucket_lo, hi=self.max_seq)

    def is_warm(self, bucket: int) -> bool:
        return bucket in self._prefill_fns

    def _plan_prefill(self, bucket: int):
        """Fetch (or plan, on a cold bucket) the bucket's prefill through the
        plan cache; jit once per plan_id.  The jitted function also extracts
        the first generated token at a *traced* prompt length, so serving
        never triggers a per-request recompile."""
        mode = "prefill_kv" if self.kv_mode else "prefill"
        t0 = time.perf_counter()
        hits0 = self.pc.hits
        plan = self.model.build_plan(1, bucket, mode=mode)
        fwd = plan_and_compile(plan, CATALOG, self.syscat,
                               engines=self.engines, cache=self.pc)
        self.metrics.observe_plan(hit=self.pc.hits > hits0)
        jitted = self._jitted_by_plan.get(fwd.plan_id)
        if jitted is None:
            vocab = self.cfg.vocab

            def _prefill_call(p, toks, n, _f=fwd):
                outs = _f(p, {"tokens": toks})
                logits = outs[0] if isinstance(outs, tuple) else outs
                row = jax.lax.dynamic_index_in_dim(logits, n - 1, axis=1,
                                                   keepdims=False)
                return outs, jnp.argmax(row[0, :vocab]).astype(jnp.int32)

            jitted = jax.jit(_prefill_call)
            self._jitted_by_plan[fwd.plan_id] = jitted
            self._prefill_base[fwd.plan_id] = _prefill_call
            # tie the jitted wrapper's lifetime to its plan-cache entry:
            # _jitted_by_plan never evicts, so once byte-budget eviction
            # drops the entry this registration shows up in ledger.leaks()
            # as "evicted" — a real retained-executable leak signal
            self.ledger.register(
                ("plan_jit", fwd.plan_id), nbytes=0, kind="plan_jit",
                tied_to=("plan_cache", fwd.plan_id))
        self._prefill_fns[bucket] = (fwd, jitted)
        return fwd, jitted, (time.perf_counter() - t0) * 1e3

    def _vjit_prefill(self, plan_id):
        """jit(vmap) of a bucket's prefill forward, cached per plan and
        ledger-tied to the plan-cache entry (same leak signal as the
        unbatched wrapper)."""
        vj = self._vjitted_by_plan.get(plan_id)
        if vj is None:
            base = self._prefill_base[plan_id]
            vj = jax.jit(jax.vmap(base, in_axes=(None, 0, 0)))
            self._vjitted_by_plan[plan_id] = vj
            self.ledger.register(
                ("plan_jit_batched", plan_id), nbytes=0,
                kind="plan_jit", tied_to=("plan_cache", plan_id))
        return vj

    def warmup(self, prompt_lens: Sequence[int]) -> None:
        """Plan + compile every bucket the trace will touch (prefill *and*
        its pool-seed program), and trace the batched decode step, so
        serving-time work is pure execution."""
        for n in sorted({self.bucket_of(n) for n in prompt_lens}):
            fwd, jitted, _ = self._plan_prefill(n)
            outs, _ = jitted(self.params, jnp.zeros((1, n), jnp.int32),
                             jnp.int32(n))
            if self.kv_mode and self.pool.alloc("__warmup__", 1) is not None:
                # compiling the bucket's seed program writes zero-token K/V
                # into a scratch slot; harmless — any join overwrites it
                self.pool.seed("__warmup__", outs[1:], n)
                self.pool.free("__warmup__")
            if self.kv_mode and self.prefill_batch > 1:
                # the batched-prefill forward too: serve-time batches pad to
                # ONE fixed width per bucket, so this is the only vmapped
                # shape the bucket ever compiles — and warm the per-row KV
                # slice + seed, which compile their own eager kernels
                w = min(self.prefill_batch, self.max_batch)
                outs_b, _ = self._vjit_prefill(fwd.plan_id)(
                    self.params, jnp.zeros((w, 1, n), jnp.int32),
                    jnp.full((w,), n, jnp.int32))
                kv0 = jax.tree.map(lambda x: x[0], outs_b[1:])
                if self.pool.alloc("__warmup__", 1) is not None:
                    self.pool.seed("__warmup__", kv0, n)
                    self.pool.free("__warmup__")
        toks = jnp.zeros((self.max_batch, 1), jnp.int32)
        idxs = jnp.zeros((self.max_batch,), jnp.int32)
        # keep the returned cache: the input buffers were donated, and the
        # position-0 write of token 0 is overwritten by any join
        _, self.pool.cache = self._dstep(self.params, self.pool.cache,
                                         toks, idxs)
        if not self.kv_mode:
            # trace the replay-fallback step too, so the first real
            # request's TTFT is execution, not compilation
            self._dstep1(self.params, init_cache(self.model, 1, self.max_seq),
                         toks[:1], jnp.int32(0))

    # -- telemetry ----------------------------------------------------------
    def telemetry_snapshot(self) -> dict:
        """One continuous-telemetry record: ledger totals, KV occupancy +
        fragmentation, per-bucket queue depth, plan-cache hit/byte ratios,
        decode-batch occupancy.  Published as registry gauges and recorded
        in the flight recorder ring."""
        pc_stats = self.pc.stats()
        snap = {
            "ledger": self.ledger.snapshot(),
            "kv": {**self.pool.occupancy(), **self.pool.fragmentation()},
            "queues": {b: len(q) for b, q in self.scheduler.queues.items()
                       if q},
            "queue_depth": self.scheduler.queue_depth(),
            "active_slots": self.scheduler.n_active(),
            "plan_cache": pc_stats,
            "ticks": self.metrics.ticks,
        }
        g = self.registry.gauge
        g("ledger.total_bytes").set(snap["ledger"]["total_bytes"])
        g("ledger.peak_bytes").set(snap["ledger"]["peak_bytes"])
        g("plan_cache.hit_rate").set(pc_stats["hit_rate"])
        g("plan_cache.bytes").set(pc_stats["bytes"])
        g("serving.queue_depth").set(snap["queue_depth"])
        g("serving.active_slots").set(snap["active_slots"])
        return snap

    def _maybe_snapshot(self, force: bool = False) -> None:
        if force or self.metrics.ticks % self.snapshot_every == 0:
            self.recorder.record("telemetry", self.telemetry_snapshot())

    # -- admission ----------------------------------------------------------
    def _reject(self, req: ServeRequest, reason: str) -> None:
        self.metrics.rejected += 1
        self._results[req.rid] = ServeResult(
            req.rid, [], "rejected", None,
            error={"reason": reason, "rid": str(req.rid)})
        self.recorder.trip("admission_reject", {
            "rid": str(req.rid), "reason": reason,
            "prompt_len": req.prompt_len, "gen": req.gen,
            "queue_depth": self.scheduler.queue_depth(),
            "active": self.scheduler.n_active()})

    def _deadline_at(self, req: ServeRequest) -> float:
        """Absolute (run-clock) expiry; +inf when no deadline is set."""
        if req.deadline_s is None:
            return float("inf")
        return req.arrival + req.deadline_s

    def _estimate_completion_s(self, req: ServeRequest) -> Optional[float]:
        """Observed-latency completion estimate for deadline admission:
        queue wait + TTFT + gen * TPOT from the lm.* summaries.  None until
        enough traffic has been observed to estimate at all."""
        s = self.metrics
        if s._ttft.count < 1 or (req.gen > 1 and s._tpot.count < 1):
            return None
        return (s._queue_wait.mean + s._ttft.mean
                + max(req.gen - 1, 0) * s._tpot.mean)

    def submit(self, req: ServeRequest) -> None:
        if self.faults is not None:
            # admission stall: the front door pauses (queue growth +
            # deadline pressure); stall sites never raise
            self.faults.check(("admission", str(req.rid)))
        if req.prompt_len < 1 or req.gen < 1:
            self._reject(req, "empty prompt or zero gen")
            return
        if req.prompt_len + req.gen > self.max_seq:
            self._reject(req, "exceeds max_seq")
            return
        try:
            bucket = self.bucket_of(req.prompt_len)
        except ValueError:
            self._reject(req, "unbucketable")
            return
        if req.deadline_s is not None:
            now = self._now()
            if now >= self._deadline_at(req):
                self._resolve_deadline(req, phase="submit")
                return
            est = self._estimate_completion_s(req)
            if est is not None and now + est > self._deadline_at(req):
                # cannot finish in time at observed latencies: shedding at
                # the door beats burning KV pages on a doomed request
                self._reject(req, "deadline_unmeetable")
                return
        action = self.admission.decide(
            warm=self.is_warm(bucket),
            queue_depth=self.scheduler.queue_depth(),
            active=self.scheduler.n_active(), max_batch=self.max_batch)
        if action == "reject":
            self._reject(req, "queue full")
            return
        # "admit" and "queue" both enqueue; a cold bucket's head is only
        # *planned* once the decode batch drains (scheduler-side gate)
        self.scheduler.enqueue(req, bucket, self._now())

    # -- deadlines -----------------------------------------------------------
    def _resolve_deadline(self, req: ServeRequest, *, phase: str,
                          tokens: Sequence[int] = (), rm=None) -> None:
        """Resolve a request whose deadline expired: structured error,
        partial tokens preserved, one deadline_miss trip per request."""
        self.metrics.registry.count("serving.deadline_miss")
        self._results[req.rid] = ServeResult(
            req.rid, list(tokens), "deadline_exceeded", rm,
            error={"reason": "deadline_exceeded", "rid": str(req.rid),
                   "phase": phase, "deadline_s": req.deadline_s,
                   "tokens_done": len(tokens)})
        self.recorder.trip("deadline_miss", {
            "rid": str(req.rid), "phase": phase,
            "deadline_s": req.deadline_s, "now": self._now(),
            "tokens_done": len(tokens)})

    def _expire_deadlines(self) -> None:
        """Deadline sweep, run once per loop iteration: queued requests are
        dropped in place; active ones leave at this token boundary, their
        KV pages going straight back to the pool (ledger-verified — the
        pool's one allocation never leaks per-request state)."""
        now = self._now()
        for w in self.scheduler.waiting():
            if now >= self._deadline_at(w.request):
                self.scheduler.remove(w)
                self._resolve_deadline(w.request, phase="queued")
        for st in list(self.scheduler.active()):
            if now >= self._deadline_at(st.request):
                self.scheduler.leave(st.slot)
                self.pool.free(st.request.rid)
                st.rm.finished_at = now
                self._resolve_deadline(st.request, phase="decode",
                                       tokens=st.out, rm=st.rm)

    # -- prefill + join ------------------------------------------------------
    def _prefill_and_join(self, req: ServeRequest, bucket: int,
                          enqueued_at: float) -> None:
        rm = RequestMetrics(req.rid, bucket=bucket,
                            prompt_len=req.prompt_len, gen=req.gen,
                            submitted_at=enqueued_at)
        if self.faults is not None:
            # before any allocation: a prefill fault leaves nothing behind
            self.faults.check(("prefill", str(req.rid), bucket))
        fwd, jitted, plan_ms = self._plan_prefill(bucket)
        rm.plan_ms = plan_ms
        t0 = time.perf_counter()
        padded_np = np.zeros((1, bucket), np.int32)
        padded_np[0, :req.prompt_len] = req.prompt
        padded = jnp.asarray(padded_np)
        outs, first_dev = jitted(self.params, padded,
                                 jnp.int32(req.prompt_len))
        # reserve prompt + the first decode write (position prompt_len is
        # written by the first tick, before extend() is consulted)
        self.pool.alloc(req.rid, req.prompt_len + 1)
        if self.kv_mode:
            self.pool.seed(req.rid, outs[1:], req.prompt_len)
        else:
            # replay fallback: families with recurrent state (mamba/rwkv)
            # rebuild the prompt state through the cached decode path
            c1 = init_cache(self.model, 1, self.max_seq)
            for t in range(req.prompt_len):
                _, c1 = self._dstep1(self.params, c1,
                                     jnp.asarray(padded_np[:, t:t + 1]),
                                     jnp.int32(t))
            self.pool.adopt(req.rid, c1)
        first = int(first_dev)
        rm.prefill_ms = (time.perf_counter() - t0) * 1e3
        now = self._now()
        rm.joined_at = rm.first_token_at = now
        st = self.scheduler.join(req, pos=req.prompt_len, tok=first,
                                 first_out=first, now=now)
        st.rm = rm
        self.metrics.joins += 1
        if st.done:                          # gen == 1: prefill was enough
            self._finish(st, "ok")

    def _pop_prefill_batch(self, w) -> list:
        """Starting from the chosen head ``w``, pop up to ``prefill_batch``
        same-bucket waiting requests that the decode batch and KV pool can
        conservatively absorb together.  Returns [(req, enqueued_at), ...]."""
        batch = [(self.scheduler.pop(w), w.enqueued_at)]
        if not self.kv_mode or self.prefill_batch <= 1:
            return batch
        q = self.scheduler.queues.get(w.bucket)
        pending_pages = self.pool.pages_for(batch[0][0].prompt_len + 1)
        while (q and len(batch) < self.prefill_batch
               and self.scheduler.n_active() + len(batch)
               < self.scheduler.max_batch
               and len(self.pool._free_slots) > len(batch)):
            nxt = q[0]
            need = self.pool.pages_for(nxt.request.prompt_len + 1)
            if self.pool.pages_in_use + pending_pages + need > \
                    self.pool.page_budget:
                break
            batch.append((self.scheduler.pop(nxt), nxt.enqueued_at))
            pending_pages += need
        return batch

    def _try_join(self) -> bool:
        """Fill free decode slots from the wait queues: FIFO within bucket,
        longest-waiting-first across buckets; cold buckets only when the
        batch has drained enough to afford planning.  When several
        same-bucket requests are waiting, they prefill as ONE vmapped
        planned forward (satellite of the multi-query work: identical token
        streams, one dispatch)."""
        joined = False
        while self.scheduler.free_slot() is not None:
            warm = {b for b in self.scheduler.queues if self.is_warm(b)}
            w = self.scheduler.peek_next(warm_buckets=warm)
            if w is None and self.admission.can_plan_cold(
                    active=self.scheduler.n_active(),
                    max_batch=self.max_batch):
                w = self.scheduler.peek_next()
            if w is None:
                break
            if not self.pool.can_admit(w.request.prompt_len + 1):
                break                        # memory pressure: keep queueing
            bucket = w.bucket
            batch = self._pop_prefill_batch(w)
            if len(batch) == 1:
                req, enq = batch[0]
                try:
                    self._prefill_and_join(req, bucket, enq)
                except Exception as exc:
                    self._prefill_failure(req, bucket, enq, exc)
            else:
                self._prefill_and_join_many(batch, bucket)
            joined = True
        return joined

    def _prefill_and_join_many(self, batch: list, bucket: int) -> None:
        """Prefill a same-bucket group as one jitted vmapped forward and
        join each member; falls back to the sequential per-request path if
        the batched call fails (nothing was allocated yet)."""
        try:
            # one plan fetch per member: the batch serves N requests, and
            # each keeps its own plan-cache hit + plan_ms accounting (warm
            # fetches are cache lookups, not re-planning)
            plan_mss = []
            for _ in batch:
                fwd, _, plan_ms = self._plan_prefill(bucket)
                plan_mss.append(plan_ms)
            vj = self._vjit_prefill(fwd.plan_id)
            # pad to the bucket's one warmed width: a short batch wastes a
            # few pad rows but never triggers a serve-time recompile
            width = max(min(self.prefill_batch, self.max_batch), len(batch))
            toks = np.zeros((width, 1, bucket), np.int32)
            ns = np.ones((width,), np.int32)
            for i, (req, _) in enumerate(batch):
                toks[i, 0, :req.prompt_len] = req.prompt
                ns[i] = req.prompt_len
            t0 = time.perf_counter()
            outs, firsts = vj(self.params, jnp.asarray(toks),
                              jnp.asarray(ns))
            firsts = np.asarray(firsts)
            prefill_ms = (time.perf_counter() - t0) * 1e3
            self.registry.count("lm.batched_prefills", len(batch))
            self.registry.summary("lm.prefill_batch").observe(len(batch))
        except Exception:
            for req, enq in batch:           # degrade to per-request prefill
                try:
                    self._prefill_and_join(req, bucket, enq)
                except Exception as exc:
                    self._prefill_failure(req, bucket, enq, exc)
            return
        for i, (req, enq) in enumerate(batch):
            rm = RequestMetrics(req.rid, bucket=bucket,
                                prompt_len=req.prompt_len, gen=req.gen,
                                submitted_at=enq)
            rm.plan_ms = plan_mss[i]
            rm.prefill_ms = prefill_ms / len(batch)
            self.pool.alloc(req.rid, req.prompt_len + 1)
            kv_i = jax.tree.map(lambda x, _i=i: x[_i], outs[1:])
            self.pool.seed(req.rid, kv_i, req.prompt_len)
            first = int(firsts[i])
            now = self._now()
            rm.joined_at = rm.first_token_at = now
            st = self.scheduler.join(req, pos=req.prompt_len, tok=first,
                                     first_out=first, now=now)
            st.rm = rm
            self.metrics.joins += 1
            if st.done:
                self._finish(st, "ok")

    def _prefill_failure(self, req: ServeRequest, bucket: int,
                         enqueued_at: float, exc: Exception) -> None:
        """A prefill attempt died (injected or real).  Clean up any pages
        the attempt claimed, then either re-enqueue (bounded retries,
        retryable errors only) or resolve with a structured error."""
        if self.pool.holds(req.rid):
            self.pool.free(req.rid)
        err = classify(exc, plan_id=f"prefill_bucket_{bucket}")
        attempts = self._prefill_attempts.get(req.rid, 0) + 1
        self._prefill_attempts[req.rid] = attempts
        self.metrics.registry.count("serving.prefill_faults")
        self.recorder.record("prefill_fault", {
            "rid": str(req.rid), "bucket": bucket, "attempt": attempts,
            "error": err.to_dict()})
        if err.retryable and attempts <= self.prefill_retries:
            # back of its bucket queue: the retry is a fresh occurrence of
            # the fault site, so rate-injected faults clear on replay
            self.scheduler.enqueue(req, bucket, enqueued_at)
            return
        self._prefill_attempts.pop(req.rid, None)
        self._results[req.rid] = ServeResult(
            req.rid, [], "error", None,
            error={"reason": "prefill_failed", "rid": str(req.rid),
                   "attempts": attempts, **err.to_dict()})
        self.recorder.trip("prefill_error", {
            "rid": str(req.rid), "bucket": bucket, "attempts": attempts,
            "error": err.to_dict()})

    # -- decode -------------------------------------------------------------
    def _finish(self, st, status: str, error: Optional[dict] = None) -> None:
        self.scheduler.leave(st.slot)
        self.pool.free(st.request.rid)
        self._prefill_attempts.pop(st.request.rid, None)
        st.rm.finished_at = self._now()
        self.metrics.finish(st.rm)
        self._results[st.request.rid] = ServeResult(
            st.request.rid, list(st.out), status, st.rm, error=error)

    def _decode_tick(self) -> bool:
        """One continuous-batching step: every active slot decodes one token
        at its own position; finished requests leave at this boundary."""
        active = self.scheduler.active()
        self.metrics.observe_tick(self.scheduler.queue_depth(),
                                  self.pool.occupancy()["fill"])
        self._maybe_snapshot()
        if not active:
            return False
        self._tick_no += 1
        if self.faults is not None:
            # the fault fires BEFORE the donated decode call, so a faulted
            # tick leaves the pool cache and every slot position untouched
            # — the retry is simply the next loop iteration re-running the
            # identical tick
            try:
                self.faults.check(("decode", self._tick_no))
            except FaultInjectedError as exc:
                self._decode_faults += 1
                self.metrics.registry.count("serving.decode_faults")
                self.recorder.record("decode_fault", {
                    "tick": self._tick_no, "consecutive": self._decode_faults,
                    "error": repr(exc)})
                if self._decode_faults > self.decode_fault_cap:
                    # persistently broken decode: fail the active batch
                    # with structured errors instead of spinning forever
                    detail = {"reason": "decode_failed",
                              "consecutive_faults": self._decode_faults,
                              "error": repr(exc)}
                    self.recorder.trip("decode_error", detail)
                    for st in list(self.scheduler.active()):
                        self._finish(st, "error",
                                     error={**detail,
                                            "rid": str(st.request.rid)})
                    self._decode_faults = 0
                return True
        self._decode_faults = 0
        toks = np.zeros((self.max_batch, 1), np.int32)
        idxs = np.zeros((self.max_batch,), np.int32)
        for st in active:
            toks[st.slot, 0] = st.tok
            idxs[st.slot] = st.pos
        logits, self.pool.cache = self._dstep(
            self.params, self.pool.cache, jnp.asarray(toks),
            jnp.asarray(idxs))
        logits = np.asarray(logits)
        for st in active:
            st.tok = int(np.argmax(logits[st.slot, 0, :self.cfg.vocab]))
            st.pos += 1
            st.out.append(st.tok)
            if st.done:
                self._finish(st, "ok")
            elif not self.pool.extend(st.request.rid, st.pos + 1):
                self._finish(st, "truncated")   # page budget exhausted
        return True

    # -- event loop ----------------------------------------------------------
    async def _submit_all(self, pending) -> None:
        for r in pending:
            delay = r.arrival - self._now()
            if delay > 0:
                await asyncio.sleep(delay)
            self.submit(r)

    def _fail_outstanding(self, requests, timeout_s: float) -> None:
        """Loop timeout: resolve every request that has no result yet with
        a structured timeout error and return its resources — active slots
        leave (KV pages freed through the normal _finish path), queued
        entries drop, never-submitted ones resolve too.  One serve_timeout
        trip captures the stuck state."""
        self.recorder.trip("serve_timeout", {
            "timeout_s": timeout_s, "done": len(self._results),
            "expected": len(requests),
            "queue_depth": self.scheduler.queue_depth(),
            "active": self.scheduler.n_active(),
            "telemetry": self.telemetry_snapshot()})
        for st in list(self.scheduler.active()):
            self._finish(st, "timeout",
                         error={"reason": "timeout", "phase": "decode",
                                "rid": str(st.request.rid),
                                "timeout_s": timeout_s,
                                "tokens_done": len(st.out)})
        for w in list(self.scheduler.waiting()):
            self.scheduler.remove(w)
        for r in requests:
            if r.rid not in self._results:
                self._results[r.rid] = ServeResult(
                    r.rid, [], "timeout", None,
                    error={"reason": "timeout", "phase": "queued",
                           "rid": str(r.rid), "timeout_s": timeout_s})

    async def run(self, requests: Sequence[ServeRequest],
                  timeout_s: float = 300.0) -> list:
        """Serve a trace of requests; returns ServeResults in input order.
        Every request terminates with a result or a structured error: a
        loop timeout resolves the outstanding requests (freeing their KV
        slots) instead of raising out of the loop."""
        self._t0 = time.perf_counter()
        pending = sorted(requests, key=lambda r: r.arrival)
        n_expected = len(pending)
        submitter = asyncio.ensure_future(self._submit_all(pending))
        try:
            while len(self._results) < n_expected:
                if self._now() > timeout_s:
                    self._fail_outstanding(requests, timeout_s)
                    break
                self._expire_deadlines()
                progressed = self._try_join()
                progressed = self._decode_tick() or progressed
                # yield so arrivals interleave with serving; back off when
                # idle (waiting on future arrivals)
                await asyncio.sleep(0 if progressed else 0.0005)
        finally:
            submitter.cancel()
        if self.plan_cache_dir:
            save_plan_cache(self.pc, self.plan_cache_dir)
        return [self._results[r.rid] for r in requests]

    def serve(self, requests: Sequence[ServeRequest],
              timeout_s: float = 300.0) -> list:
        """Synchronous wrapper around :meth:`run`.  Refuses to nest inside
        a running event loop (asyncio.run would raise a cryptic
        RuntimeError after partial work)."""
        try:
            asyncio.get_running_loop()
        except RuntimeError:
            return asyncio.run(self.run(requests, timeout_s=timeout_s))
        raise RuntimeError(
            "serve() was called from a running event loop; call "
            "`await runtime.run(requests, timeout_s=...)` instead")

    # -- analytical requests --------------------------------------------------
    def _trip_context(self) -> dict:
        """Incident context for executor_error trips: memory + occupancy
        state at failure time, not just the exception repr."""
        return {"ledger": self.ledger.snapshot(),
                "metrics": self.registry.report()}

    # -- multi-query analytics ------------------------------------------------
    def _analysis_exec(self, req: AnalysisRequest, keys=None):
        """One analytical query through the cross-query CSE path (subplan
        cache attached) or plain execution; returns (value, frontier
        info)."""
        if self.subplans is not None:
            out, info = mqo_run(req.planned, req.params, req.inputs,
                                cache=self.subplans,
                                versions=req.store_versions,
                                aux=req.aux, keys=keys, tied_to=req.tied_to)
        else:
            out = req.planned(req.params, req.inputs, aux=req.aux)
            info = {"shared_hits": 0,
                    "executed": len(req.planned.concrete.nodes)}
        jax.block_until_ready(out)
        return out, info

    @staticmethod
    def _leaf_sig(value) -> tuple:
        """Shape/dtype signature of a pytree — batchable queries must agree
        on it so stacking is well-formed."""
        return tuple((str(getattr(x, "dtype", type(x).__name__)),
                      tuple(getattr(x, "shape", ())))
                     for x in jax.tree.leaves(value))

    def _batch_group_key(self, req: AnalysisRequest, keys: dict) -> tuple:
        """Queries coalesce into one vmapped forward iff they share a plan,
        the same declared ``batch_param`` slot, the same *objects* for
        every other input, and the same batch-leaf shape/dtype.  Object
        identity is conservative (equal-but-distinct arrays miss the
        batch) but sound, and it is how multi-query workloads actually
        share bound payloads; the ids stay valid because the requests hold
        their inputs alive through the tick."""
        bp = req.batch_param
        fixed = tuple(sorted(
            (n, id(v)) for n, v in req.inputs.items() if n != bp))
        return (getattr(req.planned, "plan_id", id(req.planned)), bp,
                fixed, self._leaf_sig(req.inputs[bp]),
                "noparams" if not req.params else id(req.params))

    def _run_batched_group(self, leaders: list):
        """Execute same-shape queries as ONE vmapped planned forward over
        their stacked ``batch_param`` leaves; returns per-query values.
        vmap without jit: every primitive executes batched but *eagerly*,
        the same dispatch path the unbatched queries take."""
        bp = leaders[0].batch_param
        planned = leaders[0].planned
        fixed = {n: v for n, v in leaders[0].inputs.items() if n != bp}
        stacked = jax.tree.map(
            lambda *xs: jnp.stack([jnp.asarray(x) for x in xs], axis=0),
            *[r.inputs[bp] for r in leaders])
        params = leaders[0].params
        aux = leaders[0].aux

        def one(pv):
            return planned(params, {**fixed, bp: pv}, aux=aux)

        outs = jax.vmap(one)(stacked)
        jax.block_until_ready(outs)
        vals = [jax.tree.map(lambda x, _i=i: x[_i], outs)
                for i in range(len(leaders))]
        self.registry.count("analytics.batched", len(leaders))
        return vals

    def _root_key(self, req: AnalysisRequest, keys: dict) -> tuple:
        """The whole-query identity: plan id + the runtime keys of its
        outputs — two queries with equal root keys compute the same
        values, whatever their programs looked like."""
        return (getattr(req.planned, "plan_id", id(req.planned)),
                tuple(keys.get(o, o) for o in req.planned.concrete.outputs))

    def _settle_analysis(self, req: AnalysisRequest, res: AnalysisResult,
                         results: dict, t0: float) -> None:
        # route to the owning run_analyses call's results dict (a tick may
        # have drained a concurrent call's request); fall back to the
        # draining call's dict for requests with no registered owner
        sink, st0 = self._analysis_sinks.get(id(req), (results, t0))
        res.ttfr_ms = (time.perf_counter() - st0) * 1e3
        self.registry.summary("analytics.ttfr_ms").observe(res.ttfr_ms)
        self.registry.count("analytics.requests")
        sink[req.rid] = res

    async def _admit_analysis_tick(self, tick: list, results: dict,
                                   t0: float) -> None:
        """One admission tick: key every drained query, dedupe exact twins
        (intra-tick groups + cross-task in-flight futures), coalesce
        same-shape queries into vmapped batches, run the rest through the
        CSE path, and resolve every request with a result."""
        loop = asyncio.get_running_loop()
        groups: dict = {}        # root key -> [(req, keys), ...]
        waiters: list = []       # (req, future of an in-flight twin)
        for req in tick:
            keys = subdag_keys(req.planned, req.inputs,
                               versions=req.store_versions,
                               params=req.params)
            root = self._root_key(req, keys)
            fut = self._analysis_inflight.get(root)
            if fut is not None and root not in groups:
                waiters.append((req, fut))
                continue
            groups.setdefault(root, []).append((req, keys))
        # same-shape batching among group leaders (>=2 make a batch)
        singles, shaped = [], {}
        for root, members in groups.items():
            leader = members[0][0]
            if leader.batch_param is not None \
                    and leader.batch_param in leader.inputs:
                gk = self._batch_group_key(leader, members[0][1])
                shaped.setdefault(gk, []).append((root, members))
            else:
                singles.append((root, members))
        vbatches = []
        for g in shaped.values():
            if len(g) >= 2:
                vbatches.append(g)
            else:
                singles.extend(g)
        futs = {}
        for root, _ in singles:
            futs[root] = self._analysis_inflight[root] = loop.create_future()
        for g in vbatches:
            for root, _ in g:
                futs[root] = self._analysis_inflight[root] = \
                    loop.create_future()

        def resolve(root, members, payload, *, batched=False):
            status, val, info, err = payload
            fut = futs[root]
            if not fut.done():
                fut.set_result(payload)
            self._analysis_inflight.pop(root, None)
            for j, (req, _) in enumerate(members):
                if j > 0:
                    self.registry.count("analytics.deduped")
                res = AnalysisResult(
                    req.rid, val, status, err,
                    shared_hits=info.get("shared_hits", 0),
                    executed=info.get("executed", 0),
                    deduped=j > 0, batched=batched)
                self._settle_analysis(req, res, results, t0)

        for g in vbatches:
            leaders = [members[0][0] for _, members in g]
            try:
                vals = self._run_batched_group(leaders)
                for (root, members), val in zip(g, vals):
                    resolve(root, members,
                            ("ok", val, {"executed": 1}, None), batched=True)
            except Exception as exc:
                # vmap refused the plan (data-dependent shapes, host
                # callbacks): run each leader through the CSE path instead
                self.recorder.record("batch_fallback", {
                    "n": len(g), "error": repr(exc)})
                singles.extend(g)
        for root, members in singles:
            leader, lkeys = members[0]
            try:
                val, info = self._analysis_exec(leader, keys=lkeys)
                resolve(root, members, ("ok", val, info, None))
            except Exception as exc:
                err = {"reason": "analysis_failed",
                       "plan_id": getattr(leader.planned, "plan_id", ""),
                       "error": repr(exc)}
                self.recorder.trip("executor_error",
                                   {**err, **self._trip_context()})
                resolve(root, members, ("error", None, {}, err))
            await asyncio.sleep(0)   # let twins land on the future map
        for req, fut in waiters:
            status, val, info, err = await fut
            self.registry.count("analytics.deduped")
            res = AnalysisResult(req.rid, val, status, err,
                                 shared_hits=info.get("shared_hits", 0),
                                 deduped=True)
            self._settle_analysis(req, res, results, t0)

    async def run_analyses(self, requests: Sequence[AnalysisRequest],
                           timeout_s: float = 300.0) -> list:
        """Serve a set of analytical queries through the multi-query
        admission path: per-tenant weighted round-robin drains up to
        ``analysis_tick`` queries per tick; each tick dedupes exact twins
        (single-flight — the first computes, the rest await its future),
        coalesces same-shape queries into one vmapped forward, and runs
        the remainder through the subplan-cache CSE pass.  Returns
        AnalysisResults in input order; every query resolves (errors are
        structured, a loop timeout resolves stragglers)."""
        t0 = time.perf_counter()
        results: dict = {}
        mine = {id(r) for r in requests}
        for r in requests:
            self._analysis_sinks[id(r)] = (results, t0)
            self.analysis_sched.enqueue(r, r.tenant)
        try:
            # completion is scoped to THIS call's requests: a tick may
            # settle a concurrent call's drained query into that call's
            # sink (or pick up extras), so len(results) alone can't gate
            while any(r.rid not in results for r in requests):
                if time.perf_counter() - t0 > timeout_s:
                    # pull this call's undrained stragglers out of the
                    # shared tenant queues so a later call can't adopt
                    # them, then resolve them with structured timeouts
                    self.analysis_sched.purge(lambda item: id(item) in mine)
                    for r in requests:
                        if r.rid not in results:
                            self._settle_analysis(r, AnalysisResult(
                                r.rid, None, "error",
                                {"reason": "timeout",
                                 "timeout_s": timeout_s}),
                                results, t0)
                    break
                tick = self.analysis_sched.drain(self.analysis_tick)
                if not tick:
                    await asyncio.sleep(0.0005)
                    continue
                await self._admit_analysis_tick(tick, results, t0)
                await asyncio.sleep(0)
        finally:
            for r in requests:
                self._analysis_sinks.pop(id(r), None)
        self._maybe_snapshot(force=True)
        return [results[r.rid] for r in requests]

    def serve_analyses(self, requests: Sequence[AnalysisRequest],
                       timeout_s: float = 300.0) -> list:
        """Synchronous wrapper around :meth:`run_analyses` (same nesting
        rule as :meth:`serve`)."""
        try:
            asyncio.get_running_loop()
        except RuntimeError:
            return asyncio.run(self.run_analyses(requests,
                                                 timeout_s=timeout_s))
        raise RuntimeError(
            "serve_analyses() was called from a running event loop; call "
            "`await runtime.run_analyses(...)` instead")

    def run_analysis(self, planned, params, inputs: dict, *,
                     analyze: bool = False, aux: Optional[dict] = None,
                     deadline_s: Optional[float] = None,
                     degrade=None, store_versions: tuple = (),
                     tied_to=None):
        """Execute an analytical (tri-store) :class:`PlannedFunction`
        through the runtime's shared metrics registry, so LM and
        analytical traffic report into one place: wall time lands in the
        ``analytics.run_ms`` summary, request/trace counts in
        ``analytics.*`` counters.  With ``analyze=True`` the run goes
        through ``PlannedFunction.analyze`` (EXPLAIN ANALYZE tracing) and
        the trace's wall/sync split is recorded too.  Either path feeds the
        flight recorder: traced runs land their RunTrace summary in the
        ring (and trip a dump on BoundedRel overflow, inside ``analyze``);
        an executor exception trips an ``executor_error`` dump carrying the
        current ledger snapshot + metrics report.

        ``degrade``: with a :class:`~repro.serving.degrade.DegradePolicy`
        attached to the runtime, a standing query is transparently switched
        to its cheaper variant under overload — pass an int to force a
        ladder level, ``False`` to opt this call out.  ``deadline_s``
        bounds the run's wall time *post hoc*: a miss lands an
        ``analytics.deadline_miss`` count and a recorder event (analytical
        plans execute as one JAX computation — there is no token boundary
        to cancel at, so the deadline informs shedding, not abortion)."""
        if degrade is not False and self.degrade is not None:
            lvl = degrade if isinstance(degrade, int) \
                and not isinstance(degrade, bool) else \
                self.degrade.level(
                    queue_depth=self.scheduler.queue_depth(),
                    max_batch=self.max_batch,
                    kv_fill=self.pool.occupancy()["fill"])
            if lvl > 0:
                planned = self.degrade.replan(planned, lvl, cache=self.pc)
        if self.faults is not None and planned.faults is None:
            planned.faults = self.faults
        t0 = time.perf_counter()
        try:
            if analyze:
                outs = planned.analyze(params, inputs, aux=aux,
                                       recorder=self.recorder,
                                       trip_context=self._trip_context)
                tr = planned.last_run_trace
                self.registry.summary("analytics.trace_wall_ms").observe(
                    tr.wall_ms)
                self.registry.summary("analytics.sync_ms").observe(
                    tr.sync_ms)
                self.registry.count("analytics.traced")
            elif self.subplans is not None and planned.faults is None:
                # cross-query CSE: reuse cached sub-DAG intermediates and
                # execute only the residual suffix (bitwise-identical — the
                # reused values are an identical computation's arrays)
                outs, _info = mqo_run(planned, params, inputs,
                                      cache=self.subplans,
                                      versions=store_versions, aux=aux,
                                      tied_to=tied_to)
                jax.block_until_ready(outs)
            else:
                outs = planned(params, inputs, aux=aux)
                jax.block_until_ready(outs)
        except Exception as exc:
            # analyze() already tripped for its own failures (with the same
            # trip context); only the untraced path needs capture here
            if not analyze:
                self.recorder.trip("executor_error", {
                    "plan_id": getattr(planned, "plan_id", ""),
                    "error": repr(exc), **self._trip_context()})
            raise
        elapsed_s = time.perf_counter() - t0
        if deadline_s is not None and elapsed_s > deadline_s:
            self.registry.count("analytics.deadline_miss")
            self.recorder.record("deadline_miss", {
                "plan_id": planned.plan_id, "kind": "analysis",
                "deadline_s": deadline_s, "elapsed_s": elapsed_s})
        self.registry.summary("analytics.run_ms").observe(elapsed_s * 1e3)
        self.registry.count("analytics.requests")
        self._maybe_snapshot(force=True)
        return outs


def serve_sequential(model: LM, params, requests: Sequence[ServeRequest], *,
                     max_seq: int = 128, bucket_lo: int = 8,
                     engines=("xla",), syscat=None, plan_cache=None,
                     jit_memo: Optional[dict] = None) -> list:
    """The sequential seed path, as a baseline: one request at a time —
    planned (bucketed, cached) prefill for the prompt logits, prompt replay
    through the cached decode path to build the KV cache, then
    token-by-token decode at batch 1.  What ``launch/serve.py`` did before
    the async runtime; kept for the throughput benchmark's comparison."""
    syscat = syscat or SystemCatalog()
    pc = plan_cache if plan_cache is not None else default_plan_cache()
    cfg = model.cfg
    # ``jit_memo`` (caller-held) keeps the jitted step/prefills warm across
    # invocations — the benchmark warms the baseline with it so the
    # comparison against the runtime excludes compile time on both sides
    jitted = jit_memo if jit_memo is not None else {}
    if "__dstep__" not in jitted:
        jitted["__dstep__"] = jax.jit(
            lambda p, c, t, i: decode_step(model, p, c, t, i))
    dstep = jitted["__dstep__"]
    results = []
    for req in requests:
        bucket = bucket_len(req.prompt_len, lo=bucket_lo, hi=max_seq)
        plan = model.build_plan(1, bucket, mode="prefill")
        fwd = plan_and_compile(plan, CATALOG, syscat, engines=engines,
                               cache=pc)
        jf = jitted.get(fwd.plan_id)
        if jf is None:
            def jf(p, toks, n, _f=fwd):
                logits = _f(p, {"tokens": toks})
                row = jax.lax.dynamic_index_in_dim(logits, n - 1, axis=1,
                                                   keepdims=False)
                return jnp.argmax(row[0, :cfg.vocab]).astype(jnp.int32)
            jf = jitted[fwd.plan_id] = jax.jit(jf)
        padded_np = np.zeros((1, bucket), np.int32)
        padded_np[0, :req.prompt_len] = req.prompt
        tok = int(jf(params, jnp.asarray(padded_np),
                     jnp.int32(req.prompt_len)))
        cache = init_cache(model, 1, max_seq)
        for t in range(req.prompt_len):
            _, cache = dstep(params, cache,
                             jnp.asarray(padded_np[:, t:t + 1]),
                             jnp.int32(t))
        out = [tok]
        for t in range(req.prompt_len, req.prompt_len + req.gen - 1):
            lg, cache = dstep(params, cache,
                              jnp.asarray([[tok]], jnp.int32), jnp.int32(t))
            tok = int(jnp.argmax(lg[0, 0, :cfg.vocab]))
            out.append(tok)
        results.append(ServeResult(req.rid, out, "ok", None))
    return results
