"""Graceful degradation: shed load by cheapening standing analytical plans.

Under overload the right tri-store behaviour is not "queue forever" or
"reject everything" but *degrade*: a standing analytical query (social-feed
ranking, trend detection) usually tolerates a cheaper answer — fewer top-k
results, fewer PageRank power iterations — far better than a missed
deadline.  BigDAWG calls this degraded cross-island execution; here it is a
**plan-level** ladder: the :class:`DegradePolicy` clamps the cost-carrying
attrs of the *logical* plan (``k`` on ``text_topk`` / ``masked_topk``,
``iters`` on ``graph_pagerank``) and recompiles through the staged
pipeline, so the degraded variant has a provably different ``plan_id``
(the clamped attrs are part of the plan's content hash) and is itself
plan-cache-warm on repeat — a standing query flips between its full and
degraded variants with zero replanning cost after the first switch.

Every degradation is observable: an ``analytics.degraded`` counter, a
per-level counter, and a flight-recorder event carrying the exact attr
clamps applied.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

from ..core.executor import PlannedFunction
from ..core.ir import infer_types

# op -> attr the ladder clamps (missing attrs fall back to op defaults)
_CLAMP_ATTRS = {
    "text_topk": "k",
    "masked_topk": "k",
    "graph_pagerank": "iters",
}
_PAGERANK_DEFAULT_ITERS = 10


@dataclass
class DegradePolicy:
    """A two-rung degrade ladder over analytical plan attrs.

    ``ladder[level - 1]`` maps attr name -> cap for that level; level 0 is
    "no degradation".  :meth:`level` turns overload signals (queue depth,
    KV fill) into a rung; :meth:`replan` produces the degraded
    PlannedFunction."""

    catalog: Any                      # FunctionCatalog for re-inference
    ladder: tuple = (
        {"k": 32, "iters": 5},        # level 1: mild shedding
        {"k": 8, "iters": 3},         # level 2: survival mode
    )
    queue_hi: float = 1.0             # queue_depth / max_batch ratios
    queue_crit: float = 2.0
    fill_hi: float = 0.80             # KV pool fill fractions
    fill_crit: float = 0.95
    registry: Optional[Any] = None
    recorder: Optional[Any] = None
    events: list = field(default_factory=list)

    @property
    def max_level(self) -> int:
        return len(self.ladder)

    def level(self, *, queue_depth: int = 0, max_batch: int = 1,
              kv_fill: float = 0.0) -> int:
        """Overload signals -> ladder rung.  Queue depth is normalized by
        the decode batch width (a 4-wide runtime with 8 queued is twice
        oversubscribed); KV fill is the memory-pressure signal."""
        q = queue_depth / max(max_batch, 1)
        if q >= self.queue_crit or kv_fill >= self.fill_crit:
            return min(2, self.max_level)
        if q >= self.queue_hi or kv_fill >= self.fill_hi:
            return min(1, self.max_level)
        return 0

    # -- plan surgery ------------------------------------------------------
    def degrade_logical(self, plan, lvl: int):
        """Copy the logical plan with the level's caps applied; returns
        ``(plan2, changes)`` where changes lists every clamp as
        ``(node_id, attr, before, after)``.  Empty changes means the plan
        has nothing to cheapen at this level."""
        if lvl <= 0:
            return plan, []
        caps = self.ladder[min(lvl, self.max_level) - 1]
        plan2 = plan.copy()
        changes = []

        def visit(p):
            for n in p.topo():
                if n.subplan is not None:
                    visit(n.subplan)
                attr = _CLAMP_ATTRS.get(n.op)
                if attr is None or attr not in caps:
                    continue
                default = (_PAGERANK_DEFAULT_ITERS
                           if attr == "iters" else None)
                cur = n.attrs.get(attr, default)
                if cur is None:
                    continue
                cap = int(caps[attr])
                if int(cur) > cap:
                    n.attrs[attr] = cap
                    changes.append((n.id, attr, int(cur), cap))

        visit(plan2)
        if changes:
            # clamped k changes output capacities: re-infer the metadata
            # map so the planner prices the cheaper plan, not the old one
            infer_types(plan2, self.catalog)
        return plan2, changes

    def replan(self, planned: PlannedFunction, lvl: int, *,
               cache=None) -> PlannedFunction:
        """The degraded variant of a compiled analytical function.  Same
        runtime bindings (mesh / rules / interpret / faults); different —
        and provably different — plan id whenever anything was clamped.
        Returns ``planned`` unchanged when the level clamps nothing."""
        from ..core.pipeline import compile_staged
        logical2, changes = self.degrade_logical(planned.logical, lvl)
        if not changes:
            return planned
        staged = compile_staged(
            logical2, self.catalog, planned.syscat,
            options=planned.staged.options if planned.staged else None,
            cache=cache, extra_key=(("degrade_level", int(lvl)),))
        fn = PlannedFunction.from_staged(
            staged, planned.syscat, rules=planned.rules,
            mesh=planned.mesh, interpret=planned.interpret)
        fn.faults = planned.faults
        self._observe(lvl, planned.plan_id, fn.plan_id, changes)
        return fn

    def _observe(self, lvl, plan_id, degraded_id, changes) -> None:
        event = {"level": int(lvl), "plan_id": plan_id,
                 "degraded_plan_id": degraded_id,
                 "clamps": [{"node": n, "attr": a, "from": b, "to": c}
                            for n, a, b, c in changes]}
        self.events.append(event)
        if self.registry is not None:
            self.registry.count("analytics.degraded")
            self.registry.count(f"analytics.degraded.level{int(lvl)}")
        if self.recorder is not None:
            self.recorder.record("degrade", event)


__all__ = ["DegradePolicy"]
