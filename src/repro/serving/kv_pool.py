"""Preallocated paged KV-cache pool with per-request page tables.

The pool allocates the full decode cache **once** — batch axis =
``n_slots``, sequence axis = ``max_seq`` — and batch-membership changes are
pure bookkeeping: a joining request claims a free slot and its prefill K/V
is written into that slot's rows; a leaving request only returns its slot
and pages.  Nothing is reallocated, so the jitted batched decode step keeps
its shapes for the lifetime of the runtime.

Sequence capacity is accounted in fixed-size **pages**: a request holds
``ceil(tokens / page_size)`` pages from a global budget, recorded in its
:class:`PageTable`, and acquires its next page lazily as decode crosses a
page boundary.  Pages are slot-local — physical page ``(slot, j)`` backs
logical page ``j`` — which keeps every per-request cache region contiguous
(attention needs no gather; a deliberate simplification vs fully scattered
vLLM-style paging) while still giving the admission side a token-granular
occupancy signal: with ``page_budget`` below ``n_slots * pages_per_slot``
the pool refuses joins on memory pressure even when slots are free.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from ..models.decode import attn_block_indices, init_cache


@dataclass
class PageTable:
    """Logical→physical page map for one request (pages are slot-local)."""

    request_id: object
    slot: int
    page_size: int
    pages: list = field(default_factory=list)   # [(slot, j), ...] in order

    @property
    def n_tokens_capacity(self) -> int:
        return len(self.pages) * self.page_size

    def covers(self, n_tokens: int) -> bool:
        return n_tokens <= self.n_tokens_capacity


class PagedKVPool:
    def __init__(self, model, n_slots: int, max_seq: int, *,
                 page_size: int = 16, page_budget: int | None = None,
                 registry=None, ledger=None):
        if n_slots < 1 or max_seq < 1 or page_size < 1:
            raise ValueError("n_slots, max_seq, page_size must be >= 1")
        self.model = model
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.pages_per_slot = math.ceil(max_seq / page_size)
        total = n_slots * self.pages_per_slot
        self.page_budget = total if page_budget is None else \
            min(page_budget, total)
        # the one allocation: full-length, unquantized caches (prefill_kv
        # seeding and per-slot decode positions need non-ring layouts)
        self.cache = init_cache(model, n_slots, max_seq)
        self._free_slots = list(range(n_slots))
        self._tables: dict = {}      # request_id -> PageTable
        self.pages_in_use = 0
        # occupancy/fragmentation gauges live in the shared registry (one
        # report covers serving + analytics); the ledger records the one
        # allocation — resident for the runtime's lifetime, so it never
        # re-registers
        self.registry = registry
        if ledger is not None:
            ledger.register(("kv_pool", f"{id(self):#x}"), self.cache,
                            kind="kv_pool")
        self._update_gauges()
        # jitted write paths with a *traced* slot index: one XLA program per
        # prefill bucket (seed) / one total (adopt), instead of an eager
        # recompile per (slot, prompt_len) combination on every join.  The
        # pool cache is donated so the update aliases in place on backends
        # that support donation (CPU ignores it) instead of copying the
        # whole pool on every join.
        self._seed_jit = jax.jit(self._seed_impl, donate_argnums=0)
        self._adopt_jit = jax.jit(self._adopt_impl, donate_argnums=0)

    # -- admission-facing capacity -----------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        return max(1, math.ceil(n_tokens / self.page_size))

    def can_admit(self, n_tokens: int) -> bool:
        if n_tokens > self.max_seq:
            return False
        return bool(self._free_slots) and \
            self.pages_in_use + self.pages_for(n_tokens) <= self.page_budget

    # -- page-table lifecycle ----------------------------------------------
    def alloc(self, request_id, n_tokens: int) -> PageTable | None:
        """Claim a slot + the pages covering ``n_tokens`` (the prompt).
        Returns None when out of slots or pages (caller keeps queueing)."""
        if request_id in self._tables:
            raise ValueError(f"request {request_id!r} already in pool")
        if not self.can_admit(n_tokens):
            return None
        slot = self._free_slots.pop(0)
        n_pages = self.pages_for(n_tokens)
        pt = PageTable(request_id, slot, self.page_size,
                       [(slot, j) for j in range(n_pages)])
        self._tables[request_id] = pt
        self.pages_in_use += n_pages
        self._update_gauges()
        return pt

    def extend(self, request_id, n_tokens: int) -> bool:
        """Grow a request's page table to cover ``n_tokens`` (decode crossing
        a page boundary).  False when the budget or the slot is exhausted —
        the runtime must finish/evict the request."""
        pt = self._tables[request_id]
        if pt.covers(n_tokens):
            return True
        if n_tokens > self.max_seq:
            return False
        need = self.pages_for(n_tokens) - len(pt.pages)
        if self.pages_in_use + need > self.page_budget:
            return False
        start = len(pt.pages)
        pt.pages.extend((pt.slot, j) for j in range(start, start + need))
        self.pages_in_use += need
        self._update_gauges()
        return True

    def free(self, request_id) -> int:
        """Release a request's slot and pages; returns the freed slot."""
        pt = self._tables.pop(request_id)
        self.pages_in_use -= len(pt.pages)
        self._free_slots.append(pt.slot)
        self._free_slots.sort()
        if self.registry is not None:
            # final page count = the request's lifetime footprint
            self.registry.summary("kv.pages_per_request").observe(
                len(pt.pages))
        self._update_gauges()
        return pt.slot

    def table(self, request_id) -> PageTable:
        return self._tables[request_id]

    def holds(self, request_id) -> bool:
        """True while the request owns a slot + pages (fault-path cleanup
        checks this before freeing, since prefill faults can land either
        side of the alloc)."""
        return request_id in self._tables

    # -- data path ----------------------------------------------------------
    def _seed_impl(self, cache, kv_groups, slot):
        new = {g: dict(c) for g, c in cache.items()}
        for g, kv_g in zip(self.model.groups, kv_groups):
            gc = new[g.name]
            for bi, (k, v) in zip(attn_block_indices(g), kv_g):
                for key, val in ((f"b{bi}_k", k), (f"b{bi}_v", v)):
                    leaf = gc[key]
                    starts = (0, slot) + (0,) * (leaf.ndim - 2)
                    gc[key] = jax.lax.dynamic_update_slice(
                        leaf, val.astype(leaf.dtype), starts)
        return new

    def _adopt_impl(self, cache, cache1, slot):
        def upd(leaf, src):
            starts = (0, slot) + (0,) * (leaf.ndim - 2)
            return jax.lax.dynamic_update_slice(
                leaf, src.astype(leaf.dtype), starts)
        return jax.tree.map(upd, cache, cache1)

    def seed(self, request_id, kv_groups, prompt_len: int) -> int:
        """Write a batch-1 ``prefill_kv`` plan output into the request's
        slot; returns the slot.  The full bucket (prompt + right padding) is
        written: padded positions are never read — decode overwrites
        position p before the valid mask reaches it — and a fixed write
        extent keeps this a single compiled program per bucket.  O(bucket)
        data movement — the join cost."""
        pt = self._tables[request_id]
        for g, kv_g in zip(self.model.groups, kv_groups):
            for _bi, (k, _v) in zip(attn_block_indices(g), kv_g):
                if f"b{_bi}_ksc" in self.cache[g.name] or \
                        k.shape[2] > self.max_seq:
                    raise ValueError(
                        "KV pool needs full-length, unquantized caches")
        self.cache = self._seed_jit(self.cache, tuple(kv_groups),
                                    jnp.int32(pt.slot))
        return pt.slot

    def adopt(self, request_id, cache1) -> int:
        """Write a batch-1 decode cache (the replay-prefill fallback for
        recurrent families) into the request's slot; returns the slot."""
        pt = self._tables[request_id]
        self.cache = self._adopt_jit(self.cache, cache1, jnp.int32(pt.slot))
        return pt.slot

    def occupancy(self) -> dict:
        return {
            "slots_used": self.n_slots - len(self._free_slots),
            "n_slots": self.n_slots,
            "pages_used": self.pages_in_use,
            "page_budget": self.page_budget,
            "page_size": self.page_size,
            "fill": self.pages_in_use / max(self.page_budget, 1),
        }

    def fragmentation(self) -> dict:
        """Free-space shape, not just amount.  Pages are slot-local and
        each slot's used pages are a prefix, so the free space is one tail
        run per slot; ``max_contig_free_run`` — the longest such run,
        counting runs that span consecutive fully-free slots — is the
        largest single-request footprint that can still be admitted
        without eviction."""
        free_pages = self.page_budget - self.pages_in_use
        used_by_slot = {}
        for pt in self._tables.values():
            used_by_slot[pt.slot] = used_by_slot.get(pt.slot, 0) \
                + len(pt.pages)
        # slot-major page order: a used slot's occupied prefix breaks the
        # run, its free tail starts the next one (adjacent to the next
        # slot's first page); fully-free slots extend the current run
        max_run = 0
        cur = 0
        for slot in range(self.n_slots):
            used = used_by_slot.get(slot, 0)
            if used:
                max_run = max(max_run, cur)
                cur = self.pages_per_slot - used
            else:
                cur += self.pages_per_slot
        max_run = max(max_run, cur)
        # the budget caps any admission below the geometric free run
        max_run = min(max_run, free_pages)
        return {"free_pages": free_pages,
                "free_slots": len(self._free_slots),
                "max_contig_free_run": max_run}

    def _update_gauges(self) -> None:
        if self.registry is None:
            return
        frag = self.fragmentation()
        self.registry.gauge("kv.free_pages").set(frag["free_pages"])
        self.registry.gauge("kv.free_slots").set(frag["free_slots"])
        self.registry.gauge("kv.max_contig_free_run").set(
            frag["max_contig_free_run"])
        self.registry.gauge("kv.fill").set(
            self.pages_in_use / max(self.page_budget, 1))


__all__ = ["PagedKVPool", "PageTable", "attn_block_indices"]
