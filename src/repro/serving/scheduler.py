"""Continuous batching scheduler.

The decode batch has a fixed capacity (``max_batch`` slots — the jitted
batched decode step compiles once at that width).  Requests join a free slot
at a token boundary after their planned prefill, decode one token per
scheduler tick at their own sequence position, and leave at the boundary
where their generation completes — no batch-wide barrier, no reallocation.

Queueing policy: FIFO within a bucket, **longest-waiting-first across
buckets** — the head chosen for the next free slot is the earliest-enqueued
head among all bucket queues (ties broken by bucket for determinism).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class SlotState:
    """One in-flight request occupying a decode-batch slot."""

    request: object                  # ServeRequest
    slot: int
    pos: int                         # next cache position to write
    tok: int                         # token to feed at ``pos``
    out: list = field(default_factory=list)   # generated token ids
    joined_at: float = 0.0
    rm: object = None                # RequestMetrics, attached by the runtime

    @property
    def done(self) -> bool:
        return len(self.out) >= self.request.gen


@dataclass
class _Waiting:
    request: object
    bucket: int
    enqueued_at: float
    seq: int                         # arrival tiebreaker


class TenantScheduler:
    """Per-tenant weighted round-robin over analytical query queues.

    Smooth WRR (the nginx variant): each pick adds every backlogged
    tenant's weight to its credit, the tenant with the highest credit
    wins and pays the total weight back.  Over any window the picks a
    tenant receives are proportional to its weight, and a tenant with an
    empty queue accrues nothing — no starvation, no bursts after idle.
    """

    def __init__(self, weights: Optional[dict] = None,
                 default_weight: int = 1):
        self.weights = dict(weights or {})
        self.default_weight = max(int(default_weight), 1)
        self.queues: dict = {}       # tenant -> deque of items
        self._credit: dict = {}      # tenant -> smooth-WRR credit
        self.picks: dict = {}        # tenant -> granted picks (fairness view)

    def weight_of(self, tenant) -> int:
        return max(int(self.weights.get(tenant, self.default_weight)), 1)

    def enqueue(self, item, tenant="default") -> None:
        self.queues.setdefault(tenant, deque()).append(item)

    def depth(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def pop_next(self):
        """The next item under smooth WRR, or None when all queues are
        empty."""
        backlogged = [t for t, q in self.queues.items() if q]
        if not backlogged:
            return None
        total = 0
        for t in backlogged:
            w = self.weight_of(t)
            self._credit[t] = self._credit.get(t, 0) + w
            total += w
        best = max(backlogged, key=lambda t: (self._credit[t], str(t)))
        self._credit[best] -= total
        self.picks[best] = self.picks.get(best, 0) + 1
        return self.queues[best].popleft()

    def purge(self, pred) -> list:
        """Remove (and return) every queued item matching ``pred``.  A
        timed-out ``run_analyses`` call purges its own stragglers so a
        later call draining the shared queues can never adopt them."""
        removed = []
        for t, q in self.queues.items():
            keep = deque()
            for item in q:
                (removed if pred(item) else keep).append(item)
            self.queues[t] = keep
        return removed

    def drain(self, k: Optional[int] = None) -> list:
        """Up to ``k`` items (all backlogged items when None) in WRR
        order — one admission tick's worth of queries."""
        out = []
        while k is None or len(out) < k:
            item = self.pop_next()
            if item is None:
                break
            out.append(item)
        return out


class ContinuousBatchScheduler:
    def __init__(self, max_batch: int):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self.slots: list = [None] * max_batch
        self.queues: dict = {}       # bucket -> deque[_Waiting]
        self._seq = 0

    # -- waiting side ------------------------------------------------------
    def enqueue(self, request, bucket: int, now: float) -> None:
        self.queues.setdefault(bucket, deque()).append(
            _Waiting(request, bucket, now, self._seq))
        self._seq += 1

    def queue_depth(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def peek_next(self, *, warm_buckets=None) -> Optional[_Waiting]:
        """The longest-waiting head across bucket FIFOs.  With
        ``warm_buckets`` given, only heads whose bucket is warm qualify
        (cold heads wait for a planning window)."""
        best = None
        for bucket, q in self.queues.items():
            if not q:
                continue
            if warm_buckets is not None and bucket not in warm_buckets:
                continue
            head = q[0]
            if best is None or (head.enqueued_at, head.seq) < \
                    (best.enqueued_at, best.seq):
                best = head
        return best

    def pop(self, waiting: _Waiting):
        q = self.queues[waiting.bucket]
        assert q[0] is waiting, "pop must take the queue head"
        return q.popleft().request

    def remove(self, waiting: _Waiting) -> None:
        """Drop a waiting entry from anywhere in its bucket queue (deadline
        expiry and timeout resolution cancel mid-queue, not just heads)."""
        self.queues[waiting.bucket].remove(waiting)

    def waiting(self) -> list:
        """Every queued entry across buckets (deadline sweep order-free)."""
        return [w for q in self.queues.values() for w in q]

    # -- batch side --------------------------------------------------------
    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def join(self, request, *, pos: int, tok: int, first_out: int,
             now: float) -> SlotState:
        slot = self.free_slot()
        if slot is None:
            raise RuntimeError("no free decode slot")
        st = SlotState(request, slot, pos, tok, [first_out], now)
        self.slots[slot] = st
        return st

    def leave(self, slot: int) -> SlotState:
        st = self.slots[slot]
        if st is None:
            raise RuntimeError(f"slot {slot} already free")
        self.slots[slot] = None
        return st

    def active(self) -> list:
        return [s for s in self.slots if s is not None]

    def n_active(self) -> int:
        return sum(1 for s in self.slots if s is not None)
