"""RWKV6 (Finch) block: time-mix with data-dependent decay + channel-mix.

The time-mix core is the WKV6 recurrence (kernels/wkv6); the data-dependent
decay w_t = exp(-exp(w0 + (x_t·A)·B)) is the Finch contribution (low-rank
LoRA on the decay).  Token-shift interpolation uses a single learned mu per
projection (a documented simplification of per-channel mus — structurally
identical dataflow).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import he_init, rmsnorm
from ..kernels.wkv6.ops import wkv6 as wkv6_kernel
from ..kernels.wkv6.ref import wkv6_chunked, wkv6_reference

LORA_RANK = 64


def init_rwkv_time_mix(kg, cfg, dtype=jnp.float32):
    e = cfg["embed"]
    h, d = cfg["heads"], cfg["head_dim"]
    assert h * d == e
    rank = min(LORA_RANK, e // 2)
    p = {
        "wr": he_init(kg(), (e, e), e, dtype),
        "wk": he_init(kg(), (e, e), e, dtype),
        "wv": he_init(kg(), (e, e), e, dtype),
        "wg": he_init(kg(), (e, e), e, dtype),
        "wo": he_init(kg(), (e, e), e, dtype),
        "w0": jnp.full((e,), -3.0, dtype),              # decay bias
        "wA": he_init(kg(), (e, rank), e, dtype),       # decay LoRA
        "wB": he_init(kg(), (rank, e), rank, dtype),
        "u": he_init(kg(), (h, d), d, dtype),           # bonus
        "mu": jnp.full((5,), 0.5, dtype),               # token-shift mixes
        "ln_scale": jnp.zeros((e,), dtype),             # per-head group norm
    }
    s = {
        "wr": ("embed", "heads_flat"), "wk": ("embed", "heads_flat"),
        "wv": ("embed", "heads_flat"), "wg": ("embed", "heads_flat"),
        "wo": ("heads_flat", "embed"),
        "w0": ("embed",), "wA": ("embed", "lora"), "wB": ("lora", "embed"),
        "u": ("heads", "head_dim"), "mu": ("mix",), "ln_scale": ("embed",),
    }
    return p, s


def _token_shift(x):
    return jnp.pad(x, [(0, 0), (1, 0), (0, 0)])[:, :-1]


def rwkv_time_mix(p, x, *, heads, head_dim, use_kernel=False, interpret=True,
                  last_x=None, state=None):
    """x: (B, T, E).  When ``state``/``last_x`` are given (decode), runs the
    single-step recurrence and returns (y, new_last_x, new_state)."""
    b, t, e = x.shape
    decode = state is not None
    xs = (jnp.concatenate([last_x[:, None], x[:, :-1]], axis=1)
          if decode else _token_shift(x))
    mu = p["mu"].astype(x.dtype)

    def mix(i):
        return x + mu[i] * (xs - x)

    r = jnp.einsum("bte,ef->btf", mix(0), p["wr"].astype(x.dtype))
    k = jnp.einsum("bte,ef->btf", mix(1), p["wk"].astype(x.dtype))
    v = jnp.einsum("bte,ef->btf", mix(2), p["wv"].astype(x.dtype))
    g = jnp.einsum("bte,ef->btf", mix(3), p["wg"].astype(x.dtype))
    lora = jnp.einsum("btr,re->bte",
                      jnp.tanh(jnp.einsum("bte,er->btr", mix(4),
                                          p["wA"].astype(x.dtype))),
                      p["wB"].astype(x.dtype))
    w = jnp.exp(-jnp.exp((p["w0"].astype(jnp.float32) +
                          lora.astype(jnp.float32))))    # (B,T,E) in (0,1)

    rh = r.reshape(b, t, heads, head_dim)
    kh = k.reshape(b, t, heads, head_dim)
    vh = v.reshape(b, t, heads, head_dim)
    wh = w.reshape(b, t, heads, head_dim)

    if decode:
        y, new_state = wkv6_reference(rh, kh, vh, wh.astype(rh.dtype),
                                      p["u"], initial_state=state)
    elif use_kernel:
        y = wkv6_kernel(rh, kh, vh, wh.astype(rh.dtype), p["u"],
                        interpret=interpret)
        new_state = None
    else:
        # chunked jnp engine: state materializes once per chunk, not per
        # timestep (the sequential ref is the oracle, not an engine)
        y, new_state = wkv6_chunked(rh, kh, vh, wh.astype(rh.dtype), p["u"])

    y = y.reshape(b, t, e)
    y = rmsnorm(y, p["ln_scale"])                         # head-merge norm
    y = y * jax.nn.silu(g)
    out = jnp.einsum("btf,fe->bte", y, p["wo"].astype(x.dtype))
    if decode:
        return out, x[:, -1], new_state
    return out


def init_rwkv_channel_mix(kg, cfg, dtype=jnp.float32):
    e, f = cfg["embed"], cfg["ffn"]
    p = {
        "wk": he_init(kg(), (e, f), e, dtype),
        "wv": he_init(kg(), (f, e), f, dtype),
        "wr": he_init(kg(), (e, e), e, dtype),
        "mu": jnp.full((2,), 0.5, dtype),
    }
    s = {"wk": ("embed", "ffn"), "wv": ("ffn", "embed"),
         "wr": ("embed", "embed2"), "mu": ("mix",)}
    return p, s


def rwkv_channel_mix(p, x, last_x=None):
    xs = (jnp.concatenate([last_x[:, None], x[:, :-1]], axis=1)
          if last_x is not None else _token_shift(x))
    mu = p["mu"].astype(x.dtype)
    xk = x + mu[0] * (xs - x)
    xr = x + mu[1] * (xs - x)
    k = jnp.square(jax.nn.relu(
        jnp.einsum("bte,ef->btf", xk, p["wk"].astype(x.dtype))))
    kv = jnp.einsum("btf,fe->bte", k, p["wv"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.einsum("bte,ef->btf", xr, p["wr"].astype(x.dtype)))
    out = r * kv
    if last_x is not None:
        return out, x[:, -1]
    return out
