"""Mamba2 block (zamba2's backbone): in-proj → short conv → SSD → gate → out.

The SSD core has two physical candidates (the planner's choice): the chunked
jnp form (``ssd_chunked_xla``) and the Pallas kernel (``ssd_pallas``), both
validated against the sequential-scan oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import he_init
from ..kernels.ssd.ops import ssd as ssd_kernel
from ..kernels.ssd.ref import ssd_chunked, ssd_reference

CONV_K = 4


def init_mamba2(kg, cfg, dtype=jnp.float32):
    e = cfg["embed"]
    n = cfg["state"]
    expand = cfg.get("expand", 2)
    ei = expand * e
    pdim = cfg.get("head_dim", 64)
    h = ei // pdim
    d_in = 2 * ei + 2 * n + h          # z, x, B, C, dt
    p = {
        "w_in": he_init(kg(), (e, d_in), e, dtype),
        "conv": he_init(kg(), (CONV_K, ei + 2 * n), CONV_K, dtype),
        "a_log": jnp.zeros((h,), dtype),
        "dt_bias": jnp.full((h,), -2.0, dtype),
        "d_skip": jnp.ones((h,), dtype),
        "w_out": he_init(kg(), (ei, e), ei, dtype),
    }
    s = {
        "w_in": ("embed", "inner_cat"), "conv": ("conv_k", "inner_cat2"),
        "a_log": ("heads",), "dt_bias": ("heads",), "d_skip": ("heads",),
        "w_out": ("inner", "embed"),
    }
    return p, s


def _split(cfg, zxbcdt):
    e = cfg["embed"]
    n = cfg["state"]
    ei = cfg.get("expand", 2) * e
    pdim = cfg.get("head_dim", 64)
    h = ei // pdim
    return jnp.split(zxbcdt, [ei, 2 * ei, 2 * ei + n, 2 * ei + 2 * n], axis=-1)


def _causal_conv(x, w, conv_state=None):
    """Depthwise causal conv over time.  x: (B,T,C), w: (K,C)."""
    k = w.shape[0]
    if conv_state is not None:                     # decode: (B, K-1, C)
        xx = jnp.concatenate([conv_state, x], axis=1)
        new_state = xx[:, -(k - 1):]
    else:
        xx = jnp.pad(x, [(0, 0), (k - 1, 0), (0, 0)])
        new_state = None
    out = sum(xx[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    return jax.nn.silu(out), new_state


def mamba2_block(p, x, cfg, *, use_kernel=False, interpret=True, state=None,
                 conv_state=None):
    """x: (B,T,E).  Decode mode when ``state`` is given: returns
    (y, new_state, new_conv_state)."""
    b, t, e = x.shape
    n = cfg["state"]
    ei = cfg.get("expand", 2) * e
    pdim = cfg.get("head_dim", 64)
    h = ei // pdim
    decode = state is not None

    zxbcdt = jnp.einsum("bte,ed->btd", x, p["w_in"].astype(x.dtype))
    z, xin, bmat, cmat, dt = _split(cfg, zxbcdt)

    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv"].astype(x.dtype),
                                      conv_state)
    xin, bmat, cmat = jnp.split(conv_out, [ei, ei + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))      # (B,T,H)
    a = jnp.exp(-dt * jnp.exp(p["a_log"].astype(jnp.float32)))  # (B,T,H)

    xh = xin.reshape(b, t, h, pdim)
    xs = xh * dt[..., None].astype(xh.dtype)                    # dt-scaled in
    bh = jnp.broadcast_to(bmat[:, :, None, :], (b, t, h, n))
    chh = jnp.broadcast_to(cmat[:, :, None, :], (b, t, h, n))

    if decode:
        y, new_state = ssd_reference(xs, a.astype(xs.dtype), bh, chh,
                                     initial_state=state)
    elif use_kernel:
        y = ssd_kernel(xs, a.astype(xs.dtype), bh, chh, interpret=interpret)
        new_state = None
    else:
        # chunked jnp engine (matmul re-expression; state per chunk)
        y, new_state = ssd_chunked(xs, a.astype(xs.dtype), bh, chh)

    y = y + xh * p["d_skip"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(b, t, ei) * jax.nn.silu(z)
    out = jnp.einsum("bti,ie->bte", y, p["w_out"].astype(x.dtype))
    if decode:
        return out, new_state, new_conv
    return out
