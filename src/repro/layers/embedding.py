"""Token embedding / unembedding and the loss head."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import he_init


def init_embedding(kg, vocab, embed, dtype=jnp.float32, tied=True):
    p = {"table": he_init(kg(), (vocab, embed), embed, dtype)}
    s = {"table": ("vocab", "embed")}
    if not tied:
        p["head"] = he_init(kg(), (embed, vocab), embed, dtype)
        s["head"] = ("embed", "vocab")
    return p, s


def embed(p, ids, *, scale=False):
    out = jnp.take(p["table"], ids, axis=0)
    if scale:
        out = out * (p["table"].shape[-1] ** 0.5)
    return out


def unembed(p, x):
    w = p.get("head")
    if w is None:
        w = p["table"].T
    return jnp.einsum("...e,ev->...v", x.astype(jnp.float32),
                      w.astype(jnp.float32))


def mask_padded_logits(logits, vocab):
    """Padding rows of a padded-vocab head must not leak probability mass."""
    ids = jnp.arange(logits.shape[-1])
    return jnp.where(ids < vocab, logits, -1e30)


def softmax_xent(logits, labels, *, ignore_index=-100):
    """Mean next-token CE over valid labels.  logits (..., V), labels (...)."""
    valid = labels != ignore_index
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid.astype(logits.dtype)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid.astype(logits.dtype)), 1.0)
