"""MLP family: gated (SwiGLU/GeGLU) and plain FFN, fused and unfused forms."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import he_init

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def init_mlp(kg, cfg, dtype=jnp.float32):
    e, f = cfg["embed"], cfg["ffn"]
    p = {"wi": he_init(kg(), (e, f), e, dtype),
         "wo": he_init(kg(), (f, e), f, dtype)}
    s = {"wi": ("embed", "ffn"), "wo": ("ffn", "embed")}
    if cfg.get("gated", True):
        p["wg"] = he_init(kg(), (e, f), e, dtype)
        s["wg"] = ("embed", "ffn")
    return p, s


def ffn_up(p, x):
    return jnp.einsum("...e,ef->...f", x, p["wi"].astype(x.dtype))


def ffn_gate(p, x):
    return jnp.einsum("...e,ef->...f", x, p["wg"].astype(x.dtype))


def ffn_glu(up, gate, act="silu"):
    return _ACTS[act](gate) * up


def ffn_act(up, act="gelu"):
    return _ACTS[act](up)


def ffn_down(p, h):
    return jnp.einsum("...f,fe->...e", h, p["wo"].astype(h.dtype))


def mlp_fused(p, x, *, gated=True, act=None):
    """The single fused block (one traversal of x, jointly scheduled gemms)."""
    up = ffn_up(p, x)
    if gated and "wg" in p:
        h = ffn_glu(up, ffn_gate(p, x), act or "silu")
    else:
        h = ffn_act(up, act or "gelu")
    return ffn_down(p, h)
