"""Mixture-of-Experts family — the planner's three dispatch candidates:

  * ``moe_dense_onehot`` — capacity-2.0 scatter dispatch (≈ no drops at
    typical balance); the Switch/Mixtral-JAX form whose all-to-all GSPMD
    emits from the expert sharding;
  * ``moe_dropping``     — capacity-1.0 dispatch (overflow tokens fall back
    to the residual path); half the expert flops;
  * ``moe_gmm``          — capacity dispatch + the Pallas grouped matmul.

Dispatch is scatter-based: each (token, k) assignment gets a rank within its
expert via a one-hot cumsum, then tokens scatter into the (E, C, D) expert
buffer and gather back after the expert MLP — O(T·K·E) bookkeeping and
O(E·C·D) buffers, never the O(T·E·C) dispatch tensor of the naive einsum
formulation (which is quadratic in tokens and unusable at pod scale).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import he_init
from .mlp import _ACTS
from ..kernels.moe_gmm.ops import grouped_matmul


def init_moe(kg, cfg, dtype=jnp.float32):
    e, f, x = cfg["embed"], cfg["ffn"], cfg["experts"]
    p = {
        "router": he_init(kg(), (e, x), e, dtype),
        "wi": he_init(kg(), (x, e, f), e, dtype),
        "wg": he_init(kg(), (x, e, f), e, dtype),
        "wo": he_init(kg(), (x, f, e), f, dtype),
    }
    s = {
        "router": ("embed", "experts"),
        "wi": ("experts", "embed", "ffn"),
        "wg": ("experts", "embed", "ffn"),
        "wo": ("experts", "ffn", "embed"),
    }
    return p, s


def _route(p, x, top_k):
    logits = jnp.einsum("bse,ex->bsx", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    weights, idx = jax.lax.top_k(logits, top_k)           # (B,S,K)
    weights = jax.nn.softmax(weights, axis=-1)
    return weights, idx


def moe_capacity_dispatch(p, x, *, top_k, experts, capacity_factor=2.0,
                          act="silu", use_gmm=False, interpret=True,
                          constrain=None):
    """Row-grouped capacity dispatch.

    The scatter into expert buffers happens *per batch row* (the row dim is
    preserved through the scatter), so under batch→data sharding the scatter
    stays device-local; the (B, E, C, D) → (E, B·C, D) rearrange before the
    expert matmuls is what GSPMD lowers to the canonical MoE **all-to-all**
    across data↔model.  (A global scatter-add buffer instead lowers to an
    all-reduce of the whole expert buffer per layer — measured +1.5e12
    bytes/device on llama4-maverick×train_4k; see §Perf iter L2.)
    """
    b, s, e = x.shape
    cap = max(8, int(s * top_k * capacity_factor / experts))
    weights, idx = _route(p, x, top_k)                    # (B,S,K)

    flat_w = weights.reshape(b, s * top_k)                # (B, A)
    flat_i = idx.reshape(b, s * top_k)                    # (B, A)
    tok_of = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s), top_k)[None], (b, s * top_k))

    onehot = jax.nn.one_hot(flat_i, experts, dtype=jnp.int32)   # (B, A, E)
    rank = jnp.sum(jnp.cumsum(onehot, axis=1) * onehot, axis=-1) - 1
    keep = rank < cap                                            # (B, A)
    dest = jnp.where(keep, flat_i * cap + rank, experts * cap)   # (B, A)

    def dispatch_row(xr, dr, tr, kr):
        buf = jnp.zeros((experts * cap + 1, e), x.dtype)
        return buf.at[dr].add(xr[tr] * kr[:, None].astype(x.dtype))[:-1]

    buf = jax.vmap(dispatch_row)(x, dest, tok_of, keep)   # (B, E*C, D)
    if constrain is not None:
        buf = constrain(buf, ("batch", None, None))
    expert_in = buf.reshape(b, experts, cap, e)
    # (B, E, C, D) -> (E, B*C, D): the all-to-all boundary
    expert_in = jnp.moveaxis(expert_in, 1, 0).reshape(experts, b * cap, e)
    if constrain is not None:
        # pin the post-a2a layout: experts→model, token rows→data — without
        # this GSPMD can replicate the expert matmuls over data (measured
        # 5× compute on llama4 with replicated weights)
        expert_in = constrain(expert_in, ("experts", "batch", None))

    if use_gmm:
        up = grouped_matmul(expert_in, p["wi"].astype(x.dtype),
                            interpret=interpret)
        gate = grouped_matmul(expert_in, p["wg"].astype(x.dtype),
                              interpret=interpret)
        h = _ACTS[act](gate) * up
        out = grouped_matmul(h, p["wo"].astype(x.dtype), interpret=interpret)
    else:
        up = jnp.einsum("xce,xef->xcf", expert_in, p["wi"].astype(x.dtype))
        gate = jnp.einsum("xce,xef->xcf", expert_in, p["wg"].astype(x.dtype))
        h = _ACTS[act](gate) * up
        out = jnp.einsum("xcf,xfe->xce", h, p["wo"].astype(x.dtype))

    if constrain is not None:
        out = constrain(out, ("experts", "batch", None))
    # (E, B*C, D) -> (B, E*C, D): the return all-to-all
    out = jnp.moveaxis(out.reshape(experts, b, cap, e), 1, 0)
    out = out.reshape(b, experts * cap, e)
    if constrain is not None:
        out = constrain(out, ("batch", None, None))

    def combine_row(orow, dr, kr, wr):
        gathered = jnp.where(
            kr[:, None], orow[jnp.minimum(dr, experts * cap - 1)],
            jnp.zeros((1, e), x.dtype))
        contrib = gathered * wr[:, None].astype(x.dtype)
        return jnp.zeros((s, e), x.dtype).at[
            jnp.repeat(jnp.arange(s), top_k)].add(contrib)

    y = jax.vmap(combine_row)(out, dest, keep, flat_w)
    return y.reshape(b, s, e)


def moe_dense(p, x, *, top_k, experts, act="silu", capacity_factor=2.0,
              interpret=True, constrain=None):
    return moe_capacity_dispatch(p, x, top_k=top_k, experts=experts,
                                 capacity_factor=capacity_factor, act=act,
                                 constrain=constrain)


def moe_dropping(p, x, *, top_k, experts, act="silu", interpret=True,
                 constrain=None):
    return moe_capacity_dispatch(p, x, top_k=top_k, experts=experts,
                                 capacity_factor=1.0, act=act,
                                 constrain=constrain)


def moe_gmm(p, x, *, top_k, experts, act="silu", capacity_factor=2.0,
            interpret=True, constrain=None):
    return moe_capacity_dispatch(p, x, top_k=top_k, experts=experts,
                                 capacity_factor=capacity_factor, act=act,
                                 use_gmm=True, interpret=interpret,
                                 constrain=constrain)


def moe_reference_dense(p, x, *, top_k, experts, act="silu"):
    """No-capacity oracle: every token reaches its experts (tests only)."""
    b, s, e = x.shape
    weights, idx = _route(p, x, top_k)
    up = jnp.einsum("bse,xef->bsxf", x, p["wi"].astype(x.dtype))
    gate = jnp.einsum("bse,xef->bsxf", x, p["wg"].astype(x.dtype))
    h = _ACTS[act](gate) * up
    out = jnp.einsum("bsxf,xfe->bsxe", h, p["wo"].astype(x.dtype))
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(top_k):
        oh = jax.nn.one_hot(idx[..., j], experts, dtype=x.dtype)
        sel = jnp.einsum("bsxe,bsx->bse", out, oh)
        y = y + sel.astype(jnp.float32) * weights[..., j:j + 1]
    return y.astype(x.dtype)
