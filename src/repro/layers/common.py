"""Shared layer utilities: norms, rotary embeddings, initializers.

Every ``init_*`` function returns ``(params, specs)`` where ``specs`` mirrors
the param pytree with tuples of *semantic dimension names* per leaf —
("embed", "ffn"), ("layers", "vocab", "embed"), … — which the sharding rules
(launch/mesh.py) translate to PartitionSpecs.  This is the variable-metadata
map of the paper carried down to parameters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rmsnorm(x, scale, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(
        jnp.float32))).astype(dt)


def rope(x, positions, *, theta=10000.0):
    """x: (..., S, H, D) with positions (..., S) — rotates pairs (even, odd)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def he_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape) * (fan ** -0.5)).astype(dtype)


class KeyGen:
    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


def stack_params(trees):
    """Stack a list of identical pytrees along a new leading 'layers' axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def stack_specs(spec):
    return jax.tree.map(
        lambda s: ("layers",) + s, spec,
        is_leaf=lambda s: isinstance(s, tuple) and all(
            isinstance(x, str) for x in s))
