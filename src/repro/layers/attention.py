"""Attention layer family: projections, SDPA variants, KV-cache decode.

Three physical realizations of the same logical sdpa (the planner's
candidates):
  * ``sdpa_xla``        — full masked attention, materialized logits;
  * ``sdpa_banded_xla`` — O(S·W) chunked local-window attention;
  * ``attn_flash``      — the Pallas kernel (kernels/flash_attention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.flash_attention.ops import flash_attention
from ..kernels.flash_attention.ref import mha_reference
from .common import he_init, rmsnorm, rope


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def init_attention(kg, cfg_attn, dtype=jnp.float32):
    """cfg_attn: dict(embed, heads, kv_heads, head_dim, qk_norm)."""
    e = cfg_attn["embed"]
    h, k, d = cfg_attn["heads"], cfg_attn["kv_heads"], cfg_attn["head_dim"]
    p = {
        "wq": he_init(kg(), (e, h * d), e, dtype),
        "wk": he_init(kg(), (e, k * d), e, dtype),
        "wv": he_init(kg(), (e, k * d), e, dtype),
        "wo": he_init(kg(), (h * d, e), h * d, dtype),
    }
    s = {
        "wq": ("embed", "heads_flat"),
        "wk": ("embed", "kv_flat"),
        "wv": ("embed", "kv_flat"),
        "wo": ("heads_flat", "embed"),
    }
    if cfg_attn.get("qk_norm"):
        p["q_norm"] = jnp.zeros((d,), dtype)
        p["k_norm"] = jnp.zeros((d,), dtype)
        s["q_norm"] = ("head_dim",)
        s["k_norm"] = ("head_dim",)
    return p, s


# --------------------------------------------------------------------------
# projections
# --------------------------------------------------------------------------

def project_q(p, x, h, d):
    return jnp.einsum("bse,ef->bsf", x, p["wq"].astype(x.dtype)).reshape(
        x.shape[0], x.shape[1], h, d)


def project_kv(p, x, k, d):
    kk = jnp.einsum("bse,ef->bsf", x, p["wk"].astype(x.dtype)).reshape(
        x.shape[0], x.shape[1], k, d)
    vv = jnp.einsum("bse,ef->bsf", x, p["wv"].astype(x.dtype)).reshape(
        x.shape[0], x.shape[1], k, d)
    return kk, vv


def project_qkv_fused(p, x, h, k, d):
    """One gemm over the concatenated projection — the fused candidate."""
    w = jnp.concatenate(
        [p["wq"], p["wk"], p["wv"]], axis=-1).astype(x.dtype)
    out = jnp.einsum("bse,ef->bsf", x, w)
    q, kk, vv = jnp.split(out, [h * d, h * d + k * d], axis=-1)
    b, s = x.shape[:2]
    return (q.reshape(b, s, h, d), kk.reshape(b, s, k, d),
            vv.reshape(b, s, k, d))


def qk_prep(p, q, k, positions, *, qk_norm=False, use_rope=True,
            rope_theta=10000.0):
    if qk_norm and "q_norm" in p:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if use_rope:
        q = rope(q, positions, theta=rope_theta)
        k = rope(k, positions, theta=rope_theta)
    return q, k


def out_project(p, attn_out):
    b, s, h, d = attn_out.shape
    return jnp.einsum("bsf,fe->bse", attn_out.reshape(b, s, h * d),
                      p["wo"].astype(attn_out.dtype))


# --------------------------------------------------------------------------
# SDPA candidates
# --------------------------------------------------------------------------

def sdpa_full(q, k, v, *, causal=True, window=0):
    return mha_reference(q, k, v, causal=causal, window=window)


def sdpa_banded(q, k, v, *, window, causal=True):
    """Chunked local attention: O(S·W) compute.  Sequence is cut into chunks
    of size W; each query chunk attends to its own chunk plus the previous
    one, masked to the sliding window — the standard TPU-friendly banding."""
    b, s, h, d = q.shape
    _, _, kh, _ = k.shape
    w = int(window)
    if w <= 0 or w >= s:
        return sdpa_full(q, k, v, causal=causal, window=window)
    groups = h // kh
    pad = (-s) % w
    sp = s + pad
    qp = jnp.pad(q, [(0, 0), (0, pad), (0, 0), (0, 0)])
    kp = jnp.pad(k, [(0, 0), (0, pad), (0, 0), (0, 0)])
    vp = jnp.pad(v, [(0, 0), (0, pad), (0, 0), (0, 0)])
    nc = sp // w
    qc = qp.reshape(b, nc, w, h, d)
    kc = kp.reshape(b, nc, w, kh, d)
    vc = vp.reshape(b, nc, w, kh, d)
    # keys: previous chunk ++ own chunk  (window ≤ W ⇒ covered)
    k2 = jnp.concatenate([jnp.pad(kc[:, :-1], [(0, 0), (1, 0), (0, 0),
                                               (0, 0), (0, 0)]), kc], axis=2)
    v2 = jnp.concatenate([jnp.pad(vc[:, :-1], [(0, 0), (1, 0), (0, 0),
                                               (0, 0), (0, 0)]), vc], axis=2)
    kr = jnp.repeat(k2, groups, axis=3)
    vr = jnp.repeat(v2, groups, axis=3)
    logits = jnp.einsum("bcqhd,bckhd->bchqk", qc.astype(jnp.float32),
                        kr.astype(jnp.float32)) * (d ** -0.5)
    qi = jnp.arange(w)[:, None] + w                       # position in 2W axis
    ki = jnp.arange(2 * w)[None, :]
    mask = (ki <= qi) & (ki > qi - w)                     # causal ∧ window
    # first chunk's "previous" keys are padding
    first = (jnp.arange(nc) == 0).reshape(1, nc, 1, 1, 1)
    pad_keys = (ki < w)[None, None, None]                 # (1,1,1,1,2w)
    mask = mask[None, None, None] & ~(first & pad_keys)
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bchqk,bckhd->bcqhd", p, vr.astype(jnp.float32))
    out = out.reshape(b, sp, h, d)[:, :s]
    return out.astype(q.dtype)


def sdpa_flash(q, k, v, *, causal=True, window=0, interpret=True):
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=interpret)


# --------------------------------------------------------------------------
# KV-cache decode
# --------------------------------------------------------------------------

def decode_attend_gqa(q, cache_k, cache_v, valid_mask, *, k_scale=None,
                      v_scale=None):
    """Repeat-free GQA attention for decode: q (B, 1, H, D) grouped as
    (B, KV, G, D) against the cache (B, S, KV, D) directly.  ``jnp.repeat``
    on a multi-GB cache materializes a full copy per layer (measured +0.13 s
    on the qwen3 decode memory term); the grouped einsum reads the cache
    once.

    int8 caches pass per-(position, head) ``k_scale``/``v_scale``
    (B, S, KV, 1): the k-scale factors out of the qk contraction (applied to
    the logits) and the v-scale folds into the softmax weights — the int8
    tensors are the only cache-sized reads."""
    b, one, h, d = q.shape
    kv = cache_k.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, d)                     # (B, KV, G, D)
    scale = d ** -0.5
    logits = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        cache_k.astype(jnp.float32)) * scale
    if k_scale is not None:                          # (B,S,KV,1) -> (B,KV,1,S)
        logits = logits * k_scale[..., 0].transpose(0, 2, 1)[:, :, None, :] \
            .astype(jnp.float32)
    logits = jnp.where(valid_mask[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    if v_scale is not None:
        p = p * v_scale[..., 0].transpose(0, 2, 1)[:, :, None, :] \
            .astype(jnp.float32)
    out = jnp.einsum("bkgs,bskd->bkgd", p, cache_v.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


def quantize_kv(x, *, axis=-1):
    """abs-max int8 quantization along ``axis``: returns (int8, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    sc = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / sc), -127, 127)
    return q.astype(jnp.int8), sc.astype(jnp.bfloat16)


def decode_attend(q, cache_k, cache_v, index, *, window=0):
    """q: (B, 1, H, D); cache_k/v: (B, S_max, K, D); index: scalar count of
    valid cache entries *including* the newly-written position."""
    b, _, h, d = q.shape
    s_max = cache_k.shape[1]
    valid = jnp.arange(s_max)[None, :] < index                  # (1, S)
    if window and window > 0:
        valid = valid & (jnp.arange(s_max)[None, :] >= index - window)
    return mha_reference(q, cache_k, cache_v, causal=False,
                         kv_len_mask=jnp.broadcast_to(valid, (b, s_max)))


def cache_update(cache_k, cache_v, new_k, new_v, index):
    """Write the new token's k/v at position ``index`` (decode step)."""
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, new_k, index, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, new_v, index, axis=1)
    return ck, cv
