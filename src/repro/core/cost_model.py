"""Learned cost model (paper §6).

The paper trains, per physical operator, a linear regression over the
degree-2 polynomial expansion of raw features (Eq. 2), estimates a candidate
sub-plan's cost as the **sum** of its operators' costs (Eq. 1 — valid because
AWESOME applies no task parallelism; same for us, a candidate chain executes
sequentially inside the jitted step), and at run time — once input sizes are
known — scores each virtual node's candidates and selects the argmin (§6.3).

Raw features here are the TPU analogues of the paper's table sizes / node
counts / keyword-list sizes: token counts, operand widths, and the three
roofline terms (per-device FLOPs / HBM bytes / interconnect bytes scaled by
the hardware peaks from the system catalog).  Before any calibration the
model falls back to the *analytic* roofline sum — which is itself an instance
of Eq. 2 with known weights (w=1 on the three roofline features) — so the
planner is always total.  Calibration (``calibrate.py``) refits the weights
from measured timings, exactly the paper's §6.2 loop.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from .ir import (CorpusT, GraphT, ScalarT, SystemCatalog, TableT, TensorT,
                 TupleT, dtype_bytes)
from .physical import PhysPlan, Candidate

# --------------------------------------------------------------------------
# Raw feature extraction (paper §6.2 "Operators and features")
# --------------------------------------------------------------------------

FEATURE_NAMES = ("f_compute", "f_memory", "f_network", "tokens_m", "width_k")

_ESTIMATORS: dict = {}


def estimator(*impls):
    def deco(fn):
        for i in impls:
            _ESTIMATORS[i] = fn
        return fn
    return deco


def _tensor_like(t):
    if isinstance(t, TupleT):
        return _tensor_like(t.elems[0])
    return t if isinstance(t, TensorT) else None


def _tokens(t):
    tt = _tensor_like(t)
    if tt is None:
        return 1
    n = 1
    for name in ("batch", "seq"):
        if tt.has_dim(name):
            n *= tt.dim(name)
    return n


def _sum_bytes(types):
    out = 0
    for t in types:
        if isinstance(t, TupleT):
            out += _sum_bytes(t.elems)
        elif isinstance(t, (TensorT, TableT, GraphT, CorpusT)):
            out += t.bytesize()
    return out


@dataclass
class OpCost:
    """Raw flops / bytes / collective-bytes for one op instance, device-local."""

    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0


def _proj_cost(in_t, d_out_total, syscat, tp_sharded=True):
    t = _tensor_like(in_t)
    toks = _tokens(t)
    d_in = t.shape[-1] if t else 1
    dp = syscat.axis_size("data") * syscat.axis_size("pod")
    tp = syscat.axis_size("model") if tp_sharded else 1
    flops = 2.0 * toks * d_in * d_out_total / (dp * tp)
    bts = (toks * d_in * dtype_bytes(t.dtype) / dp
           + d_in * d_out_total * 4 / tp
           + toks * d_out_total * dtype_bytes(t.dtype) / (dp * tp))
    return OpCost(flops, bts, 0.0)


@estimator("q_proj_xla", "k_proj_xla", "v_proj_xla")
def _e_proj(in_types, attrs, syscat):
    d_out = attrs["heads"] * attrs["head_dim"]
    return _proj_cost(in_types[0], d_out, syscat)


@estimator("qkv_proj_fused")
def _e_qkv(in_types, attrs, syscat):
    d_out = (attrs["heads"] + 2 * attrs["kv_heads"]) * attrs["head_dim"]
    c = _proj_cost(in_types[0], d_out, syscat)
    # fused: one pass over the activations instead of three
    t = _tensor_like(in_types[0])
    c.bytes -= 2 * _tokens(t) * t.shape[-1] * dtype_bytes(t.dtype) / (
        syscat.axis_size("data") * syscat.axis_size("pod"))
    return c


@estimator("out_proj_xla")
def _e_outp(in_types, attrs, syscat):
    t = _tensor_like(in_types[0])
    d_in = t.shape[-1] * t.shape[-2] if t.rank >= 2 else t.shape[-1]
    return _proj_cost(in_types[0], attrs["embed"], syscat)


def _attn_dims(in_types, attrs):
    t = _tensor_like(in_types[0])
    b = t.dim("batch") if t.has_dim("batch") else 1
    s = t.dim("seq") if t.has_dim("seq") else 1
    return b, s, attrs["heads"], attrs["head_dim"]


@estimator("sdpa_xla")
def _e_sdpa(in_types, attrs, syscat):
    b, s, h, d = _attn_dims(in_types, attrs)
    kv = s if "kv_seq" not in attrs else attrs["kv_seq"]
    causal = 0.5 if attrs.get("causal", True) and kv == s else 1.0
    dp = syscat.axis_size("data") * syscat.axis_size("pod")
    tp = syscat.axis_size("model")
    flops = 4.0 * b * s * kv * h * d * causal / (dp * tp)
    # full materialized scores: S×KV logits written+read in fp32
    bts = (b * h * s * kv * 8 * causal / (dp * tp)
           + 2 * b * s * h * d * 2 / (dp * tp)
           + 2 * b * kv * attrs["kv_heads"] * d * 2 / (dp * tp))
    return OpCost(flops, bts, 0.0)


@estimator("sdpa_banded_xla")
def _e_banded(in_types, attrs, syscat):
    b, s, h, d = _attn_dims(in_types, attrs)
    w = min(attrs.get("window") or s, s)
    dp = syscat.axis_size("data") * syscat.axis_size("pod")
    tp = syscat.axis_size("model")
    flops = 4.0 * b * s * w * h * d / (dp * tp)
    bts = (b * h * s * w * 8 / (dp * tp) + 4 * b * s * h * d * 2 / (dp * tp))
    return OpCost(flops, bts, 0.0)


@estimator("attn_flash_pallas")
def _e_flash(in_types, attrs, syscat):
    c = _e_sdpa(in_types, attrs, syscat)
    # online softmax: no materialized S×KV logits; only q/k/v/o HBM traffic
    b, s, h, d = _attn_dims(in_types, attrs)
    kv = s if "kv_seq" not in attrs else attrs["kv_seq"]
    dp = syscat.axis_size("data") * syscat.axis_size("pod")
    tp = syscat.axis_size("model")
    c.bytes = (2 * b * s * h * d * 2 + 2 * b * kv * attrs["kv_heads"] * d * 2) \
        / (dp * tp)
    return c


@estimator("mlp_fused_xla", "ffn_up_xla", "ffn_gate_xla", "ffn_down_xla")
def _e_mlp(in_types, attrs, syscat):
    t = _tensor_like(in_types[0])
    toks = _tokens(t)
    d = t.shape[-1]
    f = attrs.get("ffn", attrs.get("embed", d))
    mult = 3.0 if "mlp_fused" in str(attrs.get("pattern", "")) or \
        attrs.get("gated", False) else 1.0
    dp = syscat.axis_size("data") * syscat.axis_size("pod")
    tp = syscat.axis_size("model")
    flops = 2.0 * toks * d * f * mult / (dp * tp)
    bts = (toks * d * dtype_bytes(t.dtype) / dp + d * f * mult * 4 / tp)
    return OpCost(flops, bts, 0.0)


@estimator("moe_dense_onehot")
def _e_moe_dense(in_types, attrs, syscat):
    t = _tensor_like(in_types[0])
    toks = _tokens(t)
    d = t.shape[-1]
    f, e, k = attrs["ffn"], attrs["experts"], attrs["top_k"]
    cf = attrs.get("capacity_factor", 2.0)
    cap = max(1, int(toks * k * cf / e))
    dp = syscat.axis_size("data") * syscat.axis_size("pod")
    tp = syscat.axis_size("model")
    expert_flops = 2.0 * e * cap * 3 * d * f / (dp * tp)
    dispatch_flops = 2.0 * 2 * toks * e * cap * 1 / dp  # dispatch+combine einsum
    # all-to-all: tokens cross the model axis to reach their experts
    a2a = toks * d * 2 * 2 / dp
    return OpCost(expert_flops + dispatch_flops,
                  (toks * d * 2 + e * 3 * d * f * 4 / tp) / dp, a2a)


@estimator("moe_dropping")
def _e_moe_drop(in_types, attrs, syscat):
    # capacity-1.0 dispatch: overflow tokens drop, halving expert flops vs the
    # cf=2.0 dense dispatch (a speed/quality tradeoff the config must opt into)
    a = dict(attrs)
    a["capacity_factor"] = attrs.get("capacity_factor_dropped", 1.0)
    return _e_moe_dense(in_types, a, syscat)


@estimator("moe_gmm_pallas")
def _e_moe_gmm(in_types, attrs, syscat):
    t = _tensor_like(in_types[0])
    toks = _tokens(t)
    d = t.shape[-1]
    f, e, k = attrs["ffn"], attrs["experts"], attrs["top_k"]
    dp = syscat.axis_size("data") * syscat.axis_size("pod")
    tp = syscat.axis_size("model")
    # dropless grouped matmul: exactly tokens·k expert rows, no padding
    flops = 2.0 * toks * k * 3 * d * f / (dp * tp)
    a2a = toks * d * 2 * 2 / dp
    return OpCost(flops, (toks * d * 2 + e * 3 * d * f * 4 / tp) / dp, a2a)


@estimator("wkv6_scan_xla", "wkv6_pallas", "ssd_chunked_xla", "ssd_pallas")
def _e_recurrent(in_types, attrs, syscat):
    t = _tensor_like(in_types[0])
    toks = _tokens(t)
    h, d = attrs["heads"], attrs["head_dim"]
    n = attrs.get("state", d)
    dp = syscat.axis_size("data") * syscat.axis_size("pod")
    tp = syscat.axis_size("model")
    flops = 2.0 * toks * h * d * n * 3 / (dp * tp)
    bts = toks * h * d * 2 * 4 / (dp * tp)
    if attrs.get("_impl_pallas"):
        bts /= 2  # fused state in VMEM
    return OpCost(flops, bts, 0.0)


@estimator("embed_gather")
def _e_embed(in_types, attrs, syscat):
    t = _tensor_like(in_types[0])
    toks = _tokens(t) or t.size()
    dp = syscat.axis_size("data") * syscat.axis_size("pod")
    return OpCost(0.0, toks * attrs["embed"] * 2 / dp, 0.0)


@estimator("unembed_matmul")
def _e_unembed(in_types, attrs, syscat):
    return _proj_cost(in_types[0], attrs["vocab"], syscat)


# -- tri-store operators (raw features = the paper's table sizes / node
#    counts / keyword-list sizes, here rows / edges / postings).  Work is
#    priced on the **expected count** (the type's cardinality estimate, fed
#    by hints and observed-selectivity feedback), while streaming bytes are
#    priced on capacity — a masked engine still reads every physical row,
#    which is exactly why compact-then-dense can out-price masked-dense.


def _expected_rows(t) -> float:
    if isinstance(t, TableT):
        return float(t.expected_rows())
    return 1.0


def _capacity_rows(t) -> float:
    return float(t.rows) if isinstance(t, TableT) else 1.0


@estimator("rel_scan_col", "rel_filter_col", "col_tensor_rel")
def _e_rel_stream(in_types, attrs, syscat):
    t = in_types[0]
    b = _sum_bytes([t])
    return OpCost(_expected_rows(t), 2.0 * b, 0.0)


@estimator("rel_hash_join")
def _e_rel_join(in_types, attrs, syscat):
    lb, rb = _sum_bytes([in_types[0]]), _sum_bytes([in_types[1]])
    lr = _expected_rows(in_types[0])
    rr = _capacity_rows(in_types[1])
    # build (sort right) + probe (binary search per expected left row)
    logr = max(1.0, math.log2(max(rr, 2)))
    return OpCost(rr * logr + lr * logr, 2.0 * (lb + rb), 0.0)


@estimator("rel_join_probe_pallas")
def _e_rel_join_probe(in_types, attrs, syscat):
    """MXU key-equality probe: the whole (expected-count-bounded) build
    side against every probe block, one fused contraction — no sort.  The
    one-hot compare is MXU-shaped, so its flops are credited against the
    matrix unit; the candidate gate keeps the build bounded."""
    lb, rb = _sum_bytes([in_types[0]]), _sum_bytes([in_types[1]])
    lr = _capacity_rows(in_types[0])
    # the one-hot is as wide as the build side's *physical capacity* (the
    # VMEM-resident block); the gate keeps it bounded, the expected count
    # keeps the candidate from being offered against fat builds at all
    bw = float(attrs.get("build_rows", _capacity_rows(in_types[1])))
    mxu_credit = 64.0            # systolic contraction vs scalar compares
    blocks = max(1.0, lr / 512.0)
    return OpCost(lr * max(bw, 1.0) / mxu_credit + blocks * 256.0,
                  1.5 * (lb + rb), 0.0)


@estimator("bounded_join_col")
def _e_bounded_join(in_types, attrs, syscat):
    lb, rb = _sum_bytes([in_types[0]]), _sum_bytes([in_types[1]])
    lr = _expected_rows(in_types[0])
    rr = _capacity_rows(in_types[1])
    cap = float(attrs.get("capacity", lr))
    logr = max(1.0, math.log2(max(rr, 2)))
    # build sort + two binary searches per probe row + per-slot owner lookup
    out_b = cap * 4.0 * max(1, len(getattr(in_types[0], "columns", ())) + 1)
    return OpCost(rr * logr + 2.0 * lr * logr + cap * logr,
                  2.0 * (lb + rb) + out_b, 0.0)


@estimator("compact_prefix_col", "compact_prefix_pallas")
def _e_compact(in_types, attrs, syscat):
    """One full-capacity pass (the prefix sum over validity) plus a
    capacity-bounded gather/scatter write: what compact *costs* up front,
    repaid by every downstream op running at the narrowed capacity."""
    t = in_types[0]
    rows = _capacity_rows(t)
    cap = float(attrs.get("capacity", rows))
    ncols = max(1, len(getattr(t, "columns", ())))
    out_b = cap * 4.0 * (ncols + 1)
    flops = rows + cap * ncols
    if attrs.get("_impl_pallas"):
        # one-hot scatter: row-block x out-block matmul work instead of a
        # gather, partially credited to the MXU
        flops = rows + rows * cap / 64.0
    return OpCost(flops, _sum_bytes([t]) + 2.0 * out_b, 0.0)


@estimator("rel_group_agg_col")
def _e_rel_group(in_types, attrs, syscat):
    t = in_types[0]
    n_aggs = max(1, len(attrs.get("aggs", ())))
    out_b = int(attrs.get("num_groups", 1)) * 4 * (n_aggs + 1)
    return OpCost(_expected_rows(t) * n_aggs,
                  2.0 * _sum_bytes([t]) + out_b, 0.0)


def _graph_cost(g, passes, syscat, pallas=False):
    e, n = int(g.edges), int(g.nodes)
    flops = 2.0 * e * passes
    # CSR pass: per-edge (src gather + dst scatter) + per-node frontier r/w
    bts = passes * (e * 12.0 + n * 8.0)
    if pallas:
        bts /= 2  # frontier accumulator stays VMEM-resident per node block
    return OpCost(flops, bts, 0.0)


@estimator("graph_expand_csr", "graph_expand_pallas")
def _e_graph_expand(in_types, attrs, syscat):
    g = in_types[0]
    if not isinstance(g, GraphT):
        return OpCost(0.0, _sum_bytes(in_types), 0.0)
    return _graph_cost(g, int(attrs.get("hops", 1)), syscat,
                       pallas=attrs.get("_impl_pallas", False))


@estimator("graph_pagerank_csr", "graph_pagerank_pallas")
def _e_graph_pagerank(in_types, attrs, syscat):
    g = in_types[0]
    if not isinstance(g, GraphT):
        return OpCost(0.0, _sum_bytes(in_types), 0.0)
    return _graph_cost(g, int(attrs.get("iters", 10)), syscat,
                       pallas=attrs.get("_impl_pallas", False))


@estimator("graph_tricount_csr")
def _e_graph_tricount(in_types, attrs, syscat):
    g = in_types[0]
    if not isinstance(g, GraphT):
        return OpCost(0.0, _sum_bytes(in_types), 0.0)
    n, e = int(g.nodes), int(g.edges)
    # A·A over the densified adjacency (small-graph realization)
    return OpCost(2.0 * n * n * max(1, e // max(n, 1)), n * n * 8.0, 0.0)


@estimator("text_topk_inv")
def _e_text_topk(in_types, attrs, syscat):
    c = in_types[0]
    if not isinstance(c, CorpusT):
        return OpCost(0.0, _sum_bytes(in_types), 0.0)
    # one pass over the postings + a top-k over doc scores
    k = int(attrs.get("k", 10))
    return OpCost(2.0 * c.postings + c.docs * max(1.0, math.log2(max(k, 2))),
                  float(c.bytesize()) + c.docs * 4.0, 0.0)


@estimator("text_scores_inv")
def _e_text_scores(in_types, attrs, syscat):
    c = in_types[0]
    if not isinstance(c, CorpusT):
        return OpCost(0.0, _sum_bytes(in_types), 0.0)
    return OpCost(2.0 * c.postings, float(c.bytesize()) + c.docs * 4.0, 0.0)


@estimator("masked_topk_xla")
def _e_masked_topk(in_types, attrs, syscat):
    t = _tensor_like(in_types[0])
    n = int(t.shape[0]) if t is not None and t.rank else 1
    k = int(attrs.get("k", 10))
    return OpCost(n * max(1.0, math.log2(max(k, 2))), n * 9.0, 0.0)


@estimator("sel_mask_rel")
def _e_sel_mask(in_types, attrs, syscat):
    t = in_types[0]
    rows = t.rows if isinstance(t, TableT) else 1
    return OpCost(float(rows), rows * 5.0 + int(attrs.get("size", 1)), 0.0)


# expected-selectivity pricing (pushdown's decision variable): masked ops
# carry the rewrite pass's estimate as an IR attr, and the skip candidates
# are credited exactly the postings/edges they are expected not to touch —
# plus a per-block control overhead, so at selectivity ~1.0 the dense
# candidate prices lower and the planner keeps the unpushed execution.

TEXT_SKIP_BLOCK = 8192       # postings per block-skip scan step
GRAPH_SKIP_BLOCK = 2048      # edges per block-skip SpMV step
_BLOCK_OVERHEAD_FLOPS = 256.0


@estimator("text_topk_skip_inv", "text_topk_masked_pallas")
def _e_text_topk_skip(in_types, attrs, syscat):
    c = in_types[0]
    if not isinstance(c, CorpusT):
        return OpCost(0.0, _sum_bytes(in_types), 0.0)
    s = float(attrs.get("selectivity", 1.0))
    k = int(attrs.get("k", 10))
    blocks = max(1.0, c.postings / TEXT_SKIP_BLOCK)
    flops = (2.0 * c.postings * s + blocks * _BLOCK_OVERHEAD_FLOPS
             + c.docs * max(1.0, math.log2(max(k, 2))))
    bts = float(c.bytesize()) * s + c.docs * 9.0 + blocks * 64.0
    if attrs.get("_impl_pallas"):
        bts /= 2     # doc-block accumulator stays VMEM-resident
    return OpCost(flops, bts, 0.0)


@estimator("graph_pagerank_skip")
def _e_graph_pagerank_skip(in_types, attrs, syscat):
    """First-iteration block-skipping PageRank: iteration 0 touches only
    the edge blocks the sparse personalization activates; the remaining
    iterations (dense rank vector) cost the full CSR pass."""
    g = in_types[0]
    if not isinstance(g, GraphT):
        return OpCost(0.0, _sum_bytes(in_types), 0.0)
    s = float(attrs.get("personalization_selectivity", 1.0))
    iters = max(1, int(attrs.get("iters", 10)))
    per_pass = _graph_cost(g, 1, syscat)
    blocks = max(1.0, int(g.edges) / GRAPH_SKIP_BLOCK)
    eff = (iters - 1) + min(1.0, s)
    return OpCost(per_pass.flops * eff + blocks * _BLOCK_OVERHEAD_FLOPS
                  + 2.0 * int(g.nodes),
                  per_pass.bytes * eff + int(g.nodes) * 8.0 + blocks * 64.0,
                  0.0)


@estimator("graph_expand_skip")
def _e_graph_expand_skip(in_types, attrs, syscat):
    g = in_types[0]
    if not isinstance(g, GraphT):
        return OpCost(0.0, _sum_bytes(in_types), 0.0)
    s = float(attrs.get("frontier_selectivity", 1.0))
    hops = int(attrs.get("hops", 1))
    e, n = int(g.edges), int(g.nodes)
    deg = max(1.0, e / max(n, 1))
    # the frontier densifies by ~avg-degree per hop: later hops skip less
    eff = sum(min(1.0, s * deg ** h) for h in range(hops)) / max(hops, 1)
    base = _graph_cost(g, hops, syscat)
    blocks = max(1.0, e / GRAPH_SKIP_BLOCK)
    return OpCost(base.flops * eff + hops * (blocks * _BLOCK_OVERHEAD_FLOPS
                                             + 2.0 * n),
                  base.bytes * eff + hops * (n * 8.0 + blocks * 64.0), 0.0)


# fused store chains: Eq. 1 over the recorded steps (each step priced by
# its per-op estimator on the recorded input types), minus the interior
# table reads the fusion avoids — interior steps stream the mask, not the
# full relation, so each non-head step is charged its output instead of a
# second full input pass.

_STEP_IMPL = {"rel_scan": "rel_scan_col", "rel_filter": "rel_filter_col",
              "compact": "compact_prefix_col",
              "rel_join": "rel_hash_join",
              "bounded_join": "bounded_join_col",
              "rel_group_agg": "rel_group_agg_col"}


@estimator("rel_fused_col", "rel_fused_agg_pallas")
def _e_rel_fused(in_types, attrs, syscat):
    total = OpCost()
    prev_t = None
    for op, step_attrs, srcs, out_t in attrs.get("chain", ()):
        step_ins = [prev_t if s == "prev" else
                    (in_types[int(s)] if int(s) < len(in_types) else None)
                    for s in srcs]
        c = op_cost(_STEP_IMPL.get(op, op), step_ins, step_attrs, syscat)
        if prev_t is not None:
            # fused: the interior input was just produced in-engine; credit
            # one full-relation read per non-head step
            c.bytes = max(0.0, c.bytes - _sum_bytes([prev_t]))
        total.flops += c.flops
        total.bytes += c.bytes
        total.coll_bytes += c.coll_bytes
        prev_t = out_t
    if attrs.get("_impl_pallas"):
        total.bytes *= 0.75   # masked one-hot agg keeps partials in VMEM
    return total


@estimator("xfer_pin")
def _e_xfer_pin(in_types, attrs, syscat):
    # stays device-resident: one HBM pass at most (often free after fusion)
    return OpCost(0.0, _sum_bytes(in_types), 0.0)


@estimator("xfer_spill")
def _e_xfer_spill(in_types, attrs, syscat):
    # materialize through the host: device->host->device round trip, priced
    # on the interconnect (the cross-engine wire of the paper's tri-store)
    b = _sum_bytes(in_types)
    return OpCost(0.0, 2.0 * b, 2.0 * b)


@estimator("xfer_local")
def _e_xfer_local(in_types, attrs, syscat):
    # layout-compatible handoff between sharded store ops: no wire bytes
    return OpCost(0.0, 0.0, 0.0)


@estimator("xfer_replicate")
def _e_xfer_replicate(in_types, attrs, syscat):
    # all-gather a data-axis-partitioned value: every device receives the
    # (n-1)/n of the value it does not already hold
    n = max(1, syscat.axis_size("data"))
    b = float(attrs.get("est_bytes", _sum_bytes(in_types) * (n - 1) / n))
    return OpCost(0.0, b, b)


@estimator("xfer_repartition")
def _e_xfer_repartition(in_types, attrs, syscat):
    # all-to-all reshuffle onto the join key's owner shards: each device
    # keeps 1/n of its 1/n slice and sends the rest — (n-1)/n^2 of the
    # global value crosses the wire per device
    n = max(1, syscat.axis_size("data"))
    b = float(attrs.get("est_bytes",
                        _sum_bytes(in_types) * (n - 1) / (n * n)))
    return OpCost(0.0, b, b)


def op_cost(impl: str, in_types, attrs, syscat: SystemCatalog) -> OpCost:
    fn = _ESTIMATORS.get(impl)
    if fn is None:
        return OpCost(0.0, _sum_bytes(in_types) /
                      max(1, syscat.axis_size("data") * syscat.axis_size("pod")),
                      0.0)
    a = dict(attrs)
    if impl.endswith("_pallas"):
        a["_impl_pallas"] = True
    c = fn(in_types, a, syscat)
    dist = attrs.get("dist")
    if dist and not impl.startswith("xfer"):
        # shard-local execution (shard_stores): compute and memory divide
        # over the data axis; the broadcast join additionally prices the
        # build side's all-gather, psum-style aggregates a tree reduction
        n = max(1, syscat.axis_size("data"))
        coll = c.coll_bytes
        if dist == "broadcast":
            coll += float(attrs.get("bcast_bytes", 0.0))
        elif dist in ("psum", "doc"):
            coll += c.bytes / max(n, 1) * math.log2(max(n, 2))
        return OpCost(c.flops / n, c.bytes / n, coll)
    return c


def raw_features(impl, in_types, attrs, syscat) -> dict:
    """The paper's raw feature vector f1..fn for one operator instance."""
    c = op_cost(impl, in_types, attrs, syscat)
    hw = syscat.hardware
    t = _tensor_like(in_types[0]) if in_types else None
    return {
        "f_compute": c.flops / hw.peak_flops,
        "f_memory": c.bytes / hw.hbm_bw,
        "f_network": c.coll_bytes / hw.ici_bw,
        "tokens_m": (_tokens(t) if t is not None else 0) / 1e6,
        "width_k": (t.shape[-1] if t is not None and t.rank else 0) / 1e3,
    }


# --------------------------------------------------------------------------
# Eq. 2 — degree-2 polynomial regression per operator
# --------------------------------------------------------------------------


def poly2(x: np.ndarray) -> np.ndarray:
    """[1, xi..., xi^2..., xi*xj...] exactly as Eq. 2."""
    n = x.shape[-1]
    feats = [np.ones(x.shape[:-1] + (1,)), x, x * x]
    cross = [x[..., i:i + 1] * x[..., j:j + 1]
             for i in range(n) for j in range(i + 1, n)]
    return np.concatenate(feats + cross, axis=-1)


@dataclass
class CostModel:
    """Per-operator learned weights; falls back to analytic roofline."""

    weights: dict = field(default_factory=dict)  # impl -> np.ndarray
    feature_names: tuple = FEATURE_NAMES

    # -- Eq. 2 -------------------------------------------------------------
    def op_seconds(self, impl, in_types, attrs, syscat) -> float:
        f = raw_features(impl, in_types, attrs, syscat)
        if impl in self.weights:
            x = np.array([f[k] for k in self.feature_names])
            return float(poly2(x[None, :])[0] @ self.weights[impl])
        # analytic fallback: roofline additive model (known-weight Eq. 2)
        return f["f_compute"] + f["f_memory"] + f["f_network"]

    # -- Eq. 1 -------------------------------------------------------------
    def chain_seconds(self, impls, in_types, attrs, syscat) -> float:
        return sum(self.op_seconds(i, in_types, attrs, syscat) for i in impls)

    # -- §6.2 fit ------------------------------------------------------------
    def fit(self, samples, ridge: float = 1e-8):
        """samples: iterable of (impl, feature-dict, measured_seconds)."""
        by_impl: dict = {}
        for impl, f, t in samples:
            by_impl.setdefault(impl, []).append((f, t))
        for impl, rows in by_impl.items():
            X = np.stack([np.array([f[k] for k in self.feature_names])
                          for f, _ in rows])
            y = np.array([t for _, t in rows])
            P = poly2(X)
            A = P.T @ P + ridge * np.eye(P.shape[1])
            self.weights[impl] = np.linalg.solve(A, P.T @ y)
        return self

    def predict_samples(self, samples):
        out = []
        for impl, f, _ in samples:
            x = np.array([f[k] for k in self.feature_names])
            if impl in self.weights:
                out.append(float(poly2(x[None, :])[0] @ self.weights[impl]))
            else:
                out.append(f["f_compute"] + f["f_memory"] + f["f_network"])
        return np.array(out)

    # -- identity ------------------------------------------------------------
    def fingerprint(self) -> str:
        """Content hash of the learned weights ("analytic" before any
        calibration).  Part of the plan-cache key: refitted weights change
        candidate selection, so they must invalidate cached plans."""
        if not self.weights:
            return "analytic"
        import hashlib
        h = hashlib.sha256()
        for impl in sorted(self.weights):
            h.update(impl.encode())
            h.update(np.asarray(self.weights[impl], np.float64).tobytes())
        return h.hexdigest()

    # -- persistence ---------------------------------------------------------
    def save(self, path):
        with open(path, "w") as fh:
            json.dump({k: v.tolist() for k, v in self.weights.items()}, fh)

    @classmethod
    def load(cls, path):
        with open(path) as fh:
            w = json.load(fh)
        return cls({k: np.array(v) for k, v in w.items()})


# --------------------------------------------------------------------------
# §6.3 — run-time candidate selection at each virtual node
# --------------------------------------------------------------------------


def select_candidates(pp: PhysPlan, syscat: SystemCatalog,
                      model: Optional[CostModel] = None,
                      engines=None, allow_pallas=None) -> tuple:
    """Score every virtual node's candidates (Eq. 1 over the chain) and pick
    the argmin.  ``engines`` names the engines whose candidates are eligible
    (registry names; the legacy ``allow_pallas`` boolean still maps through).
    Returns (choices dict incl. nested subplans, report list)."""
    from .engines import resolve_engines
    engines = resolve_engines(engines, allow_pallas=allow_pallas)
    model = model or CostModel()
    choices: dict = {}
    report = []

    def visit(plan: PhysPlan):
        for n in plan.topo():
            if n.subplan is not None:
                visit(n.subplan)
            if not n.virtual:
                continue
            in_types = [plan.types.get(i) or plan.inputs.get(i)
                        for i in n.inputs]
            scored = []
            for cand in plan.pm[n.id]:
                if cand.requires_backend not in engines:
                    continue
                sec = model.chain_seconds(cand.impls, in_types, n.attrs, syscat)
                scored.append((sec, cand))
            if not scored:
                raise RuntimeError(f"no available candidate for {n.id}")
            scored.sort(key=lambda x: x[0])
            choices[n.id] = scored[0][1]
            report.append({
                "virtual": n.id,
                "pattern": n.attrs.get("pattern"),
                "chosen": scored[0][1].name,
                "engine": scored[0][1].requires_backend,
                "costs": {c.name: s for s, c in scored},
            })

    visit(pp)
    return choices, report


# --------------------------------------------------------------------------
# Resident-byte prediction (ledger predicted-vs-actual)
# --------------------------------------------------------------------------


def predicted_resident_bytes(t) -> Optional[int]:
    """Cost-model expectation for the *device-resident* bytes a store holds
    for a value of type ``t`` — ``bytesize()`` (capacity-derived: padded
    columns + validity, CSR arrays, COO postings) plus the shard-local
    block payloads a partitioned store keeps alongside the replicated
    structure.  The MemoryLedger compares this against the measured
    ``tree_bytes`` of the actual payload; the tri-store benchmark enforces
    2x agreement."""
    base = t.bytesize() if hasattr(t, "bytesize") else None
    if base is None:
        return None
    extra = 0
    if isinstance(t, GraphT) and getattr(t, "partitioning", None):
        # dst-block payload: blk_src + blk_dst_local (int32) + blk_weights
        # (f32), each padded to the max per-block edge count ~ edges total
        extra = t.edges * 12
    elif isinstance(t, CorpusT) and getattr(t, "partitioning", None):
        # doc-block payload: blk_doc_local + blk_term_ids (int32) + blk_tf
        # (f32) padded per partition ~ postings total
        extra = t.postings * 12
    return int(base + extra)
