"""Resilient execution: error taxonomy, retries, breakers, degraded replans.

PR 7/8 built the *observe* half of production readiness (tracing, ledger,
flight recorder); this module is the *survive* half, in the spirit of
BigDAWG's degraded cross-island execution and Polystore++'s
accelerator-fallback argument: when a Pallas kernel, a sharded collective,
or a compacted store op fails at runtime, the right response is usually not
"replay the same broken plan" but "re-plan without the broken capability".

The pieces:

  * :class:`ExecError` — the taxonomy.  Every executor failure is wrapped
    with its site (node id / op / impl / engine) and classified
    retryable-vs-fatal (:func:`classify`).  Injected faults and transient
    infra errors are retryable; shape/type/missing-impl bugs are fatal —
    retrying those burns the deadline for nothing.
  * :class:`RetryPolicy` — deadline-aware bounded retries with exponential
    backoff and *deterministic* jitter (hash of (seed, attempt), so two
    runs of the same schedule back off identically).
  * :class:`CircuitBreaker` — per-(plan_id, fallback-class) failure
    counters.  Tripping open feeds a **candidate blocklist** that
    :func:`degrade_options` folds into the planning options — and because
    ``engines`` and ``rewrite_pipeline`` are part of
    ``PlanOptions.cache_key()`` (plus an explicit ``extra_key``), the
    re-plan has a *provably different plan id*:

        pallas broken    -> drop the "pallas" engine (XLA impls win)
        sharded broken   -> drop "shard_stores"       (dense-global stores)
        compacted broken -> drop "choose_compaction"  (UNCOMPACTED pipeline)

  * :class:`ResilientExecutor` — the loop tying them together:
    plan (under the current blocklist) -> run -> on failure classify,
    record, maybe trip the breaker, back off, re-plan, retry — all within
    the deadline, with every event landed in the FlightRecorder ring.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .faults import FaultInjectedError

# fallback classes — the units the breaker opens over (coarse on purpose:
# one broken Pallas kernel poisons trust in the whole engine for this plan)
FALLBACK_CLASSES = ("pallas", "sharded", "compacted")


class ExecError(RuntimeError):
    """An executor failure with its site attached.

    ``node_id`` / ``op`` / ``impl`` / ``engine`` locate the failure in the
    physical plan; ``retryable`` drives the retry loop; ``plan_id`` ties
    the failure to the plan fingerprint the breaker keys on."""

    def __init__(self, message: str, *, node_id: str = "", op: str = "",
                 impl: str = "", engine: str = "", plan_id: str = "",
                 retryable: bool = True,
                 cause: Optional[BaseException] = None):
        super().__init__(message)
        self.node_id = node_id
        self.op = op
        self.impl = impl
        self.engine = engine
        self.plan_id = plan_id
        self.retryable = retryable
        self.cause = cause

    def to_dict(self) -> dict:
        return {"error": str(self), "node_id": self.node_id, "op": self.op,
                "impl": self.impl, "engine": self.engine,
                "plan_id": self.plan_id, "retryable": self.retryable,
                "cause": repr(self.cause) if self.cause else None}


# exception types that indicate a *plan or program bug* — retrying the same
# (or any) plan cannot fix them, so the loop fails fast
_FATAL_TYPES = (TypeError, ValueError, KeyError, IndexError,
                AttributeError, NotImplementedError, AssertionError)


def classify(exc: BaseException, *, node=None, plan_id: str = "",
             engine: str = "") -> ExecError:
    """Wrap any raised exception into the :class:`ExecError` taxonomy.

    Injected faults model transient infra failures -> retryable.  Python
    bug types (shape/type/lookup errors) -> fatal.  Everything else
    (RuntimeError from a backend, XLA internal errors) is treated as
    retryable: the cost of one wasted retry is far below the cost of
    failing a request on a transient."""
    if isinstance(exc, ExecError):
        return exc
    kw = {"plan_id": plan_id, "engine": engine, "cause": exc}
    if node is not None:
        kw.update(node_id=str(getattr(node, "id", "")),
                  op=str(getattr(node, "op", "")),
                  impl=str(getattr(node, "impl", "")))
    if isinstance(exc, FaultInjectedError):
        return ExecError(f"injected fault: {exc}", retryable=True, **kw)
    if isinstance(exc, _FATAL_TYPES):
        return ExecError(f"fatal {type(exc).__name__}: {exc}",
                         retryable=False, **kw)
    return ExecError(f"{type(exc).__name__}: {exc}", retryable=True, **kw)


def fallback_class(err: ExecError) -> Optional[str]:
    """Map a failure site to the capability the breaker should distrust.

    Pallas-engine impls -> "pallas"; collective/xfer impls (the sharded
    execution seams) -> "sharded"; compaction impls -> "compacted".  None
    means no structural fallback exists (plain retry is all we have)."""
    if err.engine == "pallas" or err.impl.endswith("_pallas"):
        return "pallas"
    if err.impl.startswith("xfer_") or "all_to_all" in err.impl \
            or "collective" in err.impl:
        return "sharded"
    if err.impl.startswith("compact") or "compact" in err.op:
        return "compacted"
    # fault sites carry the impl in the site tuple even when the node
    # attribution is missing (e.g. runtime-seam injections)
    if isinstance(err.cause, FaultInjectedError):
        flat = "/".join(map(str, err.cause.site))
        if "pallas" in flat:
            return "pallas"
        if "xfer" in flat or "shard" in flat:
            return "sharded"
        if "compact" in flat:
            return "compacted"
    return None


# --------------------------------------------------------------------------
# retry policy
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded, deadline-aware retries with deterministic jitter.

    ``backoff_s(attempt)`` is a pure function of (seed, attempt) — two runs
    of the same failure schedule sleep identically, keeping chaos runs
    reproducible end-to-end."""

    max_attempts: int = 3
    base_backoff_s: float = 0.01
    max_backoff_s: float = 1.0
    jitter: float = 0.25             # +/- fraction of the backoff
    seed: int = 0

    def backoff_s(self, attempt: int) -> float:
        base = min(self.base_backoff_s * (2 ** max(attempt - 1, 0)),
                   self.max_backoff_s)
        if self.jitter <= 0.0:
            return base
        h = hashlib.sha256(
            repr((self.seed, attempt)).encode()).digest()
        u = int.from_bytes(h[:8], "big") / float(1 << 64)   # [0, 1)
        return base * (1.0 + self.jitter * (2.0 * u - 1.0))

    def should_retry(self, err: ExecError, attempt: int, *,
                     elapsed_s: float = 0.0,
                     deadline_s: Optional[float] = None) -> bool:
        """One decision point: attempts left, error retryable, and the next
        backoff still fits inside the deadline."""
        if not err.retryable:
            return False
        if attempt >= self.max_attempts:
            return False
        if deadline_s is not None and \
                elapsed_s + self.backoff_s(attempt) >= deadline_s:
            return False
        return True


# --------------------------------------------------------------------------
# circuit breaker -> candidate blocklist
# --------------------------------------------------------------------------


@dataclass
class CircuitBreaker:
    """Per-(plan_id, fallback-class) failure counter with an open state
    that feeds the planner's candidate blocklist.

    ``threshold`` consecutive failures of one class open the circuit for
    ``cooldown_s``; while open, :meth:`blocklist` reports the class and the
    re-plan drops the matching capability.  A success on the fallback plan
    does *not* close the circuit early — the broken capability stays
    avoided until the cooldown expires (half-open), at which point one
    probe is allowed through."""

    threshold: int = 1
    cooldown_s: float = 60.0
    clock: Callable[[], float] = time.monotonic
    _fail: dict = field(default_factory=dict)    # (plan_id, cls) -> count
    _open_at: dict = field(default_factory=dict)  # (plan_id, cls) -> t_open
    events: list = field(default_factory=list)

    def record_failure(self, plan_id: str, err: ExecError) -> Optional[str]:
        """Count a failure; returns the fallback class if the circuit
        (newly or already) holds open for it, else None."""
        cls = fallback_class(err)
        if cls is None:
            return None
        key = (plan_id, cls)
        self._fail[key] = self._fail.get(key, 0) + 1
        if self._fail[key] >= self.threshold and key not in self._open_at:
            self._open_at[key] = self.clock()
            self.events.append(("open", plan_id, cls))
        return cls if key in self._open_at else None

    def record_success(self, plan_id: str) -> None:
        """A clean run on this plan closes any *expired* circuits (the
        half-open probe succeeded) and clears failure counters."""
        now = self.clock()
        for key in [k for k in self._open_at if k[0] == plan_id]:
            if now - self._open_at[key] >= self.cooldown_s:
                del self._open_at[key]
                self._fail.pop(key, None)
                self.events.append(("close", key[0], key[1]))
        for key in [k for k in self._fail if k[0] == plan_id
                    and k not in self._open_at]:
            self._fail.pop(key, None)

    def is_open(self, plan_id: str, cls: str) -> bool:
        key = (plan_id, cls)
        t = self._open_at.get(key)
        if t is None:
            return False
        if self.clock() - t >= self.cooldown_s:
            return False                 # half-open: allow a probe
        return True

    def blocklist(self, plan_id: str) -> tuple:
        """The fallback classes currently open for this plan, sorted —
        the tuple folded into the re-plan's ``extra_key`` (and realized
        structurally by :func:`degrade_options`)."""
        return tuple(sorted(
            cls for (pid, cls) in self._open_at
            if pid == plan_id and self.is_open(pid, cls)))

    def fingerprint(self, plan_id: str) -> tuple:
        """Plan-identity material: ``("blocklist", *classes)``.  Folding
        this into ``extra_key`` makes a breaker-open re-plan a provable
        cache miss even if the structural degrade were a no-op."""
        return ("blocklist",) + self.blocklist(plan_id)


def degrade_options(engines: tuple, rewrite_pipeline: tuple,
                    blocklist: tuple) -> tuple:
    """Realize a blocklist structurally: returns degraded
    ``(engines, rewrite_pipeline)``.

        "pallas"    -> remove the pallas engine (XLA candidates win)
        "sharded"   -> drop the shard_stores pass (dense-global stores,
                       replicated execution — no collectives to fail)
        "compacted" -> drop choose_compaction (UNCOMPACTED behaviour)

    Both tuples are part of ``PlanOptions.cache_key()``, so any non-empty
    applicable blocklist changes the plan id."""
    engines = tuple(engines)
    pipeline = tuple(rewrite_pipeline)
    if "pallas" in blocklist:
        engines = tuple(e for e in engines if e != "pallas")
    if "sharded" in blocklist:
        pipeline = tuple(p for p in pipeline if p != "shard_stores")
    if "compacted" in blocklist:
        pipeline = tuple(p for p in pipeline if p != "choose_compaction")
    return engines, pipeline


# --------------------------------------------------------------------------
# the resilient execution loop
# --------------------------------------------------------------------------


@dataclass
class ResilientExecutor:
    """plan -> run -> classify -> (breaker, backoff) -> re-plan -> retry.

    Wraps the staged plan pipeline with the full survival loop.  Give it
    the *planning inputs* (logical plan, catalogs, baseline engines /
    rewrite pipeline) rather than a compiled function: a breaker trip must
    be able to re-enter the planner with degraded options.

    ``recorder`` (FlightRecorder) receives every retry, breaker trip, and
    final failure; ``faults`` (FaultInjector) threads into the ExecContext
    of every attempt."""

    catalog: Any
    syscat: Any
    policy: RetryPolicy = RetryPolicy()
    breaker: CircuitBreaker = field(default_factory=CircuitBreaker)
    recorder: Optional[Any] = None
    faults: Optional[Any] = None
    clock: Callable[[], float] = time.monotonic
    sleep: Callable[[float], None] = time.sleep
    # plan-time knobs forwarded to plan_and_compile
    engines: tuple = ("xla",)
    rewrite_pipeline: Optional[tuple] = None
    plan_kwargs: dict = field(default_factory=dict)
    attempts_log: list = field(default_factory=list)

    def compile(self, logical, *, blocklist: tuple = ()):
        """Plan under the current blocklist.  The blocklist degrades the
        options structurally *and* is folded into extra_key, so the plan id
        provably differs from the undegraded plan's."""
        from .executor import plan_and_compile
        from .rewrite import DEFAULT_PIPELINE
        engines, pipeline = degrade_options(
            self.engines, self.rewrite_pipeline or DEFAULT_PIPELINE,
            blocklist)
        kw = dict(self.plan_kwargs)
        if blocklist:
            prior = tuple(kw.pop("store_versions", ()) or ())
            kw["store_versions"] = prior + (("blocklist",) + blocklist,)
        fn = plan_and_compile(logical, self.catalog, self.syscat,
                              engines=engines, rewrite_pipeline=pipeline,
                              **kw)
        if self.faults is not None:
            fn.faults = self.faults
        return fn

    def run(self, logical, params, inputs: dict, *,
            aux: Optional[dict] = None,
            deadline_s: Optional[float] = None):
        """Execute with retries + degraded replanning.  Returns
        ``(outputs, planned_fn)`` — callers can inspect ``planned_fn.plan_id``
        to see whether a fallback plan served the request.  Raises the last
        :class:`ExecError` when retries are exhausted or the error is
        fatal."""
        t0 = self.clock()
        attempt = 0
        base_fn = self.compile(logical)
        base_plan_id = base_fn.plan_id
        fn = base_fn
        last_blocklist: tuple = self.breaker.blocklist(base_plan_id)
        if last_blocklist:
            fn = self.compile(logical, blocklist=last_blocklist)
        while True:
            attempt += 1
            try:
                out = fn(params, inputs, aux)
                self.breaker.record_success(base_plan_id)
                self.attempts_log.append(
                    ("ok", attempt, fn.plan_id, last_blocklist))
                return out, fn
            except Exception as exc:
                err = classify(exc, plan_id=fn.plan_id)
                elapsed = self.clock() - t0
                self.attempts_log.append(
                    ("fail", attempt, fn.plan_id, err.to_dict()))
                opened = self.breaker.record_failure(base_plan_id, err)
                if self.recorder is not None:
                    self.recorder.record("exec_retry", {
                        "attempt": attempt, "plan_id": fn.plan_id,
                        "error": err.to_dict(), "elapsed_s": elapsed})
                    if opened:
                        self.recorder.trip("breaker_open", {
                            "plan_id": base_plan_id, "class": opened,
                            "error": err.to_dict()})
                if not self.policy.should_retry(
                        err, attempt, elapsed_s=elapsed,
                        deadline_s=deadline_s):
                    if self.recorder is not None:
                        reason = ("deadline_exceeded"
                                  if err.retryable else "fatal_error")
                        self.recorder.trip("retries_exhausted", {
                            "plan_id": fn.plan_id, "attempts": attempt,
                            "reason": reason, "error": err.to_dict()})
                    raise err from exc
                self.sleep(self.policy.backoff_s(attempt))
                blocklist = self.breaker.blocklist(base_plan_id)
                if blocklist != last_blocklist:
                    fn = self.compile(logical, blocklist=blocklist)
                    last_blocklist = blocklist


__all__ = ["ExecError", "classify", "fallback_class", "RetryPolicy",
           "CircuitBreaker", "degrade_options", "ResilientExecutor",
           "FALLBACK_CLASSES"]
