"""Deterministic fault injection: the chaos half of the resilience layer.

A production tri-store survives failed collectives, broken kernels, and
latency spikes only if those failures can be *rehearsed*.  This module is
the rehearsal harness: a :class:`FaultInjector` threaded through
:class:`~repro.core.executor.ExecContext` (``faults=None`` keeps the
executor on its untouched fast path, the same zero-cost pattern as
``tracer=None``) and through the serving runtime's admission/prefill/decode
seams.

Determinism is the design center.  Every potential fault site is a tuple
key — ``("node", node_id, impl)``, ``("xfer", node_id, kind)``,
``("prefill", rid, bucket)``, ``("decode", tick)`` — and the fire decision
is a pure hash of ``(seed, site, occurrence)``: the *n*-th execution of a
site either always faults or never faults for a given seed.  Two runs of
the same workload under the same seed therefore produce the **same failure
schedule** (asserted by ``tests/test_resilience.py``), which is what makes
"non-faulted requests are bitwise-identical to a fault-free run" a testable
property rather than a hope.

Fault kinds:

  * **error** — raise :class:`FaultInjectedError` at the site (executor
    node failures, xfer/collective failures, prefill/decode failures);
  * **latency** — a deterministic ``sleep(latency_s)`` spike at the site;
  * **stall** — an admission-side sleep (the serving front door pauses,
    exercising queue growth and deadline expiry under backpressure).

``always_fail`` substrings mark sites as *persistently* broken (every
occurrence faults) — the knob that forces the circuit breaker open and
proves the re-plan-onto-fallback path; ``max_faults`` bounds the total
number of injected errors so chaos runs terminate.
"""
from __future__ import annotations

import hashlib
import time
from typing import Optional, Sequence


class FaultInjectedError(RuntimeError):
    """An injected failure.  Carries its site so the resilience layer can
    attribute it (node id / impl / engine) and the tests can assert the
    schedule.  Injected faults are *retryable by definition* — they model
    transient infrastructure failures, not plan bugs."""

    def __init__(self, site: tuple, occurrence: int, kind: str = "error"):
        self.site = tuple(site)
        self.occurrence = int(occurrence)
        self.kind = kind
        super().__init__(
            f"injected {kind} fault at {self.site} "
            f"(occurrence {self.occurrence})")


def _site_hash(seed: int, site: tuple, occurrence: int) -> float:
    """Pure uniform-in-[0,1) decision value for one (site, occurrence)."""
    key = repr((int(seed), tuple(map(str, site)), int(occurrence)))
    h = hashlib.sha256(key.encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


class FaultInjector:
    """Seed + site-keyed deterministic fault source.

    ``rate`` is the per-occurrence error probability (hashed, not sampled:
    the schedule is a pure function of the seed); ``latency_rate`` /
    ``latency_s`` control deterministic latency spikes; ``stall_s`` is the
    admission-stall duration (categories listed in ``stall_categories``
    sleep instead of raising).  ``categories`` restricts error injection to
    the named site categories (first tuple element); ``always_fail``
    substrings mark persistently broken sites.
    """

    def __init__(self, seed: int = 0, rate: float = 0.0, *,
                 categories: Optional[Sequence[str]] = None,
                 always_fail: Sequence[str] = (),
                 max_faults: Optional[int] = None,
                 latency_rate: float = 0.0, latency_s: float = 0.0,
                 stall_s: float = 0.0,
                 stall_categories: Sequence[str] = ("admission",),
                 sleep=time.sleep):
        if not 0.0 <= float(rate) <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        self.seed = int(seed)
        self.rate = float(rate)
        self.categories = (None if categories is None
                           else frozenset(categories))
        self.always_fail = tuple(str(s) for s in always_fail)
        self.max_faults = max_faults if max_faults is None else int(max_faults)
        self.latency_rate = float(latency_rate)
        self.latency_s = float(latency_s)
        self.stall_s = float(stall_s)
        self.stall_categories = frozenset(stall_categories)
        self._sleep = sleep
        self._occurrence: dict = {}      # site -> times seen
        self.injected: list = []         # [(kind, site, occurrence), ...]
        self.checked = 0

    # -- schedule ----------------------------------------------------------
    def _always(self, site: tuple) -> bool:
        if not self.always_fail:
            return False
        flat = "/".join(map(str, site))
        return any(s in flat for s in self.always_fail)

    def would_fail(self, site: tuple, occurrence: int) -> bool:
        """The pure decision: does occurrence *n* of ``site`` fault?  No
        state is consumed — the schedule is inspectable ahead of time."""
        site = tuple(site)
        if self._always(site):
            return True
        if self.rate <= 0.0:
            return False
        if self.categories is not None and site[0] not in self.categories:
            return False
        return _site_hash(self.seed, site, occurrence) < self.rate

    # -- runtime hooks -----------------------------------------------------
    def check(self, site: tuple) -> None:
        """The executor/runtime hook: count this occurrence of ``site`` and
        raise / spike / pass according to the deterministic schedule."""
        site = tuple(site)
        self.checked += 1
        occ = self._occurrence.get(site, 0)
        self._occurrence[site] = occ + 1
        if site[0] in self.stall_categories:
            if self.stall_s > 0.0:
                self.injected.append(("stall", site, occ))
                self._sleep(self.stall_s)
            return
        if (self.latency_rate > 0.0 and self.latency_s > 0.0
                and _site_hash(self.seed + 0x5eed, site, occ)
                < self.latency_rate):
            self.injected.append(("latency", site, occ))
            self._sleep(self.latency_s)
        budget_left = (self.max_faults is None
                       or self.n_errors() < self.max_faults)
        if budget_left and self.would_fail(site, occ):
            self.injected.append(("error", site, occ))
            raise FaultInjectedError(site, occ)

    def n_errors(self) -> int:
        return sum(1 for k, _s, _o in self.injected if k == "error")

    def schedule(self) -> list:
        """The injected-fault log as plain tuples (determinism assert)."""
        return [(k, tuple(map(str, s)), o) for k, s, o in self.injected]

    def reset(self) -> None:
        """Clear occurrence counters + log: re-running the same workload
        replays the identical schedule."""
        self._occurrence.clear()
        self.injected.clear()
        self.checked = 0

    def __repr__(self):
        return (f"FaultInjector(seed={self.seed}, rate={self.rate}, "
                f"injected={len(self.injected)})")

    # -- CLI spec ----------------------------------------------------------
    @classmethod
    def from_spec(cls, spec: str) -> "FaultInjector":
        """Parse a pinned chaos schedule spec: ``"seed=0,rate=0.05"`` with
        optional ``latency_rate= latency_s= stall_s= max_faults=
        always_fail=sub1+sub2``.  The CI ``chaos-smoke`` job pins exactly
        this string so the schedule is reproducible across runs."""
        kw: dict = {}
        for part in str(spec).split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(f"bad fault spec element {part!r} "
                                 f"(want key=value)")
            k, v = part.split("=", 1)
            k = k.strip()
            if k in ("seed", "max_faults"):
                kw[k] = int(v)
            elif k in ("rate", "latency_rate", "latency_s", "stall_s"):
                kw[k] = float(v)
            elif k == "always_fail":
                kw[k] = tuple(v.split("+"))
            elif k == "categories":
                kw[k] = tuple(v.split("+"))
            else:
                raise ValueError(f"unknown fault spec key {k!r}")
        seed = kw.pop("seed", 0)
        rate = kw.pop("rate", 0.0)
        return cls(seed, rate, **kw)


__all__ = ["FaultInjector", "FaultInjectedError"]
