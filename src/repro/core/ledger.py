"""Resource ledger + flight recorder: the always-on accounting layer.

EXPLAIN ANALYZE (``core/tracing.py``) observes a *single run*; nothing so
far tracked what the system holds **resident across runs** — store payload
buffers, BoundedRel capacity headroom, KV-pool pages, plan-cache entry
constants, shard shuffle scratch.  BigDAWG's monitoring framework records
execution history precisely to drive cross-engine decisions, and
Polystore++ argues accelerator-aware polystores need resource-level
visibility; this module is that layer:

  * :class:`MemoryLedger` — registers every live device pytree under an
    owner key with byte gauges, high-water marks, and
    **predicted-vs-actual** deltas against the cost model's
    capacity-derived sizes (``cost_model.predicted_resident_bytes``).
    Leak detection flags entries still registered after the store version
    they snapshot is superseded, or after the plan-cache entry they are
    tied to is evicted.
  * :class:`FlightRecorder` — a bounded ring of the last N events
    (``RunTrace`` summaries, metric snapshots) that dumps to JSONL when
    tripped: on BoundedRel overflow, admission rejection, or executor
    error.  The black box you read *after* the incident.

Registration is host-side bookkeeping only — a ``tree_bytes`` walk over
already-built arrays, no device sync, no extra allocations — so it rides
along on store ``payload()`` / pool construction / plan-cache insert
unconditionally (the telemetry-off executor fast path is untouched).
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

from .tracing import tree_bytes


def _owner_key(owner) -> tuple:
    if isinstance(owner, tuple):
        return owner
    return (str(owner),)


@dataclass
class LedgerEntry:
    """One registered live pytree (or byte-sized resource)."""

    owner: tuple
    kind: str
    nbytes: int
    predicted: Optional[int] = None
    version: Optional[int] = None
    tied_to: Optional[tuple] = None   # owner whose lifetime bounds this one
    seq: int = 0

    @property
    def ratio(self) -> Optional[float]:
        """actual / predicted bytes (None without a prediction)."""
        if not self.predicted:
            return None
        return self.nbytes / self.predicted

    def as_dict(self) -> dict:
        return {"owner": list(map(str, self.owner)), "kind": self.kind,
                "nbytes": self.nbytes, "predicted": self.predicted,
                "version": self.version,
                "tied_to": (list(map(str, self.tied_to))
                            if self.tied_to else None)}


class MemoryLedger:
    """Byte accounting for every live device pytree, keyed by owner.

    ``register`` under an owner key **replaces** any previous entry for the
    same owner (the normal append/replace flow releases the superseded
    bytes); a consumer that *pins* a snapshot registers under its own owner
    with ``tied_to=`` the producing owner and ``version=`` the version it
    captured — :meth:`leaks` then flags it once the producer moves on
    (superseded version) or disappears (released / evicted).
    """

    def __init__(self):
        self._entries: "dict[tuple, LedgerEntry]" = {}
        self._kind_bytes: dict = {}
        self._kind_peak: dict = {}
        self._total = 0
        self.peak_bytes = 0
        self.transient_bytes = 0          # lifetime scratch total
        self.transient_peak = 0           # max single transient grant
        self._seq = 0
        self._lock = threading.Lock()

    # -- registration ------------------------------------------------------
    def register(self, owner, value=None, *, nbytes: Optional[int] = None,
                 predicted: Optional[int] = None,
                 version: Optional[int] = None, kind: Optional[str] = None,
                 tied_to=None) -> LedgerEntry:
        """Register (or replace) the live bytes held under ``owner``.

        ``nbytes`` defaults to :func:`~repro.core.tracing.tree_bytes` over
        ``value``; ``predicted`` is the cost model's capacity-derived
        expectation; ``version`` the producing store's monotonic version;
        ``tied_to`` another owner whose lifetime bounds this entry.
        """
        key = _owner_key(owner)
        nb = int(tree_bytes(value) if nbytes is None else nbytes)
        k = kind if kind is not None else str(key[0])
        tied = _owner_key(tied_to) if tied_to is not None else None
        with self._lock:
            old = self._entries.get(key)
            if old is not None:
                self._total -= old.nbytes
                self._kind_bytes[old.kind] = \
                    self._kind_bytes.get(old.kind, 0) - old.nbytes
            self._seq += 1
            e = LedgerEntry(key, k, nb, predicted, version, tied, self._seq)
            self._entries[key] = e
            self._total += nb
            self._kind_bytes[k] = self._kind_bytes.get(k, 0) + nb
            self.peak_bytes = max(self.peak_bytes, self._total)
            self._kind_peak[k] = max(self._kind_peak.get(k, 0),
                                     self._kind_bytes[k])
        return e

    def release(self, owner) -> int:
        """Drop the entry under ``owner``; returns the bytes released."""
        key = _owner_key(owner)
        with self._lock:
            e = self._entries.pop(key, None)
            if e is None:
                return 0
            self._total -= e.nbytes
            self._kind_bytes[e.kind] = \
                self._kind_bytes.get(e.kind, 0) - e.nbytes
            return e.nbytes

    def release_kind(self, kind: str) -> int:
        """Drop every entry of one owner kind; returns the bytes released.
        The subplan cache drains through this on clear(): its entries are
        keyed by content hash, so enumerating the owners from outside the
        ledger would duplicate its bookkeeping."""
        with self._lock:
            keys = [k for k, e in self._entries.items() if e.kind == kind]
        freed = 0
        for k in keys:
            freed += self.release(k)
        return freed

    def note_transient(self, owner, nbytes: int, kind: str = "transient"
                       ) -> None:
        """Account scratch that lives only inside one executed program
        (shuffle buckets staged through an all-to-all): it contributes to
        the high-water mark — resident bytes plus scratch is the true
        peak — without needing a paired release."""
        nb = int(nbytes)
        with self._lock:
            self.transient_bytes += nb
            self.transient_peak = max(self.transient_peak, nb)
            self.peak_bytes = max(self.peak_bytes, self._total + nb)
            self._kind_peak[kind] = max(self._kind_peak.get(kind, 0), nb)

    # -- gauges ------------------------------------------------------------
    def total_bytes(self) -> int:
        return self._total

    def bytes_for_kind(self, kind: str) -> int:
        return self._kind_bytes.get(kind, 0)

    def entries(self, kind: Optional[str] = None) -> list:
        with self._lock:
            es = list(self._entries.values())
        if kind is not None:
            es = [e for e in es if e.kind == kind]
        return es

    def get(self, owner) -> Optional[LedgerEntry]:
        return self._entries.get(_owner_key(owner))

    # -- leak detection ----------------------------------------------------
    def leaks(self) -> list:
        """Entries whose lifetime anchor has moved on: ``tied_to`` owner
        released/evicted (``"evicted"``), or still present at a *different*
        version than the one this entry snapshot captured
        (``"superseded"``).  Returns ``[(reason, entry), ...]``."""
        out = []
        with self._lock:
            for e in self._entries.values():
                if e.tied_to is None:
                    continue
                anchor = self._entries.get(e.tied_to)
                if anchor is None:
                    out.append(("evicted", e))
                elif (e.version is not None and anchor.version is not None
                      and e.version != anchor.version):
                    out.append(("superseded", e))
        return out

    def predicted_vs_actual(self) -> list:
        """Per-entry ``(entry, predicted, actual, ratio)`` for every entry
        carrying a prediction — the 2x-agreement check the tri-store
        benchmark enforces."""
        return [(e, e.predicted, e.nbytes, e.ratio)
                for e in self.entries() if e.predicted]

    # -- reporting ---------------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            by_kind = dict(sorted(self._kind_bytes.items()))
            n = len(self._entries)
            total, peak = self._total, self.peak_bytes
        return {"total_bytes": total, "peak_bytes": peak,
                "transient_bytes": self.transient_bytes,
                "by_kind": by_kind, "entries": n,
                "leaks": len(self.leaks())}

    def publish(self, registry, prefix: str = "ledger") -> None:
        """Set byte gauges in a (duck-typed) MetricsRegistry."""
        registry.gauge(f"{prefix}.total_bytes").set(self._total)
        registry.gauge(f"{prefix}.peak_bytes").set(self.peak_bytes)
        for kind, nb in self._kind_bytes.items():
            registry.gauge(f"{prefix}.{kind}_bytes").set(nb)

    def report(self) -> str:
        snap = self.snapshot()
        lines = [f"[ledger] {snap['entries']} entries, "
                 f"{snap['total_bytes'] / 1e6:.2f} MB resident "
                 f"(peak {snap['peak_bytes'] / 1e6:.2f} MB, "
                 f"transient {snap['transient_bytes'] / 1e6:.2f} MB)"]
        for kind, nb in snap["by_kind"].items():
            lines.append(f"[ledger]   {kind}: {nb / 1e6:.2f} MB "
                         f"(peak {self._kind_peak.get(kind, 0) / 1e6:.2f} MB)")
        for e, pred, act, ratio in self.predicted_vs_actual():
            lines.append(f"[ledger]   {'/'.join(map(str, e.owner))}: "
                         f"predicted {pred / 1e6:.2f} MB, actual "
                         f"{act / 1e6:.2f} MB ({ratio:.2f}x)")
        for reason, e in self.leaks():
            lines.append(f"[ledger]   LEAK ({reason}): "
                         f"{'/'.join(map(str, e.owner))} holds "
                         f"{e.nbytes / 1e6:.2f} MB")
        return "\n".join(lines)

    def reset(self) -> None:
        with self._lock:
            self._entries.clear()
            self._kind_bytes.clear()
            self._kind_peak.clear()
            self._total = 0
            self.peak_bytes = 0
            self.transient_bytes = 0
            self.transient_peak = 0


# --------------------------------------------------------------------------
# flight recorder: the bounded black box
# --------------------------------------------------------------------------


@dataclass
class FlightEvent:
    seq: int
    kind: str
    ts: float
    payload: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {"record": "event", "seq": self.seq, "kind": self.kind,
                "ts": self.ts, "payload": self.payload}


class FlightRecorder:
    """Bounded ring of the last ``capacity`` telemetry events.

    ``record`` is O(1) and never grows past the ring bound (older events
    drop, counted in ``dropped``).  ``trip(reason)`` dumps the ring as
    JSON-lines — to ``dump_dir/flight_NNN_<reason>.jsonl`` when a dump
    directory is configured, otherwise returned in-memory — and is wired
    to the three incident triggers: BoundedRel overflow
    (``PlannedFunction.analyze``), admission rejection and executor error
    (``AsyncServingRuntime``).
    """

    def __init__(self, capacity: int = 64, dump_dir: Optional[str] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.dump_dir = dump_dir
        self._ring: deque = deque(maxlen=self.capacity)
        self._seq = 0
        self.dropped = 0
        self.trips: list = []            # (reason, path-or-None)
        self._lock = threading.Lock()

    def record(self, kind: str, payload: Optional[dict] = None
               ) -> FlightEvent:
        with self._lock:
            self._seq += 1
            if len(self._ring) == self.capacity:
                self.dropped += 1
            ev = FlightEvent(self._seq, kind, time.time(), payload or {})
            self._ring.append(ev)
        return ev

    def record_trace(self, trace) -> FlightEvent:
        """Compact RunTrace summary (the full trace stays with the plan)."""
        return self.record("run_trace", {
            "plan_id": getattr(trace, "plan_id", ""),
            "wall_ms": getattr(trace, "wall_ms", 0.0),
            "sync_ms": getattr(trace, "sync_ms", 0.0),
            "spans": len(getattr(trace, "spans", ())),
            "counts": [[list(map(str, site)), c, cap]
                       for site, c, cap in getattr(trace, "counts", ())],
            "collective_totals": trace.collective_totals()
            if hasattr(trace, "collective_totals") else {},
        })

    def events(self) -> list:
        with self._lock:
            return list(self._ring)

    def __len__(self) -> int:
        return len(self._ring)

    def trip(self, reason: str, detail: Optional[dict] = None):
        """Dump the ring.  Returns the JSONL path (with ``dump_dir``) or
        the record list; either way the trip itself lands in the ring so a
        later dump shows the earlier incidents."""
        with self._lock:
            events = list(self._ring)
            n_trip = len(self.trips)
            seq, dropped = self._seq, self.dropped
        records = [{"record": "flight_dump", "reason": reason,
                    "detail": detail or {}, "ts": time.time(),
                    "events": len(events), "total_recorded": seq,
                    "dropped": dropped}]
        records.extend(ev.as_dict() for ev in events)
        path = None
        if self.dump_dir:
            os.makedirs(self.dump_dir, exist_ok=True)
            path = os.path.join(self.dump_dir,
                                f"flight_{n_trip:03d}_{reason}.jsonl")
            with open(path, "w") as fh:
                for rec in records:
                    fh.write(json.dumps(rec, default=str) + "\n")
        with self._lock:
            self.trips.append((reason, path))
        self.record("trip", {"reason": reason, "detail": detail or {},
                             "dump": path})
        return path if path is not None else records


# --------------------------------------------------------------------------
# process-wide default (store payload() / plan-cache registration target)
# --------------------------------------------------------------------------

_DEFAULT: Optional[MemoryLedger] = None


def default_ledger() -> MemoryLedger:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = MemoryLedger()
    return _DEFAULT


def reset_default_ledger() -> None:
    default_ledger().reset()


def register_store_payload(store, payload, kind: str):
    """Register a store's freshly built device payload in the default
    ledger: actual bytes from the payload pytree, predicted bytes from the
    cost model's capacity-derived sizing, version from the store's
    monotonic counter.  Re-registration (append -> new payload) replaces
    the previous entry, releasing its bytes; consumers holding the *old*
    payload pin their own tied entries if they want leak tracking."""
    from .cost_model import predicted_resident_bytes
    try:
        predicted = predicted_resident_bytes(store.type)
    except Exception:
        predicted = None
    default_ledger().register(
        (kind, f"{id(store):#x}"), payload, predicted=predicted,
        version=getattr(store, "version", 0), kind=kind)
    return payload


__all__ = ["MemoryLedger", "LedgerEntry", "FlightRecorder", "FlightEvent",
           "default_ledger", "reset_default_ledger",
           "register_store_payload"]
