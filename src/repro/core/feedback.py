"""Observed-selectivity feedback: calibrate cardinality estimates from
executed plans.

The planner's selectivity story (PR 4) was purely *a priori*: an explicit
``selectivity=`` hint or a per-comparator heuristic.  A mis-hinted filter
therefore mis-prices every masked candidate downstream and the planner
cannot recover.  This module closes the loop:

  * the executor, when asked to **observe** a run
    (``PlannedFunction.observe``), records the actual ``count / capacity``
    of every ``rel_filter`` / ``sel_mask`` site — BoundedRel makes the
    observed count a first-class runtime value;
  * observations accumulate per **site key** — a content key derived from
    the op's attrs (column, comparator, value), so the same predicate is
    recognized across recompiles and rewrite-induced node renames;
  * on re-plan, the rewrite layer's ``estimate_selectivity`` blends the
    observed fraction over the hint/heuristic (observation-weighted), so a
    mis-hinted selectivity self-corrects;
  * the feedback state's ``fingerprint()`` is folded into the staged plan
    id, so a re-plan under new observations is a **plan-cache miss** —
    stale plans priced on stale estimates are never reused.

The feedback object is caller-owned (scope it per workload / per serving
bucket family); the active one is installed for the duration of a planning
run via :func:`activate_feedback` (a context variable, so threaded
planning stays correct).
"""
from __future__ import annotations

import contextlib
import contextvars
import hashlib
from typing import Optional

# weight of the observed fraction when blending over the a-priori estimate
FEEDBACK_BLEND = 0.8

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "selectivity_feedback", default=None)


def filter_site(attrs, cols=None, capacity=None) -> tuple:
    """Site key of one ``rel_filter`` instance: the predicate plus the
    input relation's column schema and capacity.  Schema + capacity
    disambiguate same-shaped predicates over *different* tables — without
    them, one table's observed fraction would leak into another's
    compaction decisions.  (Both survive compaction and rerouting
    consistently: the rewrite-time input type and the run-time relation
    agree on column set and capacity at every filter site.)  Distinct
    same-schema, same-capacity tables still alias; scope feedback objects
    per workload when that matters."""
    return ("rel_filter", tuple(cols) if cols else (),
            None if capacity is None else int(capacity),
            str(attrs.get("col")), str(attrs.get("cmp")),
            repr(attrs.get("value")))


def sel_mask_site(attrs) -> tuple:
    """Site key of one ``sel_mask`` export: column + entity domain."""
    return ("sel_mask", str(attrs.get("col")), int(attrs.get("size", 0)))


class SelectivityFeedback:
    """Per-site EMA of observed ``count / capacity`` fractions."""

    def __init__(self, ema: float = 0.5):
        self.ema = float(ema)
        self._obs: dict = {}          # site key -> (fraction, n_observations)
        self._overflowed: set = set()  # sites whose compaction dropped rows

    def record(self, site: tuple, count, capacity) -> float:
        """Fold one observation in; returns the site's updated fraction."""
        cap = max(1, int(capacity))
        frac = min(1.0, max(0.0, float(count) / cap))
        prev = self._obs.get(site)
        if prev is None:
            cur = frac
            n = 1
        else:
            cur = (1.0 - self.ema) * prev[0] + self.ema * frac
            n = prev[1] + 1
        self._obs[site] = (cur, n)
        return cur

    def lookup(self, site: tuple) -> Optional[float]:
        hit = self._obs.get(site)
        return None if hit is None else hit[0]

    def blend(self, site: tuple, estimate: float) -> float:
        """Observed-over-heuristic blend: the planner's working estimate."""
        obs = self.lookup(site)
        if obs is None:
            return estimate
        s = FEEDBACK_BLEND * obs + (1.0 - FEEDBACK_BLEND) * float(estimate)
        return float(min(1.0, max(0.0, s)))

    def note_overflow(self, site: tuple) -> None:
        """Record that a capacity bound sized from this site's estimate
        dropped rows at run time.  ``choose_compaction`` backs off from
        overflowed sites on re-plan (overflow-adaptive replanning's first
        half: stop compacting rather than stay silently lossy)."""
        self._overflowed.add(site)

    def is_overflowed(self, site: tuple) -> bool:
        return site in self._overflowed

    def __len__(self) -> int:
        return len(self._obs)

    def fingerprint(self) -> str:
        """Content hash of the observation state (part of the plan id, so
        new observations invalidate cached plans).  Fractions are rounded
        so float noise below planning significance does not thrash the
        cache."""
        if not self._obs and not self._overflowed:
            return "none"
        rows = tuple(sorted((repr(k), round(v[0], 4), v[1])
                            for k, v in self._obs.items()))
        ovf = tuple(sorted(repr(s) for s in self._overflowed))
        return hashlib.sha256(repr((rows, ovf)).encode()).hexdigest()

    def __repr__(self):
        return (f"SelectivityFeedback(sites={len(self._obs)}, "
                f"fp={self.fingerprint()[:8]})")


def fit_weights(traces, model=None, *, min_samples: int = 3):
    """Refit cost-model constants from accumulated EXPLAIN ANALYZE traces.

    ``traces``: an iterable of :class:`~repro.core.tracing.RunTrace`
    objects (``PlannedFunction.analyze`` accumulates one per run), whose
    ``samples`` carry ``(impl, raw-feature dict, observed_seconds)`` rows —
    exactly the §6.2 calibration dataset.  Impls with fewer than
    ``min_samples`` observations are skipped (a one-point fit would just
    memorize dispatch noise).  Returns the (given or fresh)
    :class:`~repro.core.cost_model.CostModel` with refit per-impl Eq.-2
    weights; its changed ``fingerprint()`` invalidates cached plans, so the
    next compile re-selects candidates under the calibrated model — the
    adaptive-execution roadmap item's refit half."""
    from .cost_model import CostModel
    by_impl: dict = {}
    for tr in traces:
        for impl, feats, sec in getattr(tr, "samples", ()) or ():
            by_impl.setdefault(impl, []).append((impl, feats, float(sec)))
    rows = [s for ss in by_impl.values() if len(ss) >= min_samples
            for s in ss]
    model = model if model is not None else CostModel()
    if rows:
        model.fit(rows)
    return model


def active_feedback() -> Optional[SelectivityFeedback]:
    """The feedback store installed for the current planning run."""
    return _ACTIVE.get()


@contextlib.contextmanager
def activate_feedback(feedback: Optional[SelectivityFeedback]):
    """Install ``feedback`` as the active store for the duration of a
    planning run (no-op for ``None``)."""
    token = _ACTIVE.set(feedback)
    try:
        yield feedback
    finally:
        _ACTIVE.reset(token)
