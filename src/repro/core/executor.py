"""Physical-plan executor: walks the chosen physical DAG and emits JAX.

The executor is the AWESOME "execution stage": it receives the optimized
logical plan, generates candidate physical plans (physical.py), asks the
learned cost model to pick each virtual node's winner (§6.3), applies the
partitioned-data-parallelism insertion (§5.2), and then interprets the
resulting DAG as a pure JAX function — jit-able, differentiable, and
shardable on a mesh.

Param binding: nodes carry a ``pp`` attr (param path into the model's param
pytree).  ``scan_layers_xla`` executes its subplan under ``jax.lax.scan``
over the stacked per-layer params (the paper's Map node, with map-fusion
applied at the logical level), with optional rematerialization policy.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .buffering import BufferingDecision
from .cost_model import CostModel, raw_features
from .engines import dispatch, get_engine, resolve_engines
from .ir import FunctionCatalog, Plan, SystemCatalog
from .physical import PHYS_OPS, PhysPlan
from ..layers import attention as A
from ..layers import embedding as E
from ..layers import mamba as M
from ..layers import mlp as F
from ..layers import moe as X
from ..layers import rwkv as R
from ..layers.common import rmsnorm

P = jax.sharding.PartitionSpec


# --------------------------------------------------------------------------
# sharding rules: semantic dim name -> mesh axes
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardingRules:
    """MaxText-style logical-axis rules.  ``param`` maps weight dim names,
    ``act`` maps activation dim names."""

    act: tuple = (
        ("batch", ("pod", "data")),
        ("heads", ("model",)),
        ("kv_heads", ("model",)),
        ("ffn", ("model",)),
        ("vocab", ("model",)),
        ("experts", ("model",)),
    )
    param: tuple = (
        ("embed", ("data",)),          # FSDP / ZeRO-3: shard embed over data
        ("vocab", ("model",)),
        ("ffn", ("model",)),
        ("heads_flat", ("model",)),
        ("kv_flat", ("model",)),
        ("experts", ("model",)),
        ("inner", ("model",)),
        ("inner_cat", ("model",)),
        ("inner_cat2", ("model",)),
    )
    # expert weights already divide 16× over `model` via EP; FSDP-sharding
    # their embed dim over `data` additionally makes every expert matmul a
    # partial-sum + all-reduce of the (E, tokens, ffn) output (measured
    # 1.26e12 B/device on llama4×train_4k).  True ⇒ replicate expert weights
    # over data, killing that all-reduce.
    no_fsdp_experts: bool = False

    def _lookup(self, table, dim, mesh):
        for d, axes in table:
            if d == dim:
                ax = tuple(a for a in axes if a in mesh.axis_names)
                if len(ax) == 1:
                    return ax[0]
                return ax if ax else None
        return None

    def _spec(self, table, dims, mesh, *, is_param=False) -> P:
        # each mesh axis may appear at most once per spec: first dim wins
        used: set = set()
        out = []
        skip_fsdp = (is_param and self.no_fsdp_experts
                     and "experts" in dims)
        for d in dims:
            if skip_fsdp and d == "embed":
                out.append(None)
                continue
            ax = self._lookup(table, d, mesh)
            axes = (ax,) if isinstance(ax, str) else (ax or ())
            if any(a in used for a in axes):
                out.append(None)
                continue
            used.update(axes)
            out.append(ax)
        return P(*out)

    def act_spec(self, dims, mesh) -> P:
        return self._spec(self.act, dims, mesh)

    def param_spec(self, dims, mesh) -> P:
        return self._spec(self.param, dims, mesh, is_param=True)


def params_sharding(specs_tree, mesh, rules: ShardingRules):
    """Map a specs pytree (tuples of dim names) to NamedShardings."""
    def one(spec):
        return jax.sharding.NamedSharding(mesh, rules.param_spec(spec, mesh))
    return jax.tree.map(one, specs_tree,
                        is_leaf=lambda s: isinstance(s, tuple) and all(
                            isinstance(x, str) for x in s))


# --------------------------------------------------------------------------
# execution context
# --------------------------------------------------------------------------

@dataclass
class ExecContext:
    root: Any                       # full param pytree
    scope: Any                      # current scope (layer slice under scan)
    aux: dict = field(default_factory=dict)   # positions, masks, memory, ...
    mesh: Optional[Any] = None
    rules: ShardingRules = ShardingRules()
    interpret: bool = True          # pallas interpret mode (CPU container)
    tracer: Optional[Any] = None    # core.tracing.Tracer; None = fast path
    faults: Optional[Any] = None    # core.faults.FaultInjector; None = off

    def params_for(self, node):
        path = node.attrs.get("pp")
        if path is None:
            return self.scope
        base = self.root if node.attrs.get("shared") else self.scope
        for k in path:
            base = base[k]
        return base

    def constrain(self, x, dims):
        if self.mesh is None or not hasattr(x, "ndim"):
            return x
        if len(dims) != x.ndim:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh,
                                          self.rules.act_spec(dims, self.mesh)))


# --------------------------------------------------------------------------
# impl registration — each engine owns its dispatch table (engines.py)
# --------------------------------------------------------------------------

def impl(*names, engine: str = "xla"):
    """Register a physical-op implementation under a named engine.  The
    executor dispatches each node through the engine that registered its
    impl (the tri-store's per-engine execution, §2)."""
    return get_engine(engine).impl(*names)


@impl("identity", "store")
def _i_identity(ctx, args, node):
    return args[0]


@impl("const")
def _i_const(ctx, args, node):
    return node.attrs["value"]


@impl("partition")
def _i_partition(ctx, args, node):
    x = args[0]
    if ctx.mesh is None or not hasattr(x, "ndim"):
        return x
    dims = [None] * x.ndim
    dims[node.attrs.get("dim_index", 0)] = node.attrs.get("dim", "batch")
    spec = [None] * x.ndim
    spec[node.attrs.get("dim_index", 0)] = tuple(
        a for a in ("pod", "data") if a in ctx.mesh.axis_names) or None
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(ctx.mesh, P(*spec)))


@impl("merge")
def _i_merge(ctx, args, node):
    x = args[0]
    if ctx.mesh is None or not hasattr(x, "ndim"):
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(ctx.mesh, P(*([None] * x.ndim))))


@impl("embed_gather")
def _i_embed(ctx, args, node):
    p = ctx.params_for(node)
    out = E.embed(p, args[0], scale=node.attrs.get("scale", False))
    out = out.astype(node.attrs.get("dtype", out.dtype))
    return ctx.constrain(out, ("batch", None, None))


@impl("rmsnorm_xla")
def _i_rmsnorm(ctx, args, node):
    p = ctx.params_for(node)
    return rmsnorm(args[0], p["scale"])


@impl("residual_add_xla")
def _i_resid(ctx, args, node):
    return args[0] + args[1]


def _attn_cfg(node):
    a = node.attrs
    return a["heads"], a["kv_heads"], a["head_dim"]


@impl("q_proj_xla")
def _i_qproj(ctx, args, node):
    h, k, d = _attn_cfg(node)
    return A.project_q(ctx.params_for(node), args[0], h, d)


@impl("k_proj_xla")
def _i_kproj(ctx, args, node):
    h, k, d = _attn_cfg(node)
    return A.project_kv(ctx.params_for(node), args[0], k, d)[0]


@impl("v_proj_xla")
def _i_vproj(ctx, args, node):
    h, k, d = _attn_cfg(node)
    return A.project_kv(ctx.params_for(node), args[0], k, d)[1]


@impl("pack_qkv_xla")
def _i_pack(ctx, args, node):
    return tuple(args)


@impl("qkv_proj_fused")
def _i_qkv_fused(ctx, args, node):
    h, k, d = _attn_cfg(node)
    q, kk, vv = A.project_qkv_fused(ctx.params_for(node), args[0], h, k, d)
    model_size = (ctx.mesh.shape.get("model", 1)
                  if ctx.mesh is not None else 1)
    if h % max(model_size, 1) == 0:
        q = ctx.constrain(q, ("batch", None, "heads", None))
    if k % max(model_size, 1) == 0:
        # GQA: constrain kv heads only when divisible; otherwise leave the
        # layout to propagation (kv replicates across excess model shards)
        kk = ctx.constrain(kk, ("batch", None, "kv_heads", None))
        vv = ctx.constrain(vv, ("batch", None, "kv_heads", None))
    return (q, kk, vv)


def _prep(ctx, node, q, k):
    p = ctx.params_for(node)
    pos = ctx.aux.get("positions")
    if pos is None:
        pos = jnp.arange(q.shape[1])[None, :]
    return A.qk_prep(p, q, k, pos, qk_norm=node.attrs.get("qk_norm", False),
                     use_rope=node.attrs.get("rope", True),
                     rope_theta=node.attrs.get("rope_theta", 10000.0))


def _emit_kv(ctx, node, k, v):
    """KV export hook: inside a ``collect_kv`` scan, sdpa impls append their
    prepped K (post qk-norm/RoPE — exactly what the decode cache stores) and
    raw V to the sink the scan body planted in ``ctx.aux``."""
    sink = ctx.aux.get("kv_sink")
    if sink is not None and node.attrs.get("emit_kv"):
        sink.append((k, v))


@impl("sdpa_xla")
def _i_sdpa(ctx, args, node):
    q, k, v = args[0]
    q, k = _prep(ctx, node, q, k)
    _emit_kv(ctx, node, k, v)
    return A.sdpa_full(q, k, v, causal=node.attrs.get("causal", True),
                       window=node.attrs.get("window", 0) or 0)


@impl("sdpa_banded_xla")
def _i_banded(ctx, args, node):
    q, k, v = args[0]
    q, k = _prep(ctx, node, q, k)
    _emit_kv(ctx, node, k, v)
    return A.sdpa_banded(q, k, v, window=node.attrs.get("window", 0) or 0,
                         causal=node.attrs.get("causal", True))


@impl("attn_flash_pallas", engine="pallas")
def _i_flash(ctx, args, node):
    q, k, v = args[0]
    q, k = _prep(ctx, node, q, k)
    _emit_kv(ctx, node, k, v)
    return A.sdpa_flash(q, k, v, causal=node.attrs.get("causal", True),
                        window=node.attrs.get("window", 0) or 0,
                        interpret=ctx.interpret)


@impl("out_proj_xla")
def _i_outproj(ctx, args, node):
    out = A.out_project(ctx.params_for(node), args[0])
    return ctx.constrain(out, ("batch", None, None))


@impl("cross_attention_xla")
def _i_xattn(ctx, args, node):
    x, mem = args
    p = ctx.params_for(node)
    h, k, d = _attn_cfg(node)
    q = A.project_q(p, x, h, d)
    kk, vv = A.project_kv(p, mem, k, d)
    out = A.sdpa_full(q, kk, vv, causal=False)
    return A.out_project(p, out)


@impl("ffn_up_xla")
def _i_ffn_up(ctx, args, node):
    return F.ffn_up(ctx.params_for(node), args[0])


@impl("ffn_gate_xla")
def _i_ffn_gate(ctx, args, node):
    return F.ffn_gate(ctx.params_for(node), args[0])


@impl("ffn_glu_xla")
def _i_ffn_glu(ctx, args, node):
    return F.ffn_glu(args[0], args[1], node.attrs.get("act", "silu"))


@impl("ffn_act_xla")
def _i_ffn_act(ctx, args, node):
    return F.ffn_act(args[0], node.attrs.get("act", "gelu"))


@impl("ffn_down_xla")
def _i_ffn_down(ctx, args, node):
    out = F.ffn_down(ctx.params_for(node), args[0])
    return ctx.constrain(out, ("batch", None, None))


@impl("mlp_fused_xla")
def _i_mlp(ctx, args, node):
    out = F.mlp_fused(ctx.params_for(node), args[0],
                      gated=node.attrs.get("gated", True),
                      act=node.attrs.get("act"))
    return ctx.constrain(out, ("batch", None, None))


@impl("moe_dense_onehot")
def _i_moe_dense(ctx, args, node):
    a = node.attrs
    return X.moe_dense(ctx.params_for(node), args[0], top_k=a["top_k"],
                       experts=a["experts"], act=a.get("act", "silu"),
                       capacity_factor=a.get("capacity_factor", 2.0),
                       constrain=ctx.constrain if a.get("pin_moe") else None)


@impl("moe_dropping")
def _i_moe_drop(ctx, args, node):
    a = node.attrs
    return X.moe_dropping(ctx.params_for(node), args[0], top_k=a["top_k"],
                          experts=a["experts"], act=a.get("act", "silu"),
                          constrain=ctx.constrain if a.get("pin_moe") else None)


@impl("moe_gmm_pallas", engine="pallas")
def _i_moe_gmm(ctx, args, node):
    a = node.attrs
    return X.moe_gmm(ctx.params_for(node), args[0], top_k=a["top_k"],
                     experts=a["experts"], act=a.get("act", "silu"),
                     interpret=ctx.interpret,
                     constrain=ctx.constrain if a.get("pin_moe") else None)


@impl("wkv6_scan_xla")
def _i_wkv_xla(ctx, args, node):
    a = node.attrs
    return R.rwkv_time_mix(ctx.params_for(node), args[0], heads=a["heads"],
                           head_dim=a["head_dim"], use_kernel=False)


@impl("wkv6_pallas", engine="pallas")
def _i_wkv_pl(ctx, args, node):
    a = node.attrs
    return R.rwkv_time_mix(ctx.params_for(node), args[0], heads=a["heads"],
                           head_dim=a["head_dim"], use_kernel=True,
                           interpret=ctx.interpret)


@impl("ssd_chunked_xla")
def _i_ssd_xla(ctx, args, node):
    a = node.attrs
    cfg = {"embed": a["embed"], "state": a["state"],
           "expand": a.get("expand", 2), "head_dim": a["head_dim"]}
    return M.mamba2_block(ctx.params_for(node), args[0], cfg,
                          use_kernel=False)


@impl("ssd_pallas", engine="pallas")
def _i_ssd_pl(ctx, args, node):
    a = node.attrs
    cfg = {"embed": a["embed"], "state": a["state"],
           "expand": a.get("expand", 2), "head_dim": a["head_dim"]}
    return M.mamba2_block(ctx.params_for(node), args[0], cfg,
                          use_kernel=True, interpret=ctx.interpret)


@impl("rwkv_channel_mix")
def _i_rwkv_cm(ctx, args, node):
    return R.rwkv_channel_mix(ctx.params_for(node), args[0])


@impl("unembed_matmul")
def _i_unembed(ctx, args, node):
    out = E.unembed(ctx.params_for(node), args[0])
    true_v = node.attrs.get("true_vocab")
    if true_v and true_v < out.shape[-1]:
        out = E.mask_padded_logits(out, true_v)
    return ctx.constrain(out, ("batch", None, "vocab"))


@impl("softmax_xent_xla")
def _i_xent(ctx, args, node):
    return E.softmax_xent(args[0], args[1])


@impl("concat_seq")
def _i_concat_seq(ctx, args, node):
    a, b = args
    return jnp.concatenate([a.astype(b.dtype), b], axis=node.attrs.get("axis", 1))


@impl("scan_layers_xla")
def _i_scan(ctx, args, node):
    carry0 = args[0]
    extras = args[1:]                      # broadcast inputs (enc-dec memory)
    p_stack = ctx.params_for(node)
    sub = node.subplan
    in_names = list(sub.inputs.keys())
    extra_env = dict(zip(in_names[1:], extras))
    remat = node.attrs.get("remat", "none")
    collect_kv = bool(node.attrs.get("collect_kv"))

    def body(carry, layer_p):
        # a fresh sink per trace: emit_kv sdpa impls append (K, V) in subplan
        # topo order; lax.scan stacks them over layers as ys
        sink: list = []
        aux = {**ctx.aux, "kv_sink": sink} if collect_kv else ctx.aux
        ctx2 = replace(ctx, scope=layer_p, aux=aux)
        outs = run_plan(sub, ctx2, {in_names[0]: carry, **extra_env})
        return outs[0], (tuple(sink) if collect_kv else None)

    if remat and remat != "none":
        policy = {
            "full": None,
            "dots": jax.checkpoint_policies.checkpoint_dots,
            "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        }.get(remat)
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)

    y, ys = jax.lax.scan(body, carry0, p_stack,
                         unroll=node.attrs.get("unroll", 1))
    if collect_kv:
        # (carry, ((K, V), ...)) — K/V stacked to (layers, B, S, KV, D),
        # exactly the decode cache layout; tuple_get nodes project the pair
        return (y, ys)
    return y


@impl("tuple_get_xla")
def _i_tuple_get(ctx, args, node):
    return args[0][node.attrs["index"]]


@impl("map")
def _i_map(ctx, args, node):
    sub = node.subplan
    (in_name,) = sub.inputs.keys()
    return [run_plan(sub, ctx, {in_name: v})[0] for v in args[0]]


@impl("reduce")
def _i_reduce(ctx, args, node):
    fn = node.attrs["fn"]
    vals = args[0]
    acc = vals[0]
    for v in vals[1:]:
        acc = fn(acc, v) if callable(fn) else acc + v
    return acc


@impl("filter")
def _i_filter(ctx, args, node):
    pred = node.attrs["predicate"]
    return [v for v in args[0] if pred(v)]


# --------------------------------------------------------------------------
# plan execution
# --------------------------------------------------------------------------

def run_plan(pplan: PhysPlan, ctx: ExecContext, values: dict) -> tuple:
    tracer = ctx.tracer
    traced = tracer is not None and tracer.enabled
    if not traced and ctx.faults is None:
        # the untouched fast path: tracing and fault injection both off
        # means zero per-op overhead
        env = dict(values)
        for n in pplan.topo():
            opdef = PHYS_OPS.get(n.impl)
            fn = dispatch(n.impl, opdef.backend if opdef else None)
            if fn is None:
                raise NotImplementedError(
                    f"no engine implements {n.impl!r}")
            env[n.id] = fn(ctx, [env[i] for i in n.inputs], n)
        return tuple(env[o] for o in pplan.outputs)
    if traced:
        return _run_plan_traced(pplan, ctx, values)
    return _run_plan_faulted(pplan, ctx, values)


def run_plan_subset(pplan: PhysPlan, ctx: ExecContext, values: dict,
                    node_ids) -> dict:
    """Execute only ``node_ids`` of a physical plan (in plan topo order),
    seeding the environment from ``values`` — plan inputs *plus* any
    already-materialized node outputs.  The cross-query MQO pass
    (``core/mqo.py``) splits a plan at its subplan-cache-hit frontier and
    runs just the residual suffix through this; the op dispatch is the same
    fast path as :func:`run_plan`.  Returns the full environment so the
    caller can both extract the plan outputs and insert fresh
    intermediates into the cache."""
    wanted = set(node_ids)
    env = dict(values)
    for n in pplan.topo():
        if n.id not in wanted:
            continue
        opdef = PHYS_OPS.get(n.impl)
        fn = dispatch(n.impl, opdef.backend if opdef else None)
        if fn is None:
            raise NotImplementedError(f"no engine implements {n.impl!r}")
        env[n.id] = fn(ctx, [env[i] for i in n.inputs], n)
    return env


def _fault_site(n) -> tuple:
    """Site key for a physical node: xfer/collective nodes get their own
    category (the "sharded" failure class), everything else is "node"."""
    if n.impl.startswith("xfer_"):
        return ("xfer", n.id, n.impl)
    return ("node", n.id, n.impl)


def _run_plan_faulted(pplan: PhysPlan, ctx: ExecContext,
                      values: dict) -> tuple:
    """run_plan with a FaultInjector at every node boundary.  Impl
    exceptions (injected or real) are wrapped into the ExecError taxonomy
    with their site attached, so the resilience layer can classify and the
    breaker can pick a fallback class."""
    from .resilience import classify
    faults = ctx.faults
    env = dict(values)
    for n in pplan.topo():
        opdef = PHYS_OPS.get(n.impl)
        fn = dispatch(n.impl, opdef.backend if opdef else None)
        if fn is None:
            raise NotImplementedError(f"no engine implements {n.impl!r}")
        engine = (opdef.backend or "xla") if opdef else "xla"
        try:
            faults.check(_fault_site(n))
            env[n.id] = fn(ctx, [env[i] for i in n.inputs], n)
        except Exception as exc:
            raise classify(exc, node=n, engine=engine) from exc
    return tuple(env[o] for o in pplan.outputs)


def _run_plan_traced(pplan: PhysPlan, ctx: ExecContext, values: dict) -> tuple:
    """run_plan with one span per physical op.  Span durations are dispatch
    times (JAX async dispatch); the caller device-syncs once per run.
    Device-side observations (BoundedRel counts, overflow flags) are
    *deferred* into the tracer and fetched in one transfer at resolve()."""
    from .tracing import tree_bytes, xfer_wire_bytes
    tracer = ctx.tracer
    n_data = 1
    if ctx.mesh is not None and "data" in getattr(ctx.mesh, "axis_names", ()):
        n_data = int(ctx.mesh.shape["data"])
    env = dict(values)
    for n in pplan.topo():
        opdef = PHYS_OPS.get(n.impl)
        fn = dispatch(n.impl, opdef.backend if opdef else None)
        if fn is None:
            raise NotImplementedError(f"no engine implements {n.impl!r}")
        attrs = {"impl": n.impl,
                 "engine": (opdef.backend or "xla") if opdef else "xla"}
        if "dist" in n.attrs:
            attrs["dist"] = n.attrs["dist"]
        with tracer.span(n.id, "op", **attrs) as sp:
            if ctx.faults is not None:
                ctx.faults.check(_fault_site(n))
            out = fn(ctx, [env[i] for i in n.inputs], n)
            if n.impl.startswith("xfer_"):
                kind = n.impl[len("xfer_"):]
                payload = tree_bytes(out)
                sp.attrs["xfer_kind"] = kind
                sp.attrs["payload_bytes"] = payload
                sp.attrs["wire_bytes"] = xfer_wire_bytes(kind, payload,
                                                         n_data)
            # duck-typed BoundedRel (avoids a core -> stores import): its
            # count/overflow are device scalars — defer, don't fetch
            if hasattr(out, "cols") and hasattr(out, "valid"):
                tracer.defer("count", out.count)
                tracer.defer("overflow", out.overflow)
                sp.attrs["capacity"] = int(out.capacity)
        env[n.id] = out
    return tuple(env[o] for o in pplan.outputs)


# --------------------------------------------------------------------------
# end-to-end: logical plan -> planned jittable function
# --------------------------------------------------------------------------

def _drain_counts(resolved, feedback) -> None:
    """Fold already-resolved count-sink entries into a feedback store."""
    for site, count, capacity in resolved:
        if site and site[0] == "compact_overflow":
            # a capacity bound dropped rows: flag the originating
            # predicate site so re-planning backs off from compacting it
            if count > 0:
                feedback.note_overflow(tuple(site[1]))
            continue
        feedback.record(site, count, capacity)


@dataclass
class PlannedFunction:
    """A cached-able staged plan bound to one runtime context.

    The planning product itself (logical_opt / candidates / concrete plan /
    choices / buffering / EXPLAIN trace) lives in the StagedPhysicalPlan —
    the unit the plan cache stores; this wrapper adds the runtime-only
    bindings (mesh, sharding rules, interpret mode) plus legacy field access
    for existing callers."""

    logical: Plan
    pplan: PhysPlan                  # with virtual nodes (pre-choice)
    concrete: PhysPlan               # chosen + data-parallelized
    choices: dict
    report: list
    buffering: BufferingDecision
    syscat: SystemCatalog
    rules: ShardingRules
    mesh: Optional[Any] = None
    interpret: bool = True
    plan_id: str = ""
    staged: Optional[Any] = None     # StagedPhysicalPlan
    faults: Optional[Any] = None     # core.faults.FaultInjector; None = off
    last_run_trace: Optional[Any] = None   # RunTrace of the last analyze()
    _predicted: Optional[dict] = None      # node id -> (seconds, features)

    @classmethod
    def from_staged(cls, staged, syscat: SystemCatalog, *,
                    rules: "ShardingRules" = None, mesh=None,
                    interpret: bool = True) -> "PlannedFunction":
        return cls(staged.logical, staged.pplan, staged.concrete,
                   staged.choices, staged.report, staged.buffering,
                   syscat, rules or ShardingRules(), mesh, interpret,
                   staged.plan_id, staged)

    def explain(self, analyze=False) -> str:
        """The plan-time EXPLAIN report; with ``analyze`` the runtime
        section merges in.  ``analyze=True`` uses the last :meth:`analyze`
        run's trace; a RunTrace may also be passed directly."""
        if self.staged is None:
            return ""
        trace = None
        if analyze is not False and analyze is not None:
            trace = analyze if hasattr(analyze, "spans") \
                else self.last_run_trace
            if trace is None:
                raise ValueError(
                    "explain(analyze=True) needs a run trace: call "
                    ".analyze(params, inputs) first")
        return self.staged.explain(analyze=trace)

    def __call__(self, params, inputs: dict, aux: Optional[dict] = None):
        ctx = ExecContext(root=params, scope=params, aux=aux or {},
                          mesh=self.mesh, rules=self.rules,
                          interpret=self.interpret, faults=self.faults)
        outs = run_plan(self.concrete, ctx, inputs)
        return outs if len(outs) > 1 else outs[0]

    # -- EXPLAIN ANALYZE ----------------------------------------------------
    def _predict_costs(self, cost_model=None) -> dict:
        """Cost-model predictions per concrete node (memoized: the plan is
        immutable, so one walk serves every analyze run)."""
        if self._predicted is not None and cost_model is None:
            return self._predicted
        cm = cost_model or CostModel()
        predicted: dict = {}

        def visit(plan):
            for n in plan.topo():
                if n.subplan is not None:
                    visit(n.subplan)
                in_types = [plan.types.get(i) or plan.inputs.get(i)
                            for i in n.inputs]
                try:
                    feats = raw_features(n.impl, in_types, n.attrs,
                                         self.syscat)
                    sec = cm.op_seconds(n.impl, in_types, n.attrs,
                                        self.syscat)
                except Exception:
                    continue
                predicted[n.id] = (float(sec), feats)

        visit(self.concrete)
        if cost_model is None:
            object.__setattr__(self, "_predicted", predicted)
        return predicted

    def analyze(self, params, inputs: dict, aux: Optional[dict] = None, *,
                feedback=None, cost_model=None, recorder=None,
                trip_context=None):
        """EXPLAIN ANALYZE execution: run the plan **eagerly** under a span
        tracer, device-sync **once** at the end, and build a
        :class:`~repro.core.tracing.RunTrace` pairing every physical node's
        observed dispatch-ms / counts / xfer bytes with the cost model's
        prediction.  The trace lands in ``self.last_run_trace`` (rendered by
        ``explain(analyze=True)``) and its ``(impl, features, observed_s)``
        samples feed ``core.feedback.fit_weights``.  With ``feedback``
        given, the count sink also drains into it (superset of
        :meth:`observe`).  With ``recorder`` (a
        :class:`~repro.core.ledger.FlightRecorder`), the run's trace summary
        lands in the ring, and two incident triggers trip a dump: an
        executor exception, and any BoundedRel overflow observed in the
        resolved counts.  ``trip_context`` — a zero-arg callable returning a
        dict — is merged into the ``executor_error`` trip detail, letting
        the serving runtime attach the ledger snapshot + metrics report so
        an incident dump shows memory/occupancy state at failure time.
        Returns the plan outputs, like ``__call__``."""
        from .tracing import RunTrace, Tracer
        tracer = Tracer()
        sink: list = []
        run_aux = dict(aux or {})
        run_aux["count_sink"] = sink
        ctx = ExecContext(root=params, scope=params, aux=run_aux,
                          mesh=self.mesh, rules=self.rules,
                          interpret=self.interpret, tracer=tracer,
                          faults=self.faults)
        t0 = time.perf_counter()
        try:
            with tracer.span("run", "run", plan_id=self.plan_id):
                outs = run_plan(self.concrete, ctx, inputs)
            with tracer.span("device_sync", "sync") as sync_sp:
                jax.block_until_ready(outs)
        except Exception as exc:
            if recorder is not None:
                detail = {"plan_id": self.plan_id, "error": repr(exc)}
                if trip_context is not None:
                    try:
                        detail.update(trip_context() or {})
                    except Exception:
                        pass
                recorder.trip("executor_error", detail)
            raise
        wall_ms = (time.perf_counter() - t0) * 1e3
        # ONE device_get: deferred span attrs + the count sink together
        counts = tracer.resolve(sink)
        predicted = self._predict_costs(cost_model)
        samples = []
        for sp in tracer.spans:
            hit = predicted.get(sp.name)
            if hit is None:
                continue
            sec, feats = hit
            sp.attrs["predicted_s"] = sec
            samples.append((sp.attrs.get("impl", sp.name), feats, sp.dur))
        trace = RunTrace(spans=list(tracer.spans), wall_ms=wall_ms,
                         sync_ms=sync_sp.dur_ms if sync_sp else 0.0,
                         counts=counts, samples=samples,
                         plan_id=self.plan_id)
        object.__setattr__(self, "last_run_trace", trace)
        if recorder is not None:
            recorder.record_trace(trace)
            overflows = [
                {"site": list(map(str, site)), "count": float(c),
                 "capacity": int(cap)}
                for site, c, cap in counts
                if site and site[0] == "compact_overflow" and c > 0]
            overflows += [
                {"span": sp.name, "capacity": sp.attrs.get("capacity")}
                for sp in trace.spans if sp.attrs.get("overflow")]
            if overflows:
                recorder.trip("overflow", {"plan_id": self.plan_id,
                                           "overflows": overflows})
        if feedback is not None:
            _drain_counts(counts, feedback)
        return outs if len(outs) > 1 else outs[0]

    def observe(self, params, inputs: dict, feedback,
                aux: Optional[dict] = None):
        """Execute the plan **eagerly** while recording observed
        cardinalities: every ``rel_filter`` / ``sel_mask`` site reports its
        actual ``count / capacity`` into ``feedback`` (a
        ``SelectivityFeedback``).  BoundedRel makes the count a concrete
        runtime value outside jit, so observation is one un-jitted run;
        the accumulated device-side counts transfer in **one**
        ``device_get`` at the end (``resolve_counts`` — the same transfer
        point EXPLAIN ANALYZE uses), never per site.  Re-compiling with the
        same feedback object then re-plans under the observed
        selectivities (and misses the plan cache by construction).
        Returns the plan outputs, exactly like ``__call__``."""
        from .tracing import resolve_counts
        sink: list = []
        out_aux = dict(aux or {})
        out_aux["count_sink"] = sink
        outs = self.__call__(params, inputs, aux=out_aux)
        _drain_counts(resolve_counts(sink), feedback)
        return outs


def plan_and_compile(logical: Plan, catalog: FunctionCatalog,
                     syscat: SystemCatalog, *,
                     mesh=None, rules: ShardingRules = ShardingRules(),
                     cost_model: Optional[CostModel] = None,
                     engines=None,
                     allow_pallas=None,
                     data_parallel: bool = True,
                     buffering: bool = False,
                     global_batch: int = 1,
                     rewrite_pipeline=None,
                     interpret: bool = True,
                     cache=None,
                     pipeline=None,
                     plan_threads: int = 1,
                     feedback=None,
                     store_versions: tuple = ()) -> PlannedFunction:
    """Thin compatibility wrapper over the staged plan pipeline.

    Resolves the engine selection (``engines`` names from the registry;
    legacy ``allow_pallas`` still maps through), runs — or fetches from the
    plan cache — the Algorithm-1 pass pipeline, and binds the staged plan to
    this call's runtime context.  ``cache=False`` forces a fresh planning
    run; any other value uses the given / default PlanCache.

    ``feedback`` is an optional observed-selectivity store (consumed by the
    rewrites, folded into the plan id); ``store_versions`` is the bound
    stores' monotonic version vector — appending to a store bumps it, so
    plans cached against the previous contents provably invalidate.
    """
    from .pipeline import PlanOptions, compile_staged
    from .rewrite import DEFAULT_PIPELINE
    opts = PlanOptions(
        engines=resolve_engines(engines, allow_pallas=allow_pallas),
        data_parallel=data_parallel,
        buffering=buffering,
        global_batch=global_batch,
        rewrite_pipeline=tuple(rewrite_pipeline or DEFAULT_PIPELINE),
        plan_threads=plan_threads)
    extra_key = (("store_versions", tuple(store_versions))
                 if store_versions else ())
    staged = compile_staged(logical, catalog, syscat, options=opts,
                            cost_model=cost_model, pipeline=pipeline,
                            cache=cache, feedback=feedback,
                            extra_key=extra_key)
    return PlannedFunction.from_staged(staged, syscat, rules=rules,
                                       mesh=mesh, interpret=interpret)
