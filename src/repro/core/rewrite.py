"""Logical-plan rewriting (paper §4.2).

Three rule families, ported from the paper:

  1. **Function decomposition** (§4.2.1) — coarse analytical functions are
     decomposed into primitive operators (NER → CoreNLP annotator chain in the
     paper; here ``attention`` → q/k/v projections + sdpa + out-proj and
     ``mlp`` → up/gate/act/down), exposing a deeper level of optimization.
  2. **Redundancy elimination** (§4.2.2) — identical operators on identical
     inputs execute once (CSE).  The paper's motivating case — Preprocess and
     NER sharing a tokenize/ssplit/pos/lemma prefix — maps to shared
     projection/norm prefixes after decomposition.
  3. **Operator fusion** (§4.2.3) — chains of per-element operators fuse so
     that (a) intermediates are never materialized, and (b) *larger logical
     patterns* exist for the physical planner to match, which unlocks better
     fused physical candidates (the paper's Fig. 5/7 argument).  Here:
     q/k/v-projection fusion, GLU-FFN refusion, and scan(=Map)-fusion of
     consecutive ``scan_layers`` nodes.

All passes re-run :func:`infer_types` afterwards, so a rewritten plan is
always re-validated (the paper re-checks metadata after every rewrite).
"""
from __future__ import annotations

import contextvars
import math
from typing import Callable

from .ir import (CMP_SELECTIVITY as _CMP_SELECTIVITY, FunctionCatalog, Node,
                 Plan, ValidationError, count_nodes, infer_types)

# --------------------------------------------------------------------------
# 1. function decomposition
# --------------------------------------------------------------------------

# op -> builder(plan_like, node) -> (new chain of (op, attrs)) replacing node.
# Chains are linear: first element consumes node.inputs, last produces output.


def _carry(node: Node) -> dict:
    """Attrs every decomposed sub-op inherits (param path, sharing)."""
    out = {}
    for k in ("pp", "shared"):
        if k in node.attrs:
            out[k] = node.attrs[k]
    return out


def _decompose_attention(node: Node):
    a = node.attrs
    base = _carry(node)
    proj = {**base, **{k: a[k] for k in ("heads", "kv_heads", "head_dim")}}
    sdpa = dict(proj)
    for k in ("causal", "window", "qk_norm", "rope", "rope_theta", "sink",
              "emit_kv"):
        if k in a:
            sdpa[k] = a[k]
    return [
        ("q_proj", proj), ("k_proj", proj), ("v_proj", proj),
        ("pack_qkv", dict(base)),
        ("sdpa", sdpa),
        ("out_proj", {**base, "embed": a["embed"]}),
    ]


def _decompose_mlp(node: Node):
    a = node.attrs
    base = _carry(node)
    if a.get("gated", True):
        return [
            ("ffn_up", {**base, "ffn": a["ffn"]}),
            ("ffn_gate", {**base, "ffn": a["ffn"]}),
            ("ffn_glu", {**base, "act": a.get("act", "silu")}),
            ("ffn_down", {**base, "embed": a["embed"]}),
        ]
    return [
        ("ffn_up", {**base, "ffn": a["ffn"]}),
        ("ffn_act", {**base, "act": a.get("act", "gelu")}),
        ("ffn_down", {**base, "embed": a["embed"]}),
    ]


_DECOMPOSE: dict = {"attention": _decompose_attention, "mlp": _decompose_mlp}

# wiring templates: how the produced ops connect (index into produced list,
# -1 == the original node's input).  Linear chains need no template; these two
# have fan-in joins.
_WIRING = {
    "attention": {
        0: (-1,), 1: (-1,), 2: (-1,),        # q,k,v proj from the input
        3: (0, 1, 2),                          # pack_qkv(q, k, v)
        4: (3,),                               # sdpa
        5: (4,),                               # out_proj
    },
    # mlp is wired explicitly in ``decompose`` (gated vs ungated fan-in).
}


def decompose(plan: Plan, catalog: FunctionCatalog) -> Plan:
    """Apply function-decomposition rules (recursively into subplans)."""
    out = Plan(plan.name, {}, dict(plan.inputs), plan.outputs, {}, plan._ctr)
    remap: dict = {i: i for i in plan.inputs}

    for node in plan.topo():
        sub = node.subplan
        if sub is not None:
            sub = decompose(sub, catalog)
        if node.op not in _DECOMPOSE:
            nid = out.add(node.op, [remap[i] for i in node.inputs],
                          dict(node.attrs), sub, id=node.id)
            remap[node.id] = nid
            continue

        chain = _DECOMPOSE[node.op](node)
        src = remap[node.inputs[0]]
        produced = []
        if node.op == "attention":
            wiring = _WIRING["attention"]
            for idx, (op, attrs) in enumerate(chain):
                ins = [src if j == -1 else produced[j] for j in wiring[idx]]
                produced.append(out.add(op, ins, attrs))
        else:  # mlp: explicit wiring
            a = node.attrs
            up = out.add(chain[0][0], [src], chain[0][1])
            produced.append(up)
            if a.get("gated", True):
                gate = out.add("ffn_gate", [src], chain[1][1])
                glu = out.add("ffn_glu", [up, gate], chain[2][1])
                produced += [gate, glu]
                last_in = glu
                down_attrs = chain[3][1]
            else:
                act = out.add("ffn_act", [up], chain[1][1])
                produced.append(act)
                last_in = act
                down_attrs = chain[2][1]
            produced.append(out.add("ffn_down", [last_in], down_attrs))
        remap[node.id] = produced[-1]

    out.outputs = tuple(remap[o] for o in plan.outputs)
    return infer_types(out, catalog)


# --------------------------------------------------------------------------
# 2. redundancy elimination (CSE)
# --------------------------------------------------------------------------


def eliminate_redundancy(plan: Plan, catalog: FunctionCatalog) -> Plan:
    """§4.2.2: identical (op, inputs, attrs) nodes are merged, recursively."""
    out = Plan(plan.name, {}, dict(plan.inputs), plan.outputs, {}, plan._ctr)
    remap: dict = {i: i for i in plan.inputs}
    seen: dict = {}

    for node in plan.topo():
        sub = node.subplan
        if sub is not None:
            sub = eliminate_redundancy(sub, catalog)
        ins = tuple(remap[i] for i in node.inputs)
        key = (node.op, ins,
               tuple(sorted((k, _hashable(v)) for k, v in node.attrs.items())),
               sub.structure_key() if sub is not None else None)
        if key in seen and node.op != "store":  # stores are effects; keep them
            remap[node.id] = seen[key]
            continue
        nid = out.add(node.op, list(ins), dict(node.attrs), sub, id=node.id)
        seen[key] = nid
        remap[node.id] = nid

    out.outputs = tuple(remap[o] for o in plan.outputs)
    return infer_types(out, catalog)


def _hashable(v):
    if isinstance(v, dict):
        return tuple(sorted((k, _hashable(x)) for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return tuple(_hashable(x) for x in v)
    if callable(v):
        return getattr(v, "__name__", repr(v))
    return v


# --------------------------------------------------------------------------
# 3. operator fusion
# --------------------------------------------------------------------------


def fuse_qkv(plan: Plan, catalog: FunctionCatalog) -> Plan:
    """Fuse sibling q/k/v projections on the same input into one ``qkv_proj``.

    This is the tensor analogue of the paper's NLP-annotator pipeline fusion:
    three per-token projections sharing one input become a single fused
    operator whose output tuple feeds sdpa, and the *fused* pattern
    (qkv_proj→sdpa→out_proj) is what the physical pattern set matches to
    flash-attention candidates (Fig. 7's "larger pattern ⇒ better plans").
    """
    out = Plan(plan.name, {}, dict(plan.inputs), plan.outputs, {}, plan._ctr)
    remap: dict = {i: i for i in plan.inputs}
    nodes = list(plan.topo())
    consumed: set = set()

    by_input: dict = {}
    for n in nodes:
        if n.op in ("q_proj", "k_proj", "v_proj"):
            by_input.setdefault((n.inputs[0], _attr_key(n.attrs)), {})[n.op] = n

    fused_for: dict = {}  # pack_qkv node id -> fused qkv node will replace it
    for (src, _), group in by_input.items():
        if set(group) == {"q_proj", "k_proj", "v_proj"}:
            cons = plan.consumers()
            packs = [c for c in cons[group["q_proj"].id]
                     if plan.nodes[c].op == "pack_qkv"]
            for p in packs:
                pn = plan.nodes[p]
                if (pn.inputs == (group["q_proj"].id, group["k_proj"].id,
                                  group["v_proj"].id)):
                    fused_for[p] = (src, dict(group["q_proj"].attrs))
                    consumed.update(g.id for g in group.values())

    for n in nodes:
        sub = n.subplan
        if sub is not None:
            sub = fuse_qkv(sub, catalog)
        if n.id in consumed:
            continue
        if n.id in fused_for:
            src, attrs = fused_for[n.id]
            nid = out.add("qkv_proj", [remap[src]], attrs, id=n.id + "_fused")
            remap[n.id] = nid
            continue
        nid = out.add(n.op, [remap[i] for i in n.inputs], dict(n.attrs), sub,
                      id=n.id)
        remap[n.id] = nid

    out.outputs = tuple(remap[o] for o in plan.outputs)
    return infer_types(out, catalog)


def fuse_scans(plan: Plan, catalog: FunctionCatalog) -> Plan:
    """Map-fusion (§4.2.3) for ``scan_layers``: consecutive scans with the same
    trip count fuse into one scan whose subplan is the concatenation.  The
    intermediate carry between the two scans is never materialized per-layer,
    and XLA sees one loop instead of two (smaller HLO, better overlap)."""
    out = Plan(plan.name, {}, dict(plan.inputs), plan.outputs, {}, plan._ctr)
    remap: dict = {i: i for i in plan.inputs}
    nodes = list(plan.topo())
    cons = plan.consumers()
    skip: set = set()

    i = 0
    by_id = {n.id: n for n in nodes}
    for n in nodes:
        if n.id in skip:
            continue
        sub = n.subplan
        if (n.op == "scan_layers" and len(cons[n.id]) == 1):
            nxt = by_id.get(cons[n.id][0])
            if (nxt is not None and nxt.op == "scan_layers"
                    and nxt.inputs == (n.id,)
                    and nxt.attrs.get("n_layers") == n.attrs.get("n_layers")
                    and n.attrs.get("param_group") == nxt.attrs.get("param_group")):
                merged = _concat_subplans(n.subplan, nxt.subplan)
                attrs = dict(n.attrs)
                attrs["fused_from"] = (n.id, nxt.id)
                nid = out.add("scan_layers", [remap[n.inputs[0]]], attrs,
                              merged, id=n.id + "+" + nxt.id)
                remap[n.id] = nid
                remap[nxt.id] = nid
                skip.add(nxt.id)
                continue
        if sub is not None:
            sub = fuse_scans(sub, catalog)
        nid = out.add(n.op, [remap[i2] for i2 in n.inputs], dict(n.attrs), sub,
                      id=n.id)
        remap[n.id] = nid

    out.outputs = tuple(remap[o] for o in plan.outputs)
    return infer_types(out, catalog)


def _concat_subplans(a: Plan, b: Plan) -> Plan:
    """Concatenate two single-input/single-output subplans: b(a(x))."""
    assert len(a.inputs) == 1 and len(b.inputs) == 1
    out = a.copy()
    out.name = f"{a.name}+{b.name}"
    (a_out,) = a.outputs
    (b_in,) = b.inputs
    remap = {b_in: a_out}
    for n in b.topo():
        nid = out.add(n.op, [remap.get(i, i) for i in n.inputs], dict(n.attrs),
                      n.subplan.copy() if n.subplan else None,
                      id="b_" + n.id)
        remap[n.id] = nid
    out.outputs = (remap[b.outputs[0]],)
    return out


def _attr_key(attrs):
    return tuple(sorted((k, _hashable(v)) for k, v in attrs.items()))


# --------------------------------------------------------------------------
# 4. cross-engine transfer placement (tri-store: AWESOME §2 / tech-report §4)
# --------------------------------------------------------------------------
#
# AWESOME's optimizer is aware that a workload straddles engines: data moving
# between the relational, graph, and text stores is an explicit, costed
# operation, and the in-memory optimization decides *where* intermediates
# materialize.  ``place_xfers`` makes every engine boundary an explicit
# ``xfer`` node; the physical pattern set then offers two candidates per
# xfer — ``xfer_pin`` (keep the value device-resident) and ``xfer_spill``
# (materialize through the host) — and the cost model picks per boundary.
# ``place_xfers_naive`` models the federated-baseline strawman instead:
# every store-engine operator's output is materialized through the host
# (spill-only xfer), the per-op materialization AWESOME's placement beats.


def _engine_of_type(t) -> str:
    from .ir import CorpusT, GraphT, TableT
    if isinstance(t, TableT):
        return "rel"
    if isinstance(t, GraphT):
        return "graph"
    if isinstance(t, CorpusT):
        return "text"
    return "xla"


def _engine_of(plan: Plan, nid: str, catalog: FunctionCatalog) -> str:
    """Engine a value lives on: plan inputs by their data-model type, xfer
    nodes by their destination, other ops by their catalog attribution."""
    if nid in plan.inputs:
        return _engine_of_type(plan.inputs[nid])
    node = plan.nodes[nid]
    if node.op == "xfer":
        return node.attrs.get("dst_engine", "xla")
    return catalog.get(node.op).engine


def _pure_xla(plan: Plan, catalog: FunctionCatalog) -> bool:
    """No store-typed inputs and no store-engine ops, recursively — the
    overwhelmingly common tensor-only case, where xfer placement is a
    guaranteed no-op."""
    if any(_engine_of_type(t) != "xla" for t in plan.inputs.values()):
        return False
    for n in plan.topo():
        if n.op != "xfer" and catalog.get(n.op).engine != "xla":
            return False
        if n.subplan is not None and not _pure_xla(n.subplan, catalog):
            return False
    return True


def place_xfers(plan: Plan, catalog: FunctionCatalog) -> Plan:
    """Insert an ``xfer`` node on every edge that crosses an engine boundary.

    One xfer is shared per (producer, destination-engine) pair, so a value
    consumed by several same-engine operators moves once.  Pure-tensor plans
    (every op on the ``xla`` engine) are returned unchanged — and without
    paying the plan copy, since this pass runs on every default compile.
    """
    if _pure_xla(plan, catalog):
        return plan
    infer_types(plan, catalog)
    out = Plan(plan.name, {}, dict(plan.inputs), plan.outputs, {}, plan._ctr)
    remap: dict = {i: i for i in plan.inputs}
    xfer_for: dict = {}   # (producer id in out, dst engine) -> xfer id

    def crossed(src_old: str, src_new: str, dst_engine: str) -> str:
        src_engine = _engine_of(plan, src_old, catalog)
        if src_engine == dst_engine:
            return src_new
        key = (src_new, dst_engine)
        if key not in xfer_for:
            xfer_for[key] = out.add(
                "xfer", [src_new],
                {"src_engine": src_engine, "dst_engine": dst_engine},
                id=f"xfer_{src_old}_{dst_engine}")
        return xfer_for[key]

    for node in plan.topo():
        sub = node.subplan
        if sub is not None:
            sub = place_xfers(sub, catalog)
        dst_engine = ("xla" if node.op == "xfer"
                      else catalog.get(node.op).engine)
        ins = []
        for i in node.inputs:
            src = remap[i]
            if node.op != "xfer":
                src = crossed(i, src, dst_engine)
            ins.append(src)
        nid = out.add(node.op, ins, dict(node.attrs), sub, id=node.id)
        remap[node.id] = nid

    out.outputs = tuple(remap[o] for o in plan.outputs)
    return infer_types(out, catalog)


def place_xfers_naive(plan: Plan, catalog: FunctionCatalog) -> Plan:
    """The per-op-materialization baseline: every store-engine operator's
    output round-trips through the host (a spill-only xfer), the way a
    naive federated system hands each engine result back to the mediator.
    Used by ``benchmarks/tri_store_eff.py`` as the strawman that planned
    placement must beat."""
    infer_types(plan, catalog)
    out = Plan(plan.name, {}, dict(plan.inputs), plan.outputs, {}, plan._ctr)
    remap: dict = {i: i for i in plan.inputs}

    for node in plan.topo():
        sub = node.subplan
        if sub is not None:
            sub = place_xfers_naive(sub, catalog)
        nid = out.add(node.op, [remap[i] for i in node.inputs],
                      dict(node.attrs), sub, id=node.id)
        remap[node.id] = nid
        engine = ("xla" if node.op == "xfer"
                  else catalog.get(node.op).engine)
        if engine != "xla":
            remap[node.id] = out.add(
                "xfer", [nid],
                {"src_engine": engine, "dst_engine": "xla",
                 "spill_only": True},
                id=f"spill_{node.id}")

    out.outputs = tuple(remap[o] for o in plan.outputs)
    return infer_types(out, catalog)


# --------------------------------------------------------------------------
# 5. cross-engine predicate pushdown (AWESOME tech report: pushdown + lazy
#    materialization across the tri-store)
# --------------------------------------------------------------------------
#
# Relational filters narrow a selection mask that, without this pass, only
# the relational engine sees: downstream engines score every document and
# touch every edge even when the seed relation kept 1% of its rows.
# ``push_predicates`` propagates that selection across engine boundaries:
#
#   * **filter-below-join** — a ``rel_filter`` over a ``rel_join`` whose
#     predicate column comes from the probe (left) side sinks below the
#     join, so the probe runs on the narrowed relation (mask conjunction
#     commutes, so this is exact);
#   * **mask-into-text** — the unpushed idiom ``masked_topk(text_scores(cx,
#     q), m)`` (score the whole corpus in the text engine, select+top-k
#     outside it) collapses into a 3-input ``text_topk(cx, q, m)``: the
#     mask crosses the xfer boundary *into* the text engine, where the
#     physical layer can offer masked/fused scoring candidates;
#   * **graph frontier masks** — ``graph_expand``/``graph_pagerank`` whose
#     frontier/personalization descends from a filtered relation are
#     annotated with the estimated frontier sparsity, unlocking the
#     block-skipping SpMV candidate.
#
# Every rewritten/annotated op carries a ``selectivity`` attr — the
# estimated selected fraction, the product of upstream filter
# selectivities (explicit ``selectivity=`` hints win over the per-cmp
# heuristics).  The cost model prices masked candidates with it, so
# pushdown is chosen only where it is expected to win (at selectivity 1.0
# the dense plan is kept).

def _filter_site_of(plan: Plan, node: Node) -> tuple:
    """The filter's feedback site key, built from its input relation's
    type (schema + capacity: the table-identity components the run-time
    recording side derives from the relation itself)."""
    from .feedback import filter_site
    t = plan.types.get(node.inputs[0]) if node.inputs else None
    cols = t.col_names() if hasattr(t, "col_names") else ()
    cap = getattr(t, "rows", None)
    return filter_site(node.attrs, cols, cap)


def _filter_selectivity(node: Node, site: tuple = None) -> float:
    """*Marginal* selected fraction of one rel_filter: observed feedback
    (blended over the a-priori estimate) wins, then the explicit
    ``selectivity=`` hint (the paper's metadata route), then a
    per-comparator heuristic.

    Observation-over-hint ordering is the point of the feedback loop: a
    mis-hinted filter self-corrects once a run has been observed."""
    from .feedback import active_feedback
    if "selectivity" in node.attrs:
        base = float(node.attrs["selectivity"])
    else:
        base = _CMP_SELECTIVITY.get(node.attrs.get("cmp"), 0.5)
    fb = active_feedback()
    if fb is not None and site is not None:
        return fb.blend(site, base)
    return base


def estimate_selectivity(plan: Plan, nid: str, catalog: FunctionCatalog,
                         _memo: dict | None = None) -> float:
    """Estimated selected fraction of the value produced at ``nid``.

    Filters multiply along the lineage; group-by and entity-mask exports
    rescale row selectivity onto the group/entity domain (an upper bound:
    ``min(1, s · rows / domain)``); joins only narrow, so they pass the
    probe side's estimate through.  Plan inputs are fully selected (1.0).
    """
    memo = _memo if _memo is not None else {}
    if nid in memo:
        return memo[nid]
    if nid in plan.inputs:
        return 1.0
    node = plan.nodes[nid]

    def up(i):
        return estimate_selectivity(plan, node.inputs[i], catalog, memo)

    if node.op == "rel_filter":
        s = up(0) * _filter_selectivity(node, _filter_site_of(plan, node))
    elif node.op in ("rel_scan", "col_tensor", "xfer"):
        s = up(0)
    elif node.op == "rel_join":
        s = up(0)
    elif node.op == "compact":
        # compaction re-bases the fraction onto the narrowed capacity: the
        # surviving rows now fill (up to) the whole smaller relation
        t_in = plan.types.get(node.inputs[0])
        rows = getattr(t_in, "rows", 1)
        cap = int(node.attrs.get("capacity", rows))
        s = min(1.0, up(0) * max(rows, 1) / max(cap, 1))
    elif node.op in ("rel_group_agg", "sel_mask"):
        t = plan.types.get(node.inputs[0])
        rows = getattr(t, "rows", 1)
        domain = int(node.attrs.get("num_groups", node.attrs.get("size", 1)))
        s = min(1.0, up(0) * max(rows, 1) / max(domain, 1))
        if node.op == "sel_mask":
            from .feedback import active_feedback, sel_mask_site
            fb = active_feedback()
            if fb is not None:
                s = fb.blend(sel_mask_site(node.attrs), s)
    else:
        s = 1.0
    s = float(min(max(s, 0.0), 1.0))
    memo[nid] = s
    return s


def _rebuild(plan: Plan, skip: set, replace_fn) -> Plan:
    """Rebuild ``plan`` skipping ``skip`` node ids; ``replace_fn(node, out,
    remap)`` may emit a replacement and return its id (or None to copy)."""
    out = Plan(plan.name, {}, dict(plan.inputs), plan.outputs, {}, plan._ctr)
    remap: dict = {i: i for i in plan.inputs}
    for node in plan.topo():
        if node.id in skip:
            continue
        rid = replace_fn(node, out, remap)
        if rid is None:
            rid = out.add(node.op, [remap[i] for i in node.inputs],
                          dict(node.attrs), node.subplan, id=node.id)
        remap[node.id] = rid
    out.outputs = tuple(remap[o] for o in plan.outputs)
    return out


def _dce(plan: Plan) -> Plan:
    """Drop nodes unreachable from the outputs (pushdown leaves the
    replaced ``text_scores``/``masked_topk`` producers dangling)."""
    live: set = set(plan.outputs)
    for node in reversed(list(plan.topo())):
        if node.id in live:
            live.update(node.inputs)
    dead = {n.id for n in plan.topo() if n.id not in live}
    if not dead:
        return plan
    return _rebuild(plan, dead, lambda n, o, r: None)


def _sink_filters_below_joins(plan: Plan, catalog: FunctionCatalog,
                              info: list) -> Plan:
    """``rel_filter(rel_join(L, R), col ∈ L)`` → ``rel_join(rel_filter(L),
    R)`` to fixpoint, when the join's only consumer is the filter."""
    from .ir import TableT
    changed = True
    while changed:
        changed = False
        cons = plan.consumers()
        for node in plan.topo():
            if node.op != "rel_filter":
                continue
            src = node.inputs[0]
            if src in plan.inputs:
                continue
            j = plan.nodes[src]
            if j.op != "rel_join" or len(cons[src]) != 1:
                continue
            lt = plan.types.get(j.inputs[0])
            if not (isinstance(lt, TableT) and lt.has_col(node.attrs["col"])):
                continue        # predicate reads a build-side column

            def repl(n, out, remap, _f=node, _j=j):
                if n.id == _j.id:
                    f2 = out.add("rel_filter", [remap[_j.inputs[0]]],
                                 dict(_f.attrs), id=_f.id + "_sunk")
                    return out.add("rel_join", [f2, remap[_j.inputs[1]]],
                                   dict(_j.attrs), id=_j.id)
                if n.id == _f.id:
                    return remap[_j.id]
                return None

            info.append({"rule": "filter_below_join", "filter": node.id,
                         "join": j.id, "col": node.attrs["col"]})
            plan = infer_types(_rebuild(plan, set(), repl), catalog)
            changed = True
            break
    return plan


def push_predicates(plan: Plan, catalog: FunctionCatalog) -> Plan:
    """Propagate relational selection masks across engine boundaries."""
    if _pure_xla(plan, catalog):
        return plan
    infer_types(plan, catalog)
    info: list = []
    plan = _sink_filters_below_joins(plan, catalog, info)

    memo: dict = {}
    cons = plan.consumers()
    # mask-into-text: masked_topk(text_scores(cx, q), m) -> text_topk(cx,
    # q, m) when the full score vector has no other consumer
    pushed: dict = {}       # masked_topk id -> (scores node, mask id)
    for node in plan.topo():
        if node.op != "masked_topk":
            continue
        sc_id, m_id = node.inputs
        if sc_id in plan.inputs:
            continue
        sc = plan.nodes[sc_id]
        if sc.op == "text_scores" and len(cons[sc_id]) == 1:
            pushed[node.id] = (sc, m_id)

    def repl(node, out, remap):
        if node.id in pushed:
            sc, m_id = pushed[node.id]
            sel = float(node.attrs.get(
                "selectivity",
                estimate_selectivity(plan, m_id, catalog, memo)))
            attrs = {"k": node.attrs["k"], "pushed": True,
                     "selectivity": sel}
            info.append({"rule": "mask_into_text", "op": node.id,
                         "mask": m_id, "selectivity": round(sel, 4)})
            return out.add(
                "text_topk",
                [remap[sc.inputs[0]], remap[sc.inputs[1]], remap[m_id]],
                attrs, id=node.id + "_pushed")
        if node.op in ("graph_expand", "graph_pagerank") \
                and len(node.inputs) == 2:
            sel = estimate_selectivity(plan, node.inputs[1], catalog, memo)
            if sel < 1.0:
                key = ("frontier_selectivity" if node.op == "graph_expand"
                       else "personalization_selectivity")
                attrs = dict(node.attrs)
                attrs[key] = float(round(sel, 6))
                info.append({"rule": "mask_into_graph", "op": node.id,
                             key: round(sel, 4)})
                return out.add(node.op, [remap[i] for i in node.inputs],
                               attrs, id=node.id)
        return None

    out = _dce(_rebuild(plan, set(), repl))
    out = infer_types(out, catalog)
    if info:
        out.__dict__["_pass_info"] = {"pushed": info}
    return out


# --------------------------------------------------------------------------
# 5b. compaction placement + cardinality annotation (bounded relations)
# --------------------------------------------------------------------------
#
# Masked execution drags every relation at full capacity through every
# downstream operator: a 1%-selective filter still probes, aggregates, and
# exports masks over 100% of the rows.  ``choose_compaction`` inserts a
# ``compact`` node — stable prefix compaction into a small capacity sized
# from the expected count — below low-selectivity filters, and reroutes the
# shape-agnostic consumers (further filters, group-by, mask export, and the
# *probe* side of joins) onto the compacted relation.  Compaction is only
# placed where the cardinality estimate is **trustworthy** (an explicit
# ``selectivity=`` hint or an observed-feedback site, on an otherwise
# unnarrowed input), because an underestimate would overflow the bound
# and drop rows; the capacity carries 2x slack and the runtime overflow
# flag makes any residual miss observable rather than silent.  (Dropped
# rows contribute exactly +/-0.0 to every mask-weighted consumer, so
# compaction is bitwise-neutral for *finite* column data; a masked NaN/inf
# value would poison a masked-dense sum but not a compacted one.)
#
# The same pass annotates every join with its build/probe cardinalities
# (``build_rows`` / ``build_expected`` / ``probe_expected``), the attrs the
# physical layer's Pallas probe-kernel candidate is gated and priced on.

COMPACT_SELECTIVITY = 0.125    # compact only below this expected fraction
COMPACT_SLACK = 2.0            # capacity headroom over the expected count
COMPACT_MIN_CAPACITY = 8

def _round_up(n: int, mult: int = 8) -> int:
    return ((int(n) + mult - 1) // mult) * mult


def _confident_selectivity(plan: Plan, node: Node, catalog, memo) -> float:
    """The filter's expected fraction, but only when the estimate is
    trustworthy enough to size a lossy capacity bound: the site must carry
    an explicit hint or an observed-feedback record, the filter's input
    must be **unnarrowed** (any upstream selection — hinted or not —
    disqualifies the site: the bound is sized from this filter's fraction
    alone, so compounded upstream narrowing has no backing estimate here;
    compound-confidence tracking is future work), and the site must not
    have been *observed to overflow* a previous compaction.  Returns a
    fraction, or -1 when not confident."""
    from .feedback import active_feedback
    fb = active_feedback()
    site = _filter_site_of(plan, node)
    if fb is not None and fb.is_overflowed(site):
        return -1.0            # a prior bound dropped rows: back off
    observed = fb is not None and fb.lookup(site) is not None
    if "selectivity" not in node.attrs and not observed:
        return -1.0
    up = estimate_selectivity(plan, node.inputs[0], catalog, memo)
    if up < 1.0 - 1e-9:
        return -1.0
    return _filter_selectivity(node, site)


def _capacity_safe(plan: Plan, cons: dict, nid: str, memo: dict) -> bool:
    """Whether every *transitive* consumer of ``nid`` re-bases onto a
    fixed domain before any capacity-sensitive use.  A compacted relation
    has a smaller capacity and prefix-reordered rows, so it may only flow
    into consumers whose output shape/content is independent of the input
    capacity: group-bys and mask exports (fixed domains), ``bounded_join``
    (fixed declared capacity, duplicate/masked build rows handled), and —
    recursively — filters, further compacts, and unique-join *probe* sides
    whose own outputs are capacity-safe.  ``col_tensor`` (capacity-long
    tensor out), a unique-join *build* side (padding would duplicate
    keys), and being a plan output are all capacity-sensitive."""
    if nid in memo:
        return memo[nid]
    if nid in set(plan.outputs):
        memo[nid] = False
        return False
    ok = True
    for c in cons[nid]:
        cn = plan.nodes[c]
        if cn.op in ("rel_group_agg", "sel_mask", "bounded_join"):
            continue
        if cn.op in ("rel_filter", "compact", "rel_scan") \
                and cn.inputs[0] == nid:
            ok = _capacity_safe(plan, cons, c, memo)
        elif cn.op == "rel_join" and cn.inputs[0] == nid \
                and cn.inputs[1] != nid:
            ok = _capacity_safe(plan, cons, c, memo)
        else:
            ok = False
        if not ok:
            break
    memo[nid] = ok
    return ok


def choose_compaction(plan: Plan, catalog: FunctionCatalog) -> Plan:
    """Insert ``compact`` below confidently low-selectivity filters and
    annotate joins with build/probe cardinalities."""
    if _pure_xla(plan, catalog):
        return plan
    infer_types(plan, catalog)
    memo: dict = {}
    cons = plan.consumers()
    info: list = []

    safe_memo: dict = {}
    targets: dict = {}        # filter node id -> (capacity, expected, site)
    reroute: set = set()      # (consumer id, input position) pairs
    for node in plan.topo():
        if node.op != "rel_filter":
            continue
        t = plan.types.get(node.id)
        rows = getattr(t, "rows", 0)
        sel = _confident_selectivity(plan, node, catalog, memo)
        if sel < 0.0 or sel > COMPACT_SELECTIVITY:
            continue
        expected = max(1, int(math.ceil(rows * sel)))
        capacity = _round_up(max(COMPACT_MIN_CAPACITY,
                                 int(math.ceil(expected * COMPACT_SLACK))))
        if capacity >= rows:
            continue          # nothing to gain
        elig = []
        for c in cons[node.id]:
            cn = plan.nodes[c]
            for pos, i in enumerate(cn.inputs):
                if i != node.id:
                    continue
                if cn.op in ("rel_group_agg", "sel_mask", "bounded_join"):
                    elig.append((c, pos))      # fixed-domain consumers
                elif cn.op == "rel_filter" and pos == 0 \
                        and _capacity_safe(plan, cons, c, safe_memo):
                    elig.append((c, pos))
                elif cn.op == "rel_join" and pos == 0 \
                        and _capacity_safe(plan, cons, c, safe_memo):
                    elig.append((c, pos))      # probe side, safe downstream
        if not elig:
            continue
        targets[node.id] = (capacity, expected, _filter_site_of(plan, node))
        reroute.update(elig)
        info.append({"rule": "compact_below_filter", "filter": node.id,
                     "capacity": capacity, "expected": expected,
                     "rows": int(rows), "selectivity": round(sel, 4)})

    out = Plan(plan.name, {}, dict(plan.inputs), plan.outputs, {}, plan._ctr)
    remap: dict = {i: i for i in plan.inputs}
    compact_of: dict = {}
    for node in plan.topo():
        ins = []
        for pos, i in enumerate(node.inputs):
            if (node.id, pos) in reroute and i in compact_of:
                ins.append(compact_of[i])
            else:
                ins.append(remap[i])
        attrs = dict(node.attrs)
        if node.op == "rel_filter":
            # stamp the feedback site computed from the *pre-compaction*
            # view: a filter rerouted onto a compact sees a different
            # capacity at run time, so without the stamp its observations
            # would be recorded under a key no planning run ever looks up
            attrs["site"] = _filter_site_of(plan, node)
        if node.op in ("rel_join", "bounded_join"):
            # cardinality annotation for the physical probe-kernel gate
            bt = plan.types.get(node.inputs[1])
            pt = plan.types.get(node.inputs[0])
            if hasattr(bt, "expected_rows"):
                attrs["build_rows"] = int(bt.rows)
                attrs["build_expected"] = bt.expected_rows()
            if hasattr(pt, "expected_rows"):
                attrs["probe_expected"] = pt.expected_rows()
        nid = out.add(node.op, ins, attrs, node.subplan, id=node.id)
        remap[node.id] = nid
        if node.id in targets:
            cap, exp, site = targets[node.id]
            t = plan.types.get(node.id)
            compact_of[node.id] = out.add(
                "compact", [nid],
                {"capacity": cap, "expected_count": exp,
                 # the predicate site (overflow observations feed back to
                 # _confident_selectivity) and the column dtypes (the
                 # Pallas one-hot candidate is float/bool-exact only)
                 "site": site,
                 "col_dtypes": tuple(d for _, d in t.columns)},
                id=node.id + "_compact")

    out.outputs = tuple(remap[o] for o in plan.outputs)
    out = infer_types(out, catalog)
    if info:
        out.__dict__["_pass_info"] = {"compacted": info}
    return out


# --------------------------------------------------------------------------
# 6. same-engine store-op fusion (the Fig. 7 larger-pattern argument, for
#    store chains: masks never round-trip as full-width intermediates)
# --------------------------------------------------------------------------

# compact and bounded_join fuse like any other rel op (their step fns are
# in the executor's shared _REL_STEPS table), so inserting a compaction
# below a filter does not split a scan->filter->join->group_agg chain —
# the low-selectivity regime compaction targets is exactly where the
# fused-superkernel win matters most
_REL_FUSABLE = ("rel_scan", "rel_filter", "compact", "rel_join",
                "bounded_join", "rel_group_agg")


def fuse_store_ops(plan: Plan, catalog: FunctionCatalog) -> Plan:
    """Collapse single-consumer chains of relational store ops into one
    ``rel_fused`` node whose ``chain`` attr records the steps.  The fused
    node is a *larger logical pattern* for the physical layer: one engine
    call per chain (the masked segment-aggregate kernel slots in here), and
    interior tables never surface as plan-level intermediates.
    """
    if _pure_xla(plan, catalog):
        return plan
    infer_types(plan, catalog)
    cons = plan.consumers()
    out_set = set(plan.outputs)

    fusable = _REL_FUSABLE
    syscat = _ACTIVE_SYSCAT.get()
    if (syscat is not None and syscat.axis_size("data") > 1
            and any(getattr(t, "partitioning", None)
                    for t in plan.inputs.values())):
        # under mesh sharding, joins stay standalone plan nodes: the
        # distributed join kernels (broadcast build / all-to-all
        # co-partition) dispatch on the node's ``dist`` attr, which
        # ``shard_stores`` cannot stamp on a step buried inside a chain
        fusable = tuple(op for op in fusable
                        if op not in ("rel_join", "bounded_join"))

    # group maximal chains by walking producers of the first (table) input
    group_of: dict = {}       # node id -> chain head id
    chains: dict = {}         # head id -> [Node, ...] in order
    for node in plan.topo():
        if node.op not in fusable:
            continue
        src = node.inputs[0]
        head = group_of.get(src)
        if (head is not None and len(cons[src]) == 1
                and src not in out_set):
            group_of[node.id] = head
            chains[head].append(node)
        else:
            group_of[node.id] = node.id
            chains[node.id] = [node]

    fused = {h: c for h, c in chains.items() if len(c) >= 2}
    if not fused:
        return plan
    in_chain = {n.id: h for h, c in fused.items() for n in c}
    info = [{"head": h, "ops": [n.op for n in c], "len": len(c)}
            for h, c in fused.items()]

    out = Plan(plan.name, {}, dict(plan.inputs), plan.outputs, {}, plan._ctr)
    remap: dict = {i: i for i in plan.inputs}
    for node in plan.topo():
        head = in_chain.get(node.id)
        if head is None:
            nid = out.add(node.op, [remap[i] for i in node.inputs],
                          dict(node.attrs), node.subplan, id=node.id)
            remap[node.id] = nid
            continue
        chain = fused[head]
        if node.id != chain[-1].id:
            # interior members are consumed only inside the chain: defer
            # emission to the tail's position, where every external input
            # (e.g. a later join's build side) is already remapped
            continue
        members = {n.id for n in chain}
        ext_inputs: list = []   # external producer ids, in first-use order
        steps = []
        prev_id = None
        for n in chain:
            srcs = []
            for i in n.inputs:
                # the chain is linear along first inputs: only the previous
                # member is reachable as "prev"; anything else (e.g. a
                # join's build side) is an external input
                if i in members and i == prev_id:
                    srcs.append("prev")
                else:
                    key = remap[i]
                    if key not in ext_inputs:
                        ext_inputs.append(key)
                    srcs.append(ext_inputs.index(key))
            steps.append((n.op, dict(n.attrs), tuple(srcs),
                          plan.types.get(n.id)))
            prev_id = n.id
        nid = out.add("rel_fused", ext_inputs,
                      {"chain": tuple(steps)}, id="fused_" + head)
        for n in chain:
            remap[n.id] = nid

    out.outputs = tuple(remap[o] for o in plan.outputs)
    out = infer_types(out, catalog)
    out.__dict__["_pass_info"] = {"fused_chains": info}
    return out


# --------------------------------------------------------------------------
# 7. store sharding over the device mesh ("shard_stores")
# --------------------------------------------------------------------------
#
# When any bound store is declared partitioned over the mesh's ``data`` axis
# (``ColumnStore.with_shards`` / ``GraphStore.with_shards`` /
# ``TextStore.with_shards``), this pass (a) propagates partitioned-ness
# through the dataflow, (b) stamps a ``dist`` attr on every store op the
# runtime can execute shard-locally, (c) picks the distributed join strategy
# (broadcast the build side vs co-partition both sides) from the build
# side's *expected* cardinality, and (d) kinds every cross-engine ``xfer``
# as ``local`` / ``replicate`` / ``repartition`` so the cost model prices
# its wire bytes.  Values stay logically global throughout — ``dist`` is a
# pure performance annotation (shard_map slices the global value; any op
# without a sharded realization falls back to the dense global kernel), so
# there is no correctness cliff when a shape fails a divisibility check.

_ACTIVE_SYSCAT = contextvars.ContextVar("rewrite_syscat", default=None)

# build sides at or under this many expected rows replicate (all-gather);
# larger builds co-partition both sides with an all-to-all shuffle
BROADCAST_BUILD_MAX = 4096
# headroom multiplier on the expected per-(sender, owner) shuffle bucket
SHUFFLE_SLACK = 4

_DTYPE_BYTES = {"float64": 8, "int64": 8, "float32": 4, "int32": 4,
                "float16": 2, "bfloat16": 2, "int16": 2, "int8": 1, "bool": 1}


def _value_bytes(t) -> int:
    """Expected wire size of a value: tables by expected (not capacity)
    rows, tensors dense, stores by their edge/posting payloads."""
    from .ir import CorpusT, GraphT, TableT, TensorT
    if isinstance(t, TableT):
        row = sum(_DTYPE_BYTES.get(str(d), 4) for _, d in t.columns) + 1
        return int(t.expected_rows()) * row
    if isinstance(t, TensorT):
        size = 1
        for s in t.shape:
            size *= int(s)
        return size * _DTYPE_BYTES.get(str(t.dtype), 4)
    if isinstance(t, GraphT):
        return int(t.edges) * 12          # (src, dst, weight) per edge
    if isinstance(t, CorpusT):
        return int(t.postings) * 12       # (doc, term, tf) per posting
    return 0


# per-op partitioned-ness transfer for fused-chain steps: ops that keep the
# row partition of their first input vs ops whose output is replicated
_PART_KEEPS = {"rel_scan", "rel_filter", "rel_join", "bounded_join",
               "col_tensor"}
_PART_DROPS = {"rel_group_agg", "compact", "sel_mask", "text_topk",
               "masked_topk", "graph_tricount"}


def shard_stores(plan: Plan, catalog: FunctionCatalog) -> Plan:
    syscat = _ACTIVE_SYSCAT.get()
    n = 1 if syscat is None else int(syscat.axis_size("data"))
    if n <= 1:
        return plan
    infer_types(plan, catalog)
    if not any(getattr(t, "partitioning", None)
               for t in plan.inputs.values()):
        return plan

    out = Plan(plan.name, {}, dict(plan.inputs), plan.outputs, {}, plan._ctr)
    remap: dict = {i: i for i in plan.inputs}
    part: dict = {i: bool(getattr(t, "partitioning", None))
                  for i, t in plan.inputs.items()}
    xfers, dist_nodes = [], []

    def table_divides(t) -> bool:
        return int(t.rows) % n == 0

    def reshard(src: str, owner: str, side: str, est: int) -> str:
        nid = out.add("xfer", [src],
                      {"src_engine": "rel", "dst_engine": "rel",
                       "kind": "repartition", "est_bytes": est},
                      id=f"reshard_{owner}_{side}")
        part[nid] = True
        xfers.append({"id": nid, "kind": "repartition", "est_bytes": est})
        return nid

    for node in plan.topo():
        tys = [plan.types[i] if i in plan.nodes else plan.inputs[i]
               for i in node.inputs]
        ins = [remap[i] for i in node.inputs]
        attrs = dict(node.attrs)
        p_in = part.get(node.inputs[0], False) if node.inputs else False
        p_out = False
        ty = plan.types[node.id]

        if node.op == "xfer":
            if attrs.get("spill_only"):
                kind = None               # the naive spill path stays priced
            elif not p_in:
                kind = "local"            # replicated value: pointer move
            elif attrs.get("dst_engine") == "xla":
                kind = "replicate"        # dense consumers need it whole
            else:
                kind = "local"            # stays partitioned in the store
                p_out = True
            if kind is not None:
                b = _value_bytes(tys[0])
                est = (0 if kind == "local"
                       else b * (n - 1) // n)
                attrs["kind"] = kind
                attrs["est_bytes"] = est
                xfers.append({"id": node.id, "kind": kind, "est_bytes": est})
        elif node.op in ("rel_join", "bounded_join") and p_in:
            lt, rt = tys
            be = attrs.get("build_expected", rt.expected_rows())
            cap = int(attrs.get("capacity", 0))
            can_partition = (node.op == "bounded_join" and cap % n == 0
                             and table_divides(lt) and table_divides(rt))
            if int(be) <= BROADCAST_BUILD_MAX or not can_partition:
                attrs["dist"] = "broadcast"
                # build side replicates: price its all-gather on this node
                attrs["bcast_bytes"] = _value_bytes(rt) * (n - 1) // n
            else:
                attrs["dist"] = "partitioned"
                per_bucket = max(lt.expected_rows(), rt.expected_rows())
                attrs["bucket_cap"] = max(
                    16, -(-SHUFFLE_SLACK * int(per_bucket)) // (n * n))
                est = (_value_bytes(lt) + _value_bytes(rt)) * (n - 1) // (n * n)
                ins = [reshard(ins[0], node.id, "l", est // 2),
                       reshard(ins[1], node.id, "r", est - est // 2)]
            p_out = True
            dist_nodes.append({"id": node.id, "op": node.op,
                               "dist": attrs["dist"],
                               "build_expected": int(be)})
        elif node.op in ("rel_scan", "rel_filter", "col_tensor", "sel_mask",
                         "rel_group_agg") and p_in:
            attrs["dist"] = "row"
            p_out = node.op in _PART_KEEPS
            dist_nodes.append({"id": node.id, "op": node.op, "dist": "row"})
        elif node.op == "rel_fused" and p_in:
            attrs["dist"] = "row"
            p = True
            for op, _a, _s, _t in attrs["chain"]:
                p = p and op in _PART_KEEPS
            p_out = p
            dist_nodes.append({"id": node.id, "op": node.op, "dist": "row"})
        elif (node.op in ("graph_expand", "graph_pagerank")
              and getattr(tys[0], "partitioning", None) == "block"):
            attrs["dist"] = "block"
            p_out = True
            dist_nodes.append({"id": node.id, "op": node.op, "dist": "block"})
        elif (node.op == "text_topk" and len(node.inputs) == 2
              and getattr(tys[0], "partitioning", None) == "doc"):
            attrs["dist"] = "doc"
            dist_nodes.append({"id": node.id, "op": node.op, "dist": "doc"})
        elif node.op == "compact":
            p_out = False
        else:
            # dense / xla ops consume the global value and emit replicated;
            # fall back to the output type's own declaration when present
            p_out = bool(getattr(ty, "partitioning", None))

        nid = out.add(node.op, ins, attrs, node.subplan, id=node.id)
        remap[node.id] = nid
        part[nid] = p_out

    out.outputs = tuple(remap[o] for o in plan.outputs)
    out = infer_types(out, catalog)
    out.__dict__["_pass_info"] = {"xfers": xfers, "dist": dist_nodes}
    return out


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

DEFAULT_PIPELINE = ("decompose", "cse", "fuse_qkv", "fuse_scans", "cse",
                    "push_predicates", "choose_compaction", "fuse_store_ops",
                    "place_xfers", "shard_stores")

# PR 3's pipeline (planned xfer placement, no cross-engine pushdown): the
# baseline the pushdown benchmark compares against
UNPUSHED_PIPELINE = ("decompose", "cse", "fuse_qkv", "fuse_scans", "cse",
                     "place_xfers", "shard_stores")

# the masked-dense baseline: full pushdown but no compaction — every
# relation stays at base capacity behind its mask (what the --bounded
# benchmark compares compact-then-dense against)
UNCOMPACTED_PIPELINE = tuple(p for p in DEFAULT_PIPELINE
                             if p != "choose_compaction")

_PASSES: dict = {
    "decompose": decompose,
    "cse": eliminate_redundancy,
    "fuse_qkv": fuse_qkv,
    "fuse_scans": fuse_scans,
    "push_predicates": push_predicates,
    "choose_compaction": choose_compaction,
    "fuse_store_ops": fuse_store_ops,
    "place_xfers": place_xfers,
    "place_xfers_naive": place_xfers_naive,
    "shard_stores": shard_stores,
}


def rewrite(plan: Plan, catalog: FunctionCatalog,
            pipeline=DEFAULT_PIPELINE) -> Plan:
    """Run the logical-rewrite pipeline (the paper's Fig. 6 sequencing:
    decompose → merge redundancy → fuse)."""
    out, _ = rewrite_with_trace(plan, catalog, pipeline)
    return out


def rewrite_with_trace(plan: Plan, catalog: FunctionCatalog,
                       pipeline=DEFAULT_PIPELINE, syscat=None) -> tuple:
    """Like :func:`rewrite`, also returning per-rule timing/size records
    ``[{"rule", "wall_ms", "nodes_before", "nodes_after"}, ...]`` for the
    EXPLAIN report of the staged plan pipeline.  ``syscat`` (the mesh-aware
    system catalog) is installed for passes that shard against the mesh —
    without it ``shard_stores`` no-ops."""
    import time

    token = _ACTIVE_SYSCAT.set(syscat)
    try:
        return _rewrite_with_trace(plan, catalog, pipeline)
    finally:
        _ACTIVE_SYSCAT.reset(token)


def _rewrite_with_trace(plan: Plan, catalog: FunctionCatalog,
                        pipeline=DEFAULT_PIPELINE) -> tuple:
    import time

    infer_types(plan, catalog)
    trace = []
    for name in pipeline:
        before = count_nodes(plan)
        t0 = time.perf_counter()
        plan = _PASSES[name](plan, catalog)
        rec = {
            "rule": name,
            "wall_ms": (time.perf_counter() - t0) * 1e3,
            "nodes_before": before,
            "nodes_after": count_nodes(plan),
        }
        # passes may leave a side-channel report (e.g. push_predicates:
        # which ops received masks and at what estimated selectivity) —
        # surfaced per rule in the EXPLAIN trace
        extra = plan.__dict__.pop("_pass_info", None)
        if extra:
            rec["info"] = extra
        trace.append(rec)
    return plan, trace
