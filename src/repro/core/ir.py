"""Typed logical IR for AWESOME-JAX (paper §2–§3).

The paper's ADIL is a strongly-typed dataflow language: a workload is a DAG of
assignment statements whose RHS expressions are constants, queries, function
calls, or higher-order map/filter/reduce expressions.  Validation happens
*before* execution against three sources of truth:

  * the **system catalog**   — metadata of external stores      (here: mesh +
    hardware description + parameter collections),
  * the **function catalog** — signatures of registered ops     (here:
    ``OpSignature`` registry),
  * the **variable metadata map** — inferred per-variable types (here:
    ``Plan.types``; populated by :func:`infer_types`).

Types carry *semantic dimension names* (``batch``/``seq``/``embed``/…) in
addition to shape+dtype; these names drive sharding rules, the ``capOn``
data-parallel capability checks (§5.2), and cost-model features (§6).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import math
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Iterable, Mapping, Optional, Sequence

# --------------------------------------------------------------------------
# Types (paper §2.1 — ADIL data types)
# --------------------------------------------------------------------------


class Type:
    """Base class for ADIL-style types."""


@dataclass(frozen=True)
class TensorT(Type):
    """A dense tensor with semantic dimension names.

    ``dims`` plays the role of the paper's per-type metadata (Table 1): it is
    the Relation *schema* / Matrix *row–column map* analogue, and is what the
    planner consults when deciding how an operator may be partitioned.
    """

    shape: tuple
    dtype: str = "float32"
    dims: tuple = ()  # semantic names, len == len(shape) (or () if unknown)

    def __post_init__(self):
        if self.dims and len(self.dims) != len(self.shape):
            raise ValidationError(
                f"dims {self.dims} incompatible with shape {self.shape}"
            )

    @property
    def rank(self) -> int:
        return len(self.shape)

    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= int(s)
        return n

    def bytesize(self) -> int:
        return self.size() * dtype_bytes(self.dtype)

    def dim(self, name: str) -> int:
        """Size of the named dimension (ValidationError if absent)."""
        if name not in self.dims:
            raise ValidationError(f"no dim {name!r} in {self}")
        return int(self.shape[self.dims.index(name)])

    def has_dim(self, name: str) -> bool:
        return name in self.dims

    def __repr__(self):
        inner = ", ".join(
            f"{d}={s}" if d else str(s)
            for d, s in itertools.zip_longest(self.dims, self.shape, fillvalue="")
        )
        return f"TensorT[{self.dtype}]({inner})"


@dataclass(frozen=True)
class ListT(Type):
    """Homogeneous collection (paper: List) — e.g. per-layer or per-topic."""

    elem: Type
    size: int

    def __repr__(self):
        return f"ListT({self.elem!r} x {self.size})"


@dataclass(frozen=True)
class TupleT(Type):
    """Heterogeneous finite collection (paper: Tuple)."""

    elems: tuple

    def __repr__(self):
        return f"TupleT{self.elems!r}"


@dataclass(frozen=True)
class ScalarT(Type):
    dtype: str = "float32"

    def __repr__(self):
        return f"ScalarT[{self.dtype}]"


# -- tri-store data-model types (paper Table 1: Relation / Graph / Text) ----
#
# AWESOME's ADIL is natively aware of its three data models.  The tensor
# reproduction mirrors that: a Table is a struct-of-arrays relation, a Graph
# is CSR adjacency, and a Corpus is a tokenized document set with an
# inverted index.  Each type carries the metadata the planner needs to price
# cross-engine movement (rows / edges / postings -> bytes).


@dataclass(frozen=True)
class TableT(Type):
    """Relational table: named, typed columns over a fixed row *capacity*.

    The runtime value is a :class:`~repro.stores.bounded.BoundedRel` —
    struct-of-JAX-arrays columns plus a ``valid`` vector and a traced row
    ``count`` — so filters narrow validity rather than the physical row
    count and every relational kernel stays static-shaped and jittable.

    ``rows`` is the **capacity** (the static array length; ``capacity`` is
    its explicit alias).  ``expected_count`` is the planner's cardinality
    estimate — how many rows are expected to be *valid* at run time.
    ``None`` means "all of them" (a base table, an unfiltered scan).  The
    cost model prices relational work on the expected count, and the
    ``choose_compaction`` rewrite inserts ``compact`` nodes where the
    expected count sits far below capacity.
    """

    columns: tuple            # ((name, dtype), ...)
    rows: int
    expected_count: Optional[int] = None
    # mesh placement over the data axis: None = single-device / replicated,
    # "row" = row-range sharded.  Part of the repr (hence the plan id) only
    # when set, so unpartitioned plans keep their pre-sharding identity.
    partitioning: Optional[str] = None

    def __post_init__(self):
        names = [c[0] for c in self.columns]
        if len(set(names)) != len(names):
            raise ValidationError(f"duplicate column names in {names}")
        if self.expected_count is not None and self.expected_count > self.rows:
            raise ValidationError(
                f"expected_count {self.expected_count} exceeds "
                f"capacity {self.rows}")

    @property
    def capacity(self) -> int:
        return int(self.rows)

    def expected_rows(self) -> int:
        """The cardinality estimate the cost model prices with: the
        expected valid-row count, defaulting to the full capacity."""
        return int(self.rows if self.expected_count is None
                   else self.expected_count)

    def col_names(self) -> tuple:
        return tuple(c[0] for c in self.columns)

    def has_col(self, name: str) -> bool:
        return name in self.col_names()

    def col_dtype(self, name: str) -> str:
        for n, d in self.columns:
            if n == name:
                return d
        raise ValidationError(f"no column {name!r} in {self}")

    def bytesize(self) -> int:
        per_row = sum(dtype_bytes(d) for _, d in self.columns) + 1  # + valid
        return int(self.rows) * per_row

    def __repr__(self):
        cols = ", ".join(f"{n}:{d}" for n, d in self.columns)
        exp = ("" if self.expected_count is None
               else f", count~{self.expected_count}")
        part = "" if self.partitioning is None else f"; part={self.partitioning}"
        return f"TableT({cols}; capacity={self.rows}{exp}{part})"


@dataclass(frozen=True)
class GraphT(Type):
    """Graph in CSR form: ``nodes`` vertices, ``edges`` directed edges."""

    nodes: int
    edges: int
    weighted: bool = False
    # None = single-device; "block" = CSR row(dst)-block partitioned
    partitioning: Optional[str] = None

    def bytesize(self) -> int:
        # indptr + indices + per-edge src expansion (+ weights) + out-degree
        per_edge = 8 + (4 if self.weighted else 0)
        return (self.nodes + 1) * 4 + int(self.edges) * per_edge + self.nodes * 4

    def __repr__(self):
        w = ", weighted" if self.weighted else ""
        part = "" if self.partitioning is None else f", part={self.partitioning}"
        return f"GraphT(nodes={self.nodes}, edges={self.edges}{w}{part})"


@dataclass(frozen=True)
class CorpusT(Type):
    """Tokenized corpus with an inverted index: ``postings`` = nnz of the
    term-document matrix (what TF-IDF scoring streams over)."""

    docs: int
    vocab: int
    postings: int
    # None = single-device; "doc" = document-range partitioned
    partitioning: Optional[str] = None

    def bytesize(self) -> int:
        # (doc, term, tf) per posting + doc lengths + idf table
        return int(self.postings) * 12 + self.docs * 4 + self.vocab * 4

    def __repr__(self):
        part = "" if self.partitioning is None else f", part={self.partitioning}"
        return (f"CorpusT(docs={self.docs}, vocab={self.vocab}, "
                f"postings={self.postings}{part})")


_DTYPE_BYTES = {
    "float64": 8, "int64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2,
    "int8": 1, "uint8": 1, "bool": 1,
    "float8_e4m3fn": 1, "float8_e5m2": 1,
}


def dtype_bytes(dtype: str) -> int:
    try:
        return _DTYPE_BYTES[str(dtype)]
    except KeyError:
        raise ValidationError(f"unknown dtype {dtype!r}")


class ValidationError(Exception):
    """Raised by compile-time validation (paper design decision 5)."""


# per-comparator selected-fraction heuristics, the single source shared by
# type inference (TableT.expected_count) and the rewrite layer's
# estimate_selectivity — both halves of the planner must reason from the
# same cardinalities (an explicit ``selectivity=`` attr wins over these)
CMP_SELECTIVITY = {"eq": 0.1, "ne": 0.9,
                   "lt": 1 / 3, "le": 1 / 3, "gt": 1 / 3, "ge": 1 / 3}


# --------------------------------------------------------------------------
# Logical operators and plans (paper §4)
# --------------------------------------------------------------------------


@dataclass
class Node:
    """One logical operator in the plan DAG.

    ``subplan`` holds the sub-operator of a higher-order node (the paper's
    Map/Filter consume a sub-plan via the dashed "sub-operator" edge in
    Fig. 4); for us the main higher-order node is ``scan_layers``.
    """

    id: str
    op: str
    inputs: tuple = ()           # ids of producer nodes
    attrs: dict = field(default_factory=dict)
    subplan: Optional["Plan"] = None

    def signature_key(self):
        """Hashable identity used by redundancy elimination (§4.2.2)."""
        items = tuple(sorted((k, _freeze(v)) for k, v in self.attrs.items()))
        sub = self.subplan.structure_key() if self.subplan is not None else None
        return (self.op, self.inputs, items, sub)


def _freeze(v):
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, set):
        return tuple(sorted(_freeze(x) for x in v))
    if callable(v):
        return getattr(v, "__name__", repr(v))
    return v


@dataclass
class Plan:
    """A logical plan: DAG of nodes, in topological insertion order."""

    name: str = "plan"
    nodes: dict = field(default_factory=dict)       # id -> Node
    inputs: dict = field(default_factory=dict)      # id -> Type   (plan inputs)
    outputs: tuple = ()                              # output node ids
    types: dict = field(default_factory=dict)       # id -> Type   (metadata map)
    _ctr: int = 0

    # -- construction ------------------------------------------------------
    def _bump(self):
        """Structural-revision counter (non-field attr): invalidates the
        memoized content fingerprint (see :func:`plan_fingerprint`)."""
        self.__dict__["_rev"] = self.__dict__.get("_rev", 0) + 1

    def _rev_key(self):
        subs = tuple(n.subplan._rev_key() for n in self.nodes.values()
                     if n.subplan is not None)
        return (self.__dict__.get("_rev", 0), subs)

    def add_input(self, name: str, typ: Type) -> str:
        if name in self.nodes or name in self.inputs:
            raise ValidationError(f"duplicate input {name!r}")
        self.inputs[name] = typ
        self.types[name] = typ
        self._bump()
        return name

    def add(self, op: str, inputs: Sequence[str] = (), attrs: dict | None = None,
            subplan: Optional["Plan"] = None, id: str | None = None) -> str:
        nid = id or f"{op}_{self._ctr}"
        self._ctr += 1
        if nid in self.nodes:
            raise ValidationError(f"duplicate node id {nid!r}")
        for i in inputs:
            if i not in self.nodes and i not in self.inputs:
                raise ValidationError(f"node {nid!r}: unknown input {i!r}")
        self.nodes[nid] = Node(nid, op, tuple(inputs), dict(attrs or {}), subplan)
        self._bump()
        return nid

    def set_outputs(self, *ids: str):
        for i in ids:
            if i not in self.nodes and i not in self.inputs:
                raise ValidationError(f"unknown output {i!r}")
        self.outputs = tuple(ids)
        self._bump()

    # -- views -------------------------------------------------------------
    def topo(self) -> Iterable[Node]:
        """Nodes in topological order (insertion order is topological)."""
        return list(self.nodes.values())

    def consumers(self) -> dict:
        out: dict = {i: [] for i in list(self.inputs) + list(self.nodes)}
        for n in self.nodes.values():
            for i in n.inputs:
                out[i].append(n.id)
        return out

    def type_of(self, nid: str) -> Type:
        if nid not in self.types:
            raise ValidationError(f"type of {nid!r} not inferred yet")
        return self.types[nid]

    def structure_key(self):
        return tuple(n.signature_key() for n in self.topo()) + (self.outputs,)

    def copy(self) -> "Plan":
        p = Plan(self.name, {}, dict(self.inputs), self.outputs,
                 dict(self.types), self._ctr)
        p.nodes = {k: Node(v.id, v.op, v.inputs, dict(v.attrs),
                           v.subplan.copy() if v.subplan else None)
                   for k, v in self.nodes.items()}
        return p

    def __len__(self):
        return len(self.nodes)


def count_nodes(plan) -> int:
    """Total node count, recursing into higher-order subplans.  Duck-typed:
    works on both logical Plans and physical PhysPlans (same topo()/subplan
    shape).  Used by the rewrite trace and the pipeline EXPLAIN deltas."""
    if plan is None:
        return 0
    n = len(plan.nodes)
    for node in plan.topo():
        if node.subplan is not None:
            n += count_nodes(node.subplan)
    return n


# --------------------------------------------------------------------------
# Canonical serialization + content hashing (plan identity)
# --------------------------------------------------------------------------
#
# A logical plan's identity is *structural*: node ids are replaced by
# topological position so the textual ADIL front end and the embedded
# builder hash identically, and attrs are frozen into a deterministic
# nested-tuple form.  ``plan_id`` additionally covers the function-catalog
# signature and the system-catalog fingerprint, so the same workload
# compiled against a different op library or mesh gets a different id —
# this is what keys the plan cache (see ``core/plan_cache.py``).


def _const_bytes(c) -> bytes:
    """Process-stable bytes for one code const: nested code objects recurse
    (their ``repr`` embeds a memory address), frozensets sort (literal
    ``in {...}`` membership sets iterate in PYTHONHASHSEED order), tuples
    recurse element-wise."""
    if hasattr(c, "co_code"):
        return _code_bytes(c)
    if isinstance(c, frozenset):
        return b"fs{" + b",".join(sorted(_const_bytes(x) for x in c)) + b"}"
    if isinstance(c, tuple):
        return b"t(" + b",".join(_const_bytes(x) for x in c) + b")"
    return repr(c).encode()


def _code_bytes(code) -> bytes:
    """Process-stable byte representation of a code object: bytecode plus
    canonicalized consts.  Anything repr-unstable across processes (nested
    code objects' addresses, frozenset iteration order) would make plan ids
    differ between runs and defeat the persisted plan cache."""
    return b"\x00".join([code.co_code] +
                        [_const_bytes(c) for c in code.co_consts])


def _canon(v):
    """Deterministic, hash-stable form of an attr value."""
    if isinstance(v, dict):
        return ("dict", tuple(sorted((str(k), _canon(x)) for k, x in v.items())))
    if isinstance(v, (list, tuple)):
        return ("seq", tuple(_canon(x) for x in v))
    if isinstance(v, set):
        return ("set", tuple(sorted(repr(_canon(x)) for x in v)))
    if hasattr(v, "shape") and hasattr(v, "dtype"):
        # ndarray-like (e.g. a const node's value): repr truncates large
        # arrays, so hash the bytes instead
        import numpy as _np
        a = _np.asarray(v)
        return ("array", str(a.dtype), tuple(a.shape),
                hashlib.sha256(a.tobytes()).hexdigest())
    if callable(v):
        # name alone is ambiguous for lambdas; mix in the bytecode, the
        # closure-captured values, and the default args so two different
        # predicates never collide to one cache entry
        code = getattr(v, "__code__", None)
        tag = getattr(v, "__qualname__", getattr(v, "__name__", repr(v)))
        if code is not None:
            h = hashlib.sha256(_code_bytes(code))
            captured = []
            try:
                for cell in (getattr(v, "__closure__", None) or ()):
                    try:
                        captured.append(_canon(cell.cell_contents))
                    except ValueError:       # empty cell
                        captured.append(("cell", "<empty>"))
                for d in (getattr(v, "__defaults__", None) or ()):
                    captured.append(_canon(d))
            except RecursionError:           # self-referential closure
                captured.append(("cell", "<recursive>"))
            return ("fn", tag, h.hexdigest()[:16], tuple(captured))
        return ("fn", tag)
    if isinstance(v, Type):
        return ("type", repr(v))
    return (type(v).__name__, repr(v))


def canonicalize_plan(plan: "Plan") -> tuple:
    """Structural canonical form of a logical plan.

    Node ids are replaced by topological index, plan inputs keep their names
    (they are the call-time binding keys) plus their declared types, and
    subplans recurse.  Two plans built through different front ends (textual
    ADIL vs the embedded builder) canonicalize identically iff they describe
    the same workload.
    """
    index: dict = {}
    for i, name in enumerate(plan.inputs):
        index[name] = ("in", i)
    for i, n in enumerate(plan.topo()):
        index[n.id] = ("n", i)
    nodes = tuple(
        (n.op,
         tuple(index[i] for i in n.inputs),
         tuple(sorted((str(k), _canon(v)) for k, v in n.attrs.items())),
         canonicalize_plan(n.subplan) if n.subplan is not None else None)
        for n in plan.topo())
    ins = tuple((name, repr(t)) for name, t in plan.inputs.items())
    outs = tuple(index[o] for o in plan.outputs)
    return ("plan", ins, nodes, outs)


def plan_fingerprint(plan: "Plan") -> str:
    """sha256 over the canonical structural form of a logical plan.

    Memoized on the plan's (recursive) structural-revision counter so a
    second compile of the same plan object pays only a cache lookup.  The
    counter tracks construction through ``add``/``add_input``/
    ``set_outputs``; callers that mutate node attrs *in place after* a first
    hash must re-create the plan (every rewrite pass already does)."""
    key = plan._rev_key()
    cached = plan.__dict__.get("_fp_cache")
    if cached is not None and cached[0] == key:
        return cached[1]
    fp = hashlib.sha256(repr(canonicalize_plan(plan)).encode()).hexdigest()
    plan.__dict__["_fp_cache"] = (key, fp)
    return fp


def plan_id(plan: "Plan", catalog: "FunctionCatalog",
            syscat: "SystemCatalog", extra: tuple = ()) -> str:
    """Stable content hash identifying one planning problem.

    Covers plan structure, the function-catalog signature, the system-catalog
    fingerprint, and any ``extra`` planning options (engines, rewrite
    pipeline, …).  Every compile of the same workload against the same
    catalogs gets the same id — the plan cache key.
    """
    payload = repr((plan_fingerprint(plan), catalog.signature(),
                    syscat.fingerprint(), _canon(extra)))
    return hashlib.sha256(payload.encode()).hexdigest()


def subdag_fingerprints(plan, *, leaf_keys=None, salt: str = "") -> dict:
    """Per-node content hashes of each node's **transitive sub-DAG**.

    Returns ``{ref: sha256 hex}`` for every node id *and* every plan input
    of ``plan``.  A node's hash covers its op/impl, canonicalized attrs
    (same ``_canon`` as ``plan_id``), its inputs' hashes in positional
    order, and its subplan (recursively) — so two nodes hash identically
    iff the entire computations rooted at them are identical.  Node *ids*
    never enter the hash: two textually different programs that share a
    subtree share its fingerprint.

    Duck-typed over logical :class:`Plan` and physical ``PhysPlan`` (both
    expose ``topo()`` / ``nodes`` / ``inputs``; logical nodes carry ``op``,
    physical nodes ``impl``).

    ``leaf_keys``: optional ``{input name: key string}`` binding plan
    inputs to runtime identities (store versions, argument content hashes).
    Unbound inputs fall back to their declared type — the *structural*
    fingerprint, stable across processes but blind to data.  With every
    reachable input bound, the hash identifies the sub-DAG's **value**:
    the key the cross-query subplan cache (``core/mqo.py``) shares
    materialized intermediates under.

    ``salt``: extra identity material folded into every hash (cost-model /
    feedback fingerprints) so re-calibration provably misses the cache.
    """
    lk = leaf_keys or {}
    fps: dict = {}

    def fp_of(ref):
        hit = fps.get(ref)
        if hit is not None:
            return hit
        n = plan.nodes.get(ref)
        if n is None:                    # a plan input leaf
            key = lk.get(ref)
            if key is None:
                key = "type:" + repr(plan.inputs.get(ref))
            payload = ("leaf", salt, str(key))
        else:
            op = getattr(n, "op", None) or getattr(n, "impl", "?")
            ins = tuple(fp_of(i) for i in n.inputs)
            attrs = tuple(sorted((str(k), _canon(v))
                                 for k, v in n.attrs.items()))
            sub = None
            if n.subplan is not None:
                sub = tuple(sorted(subdag_fingerprints(
                    n.subplan, salt=salt).items()))
            payload = ("node", salt, op, attrs, ins, sub)
        h = hashlib.sha256(repr(payload).encode()).hexdigest()
        fps[ref] = h
        return h

    for name in plan.inputs:
        fp_of(name)
    for n in plan.topo():                # topo order keeps recursion shallow
        fp_of(n.id)
    return fps


# --------------------------------------------------------------------------
# Function catalog (paper §3.1.2)
# --------------------------------------------------------------------------


@dataclass
class OpSignature:
    """Registered operator: arity/attr validation + output-type inference.

    ``infer``     : (input_types, attrs) -> Type         (raises ValidationError)
    ``n_inputs``  : exact arity, or (min, max) tuple, or None (any)
    ``engine``    : the named engine this op logically executes on (the
                    tri-store's per-op engine attribution — "rel"/"graph"/
                    "text" for store ops, "xla" for tensor ops).  The
                    ``place_xfers`` rewrite consults it to insert cross-
                    engine transfer nodes at engine boundaries.
    """

    name: str
    infer: Callable
    n_inputs: Any = None
    required_attrs: tuple = ()
    doc: str = ""
    engine: str = "xla"


class FunctionCatalog:
    def __init__(self):
        self._sigs: dict = {}
        self._sig_cache: Optional[str] = None

    def register(self, sig: OpSignature):
        if sig.name in self._sigs:
            raise ValidationError(f"op {sig.name!r} already registered")
        self._sigs[sig.name] = sig
        self._sig_cache = None

    def op(self, name: str, n_inputs=None, required_attrs=(), doc="",
           engine="xla"):
        """Decorator form: ``@catalog.op("matmul", n_inputs=2)``."""

        def deco(fn):
            self.register(OpSignature(name, fn, n_inputs, tuple(required_attrs),
                                      doc, engine))
            return fn

        return deco

    def get(self, name: str) -> OpSignature:
        if name not in self._sigs:
            raise ValidationError(f"unknown op {name!r} (function catalog)")
        return self._sigs[name]

    def __contains__(self, name: str):
        return name in self._sigs

    def names(self):
        return sorted(self._sigs)

    def signature(self) -> str:
        """Content hash of the registered-op surface (names, arities,
        required attrs).  Part of ``plan_id``: the same workload against a
        different op library is a different planning problem.  Memoized,
        invalidated by ``register``."""
        if self._sig_cache is None:
            rows = tuple((name, repr(s.n_inputs), s.required_attrs, s.engine)
                         for name, s in sorted(self._sigs.items()))
            self._sig_cache = hashlib.sha256(repr(rows).encode()).hexdigest()
        return self._sig_cache


# --------------------------------------------------------------------------
# Validation + metadata inference (paper §3)
# --------------------------------------------------------------------------


def infer_types(plan: Plan, catalog: FunctionCatalog) -> Plan:
    """Validate the plan and populate its variable-metadata map.

    Mirrors §3: every statement is validated against the function catalog and
    the already-inferred variable metadata; inference proceeds innermost-first
    for higher-order nodes (their ``subplan`` is inferred before the node's
    own output type).
    """
    plan.types = dict(plan.inputs)
    for node in plan.topo():
        sig = catalog.get(node.op)
        # arity check
        if sig.n_inputs is not None:
            lo, hi = (sig.n_inputs, sig.n_inputs) if isinstance(sig.n_inputs, int) \
                else sig.n_inputs
            if not (lo <= len(node.inputs) <= hi):
                raise ValidationError(
                    f"{node.id}: op {node.op!r} expects {sig.n_inputs} inputs, "
                    f"got {len(node.inputs)}")
        for a in sig.required_attrs:
            if a not in node.attrs:
                raise ValidationError(f"{node.id}: missing attr {a!r}")
        in_types = [plan.types[i] for i in node.inputs]
        # innermost-first for higher-order nodes (§3.1.4)
        if node.subplan is not None:
            infer_types(node.subplan, catalog)
        try:
            out = sig.infer(in_types, dict(node.attrs), node.subplan)
        except ValidationError:
            raise
        except Exception as e:  # surface inference bugs as validation errors
            raise ValidationError(f"{node.id} ({node.op}): {e}") from e
        plan.types[node.id] = out
    for o in plan.outputs:
        if o not in plan.types:
            raise ValidationError(f"output {o!r} has no type")
    return plan


# --------------------------------------------------------------------------
# Shared inference helpers used by the standard catalog
# --------------------------------------------------------------------------


def expect_tensor(t: Type, what: str = "input") -> TensorT:
    if not isinstance(t, TensorT):
        raise ValidationError(f"{what}: expected TensorT, got {t!r}")
    return t


def expect_table(t: Type, what: str = "input") -> "TableT":
    if not isinstance(t, TableT):
        raise ValidationError(f"{what}: expected TableT, got {t!r}")
    return t


def expect_graph(t: Type, what: str = "input") -> "GraphT":
    if not isinstance(t, GraphT):
        raise ValidationError(f"{what}: expected GraphT, got {t!r}")
    return t


def expect_corpus(t: Type, what: str = "input") -> "CorpusT":
    if not isinstance(t, CorpusT):
        raise ValidationError(f"{what}: expected CorpusT, got {t!r}")
    return t


def promote_dtype(a: str, b: str) -> str:
    order = ["bool", "int8", "int16", "int32", "int64",
             "bfloat16", "float16", "float32", "float64"]
    ia, ib = order.index(str(a)), order.index(str(b))
    return order[max(ia, ib)]


def standard_catalog() -> FunctionCatalog:
    """The registered-op library (paper Table 2 analogue for the tensor world)."""
    cat = FunctionCatalog()

    @cat.op("const", n_inputs=0, required_attrs=("type",))
    def _const(ins, attrs, sub):
        return attrs["type"]

    @cat.op("embed", n_inputs=1, required_attrs=("vocab", "embed"))
    def _embed(ins, attrs, sub):
        t = expect_tensor(ins[0], "embed ids")
        if not str(t.dtype).startswith("int"):
            raise ValidationError(f"embed: ids must be integer, got {t.dtype}")
        return TensorT(t.shape + (attrs["embed"],),
                       attrs.get("dtype", "bfloat16"), t.dims + ("embed",))

    @cat.op("rmsnorm", n_inputs=1)
    def _rmsnorm(ins, attrs, sub):
        return expect_tensor(ins[0])

    @cat.op("residual_add", n_inputs=2)
    def _resid(ins, attrs, sub):
        a, b = expect_tensor(ins[0]), expect_tensor(ins[1])
        if a.shape != b.shape:
            raise ValidationError(f"residual_add: {a.shape} vs {b.shape}")
        return replace(a, dtype=promote_dtype(a.dtype, b.dtype))

    @cat.op("attention", n_inputs=1,
            required_attrs=("heads", "kv_heads", "head_dim"))
    def _attention(ins, attrs, sub):
        t = expect_tensor(ins[0])
        if not t.has_dim("seq"):
            raise ValidationError("attention input needs a 'seq' dim")
        return t

    @cat.op("cross_attention", n_inputs=2,
            required_attrs=("heads", "kv_heads", "head_dim"))
    def _xattention(ins, attrs, sub):
        t = expect_tensor(ins[0])
        m = expect_tensor(ins[1], "memory")
        if t.dim("embed") != m.dim("embed"):
            # cross-attn projects from memory width; allow mismatch via attr
            if "memory_embed" not in attrs:
                raise ValidationError("cross_attention: embed mismatch")
        return t

    @cat.op("mlp", n_inputs=1, required_attrs=("ffn",))
    def _mlp(ins, attrs, sub):
        return expect_tensor(ins[0])

    @cat.op("moe", n_inputs=1, required_attrs=("ffn", "experts", "top_k"))
    def _moe(ins, attrs, sub):
        return expect_tensor(ins[0])

    @cat.op("wkv6", n_inputs=1, required_attrs=("heads", "head_dim"))
    def _wkv6(ins, attrs, sub):
        return expect_tensor(ins[0])

    @cat.op("ssd", n_inputs=1, required_attrs=("heads", "head_dim", "state"))
    def _ssd(ins, attrs, sub):
        return expect_tensor(ins[0])

    @cat.op("rwkv_channel_mix", n_inputs=1, required_attrs=("ffn",))
    def _rwkv_cm(ins, attrs, sub):
        return expect_tensor(ins[0])

    @cat.op("unembed", n_inputs=1, required_attrs=("vocab",))
    def _unembed(ins, attrs, sub):
        t = expect_tensor(ins[0])
        if not t.has_dim("embed"):
            raise ValidationError("unembed input needs an 'embed' dim")
        i = t.dims.index("embed")
        shape = t.shape[:i] + (attrs["vocab"],) + t.shape[i + 1:]
        dims = t.dims[:i] + ("vocab",) + t.dims[i + 1:]
        return TensorT(shape, "float32", dims)

    @cat.op("softmax_xent", n_inputs=2)
    def _xent(ins, attrs, sub):
        logits = expect_tensor(ins[0], "logits")
        labels = expect_tensor(ins[1], "labels")
        if logits.shape[:-1] != labels.shape:
            raise ValidationError(
                f"softmax_xent: logits {logits.shape} vs labels {labels.shape}")
        return ScalarT("float32")

    @cat.op("scan_layers", n_inputs=(1, 2), required_attrs=("n_layers",))
    def _scan(ins, attrs, sub):
        # higher-order: validates like the paper's Map — the subplan is typed
        # with the carry as its input; output type == carry type.
        t = expect_tensor(ins[0])
        if sub is None:
            raise ValidationError("scan_layers needs a subplan")
        if len(sub.outputs) != 1:
            raise ValidationError("scan_layers subplan must have 1 output")
        out_t = sub.types.get(sub.outputs[0])
        if out_t is not None and isinstance(out_t, TensorT) and out_t.shape != t.shape:
            raise ValidationError(
                f"scan_layers: carry {t.shape} != subplan out {out_t.shape}")
        if not attrs.get("collect_kv"):
            return t
        # KV-collecting scan (serving prefill): alongside the carry, the
        # per-layer K/V of every ``emit_kv`` attention in the subplan are
        # stacked over layers — TupleT((carry, ((K, V), ...))) — so the
        # serving runtime seeds its KV pool from the planned forward instead
        # of replaying the prompt through decode_step.
        kv_elems = []
        n = attrs["n_layers"]
        b = t.dim("batch") if t.has_dim("batch") else int(t.shape[0])
        s = t.dim("seq") if t.has_dim("seq") else int(t.shape[1])
        for node in sub.topo():
            if node.op in ("attention", "sdpa") and node.attrs.get("emit_kv"):
                kv_t = TensorT(
                    (n, b, s, node.attrs["kv_heads"], node.attrs["head_dim"]),
                    t.dtype,
                    ("layers", "batch", "seq", "kv_heads", "head_dim"))
                kv_elems.append(TupleT((kv_t, kv_t)))
        if not kv_elems:
            raise ValidationError(
                "scan_layers collect_kv=True but the subplan has no "
                "emit_kv attention node")
        return TupleT((t, TupleT(tuple(kv_elems))))

    @cat.op("tuple_get", n_inputs=1, required_attrs=("index",))
    def _tuple_get(ins, attrs, sub):
        tt = ins[0]
        if not isinstance(tt, TupleT):
            raise ValidationError(f"tuple_get input must be TupleT, got {tt!r}")
        i = int(attrs["index"])
        if not 0 <= i < len(tt.elems):
            raise ValidationError(
                f"tuple_get: index {i} out of range for {tt!r}")
        return tt.elems[i]

    @cat.op("map", n_inputs=1)
    def _map(ins, attrs, sub):
        lt = ins[0]
        if not isinstance(lt, ListT):
            raise ValidationError(f"map input must be ListT, got {lt!r}")
        if sub is None or len(sub.outputs) != 1:
            raise ValidationError("map needs a single-output subplan")
        return ListT(sub.types[sub.outputs[0]], lt.size)

    @cat.op("filter", n_inputs=1, required_attrs=("predicate",))
    def _filter(ins, attrs, sub):
        lt = ins[0]
        if not isinstance(lt, ListT):
            raise ValidationError(f"filter input must be ListT, got {lt!r}")
        return lt  # size is an upper bound; paper keeps Size metadata fuzzy here

    @cat.op("reduce", n_inputs=1, required_attrs=("fn",))
    def _reduce(ins, attrs, sub):
        lt = ins[0]
        if not isinstance(lt, ListT):
            raise ValidationError(f"reduce input must be ListT, got {lt!r}")
        return lt.elem

    @cat.op("store", n_inputs=1)
    def _store(ins, attrs, sub):
        return ins[0]

    @cat.op("concat_seq", n_inputs=2)
    def _concat_seq(ins, attrs, sub):
        a, b = expect_tensor(ins[0]), expect_tensor(ins[1])
        if not (a.has_dim("seq") and b.has_dim("seq")):
            raise ValidationError("concat_seq operands need 'seq' dims")
        if a.shape[-1] != b.shape[-1]:
            raise ValidationError(f"concat_seq: {a.shape} vs {b.shape}")
        i = a.dims.index("seq")
        shape = a.shape[:i] + (a.dim("seq") + b.dim("seq"),) + a.shape[i + 1:]
        return TensorT(shape, promote_dtype(a.dtype, b.dtype), a.dims)

    # decomposed primitives (targets of §4.2.1 function decomposition)
    @cat.op("qkv_proj", n_inputs=1, required_attrs=("heads", "kv_heads", "head_dim"))
    def _qkv(ins, attrs, sub):
        t = expect_tensor(ins[0])
        h, k, d = attrs["heads"], attrs["kv_heads"], attrs["head_dim"]
        return TupleT((
            TensorT(t.shape[:-1] + (h, d), t.dtype, t.dims[:-1] + ("heads", "head_dim")),
            TensorT(t.shape[:-1] + (k, d), t.dtype, t.dims[:-1] + ("kv_heads", "head_dim")),
            TensorT(t.shape[:-1] + (k, d), t.dtype, t.dims[:-1] + ("kv_heads", "head_dim")),
        ))

    @cat.op("sdpa", n_inputs=1, required_attrs=("heads", "kv_heads", "head_dim"))
    def _sdpa(ins, attrs, sub):
        tt = ins[0]
        if not isinstance(tt, TupleT) or len(tt.elems) != 3:
            raise ValidationError("sdpa expects (q, k, v) TupleT")
        return tt.elems[0]

    @cat.op("out_proj", n_inputs=1, required_attrs=("embed",))
    def _outp(ins, attrs, sub):
        t = expect_tensor(ins[0])
        return TensorT(t.shape[:-2] + (attrs["embed"],), t.dtype,
                       t.dims[:-2] + ("embed",))

    def _head_proj(kind):
        def infer(ins, attrs, sub):
            t = expect_tensor(ins[0])
            h = attrs["heads"] if kind == "q" else attrs["kv_heads"]
            d = attrs["head_dim"]
            dim = "heads" if kind == "q" else "kv_heads"
            return TensorT(t.shape[:-1] + (h, d), t.dtype,
                           t.dims[:-1] + (dim, "head_dim"))
        return infer

    for _k in ("q", "k", "v"):
        cat.register(OpSignature(f"{_k}_proj", _head_proj(_k), 1,
                                 ("heads", "kv_heads", "head_dim")))

    @cat.op("pack_qkv", n_inputs=3)
    def _pack_qkv(ins, attrs, sub):
        return TupleT(tuple(ins))

    @cat.op("ffn_up", n_inputs=1, required_attrs=("ffn",))
    def _ffn_up(ins, attrs, sub):
        t = expect_tensor(ins[0])
        return TensorT(t.shape[:-1] + (attrs["ffn"],), t.dtype,
                       t.dims[:-1] + ("ffn",))

    @cat.op("ffn_gate", n_inputs=1, required_attrs=("ffn",))
    def _ffn_gate(ins, attrs, sub):
        t = expect_tensor(ins[0])
        return TensorT(t.shape[:-1] + (attrs["ffn"],), t.dtype,
                       t.dims[:-1] + ("ffn",))

    @cat.op("ffn_glu", n_inputs=2)
    def _ffn_glu(ins, attrs, sub):
        a, b = expect_tensor(ins[0]), expect_tensor(ins[1])
        if a.shape != b.shape:
            raise ValidationError(f"ffn_glu: {a.shape} vs {b.shape}")
        return a

    @cat.op("ffn_act", n_inputs=1)
    def _ffn_act(ins, attrs, sub):
        return expect_tensor(ins[0])

    @cat.op("ffn_down", n_inputs=1, required_attrs=("embed",))
    def _ffn_down(ins, attrs, sub):
        t = expect_tensor(ins[0])
        return TensorT(t.shape[:-1] + (attrs["embed"],), t.dtype,
                       t.dims[:-1] + ("embed",))

    # -- tri-store ops (relational / graph / text engines + cross-engine
    #    movement).  Each op declares the engine it logically runs on; the
    #    ``place_xfers`` rewrite turns engine boundaries into explicit
    #    ``xfer`` nodes whose materialization the cost model decides.

    def _expected_after_filter(t: "TableT", attrs) -> Optional[int]:
        sel = attrs.get("selectivity")
        if sel is None:
            sel = CMP_SELECTIVITY.get(attrs.get("cmp"), 0.5)
        base = t.rows if t.expected_count is None else t.expected_count
        return min(int(t.rows), max(1, int(math.ceil(base * float(sel)))))

    @cat.op("rel_scan", n_inputs=1, engine="rel")
    def _rel_scan(ins, attrs, sub):
        t = expect_table(ins[0], "rel_scan")
        cols = attrs.get("cols")
        if not cols:
            return t
        for c in cols:
            if not t.has_col(c):
                raise ValidationError(f"rel_scan: no column {c!r} in {t!r}")
        return TableT(tuple((n, d) for n, d in t.columns if n in tuple(cols)),
                      t.rows, t.expected_count, t.partitioning)

    @cat.op("rel_filter", n_inputs=1, required_attrs=("col", "cmp", "value"),
            engine="rel")
    def _rel_filter(ins, attrs, sub):
        t = expect_table(ins[0], "rel_filter")
        if not t.has_col(attrs["col"]):
            raise ValidationError(
                f"rel_filter: no column {attrs['col']!r} in {t!r}")
        if attrs["cmp"] not in ("eq", "ne", "lt", "le", "gt", "ge"):
            raise ValidationError(f"rel_filter: bad cmp {attrs['cmp']!r}")
        # selection narrows validity, not capacity; the expected count
        # shrinks by the (hinted or heuristic) selectivity
        return replace(t, expected_count=_expected_after_filter(t, attrs))

    @cat.op("compact", n_inputs=1, engine="rel")
    def _compact(ins, attrs, sub):
        """Prefix-compaction: valid rows move, in order, to the front of a
        (usually smaller) capacity.  Capacity narrower than the run-time
        survivor count drops rows and raises the relation's overflow flag."""
        t = expect_table(ins[0], "compact")
        cap = int(attrs.get("capacity", t.rows))
        if cap < 1:
            raise ValidationError(f"compact: capacity={cap} out of range")
        cap = min(cap, t.rows)
        exp = attrs.get("expected_count", t.expected_count)
        exp = None if exp is None else min(int(exp), cap)
        return TableT(t.columns, cap, exp)

    def _join_columns(lt, rt, attrs, what):
        lo, ro = attrs["left_on"], attrs["right_on"]
        if not lt.has_col(lo):
            raise ValidationError(f"{what}: no left column {lo!r}")
        if not rt.has_col(ro):
            raise ValidationError(f"{what}: no right column {ro!r}")
        taken = set(lt.col_names())
        extra = tuple((n, d) for n, d in rt.columns
                      if n != ro and n not in taken)
        return lt.columns + extra

    @cat.op("rel_join", n_inputs=2, required_attrs=("left_on", "right_on"),
            engine="rel")
    def _rel_join(ins, attrs, sub):
        lt = expect_table(ins[0], "rel_join left")
        rt = expect_table(ins[1], "rel_join right")
        # unique-build-key probe: output rows mirror the probe side, so the
        # probe side's expected count (and row partitioning) pass through
        # (joins only narrow)
        return TableT(_join_columns(lt, rt, attrs, "rel_join"), lt.rows,
                      lt.expected_count, lt.partitioning)

    @cat.op("bounded_join", n_inputs=2,
            required_attrs=("left_on", "right_on", "capacity"), engine="rel")
    def _bounded_join(ins, attrs, sub):
        """Equi-join with **non-unique build keys**: every (probe, build)
        key match emits a row into a capacity-bounded output.  Matches
        beyond ``capacity`` are dropped with the overflow flag raised."""
        lt = expect_table(ins[0], "bounded_join left")
        rt = expect_table(ins[1], "bounded_join right")
        cap = int(attrs["capacity"])
        if cap < 1:
            raise ValidationError(f"bounded_join: capacity={cap} "
                                  f"out of range")
        exp = attrs.get("expected_count")
        exp = None if exp is None else min(int(exp), cap)
        return TableT(_join_columns(lt, rt, attrs, "bounded_join"), cap, exp)

    @cat.op("rel_group_agg", n_inputs=1,
            required_attrs=("key", "num_groups", "aggs"), engine="rel")
    def _rel_group_agg(ins, attrs, sub):
        t = expect_table(ins[0], "rel_group_agg")
        if not t.has_col(attrs["key"]):
            raise ValidationError(
                f"rel_group_agg: no key column {attrs['key']!r}")
        key_dt = str(t.col_dtype(attrs["key"]))
        if not (key_dt.startswith("int") or key_dt.startswith("uint")):
            raise ValidationError(
                f"rel_group_agg: key column {attrs['key']!r} must be "
                f"integer (group ids), got {key_dt}")
        cols = [(attrs["key"], "int32")]
        for out_name, fn, col in attrs["aggs"]:
            if fn not in ("sum", "count", "mean", "max"):
                raise ValidationError(f"rel_group_agg: bad agg fn {fn!r}")
            if fn != "count" and not t.has_col(col):
                raise ValidationError(f"rel_group_agg: no column {col!r}")
            cols.append((out_name, "float32"))
        groups = int(attrs["num_groups"])
        # at most one valid output row per occupied group: the expected
        # input count upper-bounds the occupied-group count
        exp = (None if t.expected_count is None
               else min(groups, int(t.expected_count)))
        return TableT(tuple(cols), groups, exp)

    @cat.op("col_tensor", n_inputs=1, required_attrs=("col",), engine="rel")
    def _col_tensor(ins, attrs, sub):
        t = expect_table(ins[0], "col_tensor")
        if not t.has_col(attrs["col"]):
            raise ValidationError(f"col_tensor: no column {attrs['col']!r}")
        dim = attrs.get("dim", "rows")
        return TensorT((t.rows,), attrs.get("dtype", "float32"), (dim,))

    @cat.op("graph_expand", n_inputs=2, engine="graph")
    def _graph_expand(ins, attrs, sub):
        g = expect_graph(ins[0], "graph_expand")
        f = expect_tensor(ins[1], "graph_expand frontier")
        if f.shape != (g.nodes,):
            raise ValidationError(
                f"graph_expand: frontier {f.shape} vs nodes {g.nodes}")
        return TensorT((g.nodes,), "float32", ("nodes",))

    @cat.op("graph_pagerank", n_inputs=(1, 2), engine="graph")
    def _graph_pagerank(ins, attrs, sub):
        g = expect_graph(ins[0], "graph_pagerank")
        if len(ins) == 2:
            p = expect_tensor(ins[1], "graph_pagerank personalization")
            if p.shape != (g.nodes,):
                raise ValidationError(
                    f"graph_pagerank: personalization {p.shape} vs "
                    f"nodes {g.nodes}")
        return TensorT((g.nodes,), "float32", ("nodes",))

    @cat.op("graph_tricount", n_inputs=1, engine="graph")
    def _graph_tricount(ins, attrs, sub):
        expect_graph(ins[0], "graph_tricount")
        return ScalarT("float32")

    @cat.op("text_topk", n_inputs=(2, 3), required_attrs=("k",), engine="text")
    def _text_topk(ins, attrs, sub):
        c = expect_corpus(ins[0], "text_topk")
        q = expect_tensor(ins[1], "text_topk query")
        if q.shape != (c.vocab,):
            raise ValidationError(
                f"text_topk: query {q.shape} vs vocab {c.vocab}")
        if len(ins) == 3:
            # candidate-doc mask (predicate pushdown): score only unmasked
            # docs; rows beyond the unmasked count come back mask=False
            m = expect_tensor(ins[2], "text_topk doc mask")
            if m.shape != (c.docs,) or str(m.dtype) != "bool":
                raise ValidationError(
                    f"text_topk: doc mask must be bool ({c.docs},), got {m!r}")
        k = int(attrs["k"])
        if k < 1:
            raise ValidationError(f"text_topk: k={k} out of range")
        # k is clamped to the document count (the true result size); rows
        # whose score slot is unfilled (k > unmasked count under a pushed
        # mask) are masked out at run time rather than over-reported here
        return TableT((("doc", "int32"), ("score", "float32")),
                      min(k, c.docs))

    @cat.op("text_scores", n_inputs=2, engine="text")
    def _text_scores(ins, attrs, sub):
        c = expect_corpus(ins[0], "text_scores")
        q = expect_tensor(ins[1], "text_scores query")
        if q.shape != (c.vocab,):
            raise ValidationError(
                f"text_scores: query {q.shape} vs vocab {c.vocab}")
        return TensorT((c.docs,), "float32", ("docs",))

    @cat.op("masked_topk", n_inputs=2, required_attrs=("k",))
    def _masked_topk(ins, attrs, sub):
        s = expect_tensor(ins[0], "masked_topk scores")
        m = expect_tensor(ins[1], "masked_topk mask")
        if s.rank != 1 or m.shape != s.shape:
            raise ValidationError(
                f"masked_topk: scores {s!r} vs mask {m!r}")
        if str(m.dtype) != "bool":
            raise ValidationError(f"masked_topk: mask must be bool, got {m!r}")
        k = int(attrs["k"])
        if k < 1:
            raise ValidationError(f"masked_topk: k={k} out of range")
        return TableT((("doc", "int32"), ("score", "float32")),
                      min(k, int(s.shape[0])))

    @cat.op("sel_mask", n_inputs=1, required_attrs=("col", "size"),
            engine="rel")
    def _sel_mask(ins, attrs, sub):
        """Selection-mask export: the relation's mask scattered over an
        entity domain (``mask[v] = any selected row with col == v``) — the
        boolean that predicate pushdown carries into the other engines."""
        t = expect_table(ins[0], "sel_mask")
        if not t.has_col(attrs["col"]):
            raise ValidationError(f"sel_mask: no column {attrs['col']!r}")
        dt = str(t.col_dtype(attrs["col"]))
        if not (dt.startswith("int") or dt.startswith("uint")):
            raise ValidationError(
                f"sel_mask: column {attrs['col']!r} must be integer "
                f"(entity ids), got {dt}")
        return TensorT((int(attrs["size"]),), "bool",
                       (attrs.get("dim", "docs"),))

    @cat.op("rel_fused", n_inputs=(1, 8), required_attrs=("chain",),
            engine="rel")
    def _rel_fused(ins, attrs, sub):
        """Fused same-engine chain (the ``fuse_store_ops`` product): each
        step is (op, attrs, srcs, out_type) where srcs name either "prev"
        (the previous step's output) or an integer input position."""
        prev = None
        for op, step_attrs, srcs, _out in attrs["chain"]:
            step_ins = []
            for s in srcs:
                if s == "prev":
                    if prev is None:
                        raise ValidationError("rel_fused: 'prev' in 1st step")
                    step_ins.append(prev)
                else:
                    step_ins.append(ins[int(s)])
            prev = cat.get(op).infer(step_ins, dict(step_attrs), None)
        return prev

    @cat.op("xfer", n_inputs=1)
    def _xfer(ins, attrs, sub):
        return ins[0]  # pure movement: the value is unchanged

    return cat


# --------------------------------------------------------------------------
# System catalog (paper §2.2): hardware + mesh description
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareSpec:
    """Per-chip peaks for the target part (defaults: TPU v5e)."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12       # bf16 FLOP/s
    hbm_bw: float = 819e9            # bytes/s
    ici_bw: float = 50e9             # bytes/s per link
    hbm_bytes: float = 16e9
    vmem_bytes: float = 128 * 2 ** 20


@dataclass(frozen=True)
class SystemCatalog:
    """Registered 'stores' — here the mesh axes + hardware description."""

    hardware: HardwareSpec = HardwareSpec()
    mesh_axes: tuple = ("data", "model")
    mesh_shape: tuple = (1, 1)

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.mesh_shape:
            n *= s
        return n

    def axis_size(self, name: str) -> int:
        if name not in self.mesh_axes:
            return 1
        return self.mesh_shape[self.mesh_axes.index(name)]

    def fingerprint(self) -> str:
        """Content hash of the store metadata (hardware peaks + mesh).  Part
        of ``plan_id``: a syscat change invalidates cached plans because the
        cost model's roofline features depend on it."""
        return hashlib.sha256(repr(
            (self.hardware, self.mesh_axes, self.mesh_shape)).encode()
        ).hexdigest()
