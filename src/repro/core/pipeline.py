"""Staged plan pipeline: a pass manager over the AWESOME planning stages.

The paper's optimizer (§4–§6, Algorithm 1) is a *staged* pipeline: rewrite
the validated logical DAG, generate engine-specific physical candidates,
pick winners with the learned cost model, then apply data parallelism and
buffering.  This module makes each stage a registered, individually-timed
**pipeline pass** over a shared :class:`PipelineContext`, and makes the
product a :class:`StagedPhysicalPlan` with a stable content-hashed
``plan_id`` — the unit the plan cache stores and the executor binds to a
runtime context (mesh / sharding rules / interpret mode).

Default pass order (Algorithm 1):

    rewrite -> generate_candidates -> select_candidates ->
    materialize_choice -> add_data_parallelism -> plan_buffering

Passes are looked up by name in :data:`PASS_REGISTRY`, so a custom pipeline
can drop, reorder, or add passes (``PlanPipeline(passes=(...,))``), and the
accumulated :class:`PassRecord` trace renders as an EXPLAIN-style report
(per-pass wall time, node-count deltas, candidate choices).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

from .buffering import BufferingDecision, plan_buffering
from .cost_model import CostModel, select_candidates
from .engines import resolve_engines
from .ir import (FunctionCatalog, Plan, SystemCatalog, ValidationError,
                 count_nodes)
from .ir import plan_id as compute_plan_id
from .parallel import add_data_parallelism, partition_stats
from .physical import (DEFAULT_PATTERNS, PhysPlan, generate_candidates,
                       materialize_choice)
from .plan_cache import PlanCache, default_plan_cache
from .rewrite import DEFAULT_PIPELINE as DEFAULT_REWRITES
from .rewrite import rewrite_with_trace


# --------------------------------------------------------------------------
# planning options (the plan-identity-relevant knobs)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class PlanOptions:
    """Everything that changes *what plan comes out* for a given logical
    plan + catalogs.  Hashed into ``plan_id``; runtime-only bindings (mesh
    object, sharding rules, interpret mode) deliberately live outside.

    ``plan_threads`` parallelizes candidate generation per scan-group; it
    changes only planning wall time, never the chosen plan, so it is
    deliberately **excluded** from ``cache_key``."""

    engines: tuple = ("xla",)
    data_parallel: bool = True
    buffering: bool = False
    global_batch: int = 1
    rewrite_pipeline: tuple = DEFAULT_REWRITES
    plan_threads: int = 1

    def cache_key(self) -> tuple:
        return ("opts", tuple(self.engines), self.data_parallel,
                self.buffering, self.global_batch,
                tuple(self.rewrite_pipeline))


# --------------------------------------------------------------------------
# pass registry + shared context
# --------------------------------------------------------------------------


@dataclass
class PassRecord:
    """One EXPLAIN row: what a pass did and what it cost."""

    name: str
    wall_ms: float
    nodes_before: int
    nodes_after: int
    info: dict = field(default_factory=dict)


@dataclass
class PipelineContext:
    """State threaded through the passes; accumulates the EXPLAIN trace."""

    catalog: FunctionCatalog
    syscat: SystemCatalog
    options: PlanOptions
    logical: Plan
    cost_model: Optional[CostModel] = None
    patterns: tuple = DEFAULT_PATTERNS
    # produced by passes
    logical_opt: Optional[Plan] = None
    pplan: Optional[PhysPlan] = None
    choices: Optional[dict] = None
    report: Optional[list] = None
    concrete: Optional[PhysPlan] = None
    buffering: Optional[BufferingDecision] = None
    trace: list = field(default_factory=list)

    def artifact(self):
        """The most-evolved plan artifact so far (for node-count deltas)."""
        for p in (self.concrete, self.pplan, self.logical_opt, self.logical):
            if p is not None:
                return p
        return None


PASS_REGISTRY: dict = {}


def pipeline_pass(name: str):
    """Register a pass: ``fn(ctx) -> info dict`` under a stable name."""
    def deco(fn):
        PASS_REGISTRY[name] = fn
        return fn
    return deco


# --------------------------------------------------------------------------
# the six Algorithm-1 stages as passes
# --------------------------------------------------------------------------


@pipeline_pass("rewrite")
def _pass_rewrite(ctx: PipelineContext) -> dict:
    ctx.logical_opt, rules = rewrite_with_trace(
        ctx.logical, ctx.catalog, ctx.options.rewrite_pipeline,
        syscat=ctx.syscat)
    return {"rules": rules}


@pipeline_pass("generate_candidates")
def _pass_generate(ctx: PipelineContext) -> dict:
    from .engines import get_engine
    ctx.pplan = generate_candidates(ctx.logical_opt, ctx.patterns,
                                    engines=ctx.options.engines,
                                    threads=ctx.options.plan_threads)

    def stats(pp):
        nv, nc = len(pp.pm), sum(len(c) for c in pp.pm.values())
        for n in pp.topo():
            if n.subplan is not None:
                sv, sc = stats(n.subplan)
                nv, nc = nv + sv, nc + sc
        return nv, nc

    nv, nc = stats(ctx.pplan)
    return {"virtual_nodes": nv, "candidates": nc,
            "engines": list(ctx.options.engines),
            # per-engine availability gate (Engine.is_available), surfaced
            # in the EXPLAIN report so an operator can see *why* a
            # hardware-gated engine's candidates were not offered
            "engine_availability": {
                e: get_engine(e).available() for e in ctx.options.engines}}


@pipeline_pass("select_candidates")
def _pass_select(ctx: PipelineContext) -> dict:
    ctx.choices, ctx.report = select_candidates(
        ctx.pplan, ctx.syscat, ctx.cost_model, engines=ctx.options.engines)
    return {"choices": [(r["pattern"], r["chosen"]) for r in ctx.report]}


@pipeline_pass("materialize_choice")
def _pass_materialize(ctx: PipelineContext) -> dict:
    ctx.concrete = materialize_choice(ctx.pplan, ctx.choices)
    return {}


@pipeline_pass("add_data_parallelism")
def _pass_data_parallel(ctx: PipelineContext) -> dict:
    if not ctx.options.data_parallel:
        return {"skipped": True}
    ctx.concrete = add_data_parallelism(ctx.concrete)
    return partition_stats(ctx.concrete)


@pipeline_pass("plan_buffering")
def _pass_buffering(ctx: PipelineContext) -> dict:
    ctx.buffering = plan_buffering(ctx.concrete,
                                   enabled=ctx.options.buffering,
                                   global_batch=ctx.options.global_batch)
    return {"enabled": ctx.buffering.enabled,
            "microbatches": ctx.buffering.num_microbatches,
            "chains": len(ctx.buffering.chains)}


# --------------------------------------------------------------------------
# the product: a staged physical plan with a stable identity
# --------------------------------------------------------------------------


@dataclass
class StagedPhysicalPlan:
    """Everything the pass pipeline produced for one planning problem.

    Cache-friendly: no runtime bindings (mesh objects, sharding rules); the
    executor's PlannedFunction wraps one of these plus the runtime context.
    Treated as immutable once built.
    """

    plan_id: str
    logical: Plan                  # the optimized (rewritten) logical plan
    pplan: PhysPlan                # with virtual nodes (pre-choice)
    concrete: PhysPlan             # chosen + data-parallelized
    choices: dict
    report: list
    buffering: BufferingDecision
    trace: list
    options: PlanOptions
    # identity material for the cross-query subplan cache (core/mqo.py):
    # cost-model + feedback fingerprints, folded into every sub-DAG hash so
    # a re-calibrated plan's intermediates provably miss the cache.  Stamped
    # by ``compile_staged``; plans unpickled from an older on-disk cache may
    # lack the attribute — read it with ``getattr(staged, "mqo_salt", "")``.
    mqo_salt: str = ""

    def subdag_fingerprints(self, *, leaf_keys=None, salt=None) -> dict:
        """Per-node sub-DAG content hashes of the **concrete** physical
        plan (see :func:`repro.core.ir.subdag_fingerprints`).  The
        structural variant (no ``leaf_keys``) is memoized — the plan is
        immutable once staged, so one walk serves every query admission."""
        from .ir import subdag_fingerprints as _sfp
        s = getattr(self, "mqo_salt", "") if salt is None else salt
        if leaf_keys is None:
            cached = self.__dict__.get("_subdag_fp_cache")
            if cached is not None and cached[0] == s:
                return cached[1]
            fps = _sfp(self.concrete, salt=s)
            self.__dict__["_subdag_fp_cache"] = (s, fps)
            return fps
        return _sfp(self.concrete, leaf_keys=leaf_keys, salt=s)

    def explain(self, analyze=None) -> str:
        """EXPLAIN-style report: per-pass wall time, node-count deltas, and
        the cost model's candidate choices.  With ``analyze`` (a
        :class:`~repro.core.tracing.RunTrace` from
        ``PlannedFunction.analyze``), an **EXPLAIN ANALYZE** section merges
        the plan-time records with the runtime spans: one
        ``predicted~ / observed=`` row per executed physical node, plus
        observed counts and per-shard collective totals."""
        avail = next((r.info["engine_availability"] for r in self.trace
                      if "engine_availability" in r.info), None)
        eng = ",".join(
            self.options.engines if avail is None else
            (f"{e}[{'up' if avail.get(e, True) else 'DOWN'}]"
             for e in self.options.engines))
        lines = [f"StagedPhysicalPlan {self.plan_id[:12]} (engines={eng})"]
        lines.append(f"  {'pass':<22}{'ms':>9}  {'nodes':<12}info")
        for r in self.trace:
            delta = (f"{r.nodes_before}"
                     if r.nodes_before == r.nodes_after
                     else f"{r.nodes_before} -> {r.nodes_after}")
            info = {k: v for k, v in r.info.items()
                    if k not in ("rules", "engine_availability")}
            lines.append(f"  {r.name:<22}{r.wall_ms:>9.2f}  {delta:<12}"
                         f"{info if info else ''}")
            for rule in r.info.get("rules", ()):
                lines.append(
                    f"    . {rule['rule']:<18}{rule['wall_ms']:>7.2f}  "
                    f"{rule['nodes_before']} -> {rule['nodes_after']}")
                # per-rule detail (pushdown: which ops received masks and
                # the estimated selectivity; fusion: collapsed chains)
                for rewr in rule.get("info", {}).get("pushed", ()):
                    lines.append("        + " + " ".join(
                        f"{k}={v}" for k, v in rewr.items()))
                # bounded relations: where compaction was placed, and the
                # count-vs-capacity reasoning behind it
                for cp in rule.get("info", {}).get("compacted", ()):
                    lines.append(
                        f"        + compact below={cp['filter']} "
                        f"count~{cp['expected']} capacity={cp['capacity']} "
                        f"(rows={cp['rows']}, "
                        f"selectivity={cp['selectivity']})")
                for ch in rule.get("info", {}).get("fused_chains", ()):
                    lines.append(
                        f"        + fused {'->'.join(ch['ops'])} "
                        f"(head={ch['head']})")
                # sharded stores: xfer kinds with priced wire bytes, and
                # the ops the runtime executes shard-locally
                for xf in rule.get("info", {}).get("xfers", ()):
                    lines.append(
                        f"        + xfer {xf['id']} kind={xf['kind']} "
                        f"~{xf['est_bytes']}B")
                for dn in rule.get("info", {}).get("dist", ()):
                    extra = ("" if "build_expected" not in dn else
                             f" build~{dn['build_expected']}")
                    lines.append(
                        f"        + dist {dn['id']} [{dn['op']}] "
                        f"{dn['dist']}{extra}")
        for r in self.report:
            costs = {k: f"{v:.3e}" for k, v in r["costs"].items()}
            lines.append(f"  choice [{r['pattern']}] -> {r['chosen']} "
                         f"({r.get('engine', '?')}) costs={costs}")
        if analyze is not None:
            lines.extend(self._explain_analyze(analyze))
        return "\n".join(lines)

    def _explain_analyze(self, trace) -> list:
        """Render one executed run against this plan: the runtime half of
        the report.  Observed times are per-op *dispatch* ms (the run
        device-syncs once, in the trailing ``device_sync`` span)."""
        lines = [f"  EXPLAIN ANALYZE wall={trace.wall_ms:.2f} ms "
                 f"(sync {trace.sync_ms:.2f} ms, "
                 f"{len(trace.op_spans())} op spans, "
                 f"plan {trace.plan_id[:12]})"]
        for sp in trace.op_spans():
            a = sp.attrs
            pred = a.get("predicted_s")
            pred_s = f"{pred:.3e}s" if pred is not None else "n/a"
            row = (f"  analyze {sp.name:<18} [{a.get('impl', '?')}] "
                   f"predicted~{pred_s} observed={sp.dur_ms:.3f}ms")
            if "count" in a:
                row += f" count={a['count']:.0f}/{a.get('capacity', '?')}"
            if "overflow" in a:
                row += f" overflow={bool(a['overflow'])}"
            if "xfer_kind" in a:
                row += (f" kind={a['xfer_kind']} "
                        f"bytes={a.get('payload_bytes', 0)} "
                        f"wire~{a.get('wire_bytes', 0.0):.0f}B")
            if "dist" in a:
                row += f" dist={a['dist']}"
            if "coll_bytes" in a:
                row += (f" coll={a.get('coll', 'collective')}"
                        f"~{a['coll_bytes']:.0f}B")
            lines.append(row)
        totals = trace.collective_totals()
        if totals:
            lines.append("  collective totals (per shard):")
            for kind in sorted(totals):
                t = totals[kind]
                lines.append(f"    {kind}: {t['ops']} ops, "
                             f"{t['bytes']:.0f} B")
        for site, count, cap in trace.counts:
            lines.append(f"  observed {site}: count={count:.0f}/{cap}")
        return lines

    @property
    def total_ms(self) -> float:
        return sum(r.wall_ms for r in self.trace)


# --------------------------------------------------------------------------
# pass manager
# --------------------------------------------------------------------------


class PlanPipeline:
    """Runs registered passes in order over a PipelineContext."""

    DEFAULT_PASSES = ("rewrite", "generate_candidates", "select_candidates",
                      "materialize_choice", "add_data_parallelism",
                      "plan_buffering")

    def __init__(self, passes: Optional[Sequence[str]] = None):
        self.passes = tuple(passes if passes is not None
                            else self.DEFAULT_PASSES)
        for name in self.passes:
            if name not in PASS_REGISTRY:
                raise ValidationError(
                    f"unknown pipeline pass {name!r} "
                    f"(registered: {sorted(PASS_REGISTRY)})")

    def run(self, logical: Plan, catalog: FunctionCatalog,
            syscat: SystemCatalog, *, options: Optional[PlanOptions] = None,
            cost_model: Optional[CostModel] = None,
            patterns=DEFAULT_PATTERNS,
            plan_id: Optional[str] = None) -> StagedPhysicalPlan:
        opts = options or PlanOptions()
        pid = plan_id or staged_plan_id(logical, catalog, syscat, opts,
                                        cost_model, patterns, self.passes)
        ctx = PipelineContext(catalog, syscat, opts, logical,
                              cost_model=cost_model, patterns=patterns)
        for name in self.passes:
            fn = PASS_REGISTRY[name]
            before = count_nodes(ctx.artifact())
            t0 = time.perf_counter()
            info = fn(ctx) or {}
            wall_ms = (time.perf_counter() - t0) * 1e3
            ctx.trace.append(PassRecord(name, wall_ms, before,
                                        count_nodes(ctx.artifact()), info))
        if ctx.concrete is None or ctx.buffering is None:
            raise ValidationError(
                f"pipeline {self.passes} did not produce a concrete plan "
                f"(need materialize_choice and plan_buffering)")
        return StagedPhysicalPlan(pid, ctx.logical_opt, ctx.pplan,
                                  ctx.concrete, ctx.choices, ctx.report or [],
                                  ctx.buffering, ctx.trace, opts)


# --------------------------------------------------------------------------
# cached entry point
# --------------------------------------------------------------------------


_PATTERNS_FP: dict = {}    # id(patterns) -> (patterns ref, fingerprint)


def _patterns_fingerprint(patterns) -> str:
    """Content hash of a physical pattern set.  Memoized by object identity
    (pattern sets are module-level constants) so the cache-hit path does not
    re-canonicalize candidate tables on every compile."""
    import hashlib

    from .ir import _canon
    hit = _PATTERNS_FP.get(id(patterns))
    if hit is not None and hit[0] is patterns:
        return hit[1]
    pats = tuple(
        (p.name, p.seq,
         tuple((c.name, c.impls, c.requires_backend, _canon(c.when))
               for c in p.candidates))
        for p in patterns)
    fp = hashlib.sha256(repr(pats).encode()).hexdigest()
    _PATTERNS_FP[id(patterns)] = (patterns, fp)
    return fp


def staged_plan_id(logical: Plan, catalog: FunctionCatalog,
                   syscat: SystemCatalog, options: PlanOptions,
                   cost_model: Optional[CostModel] = None,
                   patterns=DEFAULT_PATTERNS,
                   passes: Optional[tuple] = None,
                   feedback=None, extra_key: tuple = ()) -> str:
    """The cache key: content hash over plan structure, catalog signature,
    syscat fingerprint, planning options, cost-model weights, the physical
    pattern set, the pass list, the observed-selectivity feedback state,
    and any caller-supplied ``extra_key`` (bound-store versions) —
    everything that changes what plan comes out.  Feedback and store
    versions make cached plans *statistics-aware*: new observations or
    appended store contents are a provable cache miss, never a stale hit."""
    cm = cost_model.fingerprint() if cost_model is not None else "analytic"
    fb = feedback.fingerprint() if feedback is not None else "none"
    extra = options.cache_key() + (
        "cm", cm, "patterns", _patterns_fingerprint(patterns),
        "passes", tuple(passes or PlanPipeline.DEFAULT_PASSES),
        "feedback", fb, "extra", tuple(extra_key))
    return compute_plan_id(logical, catalog, syscat, extra=extra)


def compile_staged(logical: Plan, catalog: FunctionCatalog,
                   syscat: SystemCatalog, *,
                   options: Optional[PlanOptions] = None,
                   cost_model: Optional[CostModel] = None,
                   patterns=DEFAULT_PATTERNS,
                   pipeline: Optional[PlanPipeline] = None,
                   cache=None, feedback=None,
                   extra_key: tuple = ()) -> StagedPhysicalPlan:
    """Plan (or fetch from the plan cache) the staged physical plan.

    ``cache``: a PlanCache, None for the process-wide default, or False to
    force a fresh (uncached, uninserted) planning run.

    ``feedback``: an optional ``SelectivityFeedback`` store.  Its state is
    both *consumed* (the rewrite layer blends observed fractions over
    hints/heuristics while it is active) and *identified* (its fingerprint
    is part of the plan id, so re-planning after new observations misses
    the cache instead of reusing a plan priced on stale estimates).

    ``extra_key``: extra identity material (bound-store versions).
    """
    from .feedback import activate_feedback
    opts = options or PlanOptions()
    pl = pipeline or PlanPipeline()
    pid = staged_plan_id(logical, catalog, syscat, opts, cost_model,
                         patterns, pl.passes, feedback, extra_key)
    # the cost-model fit fingerprint doubles as the cache's calibration
    # marker: entries planned under an older fit are preferred eviction
    # victims (see PlanCache)
    cm_fp = cost_model.fingerprint() if cost_model is not None else "analytic"
    pc = None
    if cache is not False:
        pc = cache if isinstance(cache, PlanCache) else default_plan_cache()
        pc.note_fingerprint(cm_fp)
        hit = pc.lookup(pid)
        if hit is not None:
            return hit
    with activate_feedback(feedback):
        staged = pl.run(
            logical, catalog, syscat, options=opts, cost_model=cost_model,
            patterns=patterns, plan_id=pid)
    # the subplan-cache salt: everything that can change a node's *output
    # semantics or validity* without changing its structural sub-DAG hash.
    # A refit cost model or new selectivity observations replan into a new
    # pid anyway, so identical-salt entries are internally consistent; the
    # salt makes the cross-query cache miss provable for intermediates
    # materialized under the superseded calibration.
    fb_fp = feedback.fingerprint() if feedback is not None else "none"
    staged.mqo_salt = repr(("cm", cm_fp, "feedback", fb_fp))
    if pc is not None:
        pc.insert(pid, staged, fingerprint=cm_fp)
    return staged
