"""Runtime tracing + metrics: the EXPLAIN ANALYZE substrate.

The planner's cost model (paper §6) predicts; nothing so far *checked* the
prediction.  This module supplies the runtime half of that loop:

  * :class:`Tracer` — a low-overhead, thread-safe, nestable span recorder.
    Off by default (``ExecContext.tracer is None`` keeps the executor on
    its untouched fast path, zero allocations); when installed, the
    executor opens one :class:`Span` per physical op and store impls
    annotate the innermost open span with their dist strategy and
    collective-byte attribution.
  * **deferred device values** — per-op observations that live on device
    (BoundedRel counts, overflow flags) are *deferred*, not fetched: the
    tracer collects the traced scalars and :meth:`Tracer.resolve` pulls
    them all in **one** ``jax.device_get`` at end of run.  Tracing and
    ``PlannedFunction.observe`` share this single transfer point
    (:func:`resolve_counts`) — no per-op host sync, one device sync per
    run.
  * :class:`RunTrace` — one executed run: spans, resolved count-sink
    observations, per-op ``(impl, features, observed_s)`` calibration
    samples (the dataset ``core.feedback.fit_weights`` refits the cost
    model from), and exporters — structured JSON-lines
    (:meth:`RunTrace.to_jsonl`) and Chrome-trace / Perfetto-loadable JSON
    (:meth:`RunTrace.to_chrome`).

Span wall times are *dispatch* times under JAX's async dispatch; the
single ``device_sync`` span at the end of an analyzed run absorbs whatever
compute was still in flight.  That is the deliberate trade the EXPLAIN
ANALYZE design makes: per-op numbers are comparable to each other and to
the cost model's relative predictions without forcing a per-op
``block_until_ready`` (which would serialize the very pipeline being
measured).
"""
from __future__ import annotations

import io
import json
import os
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Optional

import jax


# --------------------------------------------------------------------------
# spans
# --------------------------------------------------------------------------


@dataclass
class Span:
    """One timed region: a physical op, a pass, or a whole run."""

    name: str
    cat: str = "op"
    t0: float = 0.0                # perf_counter seconds (tracer-relative)
    dur: float = 0.0               # seconds
    tid: int = 0
    span_id: int = 0
    parent_id: Optional[int] = None
    attrs: dict = field(default_factory=dict)

    @property
    def dur_ms(self) -> float:
        return self.dur * 1e3

    def as_dict(self) -> dict:
        return {"name": self.name, "cat": self.cat, "t0_s": self.t0,
                "dur_ms": self.dur_ms, "tid": self.tid,
                "span_id": self.span_id, "parent_id": self.parent_id,
                "attrs": dict(self.attrs)}


class Tracer:
    """Thread-safe nestable span recorder.

    Each thread keeps its own open-span stack (nesting is per-thread);
    completed spans land in one shared list under a lock.  ``enabled=False``
    makes every entry point a no-op so a tracer object can be threaded
    through call sites unconditionally.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.spans: list = []
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._next_id = 0
        self._epoch = time.perf_counter()
        # deferred device-side observations: (span, key, traced value) —
        # resolved in ONE device_get by resolve()
        self._deferred: list = []

    # -- span lifecycle ----------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    @contextmanager
    def span(self, name: str, cat: str = "op", **attrs):
        if not self.enabled:
            yield None
            return
        stack = self._stack()
        with self._lock:
            sid = self._next_id
            self._next_id += 1
        sp = Span(name, cat, time.perf_counter() - self._epoch, 0.0,
                  threading.get_ident(), sid,
                  stack[-1].span_id if stack else None, attrs)
        stack.append(sp)
        try:
            yield sp
        finally:
            sp.dur = (time.perf_counter() - self._epoch) - sp.t0
            stack.pop()
            with self._lock:
                self.spans.append(sp)

    def annotate(self, **attrs) -> None:
        """Attach attrs to the innermost open span of the calling thread
        (store impls report dist strategy / collective bytes this way
        without knowing which physical node wraps them)."""
        if not self.enabled:
            return
        stack = self._stack()
        if stack:
            stack[-1].attrs.update(attrs)

    def defer(self, key: str, value) -> None:
        """Record a device-side observation against the innermost open
        span; fetched by :meth:`resolve` in one transfer at end of run."""
        if not self.enabled:
            return
        stack = self._stack()
        if stack:
            with self._lock:
                self._deferred.append((stack[-1], key, value))

    def resolve(self, sink=None) -> list:
        """The single device->host transfer point: pull every deferred
        observation — and, when given, the run's ``count_sink`` entries —
        in **one** ``jax.device_get``, fold the deferred values into their
        spans' attrs, and return the resolved sink (same shape as
        :func:`resolve_counts`)."""
        with self._lock:
            pending, self._deferred = self._deferred, []
        sink = sink or []
        if not pending and not sink:
            return []
        vals, sink_vals = jax.device_get(
            ([v for _, _, v in pending],
             [(c, cap) for _site, c, cap in sink]))
        for (sp, key, _), v in zip(pending, vals):
            sp.attrs[key] = _scalarize(v)
        return [(site, float(c), int(cap))
                for (site, _c, _cp), (c, cap) in zip(sink, sink_vals)]

    # -- views -------------------------------------------------------------
    def by_name(self) -> dict:
        out: dict = {}
        for sp in self.spans:
            out.setdefault(sp.name, []).append(sp)
        return out


def _scalarize(v):
    try:
        import numpy as np
        if isinstance(v, np.ndarray) and v.ndim == 0:
            if v.dtype.kind == "b":
                return bool(v)
            if v.dtype.kind in "iu":
                return int(v)
            return float(v)
    except Exception:
        pass
    return v


# --------------------------------------------------------------------------
# the shared transfer point for count-sink observations
# --------------------------------------------------------------------------


def resolve_counts(sink) -> list:
    """Resolve accumulated ``count_sink`` entries ``(site, count, capacity)``
    in **one** ``jax.device_get`` — the single per-run transfer shared by
    ``PlannedFunction.observe`` and EXPLAIN ANALYZE.  Counts accumulate
    device-side during the run (BoundedRel counts are lazy traced scalars);
    nothing syncs until this call."""
    if not sink:
        return []
    vals = jax.device_get([(c, cap) for _site, c, cap in sink])
    return [(site, float(c), int(cap))
            for (site, _c0, _cap0), (c, cap) in zip(sink, vals)]


# --------------------------------------------------------------------------
# wire-byte attribution for the mesh-kinded transfers
# --------------------------------------------------------------------------


def xfer_wire_bytes(kind: str, payload_bytes: float, n: int) -> float:
    """Per-shard wire bytes a transfer of ``kind`` actually moves for a
    ``payload_bytes``-sized value on an ``n``-wide data axis — the runtime
    counterpart of the cost model's xfer pricing."""
    n = max(1, int(n))
    if kind == "replicate":            # all-gather: receive the (n-1)/n rest
        return payload_bytes * (n - 1) / n
    if kind == "repartition":          # all-to-all: keep 1/n of the 1/n slice
        return payload_bytes * (n - 1) / (n * n)
    if kind == "spill":                # host round trip: down and back up
        return 2.0 * payload_bytes
    return 0.0                         # pin / local: device-resident


def tree_bytes(value) -> int:
    """Static payload size of a plan value (pytree of arrays)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(value):
        nb = getattr(leaf, "nbytes", None)
        if nb is None:
            sz = getattr(leaf, "size", 1)
            it = getattr(getattr(leaf, "dtype", None), "itemsize", 4)
            nb = sz * it
        total += int(nb)
    return total


# --------------------------------------------------------------------------
# one executed run
# --------------------------------------------------------------------------


@dataclass
class RunTrace:
    """Everything one analyzed execution observed, merge-ready for
    ``StagedPhysicalPlan.explain(analyze=...)``."""

    spans: list = field(default_factory=list)
    wall_ms: float = 0.0             # whole run, device-synced once
    sync_ms: float = 0.0             # the single end-of-run device sync
    counts: list = field(default_factory=list)   # resolved sink entries
    samples: list = field(default_factory=list)  # (impl, features, obs_s)
    plan_id: str = ""

    # -- views -------------------------------------------------------------
    def span_for(self, node_id: str) -> Optional[Span]:
        for sp in self.spans:
            if sp.name == node_id:
                return sp
        return None

    def op_spans(self) -> list:
        return [sp for sp in self.spans if sp.cat not in ("run", "sync")]

    def collective_totals(self) -> dict:
        """Per-shard collective traffic, aggregated by transfer kind plus
        the store kernels' own collective annotations."""
        out: dict = {}
        for sp in self.spans:
            kind = sp.attrs.get("xfer_kind")
            if kind is not None:
                row = out.setdefault(kind, {"bytes": 0.0, "ops": 0})
                row["bytes"] += float(sp.attrs.get("wire_bytes", 0.0))
                row["ops"] += 1
            cb = sp.attrs.get("coll_bytes")
            if cb is not None:
                coll = sp.attrs.get("coll", "collective")
                row = out.setdefault(coll, {"bytes": 0.0, "ops": 0})
                row["bytes"] += float(cb)
                row["ops"] += 1
        return out

    # -- exporters ---------------------------------------------------------
    def to_jsonl(self, path) -> None:
        """Structured JSON-lines trace log: one header line, then one line
        per span in completion order."""
        own = isinstance(path, (str, os.PathLike))
        fh = open(path, "w") if own else path
        try:
            fh.write(json.dumps({
                "record": "run", "plan_id": self.plan_id,
                "wall_ms": self.wall_ms, "sync_ms": self.sync_ms,
                "spans": len(self.spans),
                "collective_totals": self.collective_totals()}) + "\n")
            for sp in self.spans:
                fh.write(json.dumps({"record": "span", **sp.as_dict()},
                                    default=str) + "\n")
            for site, count, cap in self.counts:
                fh.write(json.dumps({
                    "record": "count", "site": list(map(str, site)),
                    "count": count, "capacity": cap}) + "\n")
        finally:
            if own:
                fh.close()

    def chrome_events(self) -> list:
        """Chrome trace-event list (Perfetto/chrome://tracing loadable):
        ``ph="X"`` complete events in microseconds, plus process/thread
        metadata events, plus ``ph="C"`` **counter-track** events for the
        resolved cardinality observations — every span whose deferred
        count/overflow resolved, and every count-sink site, gets a counter
        sample at the span's (or run's) end so the BoundedRel counts are
        visible in the timeline, not only in the report."""
        pid = os.getpid()
        tids = {}
        events = [{"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
                   "args": {"name": f"repro plan {self.plan_id[:12]}"}}]
        for sp in self.spans:
            tid = tids.setdefault(sp.tid, len(tids))
            events.append({
                "ph": "X", "pid": pid, "tid": tid,
                "name": sp.name, "cat": sp.cat,
                "ts": sp.t0 * 1e6, "dur": sp.dur * 1e6,
                "args": {k: _jsonable(v) for k, v in sp.attrs.items()},
            })
            if "count" in sp.attrs:
                args = {"count": float(sp.attrs["count"])}
                if "overflow" in sp.attrs:
                    args["overflow"] = float(sp.attrs["overflow"] or 0.0)
                events.append({
                    "ph": "C", "pid": pid, "tid": tid,
                    "name": f"count:{sp.name}",
                    "ts": (sp.t0 + sp.dur) * 1e6, "args": args,
                })
        run_end = max((sp.t0 + sp.dur for sp in self.spans), default=0.0)
        for site, count, cap in self.counts:
            events.append({
                "ph": "C", "pid": pid, "tid": 0,
                "name": "count:" + "/".join(map(str, site)),
                "ts": run_end * 1e6,
                "args": {"count": float(count), "capacity": float(cap)},
            })
        for raw, tid in tids.items():
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name",
                           "args": {"name": f"thread-{raw}"}})
        return events

    def to_chrome(self, path) -> None:
        doc = {"traceEvents": self.chrome_events(),
               "displayTimeUnit": "ms",
               "otherData": {"plan_id": self.plan_id,
                             "wall_ms": self.wall_ms}}
        own = isinstance(path, (str, os.PathLike))
        fh = open(path, "w") if own else path
        try:
            json.dump(doc, fh)
        finally:
            if own:
                fh.close()


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def validate_chrome_trace(doc: dict) -> list:
    """Schema check for an exported Chrome trace (the obs-smoke CI gate):
    returns a list of violations, empty when the document is loadable."""
    errs = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["missing traceEvents"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list) or not evs:
        return ["traceEvents empty or not a list"]
    for i, ev in enumerate(evs):
        for k in ("ph", "pid", "tid", "name"):
            if k not in ev:
                errs.append(f"event {i}: missing {k!r}")
        ph = ev.get("ph")
        if ph not in ("X", "M", "B", "E", "i", "C"):
            errs.append(f"event {i}: unknown ph {ph!r}")
        if ph == "X":
            for k in ("ts", "dur"):
                if not isinstance(ev.get(k), (int, float)):
                    errs.append(f"event {i}: non-numeric {k!r}")
        if ph == "C":
            if not isinstance(ev.get("ts"), (int, float)):
                errs.append(f"event {i}: non-numeric 'ts'")
            args = ev.get("args")
            if not isinstance(args, dict) or not args or \
                    any(not isinstance(v, (int, float))
                        for v in args.values()):
                errs.append(f"event {i}: counter args must be a non-empty "
                            f"dict of numeric series")
    return errs
