"""Partitioned data parallelism (paper §5.2, Fig. 8).

Every physical operator carries a data-parallel capability (``PR``/``ST``/
``EX``) and, for multi-input PR operators, a ``capOn`` attribute naming the
input it can partition on.  The pass walks the physical DAG and inserts

  * a **Partition** step when a PR operator's ``capOn`` input arrives
    unpartitioned,
  * a **Merge** step when a non-``capOn`` input arrives partitioned, and
  * a **Merge** step when an ST operator consumes a PR operator's output

— exactly the three insertion rules of §5.2.  In the TPU realization a
Partition step lowers to ``jax.lax.with_sharding_constraint`` pinning the
semantic ``capOn`` dimension to the ``data`` mesh axis (GSPMD then emits the
scatter), and a Merge lowers to a constraint that replicates the value over
``data`` (GSPMD emits the all-gather).  ``EX`` operators are opaque engines:
they inherit whatever layout their input has and are excluded from insertion
decisions, mirroring the paper's treatment of external-library operators.
"""
from __future__ import annotations

from dataclasses import dataclass

from .ir import TensorT
from .physical import PHYS_OPS, PR, ST, EX, PhysPlan, defop

# semantic dims that the 'data' mesh axis may partition (capOn universe)
DATA_PARTITIONABLE = ("batch",)


def _type_has_dim(t, dim: str) -> bool:
    """Whether a value of type ``t`` can be partitioned on ``dim``.  A
    TensorT with semantic dim names must actually carry the dim (a (nodes,)
    graph frontier has no batch axis to shard); unknown / un-annotated
    types keep the historical always-partitionable behaviour."""
    if isinstance(t, TensorT) and t.dims:
        return t.has_dim(dim)
    return True


def _cap(n):
    return PHYS_OPS[n.impl].dp_cap


def _cap_on(n):
    # node attrs may override the opdef default (paper: capOn is per-operator
    # but set per-instance when the operator is instantiated)
    return n.attrs.get("cap_on", PHYS_OPS[n.impl].cap_on)


def add_data_parallelism(pp: PhysPlan) -> PhysPlan:
    """AddDataParallelism (Alg. 1 line 2), applied to a candidate plan.

    Tracks a 'partitioned' bit per value, inserts partition/merge nodes, and
    records the decision in node attrs so the executor can emit sharding
    constraints.  Virtual nodes are treated as PR-on-batch (all their
    candidates are tensor ops over batched activations); their candidate
    chains inherit the surrounding partitioning when materialized.
    """
    out = PhysPlan(pp.name, {}, dict(pp.inputs), (), dict(pp.types),
                   dict(pp.pm), dict(pp.logical_of))
    remap = {i: i for i in pp.inputs}
    partitioned = {i: False for i in pp.inputs}  # plan inputs arrive whole

    def emit(impl, ins, attrs, id):
        nid = out.add(impl, ins, attrs, id=id)
        out.types[nid] = out.types.get(ins[0]) if ins else None
        return nid

    for n in pp.topo():
        sub = n.subplan
        if sub is not None:
            sub = add_data_parallelism(sub)
        cap = _cap(n) if not n.virtual else PR
        cap_on = _cap_on(n) if not n.virtual else "batch"
        cap_all = (PHYS_OPS[n.impl].cap_all if not n.virtual else True)
        new_inputs = []
        for idx, i in enumerate(n.inputs):
            src = remap[i]
            src_part = partitioned.get(i, False)
            is_cap_input = cap_all or (idx == n.attrs.get("cap_idx", 0))
            if cap == PR and is_cap_input and not src_part and \
                    cap_on in DATA_PARTITIONABLE and \
                    _type_has_dim(pp.types.get(i), cap_on):
                # rule 1: partition the capOn input
                src = emit("partition", [src],
                           {"dim": cap_on, "mesh_axis": "data"},
                           id=f"part_{n.id}_{idx}")
                src_part = True
            elif cap == PR and not is_cap_input and src_part:
                # rule 2: merge a partitioned non-capOn input
                src = emit("merge", [src], {"mesh_axis": "data"},
                           id=f"merge_{n.id}_{idx}")
                src_part = False
            elif cap == ST and src_part:
                # rule 3: ST consumer of partitioned producer
                src = emit("merge", [src], {"mesh_axis": "data"},
                           id=f"merge_{n.id}_{idx}")
                src_part = False
            new_inputs.append(src)

        nid = out.add(n.impl, new_inputs, dict(n.attrs), sub, id=n.id,
                      virtual=n.virtual)
        out.types[nid] = pp.types.get(n.id)
        remap[n.id] = nid
        # EX inherits its input's layout; PR produces partitioned output;
        # ST produces whole output.
        if cap == PR:
            partitioned[n.id] = True
        elif cap == EX:
            partitioned[n.id] = any(partitioned.get(i, False) for i in n.inputs)
        else:
            partitioned[n.id] = False

    out.outputs = tuple(remap[o] for o in pp.outputs)
    return out


def partition_stats(pp: PhysPlan) -> dict:
    """Counts used by tests/benchmarks (Fig. 8 structure check)."""
    ops = [n.impl for n in pp.topo()]
    return {
        "partition": ops.count("partition"),
        "merge": ops.count("merge"),
        # store ops the shard_stores rewrite marked for shard-local
        # execution over the mesh's data axis (orthogonal to the tensor
        # partition/merge machinery above, which is ST-capped for stores)
        "dist": sum(1 for n in pp.topo() if n.attrs.get("dist")),
        "total": len(ops),
    }
