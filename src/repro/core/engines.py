"""Pluggable engine registry (the tri-store's named engines, §2).

AWESOME routes each part of a workload to one of several registered engines
(SQL / Cypher / NLP in the paper).  The tensor-world analogue has two
execution engines today:

  * ``xla``    — the interpreter path: every physical op lowered through
    plain JAX/XLA primitives;
  * ``pallas`` — fused hand-written kernels (flash attention, grouped-matmul
    MoE, WKV6, SSD), the paper's "external library" engines.

Each engine owns its *implementation table* (impl name -> python callable).
The planner names engines, not booleans: candidate generation and
cost-model selection receive an ``engines`` tuple and only consider
candidates whose ``requires_backend`` is among them, and the executor
dispatches each physical node through the engine that registered its impl.
Registering a third engine (e.g. a future ``cuda`` path) is a
``register_engine`` call plus ``@<engine>.impl(...)`` registrations — no
planner change.

``resolve_engines`` also accepts the legacy ``allow_pallas`` boolean so old
call sites keep working while they migrate.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional, Sequence

from .ir import ValidationError


@dataclass
class Engine:
    """One named execution engine: an impl table plus availability."""

    name: str
    description: str = ""
    impls: dict = field(default_factory=dict)   # impl name -> callable
    # optional gate: engines that need hardware/runtime support can report
    # unavailability and the planner will not offer their candidates
    is_available: Optional[Callable[[], bool]] = None

    def impl(self, *names):
        """Decorator: register an op implementation under this engine."""
        def deco(fn):
            for n in names:
                self.impls[n] = fn
            return fn
        return deco

    def available(self) -> bool:
        return True if self.is_available is None else bool(self.is_available())

    def __contains__(self, impl_name: str) -> bool:
        return impl_name in self.impls


_REGISTRY: dict = {}


def register_engine(name: str, description: str = "",
                    is_available=None) -> Engine:
    """Register (or fetch, idempotently) an engine by name."""
    if name in _REGISTRY:
        return _REGISTRY[name]
    eng = Engine(name, description, {}, is_available)
    _REGISTRY[name] = eng
    return eng


def get_engine(name: str) -> Engine:
    if name not in _REGISTRY:
        raise ValidationError(
            f"unknown engine {name!r} (registered: {engine_names()})")
    return _REGISTRY[name]


def engine_names() -> tuple:
    return tuple(sorted(_REGISTRY))


def resolve_engines(engines=None, *, allow_pallas=None) -> tuple:
    """Normalize an engine selection to a validated tuple of engine names.

    ``engines`` wins when given (string or iterable of strings); otherwise
    the legacy ``allow_pallas`` boolean maps to ("xla",) / ("xla", "pallas");
    otherwise the default is the always-available interpreter engine.
    """
    if engines is not None:
        if isinstance(engines, str):
            engines = (engines,)
        out = tuple(engines)
        if not out:
            raise ValidationError("engine selection must name >= 1 engine")
        for e in out:
            get_engine(e)  # raises on unknown names
        return out
    if allow_pallas:
        return ("xla", "pallas")
    return ("xla",)


def dispatch(impl_name: str, backend: Optional[str] = None):
    """Find the callable implementing ``impl_name``.

    ``backend`` (the physical opdef's engine tag) short-circuits the search;
    without it every registered engine's table is scanned.  Returns None when
    no engine implements the op.
    """
    if backend is not None and backend in _REGISTRY:
        fn = _REGISTRY[backend].impls.get(impl_name)
        if fn is not None:
            return fn
    for eng in _REGISTRY.values():
        fn = eng.impls.get(impl_name)
        if fn is not None:
            return fn
    return None


# The two engines of this reproduction.  The executor module populates their
# impl tables at import time (see ``executor.impl``).
XLA_ENGINE = register_engine(
    "xla", "interpreter path: physical ops as plain JAX/XLA primitives")
PALLAS_ENGINE = register_engine(
    "pallas", "fused Pallas kernels (flash attention, MoE GMM, WKV6, SSD)")
