"""Content-addressed LRU cache of staged physical plans.

BigDAWG and Polystore++ both observe that staged plans with *stable
identities* are the prerequisite for plan reuse across repeated traffic.
Here the identity is ``ir.plan_id`` — a content hash over plan structure,
catalog signatures, syscat fingerprint, and planning options — and the
cached value is the full :class:`~repro.core.pipeline.StagedPhysicalPlan`
(optimized logical plan, candidate plan, concrete plan, choices, buffering
decision and the per-pass trace).

A cache hit skips the entire pass pipeline: repeated/bucketed workloads
(serving buckets, re-built train steps, dry-run sweeps) rebind the cached
staged plan to their runtime context (mesh / sharding rules / interpret
mode) instead of replanning from scratch.  Staged plans are treated as
immutable once cached; the executor never mutates them at call time.
"""
from __future__ import annotations

import os
import pickle
import tempfile
from collections import OrderedDict
from typing import Any, Optional


class PlanCache:
    """LRU map: plan_id -> StagedPhysicalPlan, with hit/miss accounting."""

    def __init__(self, maxsize: int = 128):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self._entries: "OrderedDict[str, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def lookup(self, plan_id: str):
        """Return the cached staged plan (refreshing recency) or None."""
        entry = self._entries.get(plan_id)
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(plan_id)
        self.hits += 1
        return entry

    def insert(self, plan_id: str, staged) -> None:
        self._entries[plan_id] = staged
        self._entries.move_to_end(plan_id)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._entries.clear()
        self.hits = self.misses = self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, plan_id: str) -> bool:
        return plan_id in self._entries

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "size": len(self._entries),
            "maxsize": self.maxsize,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": (self.hits / total) if total else 0.0,
        }

    def __repr__(self):
        s = self.stats()
        return (f"PlanCache(size={s['size']}/{s['maxsize']} "
                f"hits={s['hits']} misses={s['misses']} "
                f"hit_rate={s['hit_rate']:.2f})")


# --------------------------------------------------------------------------
# disk persistence: plan_id-keyed warm start
# --------------------------------------------------------------------------
#
# Staged plans are content-addressed, so persisting them is safe by
# construction: the file name *is* the plan_id, and a restart that computes
# the same id gets the same plan (a syscat / catalog / options change
# computes a different id and simply misses).  Used by the serving runtime
# and launch/train for warm-started planning across process restarts.

_SUFFIX = ".staged.pkl"


def save_plan_cache(cache: PlanCache, dir_path: str) -> int:
    """Write every cached staged plan to ``dir_path/<plan_id>.staged.pkl``
    (atomic per entry; already-persisted ids are skipped).  Returns the
    number of newly written entries."""
    os.makedirs(dir_path, exist_ok=True)
    written = 0
    for plan_id, staged in cache._entries.items():
        path = os.path.join(dir_path, plan_id + _SUFFIX)
        if os.path.exists(path):
            continue
        fd, tmp = tempfile.mkstemp(dir=dir_path, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as fh:
                pickle.dump(staged, fh)
            os.replace(tmp, path)
            written += 1
        except Exception:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
    return written


def load_plan_cache(dir_path: str, cache: Optional[PlanCache] = None,
                    ) -> PlanCache:
    """Warm-start a PlanCache from a persisted directory.  Entries load in
    mtime order (oldest first) so LRU recency mirrors write order; corrupt
    or unreadable files are skipped — a warm start can only help, never
    fail the caller.  Loading counts neither hits nor misses."""
    cache = cache if cache is not None else PlanCache()
    if not os.path.isdir(dir_path):
        return cache
    entries = [e for e in os.scandir(dir_path) if e.name.endswith(_SUFFIX)]
    entries.sort(key=lambda e: e.stat().st_mtime)
    for e in entries:
        plan_id = e.name[:-len(_SUFFIX)]
        if plan_id in cache:
            continue
        try:
            with open(e.path, "rb") as fh:
                cache.insert(plan_id, pickle.load(fh))
        except Exception:
            continue
    return cache


# process-wide default, shared by every entry point (adil.Analysis.compile,
# launch/train, launch/serve, launch/dryrun, benchmarks)
_DEFAULT = PlanCache()


def default_plan_cache() -> PlanCache:
    return _DEFAULT


def clear_default_plan_cache() -> None:
    _DEFAULT.clear()
