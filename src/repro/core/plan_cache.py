"""Content-addressed LRU cache of staged physical plans.

BigDAWG and Polystore++ both observe that staged plans with *stable
identities* are the prerequisite for plan reuse across repeated traffic.
Here the identity is ``ir.plan_id`` — a content hash over plan structure,
catalog signatures, syscat fingerprint, and planning options — and the
cached value is the full :class:`~repro.core.pipeline.StagedPhysicalPlan`
(optimized logical plan, candidate plan, concrete plan, choices, buffering
decision and the per-pass trace).

A cache hit skips the entire pass pipeline: repeated/bucketed workloads
(serving buckets, re-built train steps, dry-run sweeps) rebind the cached
staged plan to their runtime context (mesh / sharding rules / interpret
mode) instead of replanning from scratch.  Staged plans are treated as
immutable once cached; the executor never mutates them at call time.
"""
from __future__ import annotations

import os
import pickle
import tempfile
import threading
from collections import OrderedDict
from typing import Any, Optional

# per-node bookkeeping overhead (dataclass + dict slots, interned strings)
# and the fallback for opaque entries staged_bytes cannot walk
_NODE_BYTES = 256
_FALLBACK_BYTES = 1024


def staged_bytes(staged) -> int:
    """Estimated resident bytes of a cached staged plan: per-node overhead
    plus the nbytes of any array constants folded into node attrs (the part
    that actually scales — a plan embedding a broadcast build side can dwarf
    a hundred constant-free plans).  An explicit ``nbytes`` attribute wins;
    anything unwalkable falls back to a flat constant so byte accounting
    degrades to count accounting, never raises."""
    nb = getattr(staged, "nbytes", None)
    if isinstance(nb, (int, float)) and nb >= 0:
        return int(nb)
    try:
        import jax
        total = 0
        for node in staged.concrete.topo():
            total += _NODE_BYTES
            for leaf in jax.tree_util.tree_leaves(dict(node.attrs)):
                n = getattr(leaf, "nbytes", None)
                if n is not None:
                    total += int(n)
        return max(total, _NODE_BYTES)
    except Exception:
        return _FALLBACK_BYTES


class PlanCache:
    """LRU map: plan_id -> StagedPhysicalPlan, with hit/miss accounting.

    Eviction is **calibration-aware**: each entry remembers the cost-model
    fit fingerprint it was planned under (``insert(..., fingerprint=)``),
    and ``note_fingerprint`` records the fingerprint of the current cost
    model.  An entry is **stale** when its fingerprint differs from the
    current one *and* it has not been touched since the current fingerprint
    took effect — i.e. it was planned under a superseded fit and nobody is
    using it.  Stale entries are evicted first (LRU among themselves); with
    none, eviction is plain LRU.  The not-touched-since condition keeps a
    *concurrently active* second cost model's hot entries protected: being
    looked up under the new calibration re-proves an entry live, so two
    callers sharing one cache cannot thrash each other's working sets.

    Alongside the entry-count bound, an optional ``byte_budget`` bounds the
    *bytes* the cached staged plans hold (estimated per entry at insert,
    registered in the MemoryLedger under ``("plan_cache", plan_id)``).
    Byte-budget eviction is stale-first, then **largest-first** — entry
    count is a poor proxy for memory when staged plans embed folded
    constants of very different sizes, so the budget sheds the biggest
    non-stale entry rather than the coldest.
    """

    def __init__(self, maxsize: int = 128,
                 byte_budget: Optional[int] = None, ledger=None):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        if byte_budget is not None and byte_budget < 1:
            raise ValueError(f"byte_budget must be >= 1, got {byte_budget}")
        self.maxsize = maxsize
        self.byte_budget = byte_budget
        self._ledger = ledger                # None -> default_ledger(), lazy
        # one reentrant lock covers every counter and map mutation: the
        # serving loop's admission path and benchmark drivers look plans up
        # from multiple tasks/threads, and the bare ``self.hits += 1``
        # read-modify-writes (plus the OrderedDict reorders) raced —
        # stats() could report hits + misses != lookups.  Reentrant because
        # insert() -> note_fingerprint() nests.
        self._lock = threading.RLock()
        self._entries: "OrderedDict[str, Any]" = OrderedDict()
        self._fps: dict = {}                 # plan_id -> fit fingerprint
        self._seen_epoch: dict = {}          # plan_id -> epoch of last touch
        self._sizes: dict = {}               # plan_id -> estimated bytes
        self._epoch = 0                      # bumps when the fit changes
        self.current_fingerprint: Optional[str] = None
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.stale_evictions = 0
        self.byte_evictions = 0
        self.bytes_in_cache = 0

    @property
    def ledger(self):
        if self._ledger is None:
            from .ledger import default_ledger
            self._ledger = default_ledger()
        return self._ledger

    def note_fingerprint(self, fingerprint: str) -> None:
        """Record the fingerprint of the cost model in current use (called
        by ``compile_staged`` on every cached planning request, so pure-hit
        workloads still see calibration refreshes).

        The uncalibrated ``"analytic"`` fallback never *displaces* a fitted
        fingerprint: many call sites pass no cost model at all, and letting
        each of their compiles flip currency back and forth would churn the
        staleness epoch on every interleaving.  Calibration only moves
        forward."""
        with self._lock:
            if fingerprint == "analytic" and \
                    self.current_fingerprint is not None:
                return
            if fingerprint != self.current_fingerprint:
                self._epoch += 1
            self.current_fingerprint = fingerprint

    def lookup(self, plan_id: str):
        """Return the cached staged plan (refreshing recency) or None."""
        with self._lock:
            entry = self._entries.get(plan_id)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(plan_id)
            self._seen_epoch[plan_id] = self._epoch
            self.hits += 1
            return entry

    def insert(self, plan_id: str, staged, fingerprint: Optional[str] = None
               ) -> None:
        # size estimation walks the staged plan — keep it outside the lock
        nb = staged_bytes(staged)
        with self._lock:
            if plan_id in self._entries:
                self.bytes_in_cache -= self._sizes.get(plan_id, 0)
            self._entries[plan_id] = staged
            self._sizes[plan_id] = nb
            self.bytes_in_cache += nb
            self.ledger.register(("plan_cache", plan_id), nbytes=nb,
                                 kind="plan_cache")
            if fingerprint is not None:
                self._fps[plan_id] = fingerprint
                self.note_fingerprint(fingerprint)
            self._seen_epoch[plan_id] = self._epoch
            self._entries.move_to_end(plan_id)
            while len(self._entries) > self.maxsize:
                self._evict_one()
            # byte budget on top of the count bound: stale entries go first
            # (LRU among themselves), then the *largest* live entry — the
            # goal is bytes back per eviction, not recency.  The newest
            # entry is never evicted on its own insert (len > 1), even when
            # it alone exceeds the budget: callers still get their plan
            # cached until something else arrives.
            if self.byte_budget is not None:
                while (self.bytes_in_cache > self.byte_budget
                       and len(self._entries) > 1):
                    self._evict_one_bytes(keep=plan_id)

    def _evict_one_bytes(self, keep: Optional[str] = None) -> None:
        victim = None
        if self.current_fingerprint is not None:
            victim = next((p for p in self._entries
                           if p != keep and self._is_stale(p)), None)
        if victim is not None:
            self.stale_evictions += 1
        else:
            victim = max((p for p in self._entries if p != keep),
                         key=lambda p: self._sizes.get(p, 0))
        self._drop(victim)
        self.evictions += 1
        self.byte_evictions += 1

    def _drop(self, plan_id: str) -> None:
        del self._entries[plan_id]
        self._fps.pop(plan_id, None)
        self._seen_epoch.pop(plan_id, None)
        self.bytes_in_cache -= self._sizes.pop(plan_id, 0)
        self.ledger.release(("plan_cache", plan_id))

    def _is_stale(self, plan_id: str) -> bool:
        fp = self._fps.get(plan_id)
        return (fp is not None and fp != self.current_fingerprint
                and self._seen_epoch.get(plan_id, -1) < self._epoch)

    def _evict_one(self) -> None:
        victim = None
        if self.current_fingerprint is not None:
            victim = next((p for p in self._entries if self._is_stale(p)),
                          None)
        if victim is None:
            victim = next(iter(self._entries))
        else:
            self.stale_evictions += 1
        self._drop(victim)
        self.evictions += 1

    def clear(self) -> None:
        with self._lock:
            for plan_id in self._entries:
                self.ledger.release(("plan_cache", plan_id))
            self._entries.clear()
            self._fps.clear()
            self._seen_epoch.clear()
            self._sizes.clear()
            self.bytes_in_cache = 0
            self._epoch = 0
            self.current_fingerprint = None
            self.hits = self.misses = self.evictions = 0
            self.stale_evictions = 0
            self.byte_evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, plan_id: str) -> bool:
        with self._lock:
            return plan_id in self._entries

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {
                "size": len(self._entries),
                "maxsize": self.maxsize,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "stale_evictions": self.stale_evictions,
                "byte_evictions": self.byte_evictions,
                "bytes": self.bytes_in_cache,
                "byte_budget": self.byte_budget,
                "hit_rate": (self.hits / total) if total else 0.0,
            }

    def __repr__(self):
        s = self.stats()
        return (f"PlanCache(size={s['size']}/{s['maxsize']} "
                f"hits={s['hits']} misses={s['misses']} "
                f"hit_rate={s['hit_rate']:.2f})")


# --------------------------------------------------------------------------
# disk persistence: plan_id-keyed warm start
# --------------------------------------------------------------------------
#
# Staged plans are content-addressed, so persisting them is safe by
# construction: the file name *is* the plan_id, and a restart that computes
# the same id gets the same plan (a syscat / catalog / options change
# computes a different id and simply misses).  Used by the serving runtime
# and launch/train for warm-started planning across process restarts.

_SUFFIX = ".staged.pkl"


def save_plan_cache(cache: PlanCache, dir_path: str) -> int:
    """Write every cached staged plan to ``dir_path/<plan_id>.staged.pkl``
    (atomic per entry; already-persisted ids are skipped).  Returns the
    number of newly written entries."""
    os.makedirs(dir_path, exist_ok=True)
    written = 0
    with cache._lock:                      # snapshot: writes happen unlocked
        entries = list(cache._entries.items())
    for plan_id, staged in entries:
        path = os.path.join(dir_path, plan_id + _SUFFIX)
        if os.path.exists(path):
            continue
        fd, tmp = tempfile.mkstemp(dir=dir_path, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as fh:
                # fingerprint rides along so calibration-aware eviction
                # classifies warm-started entries too
                pickle.dump({"staged": staged,
                             "fingerprint": cache._fps.get(plan_id)}, fh)
            os.replace(tmp, path)
            written += 1
        except Exception:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
    return written


def load_plan_cache(dir_path: str, cache: Optional[PlanCache] = None,
                    ) -> PlanCache:
    """Warm-start a PlanCache from a persisted directory.  Entries load in
    mtime order (oldest first) so LRU recency mirrors write order; corrupt
    or unreadable files are skipped — a warm start can only help, never
    fail the caller.  Loading counts neither hits nor misses."""
    cache = cache if cache is not None else PlanCache()
    if not os.path.isdir(dir_path):
        return cache
    entries = [e for e in os.scandir(dir_path) if e.name.endswith(_SUFFIX)]
    entries.sort(key=lambda e: e.stat().st_mtime)
    for e in entries:
        plan_id = e.name[:-len(_SUFFIX)]
        if plan_id in cache:
            continue
        try:
            with open(e.path, "rb") as fh:
                obj = pickle.load(fh)
        except Exception:
            continue
        if isinstance(obj, dict) and "staged" in obj:
            cache.insert(plan_id, obj["staged"])
            if obj.get("fingerprint") is not None:
                # classify the entry for stale-first eviction, but loading
                # old plans must not make their fit the *current* one
                cache._fps[plan_id] = obj["fingerprint"]
        else:                      # pre-fingerprint format: bare staged plan
            cache.insert(plan_id, obj)
    return cache


# process-wide default, shared by every entry point (adil.Analysis.compile,
# launch/train, launch/serve, launch/dryrun, benchmarks)
_DEFAULT = PlanCache()


def default_plan_cache() -> PlanCache:
    return _DEFAULT


def clear_default_plan_cache() -> None:
    _DEFAULT.clear()
