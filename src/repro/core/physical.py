"""Physical planning (paper §5, Algorithms 1–2).

The logical plan is transformed into *candidate physical plans*:

  * an **ordered pattern set** maps logical sub-DAGs to sets of physical
    sub-plans, matched largest-first (Def. 5.1, Alg. 2 line 2);
  * a pattern with exactly one candidate is substituted in place
    (Alg. 2 lines 6–7);
  * a pattern with several candidates becomes a **virtual node** whose
    candidate sub-plans live in the ``PM`` map (Alg. 2 lines 8–9) and whose
    winner is chosen by the learned cost model once input sizes are known
    (trace time, §6.3).

Every physical operator carries the paper's capability annotations
(Table 3 / Table 5): data-parallel capability ``ST``/``PR``/``EX`` with a
``capOn`` input dimension, and buffering capability ``SI``/``SO``/``B``/``SS``.
``EX`` operators (Pallas kernels — our "external engines") are excluded from
the partitioning rewrites, exactly as the paper excludes external-library
operators from its data-parallelism optimization.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from .ir import Plan, Node, TensorT, TupleT, ValidationError

# --------------------------------------------------------------------------
# Physical operator definitions (capability catalog — paper Table 3/5)
# --------------------------------------------------------------------------

ST, PR, EX = "ST", "PR", "EX"          # data-parallel capability
SI, SO, B, SS = "SI", "SO", "B", "SS"  # buffering capability


@dataclass(frozen=True)
class PhysicalOpDef:
    name: str
    dp_cap: str = PR
    buf_cap: str = SS
    cap_on: Optional[str] = "batch"   # semantic dim the op partitions/streams on
    backend: str = "xla"              # "xla" | "pallas"
    cap_all: bool = False             # PR on *every* input (elementwise joins)


PHYS_OPS: dict = {}


def defop(name, dp_cap=PR, buf_cap=SS, cap_on="batch", backend="xla",
          cap_all=False):
    PHYS_OPS[name] = PhysicalOpDef(name, dp_cap, buf_cap, cap_on, backend,
                                   cap_all)
    return PHYS_OPS[name]


# --- query-analogue ops (data movement / bookkeeping)
defop("identity")
defop("partition", dp_cap=ST, buf_cap=SO, cap_on=None)   # §5.2 Partition step
defop("merge", dp_cap=ST, buf_cap=SI, cap_on=None)       # §5.2 Merge step
defop("const", dp_cap=ST, buf_cap=SO, cap_on=None)
# store is PR: a sharded sink — each host persists its own shard (sharded
# checkpointing), so no Merge is forced before it.  (Treating store as ST,
# per the paper's Table 5, all-gathered full 32k-prefill logits to every
# device: +1.17e12 wire bytes on gemma3-27b×prefill_32k.  See §Perf.)
defop("store", dp_cap=PR, buf_cap=SI)

# --- embedding / head
defop("embed_gather")                                     # PR over batch
defop("unembed_matmul", buf_cap=SS)
defop("softmax_xent_xla", buf_cap=SI, cap_all=True)       # logits+labels sharded

# --- norms / elementwise
defop("rmsnorm_xla")
defop("residual_add_xla", cap_all=True)
defop("concat_seq", cap_all=True)

# --- attention family
defop("q_proj_xla"); defop("k_proj_xla"); defop("v_proj_xla")
defop("qkv_proj_fused")                                   # fused projection
defop("pack_qkv_xla", cap_all=True)
defop("sdpa_xla")                                         # full masked attention
defop("sdpa_banded_xla")                                  # O(S·W) local window
defop("attn_flash_pallas", dp_cap=EX, buf_cap=SS, backend="pallas")
defop("out_proj_xla")
defop("cross_attention_xla")

# --- mlp family
defop("ffn_up_xla"); defop("ffn_gate_xla")
defop("ffn_glu_xla", cap_all=True)
defop("ffn_act_xla"); defop("ffn_down_xla")
defop("mlp_fused_xla")                                    # single fused GLU block

# --- MoE family
defop("moe_dense_onehot")                                 # dense dispatch einsum
defop("moe_dropping")                                     # capacity-dropped dispatch
defop("moe_gmm_pallas", dp_cap=EX, buf_cap=SS, backend="pallas")

# --- recurrent families
defop("rwkv_channel_mix")
defop("wkv6_scan_xla", buf_cap=SS)
defop("wkv6_pallas", dp_cap=EX, buf_cap=SS, backend="pallas")
defop("ssd_chunked_xla", buf_cap=SS)
defop("ssd_pallas", dp_cap=EX, buf_cap=SS, backend="pallas")

# --- higher order
defop("scan_layers_xla", buf_cap=B, cap_on="batch")
# tuple projection (KV-collecting scans return (carry, kv); the serving
# prefill plan extracts both).  Blocking for buffering purposes: the tuple
# is produced whole by the scan.
defop("tuple_get_xla", buf_cap=B)

# --- tri-store engines (relational / graph / text) + cross-engine movement.
# Store operators are ST (they run whole-relation/whole-graph inside their
# engine, excluded from batch partitioning exactly as the paper excludes
# external engines), and the graph Pallas kernels are EX like the other
# Pallas ops.
defop("rel_scan_col", dp_cap=ST, buf_cap=SO, cap_on=None, backend="rel")
defop("rel_filter_col", dp_cap=ST, buf_cap=SS, cap_on=None, backend="rel")
defop("rel_hash_join", dp_cap=ST, buf_cap=SI, cap_on=None, backend="rel")
# bounded relations: non-unique-build join into a capacity-bounded output,
# prefix compaction (XLA gather vs Pallas one-hot scatter), and the MXU
# probe kernel gated on the build side's expected count
defop("bounded_join_col", dp_cap=ST, buf_cap=SI, cap_on=None, backend="rel")
defop("rel_join_probe_pallas", dp_cap=EX, buf_cap=SI, cap_on=None,
      backend="pallas")
defop("compact_prefix_col", dp_cap=ST, buf_cap=SS, cap_on=None, backend="rel")
defop("compact_prefix_pallas", dp_cap=EX, buf_cap=SS, cap_on=None,
      backend="pallas")
defop("rel_group_agg_col", dp_cap=ST, buf_cap=SI, cap_on=None, backend="rel")
defop("col_tensor_rel", dp_cap=ST, buf_cap=SO, cap_on=None, backend="rel")
defop("graph_expand_csr", dp_cap=ST, buf_cap=SS, cap_on=None, backend="graph")
defop("graph_expand_pallas", dp_cap=EX, buf_cap=SS, cap_on=None,
      backend="pallas")
defop("graph_pagerank_csr", dp_cap=ST, buf_cap=SS, cap_on=None,
      backend="graph")
defop("graph_pagerank_skip", dp_cap=ST, buf_cap=SS, cap_on=None,
      backend="graph")
defop("graph_pagerank_pallas", dp_cap=EX, buf_cap=SS, cap_on=None,
      backend="pallas")
defop("graph_tricount_csr", dp_cap=ST, buf_cap=SI, cap_on=None,
      backend="graph")
defop("text_topk_inv", dp_cap=ST, buf_cap=SI, cap_on=None, backend="text")
# predicate-pushdown physical surface: mask export from the relational
# engine, full-corpus scoring, tensor-level masked top-k, and the masked
# scoring realizations (dense, block-skipping, Pallas one-hot superkernel)
defop("sel_mask_rel", dp_cap=ST, buf_cap=SO, cap_on=None, backend="rel")
defop("text_scores_inv", dp_cap=ST, buf_cap=SI, cap_on=None, backend="text")
defop("masked_topk_xla", dp_cap=ST, buf_cap=SI, cap_on=None)
defop("text_topk_skip_inv", dp_cap=ST, buf_cap=SI, cap_on=None,
      backend="text")
defop("text_topk_masked_pallas", dp_cap=EX, buf_cap=SS, cap_on=None,
      backend="pallas")
# fused same-engine store chains (fuse_store_ops product) + the masked
# segment-aggregate superkernel; block-skipping frontier expansion
defop("rel_fused_col", dp_cap=ST, buf_cap=SI, cap_on=None, backend="rel")
defop("rel_fused_agg_pallas", dp_cap=EX, buf_cap=SI, cap_on=None,
      backend="pallas")
defop("graph_expand_skip", dp_cap=ST, buf_cap=SS, cap_on=None,
      backend="graph")
# cross-engine transfer: pin keeps the value device-resident (AWESOME's
# in-memory placement), spill materializes it through the host (the
# federated-baseline behaviour).  Spill is blocking for buffering purposes.
defop("xfer_pin", dp_cap=ST, buf_cap=SS, cap_on=None)
defop("xfer_spill", dp_cap=ST, buf_cap=B, cap_on=None)
# mesh-kinded transfers (shard_stores product): local = layout-compatible
# pointer move (zero wire bytes), replicate = all-gather to every device,
# repartition = all-to-all reshuffle onto the join key's owner shards
defop("xfer_local", dp_cap=ST, buf_cap=SS, cap_on=None)
defop("xfer_replicate", dp_cap=ST, buf_cap=SS, cap_on=None)
defop("xfer_repartition", dp_cap=ST, buf_cap=SS, cap_on=None)


# --------------------------------------------------------------------------
# Physical plan structure
# --------------------------------------------------------------------------


@dataclass
class PhysNode:
    id: str
    impl: str                      # PHYS_OPS key, or "virtual"
    inputs: tuple = ()
    attrs: dict = field(default_factory=dict)
    subplan: Optional["PhysPlan"] = None   # for scan_layers
    virtual: bool = False

    @property
    def opdef(self) -> PhysicalOpDef:
        return PHYS_OPS[self.impl]


@dataclass
class Candidate:
    """One candidate physical sub-plan for a virtual node: a linear chain of
    impls applied in order (first consumes the virtual node's inputs)."""

    name: str
    impls: tuple                   # impl names, applied in sequence
    requires_backend: str = "xla"  # "xla" | "pallas"
    when: Optional[Callable] = None  # (logical nodes) -> bool availability


@dataclass
class PhysPlan:
    name: str = "pplan"
    nodes: dict = field(default_factory=dict)
    inputs: dict = field(default_factory=dict)
    outputs: tuple = ()
    types: dict = field(default_factory=dict)
    pm: dict = field(default_factory=dict)   # virtual node id -> [Candidate]
    logical_of: dict = field(default_factory=dict)  # phys id -> [logical Node]
    _ctr: int = 0

    def add(self, impl, inputs=(), attrs=None, subplan=None, id=None,
            virtual=False):
        nid = id or f"{impl}_{self._ctr}"
        self._ctr += 1
        if nid in self.nodes:
            raise ValidationError(f"duplicate phys node {nid}")
        self.nodes[nid] = PhysNode(nid, impl, tuple(inputs), dict(attrs or {}),
                                   subplan, virtual)
        return nid

    def topo(self):
        return list(self.nodes.values())

    def consumers(self):
        out = {i: [] for i in list(self.inputs) + list(self.nodes)}
        for n in self.nodes.values():
            for i in n.inputs:
                out[i].append(n.id)
        return out


# --------------------------------------------------------------------------
# Pattern set (Def. 5.1) — ordered by size, largest first
# --------------------------------------------------------------------------


@dataclass
class Pattern:
    name: str
    seq: tuple                     # logical op-name chain to match
    candidates: tuple              # tuple[Candidate]; len==1 → direct replace

    @property
    def size(self):
        return len(self.seq)


def _has_window(nodes):
    return any(n.attrs.get("window") for n in nodes)


def _not_spill_only(nodes):
    return not any(n.attrs.get("spill_only") for n in nodes)


def _unkinded(nodes):
    return (_not_spill_only(nodes)
            and not any(n.attrs.get("kind") for n in nodes))


def _kind_is(kind):
    def gate(nodes):
        return any(n.attrs.get("kind") == kind for n in nodes)
    return gate


# masked-candidate gates: the skip/fused realizations are offered only when
# a doc mask was pushed in *and* the estimated selectivity is low enough
# that skipping can plausibly win — above the threshold the dense plan is
# the only candidate, so at selectivity 1.0 the unpushed execution is kept
SKIP_SELECTIVITY_THRESHOLD = 0.25


def _skip_worthwhile(nodes):
    return (len(nodes[0].inputs) == 3
            and float(nodes[0].attrs.get("selectivity", 1.0))
            <= SKIP_SELECTIVITY_THRESHOLD)


def _frontier_sparse(nodes):
    return (float(nodes[0].attrs.get("frontier_selectivity", 1.0))
            <= SKIP_SELECTIVITY_THRESHOLD)


def _personalization_sparse(nodes):
    """First-iteration PageRank pushdown: offered only when a pushed
    selection made the personalization vector sparse."""
    return (len(nodes[0].inputs) == 2
            and float(nodes[0].attrs.get("personalization_selectivity", 1.0))
            <= SKIP_SELECTIVITY_THRESHOLD)


# the MXU probe kernel holds the whole build side in one VMEM block, so the
# gate bounds the build side's *physical capacity* (what actually rides in
# VMEM and widens the one-hot), and requires a known expected count — the
# quantity the cost model prices the candidate on
JOIN_PROBE_MAX_BUILD = 4096


def _probe_kernel_ok(nodes):
    a = nodes[0].attrs
    return (0 < int(a.get("build_expected", 0))
            and 0 < int(a.get("build_rows", 0)) <= JOIN_PROBE_MAX_BUILD)


def _compact_kernel_ok(nodes):
    """The one-hot compaction kernel routes every column through a float32
    matmul: bit-exact for float/bool columns, lossy above 2^24 for integer
    keys — which cannot be bounded statically, so integer columns keep the
    gather realization."""
    dts = nodes[0].attrs.get("col_dtypes")
    return bool(dts) and all(str(d).startswith("float") or str(d) == "bool"
                             for d in dts)


def _agg_kernel_ok(nodes):
    """The masked segment-aggregate kernel covers the sum family only (max
    needs a segment-max reduction the one-hot matmul cannot express)."""
    chain = nodes[0].attrs.get("chain", ())
    if not chain or chain[-1][0] != "rel_group_agg":
        return False
    return all(fn in ("sum", "count", "mean")
               for _, fn, _c in chain[-1][1]["aggs"])


DEFAULT_PATTERNS = (
    # fused attention: the map-fusion product (Fig. 7's larger-pattern win)
    Pattern(
        "fused_attention", ("qkv_proj", "sdpa", "out_proj"),
        (
            Candidate("attn_xla", ("qkv_proj_fused", "sdpa_xla", "out_proj_xla")),
            Candidate("attn_flash",
                      ("qkv_proj_fused", "attn_flash_pallas", "out_proj_xla"),
                      requires_backend="pallas"),
            Candidate("attn_banded",
                      ("qkv_proj_fused", "sdpa_banded_xla", "out_proj_xla"),
                      when=_has_window),
        ),
    ),
    # unfused attention still plannable (pre-fusion plans work, just worse)
    Pattern(
        "sdpa_only", ("sdpa",),
        (
            Candidate("sdpa_xla", ("sdpa_xla",)),
            Candidate("sdpa_flash", ("attn_flash_pallas",),
                      requires_backend="pallas"),
            Candidate("sdpa_banded", ("sdpa_banded_xla",), when=_has_window),
        ),
    ),
    Pattern(
        "moe_block", ("moe",),
        (
            Candidate("moe_dense", ("moe_dense_onehot",)),
            Candidate("moe_drop", ("moe_dropping",)),
            Candidate("moe_gmm", ("moe_gmm_pallas",), requires_backend="pallas"),
        ),
    ),
    Pattern(
        "wkv6_block", ("wkv6",),
        (
            Candidate("wkv6_xla", ("wkv6_scan_xla",)),
            Candidate("wkv6_pallas", ("wkv6_pallas",), requires_backend="pallas"),
        ),
    ),
    Pattern(
        "ssd_block", ("ssd",),
        (
            Candidate("ssd_xla", ("ssd_chunked_xla",)),
            Candidate("ssd_pallas", ("ssd_pallas",), requires_backend="pallas"),
        ),
    ),
    # graph frontier ops: Pallas scatter-add kernel on TPU-capable engines,
    # segment_sum CSR fallback otherwise (the paper's external-engine story)
    Pattern(
        "graph_expand_op", ("graph_expand",),
        (
            Candidate("expand_csr", ("graph_expand_csr",),
                      requires_backend="graph"),
            Candidate("expand_pallas", ("graph_expand_pallas",),
                      requires_backend="pallas"),
            # frontier-mask pushdown: per-hop block-skipping SpMV, offered
            # when the estimated frontier sparsity makes skipping plausible
            Candidate("expand_skip", ("graph_expand_skip",),
                      requires_backend="graph", when=_frontier_sparse),
        ),
    ),
    # text top-k: dense scoring always; with a pushed candidate-doc mask at
    # low estimated selectivity, the block-skipping and Pallas masked
    # superkernels compete on the cost model's selectivity-priced features
    Pattern(
        "text_topk_op", ("text_topk",),
        (
            Candidate("topk_dense", ("text_topk_inv",),
                      requires_backend="text"),
            Candidate("topk_blockskip", ("text_topk_skip_inv",),
                      requires_backend="text", when=_skip_worthwhile),
            Candidate("topk_masked_pallas", ("text_topk_masked_pallas",),
                      requires_backend="pallas", when=_skip_worthwhile),
        ),
    ),
    # fused store chains: the single-call columnar realization vs the
    # masked segment-aggregate Pallas superkernel for agg-terminated chains
    Pattern(
        "rel_fused_op", ("rel_fused",),
        (
            Candidate("rel_fused_col", ("rel_fused_col",),
                      requires_backend="rel"),
            Candidate("rel_fused_agg", ("rel_fused_agg_pallas",),
                      requires_backend="pallas", when=_agg_kernel_ok),
        ),
    ),
    Pattern(
        "graph_pagerank_op", ("graph_pagerank",),
        (
            Candidate("pagerank_csr", ("graph_pagerank_csr",),
                      requires_backend="graph"),
            Candidate("pagerank_pallas", ("graph_pagerank_pallas",),
                      requires_backend="pallas"),
            # personalization-sparsity pushdown: iteration 0's SpMV
            # block-skips on the pushed mask's support (bitwise-identical)
            Candidate("pagerank_skip", ("graph_pagerank_skip",),
                      requires_backend="graph",
                      when=_personalization_sparse),
        ),
    ),
    # equi-join probe: the sort + binary-search realization always; the MXU
    # key-equality kernel when the build side's expected count is bounded
    # enough to ride in VMEM (capacity-bounded builds: compacted filters,
    # top-k relations)
    Pattern(
        "rel_join_op", ("rel_join",),
        (
            Candidate("join_sort_probe", ("rel_hash_join",),
                      requires_backend="rel"),
            Candidate("join_probe_kernel", ("rel_join_probe_pallas",),
                      requires_backend="pallas", when=_probe_kernel_ok),
        ),
    ),
    # prefix compaction: XLA gather vs the Pallas one-hot scatter kernel
    Pattern(
        "compact_op", ("compact",),
        (
            Candidate("compact_gather", ("compact_prefix_col",),
                      requires_backend="rel"),
            Candidate("compact_onehot", ("compact_prefix_pallas",),
                      requires_backend="pallas", when=_compact_kernel_ok),
        ),
    ),
    # cross-engine transfer: the cost model chooses the materialization
    # point per boundary (pin = stay in device memory, spill = host
    # round-trip).  ``spill_only`` xfers (the naive-placement baseline)
    # exclude the pin candidate.
    Pattern(
        "xfer_op", ("xfer",),
        (
            # mesh-kinded xfers (shard_stores) pair with the spill fallback
            # so the cost model genuinely prices all-gather/all-to-all wire
            # bytes against the host round-trip
            Candidate("xfer_local", ("xfer_local",), when=_kind_is("local")),
            Candidate("xfer_replicate", ("xfer_replicate",),
                      when=_kind_is("replicate")),
            Candidate("xfer_repartition", ("xfer_repartition",),
                      when=_kind_is("repartition")),
            Candidate("xfer_pin", ("xfer_pin",), when=_unkinded),
            Candidate("xfer_spill", ("xfer_spill",)),
        ),
    ),
)

# single-candidate direct mappings (Alg. 2 lines 6–7)
DIRECT_IMPL = {
    "const": "const",
    "embed": "embed_gather",
    "rmsnorm": "rmsnorm_xla",
    "residual_add": "residual_add_xla",
    "unembed": "unembed_matmul",
    "softmax_xent": "softmax_xent_xla",
    "q_proj": "q_proj_xla",
    "k_proj": "k_proj_xla",
    "v_proj": "v_proj_xla",
    "pack_qkv": "pack_qkv_xla",
    "qkv_proj": "qkv_proj_fused",
    "out_proj": "out_proj_xla",
    "ffn_up": "ffn_up_xla",
    "ffn_gate": "ffn_gate_xla",
    "ffn_glu": "ffn_glu_xla",
    "ffn_act": "ffn_act_xla",
    "ffn_down": "ffn_down_xla",
    "mlp": "mlp_fused_xla",
    "rwkv_channel_mix": "rwkv_channel_mix",
    "concat_seq": "concat_seq",
    "cross_attention": "cross_attention_xla",
    "attention": None,   # must be decomposed first; see rewrite.decompose
    "store": "store",
    "tuple_get": "tuple_get_xla",
    # tri-store single-candidate ops
    "rel_scan": "rel_scan_col",
    "rel_filter": "rel_filter_col",
    # rel_join and compact are pattern-matched (probe-kernel / Pallas
    # compaction candidates); bounded_join has one realization
    "bounded_join": "bounded_join_col",
    "rel_group_agg": "rel_group_agg_col",
    "col_tensor": "col_tensor_rel",
    "graph_tricount": "graph_tricount_csr",
    # text_topk is pattern-matched (masked candidates); these stay direct
    "sel_mask": "sel_mask_rel",
    "text_scores": "text_scores_inv",
    "masked_topk": "masked_topk_xla",
}


# --------------------------------------------------------------------------
# Algorithm 2 — candidate physical plan generation
# --------------------------------------------------------------------------


def _find_chain_matches(plan: Plan, seq, claimed):
    """Find non-overlapping linear chains matching ``seq`` where interior
    nodes have a single consumer (so substitution is sound)."""
    cons = plan.consumers()
    matches = []
    for n in plan.topo():
        if n.op != seq[0] or n.id in claimed:
            continue
        chain = [n]
        ok = True
        cur = n
        for want in seq[1:]:
            nxt_ids = cons[cur.id]
            if len(nxt_ids) != 1:
                ok = False
                break
            nxt = plan.nodes[nxt_ids[0]]
            if nxt.op != want or nxt.id in claimed:
                ok = False
                break
            chain.append(nxt)
            cur = nxt
        if ok:
            matches.append(chain)
            claimed.update(c.id for c in chain)
    return matches


def generate_candidates(plan: Plan, patterns=DEFAULT_PATTERNS,
                        engines=None, allow_pallas=None,
                        threads: int = 1) -> PhysPlan:
    """Alg. 2: largest-first pattern matching over the optimized logical plan.

    ``engines`` names the execution engines whose candidates may be offered
    (default: the always-available ``xla`` interpreter engine; on CPU
    dry-runs the Pallas engines are excluded, exactly as the paper excludes
    EX engines from optimization choices it cannot calibrate).  The legacy
    ``allow_pallas`` boolean is still accepted and maps onto the registry.

    ``threads > 1`` generates the candidate sub-plans of scan-groups
    (``scan_layers``/higher-order subplans) in a thread pool.  Generation is
    pure per subplan, so the product is identical to the serial path — only
    wall time changes.
    """
    from .engines import resolve_engines
    engines = resolve_engines(engines, allow_pallas=allow_pallas)

    # parallel scan-group prepass: each higher-order node's subplan is an
    # independent generation problem
    pregen: dict = {}
    sub_nodes = [n for n in plan.topo()
                 if n.subplan is not None
                 and n.op in ("scan_layers", "map", "filter", "reduce")]
    if threads and threads > 1 and len(sub_nodes) > 1:
        from concurrent.futures import ThreadPoolExecutor
        with ThreadPoolExecutor(max_workers=int(threads)) as ex:
            futs = {n.id: ex.submit(generate_candidates, n.subplan, patterns,
                                    engines, None, threads)
                    for n in sub_nodes}
            pregen = {nid: f.result() for nid, f in futs.items()}

    ordered = sorted(patterns, key=lambda p: -p.size)
    claimed: set = set()
    pat_of: dict = {}           # head node id -> (Pattern, chain)
    for pat in ordered:
        for chain in _find_chain_matches(plan, pat.seq, claimed):
            pat_of[chain[0].id] = (pat, chain)

    pp = PhysPlan(plan.name, {}, dict(plan.inputs), (), dict(plan.types))
    remap: dict = {i: i for i in plan.inputs}
    in_chain: dict = {}
    for head, (pat, chain) in pat_of.items():
        for c in chain:
            in_chain[c.id] = head

    emitted: set = set()
    remap_target: dict = {}
    for node in plan.topo():
        if node.id in in_chain:
            head = in_chain[node.id]
            if head in emitted:
                remap[node.id] = remap_target[head]
                continue
            pat, chain = pat_of[head]
            cands = [c for c in pat.candidates
                     if c.requires_backend in engines
                     and (c.when is None or c.when(chain))]
            attrs = {}
            for c in chain:
                attrs.update(c.attrs)
            attrs["pattern"] = pat.name
            attrs.setdefault("pp", chain[0].attrs.get("pp"))
            ext_inputs = [remap[i] for i in chain[0].inputs]
            out_t = plan.types.get(chain[-1].id)
            if len(cands) == 1:
                # single candidate → direct replacement (Alg.2 lines 6–7)
                nid = _emit_chain(pp, cands[0], ext_inputs, attrs, chain)
            else:
                nid = pp.add("identity", ext_inputs, attrs,
                             id=f"virt_{plan.name}_{pat.name}_{chain[0].id}",
                             virtual=True)
                pp.pm[nid] = cands
                pp.logical_of[nid] = chain
            pp.types[nid] = out_t
            emitted.add(head)
            remap_target[head] = nid
            for c in chain:
                remap[c.id] = nid
            continue

        impl = DIRECT_IMPL.get(node.op)
        sub = None
        if node.op == "scan_layers":
            impl = "scan_layers_xla"
            sub = pregen.get(node.id) or generate_candidates(
                node.subplan, patterns, engines, threads=threads)
        elif node.op in ("map", "filter", "reduce"):
            impl = node.op  # handled natively by the executor
            if node.subplan is not None:
                sub = pregen.get(node.id) or generate_candidates(
                    node.subplan, patterns, engines, threads=threads)
            if impl not in PHYS_OPS:
                defop(impl, dp_cap=PR, buf_cap=SS, cap_on="elem")
        if impl is None:
            raise ValidationError(
                f"no physical impl for logical op {node.op!r} "
                f"(did you run rewrite.decompose?)")
        nid = pp.add(impl, [remap[i] for i in node.inputs], dict(node.attrs),
                     sub, id=node.id)
        pp.types[nid] = plan.types.get(node.id)
        pp.logical_of[nid] = [node]
        remap[node.id] = nid

    pp.outputs = tuple(remap[o] for o in plan.outputs)
    return pp


def _emit_chain(pp: PhysPlan, cand: Candidate, ext_inputs, attrs, chain):
    prev = None
    nid = None
    for j, impl in enumerate(cand.impls):
        ins = ext_inputs if j == 0 else [prev]
        nid = pp.add(impl, ins, dict(attrs),
                     id=f"{chain[0].id}__{cand.name}_{j}")
        pp.logical_of[nid] = chain if j == 0 else []
        prev = nid
    return nid


def materialize_choice(pp: PhysPlan, choices: dict) -> PhysPlan:
    """Replace each virtual node with its chosen candidate chain (§6.3:
    'the best sub-plan with the lowest cost will be selected')."""
    out = PhysPlan(pp.name, {}, dict(pp.inputs), (), dict(pp.types))
    remap = {i: i for i in pp.inputs}
    for n in pp.topo():
        sub = n.subplan
        if sub is not None:
            sub = materialize_choice(sub, choices)
        if n.virtual:
            cand = choices[n.id]
            nid = _emit_chain(out, cand, [remap[i] for i in n.inputs],
                              dict(n.attrs), [n])
            out.types[nid] = pp.types.get(n.id)
        else:
            nid = out.add(n.impl, [remap[i] for i in n.inputs], dict(n.attrs),
                          sub, id=n.id)
            out.types[nid] = pp.types.get(n.id)
        remap[n.id] = nid
    out.outputs = tuple(remap[o] for o in pp.outputs)
    return out
