"""Textual ADIL front end (paper §2 grammar, Fig. 3 style).

The paper's first contribution is ADIL itself — a dataflow language of
assignment statements.  This parser accepts the tensor-world dialect and
produces a validated :class:`~repro.core.ir.Plan` through the same
:class:`~repro.core.adil.Analysis` builder the embedded DSL uses, so a
script and the equivalent Python build the identical logical plan.

Grammar (recursive descent; ``<ho-expr>`` covers the paper's map/reduce):

    script      := "USE" ident ";" "create" "analysis" ident "as" "{" stmt* "}"
    stmt        := ident ":=" expr ";"   |   "store" "(" ident ")" ";"
    expr        := input-expr | call-expr | ho-expr
    input-expr  := "input" "(" shape "," dtype ["," "dims" "=" list] ")"
    call-expr   := ident "(" ident ("," kwarg)* ")"
    ho-expr     := ("map"|"reduce") "(" ident "," ident "->" call-expr ")"
    kwarg       := ident "=" value
    value       := number | string | bool | list | ident

Example::

    USE demoDB;
    create analysis tiny as {
      toks := input([2, 16], int32, dims=[batch, seq]);
      h    := embed(toks, vocab=64, embed=32, pp=[embed], dtype=float32);
      h2   := attention(h, heads=4, kv_heads=2, head_dim=8, embed=32,
                        pp=[attn]);
      out  := mlp(h2, ffn=64, embed=32, pp=[mlp]);
      store(out);
    }
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Optional

from .adil import Analysis, Var
from .ir import FunctionCatalog, Plan, TensorT, ValidationError

_TOKEN = re.compile(r"""
    (?P<ws>\s+|//[^\n]*)
  | (?P<num>-?\d+\.\d+|-?\d+)
  | (?P<str>"[^"]*"|'[^']*')
  | (?P<assign>:=)
  | (?P<arrow>->)
  | (?P<punct>[{}()\[\],;=])
  | (?P<ident>[A-Za-z_][\w.\-]*)
""", re.VERBOSE)


def _tokenize(src: str):
    out, pos = [], 0
    while pos < len(src):
        m = _TOKEN.match(src, pos)
        if not m:
            raise ValidationError(f"ADIL: bad character at {src[pos:pos+20]!r}")
        pos = m.end()
        if m.lastgroup == "ws":
            continue
        out.append((m.lastgroup, m.group()))
    out.append(("eof", ""))
    return out


class _Parser:
    def __init__(self, toks, catalog: FunctionCatalog):
        self.toks = toks
        self.i = 0
        self.catalog = catalog
        self.analysis: Optional[Analysis] = None
        self.env: dict = {}

    # -- token helpers -------------------------------------------------------
    def peek(self):
        return self.toks[self.i]

    def next(self, kind=None, value=None):
        k, v = self.toks[self.i]
        if (kind and k != kind) or (value is not None and v != value):
            raise ValidationError(
                f"ADIL: expected {value or kind}, got {v!r} (token {self.i})")
        self.i += 1
        return v

    def accept(self, value):
        if self.peek()[1] == value:
            self.i += 1
            return True
        return False

    # -- grammar -------------------------------------------------------------
    def script(self) -> Analysis:
        self.next("ident", "USE")
        self.next("ident")                       # polystore instance alias
        self.next("punct", ";")
        self.next("ident", "create")
        self.next("ident", "analysis")
        name = self.next("ident")
        self.next("ident", "as")
        self.next("punct", "{")
        self.analysis = Analysis(name, self.catalog)
        while not self.accept("}"):
            self.stmt()
        if not self.analysis._stores:
            raise ValidationError(f"analysis {name!r} has no store statements")
        self.analysis.plan.set_outputs(*self.analysis._stores)
        return self.analysis

    def _lookup(self, name: str) -> Var:
        if name not in self.env:
            raise ValidationError(f"ADIL: unknown variable {name!r}")
        return self.env[name]

    def stmt(self):
        if self.peek()[1] == "store":
            self.next("ident", "store")
            self.next("punct", "(")
            var = self._lookup(self.next("ident"))
            self.next("punct", ")")
            self.next("punct", ";")
            self.analysis.store(var)
            return
        lhs = self.next("ident")
        self.next("assign")
        self.env[lhs] = self.expr(lhs)
        self.next("punct", ";")

    def expr(self, lhs: str) -> Var:
        head = self.next("ident")
        self.next("punct", "(")
        if head in ("table", "graph", "corpus"):
            return self._store_decl(head, lhs)
        if head == "input":
            shape = tuple(self.value())
            self.next("punct", ",")
            dtype = self.next("ident")
            dims = ()
            while self.accept(","):
                key = self.next("ident")
                self.next("punct", "=")
                if key != "dims":
                    raise ValidationError("input(): only dims= allowed")
                dims = tuple(self.value())
            self.next("punct", ")")
            return self.analysis.input(
                lhs, TensorT(shape, dtype, dims))
        if head in ("map", "reduce"):
            coll = self._lookup(self.next("ident"))
            self.next("punct", ",")
            local = self.next("ident")
            self.next("arrow")
            sub = self._lambda_body(local)
            self.next("punct", ")")
            if head == "map":
                return self.analysis.map(coll, sub)
            raise ValidationError("reduce literals need a python fn; use the "
                                  "embedded DSL for reduce")
        # ordinary call: first positional args are prior vars
        args, kwargs = [], {}
        while self.peek()[1] != ")":
            k, v = self.peek()
            if k == "ident" and self.toks[self.i + 1][1] == "=":
                key = self.next("ident")
                self.next("punct", "=")
                kwargs[key] = self.value()
            else:
                args.append(self._lookup(self.next("ident")))
            self.accept(",")
        self.next("punct", ")")
        return self.analysis.op(head, *args, **kwargs)

    def _store_decl(self, kind: str, lhs: str) -> Var:
        """Native store types (paper §2.1): ``table(rows=N, cols=[[name,
        dtype], ...])``, ``graph(nodes=N, edges=E)``, ``corpus(docs=D,
        vocab=V, postings=P)`` declare typed tri-store inputs."""
        kwargs = {}
        while self.peek()[1] != ")":
            key = self.next("ident")
            self.next("punct", "=")
            kwargs[key] = self.value()
            self.accept(",")
        self.next("punct", ")")
        try:
            if kind == "table":
                cols = tuple((str(c[0]), str(c[1]))
                             for c in kwargs["cols"])
                return self.analysis.table(lhs, kwargs["rows"], cols)
            if kind == "graph":
                return self.analysis.graph(
                    lhs, kwargs["nodes"], kwargs["edges"],
                    kwargs.get("weighted", False))
            return self.analysis.corpus(
                lhs, kwargs["docs"], kwargs["vocab"], kwargs["postings"])
        except (KeyError, IndexError, TypeError) as e:
            raise ValidationError(f"ADIL: bad {kind}() declaration: {e}")

    def _lambda_body(self, local: str) -> Plan:
        """`x -> op(x, k=v, ...)` becomes a single-op subplan."""
        op_name = self.next("ident")
        self.next("punct", "(")
        sub = Plan(f"lambda_{op_name}")
        # the element type is inferred later by map's validator; use a
        # placeholder tensor type that infer_types overwrites
        sub.add_input(local, TensorT((), "float32"))
        kwargs = {}
        saw_local = False
        while self.peek()[1] != ")":
            k, v = self.peek()
            if k == "ident" and self.toks[self.i + 1][1] == "=":
                key = self.next("ident")
                self.next("punct", "=")
                kwargs[key] = self.value()
            else:
                nm = self.next("ident")
                if nm != local:
                    raise ValidationError(
                        f"lambda may only reference {local!r}")
                saw_local = True
            self.accept(",")
        self.next("punct", ")")
        if not saw_local:
            raise ValidationError("lambda body must use its argument")
        nid = sub.add(op_name, [local], kwargs)
        sub.set_outputs(nid)
        return sub

    def value(self) -> Any:
        k, v = self.peek()
        if k == "num":
            self.i += 1
            return float(v) if "." in v else int(v)
        if k == "str":
            self.i += 1
            return v[1:-1]
        if v == "[":
            self.i += 1
            out = []
            while not self.accept("]"):
                out.append(self.value())
                self.accept(",")
            return out
        if k == "ident":
            self.i += 1
            if v in ("true", "True"):
                return True
            if v in ("false", "False"):
                return False
            return v  # bare identifiers: dtypes, dim names, pp path parts
        raise ValidationError(f"ADIL: bad value {v!r}")


def parse_adil(src: str, catalog: FunctionCatalog) -> Analysis:
    """Parse an ADIL script into a validated Analysis.

    Convention: list-valued ``pp=[a, b]`` kwargs become param-path tuples,
    ``dims=[batch, seq]`` become dim-name tuples.
    """
    parser = _Parser(_tokenize(src), catalog)
    analysis = parser.script()
    # normalize: pp/dims lists of idents -> tuples of strings
    for node in analysis.plan.topo():
        for key in ("pp",):
            if key in node.attrs and isinstance(node.attrs[key], list):
                node.attrs[key] = tuple(str(x) for x in node.attrs[key])
    from .ir import infer_types
    infer_types(analysis.plan, catalog)
    return analysis


# canonical short name: a script and the equivalent embedded-DSL build
# produce the identical logical plan — and therefore the identical
# ``plan_id`` (see tests/test_plan_pipeline.py round-trip)
parse = parse_adil
