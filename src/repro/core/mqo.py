"""Multi-query optimization: cross-query subplan dedup over content hashes.

Concurrent analytical queries on a shared tri-store overlap heavily — the
same scans, the same filtered relations, the same PageRank over the same
graph snapshot — yet each ``run_analysis`` call so far executed its plan
alone.  This module is the sharing layer:

  * **Runtime sub-DAG keys** — :func:`ir.subdag_fingerprints` over the
    staged plan's concrete physical plan, with every reachable plan input
    bound to a runtime identity (:func:`input_keys_for`: bound-store
    *versions*, small-argument content hashes) and the staged plan's
    ``mqo_salt`` (cost-model + feedback fingerprints) folded in.  Two
    queries' nodes get the same key iff the value computed under them is
    identical — across textually different programs, across processes.
  * :class:`SubplanCache` — key -> materialized intermediate (BoundedRel /
    graph / score pytrees), LRU with **byte-budget** eviction, every entry
    registered in the :class:`~repro.core.ledger.MemoryLedger` under owner
    kind ``"subplan"`` and tied to the producing store's ledger entry +
    version, so an append makes lingering reuse visible as a ledger leak
    (and :meth:`SubplanCache.note_store` evicts it eagerly).  An eviction
    rate above threshold inside the telemetry window trips the flight
    recorder (``subplan_thrash``) with the recent MQO frontier decisions
    in the dump.
  * :func:`mqo_run` — the CSE execution pass: split the concrete plan at
    the cache-hit **frontier**, execute only the residual suffix
    (:func:`~repro.core.executor.run_plan_subset`), insert the fresh
    intermediates back.  Reused values are the exact arrays an identical
    computation produced, so results are bitwise-identical to an isolated
    run by construction.

Single-flighting of *concurrent* identical sub-DAGs lives in the serving
runtime's admission loop (``AsyncServingRuntime.run_analyses``): queries
admitted in one tick are grouped by root key before execution, and an
in-flight future map covers queries arriving while a twin still runs.
"""
from __future__ import annotations

import hashlib
import itertools
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Mapping, Optional, Sequence

import numpy as np

from .executor import ExecContext, run_plan_subset
from .ir import subdag_fingerprints
from .tracing import tree_bytes

# content-hash cap: arguments above this (store payloads, big frontiers)
# get a unique key instead — hashing megabytes per admission would cost
# more than the sharing wins
_MAX_HASH_BYTES = 1 << 22

_uniq = itertools.count()

# impls whose output is an alias of an input or a constant — caching them
# would double-count bytes in the ledger without saving any work
_SKIP_CACHE_IMPLS = frozenset({
    "identity", "store", "const", "virtual",
    "xfer_pin", "xfer_local", "xfer_repartition",
})


def content_key(value, *, max_bytes: int = _MAX_HASH_BYTES) -> Optional[str]:
    """sha256 over a small argument pytree's leaf bytes (dtype + shape +
    data, dict keys sorted); None when the pytree is too large to hash or
    contains unhashable leaves.  Every leaf is framed with a type tag and
    a terminator, and containers emit open/close markers, so adjacent
    values can never run together: ``[1, 2]`` != ``[12]``, ``{}`` !=
    ``[]``, ``[1.5, 2]`` != ``[1.52]``."""
    h = hashlib.sha256()
    total = 0

    def walk(v):
        nonlocal total
        if isinstance(v, dict):
            h.update(b"{")
            for k in sorted(v):
                h.update(b"k:" + repr(k).encode() + b"=")
                if not walk(v[k]):
                    return False
            h.update(b"}")
            return True
        if isinstance(v, (list, tuple)):
            h.update(b"[" if isinstance(v, list) else b"(")
            for x in v:
                if not walk(x):
                    return False
            h.update(b"]" if isinstance(v, list) else b")")
            return True
        if hasattr(v, "shape") and hasattr(v, "dtype"):
            a = np.asarray(v)
            total += a.nbytes
            if total > max_bytes:
                return False
            # dtype + shape frame the raw bytes: their length is fixed
            # given the header, so no delimiter can be forged by data
            h.update(b"a:" + str(a.dtype).encode()
                     + b":" + repr(a.shape).encode() + b":")
            h.update(a.tobytes())
            h.update(b";")
            return True
        if isinstance(v, (int, float, bool, str, bytes, type(None))):
            h.update(type(v).__name__.encode()
                     + b":" + repr(v).encode() + b";")
            return True
        return False

    if not walk(value):
        return None
    return "sha:" + h.hexdigest()


def input_keys_for(inputs: Mapping[str, Any],
                   versions: Any = ()) -> dict:
    """Runtime identity per plan input, the ``leaf_keys`` of a sub-DAG key.

    ``versions``: the bound stores' ``(name, version)`` vector (what
    ``adil.Analysis.store_versions`` returns) or an equivalent mapping.
    A versioned input's key is its version — O(1), and an append provably
    changes it.  Unversioned inputs are content-hashed when small; inputs
    too large to hash get a **unique** key, so they can never produce a
    false cache hit (only missed sharing)."""
    vmap = dict(versions)
    keys = {}
    for name, v in inputs.items():
        if name in vmap:
            keys[name] = f"ver:{name}:{int(vmap[name])}"
            continue
        ck = content_key(v)
        keys[name] = ck if ck is not None else \
            f"uniq:{name}:{next(_uniq)}"
    return keys


def params_key(params) -> str:
    """Runtime identity of a query's parameter pytree.  Physical ops read
    params through ``ctx.params_for`` (pp-attr bindings), so two queries
    with equal plans and inputs but different params compute different
    values — the params identity must reach every sub-DAG key.  Empty
    params (the analytical common case) map to a constant so param-free
    queries share freely; non-empty params are content-hashed when small,
    and params too large to hash get a **unique** key — no sharing, but
    never a false hit."""
    if not params:
        return "noparams"
    ck = content_key(params)
    return ck if ck is not None else f"uniq:params:{next(_uniq)}"


class SubplanCache:
    """Content-keyed LRU of materialized sub-DAG intermediates.

    Values are whatever the physical op produced — BoundedRel pytrees,
    CSR frontier vectors, score arrays — held device-resident so a hit
    replaces the entire sub-DAG's execution with a dict lookup.  Bytes are
    bounded by ``byte_budget`` with LRU eviction; each entry registers in
    the ledger under ``("subplan", key)``, tied to the producing store's
    ledger entry at the version it was materialized from.

    Thrash detection: insertions and evictions land in a sliding window;
    when the eviction fraction over a full window reaches
    ``thrash_rate``, the flight recorder trips a ``subplan_thrash`` dump
    carrying the cache stats and the recent MQO frontier decisions —
    the working set no longer fits and queries are evicting each other's
    intermediates instead of sharing them.
    """

    def __init__(self, byte_budget: int = 64 << 20, *,
                 max_entries: int = 512, ledger=None, recorder=None,
                 registry=None, thrash_window: int = 32,
                 thrash_rate: float = 0.5):
        if byte_budget < 1:
            raise ValueError(f"byte_budget must be >= 1, got {byte_budget}")
        self.byte_budget = int(byte_budget)
        self.max_entries = int(max_entries)
        self._ledger = ledger
        self.recorder = recorder
        self.registry = registry
        self._lock = threading.RLock()
        self._entries: OrderedDict = OrderedDict()   # key -> value
        self._sizes: dict = {}                       # key -> bytes
        self._stores: dict = {}    # key -> ((store name, version), ...)
        self.bytes_in_cache = 0
        self.hits = 0
        self.misses = 0
        self.insertions = 0
        self.evictions = 0
        self.version_evictions = 0
        self.oversize_skips = 0
        self.thrash_window = int(thrash_window)
        self.thrash_rate = float(thrash_rate)
        self._events: deque = deque(maxlen=self.thrash_window)  # 1 = evict
        self.thrash_trips = 0
        self.frontier_log: deque = deque(maxlen=32)

    @property
    def ledger(self):
        if self._ledger is None:
            from .ledger import default_ledger
            self._ledger = default_ledger()
        return self._ledger

    # -- lookup / insert ----------------------------------------------------
    def lookup(self, key: str):
        """The cached intermediate under ``key`` (refreshing recency) or
        None.  Returns the value itself — entries are treated as immutable
        by every consumer, exactly like plan-cache entries."""
        with self._lock:
            if key not in self._entries:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            if self.registry is not None:
                self.registry.count("analytics.shared_hits")
            return self._entries[key]

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._entries

    def insert(self, key: str, value, *, stores: Sequence[tuple] = (),
               tied_to=None) -> bool:
        """Insert a materialized intermediate.  ``stores``: the
        ``(name, version)`` pairs of the bound stores this value was
        computed from (recorded for :meth:`note_store` invalidation);
        ``tied_to``: the producing store's ledger owner, giving the entry
        a lifetime anchor — once the store re-registers at a new version,
        a lingering entry shows up in ``ledger.leaks()`` as superseded.
        Returns False when the value alone exceeds the byte budget (not
        cached, counted in ``oversize_skips``)."""
        nb = int(tree_bytes(value))
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                return True
            if nb > self.byte_budget:
                self.oversize_skips += 1
                return False
            while (self.bytes_in_cache + nb > self.byte_budget
                   or len(self._entries) >= self.max_entries):
                self._evict_lru()
            self._entries[key] = value
            self._sizes[key] = nb
            self._stores[key] = tuple(stores)
            self.bytes_in_cache += nb
            ver = None
            if stores:
                ver = int(stores[0][1])
            self.ledger.register(("subplan", key), nbytes=nb,
                                 kind="subplan", version=ver,
                                 tied_to=tied_to)
            self.insertions += 1
            self._events.append(0)
            self._publish()
        return True

    def _evict_lru(self) -> None:
        key, _ = self._entries.popitem(last=False)
        self.bytes_in_cache -= self._sizes.pop(key, 0)
        self._stores.pop(key, None)
        self.ledger.release(("subplan", key))
        self.evictions += 1
        self._events.append(1)
        self._maybe_trip()

    def note_store(self, name: str, version: int) -> int:
        """A bound store moved to ``version``: evict every entry
        materialized from an older version of it.  Runtime keys fold the
        version in, so stale entries could never be *hit* again — this
        reclaims their bytes eagerly instead of waiting for LRU pressure
        (and clears the would-be ledger leak).  Returns evictions."""
        dropped = 0
        with self._lock:
            victims = [k for k, sv in self._stores.items()
                       if any(n == name and int(v) != int(version)
                              for n, v in sv)]
            for k in victims:
                del self._entries[k]
                self.bytes_in_cache -= self._sizes.pop(k, 0)
                self._stores.pop(k, None)
                self.ledger.release(("subplan", k))
                self.version_evictions += 1
                dropped += 1
            if dropped:
                self._publish()
        return dropped

    def note_versions(self, versions: Any) -> int:
        """Vector form of :meth:`note_store` (``(name, version)`` pairs)."""
        return sum(self.note_store(n, v) for n, v in dict(versions).items())

    # -- thrash detection ---------------------------------------------------
    def note_frontier(self, decision: dict) -> None:
        """Record one MQO frontier split (plan id, hit/executed node
        counts) — the context a thrash dump needs to show *which* queries
        were fighting over the budget."""
        self.frontier_log.append(dict(decision, ts=time.time()))

    def _maybe_trip(self) -> None:
        if self.recorder is None or len(self._events) < self.thrash_window:
            return
        rate = sum(self._events) / len(self._events)
        if rate < self.thrash_rate:
            return
        self.thrash_trips += 1
        self._events.clear()           # one trip per full thrashing window
        self.recorder.trip("subplan_thrash", {
            "eviction_rate": rate, "window": self.thrash_window,
            "stats": self.stats(),
            "frontiers": list(self.frontier_log)})

    # -- bookkeeping ---------------------------------------------------------
    def _publish(self) -> None:
        if self.registry is not None:
            self.registry.gauge("subplan.bytes").set(self.bytes_in_cache)
            self.registry.gauge("subplan.entries").set(len(self._entries))

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self) -> None:
        with self._lock:
            self.ledger.release_kind("subplan")
            self._entries.clear()
            self._sizes.clear()
            self._stores.clear()
            self.bytes_in_cache = 0
            self._events.clear()
            self._publish()

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {
                "entries": len(self._entries),
                "bytes": self.bytes_in_cache,
                "byte_budget": self.byte_budget,
                "hits": self.hits,
                "misses": self.misses,
                "insertions": self.insertions,
                "evictions": self.evictions,
                "version_evictions": self.version_evictions,
                "oversize_skips": self.oversize_skips,
                "thrash_trips": self.thrash_trips,
                "hit_rate": (self.hits / total) if total else 0.0,
            }

    def __repr__(self):
        s = self.stats()
        return (f"SubplanCache(entries={s['entries']} "
                f"bytes={s['bytes']}/{s['byte_budget']} "
                f"hits={s['hits']} misses={s['misses']})")


# --------------------------------------------------------------------------
# the CSE pass: frontier split + residual execution
# --------------------------------------------------------------------------


def subdag_keys(planned, inputs: Mapping[str, Any], *,
                versions: Any = (), params: Any = None,
                input_keys: Optional[Mapping[str, str]] = None) -> dict:
    """Runtime sub-DAG keys for one query: every concrete-plan node's
    content hash with this call's input identities, params identity
    (:func:`params_key` — ops read params through pp-attr bindings, so
    params are as much an input as ``inputs``), and the staged plan's
    salt folded in.  ``planned`` is a PlannedFunction (or anything with
    ``concrete`` + optionally ``staged``)."""
    keys = dict(input_keys) if input_keys is not None else \
        input_keys_for(inputs, versions)
    staged = getattr(planned, "staged", None)
    salt = getattr(staged, "mqo_salt", "") if staged is not None else ""
    salt = f"{salt}|{params_key(params)}"
    return subdag_fingerprints(planned.concrete, leaf_keys=keys, salt=salt)


def split_at_frontier(pplan, keys: Mapping[str, str],
                      cache: SubplanCache) -> tuple:
    """Walk the concrete plan backward from its outputs, stopping at
    cache-hit nodes.  Returns ``(hits, residual)``: node id -> cached
    value for the frontier, and the (topo-ordered) residual node ids that
    still need executing.  A fully cached plan returns an empty
    residual.  The walk uses an explicit stack (like ``run_plan``/``topo``)
    so plan depth never hits Python's recursion limit."""
    hits: dict = {}
    residual: list = []
    seen: set = set()
    stack = list(pplan.outputs)
    while stack:
        ref = stack.pop()
        if ref in seen or ref not in pplan.nodes:
            continue                    # plan input, or already resolved
        seen.add(ref)
        key = keys.get(ref)
        val = cache.lookup(key) if key is not None else None
        if val is not None:
            hits[ref] = val
            continue
        residual.append(ref)
        stack.extend(pplan.nodes[ref].inputs)
    order = {n.id: i for i, n in enumerate(pplan.topo())}
    residual.sort(key=order.__getitem__)
    return hits, residual


def mqo_run(planned, params, inputs: Mapping[str, Any], *,
            cache: SubplanCache, versions: Any = (),
            input_keys: Optional[Mapping[str, str]] = None,
            aux: Optional[dict] = None, keys: Optional[dict] = None,
            tied_to=None):
    """Execute a planned analytical function through the subplan cache.

    Equivalent to ``planned(params, inputs)`` — bitwise so, since reused
    intermediates are the arrays an identical sub-DAG produced — but only
    the residual suffix past the cache-hit frontier actually runs.  Fresh
    non-trivial intermediates are inserted for the next query, recorded
    against ``versions`` (the bound stores' ``(name, version)`` vector)
    and ledger-tied to ``tied_to`` (the producing store's ledger owner,
    when the caller holds it).  Returns ``(outputs, info)`` where ``info``
    carries the frontier decision (``shared_hits`` / ``executed`` /
    ``total``)."""
    pplan = planned.concrete
    if keys is None:
        keys = subdag_keys(planned, inputs, versions=versions,
                           params=params, input_keys=input_keys)
    hits, residual = split_at_frontier(pplan, keys, cache)
    ctx = ExecContext(root=params, scope=params, aux=aux or {},
                      mesh=planned.mesh, rules=planned.rules,
                      interpret=planned.interpret)
    env = dict(inputs)
    env.update(hits)
    env = run_plan_subset(pplan, ctx, env, residual)
    vers = tuple(dict(versions).items())
    for nid in residual:
        n = pplan.nodes[nid]
        if n.impl in _SKIP_CACHE_IMPLS or n.virtual:
            continue
        key = keys.get(nid)
        if key is not None:
            cache.insert(key, env[nid], stores=vers, tied_to=tied_to)
    info = {"plan_id": getattr(planned, "plan_id", ""),
            "shared_hits": len(hits), "executed": len(residual),
            "total": len(pplan.nodes)}
    cache.note_frontier(info)
    outs = tuple(env[o] for o in pplan.outputs)
    return (outs if len(outs) > 1 else outs[0]), info


__all__ = ["SubplanCache", "content_key", "input_keys_for", "params_key",
           "subdag_keys", "split_at_frontier", "mqo_run"]
