"""Buffering mechanism (paper §5.3 + Appendix B).

Physical operators carry a buffering capability: ``SI`` (stream in, whole
out), ``SO`` (whole in, stream out), ``B`` (blocking), ``SS`` (stream both
ways).  The physical DAG is partitioned into **chains** by the three cut
rules of Appendix B (Fig. 18):

  1. cut edge (op1, op2) if op1 cannot stream output or op2 cannot stream
     input;
  2. cut edge (op1, op2) if the data is not op2's ``capOn`` input;
  3. cut all outgoing edges of an operator with >1 consumer.

Inside a chain, intermediates stream batch-by-batch and are never fully
materialized; data *between* chains is materialized.

TPU realization: a chain whose stream axis is ``batch`` executes as a
``lax.scan`` over microbatches — the gradient-accumulation loop.  The live
working set shrinks from (global-batch × activations) to (microbatch ×
activations), the direct analogue of the paper's −37 % heap result, at a
small step-overhead (their +8 %).  The chain partitioner below is also used
by the benchmark that reproduces Fig. 16.
"""
from __future__ import annotations

from dataclasses import dataclass

from .physical import PHYS_OPS, PhysPlan, SI, SO, B, SS


def _can_stream_out(n):
    return PHYS_OPS[n.impl].buf_cap in (SO, SS)


def _can_stream_in(n):
    return PHYS_OPS[n.impl].buf_cap in (SI, SS)


def partition_chains(pp: PhysPlan) -> list:
    """Cut the physical DAG into chains per Appendix B; returns a list of
    chains, each a list of node ids in topological order."""
    cons = pp.consumers()
    nodes = {n.id: n for n in pp.topo()}
    cut: set = set()  # edges (src, dst) that are cut

    for n in pp.topo():
        outs = cons[n.id]
        # rule 3: multiple outgoing edges -> cut all
        if len(outs) > 1:
            cut.update((n.id, o) for o in outs)
        for o in outs:
            dst = nodes[o]
            # rule 1: capability mismatch
            if not _can_stream_out(n) or not _can_stream_in(dst):
                cut.add((n.id, o))
            # rule 2: not the capOn input of dst
            cap_idx = dst.attrs.get("cap_idx", 0)
            if len(dst.inputs) > cap_idx and dst.inputs[cap_idx] != n.id:
                cut.add((n.id, o))

    # connected components over uncut edges (chains)
    parent = {nid: nid for nid in nodes}

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a, b):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb

    for n in pp.topo():
        for o in cons[n.id]:
            if (n.id, o) not in cut and o in nodes:
                union(n.id, o)

    groups: dict = {}
    for n in pp.topo():  # topo order preserved within groups
        groups.setdefault(find(n.id), []).append(n.id)
    return list(groups.values())


@dataclass
class BufferingDecision:
    """What the executor consumes: whether to stream, and the microbatch
    count for the streamed (gradient-accumulation) execution."""

    enabled: bool
    num_microbatches: int
    chains: list

    @property
    def longest_chain(self):
        return max((len(c) for c in self.chains), default=0)


def plan_buffering(pp: PhysPlan, *, enabled: bool, global_batch: int,
                   target_microbatch: int = 0) -> BufferingDecision:
    """Decide streaming for a plan.  ``target_microbatch==0`` picks the
    largest divisor of ``global_batch`` that is ≤ global_batch/4 (stream in
    ≥4 slices), mirroring the paper's batch-by-batch semantics."""
    chains = partition_chains(pp)
    if not enabled:
        return BufferingDecision(False, 1, chains)
    if target_microbatch <= 0:
        num = 1
        for d in range(2, global_batch + 1):
            if global_batch % d == 0 and global_batch // d >= 1 and d <= 8:
                num = d
        # ``num`` = largest divisor of global_batch that is ≤ 8
    else:
        if global_batch % target_microbatch:
            raise ValueError(
                f"microbatch {target_microbatch} !| batch {global_batch}")
        num = global_batch // target_microbatch
    if num <= 1:
        return BufferingDecision(False, 1, chains)
    return BufferingDecision(True, num, chains)
