"""ADIL-style analysis front end (paper §2).

The paper's ADIL is a textual dataflow language; its JAX-native analogue is
a builder that gives the same *semantics* — assignment statements over typed
variables, strict compile-time validation, higher-order map/filter/reduce,
and `store` effects — as an embedded DSL whose product is a validated
logical :class:`~repro.core.ir.Plan` ready for the AWESOME pipeline.

    with Analysis("NewsAnalysis", catalog) as a:
        toks = a.input("tokens", TensorT((4, 64), "int32", ("batch","seq")))
        h = a.op("embed", toks, vocab=512, embed=64, pp=("embed",))
        h = a.op("attention", h, heads=4, kv_heads=2, head_dim=16,
                 embed=64, pp=("attn",))
        a.store(h)
    fn = a.compile(syscat)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

from .executor import PlannedFunction, plan_and_compile
from .ir import (CorpusT, FunctionCatalog, GraphT, Plan, SystemCatalog,
                 TableT, Type, ValidationError, infer_types)


@dataclass(frozen=True)
class Var:
    """A typed ADIL variable (an SSA name into the plan)."""

    name: str
    analysis: "Analysis"

    @property
    def type(self) -> Type:
        return self.analysis.plan.types[self.name]

    def __repr__(self):
        t = self.analysis.plan.types.get(self.name)
        return f"Var({self.name}: {t!r})"


class Analysis:
    """One `create analysis ... as {{ ... }}` block."""

    def __init__(self, name: str, catalog: FunctionCatalog):
        self.plan = Plan(name)
        self.catalog = catalog
        self._stores: list = []
        self._bound: dict = {}   # input name -> bound Store object

    # -- statements ----------------------------------------------------------
    def input(self, name: str, typ: Type) -> Var:
        self.plan.add_input(name, typ)
        return Var(name, self)

    # -- native store declarations (the paper's table/graph/corpus types) ----
    def table(self, name: str, rows: int, cols) -> Var:
        """Declare a relational store input: ``cols`` is ``((name, dtype),
        ...)``.  At call time the caller binds ``ColumnStore.payload()``."""
        return self.input(name, TableT(tuple((str(c), str(d))
                                             for c, d in cols), int(rows)))

    def graph(self, name: str, nodes: int, edges: int,
              weighted: bool = False) -> Var:
        """Declare a CSR graph store input (``GraphStore.payload()``)."""
        return self.input(name, GraphT(int(nodes), int(edges),
                                       bool(weighted)))

    def corpus(self, name: str, docs: int, vocab: int, postings: int) -> Var:
        """Declare a text store input (``TextStore.payload()``)."""
        return self.input(name, CorpusT(int(docs), int(vocab),
                                        int(postings)))

    def bind(self, name: str, store) -> Var:
        """Declare a store input directly from a Store object (its ``type``
        carries the size metadata the planner prices movement with).  The
        store stays tracked: its monotonic ``version`` is folded into the
        plan-cache key at compile time, so appending to a bound store
        invalidates plans cached against its previous contents."""
        self._bound[name] = store
        return self.input(name, store.type)

    def store_versions(self) -> tuple:
        """The bound stores' ``(name, version)`` vector (stores without a
        version — e.g. static graph snapshots — count as version 0)."""
        return tuple(sorted((n, int(getattr(s, "version", 0)))
                            for n, s in self._bound.items()))

    def op(self, op_name: str, *inputs, subplan: Optional[Plan] = None,
           **attrs) -> Var:
        ids = [v.name if isinstance(v, Var) else v for v in inputs]
        nid = self.plan.add(op_name, ids, attrs, subplan)
        # validate eagerly — every assignment type-checks at once (§3)
        infer_types(self.plan, self.catalog)
        return Var(nid, self)

    def map(self, coll: Var, body_plan: Plan) -> Var:
        return self.op("map", coll, subplan=body_plan)

    def filter(self, coll: Var, predicate) -> Var:
        return self.op("filter", coll, predicate=predicate)

    def reduce(self, coll: Var, fn) -> Var:
        return self.op("reduce", coll, fn=fn)

    def store(self, var: Var, **attrs) -> Var:
        nid = self.plan.add("store", [var.name], attrs)
        infer_types(self.plan, self.catalog)
        self._stores.append(nid)
        return Var(nid, self)

    # -- context manager sugar -------------------------------------------------
    def __enter__(self):
        return self

    def __exit__(self, et, ev, tb):
        if et is None:
            if not self._stores:
                raise ValidationError(
                    f"analysis {self.plan.name!r} has no store statements")
            self.plan.set_outputs(*self._stores)
        return False

    # -- compilation through the AWESOME pipeline ------------------------------
    def compile(self, syscat: SystemCatalog, **kw) -> PlannedFunction:
        """Compile through the staged plan pipeline.  Planning is cached by
        content hash (see ``core/plan_cache.py``): recompiling an identical
        analysis against the same catalogs reuses the staged plan instead of
        replanning.  Pass ``cache=False`` to force a fresh run."""
        if not self.plan.outputs:
            self.plan.set_outputs(*self._stores)
        if self._bound:
            # re-snapshot bound store types: an append since bind() may have
            # changed row counts / expected counts, and replanning against
            # the stale snapshot would price (and size compactions) on
            # stale cardinalities — the very thing the version key exists
            # to invalidate
            stale = False
            for n, s in self._bound.items():
                if self.plan.inputs.get(n) != s.type:
                    self.plan.inputs[n] = s.type
                    self.plan.types[n] = s.type
                    stale = True
            if stale:
                self.plan._bump()
                infer_types(self.plan, self.catalog)
            kw.setdefault("store_versions", self.store_versions())
        return plan_and_compile(self.plan, self.catalog, syscat, **kw)

    def plan_id(self, syscat: SystemCatalog) -> str:
        """Content hash identifying this analysis against the catalogs (the
        structural part of the plan-cache key; planning options are appended
        by the pipeline — see ``pipeline.staged_plan_id``).  Side-effect
        free: outputs defaulting happens on a copy, so stores added after
        this call still reach ``compile``."""
        from .ir import plan_id as _plan_id
        plan = self.plan
        if not plan.outputs and self._stores:
            plan = plan.copy()
            plan.set_outputs(*self._stores)
        return _plan_id(plan, self.catalog, syscat)
