from .lm import LM, build_model

__all__ = ["LM", "build_model"]
