"""Config-driven model zoo: every assigned architecture as (a) a logical
plan for the AWESOME planner (training / prefill — the throughput path the
paper's optimizer targets) and (b) a direct cached decode path (serving).

Layer stacking: contiguous runs of identical *superblocks* become one
``scan_layers`` node (the paper's Map) whose subplan holds the superblock's
ops — e.g. gemma3's period-6 [5×local + 1×global] superblock, zamba2's
[6×mamba + shared-attn] superblock, llama4's [dense, moe] pair.  Weight-tied
(shared) blocks read from the root param scope via ``shared=True``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..core.ir import Plan, TensorT, standard_catalog
from ..layers import attention as A
from ..layers import embedding as E
from ..layers import mamba as M
from ..layers import mlp as F
from ..layers import moe as X
from ..layers import rwkv as R
from ..layers.common import KeyGen, rmsnorm, stack_params, stack_specs

CATALOG = standard_catalog()


# --------------------------------------------------------------------------
# block descriptors and grouping
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Block:
    kind: str              # attn_mlp | attn_moe | rwkv | mamba | shared_attn
    window: int = 0        # 0 = global attention
    causal: bool = True
    cross: bool = False    # decoder block with cross-attention


@dataclass(frozen=True)
class Group:
    """A scan group: ``count`` repetitions of the ``blocks`` superblock."""

    name: str
    count: int
    blocks: tuple


def layer_groups(cfg: ModelConfig) -> list:
    f = cfg.family
    if f in ("dense", "vlm"):
        if cfg.local_ratio > 0:
            period = cfg.local_ratio + 1
            sup = tuple([Block("attn_mlp", window=cfg.window)] * cfg.local_ratio
                        + [Block("attn_mlp")])
            n_sup, rem = divmod(cfg.n_layers, period)
            groups = [Group("layers_0", n_sup, sup)]
            if rem:
                groups.append(Group(
                    "layers_1", rem, (Block("attn_mlp", window=cfg.window),)))
            return groups
        return [Group("layers_0", cfg.n_layers, (Block("attn_mlp"),))]
    if f == "moe":
        if cfg.moe_every > 1:
            sup = tuple([Block("attn_mlp")] * (cfg.moe_every - 1)
                        + [Block("attn_moe")])
            n_sup, rem = divmod(cfg.n_layers, cfg.moe_every)
            groups = [Group("layers_0", n_sup, sup)]
            if rem:
                groups.append(Group("layers_1", rem, (Block("attn_mlp"),)))
            return groups
        return [Group("layers_0", cfg.n_layers, (Block("attn_moe"),))]
    if f == "rwkv":
        return [Group("layers_0", cfg.n_layers, (Block("rwkv"),))]
    if f == "hybrid":
        period = cfg.shared_attn_period
        sup = tuple([Block("mamba")] * (period - 1) + [Block("shared_attn")])
        n_sup, rem = divmod(cfg.n_layers, period)
        groups = [Group("layers_0", n_sup, sup)]
        if rem:
            groups.append(Group("layers_1", rem, (Block("mamba"),)))
        return groups
    if f == "encdec":
        return [
            Group("enc_0", cfg.enc_layers, (Block("attn_mlp", causal=False),)),
            Group("dec_0", cfg.dec_layers,
                  (Block("attn_mlp", cross=True),)),
        ]
    raise ValueError(f"unknown family {f!r}")


# --------------------------------------------------------------------------
# param init
# --------------------------------------------------------------------------

def _attn_cfg(cfg: ModelConfig) -> dict:
    return {"embed": cfg.d_model, "heads": cfg.heads,
            "kv_heads": cfg.kv_heads, "head_dim": cfg.resolved_head_dim,
            "qk_norm": cfg.qk_norm}


def _init_block(kg, cfg: ModelConfig, block: Block, i: int, dtype):
    e = cfg.d_model
    pp = f"b{i}"
    p: dict = {}
    s: dict = {}

    def put(name, pr, sp):
        p[f"{pp}_{name}"] = pr
        s[f"{pp}_{name}"] = sp

    if block.kind in ("attn_mlp", "attn_moe"):
        put("ln1", {"scale": jnp.zeros((e,), dtype)}, {"scale": ("embed",)})
        ap, asp = A.init_attention(kg, _attn_cfg(cfg), dtype)
        put("attn", ap, asp)
        if block.cross:
            put("lnx", {"scale": jnp.zeros((e,), dtype)},
                {"scale": ("embed",)})
            xp, xsp = A.init_attention(kg, _attn_cfg(cfg), dtype)
            put("xattn", xp, xsp)
        put("ln2", {"scale": jnp.zeros((e,), dtype)}, {"scale": ("embed",)})
        if block.kind == "attn_moe":
            mp, msp = X.init_moe(
                kg, {"embed": e, "ffn": cfg.d_ff, "experts": cfg.experts},
                dtype)
            put("moe", mp, msp)
        else:
            mp, msp = F.init_mlp(
                kg, {"embed": e, "ffn": cfg.d_ff, "gated": cfg.gated}, dtype)
            put("mlp", mp, msp)
    elif block.kind == "rwkv":
        put("ln1", {"scale": jnp.zeros((e,), dtype)}, {"scale": ("embed",)})
        tp, tsp = R.init_rwkv_time_mix(
            kg, {"embed": e, "heads": cfg.heads,
                 "head_dim": cfg.resolved_head_dim}, dtype)
        put("tm", tp, tsp)
        put("ln2", {"scale": jnp.zeros((e,), dtype)}, {"scale": ("embed",)})
        cp, csp = R.init_rwkv_channel_mix(
            kg, {"embed": e, "ffn": cfg.d_ff}, dtype)
        put("cm", cp, csp)
    elif block.kind in ("mamba", "shared_attn"):
        put("ln1", {"scale": jnp.zeros((e,), dtype)}, {"scale": ("embed",)})
        mp, msp = M.init_mamba2(
            kg, {"embed": e, "state": cfg.ssm_state, "expand": cfg.expand,
                 "head_dim": cfg.mamba_head_dim}, dtype)
        put("mamba", mp, msp)
        # shared_attn reads attn/mlp weights from the *root* scope
    else:
        raise ValueError(block.kind)
    return p, s


def _init_shared(kg, cfg: ModelConfig, dtype):
    e = cfg.d_model
    ap, asp = A.init_attention(kg, _attn_cfg(cfg), dtype)
    mp, msp = F.init_mlp(
        kg, {"embed": e, "ffn": cfg.d_ff, "gated": cfg.gated}, dtype)
    p = {"ln1": {"scale": jnp.zeros((e,), dtype)}, "attn": ap,
         "ln2": {"scale": jnp.zeros((e,), dtype)}, "mlp": mp}
    s = {"ln1": {"scale": ("embed",)}, "attn": asp,
         "ln2": {"scale": ("embed",)}, "mlp": msp}
    return p, s


# --------------------------------------------------------------------------
# the model
# --------------------------------------------------------------------------

class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.groups = layer_groups(cfg)
        self.dtype = jnp.dtype(cfg.dtype)
        self.pdtype = jnp.dtype(cfg.param_dtype)

    # -- params -------------------------------------------------------------
    def init_params(self, key):
        cfg = self.cfg
        kg = KeyGen(key)
        params: dict = {}
        specs: dict = {}
        ep, es = E.init_embedding(kg, cfg.padded_vocab, cfg.d_model,
                                  self.pdtype, tied=cfg.tied_embeddings)
        params["embed"] = ep
        specs["embed"] = es
        if cfg.family == "hybrid":
            params["shared"], specs["shared"] = _init_shared(
                kg, cfg, self.pdtype)
        for g in self.groups:
            layers_p, layers_s = [], None
            for _ in range(g.count):
                lp = {}
                ls = {}
                for i, blk in enumerate(g.blocks):
                    bp, bs = _init_block(kg, cfg, blk, i, self.pdtype)
                    lp.update(bp)
                    ls.update(bs)
                layers_p.append(lp)
                layers_s = ls
            params[g.name] = stack_params(layers_p)
            specs[g.name] = stack_specs(layers_s)
        params["final_norm"] = {"scale": jnp.zeros((cfg.d_model,),
                                                   self.pdtype)}
        specs["final_norm"] = {"scale": ("embed",)}
        if cfg.family == "encdec":
            params["enc_norm"] = {"scale": jnp.zeros((cfg.d_model,),
                                                     self.pdtype)}
            specs["enc_norm"] = {"scale": ("embed",)}
        return params, specs

    # -- logical plan ---------------------------------------------------------
    def _block_nodes(self, sub: Plan, x: str, i: int, blk: Block,
                     emit_kv: bool = False) -> str:
        cfg = self.cfg
        shared = blk.kind == "shared_attn"
        pp = "b" + str(i)

        def norm(src, name, sh=False, root_pp=None):
            return sub.add("rmsnorm", [src],
                           {"pp": root_pp or (f"{pp}_{name}",),
                            **({"shared": True} if sh else {})})

        if blk.kind in ("attn_mlp", "attn_moe"):
            h = norm(x, "ln1")
            att = sub.add("attention", [h], {
                "pp": (f"{pp}_attn",), **_attn_cfg(cfg),
                "causal": blk.causal, "window": blk.window,
                "rope_theta": cfg.rope_theta,
                **({"emit_kv": True} if emit_kv else {})})
            x = sub.add("residual_add", [x, att])
            if blk.cross:
                hx = norm(x, "lnx")
                xa = sub.add("cross_attention", [hx, "memory"], {
                    "pp": (f"{pp}_xattn",), **_attn_cfg(cfg)})
                x = sub.add("residual_add", [x, xa])
            h = norm(x, "ln2")
            if blk.kind == "attn_moe":
                m = sub.add("moe", [h], {
                    "pp": (f"{pp}_moe",), "ffn": cfg.d_ff,
                    "experts": cfg.experts, "top_k": cfg.top_k,
                    "act": cfg.act, "embed": cfg.d_model,
                    "pin_moe": cfg.pin_moe_layout})
            else:
                m = sub.add("mlp", [h], {
                    "pp": (f"{pp}_mlp",), "ffn": cfg.d_ff,
                    "gated": cfg.gated, "act": cfg.act,
                    "embed": cfg.d_model})
            return sub.add("residual_add", [x, m])
        if blk.kind == "rwkv":
            h = norm(x, "ln1")
            tm = sub.add("wkv6", [h], {
                "pp": (f"{pp}_tm",), "heads": cfg.heads,
                "head_dim": cfg.resolved_head_dim})
            x = sub.add("residual_add", [x, tm])
            h = norm(x, "ln2")
            cm = sub.add("rwkv_channel_mix", [h],
                         {"pp": (f"{pp}_cm",), "ffn": cfg.d_ff})
            return sub.add("residual_add", [x, cm])
        if blk.kind in ("mamba", "shared_attn"):
            h = norm(x, "ln1")
            mb = sub.add("ssd", [h], {
                "pp": (f"{pp}_mamba",), "heads":
                    cfg.expand * cfg.d_model // cfg.mamba_head_dim,
                "head_dim": cfg.mamba_head_dim, "state": cfg.ssm_state,
                "expand": cfg.expand, "embed": cfg.d_model})
            x = sub.add("residual_add", [x, mb])
            if shared:
                h = sub.add("rmsnorm", [x], {"pp": ("shared", "ln1"),
                                             "shared": True})
                att = sub.add("attention", [h], {
                    "pp": ("shared", "attn"), "shared": True,
                    **_attn_cfg(cfg), "causal": True, "window": 0,
                    "rope_theta": cfg.rope_theta})
                x = sub.add("residual_add", [x, att])
                h = sub.add("rmsnorm", [x], {"pp": ("shared", "ln2"),
                                             "shared": True})
                m = sub.add("mlp", [h], {
                    "pp": ("shared", "mlp"), "shared": True,
                    "ffn": cfg.d_ff, "gated": cfg.gated, "act": cfg.act,
                    "embed": cfg.d_model})
                x = sub.add("residual_add", [x, m])
            return x
        raise ValueError(blk.kind)

    def _group_subplan(self, g: Group, batch: int, seq: int,
                       with_memory: bool = False,
                       emit_kv: bool = False) -> Plan:
        cfg = self.cfg
        sub = Plan(name=f"{cfg.name}_{g.name}")
        sub.add_input("h", TensorT((batch, seq, cfg.d_model), cfg.dtype,
                                   ("batch", "seq", "embed")))
        if with_memory:
            sub.add_input("memory", TensorT((batch, seq, cfg.d_model),
                                            cfg.dtype,
                                            ("batch", "seq", "embed")))
        x = "h"
        for i, blk in enumerate(g.blocks):
            x = self._block_nodes(sub, x, i, blk, emit_kv=emit_kv)
        sub.set_outputs(x)
        return sub

    def supports_prefill_kv(self) -> bool:
        """True when the whole serving cache is attention K/V — i.e. a
        ``prefill_kv`` plan captures the *entire* decode state.  Recurrent
        families (mamba/rwkv) and frontend/enc-dec models carry extra state
        the planned forward does not expose yet; the serving runtime falls
        back to decode replay for those."""
        return self.cfg.family in ("dense", "moe") and \
            self.cfg.frontend == "none"

    def build_plan(self, batch: int, seq: int, mode: str = "train") -> Plan:
        """The workload's logical plan (ADIL analysis block analogue).

        ``mode="prefill_kv"`` is the serving prefill: like ``prefill`` but
        every attention carries ``emit_kv`` and every scan group collects the
        per-layer K/V as an extra plan output — (logits, kv_g0, kv_g1, ...)
        — so the KV cache is seeded directly from the planned forward
        instead of replaying the prompt through ``decode_step``."""
        cfg = self.cfg
        collect_kv = mode == "prefill_kv"
        if collect_kv and not self.supports_prefill_kv():
            raise ValueError(
                f"prefill_kv plans need an attention-only decode state; "
                f"{cfg.name} (family={cfg.family}, frontend={cfg.frontend}) "
                f"carries recurrent/frontend state — use mode='prefill' and "
                f"decode replay")
        if cfg.family == "encdec":
            return self._build_encdec_plan(batch, seq, mode)
        plan = Plan(name=f"{cfg.name}-{mode}")
        n_front = cfg.frontend_tokens if cfg.frontend != "none" else 0
        s_text = seq - n_front
        tokens = plan.add_input("tokens", TensorT((batch, s_text), "int32",
                                                  ("batch", "seq")))
        x = plan.add("embed", [tokens], {
            "pp": ("embed",), "vocab": cfg.vocab, "embed": cfg.d_model,
            "dtype": cfg.dtype, "scale": cfg.embed_scale})
        if n_front:
            front = plan.add_input(
                "frontend_embeds",
                TensorT((batch, n_front, cfg.d_model), cfg.dtype,
                        ("batch", "seq", "embed")))
            x = plan.add("concat_seq", [front, x], {"axis": 1})
        kv_outs = []
        for g in self.groups:
            sub = self._group_subplan(g, batch, seq, emit_kv=collect_kv)
            x = plan.add("scan_layers", [x], {
                "n_layers": g.count, "pp": (g.name,),
                "param_group": g.name, "remat": cfg.remat,
                "unroll": cfg.scan_unroll,
                **({"collect_kv": True} if collect_kv else {})}, subplan=sub)
            if collect_kv:
                kv_outs.append(plan.add("tuple_get", [x], {"index": 1}))
                x = plan.add("tuple_get", [x], {"index": 0})
        x = plan.add("rmsnorm", [x], {"pp": ("final_norm",)})
        logits = plan.add("unembed", [x], {"pp": ("embed",),
                                           "vocab": cfg.padded_vocab,
                                           "true_vocab": cfg.vocab})
        if mode == "train":
            labels = plan.add_input("labels", TensorT((batch, seq), "int32",
                                                      ("batch", "seq")))
            loss = plan.add("softmax_xent", [logits, labels])
            out = plan.add("store", [loss])
            plan.set_outputs(out)
        else:
            out = plan.add("store", [logits])
            kv_stores = [plan.add("store", [k]) for k in kv_outs]
            plan.set_outputs(out, *kv_stores)
        return plan

    def _build_encdec_plan(self, batch: int, seq: int, mode: str) -> Plan:
        cfg = self.cfg
        plan = Plan(name=f"{cfg.name}-{mode}")
        frames = plan.add_input(
            "frontend_embeds", TensorT((batch, seq, cfg.d_model), cfg.dtype,
                                       ("batch", "seq", "embed")))
        enc_g, dec_g = self.groups
        enc_sub = self._group_subplan(enc_g, batch, seq)
        mem = plan.add("scan_layers", [frames], {
            "n_layers": enc_g.count, "pp": (enc_g.name,),
            "param_group": enc_g.name, "remat": cfg.remat}, subplan=enc_sub)
        mem = plan.add("rmsnorm", [mem], {"pp": ("enc_norm",)})

        tokens = plan.add_input("tokens", TensorT((batch, seq), "int32",
                                                  ("batch", "seq")))
        x = plan.add("embed", [tokens], {
            "pp": ("embed",), "vocab": cfg.vocab, "embed": cfg.d_model,
            "dtype": cfg.dtype, "scale": cfg.embed_scale})
        dec_sub = self._group_subplan(dec_g, batch, seq, with_memory=True)
        x = plan.add("scan_layers", [x, mem], {
            "n_layers": dec_g.count, "pp": (dec_g.name,),
            "param_group": dec_g.name, "remat": cfg.remat}, subplan=dec_sub)
        x = plan.add("rmsnorm", [x], {"pp": ("final_norm",)})
        logits = plan.add("unembed", [x], {"pp": ("embed",),
                                           "vocab": cfg.padded_vocab,
                                           "true_vocab": cfg.vocab})
        if mode == "train":
            labels = plan.add_input("labels", TensorT((batch, seq), "int32",
                                                      ("batch", "seq")))
            loss = plan.add("softmax_xent", [logits, labels])
            out = plan.add("store", [loss])
            plan.set_outputs(out)
        else:
            out = plan.add("store", [logits])
            plan.set_outputs(out)
        return plan

    # -- input specs (ShapeDtypeStruct stand-ins; no allocation) -------------
    def input_specs(self, shape: ShapeConfig) -> dict:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        sds = jax.ShapeDtypeStruct
        if shape.kind == "decode":
            out = {"tokens": sds((b, 1), jnp.int32),
                   "index": sds((), jnp.int32)}
            return out
        if cfg.family == "encdec":
            out = {"frontend_embeds": sds((b, s, cfg.d_model), self.dtype),
                   "tokens": sds((b, s), jnp.int32)}
        elif cfg.frontend != "none":
            out = {"frontend_embeds":
                   sds((b, cfg.frontend_tokens, cfg.d_model), self.dtype),
                   "tokens": sds((b, s - cfg.frontend_tokens), jnp.int32)}
        else:
            out = {"tokens": sds((b, s), jnp.int32)}
        if shape.kind == "train":
            out["labels"] = sds((b, s), jnp.int32)
        return out

    # -- params init at abstract level (for dry-run) --------------------------
    def abstract_params(self):
        return jax.eval_shape(lambda k: self.init_params(k)[0],
                              jax.random.key(0))

    def param_specs(self):
        holder = {}

        def f(k):
            p, s = self.init_params(k)
            holder["s"] = s          # pure-Python side channel
            return p

        jax.eval_shape(f, jax.random.key(0))
        return holder["s"]


def build_model(cfg: ModelConfig) -> LM:
    return LM(cfg)
