"""Serving path: cache construction, prefill, and single-token decode.

``serve_step`` is what the decode_* / long_* dry-run shapes lower: one new
token against a seq_len KV cache.  The cache layout follows the scan-group
structure (one stacked entry per group), sharded batch→data, kv-heads→model.

Local (sliding-window) attention layers allocate **ring-buffer** caches of
window size instead of full-context caches when ``ring_local=True`` — the
§Perf optimization for gemma3's 5:1 local:global stack (52 of 62 layers need
only W=1024 slots instead of 524288).
"""
from __future__ import annotations

import functools
from dataclasses import replace

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..layers import attention as A
from ..layers import embedding as E
from ..layers import mamba as M
from ..layers import mlp as F
from ..layers import moe as X
from ..layers import rwkv as R
from ..layers.common import rmsnorm, rope
from .lm import LM, Block, Group


def _attn_dims(cfg: ModelConfig):
    return cfg.heads, cfg.kv_heads, cfg.resolved_head_dim


# --------------------------------------------------------------------------
# cache construction
# --------------------------------------------------------------------------

def init_cache(model: LM, batch: int, max_seq: int, *, ring_local: bool = False,
               abstract: bool = False, kv_repeat_to: int = 0,
               quantize_kv: bool = False):
    """Cache pytree: {group_name: {bK_k/bK_v/...: (count, B, S, KV, D)}}.

    ``kv_repeat_to``: allocate the cache with KV heads replicated up to this
    count (e.g. the TP width).  Doubles cache bytes for kv=8→16 but lets the
    cache shard 16-way over `model` instead of replicating — per-device
    reads drop by model_axis/repeat (the Llama-70B-style GQA/TP alignment).

    ``quantize_kv``: store K/V as int8 with per-(position, head) bf16
    abs-max scales — 2× less cache HBM residency and read traffic (the
    dequant fuses into the attention matmul on TPU); error is bounded by
    1/254 of the per-head dynamic range.
    """
    cfg = model.cfg
    h, kv, d = _attn_dims(cfg)
    if kv_repeat_to and kv_repeat_to > kv:
        assert kv_repeat_to % kv == 0, (kv, kv_repeat_to)
        kv = kv_repeat_to
    zeros = (jax.ShapeDtypeStruct if abstract
             else (lambda shp, dt: jnp.zeros(shp, dt)))
    cache: dict = {}
    for g in model.groups:
        gc: dict = {}
        for i, blk in enumerate(g.blocks):
            pre = f"b{i}"
            if blk.kind in ("attn_mlp", "attn_moe", "shared_attn"):
                s_alloc = max_seq
                if ring_local and blk.window and blk.window < max_seq:
                    s_alloc = blk.window
                kv_dt = jnp.int8 if quantize_kv else model.dtype
                gc[f"{pre}_k"] = zeros((g.count, batch, s_alloc, kv, d),
                                       kv_dt)
                gc[f"{pre}_v"] = zeros((g.count, batch, s_alloc, kv, d),
                                       kv_dt)
                if quantize_kv:
                    gc[f"{pre}_ksc"] = zeros(
                        (g.count, batch, s_alloc, kv, 1), jnp.bfloat16)
                    gc[f"{pre}_vsc"] = zeros(
                        (g.count, batch, s_alloc, kv, 1), jnp.bfloat16)
            if blk.kind in ("mamba", "shared_attn"):
                ei = cfg.expand * cfg.d_model
                nheads = ei // cfg.mamba_head_dim
                gc[f"{pre}_state"] = zeros(
                    (g.count, batch, nheads, cfg.ssm_state,
                     cfg.mamba_head_dim), jnp.float32)
                gc[f"{pre}_conv"] = zeros(
                    (g.count, batch, M.CONV_K - 1,
                     ei + 2 * cfg.ssm_state), model.dtype)
            if blk.kind == "rwkv":
                gc[f"{pre}_state"] = zeros(
                    (g.count, batch, cfg.heads, cfg.resolved_head_dim,
                     cfg.resolved_head_dim), jnp.float32)
                gc[f"{pre}_last_tm"] = zeros((g.count, batch, cfg.d_model),
                                             model.dtype)
                gc[f"{pre}_last_cm"] = zeros((g.count, batch, cfg.d_model),
                                             model.dtype)
            if blk.cross:
                gc[f"b{i}_xk"] = zeros((g.count, batch, max_seq, kv, d),
                                       model.dtype)
                gc[f"b{i}_xv"] = zeros((g.count, batch, max_seq, kv, d),
                                       model.dtype)
        cache[g.name] = gc
    return cache


# --------------------------------------------------------------------------
# single-token decode
# --------------------------------------------------------------------------

def _decode_attn(p, x, ck, cv, index, cfg: ModelConfig, window: int,
                 ring: bool, ksc=None, vsc=None):
    """x: (B, 1, E).  Returns (out, new_ck, new_cv[, new_ksc, new_vsc])."""
    h, kvh, d = _attn_dims(cfg)
    q = A.project_q(p, x, h, d)
    k, v = A.project_kv(p, x, kvh, d)
    pos = jnp.full((x.shape[0], 1), index, jnp.int32)
    if cfg.qk_norm and "q_norm" in p:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = rope(q, pos, theta=cfg.rope_theta)
    k = rope(k, pos, theta=cfg.rope_theta)
    kv_alloc = ck.shape[2]
    if kv_alloc > kvh:                      # TP-aligned replicated KV cache
        reps = kv_alloc // kvh
        k = jnp.repeat(k, reps, axis=2)
        v = jnp.repeat(v, reps, axis=2)
    s_alloc = ck.shape[1]
    slot = index % s_alloc if ring else index
    if ksc is not None:
        k, k_s = A.quantize_kv(k)
        v, v_s = A.quantize_kv(v)
        ksc = jax.lax.dynamic_update_slice_in_dim(ksc, k_s, slot, axis=1)
        vsc = jax.lax.dynamic_update_slice_in_dim(vsc, v_s, slot, axis=1)
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), slot,
                                             axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), slot,
                                             axis=1)
    n_valid = jnp.minimum(index + 1, s_alloc)
    valid = jnp.arange(s_alloc)[None, :] < n_valid
    if window and window > 0 and not ring:
        valid = valid & (jnp.arange(s_alloc)[None, :] > index - window)
    out = A.decode_attend_gqa(q, ck, cv,
                              jnp.broadcast_to(valid,
                                               (x.shape[0], s_alloc)),
                              k_scale=ksc, v_scale=vsc)
    return A.out_project(p, out), ck, cv, ksc, vsc


def _decode_block(cfg: ModelConfig, blk: Block, i: int, p, root, x, gc,
                  index, ring_local: bool):
    pre = f"b{i}"
    upd = {}
    if blk.kind in ("attn_mlp", "attn_moe"):
        h = rmsnorm(x, p[f"{pre}_ln1"]["scale"])
        ring = bool(ring_local and blk.window
                    and gc[f"{pre}_k"].shape[1] == blk.window)
        att, ck, cv, ksc, vsc = _decode_attn(
            p[f"{pre}_attn"], h, gc[f"{pre}_k"], gc[f"{pre}_v"], index, cfg,
            blk.window, ring, ksc=gc.get(f"{pre}_ksc"),
            vsc=gc.get(f"{pre}_vsc"))
        upd[f"{pre}_k"], upd[f"{pre}_v"] = ck, cv
        if ksc is not None:
            upd[f"{pre}_ksc"], upd[f"{pre}_vsc"] = ksc, vsc
        x = x + att
        if blk.cross:
            hx = rmsnorm(x, p[f"{pre}_lnx"]["scale"])
            hq = A.project_q(p[f"{pre}_xattn"], hx, cfg.heads,
                             cfg.resolved_head_dim)
            all_valid = jnp.ones((x.shape[0], gc[f"{pre}_xk"].shape[1]),
                                 bool)
            out = A.decode_attend_gqa(hq, gc[f"{pre}_xk"],
                                      gc[f"{pre}_xv"], all_valid)
            x = x + A.out_project(p[f"{pre}_xattn"], out)
            upd[f"{pre}_xk"], upd[f"{pre}_xv"] = gc[f"{pre}_xk"], gc[f"{pre}_xv"]
        h = rmsnorm(x, p[f"{pre}_ln2"]["scale"])
        if blk.kind == "attn_moe":
            m = X.moe_dense(p[f"{pre}_moe"], h, top_k=cfg.top_k,
                            experts=cfg.experts, act=cfg.act)
        else:
            m = F.mlp_fused(p[f"{pre}_mlp"], h, gated=cfg.gated, act=cfg.act)
        x = x + m
    elif blk.kind == "rwkv":
        h = rmsnorm(x, p[f"{pre}_ln1"]["scale"])
        tm, last, st = R.rwkv_time_mix(
            p[f"{pre}_tm"], h, heads=cfg.heads,
            head_dim=cfg.resolved_head_dim,
            last_x=gc[f"{pre}_last_tm"], state=gc[f"{pre}_state"])
        upd[f"{pre}_last_tm"], upd[f"{pre}_state"] = last, st
        x = x + tm
        h = rmsnorm(x, p[f"{pre}_ln2"]["scale"])
        cm, last_cm = R.rwkv_channel_mix(p[f"{pre}_cm"], h,
                                         last_x=gc[f"{pre}_last_cm"])
        upd[f"{pre}_last_cm"] = last_cm
        x = x + cm
    elif blk.kind in ("mamba", "shared_attn"):
        h = rmsnorm(x, p[f"{pre}_ln1"]["scale"])
        mcfg = {"embed": cfg.d_model, "state": cfg.ssm_state,
                "expand": cfg.expand, "head_dim": cfg.mamba_head_dim}
        mb, st, conv = M.mamba2_block(p[f"{pre}_mamba"], h, mcfg,
                                      state=gc[f"{pre}_state"],
                                      conv_state=gc[f"{pre}_conv"])
        upd[f"{pre}_state"], upd[f"{pre}_conv"] = st, conv
        x = x + mb
        if blk.kind == "shared_attn":
            sp = root["shared"]
            h = rmsnorm(x, sp["ln1"]["scale"])
            att, ck, cv, ksc, vsc = _decode_attn(
                sp["attn"], h, gc[f"{pre}_k"], gc[f"{pre}_v"], index, cfg,
                0, False, ksc=gc.get(f"{pre}_ksc"),
                vsc=gc.get(f"{pre}_vsc"))
            upd[f"{pre}_k"], upd[f"{pre}_v"] = ck, cv
            if ksc is not None:
                upd[f"{pre}_ksc"], upd[f"{pre}_vsc"] = ksc, vsc
            x = x + att
            h = rmsnorm(x, sp["ln2"]["scale"])
            x = x + F.mlp_fused(sp["mlp"], h, gated=cfg.gated, act=cfg.act)
    else:
        raise ValueError(blk.kind)
    return x, upd


def decode_step(model: LM, params, cache, tokens, index, *,
                ring_local: bool = False):
    """tokens: (B, 1) int32; index: scalar int32 — position being decoded.
    Returns (logits (B, 1, V), new_cache)."""
    cfg = model.cfg
    x = E.embed(params["embed"], tokens,
                scale=cfg.embed_scale).astype(model.dtype)
    new_cache = {}
    for g in model.groups:
        if g.name.startswith("enc"):
            new_cache[g.name] = cache[g.name]
            continue
        gp = params[g.name]
        gc = cache[g.name]

        def body(carry, xs):
            layer_p, layer_c = xs
            h = carry
            for i, blk in enumerate(g.blocks):
                h, upd = _decode_block(cfg, blk, i, layer_p, params, h,
                                       layer_c, index, ring_local)
                layer_c = {**layer_c, **upd}
            return h, layer_c

        x, gcache = jax.lax.scan(body, x, (gp, gc))
        new_cache[g.name] = gcache
    x = rmsnorm(x, params["final_norm"]["scale"])
    logits = E.mask_padded_logits(E.unembed(params["embed"], x), cfg.vocab)
    return logits, new_cache


def decode_step_batched(model: LM, params, cache, tokens, indices, *,
                        ring_local: bool = False):
    """Continuous-batching decode: one token per batch slot at a **per-slot**
    position.  tokens: (B, 1) int32; indices: (B,) int32 — slot b decodes
    position indices[b].  Returns (logits (B, 1, V), new_cache).

    Implemented as a vmap of :func:`decode_step` over the batch axis (every
    cache leaf carries batch at axis 1), so slots at different sequence
    positions — the continuous batch after joins/leaves — share one jitted
    step.  The per-slot cache writes lower to batched dynamic slices."""

    def one(cache_b, tok, idx):
        c = jax.tree.map(lambda x: x[:, None], cache_b)   # re-add batch dim
        logits, new_c = decode_step(model, params, c, tok[None], idx,
                                    ring_local=ring_local)
        return logits[0], jax.tree.map(lambda x: x[:, 0], new_c)

    return jax.vmap(one, in_axes=(1, 0, 0), out_axes=(0, 1))(
        cache, tokens, indices)


def attn_block_indices(group) -> list:
    """Block indices within a :class:`~repro.models.lm.Group` whose cache
    entries are attention K/V — the blocks a ``prefill_kv`` plan emits, in
    emission order (subplan topo order == block order)."""
    return [i for i, blk in enumerate(group.blocks)
            if blk.kind in ("attn_mlp", "attn_moe")]


def seed_cache_from_prefill(model: LM, cache, kv_groups, prompt_len: int, *,
                            slot=None):
    """Write a ``prefill_kv`` plan's K/V outputs into a decode cache.

    ``kv_groups``: one entry per model group — a tuple over emitting blocks
    of (K, V) stacked as (layers, B, bucket, KV, D), i.e. the plan outputs
    ``(kv_g0, kv_g1, ...)`` of ``build_plan(mode="prefill_kv")``.  With
    ``slot=None`` the prefill batch must match the cache batch and all rows
    are seeded; with an int ``slot`` the prefill must be batch-1 and lands in
    that cache row (the KV-pool join path).  Returns the updated cache."""
    new_cache = {g: dict(c) for g, c in cache.items()}
    for g, kv_g in zip(model.groups, kv_groups):
        gc = new_cache[g.name]
        for bi, (k, v) in zip(attn_block_indices(g), kv_g):
            if f"b{bi}_ksc" in gc or gc[f"b{bi}_k"].shape[2] < prompt_len:
                raise ValueError(
                    "prefill_kv seeding needs full-length, unquantized "
                    "caches (no ring_local/quantize_kv)")
            for key, val in ((f"b{bi}_k", k), (f"b{bi}_v", v)):
                leaf = gc[key]
                val = val[:, :, :prompt_len].astype(leaf.dtype)
                if slot is None:
                    gc[key] = leaf.at[:, :, :prompt_len].set(val)
                else:
                    gc[key] = leaf.at[:, slot, :prompt_len].set(val[:, 0])
    return new_cache


def prefill(model: LM, params, tokens, max_seq: int, *,
            frontend_embeds=None, ring_local: bool = False):
    """Sequential prefill via decode_step (small-scale serving example; the
    throughput prefill path is the planner-compiled forward)."""
    b, s = tokens.shape
    cache = init_cache(model, b, max_seq, ring_local=ring_local)
    logits = None
    for t in range(s):
        logits, cache = decode_step(model, params, cache, tokens[:, t:t + 1],
                                    jnp.int32(t), ring_local=ring_local)
    return logits, cache
