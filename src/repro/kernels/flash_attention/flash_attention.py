"""Blocked online-softmax attention kernel (TPU target, Pallas).

TPU adaptation notes (vs. the CUDA flash-attention blocking):
  * the grid's innermost dimension iterates **sequentially** on a TPU core, so
    the running max / normalizer / accumulator live in VMEM *scratch* that
    persists across kv-block iterations — no atomics, no shared-memory
    reduction tree;
  * block shapes are MXU/VREG aligned: kv and head dims use 128-lane tiles,
    q-block rows use multiples of 8 (fp32 sublane);
  * causal + sliding-window masks are applied in-kernel with 2-D iota; a
    whole-block skip for fully-future blocks is expressed with ``pl.when``.

Layout: q (B, H, Sq, D), k/v (B, K, Skv, D) — heads-major so one (batch,
q-head) pair maps to one grid row and GQA becomes an index-map division.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 sm_scale: float, causal: bool, window: int,
                 block_q: int, block_k: int, kv_len: int, q_len: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    n_kb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # token coordinates of this (q-block, kv-block) tile
    q_ids = qb * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_ids = kb * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    q_pos = q_ids + (kv_len - q_len)      # align ends (decode: q_len < kv_len)

    mask = (k_ids < kv_len) & (q_ids < q_len)
    if causal:
        mask = mask & (k_ids <= q_pos)
    if window and window > 0:
        mask = mask & (k_ids > q_pos - window)

    def _compute():
        q = q_ref[0].astype(jnp.float32)             # (block_q, d)
        k = k_ref[0].astype(jnp.float32)             # (block_k, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                           # (block_q, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        # skip blocks whose every key is in the strict future of every query
        last_q_pos = qb * block_q + block_q - 1 + (kv_len - q_len)
        pl.when(kb * block_k <= last_q_pos)(_compute)
    else:
        _compute()

    @pl.when(kb == n_kb - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)              # fully-masked rows
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "causal", "window", "block_q", "block_k",
                     "interpret"))
def flash_attention_hmajor(q, k, v, *, sm_scale=None, causal=True, window=0,
                           block_q=128, block_k=128, interpret=False):
    """q: (B, H, Sq, D); k, v: (B, K, Skv, D), block-aligned (see ops.py)."""
    b, h, sq, d = q.shape
    _, kh, skv, _ = k.shape
    assert h % kh == 0, (h, kh)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv)
    scale = float(sm_scale) if sm_scale is not None else d ** -0.5
    groups = h // kh

    qr = q.reshape(b * h, sq, d)
    kr = k.reshape(b * kh, skv, d)
    vr = v.reshape(b * kh, skv, d)

    grid = (b * h, sq // block_q, skv // block_k)

    def q_map(bh, qb, kb):
        return (bh, qb, 0)

    def kv_map(bh, qb, kb):
        # GQA: q-head bh reads kv head (bh % h) // groups of batch bh // h
        return ((bh // h) * kh + (bh % h) // groups, kb, 0)

    kernel = functools.partial(
        _attn_kernel, sm_scale=scale, causal=causal, window=int(window or 0),
        block_q=block_q, block_k=block_k, kv_len=skv, q_len=sq)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running normalizer
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d)
