"""Pure-jnp oracle for the flash-attention kernel.

Semantics shared with the kernel:
  * GQA: q has H heads, k/v have K ≤ H heads; q head h reads kv head
    ``h * K // H``.
  * ``causal=True`` applies a lower-triangular mask offset so the last query
    attends to the last key (supports q_len < kv_len for decode).
  * ``window > 0`` additionally restricts each query to the ``window`` most
    recent keys (local / sliding-window attention, gemma3-style).
"""
from __future__ import annotations

import jax.numpy as jnp


def attention_mask(q_len: int, kv_len: int, *, causal: bool, window: int):
    qi = jnp.arange(q_len)[:, None] + (kv_len - q_len)  # align ends
    ki = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window and window > 0:
        mask &= ki > qi - window
    return mask


def mha_reference(q, k, v, *, causal: bool = True, window: int = 0,
                  sm_scale: float | None = None, kv_len_mask=None):
    """q: (B, Sq, H, D); k, v: (B, Skv, K, D).  Returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, skv, kh, _ = k.shape
    assert h % kh == 0, (h, kh)
    groups = h // kh
    scale = sm_scale if sm_scale is not None else d ** -0.5

    kr = jnp.repeat(k, groups, axis=2)
    vr = jnp.repeat(v, groups, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) * scale
    mask = attention_mask(sq, skv, causal=causal, window=window)
    if kv_len_mask is not None:  # (B, Skv) valid-key mask (decode caches)
        mask = mask[None, None] & kv_len_mask[:, None, None, :]
    else:
        mask = mask[None, None]
    logits = jnp.where(mask, logits, -1e30)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
