"""Public jit'd wrapper for the flash-attention kernel.

Accepts the framework-standard (B, S, H, D) layout, pads sequence lengths to
block multiples (masked out in-kernel via the length arguments), transposes
to the kernel's heads-major layout, and dispatches to the Pallas kernel —
``interpret=True`` on CPU (this container), compiled on TPU.

Differentiation: the kernel carries a ``custom_vjp`` whose backward is the
VJP of the pure-jnp oracle (recompute-from-inputs).  On TPU the backward
re-materializes the S×S logits (a dedicated backward kernel is the known
next step); numerically it is exactly the reference gradient.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_hmajor
from .ref import mha_reference


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def flash_attention(q, k, v, *, causal=True, window=0, sm_scale=None,
                    block_q=128, block_k=128, interpret=True):
    """q: (B, Sq, H, D); k, v: (B, Skv, K, D) -> (B, Sq, H, D).
    Differentiable (custom_vjp; backward = oracle VJP)."""
    fn = _diffable(bool(causal), int(window or 0),
                   float(sm_scale) if sm_scale is not None else None,
                   block_q, block_k, bool(interpret))
    return fn(q, k, v)


@functools.lru_cache(maxsize=None)
def _diffable(causal, window, sm_scale, block_q, block_k, interpret):
    @jax.custom_vjp
    def f(q, k, v):
        return _forward(q, k, v, causal=causal, window=window,
                        sm_scale=sm_scale, block_q=block_q, block_k=block_k,
                        interpret=interpret)

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(
            lambda q_, k_, v_: mha_reference(q_, k_, v_, causal=causal,
                                             window=window,
                                             sm_scale=sm_scale), q, k, v)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def _forward(q, k, v, *, causal=True, window=0, sm_scale=None,
             block_q=128, block_k=128, interpret=True):
    b, sq, h, d = q.shape
    _, skv, kh, _ = k.shape
    bq = min(block_q, max(8, 1 << (sq - 1).bit_length()))
    bk = min(block_k, max(8, 1 << (skv - 1).bit_length()))

    qt = _pad_to(q.transpose(0, 2, 1, 3), 2, bq)
    kt = _pad_to(k.transpose(0, 2, 1, 3), 2, bk)
    vt = _pad_to(v.transpose(0, 2, 1, 3), 2, bk)

    # padding keys must be masked: kernel masks k_ids >= kv_len via kv_len
    # argument == true length? We pass padded lengths; instead mask by
    # shifting: true lengths are threaded through the causal/q-pos logic, so
    # pad on the *left* of kv would break alignment.  We pad on the right and
    # rely on the in-kernel (k_ids < kv_len)&(q_ids < q_len) guards with the
    # *true* lengths baked in below.
    out = _call_padded(qt, kt, vt, sq, skv, causal, window, sm_scale, bq, bk,
                       interpret)
    return out[:, :, :sq, :].transpose(0, 2, 1, 3)


def _call_padded(qt, kt, vt, true_q, true_kv, causal, window, sm_scale,
                 bq, bk, interpret):
    import functools
    from .flash_attention import _attn_kernel, NEG_INF  # noqa: F401
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    b, h, sq, d = qt.shape
    _, kh, skv, _ = kt.shape
    groups = h // kh
    scale = float(sm_scale) if sm_scale is not None else qt.shape[-1] ** -0.5

    qr = qt.reshape(b * h, sq, d)
    kr = kt.reshape(b * kh, skv, d)
    vr = vt.reshape(b * kh, skv, d)
    grid = (b * h, sq // bq, skv // bk)

    kernel = functools.partial(
        _attn_kernel, sm_scale=scale, causal=causal, window=int(window or 0),
        block_q=bq, block_k=bk, kv_len=true_kv, q_len=true_q)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qb, kb: (bh, qb, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda bh, qb, kb: ((bh // h) * kh + (bh % h) // groups,
                                             kb, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda bh, qb, kb: ((bh // h) * kh + (bh % h) // groups,
                                             kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qb, kb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), qt.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d)
