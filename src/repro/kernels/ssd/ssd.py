"""Mamba2 SSD chunked-scan kernel (TPU target, Pallas).

TPU adaptation of the SSD algorithm (Dao & Gu 2024): the recurrence is
re-expressed per chunk of length L as dense matmuls that run on the MXU —

  intra-chunk:  Y_intra = ((C Bᵀ) ⊙ L_decay) X          (L×L by L×P matmul)
  inter-chunk:  Y_inter = cum_a ⊙ (C H_in)               (L×N by N×P matmul)
  state update: H_out   = (Π a)·H_in + (B ⊙ w)ᵀ X        (N×L by L×P matmul)

where ``L_decay[t,s] = Π_{r=s+1..t} a_r`` and ``w_s = Π_{r>s} a_r``.  The
chunk grid dimension iterates sequentially on the core, carrying H in fp32
VMEM scratch.  All tiles are VMEM-resident; L is chosen so (L×L + L×P + N×P)
fp32 fits comfortably (default L=128 ⇒ ≤ 192 KiB for P=N=128).

Layout: x (B·H, T, P), a (B·H, T, 1), b/c (B·H, T, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, h_scr, *, chunk: int):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)              # (L, P)
    a = a_ref[0].astype(jnp.float32)              # (L, 1)
    b = b_ref[0].astype(jnp.float32)              # (L, N)
    c = c_ref[0].astype(jnp.float32)              # (L, N)

    log_a = jnp.log(jnp.maximum(a, 1e-37))        # (L, 1)
    cum = jnp.cumsum(log_a, axis=0)               # log Π_{r<=t} a_r
    # L_decay[t,s] = exp(cum[t] - cum[s]) for s<=t (includes a_t..a_{s+1})
    seg = cum - cum.T                              # (L, L) log decay
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    l_decay = jnp.where(tri, jnp.exp(seg), 0.0)

    g = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, L)
    y_intra = (g * l_decay) @ x                                  # (L, P)

    h_in = h_scr[...]                              # (N, P)
    cum_a = jnp.exp(cum)                           # (L, 1) Π_{r<=t} a_r
    y_inter = (c * cum_a) @ h_in                   # (L, P)

    # state: H_out = (Π a)·H_in + Σ_s (Π_{r>s} a_r)·b_s ⊗ x_s
    total = jnp.exp(cum[-1:])                      # (1, 1)
    w = jnp.exp(cum[-1:] - cum)                    # (L, 1)  Π_{r>s} a_r
    h_scr[...] = total * h_in + jax.lax.dot_general(
        b * w, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (N, P)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_hmajor(x, a, b, c, *, chunk=128, interpret=False):
    """x: (BH, T, P); a: (BH, T, 1); b, c: (BH, T, N) -> y (BH, T, P)."""
    bh, t, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0, (t, chunk)
    grid = (bh, t // chunk)

    def smap(i, cb):
        return (i, cb, 0)

    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), smap),
            pl.BlockSpec((1, chunk, 1), smap),
            pl.BlockSpec((1, chunk, n), smap),
            pl.BlockSpec((1, chunk, n), smap),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), smap),
        out_shape=jax.ShapeDtypeStruct((bh, t, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, a, b, c)
