"""Public jit'd wrapper for the SSD kernel: framework layout (B, T, H, P) /
(B, T, H) / (B, T, H, N); pads T to chunk multiples with a=1, b=0 (state
preserved, no spurious contributions)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import ssd_reference
from .ssd import ssd_hmajor


def ssd(x, a, b, c, *, chunk=128, interpret=True):
    """Differentiable (custom_vjp; backward = oracle VJP)."""
    return _diffable(chunk, bool(interpret))(x, a, b, c)


@functools.lru_cache(maxsize=None)
def _diffable(chunk, interpret):
    @jax.custom_vjp
    def f(x, a, b, c):
        return _forward(x, a, b, c, chunk=chunk, interpret=interpret)

    def fwd(x, a, b, c):
        return f(x, a, b, c), (x, a, b, c)

    def bwd(res, g):
        x, a, b, c = res
        _, vjp = jax.vjp(lambda *args: ssd_reference(*args)[0], x, a, b, c)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def _forward(x, a, b, c, *, chunk=128, interpret=True):
    bs, t, h, p = x.shape
    n = b.shape[-1]
    ch = min(chunk, max(8, t))
    rem = (-t) % ch
    if rem:
        x = jnp.pad(x, [(0, 0), (0, rem), (0, 0), (0, 0)])
        a = jnp.pad(a, [(0, 0), (0, rem), (0, 0)], constant_values=1.0)
        b = jnp.pad(b, [(0, 0), (0, rem), (0, 0), (0, 0)])
        c = jnp.pad(c, [(0, 0), (0, rem), (0, 0), (0, 0)])
    tt = t + rem
    xh = x.transpose(0, 2, 1, 3).reshape(bs * h, tt, p)
    ah = a.transpose(0, 2, 1).reshape(bs * h, tt, 1)
    bh_ = b.transpose(0, 2, 1, 3).reshape(bs * h, tt, n)
    ch_ = c.transpose(0, 2, 1, 3).reshape(bs * h, tt, n)
    y = ssd_hmajor(xh, ah, bh_, ch_, chunk=ch, interpret=interpret)
    return y.reshape(bs, h, tt, p).transpose(0, 2, 1, 3)[:, :t]
