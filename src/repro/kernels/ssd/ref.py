"""Pure-jnp oracle for the Mamba2 SSD (state-space dual) recurrence.

Per head: state H ∈ R^{N×P}; per step scalar decay a_t ∈ (0,1) (head-shared),
input projection b_t ∈ R^N, output projection c_t ∈ R^N, token x_t ∈ R^P:

    H_t = a_t·H_{t-1} + b_t ⊗ x_t
    y_t = c_t · H_t  (+ D·x_t skip handled by the caller)

Shapes: x (B, T, H, P), a (B, T, H), b/c (B, T, H, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_reference(x, a, b, c, initial_state=None):
    bs, t, h, p = x.shape
    n = b.shape[-1]
    x32, a32, b32, c32 = (v.astype(jnp.float32) for v in (x, a, b, c))

    if initial_state is None:
        s0 = jnp.zeros((bs, h, n, p), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)

    def step(s, xs):
        xt, at, bt, ct = xs                       # (B,H,P), (B,H), (B,H,N)
        s = at[..., None, None] * s + bt[..., :, None] * xt[..., None, :]
        y = jnp.einsum("bhn,bhnp->bhp", ct, s)
        return s, y

    xs = (jnp.moveaxis(x32, 1, 0), jnp.moveaxis(a32, 1, 0),
          jnp.moveaxis(b32, 1, 0), jnp.moveaxis(c32, 1, 0))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), s_fin


def ssd_chunked(x, a, b, c, *, chunk=128):
    """Chunked SSD in pure jnp — the same intra/inter-chunk matmul
    re-expression as the Pallas kernel (HBM-friendly: state materializes
    once per chunk, not per timestep), scanning over chunks.

    This is the XLA *engine candidate* for the ssd pattern; the sequential
    scan above is the oracle."""
    bs, t, h, p = x.shape
    n = b.shape[-1]
    ch = min(chunk, t)
    rem = (-t) % ch
    if rem:
        x = jnp.pad(x, [(0, 0), (0, rem), (0, 0), (0, 0)])
        a = jnp.pad(a, [(0, 0), (0, rem), (0, 0)], constant_values=1.0)
        b = jnp.pad(b, [(0, 0), (0, rem), (0, 0), (0, 0)])
        c = jnp.pad(c, [(0, 0), (0, rem), (0, 0), (0, 0)])
    tt = t + rem
    nc = tt // ch

    def to_chunks(v):
        return jnp.moveaxis(
            v.reshape(bs, nc, ch, h, *v.shape[3:]), 1, 0)  # (NC,B,L,H,...)

    xc = to_chunks(x.astype(jnp.float32))
    ac = to_chunks(a[..., None].astype(jnp.float32))[..., 0]   # (NC,B,L,H)
    bc = to_chunks(b.astype(jnp.float32))
    cc = to_chunks(c.astype(jnp.float32))

    tri = jnp.tril(jnp.ones((ch, ch), bool))

    def chunk_step(h_in, xs):
        xk, ak, bk, ck = xs                       # (B,L,H,...) per chunk
        log_a = jnp.log(jnp.maximum(ak, 1e-37))   # (B,L,H)
        cum = jnp.cumsum(log_a, axis=1)
        seg = cum[:, :, None, :] - cum[:, None, :, :]          # (B,L,L,H)
        l_decay = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        g = jnp.einsum("blhn,bshn->blsh", ck, bk)              # (B,L,L,H)
        y_intra = jnp.einsum("blsh,bshp->blhp", g * l_decay, xk)
        cum_a = jnp.exp(cum)                                   # (B,L,H)
        y_inter = jnp.einsum("blhn,bhnp->blhp", ck * cum_a[..., None], h_in)
        w = jnp.exp(cum[:, -1:, :] - cum)                      # (B,L,H)
        h_out = (jnp.exp(cum[:, -1, :])[..., None, None] * h_in
                 + jnp.einsum("blhn,blhp->bhnp", bk * w[..., None], xk))
        return h_out, y_intra + y_inter

    s0 = jnp.zeros((bs, h, n, p), jnp.float32)
    s_fin, ys = jax.lax.scan(chunk_step, s0, (xc, ac, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bs, tt, h, p)[:, :t]
    return y.astype(x.dtype), s_fin
