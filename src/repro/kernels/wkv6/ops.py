"""Public jit'd wrapper for the WKV6 kernel: (B, T, H, D) layout in/out,
sequence padding to chunk multiples (decay of padded steps set to 1 and k=0
so the state is unchanged and outputs beyond T are garbage we slice off)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import wkv6_reference
from .wkv6 import wkv6_hmajor


def wkv6(r, k, v, w, u, *, chunk=128, interpret=True):
    """Differentiable (custom_vjp; backward = oracle VJP)."""
    return _diffable(chunk, bool(interpret))(r, k, v, w, u)


@functools.lru_cache(maxsize=None)
def _diffable(chunk, interpret):
    @jax.custom_vjp
    def f(r, k, v, w, u):
        return _forward(r, k, v, w, u, chunk=chunk, interpret=interpret)

    def fwd(r, k, v, w, u):
        return f(r, k, v, w, u), (r, k, v, w, u)

    def bwd(res, g):
        r, k, v, w, u = res
        _, vjp = jax.vjp(
            lambda *a: wkv6_reference(*a)[0], r, k, v, w, u)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def _forward(r, k, v, w, u, *, chunk=128, interpret=True):
    b, t, h, d = r.shape
    c = min(chunk, max(8, t))
    rem = (-t) % c
    if rem:
        pad = [(0, 0), (0, rem), (0, 0), (0, 0)]
        r = jnp.pad(r, pad)
        k = jnp.pad(k, pad)                     # k=0 ⇒ no state update
        v = jnp.pad(v, pad)
        w = jnp.pad(w, pad, constant_values=1.0)  # w=1 ⇒ state preserved
    y = wkv6_hmajor(r.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                    v.transpose(0, 2, 1, 3), w.transpose(0, 2, 1, 3), u,
                    chunk=c, interpret=interpret)
    return y.transpose(0, 2, 1, 3)[:, :t]
