"""RWKV6 WKV recurrence kernel (TPU target, Pallas).

TPU adaptation: the recurrence is sequential in t, so the kernel keeps the
per-(batch·head) state matrix S ∈ R^{D×D} in fp32 VMEM **scratch** that
persists across the chunk grid dimension (sequential on a TPU core).  Inside
a chunk the timestep loop runs over VMEM-resident (chunk, D) tiles; the
rank-1 update k_t⊗v_t and the row-vector product r_t·S are VPU outer/inner
products (D=64 for rwkv6-3b — one VREG row), so the MXU is deliberately not
used: arithmetic intensity of WKV is O(1) per state element and the op is
bandwidth-bound; the win over the XLA scan is keeping S resident instead of
round-tripping it through HBM every step.

Layout: r/k/v/w (B, H, T, D) heads-major; u (H, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_scr, *,
                 chunk: int):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0].astype(jnp.float32)              # (1, D) row

    def step(t, _):
        rt = r_ref[0, pl.ds(t, 1), :].astype(jnp.float32)   # (1, D)
        kt = k_ref[0, pl.ds(t, 1), :].astype(jnp.float32)
        vt = v_ref[0, pl.ds(t, 1), :].astype(jnp.float32)
        wt = w_ref[0, pl.ds(t, 1), :].astype(jnp.float32)
        kv = kt.T @ vt                                       # (D, D) rank-1
        s = s_scr[...]
        y = rt @ (u.T * kv + s)                              # (1, D)
        s_scr[...] = wt.T * s + kv
        y_ref[0, pl.ds(t, 1), :] = y.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def wkv6_hmajor(r, k, v, w, u, *, chunk=128, interpret=False):
    """r/k/v/w: (B, H, T, D); u: (H, D) -> y (B, H, T, D)."""
    b, h, t, d = r.shape
    assert t % chunk == 0, (t, chunk)
    rr = r.reshape(b * h, t, d)
    kk = k.reshape(b * h, t, d)
    vv = v.reshape(b * h, t, d)
    ww = w.reshape(b * h, t, d)
    uu = jnp.broadcast_to(u[None], (b, h, d)).reshape(b * h, 1, d)

    grid = (b * h, t // chunk)

    def seq_map(bh, cb):
        return (bh, cb, 0)

    def u_map(bh, cb):
        return (bh, 0, 0)

    out = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d), seq_map),
            pl.BlockSpec((1, chunk, d), seq_map),
            pl.BlockSpec((1, chunk, d), seq_map),
            pl.BlockSpec((1, chunk, d), seq_map),
            pl.BlockSpec((1, 1, d), u_map),
        ],
        out_specs=pl.BlockSpec((1, chunk, d), seq_map),
        out_shape=jax.ShapeDtypeStruct((b * h, t, d), r.dtype),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, uu)
    return out.reshape(b, h, t, d)
