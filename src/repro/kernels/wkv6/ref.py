"""Pure-jnp oracle for the RWKV6 (Finch) WKV recurrence.

Per head with head dim D, fp32 state S ∈ R^{D×D}:

    y_t = r_t · (diag(u)·(k_t ⊗ v_t) + S_{t-1})
    S_t = diag(w_t)·S_{t-1} + k_t ⊗ v_t

with data-dependent decay ``w_t ∈ (0,1)`` (the Finch contribution) and the
learned per-head bonus ``u``.  Shapes: r/k/v/w (B, T, H, D), u (H, D).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_reference(r, k, v, w, u, initial_state=None):
    b, t, h, d = r.shape
    r32, k32, v32, w32 = (x.astype(jnp.float32) for x in (r, k, v, w))
    u32 = u.astype(jnp.float32)

    if initial_state is None:
        s0 = jnp.zeros((b, h, d, d), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)

    def step(s, xs):
        rt, kt, vt, wt = xs                      # (B, H, D)
        kv = kt[..., :, None] * vt[..., None, :]  # (B, H, D, D)
        y = jnp.einsum("bhi,bhij->bhj", rt, u32[None, :, :, None] * kv + s)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r32, k32, v32, w32))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)                   # (B, T, H, D)
    return y.astype(r.dtype), s_fin


def wkv6_chunked(r, k, v, w, u, *, chunk=32, clamp=60.0):
    """Chunked WKV6 in pure jnp — the XLA engine candidate.

    The per-timestep scan materializes the D×D state T times (HBM-bound at
    training scale); this form scans over chunks of length L, expressing the
    intra-chunk interaction as an (L,L) per-head matmul with channel-wise
    decay folded into the operands:

        A[t,s] = (r_t ⊙ e^{cw_{t-1}}) · (k_s ⊙ e^{-cw_s}),  s < t

    where cw is the in-chunk cumulative log-decay.  cw ≤ 0, so the r-side
    exponent never overflows; the k-side exponent is clamped at ``clamp``
    (contributions that decayed by e^-60 are zero in fp32 anyway).
    """
    b, t, h, d = r.shape
    ch = min(chunk, t)
    rem = (-t) % ch
    if rem:
        pad = [(0, 0), (0, rem), (0, 0), (0, 0)]
        r = jnp.pad(r, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        w = jnp.pad(w, pad, constant_values=1.0)
    tt = t + rem
    nc = tt // ch

    def to_chunks(x):
        return jnp.moveaxis(x.astype(jnp.float32).reshape(b, nc, ch, h, d),
                            1, 0)                       # (NC,B,L,H,D)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))
    u32 = u.astype(jnp.float32)
    tri = jnp.tril(jnp.ones((ch, ch), bool), k=-1)      # strict lower

    def chunk_step(s_in, xs):
        rk, kk, vk, wk = xs                             # (B,L,H,D)
        logw = jnp.log(jnp.maximum(wk, 1e-37))
        cw = jnp.cumsum(logw, axis=1)                   # (B,L,H,D) ≤ 0
        cw_prev = cw - logw
        q_in = rk * jnp.exp(cw_prev)                    # decayed queries
        k_out = kk * jnp.exp(jnp.minimum(-cw, clamp))   # boosted keys
        a = jnp.einsum("blhd,bshd->bhls", q_in, k_out)
        a = jnp.where(tri[None, None], a, 0.0)
        y = jnp.einsum("bhls,bshd->blhd", a, vk)
        # current-step bonus
        diag = jnp.einsum("blhd,hd,blhd->blh", rk, u32, kk)
        y = y + diag[..., None] * vk
        # inter-chunk carry
        y = y + jnp.einsum("blhd,bhde->blhe", q_in, s_in)
        # state update (exponents ≤ 0)
        decay_to_end = jnp.exp(cw[:, -1:] - cw)         # (B,L,H,D)
        s_out = (jnp.exp(cw[:, -1])[..., None] * s_in
                 + jnp.einsum("blhd,blhe->bhde", kk * decay_to_end, vk))
        return s_out, y

    s0 = jnp.zeros((b, h, d, d), jnp.float32)
    s_fin, ys = jax.lax.scan(chunk_step, s0, (rc, kc, vc, wc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, tt, h, d)[:, :t]
    return y.astype(r.dtype), s_fin
