"""Grouped expert matmul kernel (TPU target, Pallas).

TPU adaptation of megablocks-style grouped GEMM: after capacity dispatch the
token tensor is (E, C, D) and each expert's weight (D, F) is selected by the
leading grid dimension — so expert weights stream HBM→VMEM once per expert
while C×D token tiles and a fp32 accumulator tile stay VMEM-resident.  Tiles
are MXU-aligned (128×128 default); the contraction (k) dimension is the
innermost, sequential grid axis accumulating into scratch, the canonical TPU
matmul pipeline shape.

x: (E, C, D) @ w: (E, D, F) -> (E, C, F)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr):
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kb == pl.num_programs(3) - 1)
    def _fin():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_c", "block_f", "block_d",
                                    "interpret"))
def gmm(x, w, *, block_c=128, block_f=128, block_d=128, interpret=False):
    e, c, d = x.shape
    _, _, f = w.shape
    bc, bd, bf = min(block_c, c), min(block_d, d), min(block_f, f)
    assert c % bc == 0 and d % bd == 0 and f % bf == 0, (c, d, f)

    grid = (e, c // bc, f // bf, d // bd)
    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e_, cb, fb, kb: (e_, cb, kb)),
            pl.BlockSpec((1, bd, bf), lambda e_, cb, fb, kb: (e_, kb, fb)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e_, cb, fb, kb: (e_, cb, fb)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
