"""Pure-jnp oracle for the grouped expert matmul: per-expert token blocks
(after capacity dispatch) times per-expert weights.

x: (E, C, D) tokens grouped by expert (capacity-padded),
w: (E, D, F) expert weights  ->  (E, C, F).
"""
from __future__ import annotations

import jax.numpy as jnp


def gmm_reference(x, w):
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
