"""Public jit'd wrapper for the grouped expert matmul: pads capacity and
feature dims to tile multiples, dispatches to the Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .moe_gmm import gmm as _gmm
from .ref import gmm_reference


def _pad_axis(x, axis, mult):
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def grouped_matmul(x, w, *, block=128, interpret=True):
    """Differentiable (custom_vjp; backward = einsum-oracle VJP)."""
    return _diffable(block, bool(interpret))(x, w)


@functools.lru_cache(maxsize=None)
def _diffable(block, interpret):
    @jax.custom_vjp
    def f(x, w):
        return _forward(x, w, block=block, interpret=interpret)

    def fwd(x, w):
        return f(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        _, vjp = jax.vjp(gmm_reference, x, w)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def _forward(x, w, *, block=128, interpret=True):
    e, c, d = x.shape
    f = w.shape[-1]
    bc = min(block, max(8, c))
    bd = min(block, max(8, d))
    bf = min(block, max(8, f))
    xp = _pad_axis(_pad_axis(x, 1, bc), 2, bd)
    wp = _pad_axis(_pad_axis(w, 1, bd), 2, bf)
    out = _gmm(xp, wp, block_c=bc, block_f=bf, block_d=bd,
               interpret=interpret)
    return out[:, :c, :f]
