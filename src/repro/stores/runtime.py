"""Executor implementations for the tri-store physical operators.

Each store engine owns its impl table (``engines.py``); importing this
module registers the relational / graph / text implementations plus the two
cross-engine transfer realizations.  Store values travel through the plan
as pytrees of JAX arrays (tables as column dicts with a ``_mask`` selection
vector, graphs/corpora as their CSR/COO payload dicts), so a whole
tri-model plan stays jittable end to end.

The relational ops are factored as pure *step functions* shared by the
per-op impls and the fused-chain impls (``rel_fused_*``): a fused chain
executes exactly the same step functions in the same order, so fusion is
bitwise-neutral by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engines import get_engine
from .base import GRAPH_ENGINE, REL_ENGINE, TEXT_ENGINE
from .column_store import MASK, filter_mask, group_agg, hash_join, table_mask
from .graph_store import (expand_frontier, expand_frontier_blockskip,
                          pagerank, triangle_count)
from .masked_kernels import masked_segment_agg_pallas, masked_tfidf_pallas
from .text_store import (masked_topk, tfidf_scores, tfidf_topk,
                         tfidf_topk_blockskip, tfidf_topk_masked)

_XLA = get_engine("xla")
_PALLAS = get_engine("pallas")


# --------------------------------------------------------------------------
# relational engine: step functions + per-op impls
# --------------------------------------------------------------------------


def _step_rel_scan(tbl, attrs):
    tbl = dict(tbl)
    mask = table_mask(tbl)
    cols = attrs.get("cols")
    if cols:
        tbl = {c: tbl[c] for c in cols}
    tbl.pop(MASK, None)
    tbl[MASK] = mask
    return tbl


def _step_rel_filter(tbl, attrs):
    tbl = dict(tbl)
    m = filter_mask(tbl[attrs["col"]], attrs["cmp"], attrs["value"])
    tbl[MASK] = table_mask(tbl) & m
    return tbl


def _step_rel_join(left, right, attrs):
    left, right = dict(left), dict(right)
    lo, ro = attrs["left_on"], attrs["right_on"]
    idx, matched = hash_join(left[lo], right[ro])
    lmask = table_mask(left)
    rmask = table_mask(right)[idx]
    out = {k: v for k, v in left.items() if k != MASK}
    for k, v in right.items():
        if k in (ro, MASK) or k in out:
            continue
        out[k] = v[idx]
    out[MASK] = lmask & matched & rmask
    return out


def _step_rel_group_agg(tbl, attrs):
    key = tbl[attrs["key"]]
    g = int(attrs["num_groups"])
    mask = table_mask(tbl)
    out = {attrs["key"]: jnp.arange(g, dtype=jnp.int32)}
    for out_name, fn, col in attrs["aggs"]:
        vals = None if fn == "count" else tbl[col]
        r = group_agg(vals, key, g, mask, fn)
        if fn == "max":
            r, _valid = r      # empty groups already drop via the count mask
        out[out_name] = r
    count = group_agg(None, key, g, mask, "count")
    out[MASK] = count > 0
    return out


_REL_STEPS = {
    "rel_scan": lambda ins, attrs: _step_rel_scan(ins[0], attrs),
    "rel_filter": lambda ins, attrs: _step_rel_filter(ins[0], attrs),
    "rel_join": lambda ins, attrs: _step_rel_join(ins[0], ins[1], attrs),
    "rel_group_agg": lambda ins, attrs: _step_rel_group_agg(ins[0], attrs),
}


def _run_chain(args, chain, *, stop_before_last=False):
    """Execute a ``rel_fused`` step chain over the node's bound inputs."""
    steps = chain[:-1] if stop_before_last else chain
    prev = None
    for op, attrs, srcs, _out_t in steps:
        ins = [prev if s == "prev" else args[int(s)] for s in srcs]
        prev = _REL_STEPS[op](ins, attrs)
    return prev


@REL_ENGINE.impl("rel_scan_col")
def _i_rel_scan(ctx, args, node):
    return _step_rel_scan(args[0], node.attrs)


@REL_ENGINE.impl("rel_filter_col")
def _i_rel_filter(ctx, args, node):
    return _step_rel_filter(args[0], node.attrs)


@REL_ENGINE.impl("rel_hash_join")
def _i_rel_join(ctx, args, node):
    return _step_rel_join(args[0], args[1], node.attrs)


@REL_ENGINE.impl("rel_group_agg_col")
def _i_rel_group(ctx, args, node):
    return _step_rel_group_agg(args[0], node.attrs)


@REL_ENGINE.impl("rel_fused_col")
def _i_rel_fused(ctx, args, node):
    return _run_chain(args, node.attrs["chain"])


@_PALLAS.impl("rel_fused_agg_pallas")
def _i_rel_fused_agg(ctx, args, node):
    """Fused chain whose terminal group-by runs the masked segment-
    aggregate Pallas kernel (sum/count/mean; gated by the pattern set)."""
    chain = node.attrs["chain"]
    tbl = _run_chain(args, chain, stop_before_last=True)
    attrs = chain[-1][1]
    key = tbl[attrs["key"]]
    g = int(attrs["num_groups"])
    mw = table_mask(tbl).astype(jnp.float32)
    out = {attrs["key"]: jnp.arange(g, dtype=jnp.int32)}
    count = None
    for out_name, fn, col in attrs["aggs"]:
        vals = mw if fn == "count" else tbl[col]
        s, c = masked_segment_agg_pallas(vals, key, mw, num_groups=g,
                                         interpret=ctx.interpret)
        count = c
        out[out_name] = (c if fn == "count"
                         else s if fn == "sum"
                         else s / jnp.maximum(c, 1.0))
    if count is None:
        count, _ = masked_segment_agg_pallas(mw, key, mw, num_groups=g,
                                             interpret=ctx.interpret)
    out[MASK] = count > 0
    return out


@REL_ENGINE.impl("col_tensor_rel")
def _i_col_tensor(ctx, args, node):
    tbl = args[0]
    v = tbl[node.attrs["col"]].astype(node.attrs.get("dtype", "float32"))
    return jnp.where(table_mask(tbl), v, jnp.zeros_like(v))


@REL_ENGINE.impl("sel_mask_rel")
def _i_sel_mask(ctx, args, node):
    """Selection-mask export: scatter the relation's mask over an entity
    domain (``mask[v] = any selected row with col == v``) — the boolean
    predicate pushdown hands across the engine boundary."""
    tbl = args[0]
    col = tbl[node.attrs["col"]]
    size = int(node.attrs["size"])
    m = table_mask(tbl) & (col >= 0) & (col < size)
    idx = jnp.clip(col, 0, size - 1)
    return jnp.zeros((size,), jnp.bool_).at[idx].max(m)


# --------------------------------------------------------------------------
# graph engine (CSR fallback) + Pallas frontier kernels
# --------------------------------------------------------------------------


@GRAPH_ENGINE.impl("graph_expand_csr")
def _i_expand_csr(ctx, args, node):
    return expand_frontier(args[0], args[1],
                           hops=int(node.attrs.get("hops", 1)))


@GRAPH_ENGINE.impl("graph_expand_skip")
def _i_expand_skip(ctx, args, node):
    return expand_frontier_blockskip(args[0], args[1],
                                     hops=int(node.attrs.get("hops", 1)))


@_PALLAS.impl("graph_expand_pallas")
def _i_expand_pallas(ctx, args, node):
    return expand_frontier(args[0], args[1],
                           hops=int(node.attrs.get("hops", 1)),
                           use_pallas=True, interpret=ctx.interpret)


@GRAPH_ENGINE.impl("graph_pagerank_csr")
def _i_pagerank_csr(ctx, args, node):
    return pagerank(args[0], iters=int(node.attrs.get("iters", 10)),
                    damping=float(node.attrs.get("damping", 0.85)),
                    personalization=args[1] if len(args) > 1 else None)


@_PALLAS.impl("graph_pagerank_pallas")
def _i_pagerank_pallas(ctx, args, node):
    return pagerank(args[0], iters=int(node.attrs.get("iters", 10)),
                    damping=float(node.attrs.get("damping", 0.85)),
                    personalization=args[1] if len(args) > 1 else None,
                    use_pallas=True, interpret=ctx.interpret)


@GRAPH_ENGINE.impl("graph_tricount_csr")
def _i_tricount(ctx, args, node):
    return triangle_count(args[0])


# --------------------------------------------------------------------------
# text engine
# --------------------------------------------------------------------------


def _topk_table(ids, scores, valid):
    return {"doc": ids, "score": scores, MASK: valid}


@TEXT_ENGINE.impl("text_topk_inv")
def _i_text_topk(ctx, args, node):
    k = int(node.attrs["k"])
    if len(args) == 3:
        # pushed candidate-doc mask, dense realization: score the whole
        # corpus, then mask + top-k (the bitwise reference the skipping
        # candidates must reproduce)
        return _topk_table(*tfidf_topk_masked(args[0], args[1], args[2], k))
    return _topk_table(*tfidf_topk(args[0], args[1], k))


@TEXT_ENGINE.impl("text_topk_skip_inv")
def _i_text_topk_skip(ctx, args, node):
    return _topk_table(*tfidf_topk_blockskip(args[0], args[1], args[2],
                                             int(node.attrs["k"])))


@_PALLAS.impl("text_topk_masked_pallas")
def _i_text_topk_pallas(ctx, args, node):
    """Masked TF-IDF scoring through the one-hot-matmul superkernel: the
    per-posting gathers run in XLA, the masked fused reduce in Pallas."""
    corpus, query, doc_mask = args
    w = query.astype(jnp.float32) * corpus["idf"]
    doc_ids = corpus["doc_ids"]
    scores = masked_tfidf_pallas(
        doc_ids, w[corpus["term_ids"]], corpus["tf"],
        corpus["doc_len"][doc_ids], doc_mask[doc_ids],
        n_docs=int(corpus["doc_len"].shape[0]), interpret=ctx.interpret)
    return _topk_table(*masked_topk(scores, doc_mask, int(node.attrs["k"])))


@TEXT_ENGINE.impl("text_scores_inv")
def _i_text_scores(ctx, args, node):
    return tfidf_scores(args[0], args[1])


@_XLA.impl("masked_topk_xla")
def _i_masked_topk(ctx, args, node):
    return _topk_table(*masked_topk(args[0], args[1],
                                    int(node.attrs["k"])))


# --------------------------------------------------------------------------
# cross-engine transfer
# --------------------------------------------------------------------------


@_XLA.impl("xfer_pin")
def _i_xfer_pin(ctx, args, node):
    # AWESOME's in-memory placement: the value stays device-resident; the
    # receiving engine reads it in place (a no-op at run time — the win is
    # exactly that nothing happens here)
    return args[0]


def _host_roundtrip(v):
    return jax.tree.map(lambda a: np.array(a, copy=True), v)


@_XLA.impl("xfer_spill")
def _i_xfer_spill(ctx, args, node):
    # per-op materialization: the value round-trips device -> host -> device
    # (what a naive federated mediator does between every engine call).
    # pure_callback keeps this expressible under jit while still forcing
    # the host copy at every execution.
    x = args[0]
    shapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)), x)
    return jax.pure_callback(_host_roundtrip, shapes, x)
